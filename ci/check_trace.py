#!/usr/bin/env python3
"""Validate `udcnn --trace` / `--metrics` artifacts in CI.

The CLI hand-renders its JSON (the offline build has no serde), so CI
re-parses every artifact with an independent parser and checks the
trace actually covers the subsystems the smoke run exercised.

Usage:
    check_trace.py trace   FILE CAT[,CAT...]    Chrome trace: valid JSON,
                                                >= 1 event per required cat
    check_trace.py metrics FILE NAME[,NAME...]  metrics snapshot: valid JSON,
                                                required counters present
"""

import json
import sys


def fail(msg):
    print(f"check_trace: FAIL: {msg}")
    sys.exit(1)


def check_trace(path, cats):
    with open(path) as f:
        doc = json.load(f)
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        fail(f"{path}: no traceEvents array")
    seen = {}
    for e in events:
        cat = e.get("cat")
        if cat:
            seen[cat] = seen.get(cat, 0) + 1
    for cat in cats:
        if not seen.get(cat):
            fail(f"{path}: no events with cat '{cat}' (saw {sorted(seen)})")
    print(f"check_trace: OK: {path}: {len(events)} events, cats {sorted(seen)}")


def check_metrics(path, names):
    with open(path) as f:
        doc = json.load(f)
    counters = doc.get("counters")
    if not isinstance(counters, dict):
        fail(f"{path}: no counters object")
    for name in names:
        if name not in counters:
            fail(f"{path}: counter '{name}' missing (have {sorted(counters)})")
    print(f"check_trace: OK: {path}: {len(counters)} counters")


def main(argv):
    if len(argv) != 4 or argv[1] not in ("trace", "metrics"):
        print(__doc__)
        return 2
    required = [s for s in argv[3].split(",") if s]
    if argv[1] == "trace":
        check_trace(argv[2], required)
    else:
        check_metrics(argv[2], required)
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
