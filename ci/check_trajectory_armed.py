#!/usr/bin/env python3
"""Fail CI loudly while the committed perf trajectory is a placeholder.

`tests/perf_gate.rs` gates simulated throughput against the latest
record of the committed `BENCH_trajectory.json`. A record with no
points (the bootstrap placeholder) makes that gate vacuous: every run
passes because there is nothing to compare against.

This check runs AFTER `cargo bench --bench trajectory`, which appends
a freshly measured record to the working-tree copy. It fails when:

  1. the working-tree file still has no measured points (the bench did
     not run or wrote nothing), or
  2. the committed copy (`git show HEAD:BENCH_trajectory.json`) has no
     record with points — i.e. the repository is still shipping the
     placeholder while CI demonstrably measured real numbers.

On failure (2) it prints the freshly measured record so arming the
gate is one copy-paste: commit the working-tree file.

Usage:
    check_trajectory_armed.py [FILE]    default: BENCH_trajectory.json
"""

import json
import subprocess
import sys

TRAJECTORY = "BENCH_trajectory.json"


def fail(msg):
    print(f"check_trajectory_armed: FAIL: {msg}")
    sys.exit(1)


def records_of(doc, origin):
    records = doc.get("records")
    if not isinstance(records, list) or not records:
        fail(f"{origin}: no records array")
    return records


def armed_records(records):
    return [r for r in records if r.get("points")]


def main(argv):
    path = argv[1] if len(argv) > 1 else TRAJECTORY

    with open(path) as f:
        working = json.load(f)
    measured = armed_records(records_of(working, path))
    if not measured:
        fail(
            f"{path}: the trajectory bench left no measured points — "
            "run `cargo bench --bench trajectory` before this check"
        )
    fresh = measured[-1]
    n = len(fresh.get("points", []))
    print(
        f"check_trajectory_armed: working tree has record "
        f"'{fresh.get('label')}' with {n} points"
    )

    try:
        committed_text = subprocess.check_output(
            ["git", "show", f"HEAD:{path}"], text=True
        )
    except (subprocess.CalledProcessError, OSError) as e:
        fail(f"cannot read committed {path} via git show: {e}")
    committed = json.loads(committed_text)
    if not armed_records(records_of(committed, f"HEAD:{path}")):
        print(
            f"check_trajectory_armed: the committed {path} is still the "
            "empty bootstrap placeholder — the perf gate "
            "(tests/perf_gate.rs) is NOT armed and passes vacuously."
        )
        print(
            "The numbers are simulated (deterministic on every host), so "
            "this run's freshly measured record is the baseline to ship. "
            f"Commit the updated {path}; its latest record is:"
        )
        print(json.dumps(fresh, indent=2))
        fail(f"committed {path} has no record with measured points")
    print(f"check_trajectory_armed: OK: committed {path} carries measured points")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
