//! Minimal, offline, API-compatible shim for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so the subset of
//! `anyhow` this repo actually uses is vendored here: [`Error`],
//! [`Result`], the [`anyhow!`] / [`bail!`] macros, the [`Context`]
//! extension trait, and a blanket `From<E: std::error::Error>` so `?`
//! works on std error types. Like the real crate, [`Error`]
//! deliberately does **not** implement `std::error::Error` (that is
//! what makes the blanket `From` impl coherent).
//!
//! Display follows anyhow's convention: `{}` prints the outermost
//! message, `{:#}` prints the whole cause chain joined with `": "`.

use std::fmt;

/// A dynamic error: an outermost message plus its cause chain,
/// captured as strings at construction time.
pub struct Error {
    chain: Vec<String>,
}

/// `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        let mut chain = vec![context.to_string()];
        chain.extend(self.chain);
        Error { chain }
    }

    /// The cause-chain messages, outermost first.
    pub fn chain_messages(&self) -> &[String] {
        &self.chain
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

/// `Debug` renders the full chain (what `unwrap()` panics print).
impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Attach context to a `Result`'s error, like `anyhow::Context`.
pub trait Context<T> {
    /// Wrap the error with `context`.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Wrap the error with lazily-evaluated context.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from format arguments.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("missing"));
    }

    #[test]
    fn alternate_display_shows_chain() {
        let e: Result<()> = Err(io_err()).context("opening config");
        let e = e.unwrap_err();
        assert_eq!(format!("{e}"), "opening config");
        let full = format!("{e:#}");
        assert!(full.starts_with("opening config: "), "{full}");
        assert!(full.contains("missing"));
    }

    #[test]
    fn bail_and_anyhow_macros() {
        fn f(x: u32) -> Result<u32> {
            if x == 0 {
                bail!("zero not allowed (got {x})");
            }
            Ok(x)
        }
        assert!(f(1).is_ok());
        let e = f(0).unwrap_err();
        assert!(e.to_string().contains("zero not allowed"));
        let e2 = anyhow!("custom {}", 42);
        assert_eq!(e2.to_string(), "custom 42");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("nothing there").unwrap_err();
        assert_eq!(e.to_string(), "nothing there");
    }
}
