//! End-to-end network bench: compile every zoo network through the
//! graph compiler and execute the resulting [`NetworkPlan`]s —
//! whole-network latency, TOPS and DDR traffic, plus the compile-time
//! cost itself.
//!
//! Alongside the text report it emits `reports/BENCH_e2e.json`
//! (machine-readable per-network latency/TOPS) so the perf trajectory
//! is tracked across PRs.

use udcnn::accel::{simulate_network, AccelConfig};
use udcnn::benchkit::{header, write_report_file, Bench};
use udcnn::dcnn::zoo;
use udcnn::graph::{self, NetworkGraph};
use udcnn::report::json::{array, JsonObj};
use udcnn::report::Table;

const REPORT_PATH: &str = "reports/BENCH_e2e.json";

fn main() {
    header(
        "e2e_network",
        "whole-network execution plans (graph IR + compiler, batch 8)",
    );

    let bench = Bench::from_env();
    let mut t = Table::new(
        "end-to-end network execution (pipelined plans)",
        &[
            "network", "steps", "reused", "ms/batch", "ms/item", "eff TOPS", "DDR MiB",
            "saved KiB", "compile",
        ],
    );
    let mut rows = Vec::new();
    for net in zoo::all_benchmarks() {
        let cfg = AccelConfig::paper_for(net.dims);
        let plan = graph::compile_network(&cfg, &net).expect("zoo networks compile");
        let m = graph::simulate_plan(&plan);
        let iso = simulate_network(&cfg, &net);

        // wall-clock cost of the compiler itself (graph build + passes
        // + plan), the part that runs per served model
        let compile_cost = bench.run(&format!("compile {}", net.name), || {
            let g = NetworkGraph::from_network(&net);
            let lowered = graph::passes::lower(&g).unwrap();
            let p = graph::compile(&cfg, &lowered).unwrap();
            std::hint::black_box(p.steps.len());
        });

        t.row(&[
            net.name.to_string(),
            plan.steps.len().to_string(),
            plan.reused_edges().to_string(),
            format!("{:.3}", m.time_s() * 1e3),
            format!("{:.3}", m.time_per_item_s() * 1e3),
            format!("{:.2}", m.effective_tops()),
            format!("{:.2}", m.dram_bytes as f64 / (1024.0 * 1024.0)),
            format!("{:.0}", plan.bytes_saved() as f64 / 1024.0),
            udcnn::benchkit::fmt_duration(compile_cost.median_s()),
        ]);

        rows.push(
            JsonObj::new()
                .str("network", net.name)
                .int("batch", cfg.batch as u64)
                .int("steps", plan.steps.len() as u64)
                .int("reused_edges", plan.reused_edges() as u64)
                .int("total_cycles", m.total_cycles)
                .num("latency_ms_batch", m.time_s() * 1e3)
                .num("latency_ms_item", m.time_per_item_s() * 1e3)
                .num("effective_tops", m.effective_tops())
                .num("useful_tops", m.useful_tops())
                .num("isolated_effective_tops", iso.effective_tops())
                .int("dram_bytes", m.dram_bytes)
                .int("dram_bytes_saved", plan.bytes_saved())
                .num("compile_median_s", compile_cost.median_s())
                .render(),
        );
    }
    t.print();

    let doc = JsonObj::new()
        .str("bench", "e2e_network")
        .str("unit_latency", "ms")
        .raw("networks", &array(&rows))
        .render();
    match write_report_file(REPORT_PATH, &doc) {
        Ok(()) => println!("wrote {REPORT_PATH}"),
        Err(e) => eprintln!("could not write {REPORT_PATH}: {e}"),
    }
}
