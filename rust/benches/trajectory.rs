//! `cargo bench --bench trajectory` — measure the fixed operating
//! points and update `BENCH_trajectory.json` at the repo root.
//!
//! Unlike the wall-clock benches this one records *simulated* numbers
//! only, so it ignores `UDCNN_BENCH_FAST`: the committed record must
//! be canonical and identical on every host. The record label comes
//! from `UDCNN_TRAJ_LABEL` (default `HEAD`); a record with the same
//! label is replaced in place, anything else is appended — one record
//! per PR.

use udcnn::benchkit::trajectory::{
    measure_all, parse_file, render_file, trajectory_path, TrajectoryRecord,
};
use udcnn::benchkit::write_report_file;
use udcnn::report::Table;

fn main() {
    let points = match measure_all() {
        Ok(p) => p,
        Err(e) => {
            eprintln!("trajectory measurement failed: {e}");
            std::process::exit(1);
        }
    };

    let mut t = Table::new(
        "Performance trajectory — fixed operating points (simulated)",
        &["point", "Mcycles", "throughput"],
    );
    for p in &points {
        t.row(&[
            p.point.id(),
            format!("{:.2}", p.total_cycles as f64 / 1e6),
            format!("{:.1}", p.throughput),
        ]);
    }
    t.print();

    let path = trajectory_path();
    let mut records = match std::fs::read_to_string(&path) {
        Ok(text) => match parse_file(&text) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("refusing to overwrite unparseable {path}: {e}");
                std::process::exit(1);
            }
        },
        Err(_) => Vec::new(),
    };

    let label = std::env::var("UDCNN_TRAJ_LABEL").unwrap_or_else(|_| "HEAD".to_string());
    let record = TrajectoryRecord {
        label: label.clone(),
        points: points
            .iter()
            .map(|p| (p.point.id(), p.total_cycles, p.throughput))
            .collect(),
    };
    match records.iter_mut().find(|r| r.label == label) {
        Some(existing) => *existing = record,
        None => records.push(record),
    }

    if let Err(e) = write_report_file(&path, &render_file(&records)) {
        eprintln!("failed to write {path}: {e}");
        std::process::exit(1);
    }
    println!("updated {path} (record '{label}', {} points)", points.len());
}
