//! Fig. 6(a) — PE utilization across all benchmark layers.
//!
//! Paper shape: ≥90 % everywhere except the memory-bound fourth
//! layers of DCGAN / GP-GAN (and 3D-GAN's single-channel tail, which
//! cannot fill both T_m groups).

use udcnn::accel::{simulate_layer, AccelConfig};
use udcnn::benchkit::{header, Bench};
use udcnn::dcnn::zoo;
use udcnn::report::{bar_chart, Table};

fn main() {
    header("fig6_pe_utilization", "Fig. 6(a) — PE utilization per layer");

    let mut t = Table::new(
        "PE utilization (batch 8, 200 MHz)",
        &["layer", "bound-by", "util %", "compute-only util %"],
    );
    let mut chart = Vec::new();
    for net in zoo::all_benchmarks() {
        let cfg = AccelConfig::paper_for(net.dims);
        for layer in &net.layers {
            let m = simulate_layer(&cfg, layer);
            t.row(&[
                layer.name.clone(),
                m.bound_by.to_string(),
                format!("{:.1}", 100.0 * m.pe_utilization()),
                format!("{:.1}", 100.0 * m.compute_utilization()),
            ]);
            chart.push((layer.name.clone(), 100.0 * m.pe_utilization()));
        }
    }
    t.print();
    print!("{}", bar_chart("PE utilization (%)", &chart, "%", 40));

    // simulator throughput (the thing cargo-bench actually times)
    let b = Bench::from_env();
    let cfg = AccelConfig::paper_3d();
    let nets = zoo::all_benchmarks();
    let r = b.run("simulate_all_16_layers", || {
        for net in &nets {
            let c = AccelConfig::paper_for(net.dims);
            for l in &net.layers {
                std::hint::black_box(simulate_layer(&c, l).total_cycles);
            }
        }
        std::hint::black_box(&cfg);
    });
    println!("\n{}", r.summary());
}
