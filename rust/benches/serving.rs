//! Fleet-serving bench: replay one deterministic open-loop workload
//! (2D + 3D traffic) against fleets of 1/2/4/8 simulated accelerator
//! instances and track throughput scaling, tail latency, shed rate and
//! plan-cache effectiveness.
//!
//! Alongside the fixed-size scaling sweep it runs the autoscaling
//! scenario battery's headline cases (`steady`, `flash-crowd`) so the
//! flash-crowd-vs-fixed-fleet completion ratio and the cost-normalized
//! steady-state figures land in the committed record too.
//!
//! Alongside the text report it emits `reports/BENCH_serving.json`
//! (machine-readable per-fleet-size rows plus per-scenario rows) so
//! the serving-perf trajectory is tracked across PRs, like
//! `BENCH_e2e.json` does for single-network latency.

use udcnn::benchkit::{header, write_report_file, Bench};
use udcnn::coordinator::BatchPolicy;
use udcnn::dcnn::zoo;
use udcnn::report::json::{array, JsonObj};
use udcnn::report::Table;
use udcnn::serve::{poisson_arrivals, run_scenario, Fleet, FleetOptions, ScenarioOverrides};

const REPORT_PATH: &str = "reports/BENCH_serving.json";
const SEED: u64 = 0xF1EE7;
const REQUESTS: usize = 2048;

fn main() {
    header(
        "serving",
        "fleet serving: shard scheduling + plan cache over simulated VC709 instances",
    );

    let bench = Bench::from_env();
    let nets = vec![zoo::dcgan(), zoo::gan3d()];
    let models: Vec<&str> = nets.iter().map(|n| n.name).collect();
    let policy = BatchPolicy::default();

    // saturate the largest fleet: offered load = 2.5x the aggregate
    // full-batch capacity of 8 instances
    let mut probe = Fleet::new(
        nets.clone(),
        FleetOptions {
            instances: 1,
            policy,
            ..FleetOptions::default()
        },
    )
    .expect("zoo networks compile");
    let mut per_req_s = 0.0;
    for m in &models {
        per_req_s += probe.batch_latency_s(m, policy.max_batch).unwrap() / policy.max_batch as f64;
    }
    let single_capacity = models.len() as f64 / per_req_s;
    let rps = 2.5 * 8.0 * single_capacity;
    let workload = poisson_arrivals(SEED, rps, REQUESTS, &models);

    let mut t = Table::new(
        "fleet scaling under one saturating workload (dcgan + 3d-gan)",
        &[
            "instances", "served", "shed", "req/s", "speedup", "p50 ms", "p95 ms", "p99 ms",
            "cache h/m", "harness",
        ],
    );
    let mut rows = Vec::new();
    let mut base_rps = 0.0f64;
    for &n in &[1usize, 2, 4, 8] {
        let opts = FleetOptions {
            instances: n,
            policy,
            latency_budget_s: 0.25,
            ..FleetOptions::default()
        };
        let report = Fleet::new(nets.clone(), opts.clone())
            .expect("fleet comes up")
            .run(&workload)
            .expect("workload replays");
        if n == 1 {
            base_rps = report.throughput_rps;
        }
        let speedup = report.throughput_rps / base_rps;

        // wall-clock cost of the harness itself (fleet bring-up +
        // event loop), the part that runs per capacity-planning query
        let harness_cost = bench.run(&format!("fleet x{n}"), || {
            let r = Fleet::new(nets.clone(), opts.clone())
                .unwrap()
                .run(&workload)
                .unwrap();
            std::hint::black_box(r.served);
        });

        t.row(&[
            n.to_string(),
            report.served.to_string(),
            report.shed.to_string(),
            format!("{:.1}", report.throughput_rps),
            format!("{:.2}x", speedup),
            format!("{:.3}", report.latency.p50_ms),
            format!("{:.3}", report.latency.p95_ms),
            format!("{:.3}", report.latency.p99_ms),
            format!("{}/{}", report.cache.hits, report.cache.misses),
            udcnn::benchkit::fmt_duration(harness_cost.median_s()),
        ]);
        rows.push(
            JsonObj::new()
                .int("instances", n as u64)
                .num("speedup_vs_single", speedup)
                .num("harness_median_s", harness_cost.median_s())
                .raw("report", &report.to_json())
                .render(),
        );
    }
    t.print();

    // Autoscaling scenario rows: the adversarial battery's headline
    // numbers (flash-crowd completions vs the fixed-size baseline,
    // steady-state cost-normalized throughput), all on simulated time.
    let mut st = Table::new(
        "autoscale scenarios (dcgan + 3d-gan)",
        &["scenario", "offered", "completed", "shed", "boards", "p99 ms", "req/s/DSP", "mJ/req"],
    );
    let mut srows = Vec::new();
    let mut crowd_line = None;
    for name in ["steady", "flash-crowd"] {
        let run = run_scenario(name, SEED, &nets, &ScenarioOverrides::default())
            .expect("scenario runs");
        let r = &run.report;
        let (tpd, mj) = r
            .cost
            .as_ref()
            .map_or((0.0, 0.0), |c| (c.throughput_per_dsp, c.mj_per_request));
        st.row(&[
            name.to_string(),
            r.offered.to_string(),
            r.served.to_string(),
            r.shed.to_string(),
            r.instances.to_string(),
            format!("{:.3}", r.latency.p99_ms),
            format!("{tpd:.4}"),
            format!("{mj:.4}"),
        ]);
        if let Some(b) = &run.fixed_baseline {
            let ratio = if b.served > 0 {
                r.served as f64 / b.served as f64
            } else {
                0.0
            };
            crowd_line = Some(format!(
                "flash-crowd: {} completed vs {} on the fixed-size fleet ({ratio:.2}x)",
                r.served, b.served
            ));
        }
        srows.push(run.to_json());
    }
    st.print();
    if let Some(line) = crowd_line {
        println!("{line}");
    }

    let doc = JsonObj::new()
        .str("bench", "serving")
        .str("workload", &format!("poisson seed={SEED} rps={rps:.1} n={REQUESTS}"))
        .num("offered_rps", rps)
        .raw("fleets", &array(&rows))
        .raw("scenarios", &array(&srows))
        .render();
    match write_report_file(REPORT_PATH, &doc) {
        Ok(()) => println!("wrote {REPORT_PATH}"),
        Err(e) => eprintln!("could not write {REPORT_PATH}: {e}"),
    }
}
