//! DSE-autotuner bench: default vs tuned simulated throughput per zoo
//! network, plus the cost of the search itself.
//!
//! For each benchmark network the autotuner picks a configuration
//! under the VC709 budget; this bench compares the compiled-plan
//! simulation of that pick against `AccelConfig::default()` at the
//! same batch size, times the tuner, and records the search's audit
//! counters (candidates evaluated exactly vs pruned by the roofline
//! bound). Alongside the text report it emits
//! `reports/BENCH_dse.json` so the tuning-win trajectory is tracked
//! across PRs, like `BENCH_serving.json` does for fleet scaling.

use udcnn::accel::dse::tune::{tune_network, TuneOptions};
use udcnn::benchkit::{fmt_duration, header, write_report_file, Bench};
use udcnn::dcnn::zoo;
use udcnn::report::json::{array, JsonObj};
use udcnn::report::Table;

const REPORT_PATH: &str = "reports/BENCH_dse.json";

fn main() {
    header(
        "dse_autotune",
        "per-network autotuning of the Table-II mapping parameters (roofline-pruned DSE)",
    );

    let bench = Bench::from_env();
    let opts = TuneOptions::default();

    let mut t = Table::new(
        &format!("default vs tuned compiled-plan TOPS (batch {})", opts.batch),
        &["network", "default", "tuned", "speedup", "config", "bound", "evald", "pruned", "time"],
    );
    let mut rows = Vec::new();
    let mut wins = 0usize;
    let nets = zoo::all_benchmarks();
    let total = nets.len();
    for net in nets {
        let r = tune_network(&net, &opts).expect("zoo networks tune");
        let cost = bench.run(&format!("tune {}", net.name), || {
            let r = tune_network(&net, &opts).unwrap();
            std::hint::black_box(r.best().total_cycles);
        });
        let best = r.best();
        let d = &r.default_point;
        if best.total_cycles < d.total_cycles {
            wins += 1;
        }
        t.row(&[
            net.name.to_string(),
            format!("{:.2}", d.effective_tops),
            format!("{:.2}", best.effective_tops),
            format!("{:.2}x", r.speedup_vs_default()),
            best.cfg.describe(),
            best.bound_by.to_string(),
            r.evaluated.to_string(),
            r.pruned.to_string(),
            fmt_duration(cost.median_s()),
        ]);
        rows.push(
            JsonObj::new()
                .str("network", &r.network)
                .num("default_tops", d.effective_tops)
                .num("tuned_tops", best.effective_tops)
                .num("default_time_ms", d.time_s * 1e3)
                .num("tuned_time_ms", best.time_s * 1e3)
                .num("speedup_vs_default", r.speedup_vs_default())
                .num("tune_median_s", cost.median_s())
                .raw("result", &r.to_json())
                .render(),
        );
    }
    t.print();
    println!(
        "tuned beats AccelConfig::default() on {wins}/{total} zoo networks (ties count as losses)"
    );

    let doc = JsonObj::new()
        .str("bench", "dse_autotune")
        .int("batch", opts.batch as u64)
        .int("networks_improved", wins as u64)
        .int("networks_total", total as u64)
        .raw("networks", &array(&rows))
        .render();
    match write_report_file(REPORT_PATH, &doc) {
        Ok(()) => println!("wrote {REPORT_PATH}"),
        Err(e) => eprintln!("could not write {REPORT_PATH}: {e}"),
    }
}
