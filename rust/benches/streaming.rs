//! Streaming bench: temporal-tiled 3D inference vs whole-volume.
//!
//! For each benchmark network, stream a frame sequence through a
//! [`udcnn::stream::StreamSession`] at several chunk sizes and track:
//!
//! * frames/s from the per-chunk accelerator cycle estimates,
//! * wall-clock of the golden-numerics streaming run,
//! * the session's peak working set against whole-volume execution —
//!   the headline: chunked 3D streaming must run in strictly less
//!   memory than `forward_uniform` (asserted below for the largest 3D
//!   net, so a regression fails the bench).
//!
//! 2D networks appear as the degenerate chunk=1 per-frame passthrough.
//! Emits `reports/BENCH_stream.json`.

use std::time::Instant;

use udcnn::accel::AccelConfig;
use udcnn::dcnn::{synth_frames, synth_uniform_weights, zoo, Dims, Network};
use udcnn::report::json::{array, JsonObj};
use udcnn::report::Table;
use udcnn::stream::stream_forward;

const REPORT_PATH: &str = "reports/BENCH_stream.json";
const SEED: u64 = 0x57A3;

fn main() {
    udcnn::benchkit::header(
        "streaming",
        "temporal-tiled 3D inference (depth halos, overlap-exact tiling) vs whole-volume",
    );
    let fast = std::env::var_os("UDCNN_BENCH_FAST").is_some();
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);

    // (network, frames, chunk sizes); v-net is the largest 3D net.
    let vnet_frames = if fast { 4 } else { 8 };
    let cases: Vec<(Network, usize, Vec<usize>)> = vec![
        (zoo::dcgan(), 4, vec![1]),
        (zoo::gan3d(), 4, vec![1, 2, 4]),
        (zoo::vnet(), vnet_frames, vec![1, 2, vnet_frames]),
    ];

    let mut t = Table::new(
        "streaming vs whole-volume (frames/s from per-chunk cycle estimates)",
        &[
            "network", "frames", "chunk", "frames/s", "wall s", "peak MiB", "whole MiB", "ratio",
        ],
    );
    let mut rows = Vec::new();
    let mut largest_3d_ok = true;
    for (base, frames, chunks) in &cases {
        let net = if base.dims == Dims::D3 {
            base.with_depth(*frames)
        } else {
            base.clone()
        };
        let mut cfg = AccelConfig::paper_for(net.dims);
        cfg.batch = 1;
        let weights = synth_uniform_weights(&net, 0x5EED);
        let input = synth_frames(&net.layers[0], SEED, 0, *frames);
        for &chunk in chunks {
            let t0 = Instant::now();
            let (out, sum) = stream_forward(&net, &weights, &input, chunk, &cfg, threads)
                .expect("streaming run");
            let wall_s = t0.elapsed().as_secs_f64();
            std::hint::black_box(out.len());
            let mib = |e: usize| e as f64 * 4.0 / (1024.0 * 1024.0);
            let below = sum.peak_live_elems < sum.whole_peak_elems;
            if base.name == "v-net" && chunk < *frames && !below {
                largest_3d_ok = false;
            }
            t.row(&[
                sum.network.clone(),
                frames.to_string(),
                chunk.to_string(),
                format!("{:.1}", sum.frames_per_s()),
                format!("{wall_s:.3}"),
                format!("{:.2}", mib(sum.peak_live_elems)),
                format!("{:.2}", mib(sum.whole_peak_elems)),
                format!("{:.2}", sum.peak_ratio()),
            ]);
            rows.push(
                JsonObj::new()
                    .str("base_network", base.name)
                    .int("chunk", chunk as u64)
                    .num("wall_s", wall_s)
                    .str("peak_below_whole", if below { "yes" } else { "no" })
                    .raw("session", &sum.to_json())
                    .render(),
            );
        }
    }
    t.print();

    let doc = JsonObj::new()
        .str("bench", "streaming")
        .str("workload", &format!("seed={SEED:#x} threads={threads} fast={fast}"))
        .str("largest_3d_chunked_below_whole", if largest_3d_ok { "yes" } else { "no" })
        .raw("runs", &array(&rows))
        .render();
    match udcnn::benchkit::write_report_file(REPORT_PATH, &doc) {
        Ok(()) => println!("wrote {REPORT_PATH}"),
        Err(e) => eprintln!("could not write {REPORT_PATH}: {e}"),
    }
    assert!(
        largest_3d_ok,
        "chunked streaming must peak strictly below whole-volume on the largest 3D net"
    );
}
