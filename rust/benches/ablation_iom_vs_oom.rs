//! Ablation A1 — IOM vs OOM on the same mesh.
//!
//! The paper's core mechanism isolated: identical hardware, identical
//! operands, only the mapping discipline changes. Expected: ~S²=4×
//! (2D) and approaching S³=8× (3D) cycle reduction on compute-bound
//! layers; OOM PE utilization collapses to 1−sparsity (Fig. 1's
//! complement). Also sweeps the FIFO-D serialization knob
//! (`depth_overlap_stall`).

use udcnn::accel::{oom, simulate_layer, AccelConfig};
use udcnn::benchkit::header;
use udcnn::dcnn::zoo;
use udcnn::report::Table;

fn main() {
    header("ablation_iom_vs_oom", "§II/§IV-B — mapping discipline ablation");

    let mut t = Table::new(
        "IOM vs OOM (cycles per batch-8 layer)",
        &["layer", "IOM Mcyc", "OOM Mcyc", "speedup", "IOM util %", "OOM util %"],
    );
    let mut speedups_2d = Vec::new();
    let mut speedups_3d = Vec::new();
    for net in zoo::all_benchmarks() {
        let cfg = AccelConfig::paper_for(net.dims);
        for layer in &net.layers {
            let i = simulate_layer(&cfg, layer);
            let o = oom::simulate_oom(&cfg, layer);
            let s = o.total_cycles as f64 / i.total_cycles as f64;
            t.row(&[
                layer.name.clone(),
                format!("{:.2}", i.total_cycles as f64 / 1e6),
                format!("{:.2}", o.total_cycles as f64 / 1e6),
                format!("{s:.2}x"),
                format!("{:.1}", 100.0 * i.pe_utilization()),
                format!("{:.1}", 100.0 * o.pe_utilization()),
            ]);
            match net.dims {
                udcnn::dcnn::Dims::D2 => speedups_2d.push(s),
                udcnn::dcnn::Dims::D3 => speedups_3d.push(s),
            }
        }
    }
    t.print();

    let g2 = udcnn::util::stats::geomean(&speedups_2d);
    let g3 = udcnn::util::stats::geomean(&speedups_3d);
    println!("geomean IOM speedup: 2D {g2:.2}x (→ S²=4), 3D {g3:.2}x (→ S³=8)");

    // FIFO-D serialization knob
    let mut knob = Table::new(
        "FIFO-D port ablation (3D layers)",
        &["layer", "concurrent Mcyc", "serialized Mcyc", "slowdown"],
    );
    for layer in &zoo::gan3d().layers {
        let cfg = AccelConfig::paper_3d();
        let mut cfg_stall = cfg.clone();
        cfg_stall.depth_overlap_stall = true;
        let a = simulate_layer(&cfg, layer);
        let b = simulate_layer(&cfg_stall, layer);
        knob.row(&[
            layer.name.clone(),
            format!("{:.2}", a.total_cycles as f64 / 1e6),
            format!("{:.2}", b.total_cycles as f64 / 1e6),
            format!("{:.2}x", b.total_cycles as f64 / a.total_cycles as f64),
        ]);
    }
    knob.print();
}
