//! Hot-path micro benchmarks — the §Perf instrumentation.
//!
//! Times the three L3 hot paths (timing-tier simulation, functional
//! mesh, golden Q8.8 deconv) plus the CPU-baseline inner loop, so
//! optimization deltas are measurable in isolation. Results feed
//! EXPERIMENTS.md §Perf.

use udcnn::accel::functional::run_layer_2d;
use udcnn::accel::{simulate_layer, AccelConfig};
use udcnn::baseline::CpuBaseline;
use udcnn::benchkit::{header, Bench};
use udcnn::dcnn::{zoo, LayerData, LayerDataQ};
use udcnn::func::deconv_q::deconv2d_iom_q;
use udcnn::func::{deconv2d_iom, deconv2d_oom};

fn main() {
    header("micro_hotpath", "§Perf — hot-path micro benchmarks");
    let b = Bench::from_env();

    // 1. timing-tier simulation of all 16 benchmark layers
    let nets = zoo::all_benchmarks();
    let r = b.run("timing_tier_16_layers", || {
        for net in &nets {
            let cfg = AccelConfig::paper_for(net.dims);
            for l in &net.layers {
                std::hint::black_box(simulate_layer(&cfg, l).total_cycles);
            }
        }
    });
    println!("{}", r.summary());

    // 2. functional mesh on a small layer
    let spec = &zoo::tiny_2d().layers[1];
    let q = LayerData::synth(spec, 1).quantize();
    let (input, weights) = match &q {
        LayerDataQ::D2 { input, weights } => (input.clone(), weights.clone()),
        _ => unreachable!(),
    };
    let cfg = AccelConfig::tiny(2, 2, 1, 4, 4);
    let r = b.run("functional_mesh_tiny2d_l2", || {
        std::hint::black_box(run_layer_2d(&cfg, spec, &input, &weights).stats.macs);
    });
    println!("{}", r.summary());

    // 3. golden Q8.8 IOM on the same layer
    let r = b.run("golden_q88_iom_tiny2d_l2", || {
        std::hint::black_box(deconv2d_iom_q(&input, &weights, spec.s).len());
    });
    println!("{}", r.summary());

    // 4. f32 IOM vs OOM on a mid-size layer (CPU-baseline inner loop)
    let mid = udcnn::dcnn::LayerSpec::new_2d("mid", 32, 16, 16, 32, 3, 2);
    let data = LayerData::synth(&mid, 2);
    let (fin, fw) = match &data {
        LayerData::D2 { input, weights } => (input.clone(), weights.clone()),
        _ => unreachable!(),
    };
    let r = b.run("f32_iom_32x16x16", || {
        std::hint::black_box(deconv2d_iom(&fin, &fw, 2).len());
    });
    println!("{}", r.summary());
    let r = b.run("f32_oom_32x16x16", || {
        std::hint::black_box(deconv2d_oom(&fin, &fw, 2).len());
    });
    println!("{}", r.summary());

    // 5. multithreaded CPU baseline on a DCGAN layer
    let cpu = CpuBaseline::default();
    let l = &zoo::dcgan().layers[2];
    let r = b.run("cpu_baseline_dcgan_l3", || {
        std::hint::black_box(cpu.measure_layer(l));
    });
    println!("{}", r.summary());

    println!("\n(record before/after in EXPERIMENTS.md §Perf)");
}
