//! Fig. 1 — sparsity of the deconvolutional layers (DCGAN vs 3D-GAN).
//!
//! Paper shape: every 3D-GAN layer is sparser than every DCGAN layer;
//! 2D saturates toward 75 % (S=2), 3D toward 87.5 %.

use udcnn::benchkit::{header, Bench};
use udcnn::dcnn::{sparsity, zoo};
use udcnn::report::{bar_chart, Table};

fn main() {
    header("fig1_sparsity", "Fig. 1 — sparsity of the deconvolutional layers");
    let nets = [zoo::dcgan(), zoo::gan3d()];
    let rows = sparsity::fig1_dataset(&nets, 7);

    let mut t = Table::new(
        "Fig. 1 dataset (analytic == counted)",
        &["network", "layer", "analytic", "empirical"],
    );
    let mut chart = Vec::new();
    for r in &rows {
        t.row(&[
            r.network.to_string(),
            r.layer.clone(),
            format!("{:.4}", r.analytic),
            format!("{:.4}", r.empirical),
        ]);
        chart.push((r.layer.clone(), 100.0 * r.analytic));
    }
    t.print();
    print!("{}", bar_chart("sparsity (%)", &chart, "%", 40));

    // timing: the empirical counter itself (exercises zero_insert)
    let b = Bench::from_env();
    let layer = &zoo::gan3d().layers[3];
    let r = b.run("empirical_sparsity(3d-gan.deconv4)", || {
        std::hint::black_box(sparsity::empirical_sparsity(layer, 3));
    });
    println!("\n{}", r.summary());

    // paper check
    let max2 = rows.iter().filter(|r| r.network == "dcgan").map(|r| r.analytic).fold(0.0, f64::max);
    let min3 = rows.iter().filter(|r| r.network == "3d-gan").map(|r| r.analytic).fold(1.0, f64::min);
    println!(
        "\npaper check: max(2D)={:.3} < min(3D)={:.3}  [{}]",
        max2,
        min3,
        if min3 > max2 { "OK" } else { "MISMATCH" }
    );
}
