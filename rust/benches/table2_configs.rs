//! Table II — configurations of the computation engine.
//!
//! Prints the paper's two operating points and the DSE justification:
//! where they rank in the legal design space under the 2048-PE budget.

use udcnn::accel::{dse, AccelConfig};
use udcnn::benchkit::{header, Bench};
use udcnn::dcnn::zoo;
use udcnn::report::Table;

fn main() {
    header("table2_configs", "Table II — configurations of the computation engine");

    let mut t = Table::new(
        "Table II (operating points of the fixed 2048-PE engine)",
        &["benchmarks", "Tm", "Tn", "Tz", "Tr", "Tc", "data width"],
    );
    let c2 = AccelConfig::paper_2d();
    let c3 = AccelConfig::paper_3d();
    t.row(&["2D DCNNs".into(), c2.tm.to_string(), c2.tn.to_string(), c2.tz.to_string(), c2.tr.to_string(), c2.tc.to_string(), c2.data_width_bits.to_string()]);
    t.row(&["3D DCNNs".into(), c3.tm.to_string(), c3.tn.to_string(), c3.tz.to_string(), c3.tr.to_string(), c3.tc.to_string(), c3.data_width_bits.to_string()]);
    t.print();

    let budget = dse::DseBudget::default();
    let bench = Bench::from_env();
    let fast = std::env::var_os("UDCNN_BENCH_FAST").is_some();

    // 2D point vs 2D benchmarks
    let nets2 = if fast { vec![zoo::dcgan()] } else { vec![zoo::dcgan(), zoo::gp_gan()] };
    let r = bench.run("dse_sweep_2d", || {
        std::hint::black_box(dse::sweep(&nets2, &budget).expect("legal space").len());
    });
    println!("{}", r.summary());
    let points = dse::sweep(&nets2, &budget).expect("legal space");
    let paper2 = dse::evaluate(&AccelConfig::paper_2d(), &nets2, &budget);
    let rank2 = points.iter().filter(|p| p.total_cycles < paper2.total_cycles).count();
    println!(
        "2D point rank: {rank2}/{} candidates beat it (util {:.1}%)",
        points.len(),
        100.0 * paper2.avg_utilization
    );

    let nets3 = if fast { vec![zoo::gan3d()] } else { vec![zoo::gan3d(), zoo::vnet()] };
    let points3 = dse::sweep(&nets3, &budget).expect("legal space");
    let paper3 = dse::evaluate(&AccelConfig::paper_3d(), &nets3, &budget);
    let rank3 = points3.iter().filter(|p| p.total_cycles < paper3.total_cycles).count();
    println!(
        "3D point rank: {rank3}/{} candidates beat it (util {:.1}%)",
        points3.len(),
        100.0 * paper3.avg_utilization
    );

    let mut top = Table::new(
        "best-5 design points for the 3D benchmark set",
        &["Tm", "Tn", "Tz", "Tr", "Tc", "PEs", "Mcycles", "util %"],
    );
    for p in points3.iter().take(5) {
        top.row(&[
            p.cfg.tm.to_string(),
            p.cfg.tn.to_string(),
            p.cfg.tz.to_string(),
            p.cfg.tr.to_string(),
            p.cfg.tc.to_string(),
            p.cfg.total_pes().to_string(),
            format!("{:.1}", p.total_cycles as f64 / 1e6),
            format!("{:.1}", 100.0 * p.avg_utilization),
        ]);
    }
    top.print();
}
