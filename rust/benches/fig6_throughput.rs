//! Fig. 6(b) — per-layer throughput.
//!
//! Paper shape: 1.5–3.0 TOPS (dense-equivalent) for the 2D nets with
//! the L4 dip; 3D effective throughput ≥ 2D. We print both the
//! dense-equivalent convention (the paper's headline; see DESIGN.md
//! §3 on why 3D exceeds the paper's 3.0 band under an explicit S³
//! accounting) and useful TOPS (bounded by the 0.82 peak).

use udcnn::accel::{simulate_layer, simulate_network, AccelConfig};
use udcnn::benchkit::header;
use udcnn::dcnn::zoo;
use udcnn::graph;
use udcnn::report::{bar_chart, Table};

fn main() {
    header("fig6_throughput", "Fig. 6(b) — throughput per layer");

    let mut t = Table::new(
        "throughput (batch 8, 200 MHz)",
        &["layer", "eff TOPS", "useful TOPS", "GB/s", "ms/batch"],
    );
    let mut chart = Vec::new();
    for net in zoo::all_benchmarks() {
        let cfg = AccelConfig::paper_for(net.dims);
        for layer in &net.layers {
            let m = simulate_layer(&cfg, layer);
            t.row(&[
                layer.name.clone(),
                format!("{:.2}", m.effective_tops(&cfg)),
                format!("{:.2}", m.useful_tops()),
                format!("{:.1}", m.dram_gbps()),
                format!("{:.3}", m.time_s() * 1e3),
            ]);
            chart.push((layer.name.clone(), m.effective_tops(&cfg)));
        }
    }
    t.print();
    print!("{}", bar_chart("effective TOPS", &chart, "TOPS", 40));

    // paper checks
    let cfg2 = AccelConfig::paper_2d();
    let tops: Vec<f64> = zoo::dcgan()
        .layers
        .iter()
        .map(|l| simulate_layer(&cfg2, l).effective_tops(&cfg2))
        .collect();
    let max2 = tops.iter().cloned().fold(0.0, f64::max);
    let min2 = tops.iter().cloned().fold(f64::MAX, f64::min);
    println!(
        "\npaper check: 2D band [{min2:.2}, {max2:.2}] TOPS vs paper 1.5–3.0  [{}]",
        if min2 > 1.2 && max2 < 3.6 { "OK" } else { "MISMATCH" }
    );
    let cfg3 = AccelConfig::paper_3d();
    let t3 = simulate_layer(&cfg3, &zoo::gan3d().layers[1]).effective_tops(&cfg3);
    println!(
        "paper check: 3D ({t3:.2}) >= 2D ({max2:.2})  [{}]",
        if t3 >= max2 * 0.9 { "OK" } else { "MISMATCH" }
    );

    // network granularity: the graph compiler's pipelined plans vs the
    // isolated-layer sum (same workloads, whole-network execution)
    println!();
    let mut nt = Table::new(
        "whole-network plans (graph compiler, batch 8)",
        &["network", "e2e TOPS", "isolated TOPS", "reused edges", "DDR saved KiB", "ms/batch"],
    );
    for net in zoo::all_benchmarks() {
        let cfg = AccelConfig::paper_for(net.dims);
        let plan = graph::compile_network(&cfg, &net).expect("zoo networks compile");
        let m = graph::simulate_plan(&plan);
        let iso = simulate_network(&cfg, &net);
        nt.row(&[
            net.name.to_string(),
            format!("{:.2}", m.effective_tops()),
            format!("{:.2}", iso.effective_tops()),
            plan.reused_edges().to_string(),
            format!("{:.0}", plan.bytes_saved() as f64 / 1024.0),
            format!("{:.3}", m.time_s() * 1e3),
        ]);
    }
    nt.print();
}
