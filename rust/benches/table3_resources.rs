//! Table III — resource utilization of the Xilinx VC709.
//!
//! The resource model is calibrated so the Table-II engine reproduces
//! the paper's numbers exactly; this bench prints the table and shows
//! how utilization scales with the PE budget (the extrapolation the
//! DSE uses).

use udcnn::accel::AccelConfig;
use udcnn::benchkit::header;
use udcnn::report::Table;
use udcnn::resource;

fn main() {
    header("table3_resources", "Table III — resource utilization of Xilinx VC709");

    let est = resource::estimate(&AccelConfig::paper_3d());
    let p = est.percentages();
    let mut t = Table::new(
        "Table III (paper values: 2304 / 712 / 566182 / 292292)",
        &["resource", "utilization", "percentage (%)"],
    );
    t.row(&["DSP48Es".into(), est.dsp.to_string(), format!("{:.2}", p[0])]);
    t.row(&["BRAMs".into(), est.bram36.to_string(), format!("{:.2}", p[1])]);
    t.row(&["Flip-Flops".into(), est.ff.to_string(), format!("{:.2}", p[2])]);
    t.row(&["LUTs".into(), est.lut.to_string(), format!("{:.2}", p[3])]);
    t.print();
    let exact = est.dsp == 2304 && est.bram36 == 712 && est.ff == 566_182 && est.lut == 292_292;
    println!("paper check: exact match [{}]", if exact { "OK" } else { "MISMATCH" });

    // scaling study: PE budget vs resources
    let mut scale = Table::new(
        "resource scaling with the PE budget",
        &["Tn", "PEs", "DSP", "DSP %", "FF %", "LUT %", "fits"],
    );
    for tn_log in 3..=7 {
        let mut cfg = AccelConfig::paper_2d();
        cfg.tn = 1 << tn_log;
        let e = resource::estimate(&cfg);
        let pp = e.percentages();
        scale.row(&[
            cfg.tn.to_string(),
            cfg.total_pes().to_string(),
            e.dsp.to_string(),
            format!("{:.1}", pp[0]),
            format!("{:.1}", pp[2]),
            format!("{:.1}", pp[3]),
            e.fits_vc709().to_string(),
        ]);
    }
    scale.print();
}
