//! Ablation A2 — the cost of uniformity (§IV-C).
//!
//! Runs the 2D benchmarks on (a) the native 2D operating point, (b)
//! the 3D operating point with T_z folded into channel parallelism
//! (the uniform-architecture path), and (c) a hypothetical
//! non-foldable architecture where the T_z arrays simply idle on 2D
//! work — quantifying what the paper's §IV-C fold buys.

use udcnn::accel::{simulate_network, AccelConfig};
use udcnn::benchkit::header;
use udcnn::dcnn::zoo;
use udcnn::report::Table;

fn main() {
    header("ablation_uniform_mapping", "§IV-C — uniform 2D/3D mapping ablation");

    let mut t = Table::new(
        "2D networks on the three mappings (total Mcycles, batch 8)",
        &["network", "native-2D", "uniform (Tz folded)", "no-fold (Tz idle)", "fold gain"],
    );
    for net in [zoo::dcgan(), zoo::gp_gan()] {
        let native = simulate_network(&AccelConfig::paper_2d(), &net).total_cycles();
        let folded = simulate_network(&AccelConfig::paper_3d(), &net).total_cycles();
        // no-fold: T_z arrays idle -> effectively a 512-PE machine
        let mut idle = AccelConfig::paper_3d();
        idle.tz = 1; // 2*16*1*4*4 = 512 PEs
        let no_fold = simulate_network(&idle, &net).total_cycles();
        t.row(&[
            net.name.to_string(),
            format!("{:.2}", native as f64 / 1e6),
            format!("{:.2}", folded as f64 / 1e6),
            format!("{:.2}", no_fold as f64 / 1e6),
            format!("{:.2}x", no_fold as f64 / folded as f64),
        ]);
    }
    t.print();

    // 3D nets are unaffected by the fold (sanity row)
    let mut t3 = Table::new(
        "3D networks (fold is a no-op)",
        &["network", "3D point Mcycles", "avg util %"],
    );
    for net in [zoo::gan3d(), zoo::vnet()] {
        let m = simulate_network(&AccelConfig::paper_3d(), &net);
        t3.row(&[
            net.name.to_string(),
            format!("{:.2}", m.total_cycles() as f64 / 1e6),
            format!("{:.1}", 100.0 * m.avg_pe_utilization()),
        ]);
    }
    t3.print();

    let native = simulate_network(&AccelConfig::paper_2d(), &zoo::dcgan()).total_cycles();
    let folded = simulate_network(&AccelConfig::paper_3d(), &zoo::dcgan()).total_cycles();
    println!(
        "paper check: uniform-mapping overhead on DCGAN {:.1}% (should be small)  [{}]",
        100.0 * (folded as f64 / native as f64 - 1.0),
        if (folded as f64 / native as f64) < 1.15 { "OK" } else { "MISMATCH" }
    );
}
