//! Fig. 7 — relative performance (a) and energy efficiency (b) of the
//! CPU, GPU and FPGA solutions across the four benchmarks.
//!
//! CPU times are **measured** on this host (multithreaded OOM
//! deconvolution; large layers extrapolated from the calibrated
//! effective GFLOPS — flagged in the table). GPU times come from the
//! explicit GTX 1080 model. FPGA times come from the timing tier.
//! Paper shape: FPGA 22.7–63.3× over CPU in throughput; 104.7–291.4×
//! over CPU and 3.3–8.3× over GPU in energy efficiency.

use udcnn::accel::{simulate_network, AccelConfig};
use udcnn::baseline::{CpuBaseline, GpuModel};
use udcnn::benchkit::header;
use udcnn::dcnn::zoo;
use udcnn::energy;
use udcnn::report::{ratio, Table};

fn main() {
    header("fig7_cpu_gpu", "Fig. 7 — CPU vs GPU vs FPGA (throughput + energy)");

    let cpu = CpuBaseline::default();
    let gpu = GpuModel::default();
    let batch = 8usize;
    println!(
        "host CPU: {} threads, calibrated {:.1} dense GFLOPS\n",
        cpu.threads,
        cpu.calibrated_gflops()
    );

    let mut perf = Table::new(
        "Fig. 7(a) — relative performance (batch 8)",
        &["network", "FPGA ms", "GPU ms", "CPU ms", "cpu src", "FPGA/CPU", "FPGA/GPU"],
    );
    let mut eff = Table::new(
        "Fig. 7(b) — energy efficiency (GOPS/J, dense-equivalent)",
        &["network", "FPGA", "GPU", "CPU", "vs CPU", "vs GPU"],
    );

    let mut cpu_ratios = Vec::new();
    let mut gpu_energy_ratios = Vec::new();
    for net in zoo::all_benchmarks() {
        let mut cfg = AccelConfig::paper_for(net.dims);
        cfg.batch = batch;
        let fm = simulate_network(&cfg, &net);
        let t_fpga = fm.total_time_s();

        let mut measured = true;
        let t_cpu: f64 = net
            .layers
            .iter()
            .map(|l| {
                let r = cpu.run_layer(l);
                measured &= r.measured;
                r.seconds_per_item * batch as f64
            })
            .sum();
        let t_gpu = gpu.network_seconds(&net, batch);

        let dense: u64 = net
            .layers
            .iter()
            .map(udcnn::accel::metrics::dense_equivalent_macs)
            .sum();
        let ops = 2.0 * dense as f64 * batch as f64;

        let p_fpga: f64 = fm
            .layers
            .iter()
            .map(|m| energy::fpga_watts(&cfg, m) * m.time_s())
            .sum::<f64>()
            / t_fpga;
        let e_fpga = energy::gops_per_joule(ops, t_fpga, p_fpga);
        let e_cpu = energy::gops_per_joule(ops, t_cpu, energy::CPU_WATTS);
        let e_gpu = energy::gops_per_joule(ops, t_gpu, energy::GPU_WATTS);

        perf.row(&[
            net.name.to_string(),
            format!("{:.2}", t_fpga * 1e3),
            format!("{:.2}", t_gpu * 1e3),
            format!("{:.1}", t_cpu * 1e3),
            if measured { "measured".into() } else { "extrapolated".into() },
            ratio(t_cpu / t_fpga),
            ratio(t_gpu / t_fpga),
        ]);
        eff.row(&[
            net.name.to_string(),
            format!("{:.1}", e_fpga),
            format!("{:.1}", e_gpu),
            format!("{:.2}", e_cpu),
            ratio(e_fpga / e_cpu),
            ratio(e_fpga / e_gpu),
        ]);
        cpu_ratios.push(t_cpu / t_fpga);
        gpu_energy_ratios.push(e_fpga / e_gpu);
    }
    perf.print();
    eff.print();

    // The paper's CPU was a ten-core E5 v2; this host differs (often
    // wildly — CI boxes can be single-core). Present the ratios on the
    // paper's hardware scale too, crediting the E5 with
    // E5_EFFECTIVE_GFLOPS of sustained dense-conv throughput.
    let mut norm = Table::new(
        "Fig. 7(a) normalized to the paper's CPU (E5 v2 @ 150 effective GFLOPS)",
        &["network", "FPGA ms", "E5 ms (modelled)", "FPGA/CPU", "paper"],
    );
    let paper_ratio = ["22.7x-63.3x"; 4];
    let mut e5_ratios = Vec::new();
    for (i, net) in zoo::all_benchmarks().iter().enumerate() {
        let mut cfg = AccelConfig::paper_for(net.dims);
        cfg.batch = batch;
        let t_fpga = simulate_network(&cfg, net).total_time_s();
        let dense: u64 = net
            .layers
            .iter()
            .map(udcnn::accel::metrics::dense_equivalent_macs)
            .sum();
        let ops = 2.0 * dense as f64 * batch as f64;
        let t_e5 = udcnn::baseline::cpu::e5_seconds(ops);
        e5_ratios.push(t_e5 / t_fpga);
        norm.row(&[
            net.name.to_string(),
            format!("{:.2}", t_fpga * 1e3),
            format!("{:.1}", t_e5 * 1e3),
            ratio(t_e5 / t_fpga),
            paper_ratio[i].into(),
        ]);
    }
    norm.print();

    let lo = cpu_ratios.iter().cloned().fold(f64::MAX, f64::min);
    let hi = cpu_ratios.iter().cloned().fold(0.0, f64::max);
    println!(
        "paper check: FPGA/CPU measured-on-host {lo:.1}x–{hi:.1}x (host-dependent)"
    );
    let nlo = e5_ratios.iter().cloned().fold(f64::MAX, f64::min);
    let nhi = e5_ratios.iter().cloned().fold(0.0, f64::max);
    println!(
        "paper check: FPGA/CPU normalized-to-E5 {nlo:.1}x–{nhi:.1}x (paper: 22.7x–63.3x)  [{}]",
        if nlo > 10.0 && nhi < 100.0 { "SHAPE-OK" } else { "CHECK" }
    );
    let glo = gpu_energy_ratios.iter().cloned().fold(f64::MAX, f64::min);
    let ghi = gpu_energy_ratios.iter().cloned().fold(0.0, f64::max);
    println!(
        "paper check: FPGA/GPU energy {glo:.1}x–{ghi:.1}x (paper: 3.3x–8.3x)  [{}]",
        if glo > 3.0 { "SHAPE-OK" } else { "CHECK" }
    );
}
