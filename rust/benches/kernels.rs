//! Kernel-level performance baseline for the uniform compute core.
//!
//! Measures GFLOP/s (2 · useful MACs per second — the IOM schedule
//! never touches a zero, so useful work is the honest numerator) of
//! the uniform IOM deconvolution kernel on the zoo's largest 2D and
//! largest 3D layers, in f32 and Q8.8, single- and multi-threaded.
//! The 2D layer runs through the *same* kernel as the 3D layer — as
//! the depth-1 fold — so this table is also the perf story of §IV-C.
//!
//! It also races the two kernel formulations head to head on each
//! layer plus the GAN head layers (the thin-output stride-2 shapes
//! where the difference is a *multiple*, not a percentage):
//! * **scatter** — the replaced serving path: `deconv_iom_threaded`
//!   over the full Eq.-(1) extent, then the `K−S` crop; parallelism
//!   shards output channels, so a 1-channel head clamps to one
//!   thread;
//! * **gather** — `deconv_gather_window_threaded`: each cropped
//!   output element pulls its contributor window directly (border
//!   taps never computed, nothing materialized outside the crop),
//!   sharded over output *rows*, so thin heads still fill every core.
//!
//! `gather_speedup_f32` in `reports/BENCH_kernels.json` is the
//! multi-threaded scatter-path/gather-path time ratio per layer; the
//! differential battery (`tests/diff_kernels.rs`) pins that the two
//! paths produce identical bits, so the ratio is a free lunch.
//!
//! Every layer also runs with the runtime dispatch pinned to the
//! scalar reference nests (`func::simd::set_force_scalar`), yielding
//! `simd_speedup_f32` / `simd_speedup_q88` (and their `_tn`
//! multi-threaded variants) — the vectorized-vs-scalar ratio of the
//! *same* entry points, bit-identical by `tests/prop_uniform.rs`.
//!
//! Honours `UDCNN_BENCH_FAST=1` for CI-speed runs.

use udcnn::benchkit::{header, write_report_file, Bench, BenchResult};
use udcnn::dcnn::{zoo, Dims, LayerData, LayerSpec};
use udcnn::func::{simd, uniform};
use udcnn::report::json::{array, JsonObj};

const REPORT_PATH: &str = "reports/BENCH_kernels.json";

/// The zoo layer with the most useful MACs of the given dimensionality.
fn largest_layer(dims: Dims) -> LayerSpec {
    zoo::all_benchmarks()
        .into_iter()
        .filter(|n| n.dims == dims)
        .flat_map(|n| n.layers)
        .max_by_key(|l| l.op_counts().useful_macs)
        .expect("zoo has layers of both dimensionalities")
}

/// The final (head) layer of a full-size zoo network — the thin
/// output-channel shapes where scatter's channel sharding starves.
fn head_layer(net: &str) -> LayerSpec {
    zoo::by_name(net)
        .expect("zoo network")
        .layers
        .last()
        .expect("network has layers")
        .clone()
}

fn kernel_doc(name: &str, threads: usize, r: &BenchResult, flops: f64) -> String {
    JsonObj::new()
        .str("kernel", name)
        .int("threads", threads as u64)
        .num("median_s", r.median_s())
        .num("gflops", flops / r.median_s() / 1e9)
        .render()
}

fn main() {
    header(
        "kernels",
        "uniform kernel core GFLOP/s + scatter-vs-gather head-to-head",
    );
    let b = Bench::from_env();
    // the vectorized dispatch is the measured default; the scalar
    // passes below pin the mode explicitly around each run
    simd::set_force_scalar(false);
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4);

    let mut layer_docs = Vec::new();
    let mut all_threaded_faster = true;
    let mut best_gather_speedup = 0.0f64;
    let mut best_simd_q88 = 0.0f64;
    for spec in [
        largest_layer(Dims::D2),
        largest_layer(Dims::D3),
        head_layer("dcgan"),
        head_layer("3d-gan"),
    ] {
        let macs = spec.op_counts().useful_macs;
        let flops = 2.0 * macs as f64;
        println!(
            "{spec}  ({:.1} M structural MACs, {:.1} M gather-executed)",
            macs as f64 / 1e6,
            spec.gather_macs() as f64 / 1e6
        );

        let data = LayerData::synth(&spec, 0xBE7C4);
        let input = data.uniform_input();
        let weights = data.uniform_weights();
        let qdata = data.quantize();
        let qin = qdata.uniform_input();
        let qw = qdata.uniform_weights();

        let single = b.run(&format!("{} iom_f32 t=1", spec.name), || {
            std::hint::black_box(uniform::deconv_iom(&input, &weights, spec.s).len());
        });
        println!("{}", single.summary());
        let multi = b.run(&format!("{} iom_f32 t={threads}", spec.name), || {
            std::hint::black_box(
                uniform::deconv_iom_threaded(&input, &weights, spec.s, threads).len(),
            );
        });
        println!("{}", multi.summary());
        let qsingle = b.run(&format!("{} iom_q88 t=1", spec.name), || {
            std::hint::black_box(uniform::deconv_iom_q(&qin, &qw, spec.s).len());
        });
        println!("{}", qsingle.summary());
        let qmulti = b.run(&format!("{} iom_q88 t={threads}", spec.name), || {
            std::hint::black_box(
                uniform::deconv_iom_q_threaded(&qin, &qw, spec.s, threads).len(),
            );
        });
        println!("{}", qmulti.summary());

        let speedup = single.median_s() / multi.median_s();
        all_threaded_faster &= speedup > 1.0;
        println!(
            "  f32: {:.2} -> {:.2} GFLOP/s  ({speedup:.2}x threaded speedup, {})",
            flops / single.median_s() / 1e9,
            flops / multi.median_s() / 1e9,
            if speedup > 1.0 { "OK" } else { "REGRESSION" },
        );

        // SIMD vs scalar: the same entry points with the runtime
        // dispatch pinned to the scalar reference nests.
        simd::set_force_scalar(true);
        let sc_single = b.run(&format!("{} iom_f32_scalar t=1", spec.name), || {
            std::hint::black_box(uniform::deconv_iom(&input, &weights, spec.s).len());
        });
        println!("{}", sc_single.summary());
        let sc_multi = b.run(&format!("{} iom_f32_scalar t={threads}", spec.name), || {
            std::hint::black_box(
                uniform::deconv_iom_threaded(&input, &weights, spec.s, threads).len(),
            );
        });
        println!("{}", sc_multi.summary());
        let sc_qsingle = b.run(&format!("{} iom_q88_scalar t=1", spec.name), || {
            std::hint::black_box(uniform::deconv_iom_q(&qin, &qw, spec.s).len());
        });
        println!("{}", sc_qsingle.summary());
        let sc_qmulti = b.run(&format!("{} iom_q88_scalar t={threads}", spec.name), || {
            std::hint::black_box(
                uniform::deconv_iom_q_threaded(&qin, &qw, spec.s, threads).len(),
            );
        });
        println!("{}", sc_qmulti.summary());
        simd::set_force_scalar(false);

        let simd_f32 = sc_single.median_s() / single.median_s();
        let simd_f32_tn = sc_multi.median_s() / multi.median_s();
        let simd_q88 = sc_qsingle.median_s() / qsingle.median_s();
        let simd_q88_tn = sc_qmulti.median_s() / qmulti.median_s();
        best_simd_q88 = best_simd_q88.max(simd_q88);
        let tile = simd::tile_for_layer(&spec);
        println!(
            "  simd vs scalar: f32 {simd_f32:.2}x (t={threads}: {simd_f32_tn:.2}x), \
             q88 {simd_q88:.2}x (t={threads}: {simd_q88_tn:.2}x)  \
             [tile {}x{} rows x in_ch]",
            tile.rows, tile.in_ch,
        );

        // Head-to-head: the serving path each kernel actually runs —
        // scatter materializes the full extent then crops, gather
        // emits the cropped window directly.
        let (od, oh, ow) = (spec.out_d(), spec.out_h(), spec.out_w());
        let scatter1 = b.run(&format!("{} scatter_f32 t=1", spec.name), || {
            let full = uniform::deconv_iom(&input, &weights, spec.s);
            std::hint::black_box(uniform::crop(&full, od, oh, ow).len());
        });
        println!("{}", scatter1.summary());
        let scatter_n = b.run(&format!("{} scatter_f32 t={threads}", spec.name), || {
            let full = uniform::deconv_iom_threaded(&input, &weights, spec.s, threads);
            std::hint::black_box(uniform::crop(&full, od, oh, ow).len());
        });
        println!("{}", scatter_n.summary());
        let gather1 = b.run(&format!("{} gather_f32 t=1", spec.name), || {
            std::hint::black_box(
                uniform::deconv_gather_window(&input, &weights, spec.s, 0, od, oh, ow).len(),
            );
        });
        println!("{}", gather1.summary());
        let gather_n = b.run(&format!("{} gather_f32 t={threads}", spec.name), || {
            std::hint::black_box(
                uniform::deconv_gather_window_threaded(
                    &input, &weights, spec.s, 0, od, oh, ow, threads,
                )
                .len(),
            );
        });
        println!("{}", gather_n.summary());

        let gather_speedup = scatter_n.median_s() / gather_n.median_s();
        best_gather_speedup = best_gather_speedup.max(gather_speedup);
        println!(
            "  gather vs scatter (t={threads}): {gather_speedup:.2}x  (out_c={}, {} output rows)\n",
            spec.out_c,
            spec.out_c * od * oh,
        );

        let kernels = array(&[
            kernel_doc("iom_f32", 1, &single, flops),
            kernel_doc("iom_f32", threads, &multi, flops),
            kernel_doc("iom_q88", 1, &qsingle, flops),
            kernel_doc("iom_q88", threads, &qmulti, flops),
            kernel_doc("iom_f32_scalar", 1, &sc_single, flops),
            kernel_doc("iom_f32_scalar", threads, &sc_multi, flops),
            kernel_doc("iom_q88_scalar", 1, &sc_qsingle, flops),
            kernel_doc("iom_q88_scalar", threads, &sc_qmulti, flops),
            kernel_doc("scatter_f32", 1, &scatter1, flops),
            kernel_doc("scatter_f32", threads, &scatter_n, flops),
            kernel_doc("gather_f32", 1, &gather1, flops),
            kernel_doc("gather_f32", threads, &gather_n, flops),
        ]);
        layer_docs.push(
            JsonObj::new()
                .str("layer", &spec.name)
                .str("dims", &spec.dims.to_string())
                .int("useful_macs", macs)
                .int("gather_macs", spec.gather_macs())
                .num("threaded_speedup_f32", speedup)
                .num("gather_speedup_f32", gather_speedup)
                .num("simd_speedup_f32", simd_f32)
                .num("simd_speedup_f32_tn", simd_f32_tn)
                .num("simd_speedup_q88", simd_q88)
                .num("simd_speedup_q88_tn", simd_q88_tn)
                .int("tile_rows", tile.rows as u64)
                .int("tile_in_ch", tile.in_ch as u64)
                .raw("kernels", &kernels)
                .render(),
        );
    }

    println!(
        "best simd q88 speedup: {best_simd_q88:.2}x (target > 1.5x on the largest layers)"
    );
    let doc = JsonObj::new()
        .str("bench", "kernels")
        .int("threads", threads as u64)
        .raw("threaded_beats_single", if all_threaded_faster { "true" } else { "false" })
        .num("gather_speedup_max", best_gather_speedup)
        .num("simd_speedup_q88_max", best_simd_q88)
        .str(
            "simd_note",
            "simd_speedup_* = scalar/vectorized median time via the same entry points; \
             lanes are portable explicit-width chunks (no intrinsics), so the ratio is \
             host- and autovectorizer-dependent — the honest measured number is \
             reported even when below the 1.5x target",
        )
        .raw("layers", &array(&layer_docs))
        .render();
    match write_report_file(REPORT_PATH, &doc) {
        Ok(()) => println!("wrote {REPORT_PATH}"),
        Err(e) => eprintln!("could not write {REPORT_PATH}: {e}"),
    }
}
