//! Kernel-level performance baseline for the uniform compute core.
//!
//! Measures GFLOP/s (2 · useful MACs per second — the IOM schedule
//! never touches a zero, so useful work is the honest numerator) of
//! the uniform IOM deconvolution kernel on the zoo's largest 2D and
//! largest 3D layers, in f32 and Q8.8, single- and multi-threaded.
//! The 2D layer runs through the *same* kernel as the 3D layer — as
//! the depth-1 fold — so this table is also the perf story of §IV-C.
//!
//! Alongside the text report it writes `reports/BENCH_kernels.json`
//! so the kernel-level perf trajectory is tracked across PRs. The
//! `threaded_speedup_f32` / `threaded_beats_single` fields *record*
//! whether the threaded uniform kernel beats the single-threaded path
//! (what the old `deconv2d_iom` / `deconv3d_iom` golden models
//! execute) on both layers; the bar is read from the report, not
//! enforced as an exit code — on 2-core CI runners the ratio can
//! legitimately hover near 1.0.
//!
//! Honours `UDCNN_BENCH_FAST=1` for CI-speed runs.

use udcnn::benchkit::{header, write_report_file, Bench, BenchResult};
use udcnn::dcnn::{zoo, Dims, LayerData, LayerSpec};
use udcnn::func::uniform;
use udcnn::report::json::{array, JsonObj};

const REPORT_PATH: &str = "reports/BENCH_kernels.json";

/// The zoo layer with the most useful MACs of the given dimensionality.
fn largest_layer(dims: Dims) -> LayerSpec {
    zoo::all_benchmarks()
        .into_iter()
        .filter(|n| n.dims == dims)
        .flat_map(|n| n.layers)
        .max_by_key(|l| l.op_counts().useful_macs)
        .expect("zoo has layers of both dimensionalities")
}

fn kernel_doc(name: &str, threads: usize, r: &BenchResult, flops: f64) -> String {
    JsonObj::new()
        .str("kernel", name)
        .int("threads", threads as u64)
        .num("median_s", r.median_s())
        .num("gflops", flops / r.median_s() / 1e9)
        .render()
}

fn main() {
    header(
        "kernels",
        "uniform kernel core GFLOP/s (2D = depth-1 fold of the one 3D kernel)",
    );
    let b = Bench::from_env();
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4);

    let mut layer_docs = Vec::new();
    let mut all_threaded_faster = true;
    for spec in [largest_layer(Dims::D2), largest_layer(Dims::D3)] {
        let macs = spec.op_counts().useful_macs;
        let flops = 2.0 * macs as f64;
        println!("{spec}  ({:.1} M useful MACs)", macs as f64 / 1e6);

        let data = LayerData::synth(&spec, 0xBE7C4);
        let input = data.uniform_input();
        let weights = data.uniform_weights();
        let qdata = data.quantize();
        let qin = qdata.uniform_input();
        let qw = qdata.uniform_weights();

        let single = b.run(&format!("{} iom_f32 t=1", spec.name), || {
            std::hint::black_box(uniform::deconv_iom(&input, &weights, spec.s).len());
        });
        println!("{}", single.summary());
        let multi = b.run(&format!("{} iom_f32 t={threads}", spec.name), || {
            std::hint::black_box(
                uniform::deconv_iom_threaded(&input, &weights, spec.s, threads).len(),
            );
        });
        println!("{}", multi.summary());
        let qsingle = b.run(&format!("{} iom_q88 t=1", spec.name), || {
            std::hint::black_box(uniform::deconv_iom_q(&qin, &qw, spec.s).len());
        });
        println!("{}", qsingle.summary());
        let qmulti = b.run(&format!("{} iom_q88 t={threads}", spec.name), || {
            std::hint::black_box(
                uniform::deconv_iom_q_threaded(&qin, &qw, spec.s, threads).len(),
            );
        });
        println!("{}", qmulti.summary());

        let speedup = single.median_s() / multi.median_s();
        all_threaded_faster &= speedup > 1.0;
        println!(
            "  f32: {:.2} -> {:.2} GFLOP/s  ({speedup:.2}x threaded speedup, {})\n",
            flops / single.median_s() / 1e9,
            flops / multi.median_s() / 1e9,
            if speedup > 1.0 { "OK" } else { "REGRESSION" },
        );

        let kernels = array(&[
            kernel_doc("iom_f32", 1, &single, flops),
            kernel_doc("iom_f32", threads, &multi, flops),
            kernel_doc("iom_q88", 1, &qsingle, flops),
            kernel_doc("iom_q88", threads, &qmulti, flops),
        ]);
        layer_docs.push(
            JsonObj::new()
                .str("layer", &spec.name)
                .str("dims", &spec.dims.to_string())
                .int("useful_macs", macs)
                .num("threaded_speedup_f32", speedup)
                .raw("kernels", &kernels)
                .render(),
        );
    }

    let doc = JsonObj::new()
        .str("bench", "kernels")
        .int("threads", threads as u64)
        .raw("threaded_beats_single", if all_threaded_faster { "true" } else { "false" })
        .raw("layers", &array(&layer_docs))
        .render();
    match write_report_file(REPORT_PATH, &doc) {
        Ok(()) => println!("wrote {REPORT_PATH}"),
        Err(e) => eprintln!("could not write {REPORT_PATH}: {e}"),
    }
}
