//! Streaming sessions: halo-carrying chunk execution of a network.
//!
//! A [`StreamSession`] owns one inference stream. Each
//! [`StreamSession::push_chunk`] call feeds the next temporal tile of
//! input frames through every layer: the layer prepends its retained
//! depth halo (computed by the [`crate::graph::stream_shape`] pass),
//! runs the dimension-uniform IOM kernel over the slab, crops the
//! window of output frames whose contributor sets just completed, and
//! retains the new halo. Emission is prompt — `S` output frames per
//! input frame, no drain step — and per-layer state is `⌊(K_d−1)/S⌋`
//! frames, so session memory is bounded by the chunk size, not the
//! stream length.
//!
//! **Why tiled equals whole, bit-exactly.** An output frame `z` reads
//! exactly the input frames `[⌈(z−K_d+1)/S⌉, ⌊z/S⌋]`. The session
//! computes `z` only once all of them have arrived, inside one
//! [`crate::func::uniform::deconv_iom`] call whose slab contains that
//! whole window — so every output element accumulates the *same terms
//! in the same order* (input channels major, depth ascending) as the
//! whole-volume kernel. No partial sums ever cross a chunk boundary;
//! the overlap between consecutive tiles is resolved by re-scattering
//! the halo frames, not by adding partial outputs in a different
//! order. f32 addition is non-associative, so this is the *only*
//! tiling discipline that reproduces `forward_uniform` bit-for-bit —
//! `tests/diff_stream.rs` pins it across the zoo, chunk sizes,
//! precisions and configs.
//!
//! 2D networks degenerate to stateless chunk=1 passthrough: every
//! frame is an independent inference through the same golden
//! [`forward_uniform`](crate::coordinator::service::forward_uniform) path (an *unbounded* stream — useful for
//! frame-by-frame video workloads on 2D nets).

use std::collections::BTreeMap;

use crate::accel::{kernel as kern, timing, AccelConfig, KernelChoice};
use crate::coordinator::service::forward_uniform_obs;
use crate::dcnn::{Dims, LayerSpec, Network};
use crate::fixed::Q88;
use crate::func::{uniform, workspace};
use crate::graph::{passes, stream_shapes, LayerStreamShape, NetworkGraph};
use crate::obs::Obs;
use crate::report::json::JsonObj;
use crate::serve::{CacheStats, PlanCache};
use crate::tensor::{Volume, WeightsOIDHW};

use super::tiler::DepthTiler;

// ---------------------------------------------------------------------
// Per-layer halo state (generic over the element type).
// ---------------------------------------------------------------------

/// One layer's streaming state: the retained input halo plus the
/// arrival/emission cursors.
struct LayerStream<T> {
    spec: LayerSpec,
    shape: LayerStreamShape,
    /// Retained input frames `[first_contributor(emitted), seen)`.
    held: Volume<T>,
    /// Input frames consumed so far.
    seen: usize,
    /// Output frames emitted so far (always a multiple of `S`).
    emitted: usize,
}

impl<T: Copy + Default> LayerStream<T> {
    fn new(spec: &LayerSpec, shape: &LayerStreamShape) -> LayerStream<T> {
        LayerStream {
            held: Volume::zeros(spec.in_c, 0, spec.in_h, spec.in_w),
            spec: spec.clone(),
            shape: shape.clone(),
            seen: 0,
            emitted: 0,
        }
    }

    fn held_elems(&self) -> usize {
        self.held.len()
    }

    /// Consume `incoming` frames: run the kernel over halo + arrivals
    /// and emit every output frame whose contributor window just
    /// completed. `kernel` maps `(slab, d_lo, od, oh, ow)` to the
    /// cropped output *window* of the slab plus the transient elements
    /// it materialized beyond that window (the full Eq.-(1) extent for
    /// the scatter kernels; zero for the gather kernels, which write
    /// the window directly); `other_held_elems` (the halos of the
    /// *other* layers) and `peak` let the session track its
    /// live-memory high-water mark. Returns the emitted frames and the
    /// slab depth processed.
    fn step<K>(
        &mut self,
        incoming: &Volume<T>,
        kernel: K,
        other_held_elems: usize,
        peak: &mut usize,
    ) -> Result<(Volume<T>, usize), String>
    where
        K: Fn(&Volume<T>, usize, usize, usize, usize) -> (Volume<T>, usize),
    {
        self.check_incoming(incoming)?;
        let spec = &self.spec;
        // Invariant: held covers input ids [first_contributor(emitted), seen).
        let start = self.seen - self.held.d;
        let slab = self.held.concat_depth(incoming);
        *peak = (*peak).max(other_held_elems + self.held.len() + incoming.len() + slab.len());

        let new_seen = self.seen + incoming.d;
        let ready = self.shape.s * new_seen;
        let (out, transient) = kernel(
            &slab,
            self.emitted - start * self.shape.s,
            ready - self.emitted,
            spec.out_h(),
            spec.out_w(),
        );
        *peak = (*peak).max(other_held_elems + slab.len() + transient + out.len());

        let keep_lo = self.shape.first_contributor(ready).min(new_seen);
        self.held = slab.slice_depth(keep_lo - start, new_seen - keep_lo);
        let slab_frames = slab.d;
        self.seen = new_seen;
        self.emitted = ready;
        Ok((out, slab_frames))
    }

    fn check_incoming(&self, incoming: &Volume<T>) -> Result<(), String> {
        let spec = &self.spec;
        if (incoming.c, incoming.h, incoming.w) != (spec.in_c, spec.in_h, spec.in_w) {
            return Err(format!(
                "layer '{}': chunk frames are {}x{}x{} (c×h×w), expected {}x{}x{}",
                spec.name, incoming.c, incoming.h, incoming.w, spec.in_c, spec.in_h, spec.in_w
            ));
        }
        if incoming.d == 0 {
            return Err(format!("layer '{}': empty chunk", spec.name));
        }
        if self.seen + incoming.d > self.shape.in_frames {
            return Err(format!(
                "layer '{}': {} arriving frames overflow the declared depth {} ({} seen)",
                spec.name, incoming.d, self.shape.in_frames, self.seen
            ));
        }
        Ok(())
    }
}

impl LayerStream<f32> {
    /// [`LayerStream::step`] with every intermediate buffer — the
    /// halo+chunk slab and the retained halo — drawn from and returned
    /// to the [`workspace`] pool, so an f32 session's steady state
    /// performs zero heap allocation per chunk (`tests/obs_trace.rs`
    /// counts). Identical math and identical peak accounting.
    fn step_pooled<K>(
        &mut self,
        incoming: &Volume<f32>,
        kernel: K,
        other_held_elems: usize,
        peak: &mut usize,
    ) -> Result<(Volume<f32>, usize), String>
    where
        K: Fn(&Volume<f32>, usize, usize, usize, usize) -> (Volume<f32>, usize),
    {
        self.check_incoming(incoming)?;
        // Invariant: held covers input ids [first_contributor(emitted), seen).
        let start = self.seen - self.held.d;
        let slab = concat_depth_pooled(&self.held, incoming);
        *peak = (*peak).max(other_held_elems + self.held.len() + incoming.len() + slab.len());

        let new_seen = self.seen + incoming.d;
        let ready = self.shape.s * new_seen;
        let (out, transient) = kernel(
            &slab,
            self.emitted - start * self.shape.s,
            ready - self.emitted,
            self.spec.out_h(),
            self.spec.out_w(),
        );
        *peak = (*peak).max(other_held_elems + slab.len() + transient + out.len());

        let keep_lo = self.shape.first_contributor(ready).min(new_seen);
        let new_held = slice_depth_pooled(&slab, keep_lo - start, new_seen - keep_lo);
        workspace::give_volume_f32(std::mem::replace(&mut self.held, new_held));
        let slab_frames = slab.d;
        workspace::give_volume_f32(slab);
        self.seen = new_seen;
        self.emitted = ready;
        Ok((out, slab_frames))
    }
}

/// Pool-backed twin of [`Volume::concat_depth`] (per-channel copy into
/// a [`workspace`] buffer).
fn concat_depth_pooled(a: &Volume<f32>, b: &Volume<f32>) -> Volume<f32> {
    debug_assert_eq!((a.c, a.h, a.w), (b.c, b.h, b.w));
    let plane = a.h * a.w;
    let d = a.d + b.d;
    let mut out = workspace::take_volume_f32(a.c, d, a.h, a.w);
    for c in 0..a.c {
        let dst = c * d * plane;
        out.data_mut()[dst..dst + a.d * plane]
            .copy_from_slice(&a.data()[c * a.d * plane..(c + 1) * a.d * plane]);
        out.data_mut()[dst + a.d * plane..dst + d * plane]
            .copy_from_slice(&b.data()[c * b.d * plane..(c + 1) * b.d * plane]);
    }
    out
}

/// Pool-backed twin of [`Volume::slice_depth`].
fn slice_depth_pooled(v: &Volume<f32>, d_lo: usize, d: usize) -> Volume<f32> {
    debug_assert!(d_lo + d <= v.d);
    let plane = v.h * v.w;
    let mut out = workspace::take_volume_f32(v.c, d, v.h, v.w);
    for c in 0..v.c {
        let src = (c * v.d + d_lo) * plane;
        let dst = c * d * plane;
        out.data_mut()[dst..dst + d * plane].copy_from_slice(&v.data()[src..src + d * plane]);
    }
    out
}

/// Check one uniform weight set per layer, with matching shapes.
fn validate_weights<T: Copy + Default>(
    net: &Network,
    weights: &[WeightsOIDHW<T>],
) -> Result<(), String> {
    if weights.len() != net.layers.len() {
        return Err(format!(
            "network '{}' has {} layers but {} weight sets were given",
            net.name,
            net.layers.len(),
            weights.len()
        ));
    }
    for (w, l) in weights.iter().zip(&net.layers) {
        if (w.o, w.i, w.kd, w.kh, w.kw) != (l.out_c, l.in_c, l.k_d(), l.k, l.k) {
            return Err(format!("weights for '{}' do not match its layer spec", l.name));
        }
    }
    Ok(())
}

/// Lower `net` to IOM form and run the streaming shape pass.
fn shapes_of(net: &Network) -> Result<Vec<LayerStreamShape>, String> {
    Ok(stream_shapes(&passes::lower(&NetworkGraph::from_network(net))?)?)
}

/// Live elements the whole-volume golden forward
/// ([`forward_uniform`](crate::coordinator::service::forward_uniform)) holds at its worst layer: the input, the
/// full Eq.-(1) accumulation extent, and the cropped output coexist
/// during write-back. The streaming session's
/// [`StreamSummary::peak_live_elems`] is the like-for-like number.
pub fn whole_volume_peak_elems(net: &Network) -> usize {
    net.layers
        .iter()
        .map(|l| l.input_elems() + l.out_c * l.out_full_spatial() + l.output_elems())
        .max()
        .unwrap_or(0)
}

// ---------------------------------------------------------------------
// The f32 session (serving hot path, with timing + plan integration).
// ---------------------------------------------------------------------

/// Output of one [`StreamSession::push_chunk`] call.
#[derive(Clone, Debug)]
pub struct StreamChunkOutput {
    /// Output frames emitted for this chunk (depth `S^L ×` chunk
    /// frames for a 3D chain; one frame per input frame for 2D).
    pub frames: Volume<f32>,
    /// Per-chunk accelerator cycle estimate: the sum of
    /// [`crate::accel::timing::simulate_chunk`] over the per-layer
    /// slabs this chunk actually ran.
    pub cycles: u64,
    /// Simulated seconds of the compiled-plan path for this chunk
    /// (the chunk-shaped network's [`crate::graph::NetworkPlan`],
    /// cached in the session's [`PlanCache`]).
    pub plan_s: f64,
}

/// End-of-stream accounting of a session (available at any point —
/// sessions need no drain).
#[derive(Clone, Debug)]
pub struct StreamSummary {
    /// Network the session streamed (re-depthed name for 3D).
    pub network: String,
    /// Dimensionality.
    pub dims: Dims,
    /// Input frames consumed.
    pub frames_in: usize,
    /// Output frames emitted.
    pub frames_out: usize,
    /// Chunks pushed.
    pub chunks: usize,
    /// Total per-chunk accelerator cycles (isolated-layer tier).
    pub total_cycles: u64,
    /// Total simulated seconds of the per-chunk cycle estimates.
    pub accel_s: f64,
    /// Total simulated seconds of the compiled-plan path.
    pub plan_s: f64,
    /// High-water mark of live session memory, in elements: halos plus
    /// the in-flight slab/full/output volumes of the busiest moment.
    pub peak_live_elems: usize,
    /// Whole-volume peak ([`whole_volume_peak_elems`]) of the same
    /// network — the bound a chunked 3D session stays strictly under.
    pub whole_peak_elems: usize,
    /// Plan-cache counters (chunk-shaped plans compile once per
    /// distinct slab size).
    pub cache: CacheStats,
}

impl StreamSummary {
    /// Streamed input frames per simulated second (cycle-estimate
    /// tier); 0.0 before any chunk.
    pub fn frames_per_s(&self) -> f64 {
        if self.accel_s > 0.0 {
            self.frames_in as f64 / self.accel_s
        } else {
            0.0
        }
    }

    /// Streaming peak over whole-volume peak (< 1.0 means the session
    /// runs in strictly less memory than whole-volume execution).
    pub fn peak_ratio(&self) -> f64 {
        if self.whole_peak_elems > 0 {
            self.peak_live_elems as f64 / self.whole_peak_elems as f64
        } else {
            0.0
        }
    }

    /// Machine-readable form (the shape `BENCH_stream.json` embeds).
    pub fn to_json(&self) -> String {
        JsonObj::new()
            .str("network", &self.network)
            .str("dims", &self.dims.to_string())
            .int("frames_in", self.frames_in as u64)
            .int("frames_out", self.frames_out as u64)
            .int("chunks", self.chunks as u64)
            .int("total_cycles", self.total_cycles)
            .num("accel_s", self.accel_s)
            .num("plan_s", self.plan_s)
            .num("frames_per_s", self.frames_per_s())
            .int("peak_live_elems", self.peak_live_elems as u64)
            .int("whole_peak_elems", self.whole_peak_elems as u64)
            .num("peak_ratio", self.peak_ratio())
            .int("plan_cache_misses", self.cache.misses)
            .int("plan_cache_hits", self.cache.hits)
            .render()
    }
}

/// One streaming inference session over a network.
pub struct StreamSession {
    net: Network,
    weights: Vec<WeightsOIDHW<f32>>,
    shapes: Vec<LayerStreamShape>,
    /// Per-layer halo state (empty for 2D passthrough sessions).
    layers: Vec<LayerStream<f32>>,
    cfg: AccelConfig,
    threads: usize,
    /// Per-layer kernel choice for the 3D chunk path (scatter or
    /// zero-skip gather; bit-identical either way). Defaults to the
    /// per-layer model's pick on the session config.
    kernels: Vec<KernelChoice>,
    frames_in: usize,
    frames_out: usize,
    chunks: usize,
    total_cycles: u64,
    plan_s: f64,
    peak_live_elems: usize,
    /// Chunk-shaped compiled plans, keyed by the re-depthed network
    /// name — at most a handful of distinct slab sizes per stream.
    cache: PlanCache,
    /// Memoized plan latency per layer-0 slab size (avoids re-leaking
    /// `with_depth` names and re-simulating per chunk).
    plan_memo: BTreeMap<usize, f64>,
    /// Memoized per-layer chunk cycle estimate keyed by
    /// `(layer index, slab frames)` — `timing::simulate_chunk` clones
    /// the layer spec (a `String` name), which would break the
    /// zero-allocation steady state.
    sim_cycles_memo: BTreeMap<(usize, usize), u64>,
    /// Reused per-chunk scratch: the slab depths of the last chunk.
    slabs_scratch: Vec<usize>,
    /// Reused per-chunk scratch: the per-layer cycle estimates.
    cycles_scratch: Vec<u64>,
    /// Observability handle: per-chunk and per-layer spans on the
    /// `stream` track, kernel spans, and the live-memory gauge. Off by
    /// default; see [`StreamSession::set_obs`].
    obs: Obs,
}

impl StreamSession {
    /// Open a session: validate the weights against the network, run
    /// the graph streaming shape pass (per-layer halos), and size the
    /// plan cache for the few distinct chunk shapes a stream produces.
    /// `threads` bounds each kernel's scoped workers (results are
    /// bit-identical for every thread count).
    pub fn new(
        net: &Network,
        weights: Vec<WeightsOIDHW<f32>>,
        cfg: AccelConfig,
        threads: usize,
    ) -> Result<StreamSession, String> {
        cfg.validate()?;
        validate_weights(net, &weights)?;
        let shapes = shapes_of(net)?;
        let layers = match net.dims {
            Dims::D2 => Vec::new(),
            Dims::D3 => net
                .layers
                .iter()
                .zip(&shapes)
                .map(|(l, sh)| LayerStream::new(l, sh))
                .collect(),
        };
        let kernels = net
            .layers
            .iter()
            .map(|l| kern::choose_for_layer(&cfg, l).choice)
            .collect();
        Ok(StreamSession {
            net: net.clone(),
            weights,
            shapes,
            layers,
            cfg,
            threads: threads.max(1),
            kernels,
            frames_in: 0,
            frames_out: 0,
            chunks: 0,
            total_cycles: 0,
            plan_s: 0.0,
            peak_live_elems: 0,
            cache: PlanCache::with_capacity(8),
            plan_memo: BTreeMap::new(),
            sim_cycles_memo: BTreeMap::new(),
            slabs_scratch: Vec::new(),
            cycles_scratch: Vec::new(),
            obs: Obs::off(),
        })
    }

    /// Attach an observability handle. Chunk/layer spans land on the
    /// `stream` track at *simulated* timestamps (the accumulated cycle
    /// estimate times [`AccelConfig::cycle_s`]), kernel invocations on
    /// the `kernel` track, and the session's live-memory high-water
    /// mark drives the `stream.peak_live_elems` gauge.
    pub fn set_obs(&mut self, obs: Obs) {
        self.obs = obs;
    }

    /// The network this session streams.
    pub fn network(&self) -> &Network {
        &self.net
    }

    /// The per-layer kernel choices the 3D chunk path runs.
    pub fn kernels(&self) -> &[KernelChoice] {
        &self.kernels
    }

    /// Override the per-layer kernel choices (one per layer) — the
    /// differential batteries use this to pin a session to scatter or
    /// gather; output bits are identical for any assignment.
    pub fn set_kernels(&mut self, kernels: Vec<KernelChoice>) -> Result<(), String> {
        if kernels.len() != self.net.layers.len() {
            return Err(format!(
                "network '{}' has {} layers but {} kernel choices were given",
                self.net.name,
                self.net.layers.len(),
                kernels.len()
            ));
        }
        self.kernels = kernels;
        Ok(())
    }

    /// Per-layer streaming shapes (halo math) the session derives its
    /// state from.
    pub fn shapes(&self) -> &[LayerStreamShape] {
        &self.shapes
    }

    /// Input frames a 3D session still accepts (2D sessions are
    /// unbounded and report `usize::MAX`).
    pub fn frames_remaining(&self) -> usize {
        match self.net.dims {
            Dims::D2 => usize::MAX,
            Dims::D3 => self.shapes[0].in_frames - self.frames_in,
        }
    }

    /// Feed the next chunk of input frames (depth axis = time) and
    /// receive every output frame whose contributor window completed.
    /// 3D chunks stream through the halo-carrying layer chain; for 2D
    /// networks each depth slice is an independent frame inference
    /// (chunk=1 passthrough semantics regardless of the pushed depth).
    /// The emitted [`StreamChunkOutput::frames`] volume is drawn from
    /// the [`workspace`] pool on the 3D path; callers that are done
    /// with it can return it via [`workspace::give_volume_f32`] to
    /// keep long streams allocation-free.
    pub fn push_chunk(&mut self, chunk: Volume<f32>) -> Result<StreamChunkOutput, String> {
        let chunk_d = chunk.d;
        let (frames, slabs) = match self.net.dims {
            Dims::D3 => self.push_chunk_3d(chunk)?,
            Dims::D2 => self.push_chunk_2d(&chunk)?,
        };
        // per-chunk cycle estimate over the slabs actually processed,
        // memoized per (layer, slab depth) — a stream revisits only a
        // handful of slab shapes
        let mut layer_cycles = std::mem::take(&mut self.cycles_scratch);
        layer_cycles.clear();
        for (idx, &slab) in slabs.iter().enumerate() {
            let mut c = match self.sim_cycles_memo.get(&(idx, slab)) {
                Some(&c) => c,
                None => {
                    let c = timing::simulate_chunk(&self.cfg, &self.net.layers[idx], slab)
                        .total_cycles;
                    self.sim_cycles_memo.insert((idx, slab), c);
                    c
                }
            };
            if self.net.dims == Dims::D2 {
                c *= chunk_d as u64; // one full pass per frame
            }
            layer_cycles.push(c);
        }
        let cycles: u64 = layer_cycles.iter().sum();
        // compiled-plan path for the chunk-shaped network
        let per_pass = self.chunk_plan_s(slabs[0])?;
        let plan_s = match self.net.dims {
            Dims::D2 => per_pass * chunk_d as f64, // one plan pass per frame
            Dims::D3 => per_pass,
        };
        if self.obs.is_enabled() {
            self.trace_chunk(chunk_d, frames.d, &slabs, &layer_cycles, plan_s);
        }
        self.frames_in += chunk_d;
        self.frames_out += frames.d;
        self.chunks += 1;
        self.total_cycles += cycles;
        self.plan_s += plan_s;
        self.slabs_scratch = slabs;
        self.cycles_scratch = layer_cycles;
        Ok(StreamChunkOutput {
            frames,
            cycles,
            plan_s,
        })
    }

    /// Emit the chunk's trace: one `chunk` span on the `stream` track
    /// over the simulated interval the cycle estimate occupies, nested
    /// per-layer spans carrying slab/halo geometry, a `live_elems`
    /// counter sample, and the session gauges. Called *before* the
    /// accumulators advance, so `self.total_cycles` is the chunk's
    /// simulated start and `self.chunks` its index.
    fn trace_chunk(
        &self,
        frames_in: usize,
        frames_out: usize,
        slabs: &[usize],
        layer_cycles: &[u64],
        plan_s: f64,
    ) {
        let track = self.obs.track("stream");
        let cycle_s = self.cfg.cycle_s();
        let t0 = self.total_cycles as f64 * cycle_s * 1e6;
        let cycles: u64 = layer_cycles.iter().sum();
        let dur = cycles as f64 * cycle_s * 1e6;
        self.obs.span(
            track,
            "chunk",
            &format!("chunk {}", self.chunks),
            t0,
            dur,
            Some(
                JsonObj::new()
                    .int("frames_in", frames_in as u64)
                    .int("frames_out", frames_out as u64)
                    .int("slab0", slabs[0] as u64)
                    .int("cycles", cycles)
                    .num("plan_ms", plan_s * 1e3),
            ),
        );
        let mut cursor = t0;
        for (i, (&c, &slab)) in layer_cycles.iter().zip(slabs).enumerate() {
            let d = c as f64 * cycle_s * 1e6;
            self.obs.span(
                track,
                "layer",
                &self.net.layers[i].name,
                cursor,
                d,
                Some(
                    JsonObj::new()
                        .int("cycles", c)
                        .int("slab_frames", slab as u64)
                        .int("halo_frames", self.shapes[i].halo_in as u64),
                ),
            );
            cursor += d;
        }
        self.obs
            .sample(track, "live_elems", t0 + dur, self.peak_live_elems as f64);
        self.obs
            .gauge("stream.peak_live_elems", self.peak_live_elems as f64);
        self.obs.count("stream.chunks", 1);
        self.obs.count("stream.frames_in", frames_in as u64);
        self.obs.count("stream.frames_out", frames_out as u64);
    }

    /// 3D: stream the chunk through the halo-carrying layer chain. The
    /// chunk is consumed: its buffer becomes the first layer's input
    /// and then returns to the [`workspace`] pool, and every
    /// inter-layer volume round-trips through the pool too — the
    /// steady state allocates nothing.
    fn push_chunk_3d(&mut self, chunk: Volume<f32>) -> Result<(Volume<f32>, Vec<usize>), String> {
        let mut peak = self.peak_live_elems;
        let mut slabs = std::mem::take(&mut self.slabs_scratch);
        slabs.clear();
        let mut cur = chunk;
        let ktrack = self.obs.track("kernel");
        for i in 0..self.layers.len() {
            let other: usize = self
                .layers
                .iter()
                .enumerate()
                .filter(|(j, _)| *j != i)
                .map(|(_, l)| l.held_elems())
                .sum();
            let w = &self.weights[i];
            let s = self.net.layers[i].s;
            let threads = self.threads;
            let choice = self.kernels[i];
            let mut span = self.obs.scope(ktrack, "kernel", &self.net.layers[i].name);
            if self.obs.is_enabled() {
                let l = &self.net.layers[i];
                let actual = match choice {
                    KernelChoice::Scatter => l.op_counts().useful_macs,
                    KernelChoice::Gather => l.gather_macs(),
                };
                span.set_args(
                    JsonObj::new()
                        .str("kernel", &choice.to_string())
                        .int("useful_macs", l.op_counts().useful_macs)
                        .int("actual_macs", actual)
                        .num("structural_zero_ratio", l.inserted_sparsity()),
                );
                self.obs.count("kernel.invocations", 1);
            }
            let (out, slab) = self.layers[i].step_pooled(
                &cur,
                |v: &Volume<f32>, d_lo, od, oh, ow| match choice {
                    KernelChoice::Scatter => {
                        let full = uniform::deconv_iom_threaded(v, w, s, threads);
                        let transient = full.len();
                        let cropped = uniform::crop_window_pooled(&full, d_lo, od, oh, ow);
                        workspace::give_volume_f32(full);
                        (cropped, transient)
                    }
                    KernelChoice::Gather => (
                        uniform::deconv_gather_window_threaded(v, w, s, d_lo, od, oh, ow, threads),
                        0,
                    ),
                },
                other,
                &mut peak,
            )?;
            drop(span);
            slabs.push(slab);
            // the consumed layer input goes back to the scratch pool
            workspace::give_volume_f32(std::mem::replace(&mut cur, out));
        }
        self.peak_live_elems = peak;
        Ok((cur, slabs))
    }

    /// 2D: every depth slice is an independent frame through the
    /// golden serving forward (identical bits to `forward_uniform` by
    /// construction — it *is* that code path).
    fn push_chunk_2d(&mut self, chunk: &Volume<f32>) -> Result<(Volume<f32>, Vec<usize>), String> {
        let l0 = &self.net.layers[0];
        if (chunk.c, chunk.h, chunk.w) != (l0.in_c, l0.in_h, l0.in_w) {
            return Err(format!(
                "network '{}': chunk frames are {}x{}x{} (c×h×w), expected {}x{}x{}",
                self.net.name, chunk.c, chunk.h, chunk.w, l0.in_c, l0.in_h, l0.in_w
            ));
        }
        if chunk.d == 0 {
            return Err(format!("network '{}': empty chunk", self.net.name));
        }
        let last = self.net.layers.last().expect("non-empty network");
        let (oc, oh, ow) = (last.out_c, last.out_h(), last.out_w());
        let frame_peak = whole_volume_peak_elems(&self.net);
        let mut outs = Vec::with_capacity(chunk.d);
        let mut out_elems = 0usize;
        for f in 0..chunk.d {
            let frame = chunk.slice_depth(f, 1);
            let y = forward_uniform_obs(&self.net, &self.weights, frame.data(), &self.obs);
            out_elems += y.len();
            outs.push(Volume::from_vec(oc, 1, oh, ow, y));
            self.peak_live_elems = self
                .peak_live_elems
                .max(chunk.len() + out_elems + frame_peak);
        }
        Ok((concat_frames(&outs), vec![1; self.net.layers.len()]))
    }

    /// Simulated plan seconds for a chunk whose layer-0 slab holds
    /// `slab0` frames, memoized per distinct slab size. The chunk
    /// network is the stream's architecture re-anchored to the slab
    /// depth ([`Network::with_depth`]), compiled through the session
    /// [`PlanCache`] — a full-depth slab is the whole-volume plan.
    fn chunk_plan_s(&mut self, slab0: usize) -> Result<f64, String> {
        if let Some(&lat) = self.plan_memo.get(&slab0) {
            return Ok(lat);
        }
        let chunk_net = self.net.with_depth(slab0);
        let plan = self
            .cache
            .get_or_compile_obs(&self.cfg, &chunk_net, &self.obs)?;
        let lat = crate::graph::simulate_plan(&plan).time_s();
        self.plan_memo.insert(slab0, lat);
        Ok(lat)
    }

    /// Session accounting so far (no drain needed — emission is
    /// prompt, so after the last chunk this is the final summary).
    pub fn summary(&self) -> StreamSummary {
        StreamSummary {
            network: self.net.name.to_string(),
            dims: self.net.dims,
            frames_in: self.frames_in,
            frames_out: self.frames_out,
            chunks: self.chunks,
            total_cycles: self.total_cycles,
            accel_s: self.total_cycles as f64 * self.cfg.cycle_s(),
            plan_s: self.plan_s,
            peak_live_elems: self.peak_live_elems,
            whole_peak_elems: whole_volume_peak_elems(&self.net),
            cache: self.cache.stats(),
        }
    }
}

/// Concatenate volumes along the depth (time) axis with a single
/// allocation — the frame reassembly of a streamed output (a repeated
/// [`Volume::concat_depth`] fold would re-copy the accumulated output
/// once per chunk). Panics on an empty slice or mismatched c/h/w.
pub fn concat_frames<T: Copy + Default>(parts: &[Volume<T>]) -> Volume<T> {
    let first = &parts[0];
    let d: usize = parts.iter().map(|p| p.d).sum();
    let plane = first.h * first.w;
    let mut out = Volume::zeros(first.c, d, first.h, first.w);
    let mut off = 0;
    for p in parts {
        debug_assert_eq!((p.c, p.h, p.w), (first.c, first.h, first.w));
        for c in 0..p.c {
            let src = c * p.d * plane;
            let dst = (c * d + off) * plane;
            out.data_mut()[dst..dst + p.d * plane]
                .copy_from_slice(&p.data()[src..src + p.d * plane]);
        }
        off += p.d;
    }
    out
}

// ---------------------------------------------------------------------
// One-call drivers (tests, CLI, benches).
// ---------------------------------------------------------------------

/// Drive a full [`StreamSession`] over `input`, tiled into
/// `chunk`-frame temporal tiles, and return the reassembled output
/// with the session summary. The reassembled bits equal whole-volume
/// [`forward_uniform`](crate::coordinator::service::forward_uniform) exactly (`tests/diff_stream.rs` pins it).
pub fn stream_forward(
    net: &Network,
    weights: &[WeightsOIDHW<f32>],
    input: &Volume<f32>,
    chunk: usize,
    cfg: &AccelConfig,
    threads: usize,
) -> Result<(Volume<f32>, StreamSummary), String> {
    let mut sess = StreamSession::new(net, weights.to_vec(), cfg.clone(), threads)?;
    let tiler = DepthTiler::new(input.d, chunk)?;
    let mut outs = Vec::with_capacity(tiler.len());
    for ch in tiler.chunks() {
        let part = sess.push_chunk(input.slice_depth(ch.start, ch.frames))?;
        outs.push(part.frames);
    }
    Ok((concat_frames(&outs), sess.summary()))
}

/// [`stream_forward`] with every layer pinned to one kernel (scatter
/// or zero-skip gather) instead of the session's per-layer choice —
/// what `tests/diff_stream.rs` uses to prove the halo bit-exactness
/// argument is kernel-independent.
pub fn stream_forward_kernel(
    net: &Network,
    weights: &[WeightsOIDHW<f32>],
    input: &Volume<f32>,
    chunk: usize,
    cfg: &AccelConfig,
    threads: usize,
    kernel: KernelChoice,
) -> Result<(Volume<f32>, StreamSummary), String> {
    let mut sess = StreamSession::new(net, weights.to_vec(), cfg.clone(), threads)?;
    sess.set_kernels(vec![kernel; net.layers.len()])?;
    let tiler = DepthTiler::new(input.d, chunk)?;
    let mut outs = Vec::with_capacity(tiler.len());
    for ch in tiler.chunks() {
        let part = sess.push_chunk(input.slice_depth(ch.start, ch.frames))?;
        outs.push(part.frames);
    }
    Ok((concat_frames(&outs), sess.summary()))
}

/// Q8.8 whole-volume golden forward: per-layer
/// [`uniform::deconv_iom_q`] accumulation (48-bit, one rounding at
/// write-back) plus the `K−S` crop — the fixed-point counterpart of
/// [`forward_uniform`](crate::coordinator::service::forward_uniform), used as the streaming battery's reference.
pub fn whole_forward_q(
    net: &Network,
    weights: &[WeightsOIDHW<Q88>],
    input: &Volume<Q88>,
) -> Result<Volume<Q88>, String> {
    validate_weights(net, weights)?;
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let mut cur = input.clone();
    for (layer, w) in net.layers.iter().zip(weights) {
        // threaded kernel: bit-identical to single-threaded (integer
        // accumulation; prop_uniform pins it), full zoo nets are big
        let full = uniform::deconv_iom_q_threaded(&cur, w, layer.s, threads);
        cur = uniform::crop(&full, layer.out_d(), layer.out_h(), layer.out_w());
    }
    Ok(cur)
}

/// Q8.8 streaming forward over `chunk`-frame tiles. Integer
/// accumulation makes bit-exactness unconditional here, but the slab
/// discipline is identical to the f32 session — each output frame
/// rounds exactly once, from its complete contributor set. 2D
/// networks run per-frame [`whole_forward_q`] passthrough.
pub fn stream_forward_q(
    net: &Network,
    weights: &[WeightsOIDHW<Q88>],
    input: &Volume<Q88>,
    chunk: usize,
    threads: usize,
) -> Result<Volume<Q88>, String> {
    validate_weights(net, weights)?;
    let tiler = DepthTiler::new(input.d, chunk)?;
    let mut outs = Vec::with_capacity(tiler.len());
    if net.dims == Dims::D2 {
        for f in 0..input.d {
            outs.push(whole_forward_q(net, weights, &input.slice_depth(f, 1))?);
        }
        return Ok(concat_frames(&outs));
    }
    let shapes = shapes_of(net)?;
    let mut layers: Vec<LayerStream<Q88>> = net
        .layers
        .iter()
        .zip(&shapes)
        .map(|(l, sh)| LayerStream::new(l, sh))
        .collect();
    let mut peak = 0usize; // tracked but unused in the Q driver
    for ch in tiler.chunks() {
        let mut cur = input.slice_depth(ch.start, ch.frames);
        for (i, ls) in layers.iter_mut().enumerate() {
            let w = &weights[i];
            let s = net.layers[i].s;
            let kernel = |v: &Volume<Q88>, d_lo: usize, od: usize, oh: usize, ow: usize| {
                let full = uniform::deconv_iom_q_threaded(v, w, s, threads);
                let transient = full.len();
                (uniform::crop_window(&full, d_lo, od, oh, ow), transient)
            };
            let (out, _) = ls.step(&cur, kernel, 0, &mut peak)?;
            cur = out;
        }
        outs.push(cur);
    }
    Ok(concat_frames(&outs))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::service::forward_uniform;
    use crate::dcnn::{synth_frames, synth_uniform_weights, zoo};

    fn cfg_for(net: &Network) -> AccelConfig {
        let mut c = AccelConfig::paper_for(net.dims);
        c.batch = 1;
        c
    }

    #[test]
    fn tiny_3d_stream_is_bit_exact_for_every_chunking() {
        let net = zoo::tiny_3d().with_depth(6);
        let weights = synth_uniform_weights(&net, 0x5EED);
        let input = synth_frames(&net.layers[0], 7, 0, 6);
        let golden = forward_uniform(&net, &weights, input.data());
        for chunk in 1..=6 {
            let (out, sum) =
                stream_forward(&net, &weights, &input, chunk, &cfg_for(&net), 2).unwrap();
            assert_eq!(out.data(), &golden[..], "chunk={chunk}");
            assert_eq!(sum.frames_in, 6);
            assert_eq!(sum.frames_out, out.d);
            assert_eq!(out.d, net.layers.last().unwrap().out_d());
            assert!(sum.total_cycles > 0 && sum.plan_s > 0.0);
        }
    }

    #[test]
    fn chunked_session_peaks_below_whole_volume() {
        let net = zoo::tiny_3d().with_depth(8);
        let weights = synth_uniform_weights(&net, 1);
        let input = synth_frames(&net.layers[0], 2, 0, 8);
        let (_, sum) = stream_forward(&net, &weights, &input, 2, &cfg_for(&net), 1).unwrap();
        assert!(
            sum.peak_live_elems < sum.whole_peak_elems,
            "stream {} !< whole {}",
            sum.peak_live_elems,
            sum.whole_peak_elems
        );
        assert!(sum.peak_ratio() < 1.0);
        // a single whole-depth chunk cannot beat whole-volume memory —
        // a *scatter* statement: only the scatter path materializes
        // the full Eq.-(1) extent `whole_volume_peak_elems` counts
        let (_, whole) = stream_forward_kernel(
            &net,
            &weights,
            &input,
            8,
            &cfg_for(&net),
            1,
            KernelChoice::Scatter,
        )
        .unwrap();
        assert!(whole.peak_live_elems >= whole.whole_peak_elems);
    }

    #[test]
    fn gather_and_scatter_sessions_stream_identical_bits() {
        let net = zoo::tiny_3d().with_depth(6);
        let weights = synth_uniform_weights(&net, 0xABCD);
        let input = synth_frames(&net.layers[0], 11, 0, 6);
        for chunk in [1, 2, 3] {
            let (sc, sc_sum) = stream_forward_kernel(
                &net,
                &weights,
                &input,
                chunk,
                &cfg_for(&net),
                2,
                KernelChoice::Scatter,
            )
            .unwrap();
            let (ga, ga_sum) = stream_forward_kernel(
                &net,
                &weights,
                &input,
                chunk,
                &cfg_for(&net),
                2,
                KernelChoice::Gather,
            )
            .unwrap();
            assert_eq!(sc.data(), ga.data(), "chunk={chunk}");
            // gather never materializes the full extent, so its
            // live-memory peak can only be lower
            assert!(
                ga_sum.peak_live_elems <= sc_sum.peak_live_elems,
                "chunk={chunk}: gather {} > scatter {}",
                ga_sum.peak_live_elems,
                sc_sum.peak_live_elems
            );
        }
    }

    #[test]
    fn d2_session_is_per_frame_passthrough() {
        let net = zoo::tiny_2d();
        let weights = synth_uniform_weights(&net, 3);
        let frames = synth_frames(&net.layers[0], 4, 0, 3);
        // any chunking gives the same bits: frame-by-frame golden
        let (a, sum) = stream_forward(&net, &weights, &frames, 1, &cfg_for(&net), 1).unwrap();
        let (b, _) = stream_forward(&net, &weights, &frames, 3, &cfg_for(&net), 1).unwrap();
        assert_eq!(a.data(), b.data());
        assert_eq!(sum.frames_out, 3);
        for f in 0..3 {
            let golden = forward_uniform(&net, &weights, frames.slice_depth(f, 1).data());
            assert_eq!(a.slice_depth(f, 1).data(), &golden[..], "frame {f}");
        }
        // 2D sessions accept an unbounded stream
        let mut sess = StreamSession::new(&net, weights.clone(), cfg_for(&net), 1).unwrap();
        assert_eq!(sess.frames_remaining(), usize::MAX);
        for start in 0..4 {
            sess.push_chunk(synth_frames(&net.layers[0], 4, start, 1)).unwrap();
        }
        assert_eq!(sess.summary().frames_in, 4);
    }

    #[test]
    fn q88_stream_matches_whole_volume() {
        let net = zoo::tiny_3d();
        let data: Vec<crate::dcnn::LayerData> = net
            .layers
            .iter()
            .enumerate()
            .map(|(i, l)| crate::dcnn::LayerData::synth(l, i as u64))
            .collect();
        let qw: Vec<WeightsOIDHW<Q88>> =
            data.iter().map(|d| d.quantize().uniform_weights()).collect();
        let qi = crate::dcnn::LayerData::synth(&net.layers[0], 42)
            .quantize()
            .uniform_input();
        let whole = whole_forward_q(&net, &qw, &qi).unwrap();
        for chunk in [1, 2] {
            let tiled = stream_forward_q(&net, &qw, &qi, chunk, 2).unwrap();
            assert_eq!(tiled.data(), whole.data(), "chunk={chunk}");
        }
    }

    #[test]
    fn plan_cache_sees_few_distinct_chunk_shapes() {
        let net = zoo::tiny_3d().with_depth(9);
        let weights = synth_uniform_weights(&net, 5);
        let input = synth_frames(&net.layers[0], 6, 0, 9);
        // chunk=2 over 9 frames: slabs 2 (first), 3 (steady), 2 (last)
        let (_, sum) = stream_forward(&net, &weights, &input, 2, &cfg_for(&net), 1).unwrap();
        assert_eq!(sum.chunks, 5);
        assert!(sum.cache.misses <= 2, "{:?}", sum.cache);
        assert!(sum.cache.hits + sum.cache.misses <= sum.chunks as u64);
    }

    #[test]
    fn overflow_and_bad_shapes_are_rejected() {
        let net = zoo::tiny_3d(); // depth 2
        let weights = synth_uniform_weights(&net, 0);
        let mut sess = StreamSession::new(&net, weights.clone(), cfg_for(&net), 1).unwrap();
        assert_eq!(sess.frames_remaining(), 2);
        let too_deep = synth_frames(&net.layers[0], 0, 0, 3);
        assert!(sess.push_chunk(too_deep).unwrap_err().contains("overflow"));
        let bad_frame: Volume<f32> = Volume::zeros(1, 1, 2, 2);
        assert!(sess.push_chunk(bad_frame).is_err());
        // wrong weight count
        assert!(StreamSession::new(&net, weights[..1].to_vec(), cfg_for(&net), 1).is_err());
    }
}
