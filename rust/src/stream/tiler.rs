//! Depth-axis tiling: split a frame sequence into fixed-size chunks.
//!
//! [`DepthTiler`] is pure index arithmetic — it never touches tensor
//! data. The session ([`super::session`]) consumes its chunks in
//! order; the differential battery re-tiles the same stream several
//! ways and demands identical output bits, which holds because chunk
//! boundaries only decide *when* frames arrive, never *what* any
//! output frame reads (see [`crate::graph::stream_shape`]).

/// One depth chunk of a tiled frame sequence.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DepthChunk {
    /// Chunk ordinal, 0-based.
    pub index: usize,
    /// First frame of the chunk in the whole sequence.
    pub start: usize,
    /// Frames in this chunk (the last chunk may be short).
    pub frames: usize,
}

/// Splits `total` depth frames into chunks of (at most) `chunk`
/// frames.
#[derive(Clone, Copy, Debug)]
pub struct DepthTiler {
    total: usize,
    chunk: usize,
}

impl DepthTiler {
    /// A tiler over `total` frames in chunks of `chunk`. A chunk size
    /// at or above `total` yields a single whole-sequence chunk.
    /// Errors when either count is zero.
    pub fn new(total: usize, chunk: usize) -> Result<DepthTiler, String> {
        if total == 0 {
            return Err("cannot tile an empty frame sequence".into());
        }
        if chunk == 0 {
            return Err("chunk size must be at least one frame".into());
        }
        Ok(DepthTiler {
            total,
            chunk: chunk.min(total),
        })
    }

    /// Number of chunks (`⌈total/chunk⌉`, at least 1).
    pub fn len(&self) -> usize {
        self.total.div_ceil(self.chunk)
    }

    /// Always `false` — a tiler covers at least one frame.
    pub fn is_empty(&self) -> bool {
        false
    }

    /// Effective chunk size (the requested size capped at `total`).
    pub fn chunk_frames(&self) -> usize {
        self.chunk
    }

    /// Total frames tiled.
    pub fn total_frames(&self) -> usize {
        self.total
    }

    /// The chunks, in arrival order.
    pub fn chunks(&self) -> Vec<DepthChunk> {
        (0..self.len())
            .map(|index| {
                let start = index * self.chunk;
                DepthChunk {
                    index,
                    start,
                    frames: self.chunk.min(self.total - start),
                }
            })
            .collect()
    }
}

/// Input frames a layer retains across chunks: `⌊(k_d − 1)/s⌋` (the
/// depth halo; see [`crate::graph::stream_shape`] for the derivation).
pub fn halo_frames(k_d: usize, s: usize) -> usize {
    debug_assert!(k_d >= 1 && s >= 1);
    (k_d - 1) / s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_every_frame_exactly_once() {
        for total in 1..=12usize {
            for chunk in 1..=13usize {
                let t = DepthTiler::new(total, chunk).unwrap();
                let chunks = t.chunks();
                assert_eq!(chunks.len(), t.len());
                assert!(!t.is_empty());
                let mut next = 0;
                for (i, c) in chunks.iter().enumerate() {
                    assert_eq!(c.index, i);
                    assert_eq!(c.start, next);
                    assert!(c.frames >= 1);
                    assert!(c.frames <= t.chunk_frames());
                    next += c.frames;
                }
                assert_eq!(next, total, "total={total} chunk={chunk}");
            }
        }
    }

    #[test]
    fn oversized_chunk_degenerates_to_whole() {
        let t = DepthTiler::new(4, 99).unwrap();
        assert_eq!(t.len(), 1);
        let c = t.chunks()[0];
        assert_eq!((c.index, c.start, c.frames), (0, 0, 4));
        assert_eq!(t.total_frames(), 4);
    }

    #[test]
    fn zero_inputs_are_rejected() {
        assert!(DepthTiler::new(0, 2).is_err());
        assert!(DepthTiler::new(2, 0).is_err());
    }

    #[test]
    fn halo_matches_kernel_geometry() {
        assert_eq!(halo_frames(3, 2), 1, "the paper's K=3, S=2");
        assert_eq!(halo_frames(1, 1), 0, "2D depth-1 fold is stateless");
        assert_eq!(halo_frames(1, 2), 0);
        assert_eq!(halo_frames(3, 1), 2);
        assert_eq!(halo_frames(5, 2), 2);
    }
}
