//! Streaming jobs on the serving fleet.
//!
//! A streaming job is a frame source (a camera, a video decode, a
//! volumetric sensor) that captures `chunk` frames per period and
//! ships each completed chunk to the fleet. This adapter lowers jobs
//! onto the *existing* serving machinery rather than growing a second
//! scheduler:
//!
//! * each job's chunks become ordinary requests against a
//!   **chunk-shaped model** — the stream's architecture re-anchored to
//!   its steady-state slab depth via [`Network::with_depth`] (chunk
//!   plus halo; 2D streams are per-frame, chunk 1). Distinct chunk
//!   shapes get distinct model names, so [`crate::serve::PlanCache`]
//!   compiles each slab geometry exactly once and every fleet instance
//!   serves the stream from the same compiled plan;
//! * arrivals come from [`crate::serve::periodic_arrivals`] at the
//!   job's chunk cadence (seeded jitter, one source per job), merged
//!   into one sorted workload;
//! * [`crate::serve::Fleet::run`] then batches, routes least-loaded,
//!   sheds past the latency budget and reports percentiles exactly as
//!   it does for request traffic.

use std::collections::BTreeMap;

use crate::dcnn::{Dims, Network};
use crate::serve::{periodic_arrivals, Arrival, Fleet, FleetOptions, FleetReport};

use super::tiler::halo_frames;

/// One streaming inference job: a frame source against a registered
/// model.
#[derive(Clone, Debug)]
pub struct StreamJob {
    /// Base model (network) name the stream runs on.
    pub model: String,
    /// Total frames the source will deliver.
    pub frames: usize,
    /// Frames captured per chunk (forced to 1 on 2D models).
    pub chunk: usize,
    /// Source frame rate (frames per second of simulated time).
    pub fps: f64,
}

/// Replay streaming `jobs` against a fleet of `opts.instances`
/// simulated accelerator instances. Returns the fleet report plus the
/// chunk-model name each job was served under (job order preserved).
///
/// Errors on an empty job list, a job naming an unknown model, zero
/// frames/chunk, a non-positive frame rate, or any fleet bring-up
/// failure.
pub fn serve_streams(
    nets: &[Network],
    opts: FleetOptions,
    jobs: &[StreamJob],
    seed: u64,
) -> Result<(FleetReport, Vec<String>), String> {
    if jobs.is_empty() {
        return Err("need at least one streaming job".into());
    }
    let mut chunk_models: BTreeMap<String, Network> = BTreeMap::new();
    let mut job_models = Vec::with_capacity(jobs.len());
    let mut arrivals: Vec<Arrival> = Vec::new();
    for (ji, job) in jobs.iter().enumerate() {
        let base = nets
            .iter()
            .find(|n| n.name == job.model)
            .ok_or_else(|| format!("streaming job {ji}: unknown model '{}'", job.model))?;
        if job.frames == 0 || job.chunk == 0 {
            return Err(format!("streaming job {ji}: frames and chunk must be positive"));
        }
        if !(job.fps > 0.0) || !job.fps.is_finite() {
            return Err(format!("streaming job {ji}: fps must be positive and finite"));
        }
        let (chunk_net, chunk_eff) = match base.dims {
            Dims::D2 => (base.clone(), 1),
            Dims::D3 => {
                let l0 = &base.layers[0];
                let chunk_eff = job.chunk.min(job.frames);
                let slab = (chunk_eff + halo_frames(l0.k_d(), l0.s)).min(job.frames);
                (base.with_depth(slab), chunk_eff)
            }
        };
        let name = chunk_net.name.to_string();
        chunk_models.entry(name.clone()).or_insert(chunk_net);
        job_models.push(name.clone());
        let n = job.frames.div_ceil(chunk_eff);
        let period = chunk_eff as f64 / job.fps;
        arrivals.extend(periodic_arrivals(
            seed ^ (ji as u64).wrapping_mul(0x9E37_79B9),
            &name,
            period,
            n,
            0.1,
        ));
    }
    arrivals.sort_by(|a, b| {
        a.t_s
            .partial_cmp(&b.t_s)
            .expect("arrival times are never NaN")
            .then_with(|| a.model.cmp(&b.model))
    });
    let models: Vec<Network> = chunk_models.into_values().collect();
    let report = Fleet::new(models, opts)?.run(&arrivals)?;
    Ok((report, job_models))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dcnn::zoo;

    fn jobs() -> Vec<StreamJob> {
        vec![
            StreamJob {
                model: "tiny-3d".into(),
                frames: 8,
                chunk: 2,
                fps: 120.0,
            },
            StreamJob {
                model: "tiny-2d".into(),
                frames: 6,
                chunk: 4, // forced to per-frame on 2D
                fps: 60.0,
            },
        ]
    }

    fn nets() -> Vec<Network> {
        vec![zoo::tiny_2d(), zoo::tiny_3d()]
    }

    #[test]
    fn jobs_ride_the_existing_fleet_machinery() {
        let (r, served_as) = serve_streams(
            &nets(),
            FleetOptions {
                instances: 2,
                ..FleetOptions::default()
            },
            &jobs(),
            0xCAFE,
        )
        .unwrap();
        // 3D: 8 frames in 2-frame chunks = 4 requests against the
        // chunk-shaped model (slab 2+1); 2D: 6 per-frame requests.
        assert_eq!(r.offered, 4 + 6);
        assert_eq!(r.served, 10);
        assert_eq!(served_as, vec!["tiny-3d@d3".to_string(), "tiny-2d".to_string()]);
        assert_eq!(r.per_model["tiny-3d@d3"], 4);
        assert_eq!(r.per_model["tiny-2d"], 6);
        // chunk-shaped plans are first-class cache citizens
        assert!(r.model_configs.contains_key("tiny-3d@d3"));
    }

    #[test]
    fn deterministic_and_chunk_models_deduplicate() {
        let mut two = jobs();
        two.push(StreamJob {
            model: "tiny-3d".into(),
            frames: 4,
            chunk: 2,
            fps: 30.0,
        });
        let opts = FleetOptions::default();
        let (a, ma) = serve_streams(&nets(), opts.clone(), &two, 7).unwrap();
        let (b, mb) = serve_streams(&nets(), opts, &two, 7).unwrap();
        assert_eq!(a.to_json(), b.to_json());
        assert_eq!(ma, mb);
        // both 3D jobs share one chunk model: it is registered once
        assert_eq!(ma[0], ma[2]);
        assert_eq!(a.per_model.len(), 2);
        assert_eq!(a.per_model["tiny-3d@d3"], 4 + 2);
    }

    #[test]
    fn bad_jobs_are_rejected() {
        let opts = FleetOptions::default();
        assert!(serve_streams(&nets(), opts.clone(), &[], 1).is_err());
        let bad = |j: StreamJob| serve_streams(&nets(), FleetOptions::default(), &[j], 1);
        assert!(bad(StreamJob {
            model: "nope".into(),
            frames: 4,
            chunk: 2,
            fps: 30.0
        })
        .is_err());
        assert!(bad(StreamJob {
            model: "tiny-3d".into(),
            frames: 0,
            chunk: 2,
            fps: 30.0
        })
        .is_err());
        assert!(bad(StreamJob {
            model: "tiny-3d".into(),
            frames: 4,
            chunk: 2,
            fps: 0.0
        })
        .is_err());
    }
}
