//! Streaming temporal-tiled inference: unbounded frame sequences
//! through 3D DCNNs in bounded memory.
//!
//! The paper's 3D benchmarks (3D-GAN volumes, V-Net-style decoders,
//! video super-resolution workloads) consume *temporal* volumes —
//! depth is time. Whole-volume [`forward_uniform`] bounds a "video"
//! by host memory and makes latency all-or-nothing; this subsystem
//! instead tiles the depth axis and streams:
//!
//! * [`tiler`] — [`DepthTiler`] splits a frame sequence into
//!   fixed-size chunks; pure index arithmetic, plus the
//!   [`tiler::halo_frames`] kernel-geometry helper;
//! * [`session`] — [`StreamSession`]: per-layer halo state (derived
//!   from the [`crate::graph::stream_shape`] pass), chunk execution
//!   through the dimension-uniform IOM kernels, per-chunk cycle
//!   estimates ([`crate::accel::timing::simulate_chunk`]) and
//!   compiled-plan latencies (chunk-shaped [`Network::with_depth`]
//!   plans through a [`crate::serve::PlanCache`]), and live-memory
//!   high-water tracking;
//! * [`serve`] — [`serve_streams`]: streaming jobs on the fleet —
//!   chunk arrivals generated at each source's cadence and replayed
//!   through the existing batcher/scheduler/admission machinery.
//!
//! **The determinism contract.** Deconvolution *scatters* along
//! depth, so consecutive output tiles overlap by `K_d − S` frames.
//! Combining overlapping tiles by adding partial sums would reorder
//! f32 accumulation and drift from the whole-volume result; instead
//! every output frame is produced exactly once, from one kernel call
//! whose input slab contains the frame's complete contributor window
//! — the same terms in the same order as whole-volume execution.
//! Tiled output is therefore **bit-exact** against
//! [`forward_uniform`] for every chunk size, thread count, precision
//! (f32 and Q8.8) and accelerator config; `tests/diff_stream.rs` and
//! `tests/prop_stream.rs` enforce it across the zoo and randomized
//! geometries. 2D networks degenerate to stateless per-frame
//! passthrough (chunk = 1).
//!
//! Front ends: `udcnn stream <net> --frames N --chunk D [--json]`,
//! and `benches/streaming.rs` → `reports/BENCH_stream.json`
//! (frames/s and peak working set vs whole-volume).
//!
//! [`forward_uniform`]: crate::coordinator::service::forward_uniform
//! [`Network::with_depth`]: crate::dcnn::Network::with_depth

pub mod serve;
pub mod session;
pub mod tiler;

pub use serve::{serve_streams, StreamJob};
pub use session::{
    concat_frames, stream_forward, stream_forward_kernel, stream_forward_q, whole_forward_q,
    whole_volume_peak_elems, StreamChunkOutput, StreamSession, StreamSummary,
};
pub use tiler::{DepthChunk, DepthTiler};
