//! `udcnn` CLI — the leader entrypoint.
//!
//! Subcommands (hand-rolled parser; the offline build has no clap):
//!
//! ```text
//! udcnn simulate   [--net NAME] [--batch N] [--all]     Fig. 6 numbers
//! udcnn sparsity                                        Fig. 1 numbers
//! udcnn resources                                       Table III
//! udcnn dse        [--max-pes N]                        Table II rationale
//! udcnn tune       <net>... [--json]                    per-network autotuner
//! udcnn compare    [--net NAME]                         Fig. 7 numbers
//! udcnn zoo        --dump                               layer shapes (JSON-ish)
//! udcnn verify     [--artifacts DIR]                    PJRT artifacts vs golden
//! udcnn serve      <net>... --instances N --rps R       fleet serving harness
//! udcnn serve      --autoscale [--scenario NAME]        autoscaling scenario battery
//! ```

use std::collections::BTreeMap;
use std::time::Duration;

use anyhow::{bail, Result};

use udcnn::accel::{simulate_layer, simulate_network, AccelConfig};
use udcnn::baseline::{CpuBaseline, GpuModel};
use udcnn::cli::{first_positional, network_by_name, opt_parse, parse_opts, positionals};
use udcnn::coordinator::{serve_fleet, serve_fleet_obs, serve_scenario_obs, BatchPolicy};
use udcnn::dcnn::{sparsity, zoo, Network};
use udcnn::energy;
use udcnn::obs::Obs;
use udcnn::report::json::{array, JsonObj};
use udcnn::report::{bar_chart, ratio, Table};
use udcnn::resource;
use udcnn::serve::{poisson_arrivals, ConfigPolicy, Fleet, FleetOptions};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let opts = parse_opts(&args[1..]);
    match cmd.as_str() {
        "simulate" => cmd_simulate(&opts),
        "compile" => cmd_compile(&args[1..]),
        "plan" => cmd_plan(&opts),
        "sparsity" => cmd_sparsity(),
        "resources" => cmd_resources(),
        "dse" => cmd_dse(&opts),
        "tune" => cmd_tune(&args[1..]),
        "compare" => cmd_compare(&opts),
        "zoo" => cmd_zoo(),
        "verify" => cmd_verify(&opts),
        "serve" => cmd_serve(&args[1..]),
        "stream" => cmd_stream(&args[1..]),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown subcommand '{other}' (try `udcnn help`)"),
    }
}

fn print_usage() {
    println!(
        "udcnn — uniform 2D/3D DCNN accelerator (Wang et al. 2019 reproduction)\n\
         \n\
         usage: udcnn <simulate|compile|plan|sparsity|resources|dse|tune|compare|zoo|verify|serve|stream> [options]\n\
         \n\
         simulate   --net NAME | --all   [--batch N]   per-layer util + TOPS (Fig. 6)\n\
         compile    NAME [--batch N] [--json] [--oom]  whole-network plan (graph compiler)\n\
           compile options: --trace FILE  --metrics FILE (per-pass spans)\n\
           skip-DAG zoo entries (e.g. `udcnn compile unet3d`, `unetr-dec`) plan\n\
           merge/resample moves; --oom stays chain-only\n\
         plan       --net NAME [--layer NAME]          explain the execution schedule\n\
         sparsity                                      inserted-map sparsity (Fig. 1)\n\
         resources                                     VC709 utilization (Table III)\n\
         dse        [--max-pes N]                      design-space sweep (Table II)\n\
         tune       <net>... [--batch N] [--top K]     per-network DSE autotuner\n\
           tune options: --max-pes N (default 2048)  --json\n\
         compare    [--net NAME]                       CPU/GPU/FPGA (Fig. 7)\n\
         zoo                                           dump benchmark layer shapes\n\
         verify     [--artifacts DIR]                  run PJRT artifacts vs golden\n\
         serve      <net>... [--instances N] [--rps R] fleet serving harness\n\
           serve options: --requests N (default 2048)  --seed S\n\
                          --budget-ms B (default 250)  --max-batch M  --max-wait-ms W\n\
                          --queue-cap Q (shed arrivals past Q queued; default unbounded)\n\
                          --shard (shard models across instances)\n\
                          --tuned (serve autotuned per-model plans)  --json\n\
                          --trace FILE (Chrome trace JSON)  --metrics FILE\n\
           autoscale mode: --autoscale [--scenario NAME]  (default scenario: steady)\n\
                          scenarios: steady diurnal flash-crowd one-tenant-overload\n\
                                     instance-failure scale-down closed-loop\n\
                          --tenants name:class:slo_ms[:queue_cap],... (inf/- = unbounded)\n\
                          --min-instances N  --max-instances N  --bring-up-ms B\n\
                          --seed S  --trace FILE  --metrics FILE  --json\n\
         stream     <net> [--frames N] [--chunk D]     streaming temporal-tiled inference\n\
           stream options: --threads T  --seed S  --verify (check bits vs whole volume)\n\
                           --trace FILE  --metrics FILE  --json"
    );
}

/// Build the observability handle the `--trace FILE` / `--metrics
/// FILE` flags ask for. CLI recording always uses the deterministic
/// clock, so a traced run is byte-identical across repeats and host
/// thread counts (`tests/obs_trace.rs` pins this).
fn obs_from_opts(opts: &BTreeMap<String, String>) -> Obs {
    if opts.contains_key("trace") || opts.contains_key("metrics") {
        Obs::deterministic()
    } else {
        Obs::off()
    }
}

/// Write the recorder's artifacts: Chrome trace-event JSON for
/// `--trace` (loadable at ui.perfetto.dev) and the flat metrics
/// snapshot for `--metrics`. No-op when recording is off.
fn write_obs_artifacts(obs: &Obs, opts: &BTreeMap<String, String>) -> Result<()> {
    let Some(rec) = obs.recorder() else {
        return Ok(());
    };
    if let Some(path) = opts.get("trace") {
        std::fs::write(path, rec.trace_json())?;
        eprintln!("wrote trace: {path} ({} events)", rec.event_count());
    }
    if let Some(path) = opts.get("metrics") {
        std::fs::write(path, rec.metrics_json())?;
        eprintln!("wrote metrics: {path}");
    }
    Ok(())
}

fn cmd_simulate(opts: &BTreeMap<String, String>) -> Result<()> {
    let nets: Vec<Network> = if opts.contains_key("all") || !opts.contains_key("net") {
        zoo::all_benchmarks()
    } else {
        vec![network_by_name(opts.get("net").unwrap())?]
    };
    let batch: usize = opts
        .get("batch")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(8);
    let mut t = Table::new(
        "Fig. 6 — PE utilization and throughput",
        &["layer", "bound", "util %", "eff TOPS", "useful TOPS", "ms/batch"],
    );
    for net in &nets {
        let mut cfg = AccelConfig::paper_for(net.dims);
        cfg.batch = batch;
        for layer in &net.layers {
            let m = simulate_layer(&cfg, layer);
            t.row(&[
                layer.name.clone(),
                m.bound_by.to_string(),
                format!("{:.1}", 100.0 * m.pe_utilization()),
                format!("{:.2}", m.effective_tops(&cfg)),
                format!("{:.2}", m.useful_tops()),
                format!("{:.3}", m.time_s() * 1e3),
            ]);
        }
    }
    t.print();
    Ok(())
}

fn cmd_compile(rest: &[String]) -> Result<()> {
    use udcnn::graph::{self, NetworkGraph};
    let opts = parse_opts(rest);
    let name = first_positional(rest, &["batch", "net", "trace", "metrics"])
        .cloned()
        .or_else(|| opts.get("net").cloned())
        .ok_or_else(|| {
            anyhow::anyhow!("usage: udcnn compile <network> [--batch N] [--json] [--oom]")
        })?;
    let net = network_by_name(&name)?;
    let mut cfg = AccelConfig::paper_for(net.dims);
    cfg.batch = opt_parse(&opts, "batch", cfg.batch)?;

    // Front-end form: the network's native (possibly skip-topology)
    // graph, or the OOM decomposition (`--oom`) that the lowering pass
    // rewrites to the same plan. The OOM front end only exists for
    // linear chains.
    let g = if opts.contains_key("oom") {
        if net.topology != udcnn::dcnn::Topology::Chain {
            anyhow::bail!(
                "--oom only applies to chain networks; '{}' has a skip topology",
                net.name
            );
        }
        NetworkGraph::from_network_oom(&net)
    } else {
        net.graph()
    };
    let obs = obs_from_opts(&opts);
    let track = obs.track("compile");
    let whole = obs.scope(track, "compile", &format!("compile {}", net.name));
    let lowered = graph::passes::lower_obs(&g, &obs).map_err(|e| anyhow::anyhow!("{e}"))?;
    let plan = {
        let _s = obs.scope(track, "pass", "schedule_and_reuse");
        graph::compile(&cfg, &lowered).map_err(|e| anyhow::anyhow!("{e}"))?
    };
    drop(whole);
    write_obs_artifacts(&obs, &opts)?;

    if opts.contains_key("json") {
        println!("{}", plan.to_json());
        return Ok(());
    }
    print!("{}", plan.render());
    let m = graph::simulate_plan(&plan);
    let iso = simulate_network(&cfg, &net);
    println!(
        "e2e: {:.3} ms/batch-{} | {:.2} effective TOPS | {:.2} useful TOPS | util {:.1}% | {:.1} GB/s DDR",
        m.time_s() * 1e3,
        m.batch,
        m.effective_tops(),
        m.useful_tops(),
        100.0 * m.avg_pe_utilization(),
        m.dram_gbps(),
    );
    println!(
        "vs isolated layers: {:.3} ms | {:.2} effective TOPS | DDR saved {:.2} MiB",
        iso.total_time_s() * 1e3,
        iso.effective_tops(),
        plan.bytes_saved() as f64 / (1024.0 * 1024.0),
    );
    Ok(())
}

fn cmd_plan(opts: &BTreeMap<String, String>) -> Result<()> {
    let net = network_by_name(opts.get("net").map(|s| s.as_str()).unwrap_or("dcgan"))?;
    let cfg = AccelConfig::paper_for(net.dims);
    match opts.get("layer") {
        Some(name) => {
            let layer = net
                .layer(name)
                .ok_or_else(|| anyhow::anyhow!("no layer '{name}' in {}", net.name))?;
            print!("{}", udcnn::accel::plan::explain(&cfg, layer));
        }
        None => {
            for layer in &net.layers {
                print!("{}", udcnn::accel::plan::explain(&cfg, layer));
                println!();
            }
        }
    }
    Ok(())
}

fn cmd_sparsity() -> Result<()> {
    let rows = sparsity::fig1_dataset(&[zoo::dcgan(), zoo::gan3d()], 7);
    let mut t = Table::new(
        "Fig. 1 — sparsity of the deconvolutional layers",
        &["layer", "analytic", "empirical"],
    );
    for r in &rows {
        t.row(&[
            r.layer.clone(),
            format!("{:.3}", r.analytic),
            format!("{:.3}", r.empirical),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_resources() -> Result<()> {
    let est = resource::estimate(&AccelConfig::paper_3d());
    let p = est.percentages();
    let mut t = Table::new(
        "Table III — resource utilization of Xilinx VC709",
        &["resource", "used", "device", "percent"],
    );
    t.row(&["DSP48E".into(), est.dsp.to_string(), resource::VC709_DSP.to_string(), format!("{:.2}", p[0])]);
    t.row(&["BRAM36".into(), est.bram36.to_string(), resource::VC709_BRAM36.to_string(), format!("{:.2}", p[1])]);
    t.row(&["Flip-Flops".into(), est.ff.to_string(), resource::VC709_FF.to_string(), format!("{:.2}", p[2])]);
    t.row(&["LUTs".into(), est.lut.to_string(), resource::VC709_LUT.to_string(), format!("{:.2}", p[3])]);
    t.print();
    Ok(())
}

fn cmd_dse(opts: &BTreeMap<String, String>) -> Result<()> {
    use udcnn::accel::dse;
    let max_pes: usize = opts
        .get("max-pes")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(2048);
    let budget = dse::DseBudget { max_pes };
    let nets = zoo::all_benchmarks();
    let points = dse::sweep(&nets, &budget).map_err(|e| anyhow::anyhow!("{e}"))?;
    let mut t = Table::new(
        "Table II rationale — design-space sweep (best 10 of the space)",
        &["Tm", "Tn", "Tz", "Tr", "Tc", "PEs", "Mcycles", "util %"],
    );
    for p in points.iter().take(10) {
        t.row(&[
            p.cfg.tm.to_string(),
            p.cfg.tn.to_string(),
            p.cfg.tz.to_string(),
            p.cfg.tr.to_string(),
            p.cfg.tc.to_string(),
            p.cfg.total_pes().to_string(),
            format!("{:.1}", p.total_cycles as f64 / 1e6),
            format!("{:.1}", 100.0 * p.avg_utilization),
        ]);
    }
    t.print();
    Ok(())
}

/// `udcnn tune <net>... [--batch N] [--top K] [--max-pes N] [--json]`:
/// run the roofline-pruned autotuner per network and print the ranked
/// designs with their justification (binding roofline, utilization,
/// resource footprint) next to the `AccelConfig::default()` baseline.
fn cmd_tune(rest: &[String]) -> Result<()> {
    use udcnn::accel::dse::tune::{tune_network, TuneOptions};
    use udcnn::accel::dse::DseBudget;
    let opts = parse_opts(rest);
    let value_keys = &["batch", "max-pes", "top"];
    let names = positionals(rest, value_keys);
    let nets: Vec<Network> = if names.is_empty() {
        zoo::all_benchmarks()
    } else {
        names
            .iter()
            .map(|n| network_by_name(n.as_str()))
            .collect::<Result<_>>()?
    };
    let max_pes: usize = opt_parse(&opts, "max-pes", DseBudget::default().max_pes)?;
    let topts = TuneOptions {
        budget: DseBudget { max_pes },
        batch: opt_parse(&opts, "batch", TuneOptions::default().batch)?,
        keep: opt_parse(&opts, "top", TuneOptions::default().keep)?,
    };
    let mut results = Vec::new();
    for net in &nets {
        let r = tune_network(net, &topts).map_err(anyhow::Error::msg)?;
        results.push(r);
    }

    if opts.contains_key("json") {
        let docs: Vec<String> = results.iter().map(|r| r.to_json()).collect();
        println!("{}", array(&docs));
        return Ok(());
    }

    for r in &results {
        let mut t = Table::new(
            &format!(
                "tuned configs for {} (batch {}, {} evaluated / {} pruned by roofline)",
                r.network, topts.batch, r.evaluated, r.pruned
            ),
            &["rank", "config", "PEs", "DSP", "BRAM", "Mcycles", "ms", "TOPS", "bound", "util%"],
        );
        for (i, p) in r.ranked.iter().enumerate() {
            let c = &p.cfg;
            t.row(&[
                (i + 1).to_string(),
                c.describe(),
                c.total_pes().to_string(),
                p.resources.dsp.to_string(),
                p.resources.bram36.to_string(),
                format!("{:.2}", p.total_cycles as f64 / 1e6),
                format!("{:.3}", p.time_s * 1e3),
                format!("{:.2}", p.effective_tops),
                p.bound_by.to_string(),
                format!("{:.1}", 100.0 * p.utilization),
            ]);
        }
        t.print();
        let d = &r.default_point;
        println!(
            "default ({}): {:.2} Mcycles, {:.2} TOPS  =>  tuned speedup {}",
            d.cfg.fingerprint(),
            d.total_cycles as f64 / 1e6,
            d.effective_tops,
            ratio(r.speedup_vs_default())
        );
        println!(
            "winner: {} ({} bound, roofline floor {:.2} Mcycles, FIFO depth {})",
            r.best().cfg.fingerprint(),
            r.best().bound_by,
            r.best().roofline.lower_bound_cycles() as f64 / 1e6,
            r.fifo_depth
        );
        println!();
    }
    Ok(())
}

fn cmd_compare(opts: &BTreeMap<String, String>) -> Result<()> {
    let nets: Vec<Network> = match opts.get("net") {
        Some(n) => vec![network_by_name(n)?],
        None => zoo::all_benchmarks(),
    };
    let cpu = CpuBaseline::default();
    let gpu = GpuModel::default();
    let batch = 8usize;
    let mut perf_items = Vec::new();
    let mut energy_items = Vec::new();
    for net in &nets {
        let mut cfg = AccelConfig::paper_for(net.dims);
        cfg.batch = batch;
        let fm = simulate_network(&cfg, net);
        let t_fpga = fm.total_time_s();
        let t_cpu: f64 = net
            .layers
            .iter()
            .map(|l| cpu.run_layer(l).seconds_per_item * batch as f64)
            .sum();
        let t_gpu = gpu.network_seconds(net, batch);
        let dense: u64 = net
            .layers
            .iter()
            .map(udcnn::accel::metrics::dense_equivalent_macs)
            .sum();
        let ops = 2.0 * dense as f64 * batch as f64;
        let p_fpga: f64 = fm
            .layers
            .iter()
            .map(|m| energy::fpga_watts(&cfg, m) * m.time_s())
            .sum::<f64>()
            / t_fpga;
        println!(
            "{}: FPGA {:.2} ms  CPU {:.1} ms ({})  GPU {:.2} ms   speedup vs CPU {}  vs GPU {}",
            net.name,
            t_fpga * 1e3,
            t_cpu * 1e3,
            if net.layers.iter().all(|l| l.op_counts().dense_macs <= cpu.direct_limit_macs) { "measured" } else { "partly extrapolated" },
            t_gpu * 1e3,
            ratio(t_cpu / t_fpga),
            ratio(t_gpu / t_fpga),
        );
        perf_items.push((format!("{} fpga", net.name), ops / t_fpga / 1e12));
        perf_items.push((format!("{} gpu", net.name), ops / t_gpu / 1e12));
        perf_items.push((format!("{} cpu", net.name), ops / t_cpu / 1e12));
        let e_fpga = energy::gops_per_joule(ops, t_fpga, p_fpga);
        let e_cpu = energy::gops_per_joule(ops, t_cpu, energy::CPU_WATTS);
        let e_gpu = energy::gops_per_joule(ops, t_gpu, energy::GPU_WATTS);
        energy_items.push((format!("{} fpga", net.name), e_fpga));
        energy_items.push((format!("{} gpu", net.name), e_gpu));
        energy_items.push((format!("{} cpu", net.name), e_cpu));
    }
    println!();
    print!("{}", bar_chart("Fig. 7(a) — throughput (dense-equiv TOPS)", &perf_items, "TOPS", 40));
    println!();
    print!("{}", bar_chart("Fig. 7(b) — energy efficiency (GOPS/J)", &energy_items, "GOPS/J", 40));
    Ok(())
}

fn cmd_zoo() -> Result<()> {
    for net in zoo::all_benchmarks() {
        println!("network {} ({})", net.name, net.dims);
        for l in &net.layers {
            println!("  {l}");
        }
    }
    Ok(())
}

fn cmd_verify(opts: &BTreeMap<String, String>) -> Result<()> {
    use udcnn::runtime::{ArtifactSet, Runtime};
    let dir = opts
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(ArtifactSet::default_dir);
    let set = ArtifactSet::discover(&dir)?;
    if set.is_empty() {
        bail!("no .hlo.txt artifacts in {} — run `make artifacts`", dir.display());
    }
    let rt = Runtime::cpu()?;
    println!("PJRT platform: {} ({} devices)", rt.platform(), rt.device_count());
    for name in set.names() {
        let exe = rt.load_hlo_text(set.get(name).unwrap())?;
        println!("  compiled artifact '{}' OK", exe.name);
    }
    println!("all {} artifacts compile", set.names().len());
    Ok(())
}

/// `udcnn serve <net>... --instances N --rps R`: replay a deterministic
/// open-loop Poisson workload against a fleet of N simulated
/// accelerator instances, and against a single instance for the
/// scaling comparison. Without `--rps` the offered load is set to
/// 2.5× the fleet's estimated aggregate capacity, which saturates it
/// and makes the reported speedup a capacity ratio.
fn cmd_serve(rest: &[String]) -> Result<()> {
    let opts = parse_opts(rest);
    if opts.contains_key("autoscale") || opts.contains_key("scenario") {
        return cmd_serve_autoscale(rest);
    }
    let value_keys = &[
        "instances",
        "rps",
        "requests",
        "seed",
        "budget-ms",
        "max-batch",
        "max-wait-ms",
        "queue-cap",
        "trace",
        "metrics",
    ];
    let names = positionals(rest, value_keys);
    let nets: Vec<Network> = if names.is_empty() {
        vec![zoo::dcgan(), zoo::gan3d()] // one 2D + one 3D by default
    } else {
        names
            .iter()
            .map(|n| network_by_name(n.as_str()))
            .collect::<Result<_>>()?
    };
    let instances: usize = opt_parse(&opts, "instances", 2)?;
    let requests: usize = opt_parse(&opts, "requests", 2048)?;
    let seed: u64 = opt_parse(&opts, "seed", 0xF1EE7)?;
    let budget_ms: f64 = opt_parse(&opts, "budget-ms", 250.0)?;
    let policy = BatchPolicy {
        max_batch: opt_parse(&opts, "max-batch", BatchPolicy::default().max_batch)?,
        max_wait: Duration::from_micros(
            (opt_parse(&opts, "max-wait-ms", 2.0f64)? * 1e3) as u64,
        ),
    };
    // --tuned: run the autotuner once per model here and hand every
    // fleet (probe, main, baseline) the resolved configs explicitly,
    // so bring-up does not repeat the identical search three times.
    // The fleet reports therefore label the policy "explicit"; the
    // top-level `config_mode` field (JSON) and the banner line (text)
    // record that the configs came from the autotuner.
    let tuned_mode = opts.contains_key("tuned");
    let config_policy = if tuned_mode {
        let mut tuned = std::collections::BTreeMap::new();
        for net in &nets {
            let cfg = ConfigPolicy::Tuned
                .resolve(net, policy.max_batch)
                .map_err(anyhow::Error::msg)?;
            tuned.insert(net.name.to_string(), cfg);
        }
        ConfigPolicy::Explicit(tuned)
    } else {
        ConfigPolicy::Paper
    };
    let fleet_opts = FleetOptions {
        instances,
        policy,
        latency_budget_s: budget_ms / 1e3,
        shard_models: opts.contains_key("shard"),
        config_policy: config_policy.clone(),
        queue_cap: opt_parse(&opts, "queue-cap", usize::MAX)?,
    };

    // offered load: explicit --rps, else saturate the fleet (2.5x the
    // estimated aggregate full-batch capacity)
    let model_names: Vec<&str> = nets.iter().map(|n| n.name).collect();
    let rps: f64 = match opts.get("rps") {
        Some(v) => {
            let r: f64 = v
                .parse()
                .map_err(|e| anyhow::anyhow!("invalid --rps '{v}': {e}"))?;
            if !(r > 0.0) || !r.is_finite() {
                bail!("--rps must be a positive finite rate (got {v})");
            }
            r
        }
        None => {
            let mut probe = Fleet::new(
                nets.clone(),
                FleetOptions {
                    instances: 1,
                    policy,
                    config_policy: config_policy.clone(),
                    ..FleetOptions::default()
                },
            )
            .map_err(|e| anyhow::anyhow!("{e}"))?;
            let mut per_req_s = 0.0;
            for m in &model_names {
                per_req_s +=
                    probe.batch_latency_s(m, policy.max_batch).map_err(|e| anyhow::anyhow!("{e}"))?
                        / policy.max_batch as f64;
            }
            let single_capacity = model_names.len() as f64 / per_req_s;
            2.5 * instances as f64 * single_capacity
        }
    };

    let workload = poisson_arrivals(seed, rps, requests, &model_names);
    // Only the main fleet is observed: the probe and scaling-baseline
    // runs would interleave their events with the run being traced.
    let obs = obs_from_opts(&opts);
    let fleet = serve_fleet_obs(nets.clone(), fleet_opts.clone(), &workload, obs.clone())
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let single = if instances == 1 {
        fleet.clone()
    } else {
        // the scaling baseline: one instance hosting every model (no
        // sharding — a single board cannot shard), same workload
        serve_fleet(
            nets,
            FleetOptions {
                instances: 1,
                shard_models: false,
                ..fleet_opts
            },
            &workload,
        )
        .map_err(|e| anyhow::anyhow!("{e}"))?
    };
    let speedup = if single.throughput_rps > 0.0 {
        fleet.throughput_rps / single.throughput_rps
    } else {
        0.0
    };
    write_obs_artifacts(&obs, &opts)?;

    if opts.contains_key("json") {
        let doc = JsonObj::new()
            .str("workload", &format!("poisson seed={seed} rps={rps:.1} n={requests}"))
            .str("config_mode", if tuned_mode { "tuned" } else { "paper" })
            .num("offered_rps", rps)
            .num("speedup_vs_single", speedup)
            .raw("fleet", &fleet.to_json())
            .raw("single_instance", &single.to_json())
            .render();
        println!("{doc}");
        return Ok(());
    }

    println!(
        "workload: {} requests, poisson @ {:.1} req/s (seed {seed}), models {:?}",
        requests, rps, model_names
    );
    if tuned_mode {
        println!("configs autotuned once per model (served as explicit per-model configs)");
    }
    print!("{}", fleet.render());
    println!(
        "single instance: {:.1} req/s | p99 {:.3} ms  =>  aggregate speedup {:.2}x with {} instances",
        single.throughput_rps,
        single.latency.p99_ms,
        speedup,
        fleet.instances
    );
    Ok(())
}

/// `udcnn serve --autoscale [--scenario NAME]`: run one named
/// adversarial scenario against the autoscaling multi-tenant fleet
/// (`--autoscale` alone runs `steady`; the roster of names is
/// [`udcnn::serve::SCENARIO_NAMES`]). The scenario self-parameterizes
/// from a capacity probe of the chosen networks, so the same name
/// stresses a rack of DCGANs and a rack of V-Nets proportionally.
/// Everything runs on the simulated clock: repeated runs print
/// byte-identical reports on any host at any thread count, which is
/// what lets CI `cmp` two invocations.
fn cmd_serve_autoscale(rest: &[String]) -> Result<()> {
    use udcnn::serve::{parse_tenant_specs, ScenarioOverrides};
    let opts = parse_opts(rest);
    let value_keys = &[
        "scenario",
        "tenants",
        "seed",
        "min-instances",
        "max-instances",
        "bring-up-ms",
        "trace",
        "metrics",
    ];
    let names = positionals(rest, value_keys);
    let nets: Vec<Network> = if names.is_empty() {
        vec![zoo::dcgan(), zoo::gan3d()] // one 2D + one 3D by default
    } else {
        names
            .iter()
            .map(|n| network_by_name(n.as_str()))
            .collect::<Result<_>>()?
    };
    let scenario = opts.get("scenario").map(|s| s.as_str()).unwrap_or("steady");
    let seed: u64 = opt_parse(&opts, "seed", 0xF1EE7)?;
    let ov = ScenarioOverrides {
        min_instances: opts.get("min-instances").map(|s| s.parse()).transpose()?,
        max_instances: opts.get("max-instances").map(|s| s.parse()).transpose()?,
        bring_up_s: opts
            .get("bring-up-ms")
            .map(|s| s.parse::<f64>())
            .transpose()?
            .map(|ms| ms / 1e3),
        tenants: opts
            .get("tenants")
            .map(|s| parse_tenant_specs(s).map_err(anyhow::Error::msg))
            .transpose()?,
    };
    let obs = obs_from_opts(&opts);
    let run = serve_scenario_obs(scenario, seed, &nets, &ov, obs.clone())
        .map_err(anyhow::Error::msg)?;
    write_obs_artifacts(&obs, &opts)?;
    if opts.contains_key("json") {
        println!("{}", run.to_json());
    } else {
        print!("{}", run.render());
    }
    Ok(())
}

/// `udcnn stream <net> [--frames N] [--chunk D]`: run a streaming
/// temporal-tiled inference session — a 3D network re-anchored to an
/// `N`-frame sequence, fed in `D`-frame chunks with per-layer halo
/// carry (2D networks stream frame by frame). Reports frames/s from
/// the per-chunk cycle estimates and the compiled-plan path, and the
/// session's peak working set against whole-volume execution.
/// `--verify` reassembles the streamed output and checks it bit-exact
/// against the whole-volume golden forward.
fn cmd_stream(rest: &[String]) -> Result<()> {
    use udcnn::coordinator::service::forward_uniform;
    use udcnn::dcnn::{synth_frames, synth_uniform_weights, Dims};
    use udcnn::stream::{DepthTiler, StreamSession};
    let opts = parse_opts(rest);
    let value_keys = &["frames", "chunk", "threads", "seed", "trace", "metrics"];
    let name = first_positional(rest, value_keys).cloned().ok_or_else(|| {
        anyhow::anyhow!("usage: udcnn stream <network> [--frames N] [--chunk D] [--json]")
    })?;
    let base = network_by_name(&name)?;
    let frames: usize = opt_parse(&opts, "frames", 16)?;
    let chunk: usize = opt_parse(&opts, "chunk", 4)?;
    if frames == 0 || chunk == 0 {
        bail!("--frames and --chunk must be positive");
    }
    let default_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let threads: usize = opt_parse(&opts, "threads", default_threads)?;
    let seed: u64 = opt_parse(&opts, "seed", 0xF00D)?;
    let verify = opts.contains_key("verify");

    let net = if base.dims == Dims::D3 {
        base.with_depth(frames)
    } else {
        base
    };
    let mut cfg = AccelConfig::paper_for(net.dims);
    cfg.batch = 1; // one stream, one volume in flight per chunk
    let weights = synth_uniform_weights(&net, 0x5EED);
    let mut sess = StreamSession::new(&net, weights.clone(), cfg, threads)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let obs = obs_from_opts(&opts);
    sess.set_obs(obs.clone());

    // Frames are synthesized per chunk (seeded per frame index), so
    // nothing whole-volume is ever allocated unless --verify asks for
    // the golden comparison.
    let tiler = DepthTiler::new(frames, chunk).map_err(|e| anyhow::anyhow!("{e}"))?;
    let mut outs = Vec::new();
    for ch in tiler.chunks() {
        let arriving = synth_frames(&net.layers[0], seed, ch.start, ch.frames);
        let out = sess.push_chunk(arriving).map_err(|e| anyhow::anyhow!("{e}"))?;
        if verify {
            outs.push(out.frames);
        }
    }
    let sum = sess.summary();
    write_obs_artifacts(&obs, &opts)?;

    let bit_exact = if verify {
        let streamed = udcnn::stream::concat_frames(&outs);
        let ok = if net.dims == Dims::D3 {
            let input = synth_frames(&net.layers[0], seed, 0, frames);
            let golden = forward_uniform(&net, &weights, input.data());
            streamed.data() == &golden[..]
        } else {
            (0..frames).all(|f| {
                let frame = synth_frames(&net.layers[0], seed, f, 1);
                let golden = forward_uniform(&net, &weights, frame.data());
                streamed.slice_depth(f, 1).data() == &golden[..]
            })
        };
        if !ok {
            bail!("streamed output diverged from the whole-volume forward");
        }
        Some(true)
    } else {
        None
    };

    let plan_fps = if sum.plan_s > 0.0 {
        frames as f64 / sum.plan_s
    } else {
        0.0
    };
    if opts.contains_key("json") {
        let mut doc = JsonObj::new()
            .str("workload", &format!("seed={seed} frames={frames} chunk={chunk}"))
            .int("threads", threads as u64)
            .num("plan_frames_per_s", plan_fps)
            .raw("session", &sum.to_json());
        if let Some(ok) = bit_exact {
            doc = doc.str("bit_exact_vs_whole", if ok { "yes" } else { "no" });
        }
        println!("{}", doc.render());
        return Ok(());
    }

    println!(
        "streaming {}: {} frames in {} chunk(s) of <= {} ({} threads)",
        sum.network,
        sum.frames_in,
        sum.chunks,
        tiler.chunk_frames(),
        threads
    );
    for sh in sess.shapes() {
        println!(
            "  {}: halo {} frame(s), {} -> {} frames (K_d={}, S={})",
            sh.name, sh.halo_in, sh.in_frames, sh.out_frames, sh.k_d, sh.s
        );
    }
    println!(
        "cycles: {:.2} M ({:.3} ms) => {:.1} frames/s | plan path: {:.3} ms => {:.1} frames/s",
        sum.total_cycles as f64 / 1e6,
        sum.accel_s * 1e3,
        sum.frames_per_s(),
        sum.plan_s * 1e3,
        plan_fps,
    );
    let mib = |elems: usize| elems as f64 * 4.0 / (1024.0 * 1024.0);
    println!(
        "peak working set: {:.2} MiB streamed vs {:.2} MiB whole-volume ({})",
        mib(sum.peak_live_elems),
        mib(sum.whole_peak_elems),
        ratio(sum.peak_ratio()),
    );
    println!(
        "plan cache: {} compiled chunk shapes, {} hits / {} misses",
        sum.cache.misses, sum.cache.hits, sum.cache.misses
    );
    if bit_exact == Some(true) {
        println!("bit-exact vs whole volume: yes ({} output frames)", sum.frames_out);
    }
    Ok(())
}
