//! Thread-local scratch-buffer pools for the allocation-free host
//! hot path.
//!
//! The kernel entry points in [`super::uniform`], the coordinator's
//! golden forward, and the streaming session all need per-call output
//! and scratch buffers whose sizes repeat request after request. This
//! module lends them out of a thread-local free list instead of the
//! global allocator: [`take_f32`] hands back a zero-filled buffer of
//! the requested length, reusing any pooled allocation whose
//! *capacity* fits (so steady-state serving performs **zero** heap
//! allocation per request — the contract the counting-allocator
//! battery in `tests/obs_trace.rs` pins); [`give_f32`] returns a
//! buffer to the pool when its holder is done.
//!
//! Lifecycle: buffer sizes grow monotonically toward each workload's
//! fixpoint during warm-up, after which every `take` is a capacity
//! hit. Buffers that escape to callers (a forward pass's final
//! output) simply leave the pool; the next `take` of that size
//! allocates a replacement. The pool holds at most [`MAX_POOLED`]
//! buffers per element type — give-backs beyond that are dropped, so
//! an unusual burst cannot pin memory forever. Pools are
//! thread-local: scoped kernel worker threads see fresh (empty)
//! pools and fall back to plain allocation, which is fine — spawning
//! those workers allocates stacks anyway, and the allocation-free
//! batteries pin the single-threaded serving path.

use std::cell::RefCell;

use crate::tensor::Volume;

/// Maximum buffers retained per element-type pool; give-backs beyond
/// this are dropped.
pub const MAX_POOLED: usize = 32;

/// Running pool counters (monotonic), for tests and diagnostics.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// `take` calls served from a pooled buffer (no allocation).
    pub hits: u64,
    /// `take` calls that had to allocate (empty pool or no capacity fit).
    pub misses: u64,
    /// Buffers accepted back by `give`.
    pub returned: u64,
}

struct Pool<T> {
    bufs: Vec<Vec<T>>,
    stats: PoolStats,
}

impl<T> Pool<T> {
    fn new() -> Pool<T> {
        Pool {
            // full capacity up front: pushing a give-back never reallocates
            bufs: Vec::with_capacity(MAX_POOLED),
            stats: PoolStats::default(),
        }
    }
}

impl<T: Copy + Default> Pool<T> {
    fn take(&mut self, len: usize) -> Vec<T> {
        let mut buf = match self.bufs.iter().position(|b| b.capacity() >= len) {
            Some(i) => {
                self.stats.hits += 1;
                self.bufs.swap_remove(i)
            }
            None => {
                self.stats.misses += 1;
                Vec::with_capacity(len)
            }
        };
        buf.clear();
        buf.resize(len, T::default());
        buf
    }

    fn give(&mut self, buf: Vec<T>) {
        if buf.capacity() == 0 || self.bufs.len() >= MAX_POOLED {
            return;
        }
        self.stats.returned += 1;
        self.bufs.push(buf);
    }
}

thread_local! {
    static POOL_F32: RefCell<Pool<f32>> = RefCell::new(Pool::new());
    static POOL_I64: RefCell<Pool<i64>> = RefCell::new(Pool::new());
}

/// Check out a zero-filled `f32` buffer of exactly `len` elements.
/// Reuses a pooled allocation when one with sufficient capacity
/// exists (zero-filling is a memset, not an allocation).
pub fn take_f32(len: usize) -> Vec<f32> {
    POOL_F32.with(|p| p.borrow_mut().take(len))
}

/// Return an `f32` buffer to the pool for reuse.
pub fn give_f32(buf: Vec<f32>) {
    POOL_F32.with(|p| p.borrow_mut().give(buf))
}

/// Check out a zero-filled `i64` buffer (raw [`crate::fixed::Acc48`]
/// bits for the Q8.8 kernels' wide accumulation scratch).
pub fn take_i64(len: usize) -> Vec<i64> {
    POOL_I64.with(|p| p.borrow_mut().take(len))
}

/// Return an `i64` buffer to the pool for reuse.
pub fn give_i64(buf: Vec<i64>) {
    POOL_I64.with(|p| p.borrow_mut().give(buf))
}

/// Check out a zero-filled `c × d × h × w` [`Volume`] backed by a
/// pooled buffer — the pooled equivalent of [`Volume::zeros`].
pub fn take_volume_f32(c: usize, d: usize, h: usize, w: usize) -> Volume<f32> {
    Volume::from_vec(c, d, h, w, take_f32(c * d * h * w))
}

/// Return a volume's backing buffer to the pool.
pub fn give_volume_f32(vol: Volume<f32>) {
    give_f32(vol.into_vec());
}

/// Snapshot of the calling thread's `f32` pool counters.
pub fn stats_f32() -> PoolStats {
    POOL_F32.with(|p| p.borrow().stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_reuses_capacity_and_zero_fills() {
        let before = stats_f32();
        let mut a = take_f32(128);
        a.iter_mut().for_each(|v| *v = 7.0);
        let cap = a.capacity();
        give_f32(a);
        // a smaller request must reuse the same allocation, zeroed
        let b = take_f32(64);
        assert!(b.capacity() >= 64);
        assert_eq!(b.capacity(), cap, "capacity-fit reuse");
        assert!(b.iter().all(|&v| v == 0.0), "pooled buffers come back zeroed");
        let after = stats_f32();
        assert_eq!(after.hits - before.hits, 1);
        assert_eq!(after.returned - before.returned, 1);
        give_f32(b);
    }

    #[test]
    fn growth_reaches_a_fixpoint() {
        // after the largest size is pooled, every take is a hit
        for len in [16usize, 64, 256] {
            give_f32(take_f32(len));
        }
        let before = stats_f32();
        for _ in 0..10 {
            for len in [16usize, 64, 256] {
                give_f32(take_f32(len));
            }
        }
        let after = stats_f32();
        assert_eq!(after.misses, before.misses, "steady state never allocates");
        assert_eq!(after.hits - before.hits, 30);
    }

    #[test]
    fn volume_round_trip_is_zeroed() {
        let mut v = take_volume_f32(2, 1, 3, 4);
        *v.at_mut(1, 0, 2, 3) = 5.0;
        give_volume_f32(v);
        let v2 = take_volume_f32(2, 1, 3, 4);
        assert_eq!((v2.c, v2.d, v2.h, v2.w), (2, 1, 3, 4));
        assert!(v2.data().iter().all(|&x| x == 0.0));
        give_volume_f32(v2);
    }

    #[test]
    fn empty_and_overflow_givebacks_are_dropped() {
        give_f32(Vec::new()); // capacity 0: dropped silently
        let before = stats_f32();
        give_f32(Vec::new());
        assert_eq!(stats_f32().returned, before.returned);
        let bufs: Vec<Vec<f32>> = (0..MAX_POOLED + 4).map(|_| Vec::with_capacity(8)).collect();
        for b in bufs {
            give_f32(b);
        }
        // no panic, pool capped — a take still works
        give_f32(take_f32(8));
    }

    #[test]
    fn i64_pool_round_trips() {
        let a = take_i64(32);
        assert!(a.iter().all(|&x| x == 0));
        give_i64(a);
        let b = take_i64(16);
        assert!(b.capacity() >= 32, "reused the larger pooled buffer");
        give_i64(b);
    }
}
