//! The two deconvolution formulations in f32.

use crate::tensor::{FeatureMap, Volume, WeightsOIHW, WeightsOIDHW};

use super::conv::{corr2d, corr3d, flip_2d, flip_3d};
use super::zero_insert::{insert_2d, insert_3d, pad_2d, pad_3d};

// ---------------------------------------------------------------------
// IOM: scatter-accumulate. out[o][ih·S+kh][iw·S+kw] += in[i][ih][iw]·w
// ---------------------------------------------------------------------

/// 2D IOM deconvolution over the full Eq. (1) extent.
///
/// Hot path of the coordinator's golden forward (§Perf): the inner
/// loops work on contiguous row slices so the compiler can vectorize
/// the `K`-wide scatter-accumulate.
pub fn deconv2d_iom(
    input: &FeatureMap<f32>,
    w: &WeightsOIHW<f32>,
    s: usize,
) -> FeatureMap<f32> {
    assert_eq!(input.c, w.i, "channel mismatch");
    assert_eq!(w.kh, w.kw, "square kernels only");
    let k = w.kh;
    let (in_h, in_w) = (input.h, input.w);
    let oh = (in_h - 1) * s + k;
    let ow = (in_w - 1) * s + k;
    let mut out = FeatureMap::zeros(w.o, oh, ow);
    let out_data = out.data_mut();
    for o in 0..w.o {
        let o_base = o * oh * ow;
        for i in 0..input.c {
            let kern = w.kernel(o, i);
            let in_plane = input.plane(i);
            for ih in 0..in_h {
                let in_row = &in_plane[ih * in_w..(ih + 1) * in_w];
                for kh in 0..k {
                    let krow = &kern[kh * k..(kh + 1) * k];
                    let orow_base = o_base + (ih * s + kh) * ow;
                    if k == 3 {
                        // benchmark-uniform K=3: unrolled scatter
                        let (k0, k1, k2) = (krow[0], krow[1], krow[2]);
                        for (iw, &a) in in_row.iter().enumerate() {
                            if a == 0.0 {
                                continue;
                            }
                            let base = orow_base + iw * s;
                            out_data[base] += a * k0;
                            out_data[base + 1] += a * k1;
                            out_data[base + 2] += a * k2;
                        }
                    } else {
                        for (iw, &a) in in_row.iter().enumerate() {
                            if a == 0.0 {
                                continue; // IOM never multiplies a zero
                            }
                            let dst =
                                &mut out_data[orow_base + iw * s..orow_base + iw * s + k];
                            for (d, &kv) in dst.iter_mut().zip(krow) {
                                *d += a * kv;
                            }
                        }
                    }
                }
            }
        }
    }
    out
}

/// 3D IOM deconvolution over the full Eq. (1) extent (Fig. 5).
pub fn deconv3d_iom(
    input: &Volume<f32>,
    w: &WeightsOIDHW<f32>,
    s: usize,
) -> Volume<f32> {
    assert_eq!(input.c, w.i, "channel mismatch");
    assert!(w.kd == w.kh && w.kh == w.kw, "cubic kernels only");
    let k = w.kh;
    let od = (input.d - 1) * s + k;
    let oh = (input.h - 1) * s + k;
    let ow = (input.w - 1) * s + k;
    let mut out = Volume::zeros(w.o, od, oh, ow);
    let out_data = out.data_mut();
    let (in_d, in_h, in_w) = (input.d, input.h, input.w);
    for o in 0..w.o {
        let o_base = o * od * oh * ow;
        for i in 0..input.c {
            let kern = w.kernel(o, i);
            for id in 0..in_d {
                for ih in 0..in_h {
                    for iw in 0..in_w {
                        let a = input.at(i, id, ih, iw);
                        if a == 0.0 {
                            continue;
                        }
                        for kd in 0..k {
                            let z_base = o_base + (id * s + kd) * oh * ow;
                            for kh in 0..k {
                                let krow = &kern[(kd * k + kh) * k..(kd * k + kh + 1) * k];
                                let row = z_base + (ih * s + kh) * ow + iw * s;
                                if k == 3 {
                                    // benchmark-uniform K=3: unrolled
                                    out_data[row] += a * krow[0];
                                    out_data[row + 1] += a * krow[1];
                                    out_data[row + 2] += a * krow[2];
                                } else {
                                    let dst = &mut out_data[row..row + k];
                                    for (d, &kv) in dst.iter_mut().zip(krow) {
                                        *d += a * kv;
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------
// OOM: zero-insert, pad K−1, correlate with the flipped kernel.
// ---------------------------------------------------------------------

/// 2D OOM deconvolution (conventional formulation) over the full extent.
pub fn deconv2d_oom(
    input: &FeatureMap<f32>,
    w: &WeightsOIHW<f32>,
    s: usize,
) -> FeatureMap<f32> {
    let k = w.kh;
    let ins = insert_2d(input, s);
    let padded = pad_2d(&ins, k - 1);
    corr2d(&padded, &flip_2d(w))
}

/// 3D OOM deconvolution over the full extent.
pub fn deconv3d_oom(
    input: &Volume<f32>,
    w: &WeightsOIDHW<f32>,
    s: usize,
) -> Volume<f32> {
    let k = w.kh;
    let ins = insert_3d(input, s);
    let padded = pad_3d(&ins, k - 1);
    corr3d(&padded, &flip_3d(w))
}

// ---------------------------------------------------------------------
// Cropping: remove the K−S high-side edge padding (§IV-B).
// ---------------------------------------------------------------------

/// Keep `out[:, :h, :w]`.
pub fn crop_2d(fm: &FeatureMap<f32>, h: usize, w: usize) -> FeatureMap<f32> {
    assert!(h <= fm.h && w <= fm.w);
    let mut out = FeatureMap::zeros(fm.c, h, w);
    for c in 0..fm.c {
        for y in 0..h {
            for x in 0..w {
                *out.at_mut(c, y, x) = fm.at(c, y, x);
            }
        }
    }
    out
}

/// Keep `out[:, :d, :h, :w]`.
pub fn crop_3d(vol: &Volume<f32>, d: usize, h: usize, w: usize) -> Volume<f32> {
    assert!(d <= vol.d && h <= vol.h && w <= vol.w);
    let mut out = Volume::zeros(vol.c, d, h, w);
    for c in 0..vol.c {
        for z in 0..d {
            for y in 0..h {
                for x in 0..w {
                    *out.at_mut(c, z, y, x) = vol.at(c, z, y, x);
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dcnn::{zoo, LayerData};
    use crate::util::Prng;

    #[test]
    fn iom_2d_single_pixel_is_kernel_copy() {
        // One activation of value a at (0,0): output = a * kernel.
        let input = FeatureMap::from_vec(1, 1, 1, vec![2.0]);
        let w = WeightsOIHW::from_vec(1, 1, 3, 3, (1..=9).map(|x| x as f32).collect());
        let out = deconv2d_iom(&input, &w, 2);
        assert_eq!((out.h, out.w), (3, 3));
        for idx in 0..9 {
            assert_eq!(out.data()[idx], 2.0 * (idx + 1) as f32);
        }
    }

    #[test]
    fn iom_2d_overlap_adds() {
        // Two adjacent activations with S=2, K=3 overlap in one column
        // of width K−S=1.
        let input = FeatureMap::from_vec(1, 1, 2, vec![1.0, 1.0]);
        let w = WeightsOIHW::from_vec(1, 1, 3, 3, vec![1.0; 9]);
        let out = deconv2d_iom(&input, &w, 2);
        assert_eq!((out.h, out.w), (3, 5));
        // column 2 is covered by both kernels -> value 2
        for y in 0..3 {
            assert_eq!(out.at(0, y, 2), 2.0, "overlap column");
            assert_eq!(out.at(0, y, 0), 1.0);
            assert_eq!(out.at(0, y, 4), 1.0);
        }
    }

    #[test]
    fn iom_equals_oom_2d_exact() {
        let mut rng = Prng::new(17);
        for (c_in, c_out, h, w) in [(1, 1, 2, 2), (3, 2, 4, 5), (2, 4, 3, 3)] {
            let mut input = FeatureMap::zeros(c_in, h, w);
            rng.fill_f32(input.data_mut(), -1.0, 1.0);
            let mut wt = WeightsOIHW::zeros(c_out, c_in, 3, 3);
            rng.fill_f32(wt.data_mut(), -1.0, 1.0);
            for s in [1, 2, 3] {
                let a = deconv2d_iom(&input, &wt, s);
                let b = deconv2d_oom(&input, &wt, s);
                assert_eq!((a.c, a.h, a.w), (b.c, b.h, b.w));
                for (x, y) in a.data().iter().zip(b.data()) {
                    assert!((x - y).abs() < 1e-4, "IOM {x} vs OOM {y} (s={s})");
                }
            }
        }
    }

    #[test]
    fn iom_equals_oom_3d_exact() {
        let mut rng = Prng::new(23);
        let mut input = Volume::zeros(2, 3, 3, 2);
        rng.fill_f32(input.data_mut(), -1.0, 1.0);
        let mut wt = WeightsOIDHW::zeros(2, 2, 3, 3, 3);
        rng.fill_f32(wt.data_mut(), -1.0, 1.0);
        for s in [1, 2] {
            let a = deconv3d_iom(&input, &wt, s);
            let b = deconv3d_oom(&input, &wt, s);
            assert_eq!((a.c, a.d, a.h, a.w), (b.c, b.d, b.h, b.w));
            for (x, y) in a.data().iter().zip(b.data()) {
                assert!((x - y).abs() < 1e-4, "IOM {x} vs OOM {y} (s={s})");
            }
        }
    }

    #[test]
    fn tiny_zoo_layers_agree() {
        for net in [zoo::tiny_2d()] {
            for spec in &net.layers {
                if let LayerData::D2 { input, weights } = LayerData::synth(spec, 5) {
                    let a = deconv2d_iom(&input, &weights, spec.s);
                    let b = deconv2d_oom(&input, &weights, spec.s);
                    assert!(a.into_tensor().max_abs_diff(&b.into_tensor()) < 1e-3);
                }
            }
        }
        for net in [zoo::tiny_3d()] {
            for spec in &net.layers {
                if let LayerData::D3 { input, weights } = LayerData::synth(spec, 5) {
                    let a = deconv3d_iom(&input, &weights, spec.s);
                    let b = deconv3d_oom(&input, &weights, spec.s);
                    assert!(a.into_tensor().max_abs_diff(&b.into_tensor()) < 1e-3);
                }
            }
        }
    }

    #[test]
    fn crop_matches_expected_extent() {
        let input = FeatureMap::from_vec(1, 2, 2, vec![1.0; 4]);
        let w = WeightsOIHW::from_vec(1, 1, 3, 3, vec![1.0; 9]);
        let full = deconv2d_iom(&input, &w, 2);
        assert_eq!((full.h, full.w), (5, 5)); // (2-1)*2+3
        let cropped = crop_2d(&full, 4, 4); // I*S = 4
        assert_eq!((cropped.h, cropped.w), (4, 4));
        assert_eq!(cropped.at(0, 0, 0), full.at(0, 0, 0));
    }

    #[test]
    fn output_extents_match_eq1() {
        let input = Volume::from_vec(1, 2, 3, 4, vec![1.0; 24]);
        let w = WeightsOIDHW::from_vec(1, 1, 3, 3, 3, vec![1.0; 27]);
        let out = deconv3d_iom(&input, &w, 2);
        assert_eq!((out.d, out.h, out.w), (5, 7, 9));
    }
}
