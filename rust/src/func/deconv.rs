//! Typed 2D/3D deconvolution entry points.
//!
//! Since the dimension-uniform refactor the loop nests live **once**
//! in [`super::uniform`]: a 2D call is the depth-1 fold (`d = 1`,
//! `kd = 1`) of the same kernel that runs 3D (§IV-C), so *2D ==
//! depth-1 3D* holds bit-exactly by construction. These wrappers are
//! kept only because a body of tests and benches pins the original
//! typed signatures; new code should call [`super::uniform`] directly
//! (the threaded variants live only there).

use crate::tensor::{FeatureMap, Volume, WeightsOIDHW, WeightsOIHW};

use super::uniform;

// ---------------------------------------------------------------------
// IOM: scatter-accumulate. out[o][ih·S+kh][iw·S+kw] += in[i][ih][iw]·w
// ---------------------------------------------------------------------

/// 2D IOM deconvolution over the full Eq. (1) extent — the depth-1
/// fold of [`uniform::deconv_iom`].
pub fn deconv2d_iom(input: &FeatureMap<f32>, w: &WeightsOIHW<f32>, s: usize) -> FeatureMap<f32> {
    uniform::deconv_iom(&input.to_volume(), &w.to_oidhw(), s).into_feature_map()
}

/// 3D IOM deconvolution over the full Eq. (1) extent (Fig. 5) —
/// [`uniform::deconv_iom`] under its original name.
pub fn deconv3d_iom(input: &Volume<f32>, w: &WeightsOIDHW<f32>, s: usize) -> Volume<f32> {
    uniform::deconv_iom(input, w, s)
}

// ---------------------------------------------------------------------
// OOM: zero-insert, pad K−1, correlate with the flipped kernel.
// ---------------------------------------------------------------------

/// 2D OOM deconvolution (conventional formulation) over the full
/// extent — the depth-1 fold of [`uniform::deconv_oom`].
pub fn deconv2d_oom(input: &FeatureMap<f32>, w: &WeightsOIHW<f32>, s: usize) -> FeatureMap<f32> {
    uniform::deconv_oom(&input.to_volume(), &w.to_oidhw(), s).into_feature_map()
}

/// 3D OOM deconvolution over the full extent.
pub fn deconv3d_oom(input: &Volume<f32>, w: &WeightsOIDHW<f32>, s: usize) -> Volume<f32> {
    uniform::deconv_oom(input, w, s)
}

// ---------------------------------------------------------------------
// Cropping: remove the K−S high-side edge padding (§IV-B).
// ---------------------------------------------------------------------

/// Keep `out[:, :h, :w]`.
pub fn crop_2d(fm: &FeatureMap<f32>, h: usize, w: usize) -> FeatureMap<f32> {
    uniform::crop(&fm.to_volume(), 1, h, w).into_feature_map()
}

/// Keep `out[:, :d, :h, :w]`.
pub fn crop_3d(vol: &Volume<f32>, d: usize, h: usize, w: usize) -> Volume<f32> {
    uniform::crop(vol, d, h, w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dcnn::{zoo, LayerData};
    use crate::util::Prng;

    #[test]
    fn iom_2d_single_pixel_is_kernel_copy() {
        // One activation of value a at (0,0): output = a * kernel.
        let input = FeatureMap::from_vec(1, 1, 1, vec![2.0]);
        let w = WeightsOIHW::from_vec(1, 1, 3, 3, (1..=9).map(|x| x as f32).collect());
        let out = deconv2d_iom(&input, &w, 2);
        assert_eq!((out.h, out.w), (3, 3));
        for idx in 0..9 {
            assert_eq!(out.data()[idx], 2.0 * (idx + 1) as f32);
        }
    }

    #[test]
    fn iom_2d_overlap_adds() {
        // Two adjacent activations with S=2, K=3 overlap in one column
        // of width K−S=1.
        let input = FeatureMap::from_vec(1, 1, 2, vec![1.0, 1.0]);
        let w = WeightsOIHW::from_vec(1, 1, 3, 3, vec![1.0; 9]);
        let out = deconv2d_iom(&input, &w, 2);
        assert_eq!((out.h, out.w), (3, 5));
        // column 2 is covered by both kernels -> value 2
        for y in 0..3 {
            assert_eq!(out.at(0, y, 2), 2.0, "overlap column");
            assert_eq!(out.at(0, y, 0), 1.0);
            assert_eq!(out.at(0, y, 4), 1.0);
        }
    }

    #[test]
    fn iom_equals_oom_2d_exact() {
        let mut rng = Prng::new(17);
        for (c_in, c_out, h, w) in [(1, 1, 2, 2), (3, 2, 4, 5), (2, 4, 3, 3)] {
            let mut input = FeatureMap::zeros(c_in, h, w);
            rng.fill_f32(input.data_mut(), -1.0, 1.0);
            let mut wt = WeightsOIHW::zeros(c_out, c_in, 3, 3);
            rng.fill_f32(wt.data_mut(), -1.0, 1.0);
            for s in [1, 2, 3] {
                let a = deconv2d_iom(&input, &wt, s);
                let b = deconv2d_oom(&input, &wt, s);
                assert_eq!((a.c, a.h, a.w), (b.c, b.h, b.w));
                for (x, y) in a.data().iter().zip(b.data()) {
                    assert!((x - y).abs() < 1e-4, "IOM {x} vs OOM {y} (s={s})");
                }
            }
        }
    }

    #[test]
    fn iom_equals_oom_3d_exact() {
        let mut rng = Prng::new(23);
        let mut input = Volume::zeros(2, 3, 3, 2);
        rng.fill_f32(input.data_mut(), -1.0, 1.0);
        let mut wt = WeightsOIDHW::zeros(2, 2, 3, 3, 3);
        rng.fill_f32(wt.data_mut(), -1.0, 1.0);
        for s in [1, 2] {
            let a = deconv3d_iom(&input, &wt, s);
            let b = deconv3d_oom(&input, &wt, s);
            assert_eq!((a.c, a.d, a.h, a.w), (b.c, b.d, b.h, b.w));
            for (x, y) in a.data().iter().zip(b.data()) {
                assert!((x - y).abs() < 1e-4, "IOM {x} vs OOM {y} (s={s})");
            }
        }
    }

    #[test]
    fn tiny_zoo_layers_agree() {
        for net in [zoo::tiny_2d()] {
            for spec in &net.layers {
                if let LayerData::D2 { input, weights } = LayerData::synth(spec, 5) {
                    let a = deconv2d_iom(&input, &weights, spec.s);
                    let b = deconv2d_oom(&input, &weights, spec.s);
                    assert!(a.into_tensor().max_abs_diff(&b.into_tensor()) < 1e-3);
                }
            }
        }
        for net in [zoo::tiny_3d()] {
            for spec in &net.layers {
                if let LayerData::D3 { input, weights } = LayerData::synth(spec, 5) {
                    let a = deconv3d_iom(&input, &weights, spec.s);
                    let b = deconv3d_oom(&input, &weights, spec.s);
                    assert!(a.into_tensor().max_abs_diff(&b.into_tensor()) < 1e-3);
                }
            }
        }
    }

    #[test]
    fn crop_matches_expected_extent() {
        let input = FeatureMap::from_vec(1, 2, 2, vec![1.0; 4]);
        let w = WeightsOIHW::from_vec(1, 1, 3, 3, vec![1.0; 9]);
        let full = deconv2d_iom(&input, &w, 2);
        assert_eq!((full.h, full.w), (5, 5)); // (2-1)*2+3
        let cropped = crop_2d(&full, 4, 4); // I*S = 4
        assert_eq!((cropped.h, cropped.w), (4, 4));
        assert_eq!(cropped.at(0, 0, 0), full.at(0, 0, 0));
    }

    #[test]
    fn output_extents_match_eq1() {
        let input = Volume::from_vec(1, 2, 3, 4, vec![1.0; 24]);
        let w = WeightsOIDHW::from_vec(1, 1, 3, 3, 3, vec![1.0; 27]);
        let out = deconv3d_iom(&input, &w, 2);
        assert_eq!((out.d, out.h, out.w), (5, 7, 9));
    }
}
