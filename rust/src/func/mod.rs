//! Functional golden models of 2D/3D deconvolution.
//!
//! Two mathematically equal formulations (§III, Fig. 3):
//!
//! * **OOM** (output-oriented, the conventional baseline): insert
//!   `S − 1` zeros between activations, pad the border by `K − 1`, and
//!   run a dense convolution. Scans every inserted zero — the
//!   inefficiency the paper attacks.
//! * **IOM** (input-oriented, the paper's mapping): for every *input*
//!   activation, scatter `activation × kernel` into the output at
//!   offset `(i·S + k)` and accumulate the overlaps. Touches only
//!   useful products — exactly what each PE of the accelerator
//!   computes (Fig. 5).
//!
//! The IOM sum can also be evaluated **output-stationary** — the
//! zero-skip *gather* family in [`uniform`] (`deconv_gather*`), which
//! reads each output element's contributor window directly (the TDC
//! formulation of arXiv:1705.02583), writes every output exactly
//! once, and is bit-exact against the scatter kernels by a documented
//! accumulation-order contract. The compiler picks scatter vs gather
//! per layer (see `accel::kernel`).
//!
//! `iom == oom` on every shape is the correctness spine of the repo:
//! it is asserted here in unit tests, by the property suite, by the
//! Python kernel tests (Pallas IOM kernel vs `ref.py` OOM oracle), and
//! by the simulator's functional tier (bit-exact in Q8.8).
//!
//! Since the dimension-uniform refactor every loop nest lives exactly
//! once, in [`uniform`], over `(c, d, h, w)` activations with `d = 1`
//! for 2D — the software mirror of the paper's one-datapath claim
//! (§IV-C). The `*2d_*` / `*3d_*` functions in [`conv`], [`deconv`],
//! [`deconv_q`] and [`zero_insert`] are thin folds kept for the
//! signatures that tests and benches pin; `tests/prop_uniform.rs`
//! proves the folds are bit-exact.
//!
//! The host hot path under those loop nests lives in two support
//! modules: [`simd`] (portable explicit-width lanes + the per-layer
//! cache-blocking tile; scalar fallback forced via
//! `UDCNN_FORCE_SCALAR=1`) and [`workspace`] (thread-local scratch
//! pools that make steady-state serving allocation-free). Both keep
//! the bit-exactness contract: SIMD == scalar == threaded, pinned by
//! `tests/prop_uniform.rs`.
//!
//! Output conventions: `*_full` returns the Eq. (1) extent
//! `(I − 1)·S + K`; [`crop_2d`]/[`crop_3d`] remove the `K − S` edge
//! padding from the high side of each axis (matching
//! `jax.lax.conv_transpose(..., 'VALID')[..., :I·S, :I·S]` — see
//! `python/compile/kernels/ref.py`).

pub mod conv;
pub mod deconv;
pub mod deconv_q;
pub mod simd;
pub mod uniform;
pub mod workspace;
pub mod zero_insert;

pub use deconv::{
    crop_2d, crop_3d, deconv2d_iom, deconv2d_oom, deconv3d_iom, deconv3d_oom,
};
pub use deconv_q::{deconv2d_iom_q, deconv3d_iom_q};
pub use uniform::{
    deconv_gather, deconv_gather_q, deconv_gather_q_threaded, deconv_gather_threaded,
    deconv_gather_window, deconv_gather_window_q, deconv_gather_window_q_threaded,
    deconv_gather_window_threaded, deconv_iom, deconv_iom_q, deconv_iom_q_threaded,
    deconv_iom_threaded, deconv_oom, deconv_oom_threaded,
};
