//! The dimension-uniform kernel core (§IV-C in software).
//!
//! The paper's central claim is that one datapath serves both 2D and
//! 3D DCNNs: a 2D deconvolution is simply the depth-1 fold of the 3D
//! loop nest, exactly as [`crate::accel::mapping`] folds the `T_z`
//! depth arrays into channel parallelism when a 2D net runs on the 3D
//! operating point. This module is the software reflection of that
//! claim: ONE implementation of every compute kernel over the uniform
//! activation layout `C × D × H × W` (`d = 1` for 2D) and weight
//! layout `O × I × Kd × Kh × Kw` (`kd = 1` for 2D).
//!
//! The typed 2D/3D entry points ([`super::deconv2d_iom`],
//! [`super::conv::corr2d`], the `baseline` threaded kernels, ...) are
//! thin folds onto these kernels, so *2D == depth-1 3D* holds
//! **bit-exactly** by construction — asserted across the f32, Q8.8,
//! OOM and threaded paths by `tests/prop_uniform.rs`.
//!
//! Performance notes (§Perf):
//!
//! * the IOM scatter works on contiguous output rows; the `K`-wide
//!   inner scatter is monomorphized for the common kernel widths
//!   (replacing the hand-copied `K = 3` special cases the old 2D and
//!   3D kernels each carried) and falls back to a slice loop for any
//!   other width;
//! * [`deconv_iom_threaded`] / [`deconv_iom_q_threaded`] shard output
//!   channels across scoped `std::thread` workers. Each output channel
//!   is written by exactly one thread in the same order as the
//!   single-threaded kernel, so threaded results are deterministic and
//!   bit-identical to the single-threaded ones;
//! * the **gather** family ([`deconv_gather_window`] and friends)
//!   computes each output element directly from its contributor window
//!   `[⌈(z−K+1)/S⌉, ⌊z/S⌋]` per axis — never materializing the
//!   zero-inserted map *or* the full Eq.-(1) extent. It writes each
//!   output element exactly once, crops for free (it simply never
//!   computes the discarded border), and its threaded variants shard
//!   *output rows* instead of output channels, so layers with few
//!   output channels (the last layer of every GAN generator) still
//!   parallelize. Bit-exact against the scatter path by the
//!   accumulation-order contract documented at the gather section;
//! * the OOM path materializes the zero-inserted, padded map **once**
//!   and threads the dense correlation over output channels (the old
//!   per-dimensionality baselines re-inserted zeros in every thread);
//! * under the default SIMD mode ([`super::simd`]), the deconvolution
//!   entry points route both kernel families through one **blocked
//!   row core** (`gather_rows_blocked`): output rows are tiled into an
//!   L1-resident scratch strip, input channels stream in L2-sized
//!   groups, and the inner loop is a contiguous lane-wide
//!   multiply-accumulate across *output elements* (one element per
//!   lane, no reassociation — see the residue-class layout at the
//!   core). `UDCNN_FORCE_SCALAR=1` (or
//!   [`super::simd::set_force_scalar`]) selects the scalar reference
//!   nests instead; the `*_scalar` twins expose them directly for the
//!   bit-exactness properties in `tests/prop_uniform.rs`;
//! * per-call outputs and scratch come from the thread-local pools in
//!   [`super::workspace`], so steady-state serving and streaming
//!   allocate nothing on this path (`tests/obs_trace.rs` counts).

use super::{simd, workspace};
use crate::fixed::{Acc48, Q88};
use crate::tensor::{Volume, WeightsOIDHW};

/// Eq. (1) accumulation extents `(I − 1)·S + K` per axis.
#[inline]
fn full_extents<T: Copy + Default>(
    input: &Volume<T>,
    kd: usize,
    kh: usize,
    kw: usize,
    s: usize,
) -> (usize, usize, usize) {
    (
        (input.d - 1) * s + kd,
        (input.h - 1) * s + kh,
        (input.w - 1) * s + kw,
    )
}

/// Clamp a requested worker count to `[1, out_channels]`.
#[inline]
fn clamp_threads(threads: usize, out_channels: usize) -> usize {
    threads.clamp(1, out_channels.max(1))
}

// ---------------------------------------------------------------------
// The K-wide row scatter: out_row[iw·S + j] += a · k[j].
//
// One implementation, monomorphized per kernel width — the
// generalization of the old per-kernel K=3 unrolled branches.
// ---------------------------------------------------------------------

#[inline(always)]
fn scatter_row_k<const K: usize>(out_row: &mut [f32], in_row: &[f32], krow: &[f32], s: usize) {
    let kern: &[f32; K] = krow.try_into().expect("kernel row width");
    for (iw, &a) in in_row.iter().enumerate() {
        if a == 0.0 {
            continue; // IOM never multiplies a zero
        }
        let dst: &mut [f32; K] = (&mut out_row[iw * s..iw * s + K])
            .try_into()
            .expect("output row width");
        for j in 0..K {
            dst[j] += a * kern[j];
        }
    }
}

#[inline]
fn scatter_row(out_row: &mut [f32], in_row: &[f32], krow: &[f32], s: usize) {
    match krow.len() {
        1 => scatter_row_k::<1>(out_row, in_row, krow, s),
        2 => scatter_row_k::<2>(out_row, in_row, krow, s),
        3 => scatter_row_k::<3>(out_row, in_row, krow, s),
        4 => scatter_row_k::<4>(out_row, in_row, krow, s),
        5 => scatter_row_k::<5>(out_row, in_row, krow, s),
        k => {
            for (iw, &a) in in_row.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let dst = &mut out_row[iw * s..iw * s + k];
                for (d, &kv) in dst.iter_mut().zip(krow) {
                    *d += a * kv;
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// IOM: scatter-accumulate (f32).
// out[o][id·S+kd][ih·S+kh][iw·S+kw] += in[i][id][ih][iw] · w[o][i][kd][kh][kw]
// ---------------------------------------------------------------------

/// Compute output channels `[o_lo, o_hi)` of the IOM deconvolution
/// into `out`, a **zero-filled** buffer holding exactly those
/// channels. Dispatches to the blocked SIMD row core (which computes
/// the identical sum output-stationary, by the accumulation-order
/// contract at the gather section) or the scalar scatter nest.
fn deconv_iom_into(
    input: &Volume<f32>,
    w: &WeightsOIDHW<f32>,
    s: usize,
    o_lo: usize,
    o_hi: usize,
    out: &mut [f32],
) {
    if simd::simd_enabled() {
        assert_eq!(input.c, w.i, "channel mismatch");
        let (fd, fh, fw) = full_extents(input, w.kd, w.kh, w.kw, s);
        gather_rows_blocked(input, w, s, 0, fd, fh, fw, o_lo * fd * fh, o_hi * fd * fh, out);
    } else {
        deconv_iom_into_scalar(input, w, s, o_lo, o_hi, out);
    }
}

fn deconv_iom_into_scalar(
    input: &Volume<f32>,
    w: &WeightsOIDHW<f32>,
    s: usize,
    o_lo: usize,
    o_hi: usize,
    out: &mut [f32],
) {
    assert_eq!(input.c, w.i, "channel mismatch");
    let (od, oh, ow) = full_extents(input, w.kd, w.kh, w.kw, s);
    debug_assert_eq!(out.len(), (o_hi - o_lo) * od * oh * ow);
    for o in o_lo..o_hi {
        let o_base = (o - o_lo) * od * oh * ow;
        for i in 0..input.c {
            let kern = w.kernel(o, i);
            for id in 0..input.d {
                for ih in 0..input.h {
                    let in_row = input.row(i, id, ih);
                    for dz in 0..w.kd {
                        let z_base = o_base + (id * s + dz) * oh * ow;
                        for dy in 0..w.kh {
                            let kbase = (dz * w.kh + dy) * w.kw;
                            let krow = &kern[kbase..kbase + w.kw];
                            let row = z_base + (ih * s + dy) * ow;
                            scatter_row(&mut out[row..row + ow], in_row, krow, s);
                        }
                    }
                }
            }
        }
    }
}

/// Dimension-uniform IOM deconvolution over the full Eq. (1) extent
/// (Fig. 5). A depth-1 input with a depth-1 kernel *is* the 2D case.
/// The output volume is drawn from the [`workspace`] pool — return it
/// with [`workspace::give_volume_f32`] when done to keep the serving
/// path allocation-free.
pub fn deconv_iom(input: &Volume<f32>, w: &WeightsOIDHW<f32>, s: usize) -> Volume<f32> {
    let (od, oh, ow) = full_extents(input, w.kd, w.kh, w.kw, s);
    let mut out = workspace::take_volume_f32(w.o, od, oh, ow);
    deconv_iom_into(input, w, s, 0, w.o, out.data_mut());
    out
}

/// [`deconv_iom`] pinned to the scalar reference nest regardless of
/// the SIMD mode — the oracle side of the SIMD bit-exactness
/// properties (`tests/prop_uniform.rs`).
pub fn deconv_iom_scalar(input: &Volume<f32>, w: &WeightsOIDHW<f32>, s: usize) -> Volume<f32> {
    let (od, oh, ow) = full_extents(input, w.kd, w.kh, w.kw, s);
    let mut out = Volume::zeros(w.o, od, oh, ow);
    deconv_iom_into_scalar(input, w, s, 0, w.o, out.data_mut());
    out
}

/// [`deconv_iom`] with output channels sharded across `threads` scoped
/// `std::thread` workers. Bit-identical to the single-threaded kernel
/// (each output channel is written by exactly one thread, in the same
/// accumulation order).
pub fn deconv_iom_threaded(
    input: &Volume<f32>,
    w: &WeightsOIDHW<f32>,
    s: usize,
    threads: usize,
) -> Volume<f32> {
    let t = clamp_threads(threads, w.o);
    if t <= 1 {
        return deconv_iom(input, w, s);
    }
    let (od, oh, ow) = full_extents(input, w.kd, w.kh, w.kw, s);
    let per_o = od * oh * ow;
    let chunk_os = w.o.div_ceil(t);
    let mut out = workspace::take_volume_f32(w.o, od, oh, ow);
    std::thread::scope(|scope| {
        for (ti, buf) in out.data_mut().chunks_mut(chunk_os * per_o).enumerate() {
            let o_lo = ti * chunk_os;
            let o_hi = (o_lo + chunk_os).min(w.o);
            scope.spawn(move || deconv_iom_into(input, w, s, o_lo, o_hi, buf));
        }
    });
    out
}

// ---------------------------------------------------------------------
// IOM in Q8.8: the bit-exact model of the accelerator datapath.
// ---------------------------------------------------------------------

/// Accumulate output channels `[o_lo, o_hi)` of the Q8.8 IOM
/// deconvolution into `acc` (one [`Acc48`] per output element of those
/// channels) — the DSP48-style wide accumulation before the single
/// write-back rounding.
fn deconv_iom_q_into(
    input: &Volume<Q88>,
    w: &WeightsOIDHW<Q88>,
    s: usize,
    o_lo: usize,
    o_hi: usize,
    acc: &mut [Acc48],
) {
    assert_eq!(input.c, w.i, "channel mismatch");
    let (od, oh, ow) = full_extents(input, w.kd, w.kh, w.kw, s);
    debug_assert_eq!(acc.len(), (o_hi - o_lo) * od * oh * ow);
    for o in o_lo..o_hi {
        let o_base = (o - o_lo) * od * oh * ow;
        for i in 0..input.c {
            let kern = w.kernel(o, i);
            for id in 0..input.d {
                for ih in 0..input.h {
                    let in_row = input.row(i, id, ih);
                    for dz in 0..w.kd {
                        let z_base = o_base + (id * s + dz) * oh * ow;
                        for dy in 0..w.kh {
                            let kbase = (dz * w.kh + dy) * w.kw;
                            let krow = &kern[kbase..kbase + w.kw];
                            let row = z_base + (ih * s + dy) * ow;
                            for (iw, &a) in in_row.iter().enumerate() {
                                if a.is_zero() {
                                    continue;
                                }
                                let dst = &mut acc[row + iw * s..row + iw * s + w.kw];
                                for (d, &kv) in dst.iter_mut().zip(krow) {
                                    d.mac(a, kv);
                                }
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Dimension-uniform Q8.8 IOM deconvolution over the full Eq. (1)
/// extent. Accumulation happens in the 48-bit accumulator across *all*
/// input channels before a single rounding at write-back (the adder
/// tree + output buffer behaviour), so results are bit-exact against
/// the functional mesh tier. Under SIMD the blocked row core
/// accumulates the identical 48-bit sums in a pooled `i64` strip and
/// rounds straight into the output — the whole-extent `Acc48` buffer
/// of the scalar path is never allocated.
pub fn deconv_iom_q(input: &Volume<Q88>, w: &WeightsOIDHW<Q88>, s: usize) -> Volume<Q88> {
    if !simd::simd_enabled() {
        return deconv_iom_q_scalar(input, w, s);
    }
    assert_eq!(input.c, w.i, "channel mismatch");
    let (od, oh, ow) = full_extents(input, w.kd, w.kh, w.kw, s);
    let mut out = Volume::zeros(w.o, od, oh, ow);
    gather_rows_blocked_q(input, w, s, 0, od, oh, ow, 0, w.o * od * oh, out.data_mut());
    out
}

/// [`deconv_iom_q`] pinned to the scalar reference nest regardless of
/// the SIMD mode — the Q8.8 oracle of `tests/prop_uniform.rs`.
pub fn deconv_iom_q_scalar(input: &Volume<Q88>, w: &WeightsOIDHW<Q88>, s: usize) -> Volume<Q88> {
    let (od, oh, ow) = full_extents(input, w.kd, w.kh, w.kw, s);
    let mut acc = vec![Acc48::ZERO; w.o * od * oh * ow];
    deconv_iom_q_into(input, w, s, 0, w.o, &mut acc);
    Volume::from_vec(
        w.o,
        od,
        oh,
        ow,
        acc.into_iter().map(|a| a.to_q88()).collect(),
    )
}

/// [`deconv_iom_q`] with output channels sharded across `threads`
/// scoped workers; bit-identical to the single-threaded kernel
/// (integer accumulation is exact, one thread per output channel).
pub fn deconv_iom_q_threaded(
    input: &Volume<Q88>,
    w: &WeightsOIDHW<Q88>,
    s: usize,
    threads: usize,
) -> Volume<Q88> {
    let t = clamp_threads(threads, w.o);
    if t <= 1 {
        return deconv_iom_q(input, w, s);
    }
    let (od, oh, ow) = full_extents(input, w.kd, w.kh, w.kw, s);
    let per_o = od * oh * ow;
    let chunk_os = w.o.div_ceil(t);
    let mut out = Volume::zeros(w.o, od, oh, ow);
    std::thread::scope(|scope| {
        for (ti, buf) in out.data_mut().chunks_mut(chunk_os * per_o).enumerate() {
            let o_lo = ti * chunk_os;
            let o_hi = (o_lo + chunk_os).min(w.o);
            scope.spawn(move || {
                if simd::simd_enabled() {
                    gather_rows_blocked_q(
                        input,
                        w,
                        s,
                        0,
                        od,
                        oh,
                        ow,
                        o_lo * od * oh,
                        o_hi * od * oh,
                        buf,
                    );
                } else {
                    let mut acc = vec![Acc48::ZERO; buf.len()];
                    deconv_iom_q_into(input, w, s, o_lo, o_hi, &mut acc);
                    for (dst, a) in buf.iter_mut().zip(acc) {
                        *dst = a.to_q88();
                    }
                }
            });
        }
    });
    out
}

// ---------------------------------------------------------------------
// Gather (zero-skip, output-stationary) deconvolution.
//
// out[o][z][y][x] = Σ_i Σ_{id∈W(z)} Σ_{ih∈W(y)} Σ_{iw∈W(x)}
//                     in[i][id][ih][iw] · w[o][i][z−id·S][y−ih·S][x−iw·S]
//
// with the per-axis contributor window over the full Eq.-(1)
// coordinates
//
//     W(z) = [⌈(z − K + 1)/S⌉, ⌊z/S⌋] ∩ [0, I)
//
// (empty for coordinates no input reaches, e.g. inter-stride gaps
// when K < S). Neither the zero-inserted map nor the full Eq.-(1)
// extent is ever built: the kernel computes exactly the requested
// output window, so cropping is free and each output element is
// written exactly once.
//
// Accumulation-order contract (the bit-exactness argument
// `tests/diff_kernels.rs` pins): for every output element the terms
// are added ONE AT A TIME into a 0.0-initialized accumulator, in
// exactly the order the scatter kernel above visits them — input
// channel `i` ascending, then `id`, `ih`, `iw` ascending — with the
// identical `a == 0.0` zero-skip. No local partial sums are formed
// (f32 addition is non-associative; reassociating would drift), so
// gather bits equal `crop_window(deconv_iom(..), ..)` bits, f32 and
// Q8.8, threaded and single.
// ---------------------------------------------------------------------

/// Contributor window `[lo, hi)` of output coordinate `z` along one
/// axis: the input indices `i` with `0 ≤ z − i·S ≤ K − 1`, clamped to
/// `[0, in_extent)`. Empty (`lo ≥ hi`) when nothing reaches `z`.
#[inline(always)]
fn contrib_window(z: usize, k: usize, s: usize, in_extent: usize) -> (usize, usize) {
    let lo = (z + 1).saturating_sub(k).div_ceil(s);
    let hi = (z / s + 1).min(in_extent);
    (lo, hi)
}

// The K-wide row gather: out_row[x] += Σ_{iw∈W(x)} in_row[iw]·k[x−iw·S],
// terms added in iw-ascending order. The window bounds advance
// monotonically with x, so they are maintained incrementally instead
// of re-derived by division per element.

#[inline(always)]
fn gather_row_k<const K: usize>(out_row: &mut [f32], in_row: &[f32], krow: &[f32], s: usize) {
    let kern: &[f32; K] = krow.try_into().expect("kernel row width");
    let (mut lo, mut hi) = (0usize, 0usize);
    for (x, dst) in out_row.iter_mut().enumerate() {
        while lo * s + K <= x {
            lo += 1; // iw left the window: iw·S + K − 1 < x
        }
        while hi < in_row.len() && hi * s <= x {
            hi += 1; // iw entered the window: iw·S ≤ x
        }
        for (j, &a) in in_row[lo..hi].iter().enumerate() {
            if a == 0.0 {
                continue; // the scatter path's zero-skip, mirrored
            }
            *dst += a * kern[x - (lo + j) * s];
        }
    }
}

#[inline]
fn gather_row(out_row: &mut [f32], in_row: &[f32], krow: &[f32], s: usize) {
    match krow.len() {
        1 => gather_row_k::<1>(out_row, in_row, krow, s),
        2 => gather_row_k::<2>(out_row, in_row, krow, s),
        3 => gather_row_k::<3>(out_row, in_row, krow, s),
        4 => gather_row_k::<4>(out_row, in_row, krow, s),
        5 => gather_row_k::<5>(out_row, in_row, krow, s),
        k => {
            let (mut lo, mut hi) = (0usize, 0usize);
            for (x, dst) in out_row.iter_mut().enumerate() {
                while lo * s + k <= x {
                    lo += 1;
                }
                while hi < in_row.len() && hi * s <= x {
                    hi += 1;
                }
                for (j, &a) in in_row[lo..hi].iter().enumerate() {
                    if a == 0.0 {
                        continue;
                    }
                    *dst += a * krow[x - (lo + j) * s];
                }
            }
        }
    }
}

/// Compute flattened output rows `[r_lo, r_hi)` of the gather window
/// into `out`, a **zero-filled** buffer holding exactly those rows. A
/// row index `r` decodes as
/// `(o, z_w, y) = (r / (od·oh), r % (od·oh) / oh, r % oh)`
/// with `z = d_lo + z_w` on the full Eq.-(1) depth axis. Dispatches to
/// the blocked SIMD row core or the scalar reference nest.
#[allow(clippy::too_many_arguments)]
fn deconv_gather_rows(
    input: &Volume<f32>,
    w: &WeightsOIDHW<f32>,
    s: usize,
    d_lo: usize,
    od: usize,
    oh: usize,
    ow: usize,
    r_lo: usize,
    r_hi: usize,
    out: &mut [f32],
) {
    if simd::simd_enabled() {
        gather_rows_blocked(input, w, s, d_lo, od, oh, ow, r_lo, r_hi, out);
    } else {
        deconv_gather_rows_scalar(input, w, s, d_lo, od, oh, ow, r_lo, r_hi, out);
    }
}

#[allow(clippy::too_many_arguments)]
fn deconv_gather_rows_scalar(
    input: &Volume<f32>,
    w: &WeightsOIDHW<f32>,
    s: usize,
    d_lo: usize,
    od: usize,
    oh: usize,
    ow: usize,
    r_lo: usize,
    r_hi: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), (r_hi - r_lo) * ow);
    for r in r_lo..r_hi {
        let o = r / (od * oh);
        let z = d_lo + r / oh % od;
        let y = r % oh;
        let (id_lo, id_hi) = contrib_window(z, w.kd, s, input.d);
        let (ih_lo, ih_hi) = contrib_window(y, w.kh, s, input.h);
        let base = (r - r_lo) * ow;
        let out_row = &mut out[base..base + ow];
        for i in 0..input.c {
            let kern = w.kernel(o, i);
            for id in id_lo..id_hi {
                let dz = z - id * s;
                for ih in ih_lo..ih_hi {
                    let dy = y - ih * s;
                    let kbase = (dz * w.kh + dy) * w.kw;
                    let krow = &kern[kbase..kbase + w.kw];
                    gather_row(out_row, input.row(i, id, ih), krow, s);
                }
            }
        }
    }
}

/// Zero-skip gather deconvolution of an output *window*: depth frames
/// `[d_lo, d_lo + od)` of the full Eq.-(1) extent, heights `[0, oh)`
/// and widths `[0, ow)` (crops are low-anchored, §IV-B). Bit-exact
/// against `crop_window(&deconv_iom(input, w, s), d_lo, od, oh, ow)`
/// by the accumulation-order contract above — without ever building
/// the full extent. The output volume is drawn from the [`workspace`]
/// pool — return it with [`workspace::give_volume_f32`] when done.
pub fn deconv_gather_window(
    input: &Volume<f32>,
    w: &WeightsOIDHW<f32>,
    s: usize,
    d_lo: usize,
    od: usize,
    oh: usize,
    ow: usize,
) -> Volume<f32> {
    assert_eq!(input.c, w.i, "channel mismatch");
    let (fd, fh, fw) = full_extents(input, w.kd, w.kh, w.kw, s);
    assert!(d_lo + od <= fd && oh <= fh && ow <= fw, "window exceeds Eq.-(1) extent");
    let mut out = workspace::take_volume_f32(w.o, od, oh, ow);
    deconv_gather_rows(input, w, s, d_lo, od, oh, ow, 0, w.o * od * oh, out.data_mut());
    out
}

/// [`deconv_gather_window`] pinned to the scalar reference nest
/// regardless of the SIMD mode — the gather-side oracle of
/// `tests/prop_uniform.rs`.
pub fn deconv_gather_window_scalar(
    input: &Volume<f32>,
    w: &WeightsOIDHW<f32>,
    s: usize,
    d_lo: usize,
    od: usize,
    oh: usize,
    ow: usize,
) -> Volume<f32> {
    assert_eq!(input.c, w.i, "channel mismatch");
    let (fd, fh, fw) = full_extents(input, w.kd, w.kh, w.kw, s);
    assert!(d_lo + od <= fd && oh <= fh && ow <= fw, "window exceeds Eq.-(1) extent");
    let mut out = Volume::zeros(w.o, od, oh, ow);
    deconv_gather_rows_scalar(input, w, s, d_lo, od, oh, ow, 0, w.o * od * oh, out.data_mut());
    out
}

/// Gather deconvolution over the full Eq. (1) extent — the drop-in
/// equal of [`deconv_iom`], computed output-stationary.
pub fn deconv_gather(input: &Volume<f32>, w: &WeightsOIDHW<f32>, s: usize) -> Volume<f32> {
    let (fd, fh, fw) = full_extents(input, w.kd, w.kh, w.kw, s);
    deconv_gather_window(input, w, s, 0, fd, fh, fw)
}

/// [`deconv_gather`] pinned to the scalar reference nest.
pub fn deconv_gather_scalar(input: &Volume<f32>, w: &WeightsOIDHW<f32>, s: usize) -> Volume<f32> {
    let (fd, fh, fw) = full_extents(input, w.kd, w.kh, w.kw, s);
    deconv_gather_window_scalar(input, w, s, 0, fd, fh, fw)
}

/// [`deconv_gather_window`] with *output rows* `(o, z, y)` sharded
/// across `threads` scoped workers. Rows shard far finer than the
/// scatter kernels' output channels (a 3-channel or 1-channel GAN
/// head still fills every core), and each row is produced by exactly
/// one thread in the single-threaded accumulation order, so results
/// stay bit-identical.
#[allow(clippy::too_many_arguments)]
pub fn deconv_gather_window_threaded(
    input: &Volume<f32>,
    w: &WeightsOIDHW<f32>,
    s: usize,
    d_lo: usize,
    od: usize,
    oh: usize,
    ow: usize,
    threads: usize,
) -> Volume<f32> {
    assert_eq!(input.c, w.i, "channel mismatch");
    let (fd, fh, fw) = full_extents(input, w.kd, w.kh, w.kw, s);
    assert!(d_lo + od <= fd && oh <= fh && ow <= fw, "window exceeds Eq.-(1) extent");
    let rows = w.o * od * oh;
    let t = threads.clamp(1, rows.max(1));
    if t <= 1 {
        return deconv_gather_window(input, w, s, d_lo, od, oh, ow);
    }
    let chunk_rows = rows.div_ceil(t);
    let mut out = workspace::take_volume_f32(w.o, od, oh, ow);
    std::thread::scope(|scope| {
        for (ti, buf) in out.data_mut().chunks_mut(chunk_rows * ow).enumerate() {
            let r_lo = ti * chunk_rows;
            let r_hi = (r_lo + chunk_rows).min(rows);
            scope.spawn(move || {
                deconv_gather_rows(input, w, s, d_lo, od, oh, ow, r_lo, r_hi, buf)
            });
        }
    });
    out
}

/// [`deconv_gather`] threaded over output rows (bit-identical to the
/// single-threaded kernel).
pub fn deconv_gather_threaded(
    input: &Volume<f32>,
    w: &WeightsOIDHW<f32>,
    s: usize,
    threads: usize,
) -> Volume<f32> {
    let (fd, fh, fw) = full_extents(input, w.kd, w.kh, w.kw, s);
    deconv_gather_window_threaded(input, w, s, 0, fd, fh, fw, threads)
}

// Q8.8 gather: one Acc48 per output element, every contribution
// accumulated wide, a single rounding at write-back — identical to
// the scatter Q8.8 discipline (integer accumulation is
// order-insensitive, but the loop order matches anyway).

fn gather_row_q(acc_row: &mut [Acc48], in_row: &[Q88], krow: &[Q88], s: usize) {
    let k = krow.len();
    let (mut lo, mut hi) = (0usize, 0usize);
    for (x, d) in acc_row.iter_mut().enumerate() {
        while lo * s + k <= x {
            lo += 1;
        }
        while hi < in_row.len() && hi * s <= x {
            hi += 1;
        }
        for (j, &a) in in_row[lo..hi].iter().enumerate() {
            if a.is_zero() {
                continue;
            }
            d.mac(a, krow[x - (lo + j) * s]);
        }
    }
}

/// Q8.8 twin of [`deconv_gather_rows`], accumulating into `acc`.
#[allow(clippy::too_many_arguments)]
fn deconv_gather_rows_q(
    input: &Volume<Q88>,
    w: &WeightsOIDHW<Q88>,
    s: usize,
    d_lo: usize,
    od: usize,
    oh: usize,
    ow: usize,
    r_lo: usize,
    r_hi: usize,
    acc: &mut [Acc48],
) {
    debug_assert_eq!(acc.len(), (r_hi - r_lo) * ow);
    for r in r_lo..r_hi {
        let o = r / (od * oh);
        let z = d_lo + r / oh % od;
        let y = r % oh;
        let (id_lo, id_hi) = contrib_window(z, w.kd, s, input.d);
        let (ih_lo, ih_hi) = contrib_window(y, w.kh, s, input.h);
        let base = (r - r_lo) * ow;
        let acc_row = &mut acc[base..base + ow];
        for i in 0..input.c {
            let kern = w.kernel(o, i);
            for id in id_lo..id_hi {
                let dz = z - id * s;
                for ih in ih_lo..ih_hi {
                    let dy = y - ih * s;
                    let kbase = (dz * w.kh + dy) * w.kw;
                    let krow = &kern[kbase..kbase + w.kw];
                    gather_row_q(acc_row, input.row(i, id, ih), krow, s);
                }
            }
        }
    }
}

/// Q8.8 zero-skip gather deconvolution of an output window — the
/// fixed-point twin of [`deconv_gather_window`], bit-exact against
/// `crop_window(&deconv_iom_q(..), ..)`. Under SIMD the blocked row
/// core accumulates in a pooled `i64` strip instead of a whole-window
/// [`Acc48`] buffer.
pub fn deconv_gather_window_q(
    input: &Volume<Q88>,
    w: &WeightsOIDHW<Q88>,
    s: usize,
    d_lo: usize,
    od: usize,
    oh: usize,
    ow: usize,
) -> Volume<Q88> {
    if !simd::simd_enabled() {
        return deconv_gather_window_q_scalar(input, w, s, d_lo, od, oh, ow);
    }
    assert_eq!(input.c, w.i, "channel mismatch");
    let (fd, fh, fw) = full_extents(input, w.kd, w.kh, w.kw, s);
    assert!(d_lo + od <= fd && oh <= fh && ow <= fw, "window exceeds Eq.-(1) extent");
    let mut out = Volume::zeros(w.o, od, oh, ow);
    gather_rows_blocked_q(input, w, s, d_lo, od, oh, ow, 0, w.o * od * oh, out.data_mut());
    out
}

/// [`deconv_gather_window_q`] pinned to the scalar reference nest.
pub fn deconv_gather_window_q_scalar(
    input: &Volume<Q88>,
    w: &WeightsOIDHW<Q88>,
    s: usize,
    d_lo: usize,
    od: usize,
    oh: usize,
    ow: usize,
) -> Volume<Q88> {
    assert_eq!(input.c, w.i, "channel mismatch");
    let (fd, fh, fw) = full_extents(input, w.kd, w.kh, w.kw, s);
    assert!(d_lo + od <= fd && oh <= fh && ow <= fw, "window exceeds Eq.-(1) extent");
    let mut acc = vec![Acc48::ZERO; w.o * od * oh * ow];
    deconv_gather_rows_q(input, w, s, d_lo, od, oh, ow, 0, w.o * od * oh, &mut acc);
    Volume::from_vec(w.o, od, oh, ow, acc.into_iter().map(|a| a.to_q88()).collect())
}

/// Q8.8 gather over the full Eq. (1) extent — the drop-in equal of
/// [`deconv_iom_q`].
pub fn deconv_gather_q(input: &Volume<Q88>, w: &WeightsOIDHW<Q88>, s: usize) -> Volume<Q88> {
    let (fd, fh, fw) = full_extents(input, w.kd, w.kh, w.kw, s);
    deconv_gather_window_q(input, w, s, 0, fd, fh, fw)
}

/// [`deconv_gather_q`] pinned to the scalar reference nest.
pub fn deconv_gather_q_scalar(input: &Volume<Q88>, w: &WeightsOIDHW<Q88>, s: usize) -> Volume<Q88> {
    let (fd, fh, fw) = full_extents(input, w.kd, w.kh, w.kw, s);
    deconv_gather_window_q_scalar(input, w, s, 0, fd, fh, fw)
}

/// [`deconv_gather_window_q`] with output rows sharded across
/// `threads` scoped workers (bit-identical: one thread per row, one
/// rounding per element).
#[allow(clippy::too_many_arguments)]
pub fn deconv_gather_window_q_threaded(
    input: &Volume<Q88>,
    w: &WeightsOIDHW<Q88>,
    s: usize,
    d_lo: usize,
    od: usize,
    oh: usize,
    ow: usize,
    threads: usize,
) -> Volume<Q88> {
    assert_eq!(input.c, w.i, "channel mismatch");
    let (fd, fh, fw) = full_extents(input, w.kd, w.kh, w.kw, s);
    assert!(d_lo + od <= fd && oh <= fh && ow <= fw, "window exceeds Eq.-(1) extent");
    let rows = w.o * od * oh;
    let t = threads.clamp(1, rows.max(1));
    if t <= 1 {
        return deconv_gather_window_q(input, w, s, d_lo, od, oh, ow);
    }
    let chunk_rows = rows.div_ceil(t);
    let mut out = Volume::zeros(w.o, od, oh, ow);
    std::thread::scope(|scope| {
        for (ti, buf) in out.data_mut().chunks_mut(chunk_rows * ow).enumerate() {
            let r_lo = ti * chunk_rows;
            let r_hi = (r_lo + chunk_rows).min(rows);
            scope.spawn(move || {
                if simd::simd_enabled() {
                    gather_rows_blocked_q(input, w, s, d_lo, od, oh, ow, r_lo, r_hi, buf);
                } else {
                    let mut acc = vec![Acc48::ZERO; buf.len()];
                    deconv_gather_rows_q(input, w, s, d_lo, od, oh, ow, r_lo, r_hi, &mut acc);
                    for (dst, a) in buf.iter_mut().zip(acc) {
                        *dst = a.to_q88();
                    }
                }
            });
        }
    });
    out
}

/// [`deconv_gather_q`] threaded over output rows.
pub fn deconv_gather_q_threaded(
    input: &Volume<Q88>,
    w: &WeightsOIDHW<Q88>,
    s: usize,
    threads: usize,
) -> Volume<Q88> {
    let (fd, fh, fw) = full_extents(input, w.kd, w.kh, w.kw, s);
    deconv_gather_window_q_threaded(input, w, s, 0, fd, fh, fw, threads)
}

// ---------------------------------------------------------------------
// The blocked SIMD row core (the tentpole of the host hot path).
//
// One core serves BOTH kernel families: scatter and gather produce the
// identical per-element term multiset in the identical order (the
// accumulation-order contract above), so under SIMD every
// deconvolution entry point routes here — scatter as the full-extent
// window, gather as its cropped window.
//
// Residue-class layout. A strided output row interleaves `S` residue
// classes: output x = q·S + ρ with ρ ∈ [0, S). Along w the contributor
// relation `x = iw·S + t` fixes `t ≡ ρ (mod S)`, so each kernel tap
// `t = m·S + ρ` touches *one* class, and within that class the map
// `q = iw + m` is a pure shift. The scratch row therefore stores the
// classes contiguously (class-major, running offset), turning the
// strided inner loop into a contiguous lane-wide multiply-accumulate:
//
//     class[ρ][q] += in_row[q − m] · krow[m·S + ρ]   for q ∈ [m, min(n_ρ, n+m))
//
// with `n_ρ = ⌈(ow − ρ)/S⌉` elements in class ρ and `n = in_row.len()`.
// The lower bound `q ≥ m` is exactly the scalar window bound
// `iw ≥ ⌈(x + 1 − K)/S⌉`; the upper bound is the in-extent clamp.
// Taps are applied in m-DESCENDING order, which is iw-ASCENDING per
// output element — the scalar kernels' term order, preserved exactly
// (f32 addition is non-associative). Vectorization is across output
// elements (one per lane), never within one element's sum.
//
// Blocking: `tile.rows` output rows accumulate in an L1-resident
// scratch strip while input channels stream in `tile.in_ch`-sized
// groups, so each scratch row is revisited from cache instead of DRAM.
// The unpack de-interleaves classes back to the natural row with plain
// ASSIGNMENT (the scratch starts at 0.0 and received the full sum), so
// no `-0.0 + 0.0` drift is possible. Scratch comes from the
// [`workspace`] pools — steady state allocates nothing.
// ---------------------------------------------------------------------

// Accumulate one kernel row into the class-major scratch row.
fn gather_krow_classes(scr_row: &mut [f32], in_row: &[f32], krow: &[f32], s: usize, ow: usize) {
    let k = krow.len();
    let n = in_row.len();
    let mut off = 0usize;
    for rho in 0..s {
        if rho >= ow {
            break;
        }
        let n_rho = (ow - rho).div_ceil(s);
        let cls = &mut scr_row[off..off + n_rho];
        off += n_rho;
        if rho >= k {
            continue; // no kernel tap lands in this residue class
        }
        let t_max = (k - 1 - rho) / s;
        for m in (0..=t_max).rev() {
            // m descending == iw ascending per output element
            let kv = krow[m * s + rho];
            let q_lo = m;
            let q_hi = n_rho.min(n + m);
            if q_lo < q_hi {
                simd::saxpy_skip_f32(&mut cls[q_lo..q_hi], &in_row[q_lo - m..q_hi - m], kv);
            }
        }
    }
}

// Q8.8 twin over raw Acc48 bits: same classes, same order,
// unconditional integer MAC (bit-equal to the skip — see simd::mac_q88).
fn gather_krow_classes_q(scr_row: &mut [i64], in_row: &[Q88], krow: &[Q88], s: usize, ow: usize) {
    let k = krow.len();
    let n = in_row.len();
    let mut off = 0usize;
    for rho in 0..s {
        if rho >= ow {
            break;
        }
        let n_rho = (ow - rho).div_ceil(s);
        let cls = &mut scr_row[off..off + n_rho];
        off += n_rho;
        if rho >= k {
            continue;
        }
        let t_max = (k - 1 - rho) / s;
        for m in (0..=t_max).rev() {
            let kv = krow[m * s + rho];
            let q_lo = m;
            let q_hi = n_rho.min(n + m);
            if q_lo < q_hi {
                simd::mac_q88(&mut cls[q_lo..q_hi], &in_row[q_lo - m..q_hi - m], kv);
            }
        }
    }
}

// De-interleave the class-major scratch row back to the natural output
// row. Plain assignment: the scratch started at zero and holds each
// element's complete sum in scalar term order.
fn unpack_classes(out_row: &mut [f32], scr_row: &[f32], s: usize) {
    let ow = out_row.len();
    if s == 1 {
        out_row.copy_from_slice(scr_row);
        return;
    }
    let mut off = 0usize;
    for rho in 0..s {
        if rho >= ow {
            break;
        }
        let n_rho = (ow - rho).div_ceil(s);
        for (q, &v) in scr_row[off..off + n_rho].iter().enumerate() {
            out_row[q * s + rho] = v;
        }
        off += n_rho;
    }
}

// Q8.8 unpack: the single write-back rounding of the wide accumulator.
fn unpack_classes_q(out_row: &mut [Q88], scr_row: &[i64], s: usize) {
    let ow = out_row.len();
    if s == 1 {
        for (d, &v) in out_row.iter_mut().zip(scr_row) {
            *d = Acc48(v).to_q88();
        }
        return;
    }
    let mut off = 0usize;
    for rho in 0..s {
        if rho >= ow {
            break;
        }
        let n_rho = (ow - rho).div_ceil(s);
        for (q, &v) in scr_row[off..off + n_rho].iter().enumerate() {
            out_row[q * s + rho] = Acc48(v).to_q88();
        }
        off += n_rho;
    }
}

/// The blocked SIMD row core: compute flattened gather-window rows
/// `[r_lo, r_hi)` (same row decode as [`deconv_gather_rows`]) into
/// `out` through an L1-tiled, channel-blocked, lane-vectorized sweep.
/// Bit-exact against the scalar kernels by the residue-class argument
/// above.
#[allow(clippy::too_many_arguments)]
fn gather_rows_blocked(
    input: &Volume<f32>,
    w: &WeightsOIDHW<f32>,
    s: usize,
    d_lo: usize,
    od: usize,
    oh: usize,
    ow: usize,
    r_lo: usize,
    r_hi: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), (r_hi - r_lo) * ow);
    if r_hi <= r_lo || ow == 0 {
        return;
    }
    let tile = simd::tile_for(ow, 4, input.h * input.w, input.c);
    let mut scr = workspace::take_f32(tile.rows * ow);
    let mut t_lo = r_lo;
    while t_lo < r_hi {
        let t_hi = (t_lo + tile.rows).min(r_hi);
        let strip = &mut scr[..(t_hi - t_lo) * ow];
        strip.fill(0.0);
        let mut i_lo = 0;
        while i_lo < input.c {
            let i_hi = (i_lo + tile.in_ch).min(input.c);
            for r in t_lo..t_hi {
                let o = r / (od * oh);
                let z = d_lo + r / oh % od;
                let y = r % oh;
                let (id_lo, id_hi) = contrib_window(z, w.kd, s, input.d);
                let (ih_lo, ih_hi) = contrib_window(y, w.kh, s, input.h);
                let base = (r - t_lo) * ow;
                let scr_row = &mut strip[base..base + ow];
                for i in i_lo..i_hi {
                    let kern = w.kernel(o, i);
                    for id in id_lo..id_hi {
                        let dz = z - id * s;
                        for ih in ih_lo..ih_hi {
                            let dy = y - ih * s;
                            let kbase = (dz * w.kh + dy) * w.kw;
                            let krow = &kern[kbase..kbase + w.kw];
                            gather_krow_classes(scr_row, input.row(i, id, ih), krow, s, ow);
                        }
                    }
                }
            }
            i_lo = i_hi;
        }
        for r in t_lo..t_hi {
            let src = &strip[(r - t_lo) * ow..(r - t_lo + 1) * ow];
            unpack_classes(&mut out[(r - r_lo) * ow..(r - r_lo + 1) * ow], src, s);
        }
        t_lo = t_hi;
    }
    workspace::give_f32(scr);
}

/// Q8.8 blocked row core: the scratch strip holds raw [`Acc48`] bits
/// (8-byte rows in the L1 budget), rounded once at unpack.
#[allow(clippy::too_many_arguments)]
fn gather_rows_blocked_q(
    input: &Volume<Q88>,
    w: &WeightsOIDHW<Q88>,
    s: usize,
    d_lo: usize,
    od: usize,
    oh: usize,
    ow: usize,
    r_lo: usize,
    r_hi: usize,
    out: &mut [Q88],
) {
    debug_assert_eq!(out.len(), (r_hi - r_lo) * ow);
    if r_hi <= r_lo || ow == 0 {
        return;
    }
    let tile = simd::tile_for(ow, 8, input.h * input.w, input.c);
    let mut scr = workspace::take_i64(tile.rows * ow);
    let mut t_lo = r_lo;
    while t_lo < r_hi {
        let t_hi = (t_lo + tile.rows).min(r_hi);
        let strip = &mut scr[..(t_hi - t_lo) * ow];
        strip.fill(0);
        let mut i_lo = 0;
        while i_lo < input.c {
            let i_hi = (i_lo + tile.in_ch).min(input.c);
            for r in t_lo..t_hi {
                let o = r / (od * oh);
                let z = d_lo + r / oh % od;
                let y = r % oh;
                let (id_lo, id_hi) = contrib_window(z, w.kd, s, input.d);
                let (ih_lo, ih_hi) = contrib_window(y, w.kh, s, input.h);
                let base = (r - t_lo) * ow;
                let scr_row = &mut strip[base..base + ow];
                for i in i_lo..i_hi {
                    let kern = w.kernel(o, i);
                    for id in id_lo..id_hi {
                        let dz = z - id * s;
                        for ih in ih_lo..ih_hi {
                            let dy = y - ih * s;
                            let kbase = (dz * w.kh + dy) * w.kw;
                            let krow = &kern[kbase..kbase + w.kw];
                            gather_krow_classes_q(scr_row, input.row(i, id, ih), krow, s, ow);
                        }
                    }
                }
            }
            i_lo = i_hi;
        }
        for r in t_lo..t_hi {
            let src = &strip[(r - t_lo) * ow..(r - t_lo + 1) * ow];
            unpack_classes_q(&mut out[(r - r_lo) * ow..(r - r_lo + 1) * ow], src, s);
        }
        t_lo = t_hi;
    }
    workspace::give_i64(scr);
}

// ---------------------------------------------------------------------
// OOM building blocks: zero-insert, pad, flip, correlate.
// ---------------------------------------------------------------------

/// Insert `s − 1` zeros between activations along every spatial axis
/// (§III, Fig. 3). Output extent per axis: `(I − 1)·s + 1` — a depth-1
/// input keeps depth 1, so the 2D case needs no special branch.
pub fn zero_insert<T: Copy + Default>(vol: &Volume<T>, s: usize) -> Volume<T> {
    assert!(s >= 1);
    let od = (vol.d - 1) * s + 1;
    let oh = (vol.h - 1) * s + 1;
    let ow = (vol.w - 1) * s + 1;
    let mut out = Volume::zeros(vol.c, od, oh, ow);
    for c in 0..vol.c {
        for d in 0..vol.d {
            for h in 0..vol.h {
                for w in 0..vol.w {
                    *out.at_mut(c, d * s, h * s, w * s) = vol.at(c, d, h, w);
                }
            }
        }
    }
    out
}

/// Pad with a zero border: `pd` planes on both depth sides, `ph` rows
/// and `pw` columns on both spatial sides. The 2D fold passes
/// `pd = 0` (its kernel has no depth extent).
pub fn pad<T: Copy + Default>(vol: &Volume<T>, pd: usize, ph: usize, pw: usize) -> Volume<T> {
    let mut out = Volume::zeros(vol.c, vol.d + 2 * pd, vol.h + 2 * ph, vol.w + 2 * pw);
    for c in 0..vol.c {
        for d in 0..vol.d {
            for h in 0..vol.h {
                for w in 0..vol.w {
                    *out.at_mut(c, d + pd, h + ph, w + pw) = vol.at(c, d, h, w);
                }
            }
        }
    }
    out
}

/// Spatially flip a kernel on every axis (for true convolution vs
/// correlation); `kd = 1` makes the depth flip a no-op.
pub fn flip(w: &WeightsOIDHW<f32>) -> WeightsOIDHW<f32> {
    let mut out = WeightsOIDHW::zeros(w.o, w.i, w.kd, w.kh, w.kw);
    for o in 0..w.o {
        for i in 0..w.i {
            for kd in 0..w.kd {
                for kh in 0..w.kh {
                    for kw in 0..w.kw {
                        *out.at_mut(o, i, w.kd - 1 - kd, w.kh - 1 - kh, w.kw - 1 - kw) =
                            w.at(o, i, kd, kh, kw);
                    }
                }
            }
        }
    }
    out
}

/// Compute output channels `[o_lo, o_hi)` of the VALID stride-1
/// correlation into `out`, a buffer holding exactly those channels.
/// Dispatches to the lane-blocked SIMD sweep or the scalar reference.
fn corr_into(
    input: &Volume<f32>,
    w: &WeightsOIDHW<f32>,
    o_lo: usize,
    o_hi: usize,
    out: &mut [f32],
) {
    if simd::simd_enabled() {
        corr_into_simd(input, w, o_lo, o_hi, out);
    } else {
        corr_into_scalar(input, w, o_lo, o_hi, out);
    }
}

fn corr_into_scalar(
    input: &Volume<f32>,
    w: &WeightsOIDHW<f32>,
    o_lo: usize,
    o_hi: usize,
    out: &mut [f32],
) {
    assert_eq!(input.c, w.i, "channel mismatch");
    assert!(
        input.d >= w.kd && input.h >= w.kh && input.w >= w.kw,
        "kernel larger than input"
    );
    let od = input.d - w.kd + 1;
    let oh = input.h - w.kh + 1;
    let ow = input.w - w.kw + 1;
    debug_assert_eq!(out.len(), (o_hi - o_lo) * od * oh * ow);
    for o in o_lo..o_hi {
        let o_base = (o - o_lo) * od * oh * ow;
        for i in 0..input.c {
            let kern = w.kernel(o, i);
            for z in 0..od {
                for y in 0..oh {
                    for x in 0..ow {
                        let mut acc = 0.0f32;
                        for kd in 0..w.kd {
                            for kh in 0..w.kh {
                                let in_row = input.row(i, z + kd, y + kh);
                                let kbase = (kd * w.kh + kh) * w.kw;
                                let krow = &kern[kbase..kbase + w.kw];
                                for (kw, &kv) in krow.iter().enumerate() {
                                    acc += in_row[x + kw] * kv;
                                }
                            }
                        }
                        out[o_base + (z * oh + y) * ow + x] += acc;
                    }
                }
            }
        }
    }
}

// Lane-blocked correlation: LANES_F32 output elements per iteration,
// each lane keeping its own local accumulator over the identical
// (kd, kh, kw) term order before the single add into the output row —
// the scalar per-element semantics, unchanged. Dense correlation has
// no zero-skip (the zero-inserted OOM map multiplies through zeros by
// design), so the inner body is a plain shifted-window FMA.
fn corr_into_simd(
    input: &Volume<f32>,
    w: &WeightsOIDHW<f32>,
    o_lo: usize,
    o_hi: usize,
    out: &mut [f32],
) {
    const L: usize = simd::LANES_F32;
    assert_eq!(input.c, w.i, "channel mismatch");
    assert!(
        input.d >= w.kd && input.h >= w.kh && input.w >= w.kw,
        "kernel larger than input"
    );
    let od = input.d - w.kd + 1;
    let oh = input.h - w.kh + 1;
    let ow = input.w - w.kw + 1;
    debug_assert_eq!(out.len(), (o_hi - o_lo) * od * oh * ow);
    for o in o_lo..o_hi {
        let o_base = (o - o_lo) * od * oh * ow;
        for i in 0..input.c {
            let kern = w.kernel(o, i);
            for z in 0..od {
                for y in 0..oh {
                    let row_base = o_base + (z * oh + y) * ow;
                    let out_row = &mut out[row_base..row_base + ow];
                    let mut blocks = out_row.chunks_exact_mut(L);
                    let mut x0 = 0usize;
                    for ob in &mut blocks {
                        let mut acc = [0.0f32; L];
                        for kd in 0..w.kd {
                            for kh in 0..w.kh {
                                let in_row = input.row(i, z + kd, y + kh);
                                let kbase = (kd * w.kh + kh) * w.kw;
                                for (kw, &kv) in kern[kbase..kbase + w.kw].iter().enumerate() {
                                    let src: &[f32; L] = in_row[x0 + kw..x0 + kw + L]
                                        .try_into()
                                        .expect("lane width");
                                    for l in 0..L {
                                        acc[l] += src[l] * kv;
                                    }
                                }
                            }
                        }
                        for (d, a) in ob.iter_mut().zip(acc) {
                            *d += a;
                        }
                        x0 += L;
                    }
                    for (j, d) in blocks.into_remainder().iter_mut().enumerate() {
                        let x = x0 + j;
                        let mut acc = 0.0f32;
                        for kd in 0..w.kd {
                            for kh in 0..w.kh {
                                let in_row = input.row(i, z + kd, y + kh);
                                let kbase = (kd * w.kh + kh) * w.kw;
                                for (kw, &kv) in kern[kbase..kbase + w.kw].iter().enumerate() {
                                    acc += in_row[x + kw] * kv;
                                }
                            }
                        }
                        *d += acc;
                    }
                }
            }
        }
    }
}

/// Dimension-uniform VALID correlation (CNN convention), stride 1.
/// `kd = 1` on a depth-1 input is exactly the 2D case.
pub fn corr(input: &Volume<f32>, w: &WeightsOIDHW<f32>) -> Volume<f32> {
    let od = input.d - w.kd + 1;
    let oh = input.h - w.kh + 1;
    let ow = input.w - w.kw + 1;
    let mut out = Volume::zeros(w.o, od, oh, ow);
    corr_into(input, w, 0, w.o, out.data_mut());
    out
}

/// [`corr`] pinned to the scalar reference nest regardless of the SIMD
/// mode.
pub fn corr_scalar(input: &Volume<f32>, w: &WeightsOIDHW<f32>) -> Volume<f32> {
    let od = input.d - w.kd + 1;
    let oh = input.h - w.kh + 1;
    let ow = input.w - w.kw + 1;
    let mut out = Volume::zeros(w.o, od, oh, ow);
    corr_into_scalar(input, w, 0, w.o, out.data_mut());
    out
}

/// [`corr`] with output channels sharded across `threads` scoped
/// workers (bit-identical to the single-threaded kernel).
pub fn corr_threaded(input: &Volume<f32>, w: &WeightsOIDHW<f32>, threads: usize) -> Volume<f32> {
    let t = clamp_threads(threads, w.o);
    if t <= 1 {
        return corr(input, w);
    }
    let od = input.d - w.kd + 1;
    let oh = input.h - w.kh + 1;
    let ow = input.w - w.kw + 1;
    let per_o = od * oh * ow;
    let chunk_os = w.o.div_ceil(t);
    let mut out = Volume::zeros(w.o, od, oh, ow);
    std::thread::scope(|scope| {
        for (ti, buf) in out.data_mut().chunks_mut(chunk_os * per_o).enumerate() {
            let o_lo = ti * chunk_os;
            let o_hi = (o_lo + chunk_os).min(w.o);
            scope.spawn(move || corr_into(input, w, o_lo, o_hi, buf));
        }
    });
    out
}

// ---------------------------------------------------------------------
// OOM: zero-insert, pad K−1, correlate with the flipped kernel.
// ---------------------------------------------------------------------

/// Dimension-uniform OOM deconvolution (the conventional formulation)
/// over the full Eq. (1) extent. Equals [`deconv_iom`] on every shape
/// — the §III equivalence the property suite asserts.
pub fn deconv_oom(input: &Volume<f32>, w: &WeightsOIDHW<f32>, s: usize) -> Volume<f32> {
    let ins = zero_insert(input, s);
    let padded = pad(&ins, w.kd - 1, w.kh - 1, w.kw - 1);
    corr(&padded, &flip(w))
}

/// [`deconv_oom`] with the dense correlation threaded over output
/// channels — the CPU-baseline hot loop. The zero-inserted, padded map
/// is materialized once and shared by every worker.
pub fn deconv_oom_threaded(
    input: &Volume<f32>,
    w: &WeightsOIDHW<f32>,
    s: usize,
    threads: usize,
) -> Volume<f32> {
    let ins = zero_insert(input, s);
    let padded = pad(&ins, w.kd - 1, w.kh - 1, w.kw - 1);
    corr_threaded(&padded, &flip(w), threads)
}

// ---------------------------------------------------------------------
// Cropping: remove the K−S high-side edge padding (§IV-B).
// ---------------------------------------------------------------------

/// Keep `vol[:, :d, :h, :w]` (works for any element type — f32, Q8.8).
pub fn crop<T: Copy + Default>(vol: &Volume<T>, d: usize, h: usize, w: usize) -> Volume<T> {
    crop_window(vol, 0, d, h, w)
}

/// Keep `vol[:, d_lo..d_lo+d, :h, :w]` — [`crop`] with a depth offset.
/// This is the write-back of one temporal tile: a streamed chunk owns
/// a *window* of output frames of the full Eq.-(1) accumulation
/// extent, not its low corner (see [`crate::stream`]).
pub fn crop_window<T: Copy + Default>(
    vol: &Volume<T>,
    d_lo: usize,
    d: usize,
    h: usize,
    w: usize,
) -> Volume<T> {
    assert!(d_lo + d <= vol.d && h <= vol.h && w <= vol.w);
    let mut out = Volume::zeros(vol.c, d, h, w);
    for c in 0..vol.c {
        for z in 0..d {
            for y in 0..h {
                let src = &vol.row(c, d_lo + z, y)[..w];
                let base = ((c * d + z) * h + y) * w;
                out.data_mut()[base..base + w].copy_from_slice(src);
            }
        }
    }
    out
}

/// [`crop_window`] with the output drawn from the [`workspace`] pool
/// (every element is overwritten, so the pre-zeroed buffer costs one
/// redundant memset, not an allocation). The serving and streaming
/// paths use this to keep the scatter-then-crop kernel choice
/// allocation-free in steady state; return the crop — and the full
/// volume it came from — with [`workspace::give_volume_f32`].
pub fn crop_window_pooled(
    vol: &Volume<f32>,
    d_lo: usize,
    d: usize,
    h: usize,
    w: usize,
) -> Volume<f32> {
    assert!(d_lo + d <= vol.d && h <= vol.h && w <= vol.w);
    let mut out = workspace::take_volume_f32(vol.c, d, h, w);
    for c in 0..vol.c {
        for z in 0..d {
            for y in 0..h {
                let src = &vol.row(c, d_lo + z, y)[..w];
                let base = ((c * d + z) * h + y) * w;
                out.data_mut()[base..base + w].copy_from_slice(src);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    fn rand_case(
        seed: u64,
        (c_in, c_out): (usize, usize),
        (d, h, w): (usize, usize, usize),
        (kd, kh, kw): (usize, usize, usize),
    ) -> (Volume<f32>, WeightsOIDHW<f32>) {
        let mut rng = Prng::new(seed);
        let mut input = Volume::zeros(c_in, d, h, w);
        rng.fill_f32(input.data_mut(), -1.0, 1.0);
        let mut wt = WeightsOIDHW::zeros(c_out, c_in, kd, kh, kw);
        rng.fill_f32(wt.data_mut(), -1.0, 1.0);
        (input, wt)
    }

    #[test]
    fn iom_equals_oom_across_kernel_widths() {
        // the generalized unroll (K = 1..7, incl. the non-monomorphized
        // fallback) must stay equal to the OOM reference
        for k in 1..=7usize {
            for s in 1..=k.min(3) {
                let (input, wt) = rand_case(k as u64, (2, 3), (1, 3, 4), (1, k, k));
                let a = deconv_iom(&input, &wt, s);
                let b = deconv_oom(&input, &wt, s);
                assert_eq!((a.d, a.h, a.w), (b.d, b.h, b.w));
                for (x, y) in a.data().iter().zip(b.data()) {
                    assert!((x - y).abs() < 1e-4, "k={k} s={s}: IOM {x} vs OOM {y}");
                }
            }
        }
    }

    #[test]
    fn iom_equals_oom_3d() {
        let (input, wt) = rand_case(11, (2, 2), (3, 3, 2), (3, 3, 3));
        for s in [1, 2] {
            let a = deconv_iom(&input, &wt, s);
            let b = deconv_oom(&input, &wt, s);
            for (x, y) in a.data().iter().zip(b.data()) {
                assert!((x - y).abs() < 1e-4, "IOM {x} vs OOM {y} (s={s})");
            }
        }
    }

    #[test]
    fn threaded_is_bit_identical() {
        let (input, wt) = rand_case(7, (3, 5), (2, 4, 3), (3, 3, 3));
        let single = deconv_iom(&input, &wt, 2);
        for t in [1, 2, 3, 8, 64] {
            let multi = deconv_iom_threaded(&input, &wt, 2, t);
            assert_eq!(single.data(), multi.data(), "t={t}");
        }
        let oom_single = deconv_oom(&input, &wt, 2);
        for t in [2, 4] {
            let oom_multi = deconv_oom_threaded(&input, &wt, 2, t);
            assert_eq!(oom_single.data(), oom_multi.data(), "t={t}");
        }
    }

    #[test]
    fn threaded_q_is_bit_identical() {
        let (input, wt) = rand_case(13, (2, 5), (2, 3, 3), (3, 3, 3));
        let qi = Volume::from_vec(
            input.c,
            input.d,
            input.h,
            input.w,
            input.data().iter().map(|&x| Q88::from_f32(x)).collect(),
        );
        let qw = WeightsOIDHW::from_vec(
            wt.o,
            wt.i,
            wt.kd,
            wt.kh,
            wt.kw,
            wt.data().iter().map(|&x| Q88::from_f32(x)).collect(),
        );
        let single = deconv_iom_q(&qi, &qw, 2);
        for t in [2, 3, 16] {
            let multi = deconv_iom_q_threaded(&qi, &qw, 2, t);
            assert_eq!(single.data(), multi.data(), "t={t}");
        }
    }

    #[test]
    fn depth1_matches_hand_2d() {
        // one activation a = 2 at the origin: output = a * kernel
        let input = Volume::from_vec(1, 1, 1, 1, vec![2.0]);
        let w = WeightsOIDHW::from_vec(1, 1, 1, 3, 3, (1..=9).map(|x| x as f32).collect());
        let out = deconv_iom(&input, &w, 2);
        assert_eq!((out.d, out.h, out.w), (1, 3, 3));
        for idx in 0..9 {
            assert_eq!(out.data()[idx], 2.0 * (idx + 1) as f32);
        }
    }

    #[test]
    fn zero_insert_depth1_keeps_depth1() {
        let fm = Volume::from_vec(1, 1, 2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let ins = zero_insert(&fm, 2);
        assert_eq!((ins.d, ins.h, ins.w), (1, 3, 3));
        assert_eq!(ins.at(0, 0, 2, 2), 4.0);
    }

    #[test]
    fn pad_depth_only_when_asked() {
        let v = Volume::from_vec(1, 1, 1, 1, vec![5.0]);
        let p2 = pad(&v, 0, 2, 2);
        assert_eq!((p2.d, p2.h, p2.w), (1, 5, 5));
        assert_eq!(p2.at(0, 0, 2, 2), 5.0);
        let p3 = pad(&v, 1, 1, 1);
        assert_eq!((p3.d, p3.h, p3.w), (3, 3, 3));
        assert_eq!(p3.at(0, 1, 1, 1), 5.0);
    }

    #[test]
    fn crop_keeps_low_corner() {
        let v = Volume::from_vec(1, 2, 2, 2, (0..8).map(|x| x as f32).collect());
        let c = crop(&v, 1, 2, 1);
        assert_eq!((c.d, c.h, c.w), (1, 2, 1));
        assert_eq!(c.data(), &[0.0, 2.0]);
    }

    #[test]
    fn crop_window_selects_depth_offset() {
        let v = Volume::from_vec(1, 3, 2, 2, (0..12).map(|x| x as f32).collect());
        let c = crop_window(&v, 1, 2, 2, 1);
        assert_eq!((c.d, c.h, c.w), (2, 2, 1));
        // frames 1 and 2, column 0 of each row
        assert_eq!(c.data(), &[4.0, 6.0, 8.0, 10.0]);
        // zero offset is exactly `crop`
        let a = crop_window(&v, 0, 2, 2, 2);
        let b = crop(&v, 2, 2, 2);
        assert_eq!(a.data(), b.data());
    }

    #[test]
    fn eq1_output_extents() {
        let (input, wt) = rand_case(3, (1, 1), (2, 3, 4), (3, 3, 3));
        let out = deconv_iom(&input, &wt, 2);
        assert_eq!((out.d, out.h, out.w), (5, 7, 9));
    }

    #[test]
    fn gather_is_bit_exact_vs_scatter_across_kernel_widths() {
        // every monomorphized width plus the fallback, including
        // K < S shapes where contributor windows go empty
        for k in 1..=7usize {
            for s in 1..=3usize {
                let (input, wt) = rand_case(100 + k as u64, (2, 3), (1, 3, 4), (1, k, k));
                let a = deconv_iom(&input, &wt, s);
                let b = deconv_gather(&input, &wt, s);
                assert_eq!((a.d, a.h, a.w), (b.d, b.h, b.w));
                assert_eq!(a.data(), b.data(), "k={k} s={s}: gather bits != scatter bits");
            }
        }
    }

    #[test]
    fn gather_is_bit_exact_vs_scatter_3d() {
        let (input, wt) = rand_case(17, (2, 2), (3, 3, 2), (3, 3, 3));
        for s in [1, 2] {
            let a = deconv_iom(&input, &wt, s);
            let b = deconv_gather(&input, &wt, s);
            assert_eq!(a.data(), b.data(), "s={s}");
        }
    }

    #[test]
    fn gather_window_equals_cropped_scatter() {
        let (input, wt) = rand_case(23, (2, 3), (4, 3, 3), (3, 3, 3));
        let s = 2;
        let full = deconv_iom(&input, &wt, s);
        // every depth offset and a strict h/w crop
        for d_lo in 0..full.d {
            for od in 1..=(full.d - d_lo) {
                let (oh, ow) = (full.h - 2, full.w - 1);
                let want = crop_window(&full, d_lo, od, oh, ow);
                let got = deconv_gather_window(&input, &wt, s, d_lo, od, oh, ow);
                assert_eq!(want.data(), got.data(), "d_lo={d_lo} od={od}");
            }
        }
    }

    #[test]
    fn gather_threaded_is_bit_identical() {
        let (input, wt) = rand_case(29, (3, 5), (2, 4, 3), (3, 3, 3));
        let single = deconv_gather(&input, &wt, 2);
        for t in [1, 2, 3, 8, 64] {
            let multi = deconv_gather_threaded(&input, &wt, 2, t);
            assert_eq!(single.data(), multi.data(), "t={t}");
        }
        // a 1-output-channel head still shards across rows
        let (input, wt) = rand_case(31, (4, 1), (2, 4, 4), (3, 3, 3));
        let single = deconv_gather_window(&input, &wt, 2, 0, 4, 8, 8);
        for t in [2, 5] {
            let multi = deconv_gather_window_threaded(&input, &wt, 2, 0, 4, 8, 8, t);
            assert_eq!(single.data(), multi.data(), "t={t}");
        }
    }

    #[test]
    fn gather_q_matches_scatter_q_and_threads() {
        let (input, wt) = rand_case(37, (2, 5), (2, 3, 3), (3, 3, 3));
        let qi = Volume::from_vec(
            input.c,
            input.d,
            input.h,
            input.w,
            input.data().iter().map(|&x| Q88::from_f32(x)).collect(),
        );
        let qw = WeightsOIDHW::from_vec(
            wt.o,
            wt.i,
            wt.kd,
            wt.kh,
            wt.kw,
            wt.data().iter().map(|&x| Q88::from_f32(x)).collect(),
        );
        let scatter = deconv_iom_q(&qi, &qw, 2);
        let gather = deconv_gather_q(&qi, &qw, 2);
        assert_eq!(scatter.data(), gather.data());
        let win = deconv_gather_window_q(&qi, &qw, 2, 1, 2, 5, 5);
        let want = crop_window(&scatter, 1, 2, 5, 5);
        assert_eq!(win.data(), want.data());
        for t in [2, 3, 16] {
            let multi = deconv_gather_q_threaded(&qi, &qw, 2, t);
            assert_eq!(scatter.data(), multi.data(), "t={t}");
            let multi_w = deconv_gather_window_q_threaded(&qi, &qw, 2, 1, 2, 5, 5, t);
            assert_eq!(win.data(), multi_w.data(), "t={t}");
        }
    }

    #[test]
    fn contrib_window_matches_the_paper_formula() {
        // K=3, S=2, I=4: full extent 9. Hand-enumerated windows.
        let want: [(usize, usize); 9] =
            [(0, 1), (0, 1), (0, 2), (1, 2), (1, 3), (2, 3), (2, 4), (3, 4), (3, 4)];
        for (z, &w) in want.iter().enumerate() {
            assert_eq!(contrib_window(z, 3, 2, 4), w, "z={z}");
        }
        // K < S leaves gaps: S=3, K=1 reaches only multiples of 3
        assert_eq!(contrib_window(1, 1, 3, 4), (1, 1), "empty window");
        assert_eq!(contrib_window(3, 1, 3, 4), (1, 2));
    }

    #[test]
    fn simd_dispatch_matches_scalar_twins_bitexact() {
        // whatever path the dispatchers pick, bits must equal the
        // pinned scalar twins — incl. K < S gap shapes and the
        // residue-class tails of odd output widths
        for (case, &(k, s)) in [(1usize, 1usize), (3, 1), (3, 2), (1, 3), (2, 3), (5, 2), (4, 4)]
            .iter()
            .enumerate()
        {
            let (mut input, wt) =
                rand_case(900 + case as u64, (3, 2), (2, 4, 5), (k.min(2), k, k));
            // exact zeros exercise the select-form zero-skip lanes
            for (i, v) in input.data_mut().iter_mut().enumerate() {
                if i % 3 == 0 {
                    *v = 0.0;
                }
            }
            let a = deconv_iom(&input, &wt, s);
            let b = deconv_iom_scalar(&input, &wt, s);
            assert_eq!(a.data(), b.data(), "iom k={k} s={s}");
            let mt = deconv_iom_threaded(&input, &wt, s, 3);
            assert_eq!(mt.data(), b.data(), "iom threaded k={k} s={s}");
            // a strict interior window: offset depth, cropped h and w
            let (od, oh, ow) = (a.d.min(2), a.h - 1, a.w - 1);
            let d_lo = a.d - od;
            let gw = deconv_gather_window(&input, &wt, s, d_lo, od, oh, ow);
            let gs = deconv_gather_window_scalar(&input, &wt, s, d_lo, od, oh, ow);
            assert_eq!(gw.data(), gs.data(), "gather k={k} s={s}");
            // cross-family: dispatch gather == scalar scatter, cropped
            let want = crop_window(&b, d_lo, od, oh, ow);
            assert_eq!(gw.data(), want.data(), "gather vs scatter k={k} s={s}");
            let pooled = crop_window_pooled(&b, d_lo, od, oh, ow);
            assert_eq!(pooled.data(), want.data(), "pooled crop k={k} s={s}");

            // Q8.8 twins through the same shapes
            let qi = Volume::from_vec(
                input.c,
                input.d,
                input.h,
                input.w,
                input.data().iter().map(|&x| Q88::from_f32(x)).collect(),
            );
            let qw = WeightsOIDHW::from_vec(
                wt.o,
                wt.i,
                wt.kd,
                wt.kh,
                wt.kw,
                wt.data().iter().map(|&x| Q88::from_f32(x)).collect(),
            );
            let qa = deconv_iom_q(&qi, &qw, s);
            let qb = deconv_iom_q_scalar(&qi, &qw, s);
            assert_eq!(qa.data(), qb.data(), "iom_q k={k} s={s}");
            let qmt = deconv_iom_q_threaded(&qi, &qw, s, 3);
            assert_eq!(qmt.data(), qb.data(), "iom_q threaded k={k} s={s}");
            let qgw = deconv_gather_window_q(&qi, &qw, s, d_lo, od, oh, ow);
            let qgs = deconv_gather_window_q_scalar(&qi, &qw, s, d_lo, od, oh, ow);
            assert_eq!(qgw.data(), qgs.data(), "gather_q k={k} s={s}");
            assert_eq!(
                qgw.data(),
                crop_window(&qb, d_lo, od, oh, ow).data(),
                "gather_q vs scatter_q k={k} s={s}"
            );
        }
        // the dense correlation (OOM hot loop), incl. a lane-tail width
        let (input, wt) = rand_case(990, (2, 3), (2, 6, 3 + crate::func::simd::LANES_F32), (2, 3, 3));
        let c = corr(&input, &wt);
        let cs = corr_scalar(&input, &wt);
        assert_eq!(c.data(), cs.data(), "corr dispatch vs scalar");
    }
}
