//! Zero insertion (§III, Fig. 3): the transformation that turns a
//! deconvolution into a dense convolution, and the source of the
//! sparsity plotted in Fig. 1.

use crate::tensor::{FeatureMap, Volume};

/// Insert `s − 1` zeros between activations along H and W.
/// Output extent per axis: `(I − 1)·s + 1`.
pub fn insert_2d(fm: &FeatureMap<f32>, s: usize) -> FeatureMap<f32> {
    assert!(s >= 1);
    let oh = (fm.h - 1) * s + 1;
    let ow = (fm.w - 1) * s + 1;
    let mut out = FeatureMap::zeros(fm.c, oh, ow);
    for c in 0..fm.c {
        for h in 0..fm.h {
            for w in 0..fm.w {
                *out.at_mut(c, h * s, w * s) = fm.at(c, h, w);
            }
        }
    }
    out
}

/// Insert `s − 1` zeros between activations along D, H and W — including
/// the all-zero "M1 planes" between consecutive 2D data planes that
/// Fig. 3(b) highlights.
pub fn insert_3d(vol: &Volume<f32>, s: usize) -> Volume<f32> {
    assert!(s >= 1);
    let od = (vol.d - 1) * s + 1;
    let oh = (vol.h - 1) * s + 1;
    let ow = (vol.w - 1) * s + 1;
    let mut out = Volume::zeros(vol.c, od, oh, ow);
    for c in 0..vol.c {
        for d in 0..vol.d {
            for h in 0..vol.h {
                for w in 0..vol.w {
                    *out.at_mut(c, d * s, h * s, w * s) = vol.at(c, d, h, w);
                }
            }
        }
    }
    out
}

/// Pad a 2D map with a zero border of `p` on every side.
pub fn pad_2d(fm: &FeatureMap<f32>, p: usize) -> FeatureMap<f32> {
    let mut out = FeatureMap::zeros(fm.c, fm.h + 2 * p, fm.w + 2 * p);
    for c in 0..fm.c {
        for h in 0..fm.h {
            for w in 0..fm.w {
                *out.at_mut(c, h + p, w + p) = fm.at(c, h, w);
            }
        }
    }
    out
}

/// Pad a 3D volume with a zero border of `p` on every side.
pub fn pad_3d(vol: &Volume<f32>, p: usize) -> Volume<f32> {
    let mut out = Volume::zeros(vol.c, vol.d + 2 * p, vol.h + 2 * p, vol.w + 2 * p);
    for c in 0..vol.c {
        for d in 0..vol.d {
            for h in 0..vol.h {
                for w in 0..vol.w {
                    *out.at_mut(c, d + p, h + p, w + p) = vol.at(c, d, h, w);
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_2d_positions_and_zeros() {
        let fm = FeatureMap::from_vec(1, 2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let ins = insert_2d(&fm, 2);
        assert_eq!((ins.h, ins.w), (3, 3));
        assert_eq!(ins.at(0, 0, 0), 1.0);
        assert_eq!(ins.at(0, 0, 2), 2.0);
        assert_eq!(ins.at(0, 2, 0), 3.0);
        assert_eq!(ins.at(0, 2, 2), 4.0);
        // all other 5 positions are inserted zeros
        let zeros = ins.data().iter().filter(|&&x| x == 0.0).count();
        assert_eq!(zeros, 5);
    }

    #[test]
    fn insert_stride_1_is_identity() {
        let fm = FeatureMap::from_vec(2, 2, 2, (0..8).map(|x| x as f32 + 1.0).collect());
        let ins = insert_2d(&fm, 1);
        assert_eq!(ins, fm);
    }

    #[test]
    fn insert_3d_m1_planes_are_zero() {
        let vol = Volume::from_vec(1, 2, 2, 2, vec![1.0; 8]);
        let ins = insert_3d(&vol, 2);
        assert_eq!((ins.d, ins.h, ins.w), (3, 3, 3));
        // the middle depth plane (an "M1 plane") must be entirely zero
        for h in 0..3 {
            for w in 0..3 {
                assert_eq!(ins.at(0, 1, h, w), 0.0);
            }
        }
        // 8 nonzeros out of 27
        let nz = ins.data().iter().filter(|&&x| x != 0.0).count();
        assert_eq!(nz, 8);
    }

    #[test]
    fn pad_2d_border() {
        let fm = FeatureMap::from_vec(1, 1, 1, vec![5.0]);
        let p = pad_2d(&fm, 2);
        assert_eq!((p.h, p.w), (5, 5));
        assert_eq!(p.at(0, 2, 2), 5.0);
        assert_eq!(p.data().iter().filter(|&&x| x != 0.0).count(), 1);
    }

    #[test]
    fn pad_3d_border() {
        let vol = Volume::from_vec(1, 1, 1, 1, vec![5.0]);
        let p = pad_3d(&vol, 1);
        assert_eq!((p.d, p.h, p.w), (3, 3, 3));
        assert_eq!(p.at(0, 1, 1, 1), 5.0);
    }
}
