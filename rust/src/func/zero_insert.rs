//! Zero insertion (§III, Fig. 3): the transformation that turns a
//! deconvolution into a dense convolution, and the source of the
//! sparsity plotted in Fig. 1. The loop nests live in
//! [`super::uniform`]; the 2D entry points are depth-1 folds.

use crate::tensor::{FeatureMap, Volume};

use super::uniform;

/// Insert `s − 1` zeros between activations along H and W.
/// Output extent per axis: `(I − 1)·s + 1`.
pub fn insert_2d(fm: &FeatureMap<f32>, s: usize) -> FeatureMap<f32> {
    uniform::zero_insert(&fm.to_volume(), s).into_feature_map()
}

/// Insert `s − 1` zeros between activations along D, H and W — including
/// the all-zero "M1 planes" between consecutive 2D data planes that
/// Fig. 3(b) highlights.
pub fn insert_3d(vol: &Volume<f32>, s: usize) -> Volume<f32> {
    uniform::zero_insert(vol, s)
}

/// Pad a 2D map with a zero border of `p` on every side.
pub fn pad_2d(fm: &FeatureMap<f32>, p: usize) -> FeatureMap<f32> {
    uniform::pad(&fm.to_volume(), 0, p, p).into_feature_map()
}

/// Pad a 3D volume with a zero border of `p` on every side.
pub fn pad_3d(vol: &Volume<f32>, p: usize) -> Volume<f32> {
    uniform::pad(vol, p, p, p)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_2d_positions_and_zeros() {
        let fm = FeatureMap::from_vec(1, 2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let ins = insert_2d(&fm, 2);
        assert_eq!((ins.h, ins.w), (3, 3));
        assert_eq!(ins.at(0, 0, 0), 1.0);
        assert_eq!(ins.at(0, 0, 2), 2.0);
        assert_eq!(ins.at(0, 2, 0), 3.0);
        assert_eq!(ins.at(0, 2, 2), 4.0);
        // all other 5 positions are inserted zeros
        let zeros = ins.data().iter().filter(|&&x| x == 0.0).count();
        assert_eq!(zeros, 5);
    }

    #[test]
    fn insert_stride_1_is_identity() {
        let fm = FeatureMap::from_vec(2, 2, 2, (0..8).map(|x| x as f32 + 1.0).collect());
        let ins = insert_2d(&fm, 1);
        assert_eq!(ins, fm);
    }

    #[test]
    fn insert_3d_m1_planes_are_zero() {
        let vol = Volume::from_vec(1, 2, 2, 2, vec![1.0; 8]);
        let ins = insert_3d(&vol, 2);
        assert_eq!((ins.d, ins.h, ins.w), (3, 3, 3));
        // the middle depth plane (an "M1 plane") must be entirely zero
        for h in 0..3 {
            for w in 0..3 {
                assert_eq!(ins.at(0, 1, h, w), 0.0);
            }
        }
        // 8 nonzeros out of 27
        let nz = ins.data().iter().filter(|&&x| x != 0.0).count();
        assert_eq!(nz, 8);
    }

    #[test]
    fn pad_2d_border() {
        let fm = FeatureMap::from_vec(1, 1, 1, vec![5.0]);
        let p = pad_2d(&fm, 2);
        assert_eq!((p.h, p.w), (5, 5));
        assert_eq!(p.at(0, 2, 2), 5.0);
        assert_eq!(p.data().iter().filter(|&&x| x != 0.0).count(), 1);
    }

    #[test]
    fn pad_3d_border() {
        let vol = Volume::from_vec(1, 1, 1, 1, vec![5.0]);
        let p = pad_3d(&vol, 1);
        assert_eq!((p.d, p.h, p.w), (3, 3, 3));
        assert_eq!(p.at(0, 1, 1, 1), 5.0);
    }
}
