//! Portable explicit-width SIMD primitives for the uniform kernel
//! core, plus the per-layer cache-blocking heuristic.
//!
//! No nightly features and no intrinsics: each primitive processes
//! `LANES_*` elements per iteration through fixed-size arrays
//! (`chunks_exact` + `try_into`), which LLVM reliably turns into
//! vector loads, fused multiply-adds and blends on every target the
//! repo builds for, with an explicit scalar tail for the remainder.
//! The lane bodies are written so that **no floating-point
//! reassociation occurs**: vectorization runs *across* output
//! elements (one element per lane), never within one element's
//! reduction, so SIMD results are bit-identical to the scalar
//! kernels — the contract `tests/prop_uniform.rs` enforces.
//!
//! The scalar fallback is selectable at runtime: `UDCNN_FORCE_SCALAR=1`
//! in the environment (read once, on first use) or
//! [`set_force_scalar`] (benches, tests) routes every dispatching
//! kernel entry point in [`super::uniform`] to the reference scalar
//! loop nests. CI runs the whole property suite in that mode so the
//! fallback cannot rot.

use std::sync::atomic::{AtomicU8, Ordering};

use crate::dcnn::LayerSpec;
use crate::fixed::{Acc48, Q88};

/// f32 lane width: 8 × 32-bit = one AVX2 register (two NEON
/// registers); wide enough to saturate the FMA ports, narrow enough
/// that tail loops stay cheap on the zoo's smallest rows.
pub const LANES_F32: usize = 8;

/// Q8.8 lane width. The MAC widens `i16 × i16 → i32 → i64`
/// (the DSP48 P-register model in [`Acc48`]), so 8 lanes of `i64`
/// accumulator mirror the f32 width and keep one tail policy.
pub const LANES_Q: usize = 8;

// 0 = uninitialized (read UDCNN_FORCE_SCALAR on first use),
// 1 = SIMD lanes, 2 = scalar fallback forced.
static KERNEL_MODE: AtomicU8 = AtomicU8::new(0);

#[cold]
fn init_mode() -> u8 {
    let forced = std::env::var("UDCNN_FORCE_SCALAR")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    let mode = if forced { 2 } else { 1 };
    KERNEL_MODE.store(mode, Ordering::Relaxed);
    mode
}

/// Whether the vectorized kernel paths are active (the default).
/// `UDCNN_FORCE_SCALAR=1` or [`set_force_scalar`]`(true)` turns them
/// off. After the first call this is a single atomic load — the
/// dispatching entry points stay allocation-free.
#[inline]
pub fn simd_enabled() -> bool {
    let m = KERNEL_MODE.load(Ordering::Relaxed);
    let m = if m == 0 { init_mode() } else { m };
    m == 1
}

/// Force the scalar reference path (`true`) or the SIMD path
/// (`false`), overriding the environment. Benches use this to race
/// the two implementations in one process. Both paths are bit-exact,
/// so flipping this concurrently with running kernels is benign.
pub fn set_force_scalar(scalar: bool) {
    KERNEL_MODE.store(if scalar { 2 } else { 1 }, Ordering::Relaxed);
}

/// `dst[j] += src[j] · kv` for every lane where `src[j] != 0.0`,
/// leaving lanes with a zero input **untouched** — the select form of
/// the IOM zero-skip. Skipping (rather than adding `src[j] · kv =
/// ±0.0`) matters for bit-exactness: adding a zero product can flip a
/// `-0.0` accumulator to `+0.0`, which the scalar kernels' `continue`
/// never does. `kv == 0.0` is *not* skipped (the scalar loops multiply
/// through zero weights too). One output element per lane — no
/// reassociation of any element's sum.
#[inline]
pub fn saxpy_skip_f32(dst: &mut [f32], src: &[f32], kv: f32) {
    debug_assert_eq!(dst.len(), src.len());
    let mut dc = dst.chunks_exact_mut(LANES_F32);
    let mut sc = src.chunks_exact(LANES_F32);
    for (d, s) in (&mut dc).zip(&mut sc) {
        let a: [f32; LANES_F32] = s.try_into().expect("lane width");
        let mut v: [f32; LANES_F32] = (&*d).try_into().expect("lane width");
        for l in 0..LANES_F32 {
            // cmp + blend under vectorization; exact scalar-skip semantics
            v[l] = if a[l] != 0.0 { v[l] + a[l] * kv } else { v[l] };
        }
        d.copy_from_slice(&v);
    }
    for (d, &a) in dc.into_remainder().iter_mut().zip(sc.remainder()) {
        if a != 0.0 {
            *d += a * kv;
        }
    }
}

/// `dst[j] = clamp48(dst[j] + wide(src[j] · kv))` per lane over raw
/// [`Acc48`] bits (`i64`). Unlike the f32 form this needs no
/// zero-skip to stay bit-exact: accumulating a zero product adds the
/// integer 0 and the 48-bit clamp is idempotent on in-range values,
/// so the result matches the scalar kernels' skip exactly. One output
/// element per lane; each lane applies the DSP48-style MAC + clamp in
/// scalar order.
#[inline]
pub fn mac_q88(dst: &mut [i64], src: &[Q88], kv: Q88) {
    debug_assert_eq!(dst.len(), src.len());
    let mut dc = dst.chunks_exact_mut(LANES_Q);
    let mut sc = src.chunks_exact(LANES_Q);
    for (d, s) in (&mut dc).zip(&mut sc) {
        let a: [Q88; LANES_Q] = s.try_into().expect("lane width");
        let mut v: [i64; LANES_Q] = (&*d).try_into().expect("lane width");
        for l in 0..LANES_Q {
            let mut acc = Acc48(v[l]);
            acc.mac(a[l], kv);
            v[l] = acc.0;
        }
        d.copy_from_slice(&v);
    }
    for (d, &a) in dc.into_remainder().iter_mut().zip(sc.remainder()) {
        let mut acc = Acc48(*d);
        acc.mac(a, kv);
        *d = acc.0;
    }
}

/// Cache-blocking tile for the blocked gather/scatter row core:
/// `rows` output rows are accumulated in an L1-resident scratch strip
/// while `in_ch` input channels are streamed per pass, so each scratch
/// row is touched `⌈I / in_ch⌉` times from L1 instead of `I` times
/// from DRAM. Chosen once per layer ([`tile_for_layer`]) and reported
/// by `benches/kernels.rs`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Tile {
    /// Output rows per scratch strip (L1 budget / row bytes).
    pub rows: usize,
    /// Input channels per streaming pass (L2 budget / plane bytes).
    pub in_ch: usize,
}

// Per-core cache budgets the tile targets: half a typical 32 KiB L1d
// for the output scratch strip (the other half holds the streaming
// input rows), and a conservative 256 KiB slice of L2 for the input
// planes revisited across the strip.
const L1_SCRATCH_BYTES: usize = 16 * 1024;
const L2_INPUT_BYTES: usize = 256 * 1024;

/// Pick a [`Tile`] for output rows of `ow` elements of `elem_bytes`
/// bytes each, with input planes of `in_plane_elems` elements across
/// `in_c` input channels.
pub fn tile_for(ow: usize, elem_bytes: usize, in_plane_elems: usize, in_c: usize) -> Tile {
    let row_bytes = (ow * elem_bytes).max(1);
    let plane_bytes = (in_plane_elems * elem_bytes).max(1);
    Tile {
        rows: (L1_SCRATCH_BYTES / row_bytes).clamp(4, 64),
        in_ch: (L2_INPUT_BYTES / plane_bytes).clamp(1, in_c.max(1)),
    }
}

/// The [`Tile`] the f32 kernels use for `spec` (Q8.8 uses the same
/// shape with 8-byte accumulator rows). Benches record these so the
/// committed reports show the blocking each layer ran under.
pub fn tile_for_layer(spec: &LayerSpec) -> Tile {
    tile_for(spec.out_w(), 4, spec.in_h * spec.in_w, spec.in_c)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn saxpy_skip_matches_scalar_reference_with_tails() {
        let mut rng = crate::util::Prng::new(9);
        for n in [0, 1, LANES_F32 - 1, LANES_F32, LANES_F32 + 1, 3 * LANES_F32 + 5] {
            let mut src = vec![0.0f32; n];
            rng.fill_f32(&mut src, -2.0, 2.0);
            // exact zeros (and a negative-zero accumulator test below)
            for (i, v) in src.iter_mut().enumerate() {
                if i % 3 == 0 {
                    *v = 0.0;
                }
            }
            let mut dst = vec![0.0f32; n];
            rng.fill_f32(&mut dst, -1.0, 1.0);
            let mut want = dst.clone();
            let kv = 0.75f32;
            for (d, &a) in want.iter_mut().zip(&src) {
                if a != 0.0 {
                    *d += a * kv;
                }
            }
            saxpy_skip_f32(&mut dst, &src, kv);
            assert_eq!(
                dst.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "n={n}"
            );
        }
    }

    #[test]
    fn saxpy_skip_preserves_negative_zero_accumulators() {
        // a skipped lane must not flip -0.0 to +0.0
        let mut dst = vec![-0.0f32; LANES_F32 + 1];
        let src = vec![0.0f32; LANES_F32 + 1];
        saxpy_skip_f32(&mut dst, &src, 1.0);
        for v in &dst {
            assert_eq!(v.to_bits(), (-0.0f32).to_bits());
        }
    }

    #[test]
    fn mac_q88_matches_acc48_with_tails() {
        let mut rng = crate::util::Prng::new(11);
        for n in [0, 1, LANES_Q - 1, LANES_Q, LANES_Q + 1, 2 * LANES_Q + 3] {
            let src: Vec<Q88> = (0..n).map(|_| Q88::from_f32(rng.f32_range(-3.0, 3.0))).collect();
            let mut dst: Vec<i64> = (0..n).map(|i| (i as i64 - 2) << 12).collect();
            let kv = Q88::from_f32(1.25);
            let mut want = dst.clone();
            for (d, &a) in want.iter_mut().zip(&src) {
                let mut acc = Acc48(*d);
                if !a.is_zero() {
                    acc.mac(a, kv);
                }
                *d = acc.0;
            }
            // the unconditional MAC equals the skip form: +0 is exact
            mac_q88(&mut dst, &src, kv);
            assert_eq!(dst, want, "n={n}");
        }
    }

    #[test]
    fn force_scalar_round_trips() {
        // explicit sets override whatever the environment selected
        set_force_scalar(true);
        assert!(!simd_enabled());
        set_force_scalar(false);
        assert!(simd_enabled());
    }

    #[test]
    fn tiles_are_clamped_and_sane() {
        let t = tile_for(8, 4, 16, 1);
        assert_eq!(t.in_ch, 1);
        assert_eq!(t.rows, 64, "tiny rows clamp to the max strip");
        let t = tile_for(100_000, 4, 1_000_000, 512);
        assert_eq!(t.rows, 4, "huge rows clamp to the min strip");
        assert_eq!(t.in_ch, 1);
        let t = tile_for(64, 4, 64 * 64, 256);
        assert!(t.rows >= 4 && t.rows <= 64);
        assert!(t.in_ch >= 1 && t.in_ch <= 256);
    }
}
