//! Q8.8 IOM deconvolution — the bit-exact model of the accelerator
//! datapath. Every product is a DSP48-style wide multiply, every
//! overlap addition happens in the 48-bit accumulator, and write-back
//! rounds once — matching the PE's "multiply, accumulate overlaps from
//! FIFOs, write local result" pipeline, so the functional simulator
//! tier can be compared against this reference bit-for-bit.

use crate::fixed::Q88;
use crate::tensor::{FeatureMap, Volume, WeightsOIDHW, WeightsOIHW};

use super::uniform;

/// 2D IOM deconvolution in Q8.8 over the full Eq. (1) extent — the
/// depth-1 fold of [`uniform::deconv_iom_q`].
///
/// Accumulation is performed in Q16.16/48-bit per output element across
/// *all* input channels before a single rounding at write-back (the
/// adder tree + output buffer behaviour).
pub fn deconv2d_iom_q(input: &FeatureMap<Q88>, w: &WeightsOIHW<Q88>, s: usize) -> FeatureMap<Q88> {
    uniform::deconv_iom_q(&input.to_volume(), &w.to_oidhw(), s).into_feature_map()
}

/// 3D IOM deconvolution in Q8.8 over the full Eq. (1) extent.
pub fn deconv3d_iom_q(input: &Volume<Q88>, w: &WeightsOIDHW<Q88>, s: usize) -> Volume<Q88> {
    uniform::deconv_iom_q(input, w, s)
}

/// Crop a Q8.8 feature map (high-side, like [`super::crop_2d`]).
pub fn crop_2d_q(fm: &FeatureMap<Q88>, h: usize, w: usize) -> FeatureMap<Q88> {
    uniform::crop(&fm.to_volume(), 1, h, w).into_feature_map()
}

/// Crop a Q8.8 volume.
pub fn crop_3d_q(vol: &Volume<Q88>, d: usize, h: usize, w: usize) -> Volume<Q88> {
    uniform::crop(vol, d, h, w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dcnn::{zoo, LayerData, LayerDataQ};
    use crate::func::{deconv2d_iom, deconv3d_iom};

    /// Q8.8 IOM tracks the f32 IOM within accumulated quantization
    /// error: each of the `in_c · K^d` products contributes at most
    /// ~eps of input error times weight magnitude.
    #[test]
    fn q88_tracks_f32_2d() {
        let spec = &zoo::tiny_2d().layers[0];
        let data = LayerData::synth(spec, 31);
        let (input, weights) = match &data {
            LayerData::D2 { input, weights } => (input, weights),
            _ => unreachable!(),
        };
        let fout = deconv2d_iom(input, weights, spec.s);
        let q = data.quantize();
        let (qi, qw) = match &q {
            LayerDataQ::D2 { input, weights } => (input, weights),
            _ => unreachable!(),
        };
        let qout = deconv2d_iom_q(qi, qw, spec.s);
        // error bound: each product has quant error <= (|a_err·w| + |a·w_err|)
        // ~ 2 * (0.5/256) per product; chains are in_c*k^2 = 36 long here.
        let bound = 2.0 * (0.5 / 256.0) * (spec.in_c * 9) as f32 * 1.0 + 0.01;
        for (f, qv) in fout.data().iter().zip(qout.data()) {
            assert!(
                (f - qv.to_f32()).abs() < bound,
                "f32 {f} vs q {q}",
                q = qv.to_f32()
            );
        }
    }

    #[test]
    fn q88_tracks_f32_3d() {
        let spec = &zoo::tiny_3d().layers[0];
        let data = LayerData::synth(spec, 77);
        let (input, weights) = match &data {
            LayerData::D3 { input, weights } => (input, weights),
            _ => unreachable!(),
        };
        let fout = deconv3d_iom(input, weights, spec.s);
        let q = data.quantize();
        let (qi, qw) = match &q {
            LayerDataQ::D3 { input, weights } => (input, weights),
            _ => unreachable!(),
        };
        let qout = deconv3d_iom_q(qi, qw, spec.s);
        let bound = 2.0 * (0.5 / 256.0) * (spec.in_c * 27) as f32 + 0.01;
        for (f, qv) in fout.data().iter().zip(qout.data()) {
            assert!((f - qv.to_f32()).abs() < bound);
        }
    }

    #[test]
    fn deterministic() {
        let spec = &zoo::tiny_2d().layers[0];
        let q = LayerData::synth(spec, 1).quantize();
        let (qi, qw) = match &q {
            LayerDataQ::D2 { input, weights } => (input, weights),
            _ => unreachable!(),
        };
        let a = deconv2d_iom_q(qi, qw, spec.s);
        let b = deconv2d_iom_q(qi, qw, spec.s);
        assert_eq!(a.data(), b.data());
    }

    #[test]
    fn crop_q_preserves_prefix() {
        let fm = FeatureMap::from_vec(
            1,
            3,
            3,
            (0..9).map(|i| Q88::from_int(i)).collect(),
        );
        let c = crop_2d_q(&fm, 2, 2);
        assert_eq!(c.at(0, 0, 0), Q88::from_int(0));
        assert_eq!(c.at(0, 1, 1), Q88::from_int(4));
    }
}
