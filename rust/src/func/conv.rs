//! Dense convolution (cross-correlation, CNN convention), stride 1.
//!
//! Used by the OOM deconvolution formulation (over the zero-inserted,
//! border-padded map) and by the CPU baseline.

use crate::tensor::{FeatureMap, Volume, WeightsOIHW, WeightsOIDHW};

/// `out[o][y][x] = Σ_i Σ_kh Σ_kw in[i][y+kh][x+kw] · w[o][i][kh][kw]`
/// ("VALID" correlation, stride 1).
pub fn corr2d(input: &FeatureMap<f32>, w: &WeightsOIHW<f32>) -> FeatureMap<f32> {
    assert_eq!(input.c, w.i, "channel mismatch");
    assert!(input.h >= w.kh && input.w >= w.kw, "kernel larger than input");
    let oh = input.h - w.kh + 1;
    let ow = input.w - w.kw + 1;
    let mut out = FeatureMap::zeros(w.o, oh, ow);
    for o in 0..w.o {
        for i in 0..input.c {
            for y in 0..oh {
                for x in 0..ow {
                    let mut acc = 0.0f32;
                    for kh in 0..w.kh {
                        for kw in 0..w.kw {
                            acc += input.at(i, y + kh, x + kw) * w.at(o, i, kh, kw);
                        }
                    }
                    *out.at_mut(o, y, x) += acc;
                }
            }
        }
    }
    out
}

/// 3D VALID correlation, stride 1.
pub fn corr3d(input: &Volume<f32>, w: &WeightsOIDHW<f32>) -> Volume<f32> {
    assert_eq!(input.c, w.i, "channel mismatch");
    assert!(
        input.d >= w.kd && input.h >= w.kh && input.w >= w.kw,
        "kernel larger than input"
    );
    let od = input.d - w.kd + 1;
    let oh = input.h - w.kh + 1;
    let ow = input.w - w.kw + 1;
    let mut out = Volume::zeros(w.o, od, oh, ow);
    for o in 0..w.o {
        for i in 0..input.c {
            for z in 0..od {
                for y in 0..oh {
                    for x in 0..ow {
                        let mut acc = 0.0f32;
                        for kd in 0..w.kd {
                            for kh in 0..w.kh {
                                for kw in 0..w.kw {
                                    acc += input.at(i, z + kd, y + kh, x + kw)
                                        * w.at(o, i, kd, kh, kw);
                                }
                            }
                        }
                        *out.at_mut(o, z, y, x) += acc;
                    }
                }
            }
        }
    }
    out
}

/// Spatially flip a 2D kernel (for true convolution vs correlation).
pub fn flip_2d(w: &WeightsOIHW<f32>) -> WeightsOIHW<f32> {
    let mut out = WeightsOIHW::zeros(w.o, w.i, w.kh, w.kw);
    for o in 0..w.o {
        for i in 0..w.i {
            for kh in 0..w.kh {
                for kw in 0..w.kw {
                    *out.at_mut(o, i, w.kh - 1 - kh, w.kw - 1 - kw) = w.at(o, i, kh, kw);
                }
            }
        }
    }
    out
}

/// Spatially flip a 3D kernel.
pub fn flip_3d(w: &WeightsOIDHW<f32>) -> WeightsOIDHW<f32> {
    let mut out = WeightsOIDHW::zeros(w.o, w.i, w.kd, w.kh, w.kw);
    for o in 0..w.o {
        for i in 0..w.i {
            for kd in 0..w.kd {
                for kh in 0..w.kh {
                    for kw in 0..w.kw {
                        *out.at_mut(o, i, w.kd - 1 - kd, w.kh - 1 - kh, w.kw - 1 - kw) =
                            w.at(o, i, kd, kh, kw);
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corr2d_identity_kernel() {
        // 1x1 kernel of value 2 doubles the map
        let input = FeatureMap::from_vec(1, 2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let w = WeightsOIHW::from_vec(1, 1, 1, 1, vec![2.0]);
        let out = corr2d(&input, &w);
        assert_eq!(out.data(), &[2.0, 4.0, 6.0, 8.0]);
    }

    #[test]
    fn corr2d_known_values() {
        // 3x3 input, 2x2 ones kernel -> 2x2 output of window sums
        let input = FeatureMap::from_vec(1, 3, 3, (1..=9).map(|x| x as f32).collect());
        let w = WeightsOIHW::from_vec(1, 1, 2, 2, vec![1.0; 4]);
        let out = corr2d(&input, &w);
        assert_eq!((out.h, out.w), (2, 2));
        assert_eq!(out.data(), &[12.0, 16.0, 24.0, 28.0]);
    }

    #[test]
    fn corr2d_sums_channels() {
        let input = FeatureMap::from_vec(2, 1, 1, vec![3.0, 4.0]);
        let w = WeightsOIHW::from_vec(1, 2, 1, 1, vec![1.0, 10.0]);
        let out = corr2d(&input, &w);
        assert_eq!(out.data(), &[43.0]);
    }

    #[test]
    fn corr3d_window_sum() {
        let input = Volume::from_vec(1, 2, 2, 2, (1..=8).map(|x| x as f32).collect());
        let w = WeightsOIDHW::from_vec(1, 1, 2, 2, 2, vec![1.0; 8]);
        let out = corr3d(&input, &w);
        assert_eq!((out.d, out.h, out.w), (1, 1, 1));
        assert_eq!(out.data(), &[36.0]);
    }

    #[test]
    fn flip_round_trips() {
        let w = WeightsOIHW::from_vec(1, 1, 2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let f = flip_2d(&w);
        assert_eq!(f.data(), &[4.0, 3.0, 2.0, 1.0]);
        assert_eq!(flip_2d(&f).data(), w.data());
        let w3 = WeightsOIDHW::from_vec(1, 1, 2, 1, 1, vec![1.0, 2.0]);
        assert_eq!(flip_3d(&w3).data(), &[2.0, 1.0]);
    }
}
