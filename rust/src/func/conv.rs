//! Dense convolution (cross-correlation, CNN convention), stride 1.
//!
//! Used by the OOM deconvolution formulation (over the zero-inserted,
//! border-padded map) and by the CPU baseline. The loop nests live in
//! [`super::uniform`]; the 2D entry points are depth-1 folds.

use crate::tensor::{FeatureMap, Volume, WeightsOIDHW, WeightsOIHW};

use super::uniform;

/// `out[o][y][x] = Σ_i Σ_kh Σ_kw in[i][y+kh][x+kw] · w[o][i][kh][kw]`
/// ("VALID" correlation, stride 1) — the depth-1 fold of
/// [`uniform::corr`].
pub fn corr2d(input: &FeatureMap<f32>, w: &WeightsOIHW<f32>) -> FeatureMap<f32> {
    uniform::corr(&input.to_volume(), &w.to_oidhw()).into_feature_map()
}

/// 3D VALID correlation, stride 1.
pub fn corr3d(input: &Volume<f32>, w: &WeightsOIDHW<f32>) -> Volume<f32> {
    uniform::corr(input, w)
}

/// Spatially flip a 2D kernel (for true convolution vs correlation).
pub fn flip_2d(w: &WeightsOIHW<f32>) -> WeightsOIHW<f32> {
    uniform::flip(&w.to_oidhw()).into_oihw()
}

/// Spatially flip a 3D kernel.
pub fn flip_3d(w: &WeightsOIDHW<f32>) -> WeightsOIDHW<f32> {
    uniform::flip(w)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corr2d_identity_kernel() {
        // 1x1 kernel of value 2 doubles the map
        let input = FeatureMap::from_vec(1, 2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let w = WeightsOIHW::from_vec(1, 1, 1, 1, vec![2.0]);
        let out = corr2d(&input, &w);
        assert_eq!(out.data(), &[2.0, 4.0, 6.0, 8.0]);
    }

    #[test]
    fn corr2d_known_values() {
        // 3x3 input, 2x2 ones kernel -> 2x2 output of window sums
        let input = FeatureMap::from_vec(1, 3, 3, (1..=9).map(|x| x as f32).collect());
        let w = WeightsOIHW::from_vec(1, 1, 2, 2, vec![1.0; 4]);
        let out = corr2d(&input, &w);
        assert_eq!((out.h, out.w), (2, 2));
        assert_eq!(out.data(), &[12.0, 16.0, 24.0, 28.0]);
    }

    #[test]
    fn corr2d_sums_channels() {
        let input = FeatureMap::from_vec(2, 1, 1, vec![3.0, 4.0]);
        let w = WeightsOIHW::from_vec(1, 2, 1, 1, vec![1.0, 10.0]);
        let out = corr2d(&input, &w);
        assert_eq!(out.data(), &[43.0]);
    }

    #[test]
    fn corr3d_window_sum() {
        let input = Volume::from_vec(1, 2, 2, 2, (1..=8).map(|x| x as f32).collect());
        let w = WeightsOIDHW::from_vec(1, 1, 2, 2, 2, vec![1.0; 8]);
        let out = corr3d(&input, &w);
        assert_eq!((out.d, out.h, out.w), (1, 1, 1));
        assert_eq!(out.data(), &[36.0]);
    }

    #[test]
    fn flip_round_trips() {
        let w = WeightsOIHW::from_vec(1, 1, 2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let f = flip_2d(&w);
        assert_eq!(f.data(), &[4.0, 3.0, 2.0, 1.0]);
        assert_eq!(flip_2d(&f).data(), w.data());
        let w3 = WeightsOIDHW::from_vec(1, 1, 2, 1, 1, vec![1.0, 2.0]);
        assert_eq!(flip_3d(&w3).data(), &[2.0, 1.0]);
    }
}
