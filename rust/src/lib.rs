//! # udcnn — a uniform 2D/3D deconvolutional-network accelerator stack
//!
//! Reproduction of *"Towards a Uniform Architecture for the Efficient
//! Implementation of 2D and 3D Deconvolutional Neural Networks on FPGAs"*
//! (Wang, Shen, Wen, Zhang — NUDT, 2019).
//!
//! The crate is organised bottom-up:
//!
//! * [`fixed`] — Q8.8 16-bit fixed-point arithmetic (the accelerator's
//!   datapath numeric format).
//! * [`tensor`] — a small dense tensor library (1–5 dimensional) used by
//!   the golden models, the simulator and the baselines.
//! * [`dcnn`] — layer geometry, the four benchmark networks (DCGAN,
//!   GP-GAN, 3D-GAN, V-Net decoder) and the sparsity analyzer (Fig. 1).
//! * [`func`] — functional golden models of deconvolution: the OOM
//!   formulation (zero-insertion + dense convolution, the paper's
//!   baseline) and the IOM formulation (scatter-accumulate, the paper's
//!   contribution), in both `f32` and Q8.8. All loop nests live once in
//!   [`func::uniform`] — the dimension-uniform kernel core (§IV-C): 2D
//!   runs as the depth-1 fold of the 3D kernel, bit-exactly, with
//!   threaded variants for the serving hot path.
//! * [`accel`] — the paper's system contribution: a cycle-level simulator
//!   of the uniform PE-mesh architecture of Fig. 2 (PEs with overlap
//!   FIFOs, weight shift chain, adder trees, triple on-chip buffers,
//!   DDR memory controller), the 3D-IOM dataflow of Fig. 4/5, the
//!   blocking scheduler, and the design-space explorer behind Table II.
//! * [`graph`] — the whole-network graph IR and compiler: ops over
//!   explicit tensor edges, a pass pipeline (shape inference, OOM→IOM
//!   lowering, activation fusion), and [`graph::NetworkPlan`]s with
//!   inter-layer on-chip buffer reuse, executed end-to-end by
//!   [`graph::simulate_plan`] / [`accel::simulate_network_pipelined`].
//! * [`resource`] — the VC709 resource model behind Table III.
//! * [`energy`] — the energy model behind Fig. 7(b).
//! * [`baseline`] — CPU (measured, multithreaded) and GPU (analytic
//!   GTX 1080 model) comparison points for Fig. 7.
//! * [`runtime`] — PJRT client wrapper: loads the AOT-compiled HLO text
//!   artifacts produced by `python/compile/aot.py` and executes them.
//! * [`coordinator`] — the L3 service face: a batched inference service
//!   that routes deconvolution requests onto accelerator instances.
//! * [`serve`] — the fleet tier: N simulated accelerator instances
//!   behind one front door, with a shared compiled-plan cache,
//!   least-loaded shard scheduling, latency-budget admission control,
//!   and a deterministic open-loop load generator / latency harness.
//! * [`stream`] — streaming temporal-tiled 3D inference: depth-chunked
//!   sessions with per-layer halo state, bit-exact against the
//!   whole-volume forward for every chunking, in bounded memory;
//!   streaming jobs ride the fleet via chunk-shaped compiled plans.
//! * [`obs`] — the deterministic tracing + metrics spine: one
//!   [`obs::Recorder`] threaded through compile/serve/stream, emitting
//!   Perfetto-loadable Chrome trace-event JSON and flat metrics
//!   snapshots; same seed + config ⇒ byte-identical traces.
//! * [`report`] — paper-style table/figure text rendering.
//! * [`benchkit`] — a minimal statistics-aware benchmark harness (the
//!   build environment is fully offline and has no criterion crate; see
//!   DESIGN.md §1 for the substitution table).
//! * [`propcheck`] — a minimal property-based testing framework with
//!   seeded generators and shrinking (offline substitute for proptest).
//!
//! ## Quickstart
//!
//! ```no_run
//! use udcnn::dcnn::zoo;
//! use udcnn::accel::{AccelConfig, simulate_layer};
//!
//! let net = zoo::dcgan();
//! let cfg = AccelConfig::paper_2d();
//! for layer in &net.layers {
//!     let m = simulate_layer(&cfg, layer);
//!     println!("{}: util={:.1}% tops={:.2}", layer.name, 100.0 * m.pe_utilization(), m.effective_tops(&cfg));
//! }
//! ```

#![warn(missing_docs)]

pub mod cli;
pub mod util;
pub mod fixed;
pub mod tensor;
pub mod dcnn;
pub mod func;
pub mod accel;
pub mod graph;
pub mod resource;
pub mod energy;
pub mod baseline;
pub mod runtime;
pub mod coordinator;
pub mod serve;
pub mod stream;
pub mod obs;
pub mod report;
pub mod benchkit;
pub mod propcheck;

pub use accel::{AccelConfig, simulate_layer};
pub use dcnn::{LayerSpec, Network};
