//! Tiny statistics helpers shared by benchkit and the report module.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation (0.0 for len < 2).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64;
    var.sqrt()
}

/// p-th percentile (0..=100) by linear interpolation on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s: Vec<f64> = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        let frac = rank - lo as f64;
        s[lo] * (1.0 - frac) + s[hi] * frac
    }
}

/// Median.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Geometric mean of positive values.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let log_sum: f64 = xs.iter().map(|x| x.max(f64::MIN_POSITIVE).ln()).sum();
    (log_sum / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        // sample std of this classic set is ~2.138
        assert!((std_dev(&xs) - 2.13809).abs() < 1e-4);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(median(&xs), 3.0);
        assert!((percentile(&xs, 25.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_basic() {
        let xs = [1.0, 4.0, 16.0];
        assert!((geomean(&xs) - 4.0).abs() < 1e-9);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[]), 0.0);
        assert_eq!(std_dev(&[1.0]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }
}
