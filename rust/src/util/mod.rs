//! Small shared utilities: deterministic PRNG, math helpers, timing.

pub mod prng;
pub mod stats;

pub use prng::Prng;

/// Integer ceiling division.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

/// `ceil(log2(n))` for n >= 1; 0 for n <= 1.
#[inline]
pub fn ceil_log2(n: usize) -> u32 {
    if n <= 1 {
        0
    } else {
        usize::BITS - (n - 1).leading_zeros()
    }
}

/// Round `v` up to the next multiple of `m`.
#[inline]
pub fn round_up(v: usize, m: usize) -> usize {
    ceil_div(v, m) * m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basic() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
        assert_eq!(ceil_div(8, 4), 2);
        assert_eq!(ceil_div(9, 4), 3);
    }

    #[test]
    fn ceil_log2_basic() {
        assert_eq!(ceil_log2(0), 0);
        assert_eq!(ceil_log2(1), 0);
        assert_eq!(ceil_log2(2), 1);
        assert_eq!(ceil_log2(3), 2);
        assert_eq!(ceil_log2(4), 2);
        assert_eq!(ceil_log2(5), 3);
        assert_eq!(ceil_log2(16), 4);
        assert_eq!(ceil_log2(17), 5);
        assert_eq!(ceil_log2(64), 6);
    }

    #[test]
    fn round_up_basic() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
    }
}
