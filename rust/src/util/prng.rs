//! Deterministic PRNG (xoshiro256**), used everywhere randomness is
//! needed: synthetic weights/activations, property-test generators,
//! workload generation. Seeded, reproducible, no external crates.

/// xoshiro256** 1.0 by Blackman & Vigna (public domain reference impl).
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
}

impl Prng {
    /// Create a generator from a 64-bit seed via SplitMix64 expansion.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next_sm(), next_sm(), next_sm(), next_sm()];
        // Avoid the all-zero state (cannot happen from SplitMix64, but be safe).
        let s = if s == [0, 0, 0, 0] { [1, 2, 3, 4] } else { s };
        Prng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform u32.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)` (n > 0), unbiased via Lemire's method.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform in `[lo, hi]` inclusive.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.f64() as f32) * (hi - lo)
    }

    /// Standard normal via Box–Muller (one value per call; simple, fine
    /// for synthetic weight generation).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn coin(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fill a slice with uniform f32 in [lo, hi).
    pub fn fill_f32(&mut self, buf: &mut [f32], lo: f32, hi: f32) {
        for v in buf {
            *v = self.f32_range(lo, hi);
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Prng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Prng::new(3);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Prng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.08, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Prng::new(5);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
