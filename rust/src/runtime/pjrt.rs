//! Thin wrapper over the `xla` crate's PJRT CPU client.

use std::path::Path;

use anyhow::{Context, Result};

/// A PJRT client (CPU plugin).
pub struct Runtime {
    client: xla::PjRtClient,
}

/// A compiled executable plus its input arity.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// Artifact name (file stem).
    pub name: String,
}

impl Runtime {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    /// PJRT platform name (e.g. `"cpu"`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Number of PJRT devices.
    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load an HLO **text** artifact and compile it.
    pub fn load_hlo_text(&self, path: impl AsRef<Path>) -> Result<Executable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable {
            exe,
            name: path
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
        })
    }
}

impl Executable {
    /// Execute with f32 inputs (`(data, dims)` pairs); returns all f32
    /// outputs. The AOT pipeline lowers with `return_tuple=True`, so a
    /// single logical output still arrives as a 1-tuple.
    pub fn run_f32(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, dims)| {
                let lit = xla::Literal::vec1(data);
                lit.reshape(dims)
                    .with_context(|| format!("reshaping input to {dims:?}"))
            })
            .collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .context("executing")?;
        let out = result[0][0]
            .to_literal_sync()
            .context("fetching result")?;
        let parts = out.to_tuple().context("untupling result")?;
        parts
            .iter()
            .map(|p| p.to_vec::<f32>().context("reading f32 output"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    /// Minimal HLO text module: f32[2,2] add.
    const ADD_HLO: &str = r#"HloModule add_test

ENTRY main {
  x = f32[2,2]{1,0} parameter(0)
  y = f32[2,2]{1,0} parameter(1)
  s = f32[2,2]{1,0} add(x, y)
  ROOT t = (f32[2,2]{1,0}) tuple(s)
}
"#;

    #[test]
    fn cpu_client_comes_up() {
        let rt = Runtime::cpu().expect("PJRT CPU client");
        assert!(rt.device_count() >= 1);
        assert!(!rt.platform().is_empty());
    }

    #[test]
    fn load_and_run_hlo_text() {
        let dir = std::env::temp_dir();
        let path = dir.join("udcnn_add_test.hlo.txt");
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(ADD_HLO.as_bytes()).unwrap();
        drop(f);

        let rt = Runtime::cpu().unwrap();
        let exe = rt.load_hlo_text(&path).unwrap();
        let x = [1.0f32, 2.0, 3.0, 4.0];
        let y = [10.0f32, 20.0, 30.0, 40.0];
        let out = exe.run_f32(&[(&x, &[2, 2]), (&y, &[2, 2])]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0], vec![11.0, 22.0, 33.0, 44.0]);
    }

    #[test]
    fn missing_artifact_is_a_clean_error() {
        let rt = Runtime::cpu().unwrap();
        let err = rt.load_hlo_text("/nonexistent/foo.hlo.txt");
        assert!(err.is_err());
    }
}
