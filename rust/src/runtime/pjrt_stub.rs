//! Stub PJRT runtime, compiled when the `pjrt` cargo feature is off.
//!
//! The real [`super::pjrt`] implementation wraps the external `xla`
//! crate, which the offline build environment does not ship. This stub
//! keeps the exact same API surface so every caller compiles; any
//! attempt to actually create a client reports a clear error instead.

use std::path::Path;

use anyhow::{bail, Result};

const UNAVAILABLE: &str =
    "PJRT support not built: rebuild with `--features pjrt` (requires the external `xla` crate)";

/// Stub stand-in for the PJRT CPU client.
pub struct Runtime {
    _private: (),
}

/// Stub stand-in for a compiled executable.
pub struct Executable {
    /// Artifact name (file stem).
    pub name: String,
}

impl Runtime {
    /// Always fails: the `xla` crate is not available in this build.
    pub fn cpu() -> Result<Runtime> {
        bail!("{UNAVAILABLE}")
    }

    /// Stub platform name (`"stub"`).
    pub fn platform(&self) -> String {
        "stub".to_string()
    }

    /// Always 0: the stub has no devices.
    pub fn device_count(&self) -> usize {
        0
    }

    /// Always fails: the `xla` crate is not available in this build.
    pub fn load_hlo_text(&self, _path: impl AsRef<Path>) -> Result<Executable> {
        bail!("{UNAVAILABLE}")
    }
}

impl Executable {
    /// Always fails: the `xla` crate is not available in this build.
    pub fn run_f32(&self, _inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
        bail!("{UNAVAILABLE}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_clean_error() {
        let err = Runtime::cpu().err().expect("stub cannot construct");
        assert!(err.to_string().contains("pjrt"), "{err:#}");
    }
}
