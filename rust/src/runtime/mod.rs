//! PJRT runtime: load the AOT-compiled HLO text artifacts produced by
//! `python/compile/aot.py` and execute them from the request path.
//!
//! Python never runs at inference time — `make artifacts` is the only
//! step that invokes it. Interchange is **HLO text** (not serialized
//! `HloModuleProto`): jax ≥ 0.5 emits 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids.

pub mod artifacts;

/// Real PJRT wrapper (needs the external `xla` crate, `pjrt` feature).
#[cfg(feature = "pjrt")]
pub mod pjrt;
/// Offline stub with the same API (default build).
#[cfg(not(feature = "pjrt"))]
#[path = "pjrt_stub.rs"]
pub mod pjrt;

pub use artifacts::{ArtifactSet, ARTIFACTS_DIR_ENV};
pub use pjrt::{Executable, Runtime};
