//! Artifact registry: discovers `artifacts/*.hlo.txt` produced by
//! `make artifacts` and maps benchmark names to executables.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

/// Environment variable overriding the artifacts directory.
pub const ARTIFACTS_DIR_ENV: &str = "UDCNN_ARTIFACTS";

/// The set of compiled-model artifacts on disk.
#[derive(Clone, Debug, Default)]
pub struct ArtifactSet {
    /// Directory the artifacts were discovered in.
    pub dir: PathBuf,
    /// artifact name (file stem, e.g. `dcgan`) → path
    pub entries: BTreeMap<String, PathBuf>,
}

impl ArtifactSet {
    /// Default directory: `$UDCNN_ARTIFACTS`, else `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os(ARTIFACTS_DIR_ENV)
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// Scan a directory for `*.hlo.txt`.
    pub fn discover(dir: impl AsRef<Path>) -> Result<ArtifactSet> {
        let dir = dir.as_ref().to_path_buf();
        let mut entries = BTreeMap::new();
        if !dir.exists() {
            bail!(
                "artifact directory {} does not exist — run `make artifacts` first",
                dir.display()
            );
        }
        for e in std::fs::read_dir(&dir)? {
            let p = e?.path();
            let name = p.file_name().map(|s| s.to_string_lossy().into_owned());
            if let Some(name) = name {
                if let Some(stem) = name.strip_suffix(".hlo.txt") {
                    entries.insert(stem.to_string(), p.clone());
                }
            }
        }
        Ok(ArtifactSet { dir, entries })
    }

    /// Discover from the default directory.
    pub fn discover_default() -> Result<ArtifactSet> {
        Self::discover(Self::default_dir())
    }

    /// Path of the artifact named `name`, if present.
    pub fn get(&self, name: &str) -> Option<&PathBuf> {
        self.entries.get(name)
    }

    /// Sorted artifact names.
    pub fn names(&self) -> Vec<&str> {
        self.entries.keys().map(|s| s.as_str()).collect()
    }

    /// Whether no artifacts were found.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn mk_dir_with(names: &[&str]) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "udcnn_artifacts_test_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        for n in names {
            let mut f = std::fs::File::create(dir.join(n)).unwrap();
            f.write_all(b"HloModule x").unwrap();
        }
        dir
    }

    #[test]
    fn discovers_hlo_text_only() {
        let dir = mk_dir_with(&["dcgan.hlo.txt", "notes.md", "vnet.hlo.txt"]);
        let set = ArtifactSet::discover(&dir).unwrap();
        assert_eq!(set.names(), vec!["dcgan", "vnet"]);
        assert!(set.get("dcgan").is_some());
        assert!(set.get("notes").is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_dir_errors_with_hint() {
        let err = ArtifactSet::discover("/definitely/not/here").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
