//! A small dense tensor library.
//!
//! Shapes are modelled explicitly for the two layouts the paper uses:
//! `CHW` feature maps (2D nets) and `CDHW` volumes (3D nets), plus the
//! weight layouts `OIHW` / `OIDHW`. Everything is row-major contiguous.
//! The generic [`Tensor`] carries a dynamic shape; typed views give
//! bounds-checked (debug) / unchecked (release) indexing on the hot
//! paths of the golden models and baselines.

mod dense;
mod feature_map;

pub use dense::Tensor;
pub use feature_map::{FeatureMap, Volume, WeightsOIHW, WeightsOIDHW};
