//! A small dense tensor library.
//!
//! Shapes are modelled explicitly for the two layouts the paper uses:
//! `CHW` feature maps (2D nets) and `CDHW` volumes (3D nets), plus the
//! weight layouts `OIHW` / `OIDHW`. Everything is row-major contiguous.
//! The generic [`Tensor`] carries a dynamic shape; typed views give
//! bounds-checked (debug) / unchecked (release) indexing on the hot
//! paths of the golden models and baselines.
//!
//! [`Volume`] / [`WeightsOIDHW`] double as the *uniform* activation and
//! weight representation of `func::uniform` (§IV-C): a 2D tensor is the
//! depth-1 fold (`d = 1`, `kd = 1`), reached zero-copy via
//! `FeatureMap::into_volume` / `Volume::into_feature_map` and the
//! matching weight conversions.

mod dense;
mod feature_map;

pub use dense::Tensor;
pub use feature_map::{FeatureMap, Volume, WeightsOIHW, WeightsOIDHW};
