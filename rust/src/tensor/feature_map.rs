//! Typed feature-map / volume / weight wrappers.
//!
//! These give the golden models and baselines fast, self-documenting
//! indexing: `fm.at(c, h, w)` instead of `t.get(&[c, h, w])` (the
//! generic path allocates index slices on the caller side and
//! re-derives strides per access; these wrappers precompute strides).

use super::Tensor;

/// 2D feature map, layout `C × H × W`.
#[derive(Clone, Debug, PartialEq)]
pub struct FeatureMap<T> {
    /// Channels.
    pub c: usize,
    /// Height.
    pub h: usize,
    /// Width.
    pub w: usize,
    data: Vec<T>,
}

impl<T: Copy + Default> FeatureMap<T> {
    /// Zero-filled map.
    pub fn zeros(c: usize, h: usize, w: usize) -> Self {
        FeatureMap {
            c,
            h,
            w,
            data: vec![T::default(); c * h * w],
        }
    }

    /// Build from a flat `C·H·W` buffer.
    pub fn from_vec(c: usize, h: usize, w: usize, data: Vec<T>) -> Self {
        assert_eq!(data.len(), c * h * w);
        FeatureMap { c, h, w, data }
    }

    #[inline(always)]
    /// Read the element at `(c, h, w)`.
    pub fn at(&self, c: usize, h: usize, w: usize) -> T {
        debug_assert!(c < self.c && h < self.h && w < self.w);
        self.data[(c * self.h + h) * self.w + w]
    }

    #[inline(always)]
    /// Mutable access to the element at `(c, h, w)`.
    pub fn at_mut(&mut self, c: usize, h: usize, w: usize) -> &mut T {
        debug_assert!(c < self.c && h < self.h && w < self.w);
        &mut self.data[(c * self.h + h) * self.w + w]
    }

    /// Contiguous channel plane.
    #[inline]
    pub fn plane(&self, c: usize) -> &[T] {
        let sz = self.h * self.w;
        &self.data[c * sz..(c + 1) * sz]
    }

    #[inline]
    /// Flat data, `C × H × W` row-major.
    pub fn data(&self) -> &[T] {
        &self.data
    }

    #[inline]
    /// Mutable flat data.
    pub fn data_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    /// Consume into a dynamic-shape [`Tensor`].
    pub fn into_tensor(self) -> Tensor<T> {
        Tensor::from_vec(&[self.c, self.h, self.w], self.data)
    }

    /// Copy into the uniform depth-1 volume `(c, 1, h, w)` — the
    /// §IV-C fold the [`crate::func::uniform`] kernels consume.
    pub fn to_volume(&self) -> Volume<T> {
        Volume::from_vec(self.c, 1, self.h, self.w, self.data.clone())
    }

    /// Consume into the uniform depth-1 volume (zero-copy).
    pub fn into_volume(self) -> Volume<T> {
        Volume::from_vec(self.c, 1, self.h, self.w, self.data)
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// Whether the map holds no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// 3D feature volume, layout `C × D × H × W`.
#[derive(Clone, Debug, PartialEq)]
pub struct Volume<T> {
    /// Channels.
    pub c: usize,
    /// Depth.
    pub d: usize,
    /// Height.
    pub h: usize,
    /// Width.
    pub w: usize,
    data: Vec<T>,
}

impl<T: Copy + Default> Volume<T> {
    /// Zero-filled volume.
    pub fn zeros(c: usize, d: usize, h: usize, w: usize) -> Self {
        Volume {
            c,
            d,
            h,
            w,
            data: vec![T::default(); c * d * h * w],
        }
    }

    /// Build from a flat `C·D·H·W` buffer.
    pub fn from_vec(c: usize, d: usize, h: usize, w: usize, data: Vec<T>) -> Self {
        assert_eq!(data.len(), c * d * h * w);
        Volume { c, d, h, w, data }
    }

    #[inline(always)]
    /// Read the element at `(c, d, h, w)`.
    pub fn at(&self, c: usize, d: usize, h: usize, w: usize) -> T {
        debug_assert!(c < self.c && d < self.d && h < self.h && w < self.w);
        self.data[((c * self.d + d) * self.h + h) * self.w + w]
    }

    #[inline(always)]
    /// Mutable access to the element at `(c, d, h, w)`.
    pub fn at_mut(&mut self, c: usize, d: usize, h: usize, w: usize) -> &mut T {
        debug_assert!(c < self.c && d < self.d && h < self.h && w < self.w);
        &mut self.data[((c * self.d + d) * self.h + h) * self.w + w]
    }

    /// Contiguous row `(c, d, h, ·)` — what the uniform IOM scatter
    /// streams.
    #[inline]
    pub fn row(&self, c: usize, d: usize, h: usize) -> &[T] {
        debug_assert!(c < self.c && d < self.d && h < self.h);
        let base = ((c * self.d + d) * self.h + h) * self.w;
        &self.data[base..base + self.w]
    }

    /// A new volume holding `self`'s depth frames followed by
    /// `other`'s — the temporal-tile concatenation the streaming tier
    /// uses to prepend retained halo frames to an arriving chunk.
    /// Panics unless channels, height and width match. Either operand
    /// may be depth-0 (an empty halo).
    pub fn concat_depth(&self, other: &Volume<T>) -> Volume<T> {
        assert_eq!(
            (self.c, self.h, self.w),
            (other.c, other.h, other.w),
            "concat_depth shape mismatch"
        );
        let plane = self.h * self.w;
        let d = self.d + other.d;
        let mut out = Volume::zeros(self.c, d, self.h, self.w);
        for c in 0..self.c {
            let dst = c * d * plane;
            out.data[dst..dst + self.d * plane]
                .copy_from_slice(&self.data[c * self.d * plane..(c + 1) * self.d * plane]);
            out.data[dst + self.d * plane..dst + d * plane]
                .copy_from_slice(&other.data[c * other.d * plane..(c + 1) * other.d * plane]);
        }
        out
    }

    /// Copy depth frames `[lo, lo + len)` of every channel into a new
    /// volume — the halo-retention slice of the streaming tier (and
    /// the per-chunk input slice of its drivers). `len` may be 0.
    pub fn slice_depth(&self, lo: usize, len: usize) -> Volume<T> {
        assert!(lo + len <= self.d, "slice_depth out of range");
        let plane = self.h * self.w;
        let mut out = Volume::zeros(self.c, len, self.h, self.w);
        for c in 0..self.c {
            let src = (c * self.d + lo) * plane;
            let dst = c * len * plane;
            out.data[dst..dst + len * plane].copy_from_slice(&self.data[src..src + len * plane]);
        }
        out
    }

    /// Consume a depth-1 volume into its 2D [`FeatureMap`] view
    /// (zero-copy). Panics unless `d == 1`.
    pub fn into_feature_map(self) -> FeatureMap<T> {
        assert_eq!(self.d, 1, "into_feature_map requires a depth-1 volume");
        FeatureMap::from_vec(self.c, self.h, self.w, self.data)
    }

    #[inline]
    /// Flat data, `C × D × H × W` row-major.
    pub fn data(&self) -> &[T] {
        &self.data
    }

    #[inline]
    /// Mutable flat data.
    pub fn data_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    /// Consume into a dynamic-shape [`Tensor`].
    pub fn into_tensor(self) -> Tensor<T> {
        Tensor::from_vec(&[self.c, self.d, self.h, self.w], self.data)
    }

    /// Consume into the raw `C × D × H × W` row-major buffer
    /// (zero-copy) — how volumes return to the scratch pool in
    /// `func::workspace`.
    pub fn into_vec(self) -> Vec<T> {
        self.data
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// Whether the volume holds no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// 2D weights, layout `O × I × Kh × Kw`.
#[derive(Clone, Debug, PartialEq)]
pub struct WeightsOIHW<T> {
    /// Output channels.
    pub o: usize,
    /// Input channels.
    pub i: usize,
    /// Kernel height.
    pub kh: usize,
    /// Kernel width.
    pub kw: usize,
    data: Vec<T>,
}

impl<T: Copy + Default> WeightsOIHW<T> {
    /// Zero-filled weights.
    pub fn zeros(o: usize, i: usize, kh: usize, kw: usize) -> Self {
        WeightsOIHW {
            o,
            i,
            kh,
            kw,
            data: vec![T::default(); o * i * kh * kw],
        }
    }

    /// Build from a flat `O·I·Kh·Kw` buffer.
    pub fn from_vec(o: usize, i: usize, kh: usize, kw: usize, data: Vec<T>) -> Self {
        assert_eq!(data.len(), o * i * kh * kw);
        WeightsOIHW { o, i, kh, kw, data }
    }

    #[inline(always)]
    /// Read the weight at `(o, i, kh, kw)`.
    pub fn at(&self, o: usize, i: usize, kh: usize, kw: usize) -> T {
        debug_assert!(o < self.o && i < self.i && kh < self.kh && kw < self.kw);
        self.data[((o * self.i + i) * self.kh + kh) * self.kw + kw]
    }

    #[inline(always)]
    /// Mutable access to the weight at `(o, i, kh, kw)`.
    pub fn at_mut(&mut self, o: usize, i: usize, kh: usize, kw: usize) -> &mut T {
        &mut self.data[((o * self.i + i) * self.kh + kh) * self.kw + kw]
    }

    /// Contiguous `Kh × Kw` kernel for one (o, i) pair — what a PE's Rw
    /// register file holds.
    #[inline]
    pub fn kernel(&self, o: usize, i: usize) -> &[T] {
        let sz = self.kh * self.kw;
        let base = (o * self.i + i) * sz;
        &self.data[base..base + sz]
    }

    /// Copy into the uniform `O × I × 1 × Kh × Kw` weight layout (the
    /// depth-1 kernel fold).
    pub fn to_oidhw(&self) -> WeightsOIDHW<T> {
        WeightsOIDHW::from_vec(self.o, self.i, 1, self.kh, self.kw, self.data.clone())
    }

    /// Consume into the uniform depth-1 weight layout (zero-copy).
    pub fn into_oidhw(self) -> WeightsOIDHW<T> {
        WeightsOIDHW::from_vec(self.o, self.i, 1, self.kh, self.kw, self.data)
    }

    #[inline]
    /// Flat data, `O × I × Kh × Kw` row-major.
    pub fn data(&self) -> &[T] {
        &self.data
    }

    #[inline]
    /// Mutable flat data.
    pub fn data_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// Whether there are no weights.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// 3D weights, layout `O × I × Kd × Kh × Kw`.
#[derive(Clone, Debug, PartialEq)]
pub struct WeightsOIDHW<T> {
    /// Output channels.
    pub o: usize,
    /// Input channels.
    pub i: usize,
    /// Kernel depth.
    pub kd: usize,
    /// Kernel height.
    pub kh: usize,
    /// Kernel width.
    pub kw: usize,
    data: Vec<T>,
}

impl<T: Copy + Default> WeightsOIDHW<T> {
    /// Zero-filled weights.
    pub fn zeros(o: usize, i: usize, kd: usize, kh: usize, kw: usize) -> Self {
        WeightsOIDHW {
            o,
            i,
            kd,
            kh,
            kw,
            data: vec![T::default(); o * i * kd * kh * kw],
        }
    }

    /// Build from a flat `O·I·Kd·Kh·Kw` buffer.
    pub fn from_vec(o: usize, i: usize, kd: usize, kh: usize, kw: usize, data: Vec<T>) -> Self {
        assert_eq!(data.len(), o * i * kd * kh * kw);
        WeightsOIDHW {
            o,
            i,
            kd,
            kh,
            kw,
            data,
        }
    }

    #[inline(always)]
    /// Read the weight at `(o, i, kd, kh, kw)`.
    pub fn at(&self, o: usize, i: usize, kd: usize, kh: usize, kw: usize) -> T {
        debug_assert!(
            o < self.o && i < self.i && kd < self.kd && kh < self.kh && kw < self.kw
        );
        self.data[(((o * self.i + i) * self.kd + kd) * self.kh + kh) * self.kw + kw]
    }

    #[inline(always)]
    /// Mutable access to the weight at `(o, i, kd, kh, kw)`.
    pub fn at_mut(&mut self, o: usize, i: usize, kd: usize, kh: usize, kw: usize) -> &mut T {
        &mut self.data[(((o * self.i + i) * self.kd + kd) * self.kh + kh) * self.kw + kw]
    }

    /// Contiguous `Kd × Kh × Kw` kernel for one (o, i) pair.
    #[inline]
    pub fn kernel(&self, o: usize, i: usize) -> &[T] {
        let sz = self.kd * self.kh * self.kw;
        let base = (o * self.i + i) * sz;
        &self.data[base..base + sz]
    }

    /// Consume depth-1 weights into their 2D `OIHW` view (zero-copy).
    /// Panics unless `kd == 1`.
    pub fn into_oihw(self) -> WeightsOIHW<T> {
        assert_eq!(self.kd, 1, "into_oihw requires a depth-1 kernel");
        WeightsOIHW::from_vec(self.o, self.i, self.kh, self.kw, self.data)
    }

    #[inline]
    /// Flat data, `O × I × Kd × Kh × Kw` row-major.
    pub fn data(&self) -> &[T] {
        &self.data
    }

    #[inline]
    /// Mutable flat data.
    pub fn data_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// Whether there are no weights.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn feature_map_strides() {
        let mut fm: FeatureMap<f32> = FeatureMap::zeros(2, 3, 4);
        *fm.at_mut(1, 2, 3) = 9.0;
        assert_eq!(fm.at(1, 2, 3), 9.0);
        assert_eq!(fm.data()[1 * 12 + 2 * 4 + 3], 9.0);
        assert_eq!(fm.plane(1).len(), 12);
        assert_eq!(fm.plane(1)[11], 9.0);
    }

    #[test]
    fn volume_strides() {
        let mut v: Volume<f32> = Volume::zeros(2, 3, 4, 5);
        *v.at_mut(1, 2, 3, 4) = 7.0;
        assert_eq!(v.at(1, 2, 3, 4), 7.0);
        assert_eq!(v.data()[((1 * 3 + 2) * 4 + 3) * 5 + 4], 7.0);
    }

    #[test]
    fn weights_kernel_slice() {
        let mut w: WeightsOIHW<f32> = WeightsOIHW::zeros(2, 3, 3, 3);
        *w.at_mut(1, 2, 0, 0) = 1.5;
        let k = w.kernel(1, 2);
        assert_eq!(k.len(), 9);
        assert_eq!(k[0], 1.5);
    }

    #[test]
    fn weights3d_kernel_slice() {
        let mut w: WeightsOIDHW<f32> = WeightsOIDHW::zeros(2, 2, 3, 3, 3);
        *w.at_mut(1, 1, 2, 2, 2) = 4.0;
        let k = w.kernel(1, 1);
        assert_eq!(k.len(), 27);
        assert_eq!(k[26], 4.0);
    }

    #[test]
    fn uniform_fold_round_trips() {
        let fm = FeatureMap::from_vec(2, 3, 4, (0..24).map(|x| x as f32).collect());
        let vol = fm.to_volume();
        assert_eq!((vol.c, vol.d, vol.h, vol.w), (2, 1, 3, 4));
        assert_eq!(vol.at(0, 0, 2, 3), fm.at(0, 2, 3));
        assert_eq!(vol.row(1, 0, 1), &fm.plane(1)[4..8]);
        assert_eq!(vol.into_feature_map(), fm);
        assert_eq!(fm.clone().into_volume().into_feature_map(), fm);

        let w = WeightsOIHW::from_vec(2, 2, 3, 3, (0..36).map(|x| x as f32).collect());
        let w3 = w.to_oidhw();
        assert_eq!((w3.o, w3.i, w3.kd, w3.kh, w3.kw), (2, 2, 1, 3, 3));
        assert_eq!(w3.at(1, 0, 0, 2, 2), w.at(1, 0, 2, 2));
        assert_eq!(w3.kernel(1, 1), w.kernel(1, 1));
        assert_eq!(w3.into_oihw(), w);
    }

    #[test]
    fn concat_and_slice_depth_round_trip() {
        let v = Volume::from_vec(2, 3, 2, 2, (0..24).map(|x| x as f32).collect());
        let a = v.slice_depth(0, 1);
        let b = v.slice_depth(1, 2);
        assert_eq!((a.c, a.d, a.h, a.w), (2, 1, 2, 2));
        assert_eq!((b.c, b.d, b.h, b.w), (2, 2, 2, 2));
        assert_eq!(a.at(1, 0, 1, 1), v.at(1, 0, 1, 1));
        assert_eq!(b.at(1, 1, 0, 1), v.at(1, 2, 0, 1));
        let back = a.concat_depth(&b);
        assert_eq!(back.data(), v.data());
        // empty halos on either side are identities
        let empty: Volume<f32> = Volume::zeros(2, 0, 2, 2);
        assert_eq!(empty.concat_depth(&v).data(), v.data());
        assert_eq!(v.concat_depth(&empty).data(), v.data());
        assert_eq!(v.slice_depth(3, 0).len(), 0);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn slice_depth_rejects_overrun() {
        let v: Volume<f32> = Volume::zeros(1, 2, 2, 2);
        let _ = v.slice_depth(1, 2);
    }

    #[test]
    #[should_panic(expected = "depth-1")]
    fn deep_volume_rejects_2d_view() {
        let v: Volume<f32> = Volume::zeros(1, 2, 2, 2);
        let _ = v.into_feature_map();
    }

    #[test]
    fn tensor_round_trip() {
        let fm = FeatureMap::from_vec(1, 2, 2, vec![1.0f32, 2.0, 3.0, 4.0]);
        let t = fm.into_tensor();
        assert_eq!(t.shape(), &[1, 2, 2]);
        assert_eq!(t.get(&[0, 1, 1]), 4.0);
    }
}
