//! Typed feature-map / volume / weight wrappers.
//!
//! These give the golden models and baselines fast, self-documenting
//! indexing: `fm.at(c, h, w)` instead of `t.get(&[c, h, w])` (the
//! generic path allocates index slices on the caller side and
//! re-derives strides per access; these wrappers precompute strides).

use super::Tensor;

/// 2D feature map, layout `C × H × W`.
#[derive(Clone, Debug, PartialEq)]
pub struct FeatureMap<T> {
    /// Channels.
    pub c: usize,
    /// Height.
    pub h: usize,
    /// Width.
    pub w: usize,
    data: Vec<T>,
}

impl<T: Copy + Default> FeatureMap<T> {
    /// Zero-filled map.
    pub fn zeros(c: usize, h: usize, w: usize) -> Self {
        FeatureMap {
            c,
            h,
            w,
            data: vec![T::default(); c * h * w],
        }
    }

    /// Build from a flat `C·H·W` buffer.
    pub fn from_vec(c: usize, h: usize, w: usize, data: Vec<T>) -> Self {
        assert_eq!(data.len(), c * h * w);
        FeatureMap { c, h, w, data }
    }

    #[inline(always)]
    /// Read the element at `(c, h, w)`.
    pub fn at(&self, c: usize, h: usize, w: usize) -> T {
        debug_assert!(c < self.c && h < self.h && w < self.w);
        self.data[(c * self.h + h) * self.w + w]
    }

    #[inline(always)]
    /// Mutable access to the element at `(c, h, w)`.
    pub fn at_mut(&mut self, c: usize, h: usize, w: usize) -> &mut T {
        debug_assert!(c < self.c && h < self.h && w < self.w);
        &mut self.data[(c * self.h + h) * self.w + w]
    }

    /// Contiguous channel plane.
    #[inline]
    pub fn plane(&self, c: usize) -> &[T] {
        let sz = self.h * self.w;
        &self.data[c * sz..(c + 1) * sz]
    }

    #[inline]
    /// Flat data, `C × H × W` row-major.
    pub fn data(&self) -> &[T] {
        &self.data
    }

    #[inline]
    /// Mutable flat data.
    pub fn data_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    /// Consume into a dynamic-shape [`Tensor`].
    pub fn into_tensor(self) -> Tensor<T> {
        Tensor::from_vec(&[self.c, self.h, self.w], self.data)
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// Whether the map holds no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// 3D feature volume, layout `C × D × H × W`.
#[derive(Clone, Debug, PartialEq)]
pub struct Volume<T> {
    /// Channels.
    pub c: usize,
    /// Depth.
    pub d: usize,
    /// Height.
    pub h: usize,
    /// Width.
    pub w: usize,
    data: Vec<T>,
}

impl<T: Copy + Default> Volume<T> {
    /// Zero-filled volume.
    pub fn zeros(c: usize, d: usize, h: usize, w: usize) -> Self {
        Volume {
            c,
            d,
            h,
            w,
            data: vec![T::default(); c * d * h * w],
        }
    }

    /// Build from a flat `C·D·H·W` buffer.
    pub fn from_vec(c: usize, d: usize, h: usize, w: usize, data: Vec<T>) -> Self {
        assert_eq!(data.len(), c * d * h * w);
        Volume { c, d, h, w, data }
    }

    #[inline(always)]
    /// Read the element at `(c, d, h, w)`.
    pub fn at(&self, c: usize, d: usize, h: usize, w: usize) -> T {
        debug_assert!(c < self.c && d < self.d && h < self.h && w < self.w);
        self.data[((c * self.d + d) * self.h + h) * self.w + w]
    }

    #[inline(always)]
    /// Mutable access to the element at `(c, d, h, w)`.
    pub fn at_mut(&mut self, c: usize, d: usize, h: usize, w: usize) -> &mut T {
        debug_assert!(c < self.c && d < self.d && h < self.h && w < self.w);
        &mut self.data[((c * self.d + d) * self.h + h) * self.w + w]
    }

    #[inline]
    /// Flat data, `C × D × H × W` row-major.
    pub fn data(&self) -> &[T] {
        &self.data
    }

    #[inline]
    /// Mutable flat data.
    pub fn data_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    /// Consume into a dynamic-shape [`Tensor`].
    pub fn into_tensor(self) -> Tensor<T> {
        Tensor::from_vec(&[self.c, self.d, self.h, self.w], self.data)
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// Whether the volume holds no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// 2D weights, layout `O × I × Kh × Kw`.
#[derive(Clone, Debug, PartialEq)]
pub struct WeightsOIHW<T> {
    /// Output channels.
    pub o: usize,
    /// Input channels.
    pub i: usize,
    /// Kernel height.
    pub kh: usize,
    /// Kernel width.
    pub kw: usize,
    data: Vec<T>,
}

impl<T: Copy + Default> WeightsOIHW<T> {
    /// Zero-filled weights.
    pub fn zeros(o: usize, i: usize, kh: usize, kw: usize) -> Self {
        WeightsOIHW {
            o,
            i,
            kh,
            kw,
            data: vec![T::default(); o * i * kh * kw],
        }
    }

    /// Build from a flat `O·I·Kh·Kw` buffer.
    pub fn from_vec(o: usize, i: usize, kh: usize, kw: usize, data: Vec<T>) -> Self {
        assert_eq!(data.len(), o * i * kh * kw);
        WeightsOIHW { o, i, kh, kw, data }
    }

    #[inline(always)]
    /// Read the weight at `(o, i, kh, kw)`.
    pub fn at(&self, o: usize, i: usize, kh: usize, kw: usize) -> T {
        debug_assert!(o < self.o && i < self.i && kh < self.kh && kw < self.kw);
        self.data[((o * self.i + i) * self.kh + kh) * self.kw + kw]
    }

    #[inline(always)]
    /// Mutable access to the weight at `(o, i, kh, kw)`.
    pub fn at_mut(&mut self, o: usize, i: usize, kh: usize, kw: usize) -> &mut T {
        &mut self.data[((o * self.i + i) * self.kh + kh) * self.kw + kw]
    }

    /// Contiguous `Kh × Kw` kernel for one (o, i) pair — what a PE's Rw
    /// register file holds.
    #[inline]
    pub fn kernel(&self, o: usize, i: usize) -> &[T] {
        let sz = self.kh * self.kw;
        let base = (o * self.i + i) * sz;
        &self.data[base..base + sz]
    }

    #[inline]
    /// Flat data, `O × I × Kh × Kw` row-major.
    pub fn data(&self) -> &[T] {
        &self.data
    }

    #[inline]
    /// Mutable flat data.
    pub fn data_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// Whether there are no weights.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// 3D weights, layout `O × I × Kd × Kh × Kw`.
#[derive(Clone, Debug, PartialEq)]
pub struct WeightsOIDHW<T> {
    /// Output channels.
    pub o: usize,
    /// Input channels.
    pub i: usize,
    /// Kernel depth.
    pub kd: usize,
    /// Kernel height.
    pub kh: usize,
    /// Kernel width.
    pub kw: usize,
    data: Vec<T>,
}

impl<T: Copy + Default> WeightsOIDHW<T> {
    /// Zero-filled weights.
    pub fn zeros(o: usize, i: usize, kd: usize, kh: usize, kw: usize) -> Self {
        WeightsOIDHW {
            o,
            i,
            kd,
            kh,
            kw,
            data: vec![T::default(); o * i * kd * kh * kw],
        }
    }

    /// Build from a flat `O·I·Kd·Kh·Kw` buffer.
    pub fn from_vec(o: usize, i: usize, kd: usize, kh: usize, kw: usize, data: Vec<T>) -> Self {
        assert_eq!(data.len(), o * i * kd * kh * kw);
        WeightsOIDHW {
            o,
            i,
            kd,
            kh,
            kw,
            data,
        }
    }

    #[inline(always)]
    /// Read the weight at `(o, i, kd, kh, kw)`.
    pub fn at(&self, o: usize, i: usize, kd: usize, kh: usize, kw: usize) -> T {
        debug_assert!(
            o < self.o && i < self.i && kd < self.kd && kh < self.kh && kw < self.kw
        );
        self.data[(((o * self.i + i) * self.kd + kd) * self.kh + kh) * self.kw + kw]
    }

    #[inline(always)]
    /// Mutable access to the weight at `(o, i, kd, kh, kw)`.
    pub fn at_mut(&mut self, o: usize, i: usize, kd: usize, kh: usize, kw: usize) -> &mut T {
        &mut self.data[(((o * self.i + i) * self.kd + kd) * self.kh + kh) * self.kw + kw]
    }

    /// Contiguous `Kd × Kh × Kw` kernel for one (o, i) pair.
    #[inline]
    pub fn kernel(&self, o: usize, i: usize) -> &[T] {
        let sz = self.kd * self.kh * self.kw;
        let base = (o * self.i + i) * sz;
        &self.data[base..base + sz]
    }

    #[inline]
    /// Flat data, `O × I × Kd × Kh × Kw` row-major.
    pub fn data(&self) -> &[T] {
        &self.data
    }

    #[inline]
    /// Mutable flat data.
    pub fn data_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// Whether there are no weights.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn feature_map_strides() {
        let mut fm: FeatureMap<f32> = FeatureMap::zeros(2, 3, 4);
        *fm.at_mut(1, 2, 3) = 9.0;
        assert_eq!(fm.at(1, 2, 3), 9.0);
        assert_eq!(fm.data()[1 * 12 + 2 * 4 + 3], 9.0);
        assert_eq!(fm.plane(1).len(), 12);
        assert_eq!(fm.plane(1)[11], 9.0);
    }

    #[test]
    fn volume_strides() {
        let mut v: Volume<f32> = Volume::zeros(2, 3, 4, 5);
        *v.at_mut(1, 2, 3, 4) = 7.0;
        assert_eq!(v.at(1, 2, 3, 4), 7.0);
        assert_eq!(v.data()[((1 * 3 + 2) * 4 + 3) * 5 + 4], 7.0);
    }

    #[test]
    fn weights_kernel_slice() {
        let mut w: WeightsOIHW<f32> = WeightsOIHW::zeros(2, 3, 3, 3);
        *w.at_mut(1, 2, 0, 0) = 1.5;
        let k = w.kernel(1, 2);
        assert_eq!(k.len(), 9);
        assert_eq!(k[0], 1.5);
    }

    #[test]
    fn weights3d_kernel_slice() {
        let mut w: WeightsOIDHW<f32> = WeightsOIDHW::zeros(2, 2, 3, 3, 3);
        *w.at_mut(1, 1, 2, 2, 2) = 4.0;
        let k = w.kernel(1, 1);
        assert_eq!(k.len(), 27);
        assert_eq!(k[26], 4.0);
    }

    #[test]
    fn tensor_round_trip() {
        let fm = FeatureMap::from_vec(1, 2, 2, vec![1.0f32, 2.0, 3.0, 4.0]);
        let t = fm.into_tensor();
        assert_eq!(t.shape(), &[1, 2, 2]);
        assert_eq!(t.get(&[0, 1, 1]), 4.0);
    }
}
