//! Generic dynamic-shape dense tensor.

use std::fmt;

/// Row-major dense tensor with a dynamic shape.
#[derive(Clone, PartialEq)]
pub struct Tensor<T> {
    shape: Vec<usize>,
    data: Vec<T>,
}

impl<T: Copy + Default> Tensor<T> {
    /// Zero-filled tensor.
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: vec![T::default(); n],
        }
    }

    /// Build from raw data; `data.len()` must equal the shape product.
    pub fn from_vec(shape: &[usize], data: Vec<T>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} does not match data length {}",
            shape,
            data.len()
        );
        Tensor {
            shape: shape.to_vec(),
            data,
        }
    }

    /// Total element count.
    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    /// Whether the tensor holds no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    /// The shape extents.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    #[inline]
    /// Flat row-major data.
    pub fn data(&self) -> &[T] {
        &self.data
    }

    #[inline]
    /// Mutable flat row-major data.
    pub fn data_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    /// Consume into the raw buffer.
    pub fn into_vec(self) -> Vec<T> {
        self.data
    }

    /// Flat offset of a multi-index (debug-checked).
    #[inline]
    pub fn offset(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.shape.len());
        let mut off = 0;
        for (i, (&ix, &dim)) in idx.iter().zip(&self.shape).enumerate() {
            debug_assert!(ix < dim, "index {ix} out of bounds for dim {i} (size {dim})");
            off = off * dim + ix;
        }
        off
    }

    #[inline]
    /// Read the element at a multi-index.
    pub fn get(&self, idx: &[usize]) -> T {
        self.data[self.offset(idx)]
    }

    #[inline]
    /// Write the element at a multi-index.
    pub fn set(&mut self, idx: &[usize], v: T) {
        let off = self.offset(idx);
        self.data[off] = v;
    }

    /// Reshape (must preserve element count).
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            self.data.len(),
            "reshape {:?} -> {:?} changes element count",
            self.shape,
            shape
        );
        self.shape = shape.to_vec();
        self
    }
}

impl Tensor<f32> {
    /// Element-wise maximum absolute difference against another tensor.
    pub fn max_abs_diff(&self, other: &Tensor<f32>) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }

    /// Fraction of exactly-zero elements (used by the sparsity analyzer).
    pub fn zero_fraction(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        let zeros = self.data.iter().filter(|&&x| x == 0.0).count();
        zeros as f64 / self.data.len() as f64
    }
}

impl<T: Copy + Default + fmt::Debug> fmt::Debug for Tensor<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}", self.shape)?;
        if self.data.len() <= 16 {
            write!(f, " {:?}", self.data)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_indexing() {
        let mut t: Tensor<f32> = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.len(), 24);
        t.set(&[1, 2, 3], 5.0);
        assert_eq!(t.get(&[1, 2, 3]), 5.0);
        assert_eq!(t.get(&[0, 0, 0]), 0.0);
        // row-major: [1,2,3] -> 1*12 + 2*4 + 3 = 23
        assert_eq!(t.offset(&[1, 2, 3]), 23);
    }

    #[test]
    fn from_vec_and_reshape() {
        let t = Tensor::from_vec(&[2, 3], (0..6).map(|x| x as f32).collect());
        let t = t.reshape(&[3, 2]);
        assert_eq!(t.get(&[2, 1]), 5.0);
    }

    #[test]
    #[should_panic(expected = "does not match data length")]
    fn from_vec_shape_mismatch_panics() {
        let _ = Tensor::from_vec(&[2, 3], vec![0.0f32; 5]);
    }

    #[test]
    #[should_panic(expected = "changes element count")]
    fn bad_reshape_panics() {
        let t: Tensor<f32> = Tensor::zeros(&[2, 3]);
        let _ = t.reshape(&[4, 2]);
    }

    #[test]
    fn zero_fraction() {
        let t = Tensor::from_vec(&[4], vec![0.0, 1.0, 0.0, 2.0]);
        assert_eq!(t.zero_fraction(), 0.5);
    }

    #[test]
    fn max_abs_diff() {
        let a = Tensor::from_vec(&[3], vec![1.0, 2.0, 3.0]);
        let b = Tensor::from_vec(&[3], vec![1.0, 2.5, 2.0]);
        assert_eq!(a.max_abs_diff(&b), 1.0);
    }
}
