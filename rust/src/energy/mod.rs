//! Energy model — Fig. 7(b).
//!
//! Energy efficiency is throughput per watt. Platform powers:
//!
//! * **FPGA**: static + activity-scaled dynamic (per-PE switching at
//!   the measured utilization) + DDR I/O. Lands at ≈ 20 W for the
//!   paper configuration, consistent with the ratios the paper
//!   reports (it never states the absolute watts).
//! * **CPU**: Intel E5 v2 ten-core at 2.8 GHz — 95 W package power
//!   under full vector load (TDP 115 W).
//! * **GPU**: GTX 1080 — 180 W board power (TDP).

use crate::accel::{AccelConfig, LayerMetrics};

/// CPU package power under the benchmark load, watts.
pub const CPU_WATTS: f64 = 95.0;
/// GTX 1080 board power, watts.
pub const GPU_WATTS: f64 = 180.0;
/// FPGA static power, watts.
pub const FPGA_STATIC_W: f64 = 3.5;
/// Dynamic power of one active PE (multiplier + regs + local FIFO
/// traffic) at 200 MHz, watts.
pub const FPGA_PE_DYN_W: f64 = 0.008;
/// DDR interface power per GB/s of sustained traffic, watts.
pub const FPGA_DDR_W_PER_GBPS: f64 = 0.08;

/// FPGA power for a simulated layer (activity-scaled).
pub fn fpga_watts(cfg: &AccelConfig, m: &LayerMetrics) -> f64 {
    FPGA_STATIC_W
        + FPGA_PE_DYN_W * cfg.total_pes() as f64 * m.pe_utilization()
        + FPGA_DDR_W_PER_GBPS * m.dram_gbps()
}

/// Giga-operations per joule given dense-equivalent ops and seconds.
pub fn gops_per_joule(dense_ops: f64, seconds: f64, watts: f64) -> f64 {
    dense_ops / seconds / watts / 1e9
}

/// Energy-efficiency comparison row for one network (Fig. 7(b)).
#[derive(Clone, Debug)]
pub struct EfficiencyRow {
    /// Network name.
    pub network: String,
    /// FPGA energy efficiency, GOPS per joule.
    pub fpga_gops_j: f64,
    /// CPU energy efficiency, GOPS per joule.
    pub cpu_gops_j: f64,
    /// GPU energy efficiency, GOPS per joule.
    pub gpu_gops_j: f64,
}

impl EfficiencyRow {
    /// FPGA-over-CPU energy-efficiency ratio (paper: 104.7–291.4×).
    pub fn vs_cpu(&self) -> f64 {
        self.fpga_gops_j / self.cpu_gops_j
    }

    /// FPGA-over-GPU ratio (paper: 3.3–8.3×).
    pub fn vs_gpu(&self) -> f64 {
        self.fpga_gops_j / self.gpu_gops_j
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::simulate_layer;
    use crate::dcnn::zoo;

    #[test]
    fn fpga_power_in_plausible_band() {
        let cfg = AccelConfig::paper_2d();
        let m = simulate_layer(&cfg, &zoo::dcgan().layers[0]);
        let w = fpga_watts(&cfg, &m);
        assert!(
            (10.0..30.0).contains(&w),
            "FPGA power {w:.1} W out of band"
        );
    }

    #[test]
    fn idle_fpga_draws_static_power() {
        let cfg = AccelConfig::paper_2d();
        let mut m = simulate_layer(&cfg, &zoo::dcgan().layers[0]);
        m.ideal_mac_cycles = 0; // force 0 utilization
        m.dram_bytes = 0;
        let w = fpga_watts(&cfg, &m);
        assert!((w - FPGA_STATIC_W).abs() < 0.5);
    }

    #[test]
    fn gops_per_joule_math() {
        // 1 TOP in 1 s at 100 W = 10 GOPS/J
        let v = gops_per_joule(1e12, 1.0, 100.0);
        assert!((v - 10.0).abs() < 1e-9);
    }

    #[test]
    fn efficiency_ratios() {
        let row = EfficiencyRow {
            network: "x".into(),
            fpga_gops_j: 150.0,
            cpu_gops_j: 1.0,
            gpu_gops_j: 20.0,
        };
        assert!((row.vs_cpu() - 150.0).abs() < 1e-12);
        assert!((row.vs_gpu() - 7.5).abs() < 1e-12);
    }
}
