//! 16-bit fixed-point arithmetic — the accelerator's datapath format.
//!
//! The paper (§V) uses "16-bit fixed activations and weights" on the
//! VC709's DSP48E slices. We model this as **Q8.8**: a signed 16-bit
//! value with 8 integer bits and 8 fractional bits, the common choice
//! for GAN-generator feature maps whose dynamic range after batch-norm
//! is small. Products are held in 32-bit (Q16.16) and accumulated in a
//! 48-bit accumulator exactly as a DSP48E does (`P = A*B + PCIN`), then
//! rounded-to-nearest-even and saturated back to Q8.8 on write-back.

mod q88;
mod acc;

pub use acc::Acc48;
pub use q88::Q88;

/// Number of fractional bits in [`Q88`].
pub const FRAC_BITS: u32 = 8;
/// Scale factor 2^FRAC_BITS.
pub const SCALE: i32 = 1 << FRAC_BITS;

/// Quantize an `f32` slice to Q8.8.
pub fn quantize_slice(xs: &[f32]) -> Vec<Q88> {
    xs.iter().map(|&x| Q88::from_f32(x)).collect()
}

/// Dequantize a Q8.8 slice back to `f32`.
pub fn dequantize_slice(xs: &[Q88]) -> Vec<f32> {
    xs.iter().map(|x| x.to_f32()).collect()
}

/// Worst-case absolute quantization error of a single Q8.8 value.
pub const Q88_EPS: f32 = 1.0 / SCALE as f32;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_error_bounded() {
        let xs: Vec<f32> = (-1000..1000).map(|i| i as f32 * 0.0137).collect();
        let q = quantize_slice(&xs);
        let back = dequantize_slice(&q);
        for (x, b) in xs.iter().zip(&back) {
            assert!((x - b).abs() <= 0.5 * Q88_EPS + 1e-6, "x={x} back={b}");
        }
    }
}
