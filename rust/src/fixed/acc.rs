//! The 48-bit MAC accumulator modelled on the DSP48E1 P register.

use super::q88::{saturate_i16, Q88};
use super::FRAC_BITS;

/// 48-bit accumulator in Q?.16 (products are Q16.16). Wide enough that
/// a full K×K×K × N_c accumulation chain never overflows: the largest
/// chain in our benchmarks is 27 · 1024 products of magnitude
/// < 2^30, comfortably below 2^47.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Acc48(pub i64);

impl Acc48 {
    /// The zero accumulator.
    pub const ZERO: Acc48 = Acc48(0);

    /// Accumulate one Q8.8×Q8.8 product (DSP48 `P += A*B`).
    #[inline]
    pub fn mac(&mut self, a: Q88, b: Q88) {
        self.0 += a.wide_mul(b) as i64;
        self.clamp48();
    }

    /// Add another accumulator (adder-tree node).
    #[inline]
    pub fn add(&mut self, other: Acc48) {
        self.0 += other.0;
        self.clamp48();
    }

    /// Add a raw Q16.16 wide product.
    #[inline]
    pub fn add_wide(&mut self, wide: i32) {
        self.0 += wide as i64;
        self.clamp48();
    }

    #[inline]
    fn clamp48(&mut self) {
        const MAX48: i64 = (1 << 47) - 1;
        const MIN48: i64 = -(1 << 47);
        self.0 = self.0.clamp(MIN48, MAX48);
    }

    /// Write-back: convergent-round the Q16.16 accumulator to Q8.8 and
    /// saturate — the datapath's output stage.
    #[inline]
    pub fn to_q88(self) -> Q88 {
        let half = 1i64 << (FRAC_BITS - 1);
        let mut r = (self.0 + half) >> FRAC_BITS;
        if (self.0 & ((1 << FRAC_BITS) - 1)) == half && (r & 1) == 1 {
            r -= 1;
        }
        Q88::from_bits(saturate_i16(r))
    }

    /// Exact value as f64 (for cross-checking against f32 references).
    #[inline]
    pub fn to_f64(self) -> f64 {
        self.0 as f64 / (1u64 << (2 * FRAC_BITS)) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mac_chain_matches_float() {
        let mut acc = Acc48::ZERO;
        let mut expect = 0.0f64;
        let mut r = crate::util::Prng::new(4);
        for _ in 0..1000 {
            let a = Q88::from_f32(r.f32_range(-4.0, 4.0));
            let b = Q88::from_f32(r.f32_range(-4.0, 4.0));
            acc.mac(a, b);
            expect += a.to_f32() as f64 * b.to_f32() as f64;
        }
        assert!((acc.to_f64() - expect).abs() < 1e-9, "accumulator is exact");
    }

    #[test]
    fn writeback_rounds_and_saturates() {
        let mut acc = Acc48::ZERO;
        acc.mac(Q88::from_f32(100.0), Q88::from_f32(100.0));
        assert_eq!(acc.to_q88(), Q88::MAX);
        let mut acc = Acc48::ZERO;
        acc.mac(Q88::from_f32(-100.0), Q88::from_f32(100.0));
        assert_eq!(acc.to_q88(), Q88::MIN);
        let mut acc = Acc48::ZERO;
        acc.mac(Q88::from_f32(1.5), Q88::from_f32(2.0));
        assert_eq!(acc.to_q88().to_f32(), 3.0);
    }

    #[test]
    fn adder_tree_add_matches() {
        let mut a = Acc48::ZERO;
        a.mac(Q88::ONE, Q88::from_f32(2.0));
        let mut b = Acc48::ZERO;
        b.mac(Q88::ONE, Q88::from_f32(3.5));
        a.add(b);
        assert_eq!(a.to_q88().to_f32(), 5.5);
    }

    #[test]
    fn clamp48_engages() {
        let mut acc = Acc48(i64::MAX / 2);
        acc.add(Acc48(i64::MAX / 2));
        assert_eq!(acc.0, (1 << 47) - 1);
        let mut acc = Acc48(i64::MIN / 2);
        acc.add(Acc48(i64::MIN / 2));
        assert_eq!(acc.0, -(1 << 47));
    }
}
