//! The Q8.8 scalar type.

use std::fmt;
use std::ops::{Add, AddAssign, Mul, Neg, Sub};

use super::{FRAC_BITS, SCALE};

/// Signed 16-bit fixed point, 8 integer + 8 fractional bits.
///
/// Range: [-128.0, +127.996]. All arithmetic saturates (the paper's
/// datapath has no overflow trap — DSP48 saturation is the standard
/// Vivado configuration for CNN accelerators).
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct Q88(pub i16);

impl Q88 {
    /// The additive identity (0.0).
    pub const ZERO: Q88 = Q88(0);
    /// The multiplicative identity (1.0).
    pub const ONE: Q88 = Q88(SCALE as i16);
    /// Largest representable value (+127.996).
    pub const MAX: Q88 = Q88(i16::MAX);
    /// Smallest representable value (−128.0).
    pub const MIN: Q88 = Q88(i16::MIN);

    /// Quantize from f32 with round-to-nearest-even and saturation.
    #[inline]
    pub fn from_f32(x: f32) -> Q88 {
        let scaled = (x as f64) * SCALE as f64;
        // round half to even, matching DSP48 CONVERGENT rounding
        let r = round_half_even(scaled);
        Q88(saturate_i16(r))
    }

    /// Raw constructor from the underlying bits.
    #[inline]
    pub const fn from_bits(bits: i16) -> Q88 {
        Q88(bits)
    }

    /// Integer constructor (`n` must fit in [-128, 127]).
    #[inline]
    pub fn from_int(n: i32) -> Q88 {
        Q88(saturate_i16((n as i64) << FRAC_BITS))
    }

    #[inline]
    /// Convert back to f32 (exact: every Q8.8 value is an f32).
    pub fn to_f32(self) -> f32 {
        self.0 as f32 / SCALE as f32
    }

    #[inline]
    /// The raw underlying bits.
    pub const fn bits(self) -> i16 {
        self.0
    }

    #[inline]
    /// Whether the value is exactly zero.
    pub fn is_zero(self) -> bool {
        self.0 == 0
    }

    /// Full-precision product (Q16.16 in an i32) — what the DSP
    /// multiplier emits before accumulation.
    #[inline]
    pub fn wide_mul(self, rhs: Q88) -> i32 {
        (self.0 as i32) * (rhs.0 as i32)
    }

    /// Saturating absolute value.
    #[inline]
    pub fn abs(self) -> Q88 {
        Q88(self.0.saturating_abs())
    }
}

#[inline]
pub(crate) fn saturate_i16(v: i64) -> i16 {
    v.clamp(i16::MIN as i64, i16::MAX as i64) as i16
}

/// Round-half-to-even on an f64, returning i64 (saturating on
/// non-finite / out-of-range inputs).
#[inline]
pub(crate) fn round_half_even(x: f64) -> i64 {
    if x.is_nan() {
        return 0;
    }
    if x >= i64::MAX as f64 {
        return i64::MAX;
    }
    if x <= i64::MIN as f64 {
        return i64::MIN;
    }
    let floor = x.floor();
    let diff = x - floor;
    let f = floor as i64;
    if diff > 0.5 {
        f + 1
    } else if diff < 0.5 {
        f
    } else if f % 2 == 0 {
        f
    } else {
        f + 1
    }
}

impl Add for Q88 {
    type Output = Q88;
    #[inline]
    fn add(self, rhs: Q88) -> Q88 {
        Q88(self.0.saturating_add(rhs.0))
    }
}

impl AddAssign for Q88 {
    #[inline]
    fn add_assign(&mut self, rhs: Q88) {
        *self = *self + rhs;
    }
}

impl Sub for Q88 {
    type Output = Q88;
    #[inline]
    fn sub(self, rhs: Q88) -> Q88 {
        Q88(self.0.saturating_sub(rhs.0))
    }
}

impl Neg for Q88 {
    type Output = Q88;
    #[inline]
    fn neg(self) -> Q88 {
        Q88(self.0.saturating_neg())
    }
}

impl Mul for Q88 {
    type Output = Q88;
    /// Single-step Q8.8 × Q8.8 → Q8.8 with convergent rounding.
    /// (The accelerator instead keeps the wide product — see
    /// [`Q88::wide_mul`] and [`super::Acc48`].)
    #[inline]
    fn mul(self, rhs: Q88) -> Q88 {
        let wide = self.wide_mul(rhs) as i64; // Q16.16
        let half = 1i64 << (FRAC_BITS - 1);
        let mut r = (wide + half) >> FRAC_BITS;
        // adjust to round-half-even: if we were exactly at .5 and the
        // result is now odd, step back
        if (wide & ((1 << FRAC_BITS) - 1)) == half && (r & 1) == 1 {
            r -= 1;
        }
        Q88(saturate_i16(r))
    }
}

impl fmt::Debug for Q88 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Q88({})", self.to_f32())
    }
}

impl fmt::Display for Q88 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.to_f32())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constants() {
        assert_eq!(Q88::ZERO.to_f32(), 0.0);
        assert_eq!(Q88::ONE.to_f32(), 1.0);
        assert!((Q88::MAX.to_f32() - 127.99609).abs() < 1e-4);
        assert_eq!(Q88::MIN.to_f32(), -128.0);
    }

    #[test]
    fn from_f32_exact_values() {
        assert_eq!(Q88::from_f32(0.5).bits(), 128);
        assert_eq!(Q88::from_f32(-0.5).bits(), -128);
        assert_eq!(Q88::from_f32(1.0).bits(), 256);
        assert_eq!(Q88::from_f32(2.25).bits(), 576);
    }

    #[test]
    fn from_f32_saturates() {
        assert_eq!(Q88::from_f32(1000.0), Q88::MAX);
        assert_eq!(Q88::from_f32(-1000.0), Q88::MIN);
        assert_eq!(Q88::from_f32(f32::INFINITY), Q88::MAX);
        assert_eq!(Q88::from_f32(f32::NEG_INFINITY), Q88::MIN);
    }

    #[test]
    fn round_half_even_ties() {
        // 0.001953125 * 256 = 0.5 exactly -> rounds to 0 (even)
        assert_eq!(Q88::from_f32(0.001953125).bits(), 0);
        // 3*0.001953125 -> 1.5 -> rounds to 2 (even)
        assert_eq!(Q88::from_f32(0.005859375).bits(), 2);
    }

    #[test]
    fn add_sub_saturate() {
        assert_eq!(Q88::MAX + Q88::ONE, Q88::MAX);
        assert_eq!(Q88::MIN - Q88::ONE, Q88::MIN);
        let a = Q88::from_f32(1.5);
        let b = Q88::from_f32(2.25);
        assert_eq!((a + b).to_f32(), 3.75);
        assert_eq!((b - a).to_f32(), 0.75);
    }

    #[test]
    fn neg_saturates_min() {
        assert_eq!(-Q88::MIN, Q88::MAX);
        assert_eq!((-Q88::ONE).to_f32(), -1.0);
    }

    #[test]
    fn mul_simple() {
        let a = Q88::from_f32(1.5);
        let b = Q88::from_f32(2.0);
        assert_eq!((a * b).to_f32(), 3.0);
        let c = Q88::from_f32(-0.5);
        assert_eq!((a * c).to_f32(), -0.75);
    }

    #[test]
    fn mul_saturates() {
        let a = Q88::from_f32(100.0);
        let b = Q88::from_f32(100.0);
        assert_eq!(a * b, Q88::MAX);
        assert_eq!(a * (-b), Q88::MIN);
    }

    #[test]
    fn wide_mul_exact() {
        let a = Q88::from_f32(1.5);
        let b = Q88::from_f32(-2.25);
        // 1.5 * -2.25 = -3.375 = -3.375 * 65536 in Q16.16
        assert_eq!(a.wide_mul(b), (-3.375f64 * 65536.0) as i32);
    }

    #[test]
    fn mul_error_bounded_random() {
        let mut r = crate::util::Prng::new(99);
        for _ in 0..10_000 {
            let x = r.f32_range(-8.0, 8.0);
            let y = r.f32_range(-8.0, 8.0);
            let qa = Q88::from_f32(x);
            let qb = Q88::from_f32(y);
            let got = (qa * qb).to_f32();
            let want = qa.to_f32() * qb.to_f32();
            assert!(
                (got - want).abs() <= 0.5 / 256.0 + 1e-6,
                "x={x} y={y} got={got} want={want}"
            );
        }
    }
}
