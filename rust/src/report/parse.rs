//! Minimal JSON parsing — the inverse of [`crate::report::json`].
//!
//! The offline build has no serde; this recursive-descent parser is
//! just enough to read back the machine-readable artifacts the crate
//! itself emits (trace files, `BENCH_trajectory.json`, report JSON)
//! so tests can validate them structurally. It accepts standard JSON
//! (objects, arrays, strings with escapes, numbers, booleans, null)
//! and rejects everything else with a position-tagged error.

/// A parsed JSON value. Objects preserve insertion order.
#[derive(Clone, Debug, PartialEq)]
pub enum JsonValue {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any number (held as `f64`).
    Num(f64),
    /// A string (escapes decoded).
    Str(String),
    /// An array.
    Arr(Vec<JsonValue>),
    /// An object, as (key, value) pairs in source order.
    Obj(Vec<(String, JsonValue)>),
}

impl JsonValue {
    /// Member `key` of an object (first occurrence), else `None`.
    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        match self {
            JsonValue::Obj(members) => members.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            JsonValue::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// The numeric value as `u64`, if this is a non-negative integer.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            JsonValue::Num(v) if *v >= 0.0 && v.fract() == 0.0 => Some(*v as u64),
            _ => None,
        }
    }

    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[JsonValue]> {
        match self {
            JsonValue::Arr(items) => Some(items),
            _ => None,
        }
    }
}

/// Parse one JSON document (surrounding whitespace allowed; trailing
/// garbage is an error).
pub fn parse(text: &str) -> Result<JsonValue, String> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing data at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while let Some(b) = self.bytes.get(self.pos) {
            if matches!(b, b' ' | b'\t' | b'\n' | b'\r') {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<JsonValue, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(JsonValue::Str(self.string()?)),
            Some(b't') => self.literal("true", JsonValue::Bool(true)),
            Some(b'f') => self.literal("false", JsonValue::Bool(false)),
            Some(b'n') => self.literal("null", JsonValue::Null),
            Some(b) if b == b'-' || b.is_ascii_digit() => self.number(),
            Some(b) => Err(format!("unexpected '{}' at byte {}", b as char, self.pos)),
            None => Err("unexpected end of input".to_string()),
        }
    }

    fn literal(&mut self, word: &str, v: JsonValue) -> Result<JsonValue, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<JsonValue, String> {
        let start = self.pos;
        while let Some(b) = self.peek() {
            if b.is_ascii_digit() || matches!(b, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).expect("ascii");
        s.parse::<f64>()
            .map(JsonValue::Num)
            .map_err(|_| format!("bad number '{s}' at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".to_string()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or("unterminated escape")?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let cp = self.hex4()?;
                            match char::from_u32(cp) {
                                Some(c) => out.push(c),
                                // Surrogates never appear in our own
                                // emitter's output; map them to the
                                // replacement character rather than
                                // implementing pair decoding.
                                None => out.push('\u{fffd}'),
                            }
                        }
                        b => return Err(format!("bad escape '\\{}'", b as char)),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (multi-byte safe).
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| "invalid utf-8 in string".to_string())?;
                    let c = rest.chars().next().expect("non-empty");
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, String> {
        if self.pos + 4 > self.bytes.len() {
            return Err("truncated \\u escape".to_string());
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| "bad \\u escape".to_string())?;
        let cp = u32::from_str_radix(s, 16).map_err(|_| format!("bad \\u escape '{s}'"))?;
        self.pos += 4;
        Ok(cp)
    }

    fn array(&mut self) -> Result<JsonValue, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(JsonValue::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(JsonValue::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<JsonValue, String> {
        self.expect(b'{')?;
        let mut members = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(JsonValue::Obj(members));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            members.push((key, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(JsonValue::Obj(members));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::json::JsonObj;
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), JsonValue::Null);
        assert_eq!(parse(" true ").unwrap(), JsonValue::Bool(true));
        assert_eq!(parse("-2.5e2").unwrap(), JsonValue::Num(-250.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), JsonValue::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = parse("{\"a\": [1, {\"b\": \"x\"}, null], \"c\": 2}").unwrap();
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_u64(), Some(1));
        assert_eq!(arr[1].get("b").unwrap().as_str(), Some("x"));
        assert_eq!(arr[2], JsonValue::Null);
        assert_eq!(v.get("c").unwrap().as_f64(), Some(2.0));
        assert!(v.get("missing").is_none());
    }

    #[test]
    fn round_trips_the_crate_emitter() {
        let doc = JsonObj::new()
            .str("name", "dcgan \"q\"\n")
            .int("cycles", 123)
            .num("tops", 2.5)
            .raw("list", "[1, 2]")
            .render();
        let v = parse(&doc).unwrap();
        assert_eq!(v.get("name").unwrap().as_str(), Some("dcgan \"q\"\n"));
        assert_eq!(v.get("cycles").unwrap().as_u64(), Some(123));
        assert_eq!(v.get("tops").unwrap().as_f64(), Some(2.5));
        assert_eq!(v.get("list").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn decodes_control_escapes() {
        assert_eq!(parse("\"\\u0007\"").unwrap(), JsonValue::Str("\u{7}".into()));
    }

    #[test]
    fn rejects_malformed_input() {
        assert!(parse("").is_err());
        assert!(parse("{\"a\": }").is_err());
        assert!(parse("[1, 2").is_err());
        assert!(parse("{} extra").is_err());
        assert!(parse("nul").is_err());
    }

    #[test]
    fn as_u64_rejects_fractions_and_negatives() {
        assert_eq!(parse("2.5").unwrap().as_u64(), None);
        assert_eq!(parse("-3").unwrap().as_u64(), None);
        assert_eq!(parse("7").unwrap().as_u64(), Some(7));
    }
}
