//! Paper-style table/figure rendering: plain-text tables and ASCII
//! bar charts that `cargo bench` prints and `make reproduce` captures
//! into `reports/` for EXPERIMENTS.md.

pub mod parse;

/// A simple column-aligned text table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    /// Table caption.
    pub title: String,
    /// Column headers.
    pub headers: Vec<String>,
    /// Data rows, each with `headers.len()` cells.
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// An empty table with a caption and column headers.
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row; arity must match the headers.
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("--- {} ---\n", self.title));
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Render to stdout with a trailing blank line.
    pub fn print(&self) {
        print!("{}", self.render());
        println!();
    }
}

/// ASCII horizontal bar chart (for the "figure" reproductions).
pub fn bar_chart(title: &str, items: &[(String, f64)], unit: &str, width: usize) -> String {
    let max = items.iter().map(|(_, v)| *v).fold(f64::MIN_POSITIVE, f64::max);
    let label_w = items.iter().map(|(l, _)| l.len()).max().unwrap_or(0);
    let mut out = format!("--- {title} ---\n");
    for (label, v) in items {
        let n = ((v / max) * width as f64).round() as usize;
        out.push_str(&format!(
            "{:<label_w$}  {:>9.3} {unit}  |{}\n",
            label,
            v,
            "#".repeat(n),
        ));
    }
    out
}

/// Format a ratio like the paper's "63.3x".
pub fn ratio(v: f64) -> String {
    format!("{v:.1}x")
}

/// Minimal JSON rendering for machine-readable exports (`udcnn
/// compile --json`, `BENCH_e2e.json`). String-building only — the
/// offline environment has no serde; values are escaped, objects and
/// arrays compose through [`json::JsonObj::raw`] / [`json::array`].
pub mod json {
    /// Escape a string for a JSON string literal.
    pub fn escape(s: &str) -> String {
        let mut out = String::with_capacity(s.len());
        for c in s.chars() {
            match c {
                '"' => out.push_str("\\\""),
                '\\' => out.push_str("\\\\"),
                '\n' => out.push_str("\\n"),
                '\r' => out.push_str("\\r"),
                '\t' => out.push_str("\\t"),
                c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                c => out.push(c),
            }
        }
        out
    }

    /// Render a JSON array from already-rendered element strings.
    pub fn array(items: &[String]) -> String {
        format!("[{}]", items.join(", "))
    }

    /// A JSON object under construction (builder style).
    #[derive(Clone, Debug, Default)]
    pub struct JsonObj {
        fields: Vec<String>,
    }

    impl JsonObj {
        /// An empty object.
        pub fn new() -> JsonObj {
            JsonObj { fields: Vec::new() }
        }

        /// Append a string field (escaped).
        pub fn str(mut self, key: &str, value: &str) -> JsonObj {
            self.fields
                .push(format!("\"{}\": \"{}\"", escape(key), escape(value)));
            self
        }

        /// Append an unsigned integer field.
        pub fn int(mut self, key: &str, value: u64) -> JsonObj {
            self.fields.push(format!("\"{}\": {value}", escape(key)));
            self
        }

        /// Append a float field (`null` for non-finite values).
        pub fn num(mut self, key: &str, value: f64) -> JsonObj {
            let v = if value.is_finite() {
                format!("{value}")
            } else {
                "null".to_string()
            };
            self.fields.push(format!("\"{}\": {v}", escape(key)));
            self
        }

        /// Insert an already-rendered JSON value (object/array).
        pub fn raw(mut self, key: &str, value: &str) -> JsonObj {
            self.fields.push(format!("\"{}\": {value}", escape(key)));
            self
        }

        /// Render the object literal.
        pub fn render(&self) -> String {
            format!("{{{}}}", self.fields.join(", "))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("T", &["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["longer-name".into(), "22".into()]);
        let r = t.render();
        assert!(r.contains("--- T ---"));
        assert!(r.contains("longer-name"));
        let lines: Vec<&str> = r.lines().collect();
        // header + separator + 2 rows + title
        assert_eq!(lines.len(), 5);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn bar_chart_scales_to_max() {
        let c = bar_chart(
            "chart",
            &[("x".into(), 1.0), ("y".into(), 2.0)],
            "TOPS",
            10,
        );
        let lines: Vec<&str> = c.lines().collect();
        assert!(lines[1].matches('#').count() == 5);
        assert!(lines[2].matches('#').count() == 10);
    }

    #[test]
    fn ratio_format() {
        assert_eq!(ratio(63.31), "63.3x");
    }

    #[test]
    fn json_objects_render() {
        let inner = json::JsonObj::new().int("cycles", 42).render();
        let obj = json::JsonObj::new()
            .str("name", "dcgan")
            .num("tops", 2.5)
            .raw("detail", &inner)
            .raw("list", &json::array(&["1".into(), "2".into()]))
            .render();
        assert_eq!(
            obj,
            "{\"name\": \"dcgan\", \"tops\": 2.5, \"detail\": {\"cycles\": 42}, \"list\": [1, 2]}"
        );
    }

    #[test]
    fn json_escaping() {
        assert_eq!(json::escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        let obj = json::JsonObj::new().str("k", "v\"w").render();
        assert_eq!(obj, "{\"k\": \"v\\\"w\"}");
    }

    #[test]
    fn json_non_finite_is_null() {
        let obj = json::JsonObj::new().num("x", f64::NAN).render();
        assert_eq!(obj, "{\"x\": null}");
    }
}
