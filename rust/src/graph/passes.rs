//! The compiler pass pipeline over [`NetworkGraph`]s.
//!
//! Passes, in the order [`lower`] runs them:
//!
//! 1. [`validate`] — structural checks (arity, edge references,
//!    dimensionality consistency);
//! 2. [`infer_shapes`] — propagate tensor shapes along every edge in
//!    topological order (multi-input merge nodes see all producer
//!    shapes) and reject graphs whose geometries do not compose;
//! 3. [`lower_oom_to_iom`] — rewrite every `ZeroInsert → Conv` pair
//!    into the accelerator's native `Deconv` node (§III of the paper:
//!    the two formulations compute the same function; IOM never
//!    touches the inserted zeros);
//! 4. [`fuse_activations`] — fold pointwise activations into their
//!    producer's write-back path (free in hardware);
//! 5. [`infer_shapes`] again — shapes for the rewritten graph.
//!
//! Passes are pure graph→graph functions so they compose and are
//! testable in isolation; [`lower`] is the pipeline the CLI and the
//! coordinator use before [`super::plan::compile`].

use crate::dcnn::Dims;

use super::ir::{NetworkGraph, NodeId, NodeSpec, OpKind, TensorShape};

/// Structural validation: every edge references an earlier node, every
/// op has the right arity (merge nodes take two or more inputs), and
/// every layer matches the graph's dimensionality.
pub fn validate(g: &NetworkGraph) -> Result<(), String> {
    for (i, n) in g.nodes.iter().enumerate() {
        if n.id != i {
            return Err(format!("node {} has id {} (must equal its index)", i, n.id));
        }
        for &src in &n.inputs {
            if src >= i {
                return Err(format!(
                    "node '{}' ({}) references node {} out of topological order",
                    n.name, i, src
                ));
            }
        }
        let arity_ok = match &n.op {
            OpKind::Input { .. } => n.inputs.is_empty(),
            OpKind::Concat | OpKind::Add => n.inputs.len() >= 2,
            _ => n.inputs.len() == 1,
        };
        if !arity_ok {
            return Err(format!(
                "node '{}' ({}) has {} inputs, expected {}",
                n.name,
                n.op.mnemonic(),
                n.inputs.len(),
                match &n.op {
                    OpKind::Input { .. } => "0",
                    OpKind::Concat | OpKind::Add => ">= 2",
                    _ => "1",
                }
            ));
        }
        let spec_dims = match &n.op {
            OpKind::Deconv { spec }
            | OpKind::ZeroInsert { spec }
            | OpKind::Conv { spec } => Some(spec.dims),
            _ => None,
        };
        if let Some(d) = spec_dims {
            if d != g.dims {
                return Err(format!(
                    "node '{}' is {d} but the graph '{}' is {}",
                    n.name, g.name, g.dims
                ));
            }
        }
    }
    Ok(())
}

/// Expected output shape of one node given its (already inferred)
/// input shapes, in argument order. `dims` is the graph
/// dimensionality: resampling nodes touch depth only on 3D graphs.
fn node_out_shape(
    n: &NodeSpec,
    inputs: &[TensorShape],
    dims: Dims,
) -> Result<TensorShape, String> {
    let first = || -> Result<TensorShape, String> {
        inputs
            .first()
            .copied()
            .ok_or_else(|| format!("node '{}' input shape not inferred", n.name))
    };
    let expect_input = |want: TensorShape| -> Result<(), String> {
        let got = first()?;
        if got == want {
            Ok(())
        } else {
            Err(format!(
                "node '{}' expects input {want}, got {got} (layer chain does not compose)",
                n.name
            ))
        }
    };
    match &n.op {
        OpKind::Input { shape } => Ok(*shape),
        OpKind::Deconv { spec } => {
            expect_input(TensorShape::of_layer_input(spec))?;
            Ok(TensorShape::of_layer_output(spec))
        }
        OpKind::ZeroInsert { spec } => {
            expect_input(TensorShape::of_layer_input(spec))?;
            // inserted extent (I−1)·S+1, plus the K−1 'full'-conv
            // border per axis. Dimension-uniform: a 2D layer has
            // in_d = 1 (inserted extent 1) and k_d() = 1 (no depth
            // border), so no dimensionality branch is needed.
            let pad = 2 * (spec.k - 1);
            Ok(TensorShape::new(
                spec.in_c,
                spec.ins_extent(spec.in_d) + 2 * (spec.k_d() - 1),
                spec.ins_extent(spec.in_h) + pad,
                spec.ins_extent(spec.in_w) + pad,
            ))
        }
        OpKind::Conv { spec } => {
            // input must be the padded inserted map of the same layer
            let zi = NodeSpec {
                op: OpKind::ZeroInsert { spec: spec.clone() },
                ..n.clone()
            };
            let want = node_out_shape(&zi, &[TensorShape::of_layer_input(spec)], dims)?;
            expect_input(want)?;
            // VALID conv gives the full Eq.-(1) extent; the K−S edge is
            // cropped at write-back, so the edge tensor is I·S.
            Ok(TensorShape::of_layer_output(spec))
        }
        OpKind::Activation { .. } => first(),
        OpKind::Concat => {
            let f = first()?;
            let mut c = 0;
            for (i, s) in inputs.iter().enumerate() {
                if (s.d, s.h, s.w) != (f.d, f.h, f.w) {
                    return Err(format!(
                        "node '{}' concat input {i} is {s}, spatial extents differ from {f}",
                        n.name
                    ));
                }
                c += s.c;
            }
            Ok(TensorShape::new(c, f.d, f.h, f.w))
        }
        OpKind::Add => {
            let f = first()?;
            for (i, s) in inputs.iter().enumerate() {
                if *s != f {
                    return Err(format!(
                        "node '{}' add input {i} is {s}, shape differs from {f}",
                        n.name
                    ));
                }
            }
            Ok(f)
        }
        OpKind::MaxPool { k } => {
            let f = first()?;
            if *k == 0 {
                return Err(format!("node '{}' max_pool window is 0", n.name));
            }
            let kd = if dims == Dims::D3 { *k } else { 1 };
            if f.d % kd != 0 || f.h % k != 0 || f.w % k != 0 {
                return Err(format!(
                    "node '{}' max_pool window {k} does not divide input {f}",
                    n.name
                ));
            }
            Ok(TensorShape::new(f.c, f.d / kd, f.h / k, f.w / k))
        }
        OpKind::Upsample { f: factor } => {
            let f = first()?;
            if *factor == 0 {
                return Err(format!("node '{}' upsample factor is 0", n.name));
            }
            let fd = if dims == Dims::D3 { *factor } else { 1 };
            Ok(TensorShape::new(f.c, f.d * fd, f.h * factor, f.w * factor))
        }
    }
}

/// Shape inference: fills `out_shape` on every node in topological
/// order (multi-input merge nodes see every producer's shape),
/// rejecting graphs whose geometries do not compose.
pub fn infer_shapes(g: &mut NetworkGraph) -> Result<(), String> {
    validate(g)?;
    for i in 0..g.nodes.len() {
        let mut inputs = Vec::with_capacity(g.nodes[i].inputs.len());
        for &src in &g.nodes[i].inputs {
            match g.nodes[src].out_shape {
                Some(s) => inputs.push(s),
                None => {
                    return Err(format!(
                        "node '{}' reads node {src} whose shape is not inferred",
                        g.nodes[i].name
                    ))
                }
            }
        }
        let shape = node_out_shape(&g.nodes[i], &inputs, g.dims)?;
        g.nodes[i].out_shape = Some(shape);
    }
    Ok(())
}

/// Rewrite every `ZeroInsert → Conv` pair (the OOM decomposition) into
/// one native IOM `Deconv` node. A pair fuses when the `ZeroInsert`
/// feeds exactly that `Conv` and both carry the same layer geometry.
pub fn lower_oom_to_iom(g: &NetworkGraph) -> NetworkGraph {
    // Which ZeroInsert nodes fuse into which Conv consumer.
    let mut fused_zi: Vec<bool> = vec![false; g.nodes.len()];
    for n in &g.nodes {
        if let OpKind::Conv { spec } = &n.op {
            let src = n.inputs[0];
            if let OpKind::ZeroInsert { spec: zspec } = &g.nodes[src].op {
                if zspec == spec && g.consumers(src).len() == 1 {
                    fused_zi[src] = true;
                }
            }
        }
    }

    let mut out = NetworkGraph::new(g.name.clone(), g.dims);
    // old id → new id (for fused ZeroInserts: the id of their producer)
    let mut map: Vec<NodeId> = Vec::with_capacity(g.nodes.len());
    for n in &g.nodes {
        if fused_zi[n.id] {
            // skip; consumers reach through to its producer
            map.push(map[n.inputs[0]]);
            continue;
        }
        let (op, name) = match &n.op {
            OpKind::Conv { spec } if fused_zi[n.inputs[0]] => (
                OpKind::Deconv { spec: spec.clone() },
                spec.name.clone(),
            ),
            other => (other.clone(), n.name.clone()),
        };
        let inputs: Vec<NodeId> = n.inputs.iter().map(|&i| map[i]).collect();
        let id = out.add_node(name, op, &inputs);
        out.nodes[id].fused = n.fused.clone();
        map.push(id);
    }
    out
}

/// Fold pointwise activations into their producer's write-back path.
/// An activation fuses when its producer feeds it exclusively.
pub fn fuse_activations(g: &NetworkGraph) -> NetworkGraph {
    let mut out = NetworkGraph::new(g.name.clone(), g.dims);
    let mut map: Vec<NodeId> = Vec::with_capacity(g.nodes.len());
    for n in &g.nodes {
        if let OpKind::Activation { act } = &n.op {
            let src = n.inputs[0];
            let fusible = g.consumers(src).len() == 1
                && !matches!(g.nodes[src].op, OpKind::Input { .. });
            if fusible {
                let new_src = map[src];
                out.nodes[new_src].fused.push(*act);
                map.push(new_src);
                continue;
            }
        }
        let inputs: Vec<NodeId> = n.inputs.iter().map(|&i| map[i]).collect();
        let id = out.add_node(n.name.clone(), n.op.clone(), &inputs);
        out.nodes[id].fused = n.fused.clone();
        map.push(id);
    }
    out
}

/// The default pipeline: validate, infer, lower OOM→IOM, fuse
/// activations, re-infer. Returns the lowered graph ready for
/// [`super::plan::compile`].
pub fn lower(g: &NetworkGraph) -> Result<NetworkGraph, String> {
    lower_obs(g, &crate::obs::Obs::off())
}

/// [`lower`] with per-pass observability: each pass runs under a
/// scoped span (track `compile`, category `pass`) carrying the node
/// count it produced, so a trace shows where compile time goes.
pub fn lower_obs(g: &NetworkGraph, obs: &crate::obs::Obs) -> Result<NetworkGraph, String> {
    use crate::report::json::JsonObj;
    let track = obs.track("compile");
    let mut g = g.clone();
    {
        let mut s = obs.scope(track, "pass", "infer_shapes");
        infer_shapes(&mut g)?;
        s.set_args(JsonObj::new().int("nodes", g.nodes.len() as u64));
    }
    let lowered = {
        let mut s = obs.scope(track, "pass", "lower_oom_to_iom");
        let lowered = lower_oom_to_iom(&g);
        s.set_args(JsonObj::new().int("nodes", lowered.nodes.len() as u64));
        lowered
    };
    let mut g = {
        let mut s = obs.scope(track, "pass", "fuse_activations");
        let fused = fuse_activations(&lowered);
        s.set_args(JsonObj::new().int("nodes", fused.nodes.len() as u64));
        fused
    };
    {
        let mut s = obs.scope(track, "pass", "reinfer_shapes");
        infer_shapes(&mut g)?;
        s.set_args(JsonObj::new().int("nodes", g.nodes.len() as u64));
    }
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dcnn::zoo;
    use crate::graph::ir::Act;

    #[test]
    fn shapes_compose_along_zoo_chains() {
        for net in zoo::all_benchmarks() {
            let mut g = NetworkGraph::from_network(&net);
            infer_shapes(&mut g).unwrap();
            let last = g.nodes.last().unwrap();
            let spec = net.layers.last().unwrap();
            assert_eq!(
                last.out_shape.unwrap(),
                TensorShape::of_layer_output(spec),
                "{}",
                net.name
            );
        }
    }

    #[test]
    fn broken_chain_is_rejected() {
        let mut net = zoo::dcgan();
        net.layers[1].in_c = 999; // no longer matches layer 0's out_c
        let mut g = NetworkGraph::from_network(&net);
        let err = infer_shapes(&mut g).unwrap_err();
        assert!(err.contains("does not compose"), "{err}");
    }

    #[test]
    fn oom_shapes_match_reference_formulation() {
        // ZeroInsert output = padded inserted map; Conv output = the
        // same cropped tensor a Deconv produces.
        let net = zoo::tiny_2d();
        let mut g = NetworkGraph::from_network_oom(&net);
        infer_shapes(&mut g).unwrap();
        let spec = &net.layers[0];
        let zi = g.nodes[1].out_shape.unwrap();
        // (4−1)·2+1 = 7 inserted, +2·(3−1) = 11 padded
        assert_eq!((zi.h, zi.w), (11, 11));
        assert_eq!(zi.c, spec.in_c);
        let conv = g.nodes[2].out_shape.unwrap();
        assert_eq!(conv, TensorShape::of_layer_output(spec));
    }

    #[test]
    fn lowering_rewrites_every_pair() {
        for net in zoo::all_benchmarks() {
            let g = NetworkGraph::from_network_oom(&net);
            let lowered = lower(&g).unwrap();
            assert_eq!(lowered.len(), 1 + net.layers.len(), "{}", net.name);
            assert_eq!(lowered.deconv_specs().len(), net.layers.len());
            // lowered OOM graph is isomorphic to the native IOM build
            let native = lower(&NetworkGraph::from_network(&net)).unwrap();
            let a: Vec<_> = lowered.deconv_specs();
            let b: Vec<_> = native.deconv_specs();
            assert_eq!(a, b, "{}", net.name);
        }
    }

    #[test]
    fn activation_fusion_collapses_chain() {
        let net = zoo::tiny_3d();
        let g = NetworkGraph::from_network_with_activations(&net, Act::Relu);
        let lowered = lower(&g).unwrap();
        assert_eq!(lowered.len(), 1 + net.layers.len());
        for n in &lowered.nodes {
            if matches!(n.op, OpKind::Deconv { .. }) {
                assert_eq!(n.fused, vec![Act::Relu], "{}", n.name);
            }
        }
    }

    #[test]
    fn lower_is_idempotent_on_iom_graphs() {
        let net = zoo::vnet();
        let g = NetworkGraph::from_network(&net);
        let once = lower(&g).unwrap();
        let twice = lower(&once).unwrap();
        assert_eq!(once.len(), twice.len());
        assert_eq!(once.deconv_specs(), twice.deconv_specs());
    }
}
