//! The streaming (temporal-tiling) shape pass.
//!
//! Deconvolution *scatters*: input frame `id` writes output frames
//! `[id·S, id·S + K_d)`, so output frame `z` reads exactly the input
//! frames `[⌈(z − K_d + 1)/S⌉, ⌊z/S⌋]` — a bounded, causal window.
//! Two consequences drive the whole streaming tier
//! ([`crate::stream`]):
//!
//! 1. **Emission is prompt.** The cropped output keeps frames
//!    `[0, S·I)`, and after `n` input frames every output frame
//!    `z < S·n` has its full contributor set (`⌊z/S⌋ ≤ n − 1`), so a
//!    layer emits `S` output frames per input frame with *zero*
//!    lookahead and needs no end-of-stream drain.
//! 2. **State is a fixed halo.** Once outputs `[0, S·n)` are emitted,
//!    the only input frames future outputs still read are the last
//!    `⌊(K_d − 1)/S⌋` — the per-layer halo this pass computes from
//!    [`LayerSpec::k_d`] and the stride.
//!
//! [`stream_shapes`] runs over a *lowered* (IOM-form) graph and
//! returns one [`LayerStreamShape`] per deconvolution node in
//! topological order; [`crate::stream::StreamSession`] derives its
//! per-layer halo state from exactly this pass, and the property suite
//! (`tests/prop_stream.rs`) pins reassembled streaming outputs to
//! these shapes.

use crate::dcnn::{Dims, LayerSpec};

use super::ir::{NetworkGraph, OpKind};

/// Streaming-relevant geometry of one deconvolution layer.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LayerStreamShape {
    /// Layer name (from the [`LayerSpec`]).
    pub name: String,
    /// Kernel extent along depth (`K` for 3D, 1 for 2D).
    pub k_d: usize,
    /// Stride `S`.
    pub s: usize,
    /// Input frames the layer must retain across chunks:
    /// `⌊(K_d − 1)/S⌋`. Zero for 2D layers (depth-1 kernels), so a 2D
    /// network streams as stateless per-frame passthrough.
    pub halo_in: usize,
    /// Total input frames of the layer's declared geometry (1 for 2D).
    pub in_frames: usize,
    /// Total cropped output frames, `S · in_frames` (1 for 2D).
    pub out_frames: usize,
}

impl LayerStreamShape {
    /// Input slab a steady-state chunk of `chunk` new frames runs
    /// over: the retained halo plus the arrivals, capped at the
    /// layer's total depth (the first chunk has no halo yet; a chunk
    /// covering the whole depth is whole-volume execution).
    pub fn slab_frames(&self, chunk: usize) -> usize {
        (chunk + self.halo_in).min(self.in_frames)
    }

    /// First input frame output frame `z` reads:
    /// `max(0, ⌈(z − K_d + 1)/S⌉)`.
    pub fn first_contributor(&self, z: usize) -> usize {
        if z + 1 <= self.k_d {
            0
        } else {
            (z + 1 - self.k_d).div_ceil(self.s)
        }
    }

    /// Last input frame output frame `z` reads: `min(I − 1, ⌊z/S⌋)`.
    pub fn last_contributor(&self, z: usize) -> usize {
        (z / self.s).min(self.in_frames - 1)
    }
}

/// Compute the [`LayerStreamShape`] of every deconvolution node of a
/// lowered graph, in topological order.
///
/// Errors on OOM-form graphs (run [`super::passes::lower`] first), on
/// a graph with no deconvolution nodes, on a layer with `K < S`
/// (whose cropped extent is undefined — the paper's benchmarks all
/// have `K ≥ S`), and on a 3D chain whose depths do not compose.
pub fn stream_shapes(g: &NetworkGraph) -> Result<Vec<LayerStreamShape>, String> {
    for n in &g.nodes {
        if matches!(n.op, OpKind::ZeroInsert { .. } | OpKind::Conv { .. }) {
            return Err(format!(
                "node '{}' is OOM-form; run passes::lower before stream_shapes",
                n.name
            ));
        }
    }
    let specs = g.deconv_specs();
    if specs.is_empty() {
        return Err(format!("graph '{}' has no deconvolution nodes", g.name));
    }
    let mut shapes = Vec::with_capacity(specs.len());
    for spec in &specs {
        shapes.push(shape_of(spec)?);
    }
    for pair in shapes.windows(2) {
        if pair[0].out_frames != pair[1].in_frames {
            return Err(format!(
                "layer '{}' emits {} frames but '{}' consumes {} (depth chain broken)",
                pair[0].name, pair[0].out_frames, pair[1].name, pair[1].in_frames
            ));
        }
    }
    Ok(shapes)
}

/// The [`LayerStreamShape`] of one layer.
fn shape_of(spec: &LayerSpec) -> Result<LayerStreamShape, String> {
    if spec.k < spec.s {
        return Err(format!(
            "layer '{}' has K={} < S={}; streaming (and cropping) need K >= S",
            spec.name, spec.k, spec.s
        ));
    }
    let (in_frames, out_frames) = match spec.dims {
        Dims::D2 => (1, 1),
        Dims::D3 => (spec.in_d, spec.out_d()),
    };
    Ok(LayerStreamShape {
        name: spec.name.clone(),
        k_d: spec.k_d(),
        s: spec.s,
        halo_in: (spec.k_d() - 1) / spec.s,
        in_frames,
        out_frames,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dcnn::zoo;
    use crate::graph::passes;

    fn shapes_for(net: &crate::dcnn::Network) -> Vec<LayerStreamShape> {
        let g = passes::lower(&NetworkGraph::from_network(net)).unwrap();
        stream_shapes(&g).unwrap()
    }

    #[test]
    fn zoo_3d_halo_is_one_frame() {
        // K=3, S=2 everywhere: halo = (3-1)/2 = 1 retained frame.
        for net in [zoo::gan3d(), zoo::vnet()] {
            for (sh, l) in shapes_for(&net).iter().zip(&net.layers) {
                assert_eq!(sh.halo_in, 1, "{}", sh.name);
                assert_eq!(sh.k_d, 3);
                assert_eq!(sh.in_frames, l.in_d);
                assert_eq!(sh.out_frames, 2 * l.in_d);
            }
        }
    }

    #[test]
    fn zoo_2d_is_stateless_passthrough() {
        for sh in shapes_for(&zoo::dcgan()) {
            assert_eq!(sh.halo_in, 0, "{}", sh.name);
            assert_eq!(sh.k_d, 1);
            assert_eq!((sh.in_frames, sh.out_frames), (1, 1));
        }
    }

    #[test]
    fn contributor_window_matches_scatter() {
        let sh = LayerStreamShape {
            name: "t".into(),
            k_d: 3,
            s: 2,
            halo_in: 1,
            in_frames: 4,
            out_frames: 8,
        };
        // input id writes [2id, 2id+3): invert per output frame
        assert_eq!((sh.first_contributor(0), sh.last_contributor(0)), (0, 0));
        assert_eq!((sh.first_contributor(2), sh.last_contributor(2)), (0, 1));
        assert_eq!((sh.first_contributor(4), sh.last_contributor(4)), (1, 2));
        assert_eq!((sh.first_contributor(7), sh.last_contributor(7)), (3, 3));
        // emission boundary z = S·n is served once frame n arrives
        assert_eq!(sh.first_contributor(6), 2);
        // slab of a 2-frame chunk carries the 1-frame halo
        assert_eq!(sh.slab_frames(2), 3);
        assert_eq!(sh.slab_frames(4), 4, "whole depth caps the slab");
    }

    #[test]
    fn rejects_oom_form_and_bad_geometry() {
        let net = zoo::tiny_3d();
        let err = stream_shapes(&NetworkGraph::from_network_oom(&net)).unwrap_err();
        assert!(err.contains("OOM-form"), "{err}");

        let mut bad = zoo::tiny_3d();
        bad.layers[0].s = 5; // K=3 < S=5
        let g = NetworkGraph::from_network(&bad);
        let err = stream_shapes(&g).unwrap_err();
        assert!(err.contains("K >= S"), "{err}");
    }

    #[test]
    fn re_depthed_chain_composes() {
        let net = zoo::gan3d().with_depth(10);
        let shapes = shapes_for(&net);
        assert_eq!(shapes[0].in_frames, 10);
        assert_eq!(shapes.last().unwrap().out_frames, 160);
        for pair in shapes.windows(2) {
            assert_eq!(pair[0].out_frames, pair[1].in_frames);
        }
    }
}
