//! The streaming (temporal-tiling) shape pass.
//!
//! Deconvolution *scatters*: input frame `id` writes output frames
//! `[id·S, id·S + K_d)`, so output frame `z` reads exactly the input
//! frames `[⌈(z − K_d + 1)/S⌉, ⌊z/S⌋]` — a bounded, causal window.
//! Two consequences drive the whole streaming tier
//! ([`crate::stream`]):
//!
//! 1. **Emission is prompt.** The cropped output keeps frames
//!    `[0, S·I)`, and after `n` input frames every output frame
//!    `z < S·n` has its full contributor set (`⌊z/S⌋ ≤ n − 1`), so a
//!    layer emits `S` output frames per input frame with *zero*
//!    lookahead and needs no end-of-stream drain.
//! 2. **State is a fixed halo.** Once outputs `[0, S·n)` are emitted,
//!    the only input frames future outputs still read are the last
//!    `⌊(K_d − 1)/S⌋` — the per-layer halo this pass computes from
//!    [`LayerSpec::k_d`] and the stride.
//!
//! [`stream_shapes`] runs over a *lowered* (IOM-form) graph and
//! returns one [`LayerStreamShape`] per deconvolution node in
//! topological order; [`crate::stream::StreamSession`] derives its
//! per-layer halo state from exactly this pass, and the property suite
//! (`tests/prop_stream.rs`) pins reassembled streaming outputs to
//! these shapes.

use std::fmt;

use crate::dcnn::{Dims, LayerSpec};

use super::ir::{NetworkGraph, OpKind};

/// Typed failure of the streaming shape pass.
///
/// The variant that motivated the type is [`NonLinear`]: the pass used
/// to silently assume chain order, which a skip DAG (U-Net / UNETR)
/// violates — merge nodes need whole skip tensors resident, so
/// frame-by-frame streaming does not apply and callers must be able to
/// tell that apart from a mis-built graph.
///
/// [`NonLinear`]: StreamShapeError::NonLinear
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum StreamShapeError {
    /// The graph still contains OOM-form nodes
    /// (run [`super::passes::lower`] first).
    OomForm {
        /// Name of the offending node.
        node: String,
    },
    /// The graph has no deconvolution nodes.
    NoDeconvs {
        /// Graph name.
        graph: String,
    },
    /// The graph is not a linear chain, naming the offending node — a
    /// merge/resample node, a multi-input node, or the producer of a
    /// multi-consumer tensor.
    NonLinear {
        /// Name of the offending node.
        node: String,
        /// Why that node breaks chain order.
        reason: String,
    },
    /// A layer has `K < S`, so its cropped streaming extent is
    /// undefined (the paper's benchmarks all have `K ≥ S`).
    BadGeometry {
        /// Layer name.
        layer: String,
        /// Kernel extent.
        k: usize,
        /// Stride.
        s: usize,
    },
    /// Adjacent layers' depths do not compose.
    DepthChainBroken {
        /// Producer layer name.
        producer: String,
        /// Frames the producer emits.
        emits: usize,
        /// Consumer layer name.
        consumer: String,
        /// Frames the consumer expects.
        consumes: usize,
    },
}

impl fmt::Display for StreamShapeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            StreamShapeError::OomForm { node } => {
                write!(f, "node '{node}' is OOM-form; run passes::lower before stream_shapes")
            }
            StreamShapeError::NoDeconvs { graph } => {
                write!(f, "graph '{graph}' has no deconvolution nodes")
            }
            StreamShapeError::NonLinear { node, reason } => {
                write!(
                    f,
                    "node '{node}' breaks chain order ({reason}); streaming supports only linear graphs"
                )
            }
            StreamShapeError::BadGeometry { layer, k, s } => {
                write!(
                    f,
                    "layer '{layer}' has K={k} < S={s}; streaming (and cropping) need K >= S"
                )
            }
            StreamShapeError::DepthChainBroken {
                producer,
                emits,
                consumer,
                consumes,
            } => {
                write!(
                    f,
                    "layer '{producer}' emits {emits} frames but '{consumer}' consumes {consumes} (depth chain broken)"
                )
            }
        }
    }
}

impl std::error::Error for StreamShapeError {}

// The pre-existing callers thread stream-shape failures through
// `Result<_, String>` pipelines; keep `?` working for them.
impl From<StreamShapeError> for String {
    fn from(e: StreamShapeError) -> String {
        e.to_string()
    }
}

/// Streaming-relevant geometry of one deconvolution layer.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LayerStreamShape {
    /// Layer name (from the [`LayerSpec`]).
    pub name: String,
    /// Kernel extent along depth (`K` for 3D, 1 for 2D).
    pub k_d: usize,
    /// Stride `S`.
    pub s: usize,
    /// Input frames the layer must retain across chunks:
    /// `⌊(K_d − 1)/S⌋`. Zero for 2D layers (depth-1 kernels), so a 2D
    /// network streams as stateless per-frame passthrough.
    pub halo_in: usize,
    /// Total input frames of the layer's declared geometry (1 for 2D).
    pub in_frames: usize,
    /// Total cropped output frames, `S · in_frames` (1 for 2D).
    pub out_frames: usize,
}

impl LayerStreamShape {
    /// Input slab a steady-state chunk of `chunk` new frames runs
    /// over: the retained halo plus the arrivals, capped at the
    /// layer's total depth (the first chunk has no halo yet; a chunk
    /// covering the whole depth is whole-volume execution).
    pub fn slab_frames(&self, chunk: usize) -> usize {
        (chunk + self.halo_in).min(self.in_frames)
    }

    /// First input frame output frame `z` reads:
    /// `max(0, ⌈(z − K_d + 1)/S⌉)`.
    pub fn first_contributor(&self, z: usize) -> usize {
        if z + 1 <= self.k_d {
            0
        } else {
            (z + 1 - self.k_d).div_ceil(self.s)
        }
    }

    /// Last input frame output frame `z` reads: `min(I − 1, ⌊z/S⌋)`.
    pub fn last_contributor(&self, z: usize) -> usize {
        (z / self.s).min(self.in_frames - 1)
    }
}

/// Compute the [`LayerStreamShape`] of every deconvolution node of a
/// lowered graph, in topological order.
///
/// Errors with a typed [`StreamShapeError`]: OOM-form graphs (run
/// [`super::passes::lower`] first), graphs with no deconvolution
/// nodes, **non-linear graphs** (skip DAGs cannot stream
/// frame-by-frame; the offending node is named), layers with `K < S`
/// (whose cropped extent is undefined — the paper's benchmarks all
/// have `K ≥ S`), and 3D chains whose depths do not compose.
pub fn stream_shapes(g: &NetworkGraph) -> Result<Vec<LayerStreamShape>, StreamShapeError> {
    for n in &g.nodes {
        if matches!(n.op, OpKind::ZeroInsert { .. } | OpKind::Conv { .. }) {
            return Err(StreamShapeError::OomForm {
                node: n.name.clone(),
            });
        }
    }
    // Chain-order check: streaming assumes node order IS dataflow
    // order with exactly one tensor in flight. Any merge/resample
    // node, multi-input node, or multi-consumer tensor breaks that.
    for n in &g.nodes {
        if n.op.is_move() || n.inputs.len() > 1 {
            return Err(StreamShapeError::NonLinear {
                node: n.name.clone(),
                reason: if n.inputs.len() > 1 {
                    format!("{} merges {} input tensors", n.op.mnemonic(), n.inputs.len())
                } else {
                    format!("{} is a resampling node", n.op.mnemonic())
                },
            });
        }
        let consumers = g.consumers(n.id);
        if consumers.len() > 1 {
            return Err(StreamShapeError::NonLinear {
                node: n.name.clone(),
                reason: format!("its tensor has {} consumers (skip edge)", consumers.len()),
            });
        }
    }
    let specs = g.deconv_specs();
    if specs.is_empty() {
        return Err(StreamShapeError::NoDeconvs {
            graph: g.name.clone(),
        });
    }
    let mut shapes = Vec::with_capacity(specs.len());
    for spec in &specs {
        shapes.push(shape_of(spec)?);
    }
    for pair in shapes.windows(2) {
        if pair[0].out_frames != pair[1].in_frames {
            return Err(StreamShapeError::DepthChainBroken {
                producer: pair[0].name.clone(),
                emits: pair[0].out_frames,
                consumer: pair[1].name.clone(),
                consumes: pair[1].in_frames,
            });
        }
    }
    Ok(shapes)
}

/// The [`LayerStreamShape`] of one layer.
fn shape_of(spec: &LayerSpec) -> Result<LayerStreamShape, StreamShapeError> {
    if spec.k < spec.s {
        return Err(StreamShapeError::BadGeometry {
            layer: spec.name.clone(),
            k: spec.k,
            s: spec.s,
        });
    }
    let (in_frames, out_frames) = match spec.dims {
        Dims::D2 => (1, 1),
        Dims::D3 => (spec.in_d, spec.out_d()),
    };
    Ok(LayerStreamShape {
        name: spec.name.clone(),
        k_d: spec.k_d(),
        s: spec.s,
        halo_in: (spec.k_d() - 1) / spec.s,
        in_frames,
        out_frames,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dcnn::zoo;
    use crate::graph::passes;

    fn shapes_for(net: &crate::dcnn::Network) -> Vec<LayerStreamShape> {
        let g = passes::lower(&NetworkGraph::from_network(net)).unwrap();
        stream_shapes(&g).unwrap()
    }

    #[test]
    fn zoo_3d_halo_is_one_frame() {
        // K=3, S=2 everywhere: halo = (3-1)/2 = 1 retained frame.
        for net in [zoo::gan3d(), zoo::vnet()] {
            for (sh, l) in shapes_for(&net).iter().zip(&net.layers) {
                assert_eq!(sh.halo_in, 1, "{}", sh.name);
                assert_eq!(sh.k_d, 3);
                assert_eq!(sh.in_frames, l.in_d);
                assert_eq!(sh.out_frames, 2 * l.in_d);
            }
        }
    }

    #[test]
    fn zoo_2d_is_stateless_passthrough() {
        for sh in shapes_for(&zoo::dcgan()) {
            assert_eq!(sh.halo_in, 0, "{}", sh.name);
            assert_eq!(sh.k_d, 1);
            assert_eq!((sh.in_frames, sh.out_frames), (1, 1));
        }
    }

    #[test]
    fn contributor_window_matches_scatter() {
        let sh = LayerStreamShape {
            name: "t".into(),
            k_d: 3,
            s: 2,
            halo_in: 1,
            in_frames: 4,
            out_frames: 8,
        };
        // input id writes [2id, 2id+3): invert per output frame
        assert_eq!((sh.first_contributor(0), sh.last_contributor(0)), (0, 0));
        assert_eq!((sh.first_contributor(2), sh.last_contributor(2)), (0, 1));
        assert_eq!((sh.first_contributor(4), sh.last_contributor(4)), (1, 2));
        assert_eq!((sh.first_contributor(7), sh.last_contributor(7)), (3, 3));
        // emission boundary z = S·n is served once frame n arrives
        assert_eq!(sh.first_contributor(6), 2);
        // slab of a 2-frame chunk carries the 1-frame halo
        assert_eq!(sh.slab_frames(2), 3);
        assert_eq!(sh.slab_frames(4), 4, "whole depth caps the slab");
    }

    #[test]
    fn rejects_oom_form_and_bad_geometry() {
        let net = zoo::tiny_3d();
        let err = stream_shapes(&NetworkGraph::from_network_oom(&net)).unwrap_err();
        assert!(matches!(err, StreamShapeError::OomForm { .. }), "{err:?}");
        assert!(err.to_string().contains("OOM-form"), "{err}");

        let mut bad = zoo::tiny_3d();
        bad.layers[0].s = 5; // K=3 < S=5
        let g = NetworkGraph::from_network(&bad);
        let err = stream_shapes(&g).unwrap_err();
        assert!(
            matches!(err, StreamShapeError::BadGeometry { k: 3, s: 5, .. }),
            "{err:?}"
        );
        assert!(err.to_string().contains("K >= S"), "{err}");
    }

    #[test]
    fn non_linear_graph_gets_a_typed_error_naming_the_node() {
        use crate::dcnn::LayerSpec;
        use crate::graph::ir::TensorShape;
        // input -> a -> b, then concat(b, a): `a` has two consumers.
        let sp = |name: &str| LayerSpec::new_2d(name, 2, 4, 4, 2, 3, 1);
        let mut g = NetworkGraph::new("skippy", crate::dcnn::Dims::D2);
        let inp = g.add_node(
            "input",
            OpKind::Input {
                shape: TensorShape::new(2, 1, 4, 4),
            },
            &[],
        );
        let a = g.add_node("a", OpKind::Deconv { spec: sp("a") }, &[inp]);
        let b = g.add_node("b", OpKind::Deconv { spec: sp("b") }, &[a]);
        g.add_node("cat", OpKind::Concat, &[b, a]);
        let g = passes::lower(&g).unwrap();

        let err = stream_shapes(&g).unwrap_err();
        match &err {
            StreamShapeError::NonLinear { node, reason } => {
                // the first offender in topological order is the skip
                // tensor's producer `a` (two consumers: b and cat)
                assert_eq!(node, "a", "{err}");
                assert!(reason.contains("2 consumers"), "{reason}");
            }
            other => panic!("expected NonLinear, got {other:?}"),
        }
        assert!(err.to_string().contains("streaming supports only linear"), "{err}");
        // the error threads through String-error pipelines via From
        let as_string: String = err.into();
        assert!(as_string.contains("'a'"), "{as_string}");
    }

    #[test]
    fn re_depthed_chain_composes() {
        let net = zoo::gan3d().with_depth(10);
        let shapes = shapes_for(&net);
        assert_eq!(shapes[0].in_frames, 10);
        assert_eq!(shapes.last().unwrap().out_frames, 160);
        for pair in shapes.windows(2) {
            assert_eq!(pair[0].out_frames, pair[1].in_frames);
        }
    }
}
