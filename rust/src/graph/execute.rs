//! Numeric execution of a lowered graph through the uniform kernel
//! core.
//!
//! [`execute_f32`] walks a lowered (IOM-form) [`NetworkGraph`] in
//! topological order and computes its output with
//! [`crate::func::uniform`]: every `Deconv` node runs the
//! dimension-uniform threaded IOM kernel (2D graphs run as the depth-1
//! fold), the `K − S` edge is cropped at write-back, and fused
//! activations are applied in the write-back path — exactly the
//! semantics [`super::passes::fuse_activations`] claims are free in
//! hardware. Skip DAGs execute too: each node's value is kept alive
//! until its **last** consumer, and the merge/resample ops compute
//! with fixed, documented element orders so the results stay
//! bit-exact against a naively composed forward:
//!
//! * `Concat` — channel-axis concatenation in input order (the
//!   c-major layout makes this a flat data concatenation);
//! * `Add` — elementwise sum accumulated in input order (f32 addition
//!   is order-sensitive; the order is part of the contract);
//! * `MaxPool` — non-overlapping window max, scanned in `(d, h, w)`
//!   order;
//! * `Upsample` — nearest-neighbour replication.
//!
//! [`execute_q88`] is the Q8.8 mirror: saturating adds, `Ord`-exact
//! max-pooling, `Relu`-only activations (the transcendental
//! activations have no fixed-point datapath and error out).
//!
//! This is the numerical proof of the lowering pipeline: an OOM-form
//! graph, once [`super::passes::lower`]ed, computes bit-identical
//! outputs to the native IOM graph (asserted in the tests below), the
//! coordinator's golden forward produces the same values as an
//! executed graph, and `tests/diff_unet.rs` pins the DAG zoo entries
//! against an explicitly composed forward.

use crate::accel::KernelChoice;
use crate::dcnn::Dims;
use crate::fixed::Q88;
use crate::func::uniform;
use crate::tensor::{Volume, WeightsOIDHW};

use super::ir::{Act, NetworkGraph, OpKind};

/// Apply one pointwise activation in place (the PE write-back path).
pub fn apply_act(v: &mut Volume<f32>, act: Act) {
    for x in v.data_mut() {
        *x = match act {
            Act::Relu => x.max(0.0),
            Act::Tanh => x.tanh(),
            Act::Sigmoid => 1.0 / (1.0 + (-*x).exp()),
        };
    }
}

/// [`apply_act`] on Q8.8. Only `Relu` has a fixed-point datapath
/// (`max` against zero is exact); the transcendental activations
/// error rather than silently de-quantizing.
pub fn apply_act_q(v: &mut Volume<Q88>, act: Act) -> Result<(), String> {
    match act {
        Act::Relu => {
            for x in v.data_mut() {
                *x = (*x).max(Q88::ZERO);
            }
            Ok(())
        }
        other => Err(format!("activation {other} has no Q8.8 datapath")),
    }
}

/// Consume one use of node `src`'s value: the value is handed out by
/// move on its last remaining use and by clone before that, so a skip
/// tensor read by both the chain and a later `Concat` stays alive
/// exactly as long as it has readers.
fn use_value<T: Clone>(
    values: &mut [Option<Volume<T>>],
    remaining: &mut [usize],
    src: usize,
    name: &str,
) -> Result<Volume<T>, String> {
    if values[src].is_none() || remaining[src] == 0 {
        return Err(format!(
            "node '{name}': input value of node {src} is gone (graph not topologically ordered?)"
        ));
    }
    remaining[src] -= 1;
    if remaining[src] == 0 {
        Ok(values[src].take().expect("value present"))
    } else {
        Ok(values[src].clone().expect("value present"))
    }
}

/// Channel-axis concatenation in input order. The uniform `(c, d, h,
/// w)` layout is c-major, so this is a flat data concatenation.
fn concat_channels<T: Copy + Default>(
    parts: Vec<Volume<T>>,
    name: &str,
) -> Result<Volume<T>, String> {
    let (d, h, w) = (parts[0].d, parts[0].h, parts[0].w);
    let mut c = 0;
    for p in &parts {
        if (p.d, p.h, p.w) != (d, h, w) {
            return Err(format!(
                "node '{name}': concat operand is {}x{}x{}x{}, spatial extents differ",
                p.c, p.d, p.h, p.w
            ));
        }
        c += p.c;
    }
    let mut data = Vec::with_capacity(c * d * h * w);
    for p in &parts {
        data.extend_from_slice(p.data());
    }
    Ok(Volume::from_vec(c, d, h, w, data))
}

/// Elementwise sum accumulated in input order (the order is part of
/// the bit-exactness contract for f32; Q8.8 saturating adds commute
/// per pair but saturation makes the fold order observable too).
fn add_elementwise<T>(mut parts: Vec<Volume<T>>, name: &str) -> Result<Volume<T>, String>
where
    T: Copy + Default + std::ops::Add<Output = T>,
{
    let mut acc = parts.remove(0);
    for p in parts {
        if (p.c, p.d, p.h, p.w) != (acc.c, acc.d, acc.h, acc.w) {
            return Err(format!(
                "node '{name}': add operand is {}x{}x{}x{}, shape differs",
                p.c, p.d, p.h, p.w
            ));
        }
        for (a, b) in acc.data_mut().iter_mut().zip(p.data()) {
            *a = *a + *b;
        }
    }
    Ok(acc)
}

/// Non-overlapping max-pooling: window = stride = `k` per spatial
/// axis (`kd` on depth — 1 for 2D graphs).
fn max_pool<T: Copy + Default + PartialOrd>(
    v: &Volume<T>,
    k: usize,
    kd: usize,
    name: &str,
) -> Result<Volume<T>, String> {
    if k == 0 || kd == 0 || v.d % kd != 0 || v.h % k != 0 || v.w % k != 0 {
        return Err(format!(
            "node '{name}': max_pool window {k} does not divide input {}x{}x{}x{}",
            v.c, v.d, v.h, v.w
        ));
    }
    let (od, oh, ow) = (v.d / kd, v.h / k, v.w / k);
    let mut out = Volume::zeros(v.c, od, oh, ow);
    for c in 0..v.c {
        for z in 0..od {
            for y in 0..oh {
                for x in 0..ow {
                    let mut m = v.at(c, z * kd, y * k, x * k);
                    for dz in 0..kd {
                        for dy in 0..k {
                            for dx in 0..k {
                                let cand = v.at(c, z * kd + dz, y * k + dy, x * k + dx);
                                if cand > m {
                                    m = cand;
                                }
                            }
                        }
                    }
                    *out.at_mut(c, z, y, x) = m;
                }
            }
        }
    }
    Ok(out)
}

/// Nearest-neighbour upsample by integer factor `f` per spatial axis
/// (`fd` on depth — 1 for 2D graphs).
fn upsample_nearest<T: Copy + Default>(
    v: &Volume<T>,
    f: usize,
    fd: usize,
    name: &str,
) -> Result<Volume<T>, String> {
    if f == 0 || fd == 0 {
        return Err(format!("node '{name}': upsample factor must be >= 1"));
    }
    let (od, oh, ow) = (v.d * fd, v.h * f, v.w * f);
    let mut out = Volume::zeros(v.c, od, oh, ow);
    for c in 0..v.c {
        for z in 0..od {
            for y in 0..oh {
                for x in 0..ow {
                    *out.at_mut(c, z, y, x) = v.at(c, z / fd, y / f, x / f);
                }
            }
        }
    }
    Ok(out)
}

/// Execute a lowered (IOM-form) graph on `input`, with one weight set
/// per `Deconv` node in topological order. `threads` bounds the scoped
/// worker threads each deconvolution shards its output channels
/// across; results are bit-identical for every thread count.
///
/// Errors on OOM-form nodes (run [`super::passes::lower`] first) and
/// weight/shape mismatches. Skip DAGs (multi-consumer tensors,
/// `Concat`/`Add`/`MaxPool`/`Upsample` merges) execute natively.
pub fn execute_f32(
    g: &NetworkGraph,
    weights: &[WeightsOIDHW<f32>],
    input: &Volume<f32>,
    threads: usize,
) -> Result<Volume<f32>, String> {
    execute_f32_kernels(g, weights, input, threads, &[])
}

/// [`execute_f32`] with an explicit per-deconv kernel choice, in node
/// order (as recorded by a compiled plan's steps). Missing entries
/// default to scatter, so `&[]` is exactly [`execute_f32`]. Both
/// kernels are bit-exact by the accumulation-order contract
/// ([`crate::func::uniform`]), so this only changes *how* the same
/// bits are produced — which is precisely what the kernel differential
/// batteries assert.
pub fn execute_f32_kernels(
    g: &NetworkGraph,
    weights: &[WeightsOIDHW<f32>],
    input: &Volume<f32>,
    threads: usize,
    kernels: &[KernelChoice],
) -> Result<Volume<f32>, String> {
    let mut values: Vec<Option<Volume<f32>>> = vec![None; g.nodes.len()];
    let mut remaining: Vec<usize> = vec![0; g.nodes.len()];
    for n in &g.nodes {
        for &s in &n.inputs {
            remaining[s] += 1;
        }
    }
    let kd_of = |k: usize| if g.dims == Dims::D3 { k } else { 1 };
    let mut wi = 0usize;
    let mut last = None;
    for n in &g.nodes {
        let mut out = match &n.op {
            OpKind::Input { shape } => {
                if (input.c, input.d, input.h, input.w) != (shape.c, shape.d, shape.h, shape.w) {
                    return Err(format!(
                        "input is {}x{}x{}x{} but graph '{}' expects {shape} (c×d×h×w)",
                        input.c, input.d, input.h, input.w, g.name
                    ));
                }
                input.clone()
            }
            OpKind::Deconv { spec } => {
                let src = use_value(&mut values, &mut remaining, n.inputs[0], &n.name)?;
                let w = weights.get(wi).ok_or_else(|| {
                    format!(
                        "no weights for deconv node '{}' (got {} sets)",
                        n.name,
                        weights.len()
                    )
                })?;
                let kernel = kernels.get(wi).copied().unwrap_or_default();
                wi += 1;
                if (w.o, w.i, w.kd, w.kh, w.kw)
                    != (spec.out_c, spec.in_c, spec.k_d(), spec.k, spec.k)
                {
                    return Err(format!("weights for '{}' do not match its layer spec", n.name));
                }
                match kernel {
                    KernelChoice::Scatter => {
                        let full = uniform::deconv_iom_threaded(&src, w, spec.s, threads);
                        uniform::crop(&full, spec.out_d(), spec.out_h(), spec.out_w())
                    }
                    KernelChoice::Gather => uniform::deconv_gather_window_threaded(
                        &src,
                        w,
                        spec.s,
                        0,
                        spec.out_d(),
                        spec.out_h(),
                        spec.out_w(),
                        threads,
                    ),
                }
            }
            OpKind::Activation { act } => {
                let mut v = use_value(&mut values, &mut remaining, n.inputs[0], &n.name)?;
                apply_act(&mut v, *act);
                v
            }
            OpKind::Concat => {
                let mut parts = Vec::with_capacity(n.inputs.len());
                for &s in &n.inputs {
                    parts.push(use_value(&mut values, &mut remaining, s, &n.name)?);
                }
                concat_channels(parts, &n.name)?
            }
            OpKind::Add => {
                let mut parts = Vec::with_capacity(n.inputs.len());
                for &s in &n.inputs {
                    parts.push(use_value(&mut values, &mut remaining, s, &n.name)?);
                }
                add_elementwise(parts, &n.name)?
            }
            OpKind::MaxPool { k } => {
                let v = use_value(&mut values, &mut remaining, n.inputs[0], &n.name)?;
                max_pool(&v, *k, kd_of(*k), &n.name)?
            }
            OpKind::Upsample { f } => {
                let v = use_value(&mut values, &mut remaining, n.inputs[0], &n.name)?;
                upsample_nearest(&v, *f, kd_of(*f), &n.name)?
            }
            OpKind::ZeroInsert { .. } | OpKind::Conv { .. } => {
                return Err(format!(
                    "node '{}' is OOM-form; run passes::lower before execute_f32",
                    n.name
                ));
            }
        };
        for a in &n.fused {
            apply_act(&mut out, *a);
        }
        values[n.id] = Some(out);
        last = Some(n.id);
    }
    match last {
        Some(id) => Ok(values[id].take().expect("final node value present")),
        None => Err("cannot execute an empty graph".to_string()),
    }
}

/// Q8.8 mirror of [`execute_f32`]: the fixed-point kernels accumulate
/// wide (one `Acc48` per output element, one convergent rounding at
/// write-back) and the merge ops use saturating adds and `Ord`-exact
/// max — the datapath the accelerator actually ships.
pub fn execute_q88(
    g: &NetworkGraph,
    weights: &[WeightsOIDHW<Q88>],
    input: &Volume<Q88>,
    threads: usize,
) -> Result<Volume<Q88>, String> {
    execute_q88_kernels(g, weights, input, threads, &[])
}

/// [`execute_q88`] with an explicit per-deconv kernel choice, in node
/// order; missing entries default to scatter. Bit-exact across
/// choices and thread counts by the same accumulation-order contract
/// as the f32 path.
pub fn execute_q88_kernels(
    g: &NetworkGraph,
    weights: &[WeightsOIDHW<Q88>],
    input: &Volume<Q88>,
    threads: usize,
    kernels: &[KernelChoice],
) -> Result<Volume<Q88>, String> {
    let mut values: Vec<Option<Volume<Q88>>> = vec![None; g.nodes.len()];
    let mut remaining: Vec<usize> = vec![0; g.nodes.len()];
    for n in &g.nodes {
        for &s in &n.inputs {
            remaining[s] += 1;
        }
    }
    let kd_of = |k: usize| if g.dims == Dims::D3 { k } else { 1 };
    let mut wi = 0usize;
    let mut last = None;
    for n in &g.nodes {
        let mut out = match &n.op {
            OpKind::Input { shape } => {
                if (input.c, input.d, input.h, input.w) != (shape.c, shape.d, shape.h, shape.w) {
                    return Err(format!(
                        "input is {}x{}x{}x{} but graph '{}' expects {shape} (c×d×h×w)",
                        input.c, input.d, input.h, input.w, g.name
                    ));
                }
                input.clone()
            }
            OpKind::Deconv { spec } => {
                let src = use_value(&mut values, &mut remaining, n.inputs[0], &n.name)?;
                let w = weights.get(wi).ok_or_else(|| {
                    format!(
                        "no weights for deconv node '{}' (got {} sets)",
                        n.name,
                        weights.len()
                    )
                })?;
                let kernel = kernels.get(wi).copied().unwrap_or_default();
                wi += 1;
                if (w.o, w.i, w.kd, w.kh, w.kw)
                    != (spec.out_c, spec.in_c, spec.k_d(), spec.k, spec.k)
                {
                    return Err(format!("weights for '{}' do not match its layer spec", n.name));
                }
                match kernel {
                    KernelChoice::Scatter => {
                        let full = uniform::deconv_iom_q_threaded(&src, w, spec.s, threads);
                        uniform::crop(&full, spec.out_d(), spec.out_h(), spec.out_w())
                    }
                    KernelChoice::Gather => uniform::deconv_gather_window_q_threaded(
                        &src,
                        w,
                        spec.s,
                        0,
                        spec.out_d(),
                        spec.out_h(),
                        spec.out_w(),
                        threads,
                    ),
                }
            }
            OpKind::Activation { act } => {
                let mut v = use_value(&mut values, &mut remaining, n.inputs[0], &n.name)?;
                apply_act_q(&mut v, *act)?;
                v
            }
            OpKind::Concat => {
                let mut parts = Vec::with_capacity(n.inputs.len());
                for &s in &n.inputs {
                    parts.push(use_value(&mut values, &mut remaining, s, &n.name)?);
                }
                concat_channels(parts, &n.name)?
            }
            OpKind::Add => {
                let mut parts = Vec::with_capacity(n.inputs.len());
                for &s in &n.inputs {
                    parts.push(use_value(&mut values, &mut remaining, s, &n.name)?);
                }
                add_elementwise(parts, &n.name)?
            }
            OpKind::MaxPool { k } => {
                let v = use_value(&mut values, &mut remaining, n.inputs[0], &n.name)?;
                max_pool(&v, *k, kd_of(*k), &n.name)?
            }
            OpKind::Upsample { f } => {
                let v = use_value(&mut values, &mut remaining, n.inputs[0], &n.name)?;
                upsample_nearest(&v, *f, kd_of(*f), &n.name)?
            }
            OpKind::ZeroInsert { .. } | OpKind::Conv { .. } => {
                return Err(format!(
                    "node '{}' is OOM-form; run passes::lower before execute_q88",
                    n.name
                ));
            }
        };
        for a in &n.fused {
            apply_act_q(&mut out, *a)?;
        }
        values[n.id] = Some(out);
        last = Some(n.id);
    }
    match last {
        Some(id) => Ok(values[id].take().expect("final node value present")),
        None => Err("cannot execute an empty graph".to_string()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dcnn::{zoo, LayerData, Network};
    use crate::graph::{passes, NetworkGraph};

    fn synth_weights(net: &Network) -> Vec<WeightsOIDHW<f32>> {
        net.layers
            .iter()
            .enumerate()
            .map(|(i, l)| LayerData::synth(l, 0x5EED ^ (i as u64)).uniform_weights())
            .collect()
    }

    fn synth_input(net: &Network) -> Volume<f32> {
        LayerData::synth(&net.layers[0], 99).uniform_input()
    }

    #[test]
    fn lowered_oom_graph_equals_native_iom_graph() {
        for net in [zoo::tiny_2d(), zoo::tiny_3d()] {
            let weights = synth_weights(&net);
            let input = synth_input(&net);
            let native = passes::lower(&NetworkGraph::from_network(&net)).unwrap();
            let lowered = passes::lower(&NetworkGraph::from_network_oom(&net)).unwrap();
            let a = execute_f32(&native, &weights, &input, 2).unwrap();
            let b = execute_f32(&lowered, &weights, &input, 2).unwrap();
            assert_eq!(a.data(), b.data(), "{}", net.name);
        }
    }

    #[test]
    fn execution_matches_per_layer_golden_loop() {
        let net = zoo::tiny_3d();
        let weights = synth_weights(&net);
        let input = synth_input(&net);
        let g = passes::lower(&NetworkGraph::from_network(&net)).unwrap();
        let got = execute_f32(&g, &weights, &input, 4).unwrap();

        let mut cur = input;
        for (layer, w) in net.layers.iter().zip(&weights) {
            let full = uniform::deconv_iom(&cur, w, layer.s);
            cur = uniform::crop(&full, layer.out_d(), layer.out_h(), layer.out_w());
        }
        assert_eq!(got.data(), cur.data());
    }

    #[test]
    fn fused_activations_match_unfused() {
        let net = zoo::tiny_2d();
        let weights = synth_weights(&net);
        let input = synth_input(&net);
        // unfused: explicit Activation nodes
        let raw = NetworkGraph::from_network_with_activations(&net, Act::Relu);
        let mut unfused = raw.clone();
        passes::infer_shapes(&mut unfused).unwrap();
        // fused: the standard lowering folds them into the deconvs
        let fused = passes::lower(&raw).unwrap();
        assert!(fused.len() < unfused.len(), "fusion removed nodes");
        let a = execute_f32(&unfused, &weights, &input, 2).unwrap();
        let b = execute_f32(&fused, &weights, &input, 2).unwrap();
        assert_eq!(a.data(), b.data());
        assert!(a.data().iter().all(|&x| x >= 0.0), "relu clamps negatives");
    }

    #[test]
    fn gather_kernels_execute_bit_identically() {
        for net in [zoo::tiny_2d(), zoo::tiny_3d()] {
            let weights = synth_weights(&net);
            let input = synth_input(&net);
            let g = passes::lower(&NetworkGraph::from_network(&net)).unwrap();
            let scatter = execute_f32(&g, &weights, &input, 2).unwrap();
            let all_gather = vec![KernelChoice::Gather; net.layers.len()];
            let gather = execute_f32_kernels(&g, &weights, &input, 2, &all_gather).unwrap();
            assert_eq!(scatter.data(), gather.data(), "{}", net.name);
            // mixed per-layer choices are equally exact
            let mut mixed = all_gather;
            mixed[0] = KernelChoice::Scatter;
            let m = execute_f32_kernels(&g, &weights, &input, 3, &mixed).unwrap();
            assert_eq!(scatter.data(), m.data(), "{}", net.name);
        }
    }

    #[test]
    fn q88_execution_matches_per_layer_golden_loop() {
        for net in [zoo::tiny_2d(), zoo::tiny_3d()] {
            let weights: Vec<WeightsOIDHW<Q88>> = net
                .layers
                .iter()
                .enumerate()
                .map(|(i, l)| {
                    LayerData::synth(l, 0x5EED ^ (i as u64))
                        .quantize()
                        .uniform_weights()
                })
                .collect();
            let input_q = LayerData::synth(&net.layers[0], 99).quantize();
            let input = input_q.uniform_input();
            let g = passes::lower(&NetworkGraph::from_network(&net)).unwrap();
            let got = execute_q88(&g, &weights, &input, 3).unwrap();

            let mut cur = input;
            for (layer, w) in net.layers.iter().zip(&weights) {
                let full = uniform::deconv_iom_q(&cur, w, layer.s);
                cur = uniform::crop(&full, layer.out_d(), layer.out_h(), layer.out_w());
            }
            assert_eq!(got.data(), cur.data(), "{}", net.name);
        }
    }

    #[test]
    fn oom_form_graph_is_rejected_before_lowering() {
        let net = zoo::tiny_2d();
        let g = NetworkGraph::from_network_oom(&net);
        let err = execute_f32(&g, &synth_weights(&net), &synth_input(&net), 1).unwrap_err();
        assert!(err.contains("OOM-form"), "{err}");
    }

    #[test]
    fn wrong_input_shape_is_rejected() {
        let net = zoo::tiny_2d();
        let g = passes::lower(&NetworkGraph::from_network(&net)).unwrap();
        let bad = Volume::zeros(1, 1, 2, 2);
        let err = execute_f32(&g, &synth_weights(&net), &bad, 1).unwrap_err();
        assert!(err.contains("expects"), "{err}");
    }

    #[test]
    fn move_op_numerics() {
        // concat = flat data concat in input order (c-major layout)
        let a = Volume::from_vec(1, 1, 1, 2, vec![1.0f32, 2.0]);
        let b = Volume::from_vec(2, 1, 1, 2, vec![3.0, 4.0, 5.0, 6.0]);
        let cat = concat_channels(vec![a.clone(), b], "cat").unwrap();
        assert_eq!((cat.c, cat.d, cat.h, cat.w), (3, 1, 1, 2));
        assert_eq!(cat.data(), &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        // add accumulates in input order
        let c = Volume::from_vec(1, 1, 1, 2, vec![10.0f32, 20.0]);
        let sum = add_elementwise(vec![a, c], "add").unwrap();
        assert_eq!(sum.data(), &[11.0, 22.0]);
        // 2x2 max-pool picks the window max
        let v = Volume::from_vec(1, 1, 2, 2, vec![1.0f32, 4.0, 3.0, 2.0]);
        let p = max_pool(&v, 2, 1, "pool").unwrap();
        assert_eq!(p.data(), &[4.0]);
        // nearest upsample replicates
        let u = upsample_nearest(&p, 2, 1, "up").unwrap();
        assert_eq!(u.data(), &[4.0; 4]);
        // Q8.8 max-pool is Ord-exact
        let vq = Volume::from_vec(
            1,
            1,
            2,
            2,
            vec![
                Q88::from_f32(-1.0),
                Q88::from_f32(0.5),
                Q88::from_f32(0.25),
                Q88::from_f32(-2.0),
            ],
        );
        let pq = max_pool(&vq, 2, 1, "poolq").unwrap();
        assert_eq!(pq.data(), &[Q88::from_f32(0.5)]);
    }

    #[test]
    fn skip_dag_keeps_the_shared_tensor_alive() {
        use crate::dcnn::LayerSpec;
        use crate::graph::ir::TensorShape;
        // input -> a -> b -> concat(b, a): `a` is read twice.
        let sp = |name: &str, in_c: usize, out_c: usize| {
            LayerSpec::new_2d(name, in_c, 4, 4, out_c, 3, 1)
        };
        let mut g = NetworkGraph::new("skip", crate::dcnn::Dims::D2);
        let inp = g.add_node(
            "input",
            OpKind::Input {
                shape: TensorShape::new(2, 1, 4, 4),
            },
            &[],
        );
        let a = g.add_node("a", OpKind::Deconv { spec: sp("a", 2, 2) }, &[inp]);
        let b = g.add_node("b", OpKind::Deconv { spec: sp("b", 2, 2) }, &[a]);
        g.add_node("cat", OpKind::Concat, &[b, a]);
        let g = passes::lower(&g).unwrap();

        let specs: Vec<LayerSpec> = vec![sp("a", 2, 2), sp("b", 2, 2)];
        let weights: Vec<WeightsOIDHW<f32>> = specs
            .iter()
            .enumerate()
            .map(|(i, l)| LayerData::synth(l, 0x5EED ^ (i as u64)).uniform_weights())
            .collect();
        let input = LayerData::synth(&specs[0], 99).uniform_input();
        let got = execute_f32(&g, &weights, &input, 2).unwrap();

        // composed by hand
        let full_a = uniform::deconv_iom(&input, &weights[0], 1);
        let va = uniform::crop(&full_a, 1, 4, 4);
        let full_b = uniform::deconv_iom(&va, &weights[1], 1);
        let vb = uniform::crop(&full_b, 1, 4, 4);
        let want = concat_channels(vec![vb, va], "cat").unwrap();
        assert_eq!(got.data(), want.data());
    }
}
