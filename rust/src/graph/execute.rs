//! Numeric execution of a lowered graph through the uniform kernel
//! core.
//!
//! [`execute_f32`] walks a lowered (IOM-form) [`NetworkGraph`] and
//! computes its output with [`crate::func::uniform`]: every `Deconv`
//! node runs the dimension-uniform threaded IOM kernel (2D graphs run
//! as the depth-1 fold), the `K − S` edge is cropped at write-back,
//! and fused activations are applied in the write-back path — exactly
//! the semantics [`super::passes::fuse_activations`] claims are free
//! in hardware.
//!
//! This is the numerical proof of the lowering pipeline: an OOM-form
//! graph, once [`super::passes::lower`]ed, computes bit-identical
//! outputs to the native IOM graph (asserted in the tests below), and
//! the coordinator's golden forward produces the same values as an
//! executed graph.

use crate::accel::KernelChoice;
use crate::func::uniform;
use crate::tensor::{Volume, WeightsOIDHW};

use super::ir::{Act, NetworkGraph, OpKind};

/// Apply one pointwise activation in place (the PE write-back path).
pub fn apply_act(v: &mut Volume<f32>, act: Act) {
    for x in v.data_mut() {
        *x = match act {
            Act::Relu => x.max(0.0),
            Act::Tanh => x.tanh(),
            Act::Sigmoid => 1.0 / (1.0 + (-*x).exp()),
        };
    }
}

fn take_value(
    values: &mut [Option<Volume<f32>>],
    src: usize,
    name: &str,
) -> Result<Volume<f32>, String> {
    values[src].take().ok_or_else(|| {
        format!("node '{name}': input already consumed (single-consumer chains only)")
    })
}

/// Execute a lowered (IOM-form) graph on `input`, with one weight set
/// per `Deconv` node in topological order. `threads` bounds the scoped
/// worker threads each deconvolution shards its output channels
/// across; results are bit-identical for every thread count.
///
/// Errors on OOM-form nodes (run [`super::passes::lower`] first),
/// weight/shape mismatches, and non-chain graphs.
pub fn execute_f32(
    g: &NetworkGraph,
    weights: &[WeightsOIDHW<f32>],
    input: &Volume<f32>,
    threads: usize,
) -> Result<Volume<f32>, String> {
    execute_f32_kernels(g, weights, input, threads, &[])
}

/// [`execute_f32`] with an explicit per-deconv kernel choice, in node
/// order (as recorded by a compiled plan's steps). Missing entries
/// default to scatter, so `&[]` is exactly [`execute_f32`]. Both
/// kernels are bit-exact by the accumulation-order contract
/// ([`crate::func::uniform`]), so this only changes *how* the same
/// bits are produced — which is precisely what the kernel differential
/// batteries assert.
pub fn execute_f32_kernels(
    g: &NetworkGraph,
    weights: &[WeightsOIDHW<f32>],
    input: &Volume<f32>,
    threads: usize,
    kernels: &[KernelChoice],
) -> Result<Volume<f32>, String> {
    let mut values: Vec<Option<Volume<f32>>> = vec![None; g.nodes.len()];
    let mut wi = 0usize;
    let mut last = None;
    for n in &g.nodes {
        let mut out = match &n.op {
            OpKind::Input { shape } => {
                if (input.c, input.d, input.h, input.w) != (shape.c, shape.d, shape.h, shape.w) {
                    return Err(format!(
                        "input is {}x{}x{}x{} but graph '{}' expects {shape} (c×d×h×w)",
                        input.c, input.d, input.h, input.w, g.name
                    ));
                }
                input.clone()
            }
            OpKind::Deconv { spec } => {
                let src = take_value(&mut values, n.inputs[0], &n.name)?;
                let w = weights.get(wi).ok_or_else(|| {
                    format!(
                        "no weights for deconv node '{}' (got {} sets)",
                        n.name,
                        weights.len()
                    )
                })?;
                let kernel = kernels.get(wi).copied().unwrap_or_default();
                wi += 1;
                if (w.o, w.i, w.kd, w.kh, w.kw)
                    != (spec.out_c, spec.in_c, spec.k_d(), spec.k, spec.k)
                {
                    return Err(format!("weights for '{}' do not match its layer spec", n.name));
                }
                match kernel {
                    KernelChoice::Scatter => {
                        let full = uniform::deconv_iom_threaded(&src, w, spec.s, threads);
                        uniform::crop(&full, spec.out_d(), spec.out_h(), spec.out_w())
                    }
                    KernelChoice::Gather => uniform::deconv_gather_window_threaded(
                        &src,
                        w,
                        spec.s,
                        0,
                        spec.out_d(),
                        spec.out_h(),
                        spec.out_w(),
                        threads,
                    ),
                }
            }
            OpKind::Activation { act } => {
                let mut v = take_value(&mut values, n.inputs[0], &n.name)?;
                apply_act(&mut v, *act);
                v
            }
            OpKind::ZeroInsert { .. } | OpKind::Conv { .. } => {
                return Err(format!(
                    "node '{}' is OOM-form; run passes::lower before execute_f32",
                    n.name
                ));
            }
        };
        for a in &n.fused {
            apply_act(&mut out, *a);
        }
        values[n.id] = Some(out);
        last = Some(n.id);
    }
    match last {
        Some(id) => Ok(values[id].take().expect("final node value present")),
        None => Err("cannot execute an empty graph".to_string()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dcnn::{zoo, LayerData, Network};
    use crate::graph::{passes, NetworkGraph};

    fn synth_weights(net: &Network) -> Vec<WeightsOIDHW<f32>> {
        net.layers
            .iter()
            .enumerate()
            .map(|(i, l)| LayerData::synth(l, 0x5EED ^ (i as u64)).uniform_weights())
            .collect()
    }

    fn synth_input(net: &Network) -> Volume<f32> {
        LayerData::synth(&net.layers[0], 99).uniform_input()
    }

    #[test]
    fn lowered_oom_graph_equals_native_iom_graph() {
        for net in [zoo::tiny_2d(), zoo::tiny_3d()] {
            let weights = synth_weights(&net);
            let input = synth_input(&net);
            let native = passes::lower(&NetworkGraph::from_network(&net)).unwrap();
            let lowered = passes::lower(&NetworkGraph::from_network_oom(&net)).unwrap();
            let a = execute_f32(&native, &weights, &input, 2).unwrap();
            let b = execute_f32(&lowered, &weights, &input, 2).unwrap();
            assert_eq!(a.data(), b.data(), "{}", net.name);
        }
    }

    #[test]
    fn execution_matches_per_layer_golden_loop() {
        let net = zoo::tiny_3d();
        let weights = synth_weights(&net);
        let input = synth_input(&net);
        let g = passes::lower(&NetworkGraph::from_network(&net)).unwrap();
        let got = execute_f32(&g, &weights, &input, 4).unwrap();

        let mut cur = input;
        for (layer, w) in net.layers.iter().zip(&weights) {
            let full = uniform::deconv_iom(&cur, w, layer.s);
            cur = uniform::crop(&full, layer.out_d(), layer.out_h(), layer.out_w());
        }
        assert_eq!(got.data(), cur.data());
    }

    #[test]
    fn fused_activations_match_unfused() {
        let net = zoo::tiny_2d();
        let weights = synth_weights(&net);
        let input = synth_input(&net);
        // unfused: explicit Activation nodes
        let raw = NetworkGraph::from_network_with_activations(&net, Act::Relu);
        let mut unfused = raw.clone();
        passes::infer_shapes(&mut unfused).unwrap();
        // fused: the standard lowering folds them into the deconvs
        let fused = passes::lower(&raw).unwrap();
        assert!(fused.len() < unfused.len(), "fusion removed nodes");
        let a = execute_f32(&unfused, &weights, &input, 2).unwrap();
        let b = execute_f32(&fused, &weights, &input, 2).unwrap();
        assert_eq!(a.data(), b.data());
        assert!(a.data().iter().all(|&x| x >= 0.0), "relu clamps negatives");
    }

    #[test]
    fn gather_kernels_execute_bit_identically() {
        for net in [zoo::tiny_2d(), zoo::tiny_3d()] {
            let weights = synth_weights(&net);
            let input = synth_input(&net);
            let g = passes::lower(&NetworkGraph::from_network(&net)).unwrap();
            let scatter = execute_f32(&g, &weights, &input, 2).unwrap();
            let all_gather = vec![KernelChoice::Gather; net.layers.len()];
            let gather = execute_f32_kernels(&g, &weights, &input, 2, &all_gather).unwrap();
            assert_eq!(scatter.data(), gather.data(), "{}", net.name);
            // mixed per-layer choices are equally exact
            let mut mixed = all_gather;
            mixed[0] = KernelChoice::Scatter;
            let m = execute_f32_kernels(&g, &weights, &input, 3, &mixed).unwrap();
            assert_eq!(scatter.data(), m.data(), "{}", net.name);
        }
    }

    #[test]
    fn oom_form_graph_is_rejected_before_lowering() {
        let net = zoo::tiny_2d();
        let g = NetworkGraph::from_network_oom(&net);
        let err = execute_f32(&g, &synth_weights(&net), &synth_input(&net), 1).unwrap_err();
        assert!(err.contains("OOM-form"), "{err}");
    }

    #[test]
    fn wrong_input_shape_is_rejected() {
        let net = zoo::tiny_2d();
        let g = passes::lower(&NetworkGraph::from_network(&net)).unwrap();
        let bad = Volume::zeros(1, 1, 2, 2);
        let err = execute_f32(&g, &synth_weights(&net), &bad, 1).unwrap_err();
        assert!(err.contains("expects"), "{err}");
    }
}
