//! Whole-network graph IR and compiler.
//!
//! The paper's headline numbers come from running *entire* DCNNs —
//! DCGAN, GP-GAN, 3D-GAN, the V-Net decoder — through one uniform
//! architecture. This subsystem models that at network granularity
//! instead of summing isolated layers:
//!
//! * [`ir`] — [`NetworkGraph`]: a DAG of ops (deconv in IOM or OOM
//!   form, activations, channel-concat / elementwise-add skip merges,
//!   max-pool and nearest-neighbour-upsample resampling) over explicit
//!   tensor edges, built from [`crate::dcnn::zoo`] networks (including
//!   the U-Net/UNETR skip topologies via
//!   [`crate::dcnn::Network::graph`]) or any
//!   [`crate::dcnn::LayerSpec`] chain;
//! * [`passes`] — validation, shape inference, OOM→IOM lowering,
//!   activation fusion ([`passes::lower`] is the default pipeline),
//!   all over topologically ordered multi-input nodes;
//! * [`plan`] — [`compile`] binds a lowered graph to an
//!   [`crate::accel::AccelConfig`]: per-node blocking schedules plus a
//!   linear-scan register allocation of on-chip buffers over DAG live
//!   ranges (a tensor stays resident from its producer to its *last*
//!   consumer — skip tensors survive the whole decoder — and spills to
//!   DDR when the arena is full);
//! * [`simulate`] — [`simulate_plan`] executes a [`NetworkPlan`] with
//!   cross-layer double-buffered prefetch overlap and reports
//!   end-to-end latency / TOPS / DDR traffic, move steps included;
//! * [`execute`] — [`execute_f32`] / [`execute_q88`] run a lowered
//!   graph *numerically* through the dimension-uniform kernel core
//!   ([`crate::func::uniform`]), proving the lowering pipeline
//!   preserves semantics; its tests cross-check it against the same
//!   per-layer loop the coordinator's golden forward runs.
//! * [`stream_shape`] — [`stream_shapes`] derives each layer's
//!   temporal-tiling geometry (depth halo, contributor windows,
//!   emission rate) from `K_d`/stride; [`crate::stream`] builds its
//!   per-layer halo state from this pass.
//!
//! **IOM vs OOM.** A deconvolution can be computed *output-oriented*
//! (OOM): insert `S−1` zeros between input activations, pad, and run a
//! dense convolution — simple, but most multiplies hit inserted zeros
//! (75 % for 2D, 87.5 % for 3D at `S = 2`; Fig. 1). The paper's
//! *input-oriented* mapping (IOM) instead scatters each real input
//! activation against the whole kernel and accumulates overlaps, so
//! every multiply is useful. The IR can express both forms: front ends
//! may emit the OOM decomposition (`ZeroInsert` + `Conv`), and the
//! lowering pass rewrites each pair into the accelerator's native
//! `Deconv` (IOM) node — same numerics, none of the wasted work.
//!
//! The CLI front end is `udcnn compile <net>`; the coordinator serves
//! compiled plans; `benches/e2e_network.rs` tracks the numbers.

pub mod execute;
pub mod ir;
pub mod passes;
pub mod plan;
pub mod simulate;
pub mod stream_shape;

pub use execute::{execute_f32, execute_f32_kernels, execute_q88, execute_q88_kernels};
pub use ir::{Act, NetworkGraph, NodeId, NodeSpec, OpKind, TensorShape};
pub use plan::{compile, compile_forced, BufferAlloc, EdgePlace, MovePlan, NetworkPlan, StepPlan};
pub use simulate::{simulate_plan, NetworkRunMetrics};
pub use stream_shape::{stream_shapes, LayerStreamShape, StreamShapeError};

use crate::accel::AccelConfig;
use crate::dcnn::Network;

/// A shared, immutable handle to a compiled plan.
///
/// Compiled plans are immutable once built, so the serving tier passes
/// them around by reference count instead of cloning the step list:
/// [`crate::serve::PlanCache`] hands the *same* handle to every
/// accelerator instance hosting the model.
pub type PlanHandle = std::sync::Arc<NetworkPlan>;

/// One-call front end: build the IOM graph of `net`, run the default
/// pass pipeline, and compile it onto `cfg`.
pub fn compile_network(cfg: &AccelConfig, net: &Network) -> Result<NetworkPlan, String> {
    compile_network_obs(cfg, net, &crate::obs::Obs::off())
}

/// [`compile_network`] with every step pinned to `forced` instead of
/// the per-layer kernel decision — the comparison baseline used by the
/// differential batteries and the kernel benches.
pub fn compile_network_forced(
    cfg: &AccelConfig,
    net: &Network,
    forced: crate::accel::KernelChoice,
) -> Result<NetworkPlan, String> {
    let g = passes::lower(&net.graph())?;
    compile_forced(cfg, &g, forced)
}

/// [`compile_network`] with observability: the whole compile runs
/// under a scoped span (track `compile`) whose arguments carry the
/// plan's buffer-reuse stats (reused edges, DRAM bytes saved), each
/// pass gets its own span via [`passes::lower_obs`], and the
/// `compile.plans` counter ticks once per compiled plan.
pub fn compile_network_obs(
    cfg: &AccelConfig,
    net: &Network,
    obs: &crate::obs::Obs,
) -> Result<NetworkPlan, String> {
    use crate::report::json::JsonObj;
    let track = obs.track("compile");
    let mut whole = obs.scope(track, "compile", &format!("compile {}", net.name));
    let g = passes::lower_obs(&net.graph(), obs)?;
    let plan = {
        let _s = obs.scope(track, "pass", "schedule_and_reuse");
        compile(cfg, &g)?
    };
    whole.set_args(
        JsonObj::new()
            .str("network", &plan.network)
            .int("steps", plan.steps.len() as u64)
            .int("batch", cfg.batch as u64)
            .int("reused_edges", plan.reused_edges() as u64)
            .int("dram_bytes", plan.total_dram_bytes())
            .int("dram_bytes_saved", plan.bytes_saved()),
    );
    obs.count("compile.plans", 1);
    Ok(plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dcnn::zoo;

    #[test]
    fn compile_network_front_end() {
        for net in zoo::all_benchmarks() {
            let cfg = AccelConfig::paper_for(net.dims);
            let plan = compile_network(&cfg, &net).unwrap();
            assert_eq!(plan.steps.len(), net.layers.len(), "{}", net.name);
            assert_eq!(plan.network, net.name);
        }
    }

    #[test]
    fn compile_network_routes_skip_topologies_through_the_dag() {
        for net in [zoo::unet3d(), zoo::unetr_dec()] {
            let cfg = AccelConfig::paper_for(net.dims);
            let plan = compile_network(&cfg, &net).unwrap();
            assert_eq!(plan.steps.len(), net.layers.len(), "{}", net.name);
            assert!(
                !plan.moves.is_empty(),
                "{}: skip topology should plan merge/resample moves",
                net.name
            );
            let m = simulate_plan(&plan);
            assert!(m.total_cycles > 0, "{}", net.name);
        }
    }
}
