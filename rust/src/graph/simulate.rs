//! Execute a [`NetworkPlan`]: end-to-end latency / TOPS / DDR traffic
//! at network granularity (Fig. 6/7 numbers without the isolated-layer
//! approximation).
//!
//! Per step the cycle model is the timing tier's (`max(compute,
//! memory)` under double buffering), but the layer-boundary edges
//! change:
//!
//! * interior boundaries lose their un-overlappable edge transfers —
//!   the next layer's first weight/input blocks prefetch during the
//!   current layer's steady state (cross-layer double buffering), and
//!   the previous layer's last output slice drains into the next
//!   layer's ramp-up;
//! * boundaries the reuse pass kept on-chip move no DDR traffic at
//!   all, shrinking the step's memory cycles;
//! * only the network's first load and final store remain exposed;
//! * weight-free merge/resample steps ([`super::plan::MovePlan`]) burn
//!   no MACs — they add pure DDR transfer cycles for whichever
//!   operands spilled, and nothing at all when the reuse pass kept the
//!   skip tensors on-chip.
//!
//! The per-step [`LayerMetrics`] plus the move cycles sum exactly to
//! the network total, so existing per-layer reporting keeps working on
//! plan output.

use crate::accel::memory::DdrModel;
use crate::accel::metrics::{dense_equivalent_macs, BoundBy, LayerMetrics};

use super::plan::{EdgePlace, NetworkPlan, StepPlan};

/// End-to-end metrics for one compiled network plan.
#[derive(Clone, Debug)]
pub struct NetworkRunMetrics {
    /// Network name.
    pub network: String,
    /// Per-step metrics (traffic-adjusted); totals sum to the network.
    pub steps: Vec<LayerMetrics>,
    /// End-to-end cycles for the whole batch.
    pub total_cycles: u64,
    /// Batch size the run covers.
    pub batch: usize,
    /// Clock for time conversion.
    pub freq_mhz: f64,
    /// Total DDR traffic (batch totals, after reuse, moves included).
    pub dram_bytes: u64,
    /// Cycles spent streaming the weight-free merge/resample (move)
    /// steps' spilled operands through DDR — zero on linear chains and
    /// whenever the reuse pass kept every skip tensor on-chip.
    pub move_cycles: u64,
    /// DDR bytes moved by the merge/resample steps alone.
    pub move_dram_bytes: u64,
    /// Dense-equivalent MACs per batch item, all layers.
    pub dense_macs: u64,
    /// Useful MACs per batch item, all layers.
    pub useful_macs: u64,
    /// PE count of the configuration.
    pub total_pes: usize,
}

impl NetworkRunMetrics {
    /// Wall-clock seconds for the whole batch.
    pub fn time_s(&self) -> f64 {
        self.total_cycles as f64 / (self.freq_mhz * 1e6)
    }

    /// Seconds per single inference.
    pub fn time_per_item_s(&self) -> f64 {
        self.time_s() / self.batch as f64
    }

    /// Network-level dense-equivalent TOPS (the paper's convention).
    pub fn effective_tops(&self) -> f64 {
        2.0 * self.dense_macs as f64 * self.batch as f64 / self.time_s() / 1e12
    }

    /// Network-level useful TOPS (bounded by the configuration peak).
    pub fn useful_tops(&self) -> f64 {
        2.0 * self.useful_macs as f64 * self.batch as f64 / self.time_s() / 1e12
    }

    /// Time-weighted average PE utilization.
    pub fn avg_pe_utilization(&self) -> f64 {
        if self.total_cycles == 0 {
            return 0.0;
        }
        self.steps
            .iter()
            .map(|m| m.pe_utilization() * m.total_cycles as f64)
            .sum::<f64>()
            / self.total_cycles as f64
    }

    /// Sustained DDR bandwidth over the whole run.
    pub fn dram_gbps(&self) -> f64 {
        self.dram_bytes as f64 / self.time_s() / 1e9
    }
}

/// First-load bytes of a step (one weight block + one input tile).
fn lead_in_bytes(plan: &NetworkPlan, s: &StepPlan) -> u64 {
    let eb = plan.cfg.elem_bytes() as u64;
    let m = &s.schedule.mapping;
    let w = (m.out_par * m.chan_par * s.layer.kernel_volume()) as u64 * eb;
    let i = if s.input_src == EdgePlace::Ddr {
        (m.chan_par * m.depth_par * plan.cfg.tr * plan.cfg.tc) as u64 * eb
    } else {
        0
    };
    w + i
}

/// Final-store bytes of a step (the last output slice).
fn tail_bytes(plan: &NetworkPlan, s: &StepPlan) -> u64 {
    if s.output_dst == EdgePlace::Ddr {
        let eb = plan.cfg.elem_bytes() as u64;
        (s.schedule.mapping.out_par * s.layer.out_spatial()) as u64 * eb
    } else {
        0
    }
}

/// Simulate a compiled plan end to end.
pub fn simulate_plan(plan: &NetworkPlan) -> NetworkRunMetrics {
    let cfg = &plan.cfg;
    let ddr = DdrModel::from_config(cfg);
    let last = plan.steps.len() - 1;

    let mut steps = Vec::with_capacity(plan.steps.len());
    let mut total_cycles = 0u64;
    for (i, s) in plan.steps.iter().enumerate() {
        let compute_cycles = s.compute_cycles(cfg);
        let memory_cycles = ddr.transfer_cycles(s.dram_bytes(), cfg.freq_mhz);
        // MACs the chosen kernel actually executes: the gather kernel
        // never issues the cropped border's taps, so its utilization
        // and useful-TOPS accounting must use gather_macs or the
        // ratios would exceed 1.0 / the peak.
        let executed_macs = match s.kernel.choice {
            crate::accel::KernelChoice::Scatter => s.layer.op_counts().useful_macs,
            crate::accel::KernelChoice::Gather => s.layer.gather_macs(),
        };
        let mut cycles = compute_cycles.max(memory_cycles);
        // Only the network edges stay exposed; interior boundaries
        // overlap with the neighbouring layers (see module docs).
        if i == 0 {
            cycles += ddr.transfer_cycles(lead_in_bytes(plan, s), cfg.freq_mhz);
        }
        if i == last {
            cycles += ddr.transfer_cycles(tail_bytes(plan, s), cfg.freq_mhz);
        }
        total_cycles += cycles;
        steps.push(LayerMetrics {
            layer_name: s.name.clone(),
            compute_cycles,
            memory_cycles,
            total_cycles: cycles,
            ideal_mac_cycles: cfg.batch as u64 * executed_macs,
            total_pes: cfg.total_pes(),
            batch: cfg.batch,
            dense_macs: dense_equivalent_macs(&s.layer),
            useful_macs: executed_macs,
            dram_bytes: s.dram_bytes(),
            bound_by: if memory_cycles > compute_cycles {
                BoundBy::Memory
            } else {
                BoundBy::Compute
            },
            freq_mhz: cfg.freq_mhz,
        });
    }

    // Merge/resample steps burn no MACs; their only cost is streaming
    // whichever operands the reuse pass could not keep on-chip.
    let mut move_cycles = 0u64;
    let mut move_dram_bytes = 0u64;
    for m in &plan.moves {
        move_cycles += ddr.transfer_cycles(m.dram_bytes(), cfg.freq_mhz);
        move_dram_bytes += m.dram_bytes();
    }
    total_cycles += move_cycles;

    NetworkRunMetrics {
        network: plan.network.clone(),
        total_cycles,
        batch: cfg.batch,
        freq_mhz: cfg.freq_mhz,
        dram_bytes: plan.total_dram_bytes(),
        dense_macs: plan.dense_macs(),
        useful_macs: steps.iter().map(|m| m.useful_macs).sum(),
        total_pes: cfg.total_pes(),
        move_cycles,
        move_dram_bytes,
        steps,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::{simulate_network, AccelConfig};
    use crate::dcnn::zoo;
    use crate::graph::ir::NetworkGraph;
    use crate::graph::passes::lower;
    use crate::graph::plan::compile;

    fn run(net: &crate::dcnn::Network) -> NetworkRunMetrics {
        let cfg = AccelConfig::paper_for(net.dims);
        let g = lower(&NetworkGraph::from_network(net)).unwrap();
        simulate_plan(&compile(&cfg, &g).unwrap())
    }

    #[test]
    fn pipelined_never_slower_than_isolated_sum() {
        for net in zoo::all_benchmarks() {
            let cfg = AccelConfig::paper_for(net.dims);
            let isolated = simulate_network(&cfg, &net);
            let plan = run(&net);
            assert!(
                plan.total_cycles <= isolated.total_cycles(),
                "{}: plan {} > isolated {}",
                net.name,
                plan.total_cycles,
                isolated.total_cycles()
            );
        }
    }

    #[test]
    fn e2e_tops_within_ten_percent_of_isolated() {
        // The acceptance band: pipelining and reuse refine, not
        // rewrite, the Fig. 6/7 numbers. The isolated model is
        // scatter-only, so the band is checked against the
        // forced-scatter plan; the auto plan (which may pick gather
        // per layer) must only ever be faster.
        for net in zoo::all_benchmarks() {
            let cfg = AccelConfig::paper_for(net.dims);
            let isolated = simulate_network(&cfg, &net).effective_tops();
            let scatter_plan = crate::graph::compile_network_forced(
                &cfg,
                &net,
                crate::accel::KernelChoice::Scatter,
            )
            .unwrap();
            let scatter = simulate_plan(&scatter_plan).effective_tops();
            let rel = (scatter - isolated).abs() / isolated;
            assert!(
                rel <= 0.10,
                "{}: plan {scatter:.3} vs isolated {isolated:.3} TOPS ({:.1}% apart)",
                net.name,
                100.0 * rel
            );
            let auto = run(&net).effective_tops();
            assert!(
                auto >= scatter - 1e-9,
                "{}: auto kernel choice ({auto:.3} TOPS) lost to scatter ({scatter:.3})",
                net.name
            );
        }
    }

    #[test]
    fn step_totals_sum_to_network_total() {
        for net in zoo::all_benchmarks() {
            let m = run(&net);
            let sum: u64 = m.steps.iter().map(|s| s.total_cycles).sum();
            assert_eq!(sum + m.move_cycles, m.total_cycles, "{}", net.name);
            let traffic: u64 = m.steps.iter().map(|s| s.dram_bytes).sum();
            assert_eq!(traffic + m.move_dram_bytes, m.dram_bytes, "{}", net.name);
            // benchmark decoders are linear chains: no move steps
            assert_eq!(m.move_cycles, 0, "{}", net.name);
        }
    }

    #[test]
    fn useful_tops_bounded_by_peak() {
        for net in zoo::all_benchmarks() {
            let cfg = AccelConfig::paper_for(net.dims);
            let m = run(&net);
            assert!(
                m.useful_tops() <= cfg.peak_tops() + 1e-9,
                "{}: {:.3} > peak {:.3}",
                net.name,
                m.useful_tops(),
                cfg.peak_tops()
            );
            let u = m.avg_pe_utilization();
            assert!((0.0..=1.0 + 1e-9).contains(&u), "{}: util {u}", net.name);
        }
    }

    #[test]
    fn reuse_shrinks_traffic_and_never_time() {
        let net = zoo::dcgan();
        let cfg = AccelConfig::paper_for(net.dims);
        let m = run(&net);
        let isolated = simulate_network(&cfg, &net);
        let isolated_traffic: u64 = isolated.layers.iter().map(|l| l.dram_bytes).sum();
        assert!(m.dram_bytes < isolated_traffic, "reuse fired for dcgan");
        assert!(m.time_s() <= isolated.total_time_s() + 1e-12);
    }
}
