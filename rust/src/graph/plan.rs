//! The [`NetworkPlan`] artifact: a lowered graph bound to one
//! accelerator configuration.
//!
//! [`compile`] sequences the deconvolution nodes of a lowered DAG in
//! topological order, derives each node's blocking [`Schedule`] and
//! operand [`Residency`], carries the weight-free merge/resampling
//! nodes (`Concat`/`Add`/`MaxPool`/`Upsample`) as [`MovePlan`] data
//! movements, and then runs the **inter-layer buffer-reuse pass** as
//! linear-scan register allocation over DAG live ranges:
//!
//! * every intermediate tensor gets a live range `[def, last_use]` in
//!   topological positions — a U-Net skip tensor's range spans the
//!   whole decoder between its producer and the `Concat` that finally
//!   consumes it;
//! * tensors small enough for the on-chip buffers are placed into one
//!   byte arena of capacity `input_buf + output_buf` by deterministic
//!   first-fit, and a buffer is released only after the tensor's
//!   **last** consumer has run (a node's output is allocated *before*
//!   its dying inputs are freed, so an output can never alias a tensor
//!   the node is still reading — the classic free-after-first-consume
//!   aliasing bug, pinned by `tests/prop_graph.rs`);
//! * placed tensors move zero DDR bytes on both sides of the edge;
//!   everything else spills to DDR exactly as in the isolated-layer
//!   model. On a linear chain this reproduces the historical
//!   edge-by-edge rule bit-for-bit (at most two tensors are ever live,
//!   each bounded by the smaller buffer).
//!
//! The plan records both the adjusted and the isolated traffic plus
//! the arena's peak footprint so the savings are auditable, renders as
//! human-readable text (the `udcnn compile` dump) and exports as JSON
//! via [`crate::report`].

use crate::accel::buffers::Residency;
use crate::accel::{kernel, AccelConfig, KernelChoice, KernelSelection, Schedule};
use crate::dcnn::LayerSpec;
use crate::report::json::JsonObj;

use super::ir::{Act, NetworkGraph, NodeId, OpKind};

/// Where a step's input/output tensor lives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EdgePlace {
    /// Kept in the on-chip buffers across the layer boundary.
    OnChip,
    /// Streamed through DDR.
    Ddr,
}

impl std::fmt::Display for EdgePlace {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EdgePlace::OnChip => write!(f, "on-chip"),
            EdgePlace::Ddr => write!(f, "DDR"),
        }
    }
}

/// One executable step of a network plan (one deconvolution layer).
#[derive(Clone, Debug)]
pub struct StepPlan {
    /// Node id in the lowered graph.
    pub node: NodeId,
    /// Layer name (from the graph node).
    pub name: String,
    /// Layer geometry.
    pub layer: LayerSpec,
    /// Blocking schedule on the bound configuration.
    pub schedule: Schedule,
    /// Per-layer kernel decision (scatter vs gather) with both
    /// kernels' modeled cycles as machine-readable justification.
    pub kernel: KernelSelection,
    /// Activations fused into this step's write-back.
    pub fused: Vec<Act>,
    /// Where the step reads its input tensor.
    pub input_src: EdgePlace,
    /// Where the step writes its output tensor.
    pub output_dst: EdgePlace,
    /// DDR traffic after reuse adjustment (batch totals).
    pub weight_bytes: u64,
    /// Input bytes after reuse adjustment.
    pub input_bytes: u64,
    /// Output bytes after reuse adjustment.
    pub output_bytes: u64,
    /// What the isolated-layer residency plan would have moved.
    pub isolated_dram_bytes: u64,
}

impl StepPlan {
    /// Total adjusted DDR traffic of this step.
    pub fn dram_bytes(&self) -> u64 {
        self.weight_bytes + self.input_bytes + self.output_bytes
    }

    /// Compute cycles of this step under its chosen kernel.
    pub fn compute_cycles(&self, cfg: &AccelConfig) -> u64 {
        kernel::compute_cycles(cfg, &self.layer, &self.schedule, self.kernel.choice)
    }
}

/// One weight-free data-movement step of a network plan: a `Concat`,
/// `Add`, `MaxPool` or `Upsample` node carried between the compute
/// steps. Moves burn no MACs; their cost is pure DDR traffic for
/// whichever operands the reuse pass could not keep on-chip.
#[derive(Clone, Debug)]
pub struct MovePlan {
    /// Node id in the lowered graph.
    pub node: NodeId,
    /// Node name (from the graph node).
    pub name: String,
    /// The merge/resample operation.
    pub op: OpKind,
    /// Where the result tensor is written.
    pub output_dst: EdgePlace,
    /// DDR bytes read for operands not already resident on-chip.
    pub input_bytes: u64,
    /// DDR bytes written when the result spills.
    pub output_bytes: u64,
    /// What an all-DDR execution of this node would have moved.
    pub isolated_dram_bytes: u64,
}

impl MovePlan {
    /// Total adjusted DDR traffic of this move.
    pub fn dram_bytes(&self) -> u64 {
        self.input_bytes + self.output_bytes
    }
}

/// One on-chip placement made by the linear-scan reuse pass: the
/// tensor produced by `node` occupies `[offset, offset + bytes)` of
/// the unified buffer arena from its definition until its **last**
/// consumer (`last_use`) has run. Exposed on the plan so tests can
/// prove no two overlapping live ranges ever share bytes — the
/// skip-tensor aliasing regression of `tests/prop_graph.rs`.
#[derive(Clone, Debug)]
pub struct BufferAlloc {
    /// Producer node id (the tensor's definition position).
    pub node: NodeId,
    /// Producer node name.
    pub name: String,
    /// Byte offset inside the arena.
    pub offset: u64,
    /// Tensor size in bytes (whole batch).
    pub bytes: u64,
    /// Topological position (node id) of the last consumer.
    pub last_use: NodeId,
}

/// A compiled whole-network execution plan.
#[derive(Clone, Debug)]
pub struct NetworkPlan {
    /// Network name.
    pub network: String,
    /// The configuration the plan is bound to.
    pub cfg: AccelConfig,
    /// Executable steps in topological order.
    pub steps: Vec<StepPlan>,
    /// Weight-free merge/resample steps in topological order (empty
    /// on linear chains).
    pub moves: Vec<MovePlan>,
    /// On-chip placements made by the linear-scan reuse pass.
    pub onchip: Vec<BufferAlloc>,
    /// High-water mark of the arena: the most bytes ever live at once.
    pub peak_onchip_bytes: u64,
}

/// Compile a lowered graph onto one configuration.
///
/// The graph must already be through [`super::passes::lower`]: only
/// `Input`, `Deconv` and the weight-free merge/resample ops may
/// remain. Linear chains and skip DAGs (U-Net, UNETR decoder) both
/// compile; unlowered `Conv`/`ZeroInsert`/`Activation` nodes are
/// rejected with a clear error rather than silently mis-planned.
///
/// Each step also gets a per-layer kernel decision
/// ([`kernel::choose`]): scatter vs zero-skip gather, scored under the
/// step's own compute and DDR terms, with both scores recorded on the
/// step as justification.
pub fn compile(cfg: &AccelConfig, g: &NetworkGraph) -> Result<NetworkPlan, String> {
    compile_with(cfg, g, None)
}

/// [`compile`] with every step pinned to one kernel instead of the
/// per-layer [`kernel::choose`] decision — the baseline the
/// scatter-vs-gather differential tests and benches compare against.
pub fn compile_forced(
    cfg: &AccelConfig,
    g: &NetworkGraph,
    forced: KernelChoice,
) -> Result<NetworkPlan, String> {
    compile_with(cfg, g, Some(forced))
}

fn compile_with(
    cfg: &AccelConfig,
    g: &NetworkGraph,
    forced: Option<KernelChoice>,
) -> Result<NetworkPlan, String> {
    cfg.validate()?;
    let eb = cfg.elem_bytes() as u64;
    let batch = cfg.batch as u64;

    // Whole-batch bytes of the tensor each node produces. Derivable
    // without shape inference for Input/Deconv (so hand-built chains
    // still compile un-inferred); merge/resample nodes need the shape
    // the lowering pipeline attached.
    let tensor_bytes = |id: NodeId| -> Result<u64, String> {
        let n = &g.nodes[id];
        match &n.op {
            OpKind::Input { shape } => Ok(batch * shape.elems() as u64 * eb),
            OpKind::Deconv { spec } => Ok(batch * spec.output_elems() as u64 * eb),
            _ => n
                .out_shape
                .map(|s| batch * s.elems() as u64 * eb)
                .ok_or_else(|| {
                    format!(
                        "node '{}' has no inferred shape; run graph::passes::lower before compile",
                        n.name
                    )
                }),
        }
    };

    let mut steps: Vec<StepPlan> = Vec::new();
    let mut moves: Vec<MovePlan> = Vec::new();
    for n in &g.nodes {
        match &n.op {
            OpKind::Input { .. } => {}
            OpKind::Deconv { spec } => {
                let schedule = Schedule::new(cfg, spec);
                let mut sel = kernel::choose(cfg, spec, &schedule);
                if let Some(k) = forced {
                    sel.choice = k;
                }
                let res = Residency::plan_kernel(cfg, spec, &schedule, sel.choice);
                steps.push(StepPlan {
                    node: n.id,
                    name: n.name.clone(),
                    layer: spec.clone(),
                    schedule,
                    kernel: sel,
                    fused: n.fused.clone(),
                    input_src: EdgePlace::Ddr,
                    output_dst: EdgePlace::Ddr,
                    weight_bytes: res.weight_bytes,
                    input_bytes: res.input_bytes,
                    output_bytes: res.output_bytes,
                    isolated_dram_bytes: res.dram_bytes,
                });
            }
            op if op.is_move() => {
                let mut input_bytes = 0u64;
                for &src in &n.inputs {
                    input_bytes += tensor_bytes(src)?;
                }
                let output_bytes = tensor_bytes(n.id)?;
                moves.push(MovePlan {
                    node: n.id,
                    name: n.name.clone(),
                    op: n.op.clone(),
                    output_dst: EdgePlace::Ddr,
                    input_bytes,
                    output_bytes,
                    isolated_dram_bytes: input_bytes + output_bytes,
                });
            }
            other => {
                return Err(format!(
                    "node '{}' is {}; run graph::passes::lower before compile",
                    n.name,
                    other.mnemonic()
                ));
            }
        }
    }
    if steps.is_empty() {
        return Err(format!("graph '{}' has no deconvolution nodes", g.name));
    }

    // ---- inter-layer buffer reuse: linear-scan register allocation
    // over DAG live ranges ----
    //
    // Each intermediate tensor is live from its producer's position to
    // its LAST consumer's position (a U-Net skip tensor stays live
    // across the whole decoder). Eligible tensors are placed into one
    // byte arena of capacity input_buf + output_buf by deterministic
    // first-fit; a placed tensor moves zero DDR bytes on both sides.
    // Eligibility mirrors the historical chain rule exactly: the
    // tensor must fit the smaller of the two buffers, and every deconv
    // endpoint's residency must already move it exactly once (no RMW
    // spill, no per-block re-streaming), so zeroing its traffic is
    // exact. Network inputs and consumer-less outputs always cross DDR.
    let in_cap = cfg.input_buf_kib as u64 * 1024;
    let out_cap = cfg.output_buf_kib as u64 * 1024;
    let arena_cap = in_cap + out_cap;
    let elig_cap = in_cap.min(out_cap);

    let n_nodes = g.nodes.len();
    let mut last_use: Vec<NodeId> = (0..n_nodes).collect();
    let mut consumers_of: Vec<Vec<NodeId>> = vec![Vec::new(); n_nodes];
    for n in &g.nodes {
        for &src in &n.inputs {
            last_use[src] = last_use[src].max(n.id);
            consumers_of[src].push(n.id);
        }
    }
    let mut frees_at: Vec<Vec<NodeId>> = vec![Vec::new(); n_nodes];
    for (id, &lu) in last_use.iter().enumerate() {
        frees_at[lu].push(id);
    }

    let mut step_of: Vec<Option<usize>> = vec![None; n_nodes];
    for (i, s) in steps.iter().enumerate() {
        step_of[s.node] = Some(i);
    }
    // "moved exactly once" per residency plan; merge/resample moves
    // materialize their operands and result exactly once by definition.
    let producer_once = |id: NodeId| -> bool {
        match step_of[id] {
            Some(i) => {
                steps[i].output_bytes == batch * steps[i].layer.output_elems() as u64 * eb
            }
            None => true,
        }
    };
    let consumer_once = |id: NodeId| -> bool {
        match step_of[id] {
            Some(i) => steps[i].input_bytes == batch * steps[i].layer.input_elems() as u64 * eb,
            None => true,
        }
    };

    let mut free: Vec<(u64, u64)> = vec![(0, arena_cap)]; // (offset, len), offset-sorted
    let mut placed: Vec<Option<(u64, u64)>> = vec![None; n_nodes];
    let mut onchip: Vec<BufferAlloc> = Vec::new();
    let mut live_bytes = 0u64;
    let mut peak_onchip_bytes = 0u64;
    for u in 0..n_nodes {
        let n = &g.nodes[u];
        let is_input = matches!(n.op, OpKind::Input { .. });
        let has_consumers = last_use[u] > u;
        if !is_input && has_consumers {
            let bytes = tensor_bytes(u)?;
            let eligible = bytes <= elig_cap
                && producer_once(u)
                && consumers_of[u].iter().all(|&c| consumer_once(c));
            if eligible {
                // First-fit. The output is placed BEFORE the node's
                // dying inputs are released: freeing them first would
                // let the output alias a tensor the node is still
                // reading — the free-after-first-consume aliasing bug.
                if let Some(slot) = free.iter().position(|&(_, len)| len >= bytes) {
                    let (off, len) = free[slot];
                    if len == bytes {
                        free.remove(slot);
                    } else {
                        free[slot] = (off + bytes, len - bytes);
                    }
                    placed[u] = Some((off, bytes));
                    live_bytes += bytes;
                    peak_onchip_bytes = peak_onchip_bytes.max(live_bytes);
                    onchip.push(BufferAlloc {
                        node: u,
                        name: n.name.clone(),
                        offset: off,
                        bytes,
                        last_use: last_use[u],
                    });
                }
            }
        }
        // Release every tensor whose last read happened at this node,
        // coalescing the free list so it stays offset-sorted.
        for &t in &frees_at[u] {
            if let Some((off, len)) = placed[t] {
                live_bytes -= len;
                let pos = free.iter().position(|&(o, _)| o > off).unwrap_or(free.len());
                free.insert(pos, (off, len));
                if pos + 1 < free.len() && free[pos].0 + free[pos].1 == free[pos + 1].0 {
                    free[pos].1 += free[pos + 1].1;
                    free.remove(pos + 1);
                }
                if pos > 0 && free[pos - 1].0 + free[pos - 1].1 == free[pos].0 {
                    free[pos - 1].1 += free[pos].1;
                    free.remove(pos);
                }
            }
        }
    }

    // Zero the DDR traffic on both sides of every placed tensor.
    for s in steps.iter_mut() {
        let src = g.nodes[s.node].inputs[0];
        if placed[src].is_some() {
            s.input_src = EdgePlace::OnChip;
            s.input_bytes = 0;
        }
        if placed[s.node].is_some() {
            s.output_dst = EdgePlace::OnChip;
            s.output_bytes = 0;
        }
    }
    for m in moves.iter_mut() {
        let mut in_ddr = 0u64;
        for &src in &g.nodes[m.node].inputs {
            if placed[src].is_none() {
                in_ddr += tensor_bytes(src)?;
            }
        }
        m.input_bytes = in_ddr;
        if placed[m.node].is_some() {
            m.output_dst = EdgePlace::OnChip;
            m.output_bytes = 0;
        }
    }

    Ok(NetworkPlan {
        network: g.name.clone(),
        cfg: cfg.clone(),
        steps,
        moves,
        onchip,
        peak_onchip_bytes,
    })
}

/// The canonical plan-cache key for a network under a configuration:
/// `<network>@<config fingerprint>`. Two calls to [`compile`] with the
/// same key produce identical plans, which is what lets
/// [`crate::serve::PlanCache`] compile once per (model, config) pair
/// and share the handle across accelerator instances.
pub fn cache_key_for(network: &str, cfg: &AccelConfig) -> String {
    let mut s = String::new();
    cache_key_into(&mut s, network, cfg);
    s
}

/// Render [`cache_key_for`] into a reused buffer (cleared first) —
/// the allocation-free form the serving hot path uses once the buffer
/// has grown to its fixpoint capacity.
pub fn cache_key_into(buf: &mut String, network: &str, cfg: &AccelConfig) {
    buf.clear();
    buf.push_str(network);
    buf.push('@');
    cfg.write_fingerprint(buf);
}

impl NetworkPlan {
    /// The plan-cache key this plan compiles under — see
    /// [`cache_key_for`].
    pub fn cache_key(&self) -> String {
        cache_key_for(&self.network, &self.cfg)
    }

    /// Total DDR traffic after inter-layer reuse (compute + move steps).
    pub fn total_dram_bytes(&self) -> u64 {
        self.steps.iter().map(|s| s.dram_bytes()).sum::<u64>()
            + self.moves.iter().map(|m| m.dram_bytes()).sum::<u64>()
    }

    /// What the isolated-layer model would have moved.
    pub fn isolated_dram_bytes(&self) -> u64 {
        self.steps.iter().map(|s| s.isolated_dram_bytes).sum::<u64>()
            + self.moves.iter().map(|m| m.isolated_dram_bytes).sum::<u64>()
    }

    /// DDR bytes saved by the reuse pass.
    pub fn bytes_saved(&self) -> u64 {
        self.isolated_dram_bytes() - self.total_dram_bytes()
    }

    /// Number of tensors the reuse pass kept on-chip (one per placed
    /// buffer; on a linear chain this is the number of layer
    /// boundaries kept on-chip).
    pub fn reused_edges(&self) -> usize {
        self.onchip.len()
    }

    /// Dense-equivalent MACs per batch item, all steps.
    pub fn dense_macs(&self) -> u64 {
        self.steps
            .iter()
            .map(|s| crate::accel::metrics::dense_equivalent_macs(&s.layer))
            .sum()
    }

    /// Human-readable plan dump (the `udcnn compile` output).
    pub fn render(&self) -> String {
        let c = &self.cfg;
        let mut out = format!(
            "=== network plan: {} (batch {}, mesh Tm={} Tn={} Tz={} Tr={} Tc={}, {} PEs @ {} MHz) ===\n",
            self.network, c.batch, c.tm, c.tn, c.tz, c.tr, c.tc, c.total_pes(), c.freq_mhz
        );
        for (i, s) in self.steps.iter().enumerate() {
            let fused = if s.fused.is_empty() {
                String::new()
            } else {
                let names: Vec<String> = s.fused.iter().map(|a| a.to_string()).collect();
                format!(" + fused {}", names.join("+"))
            };
            out.push_str(&format!("step {i}: {}{fused}\n", s.layer));
            out.push_str(&format!(
                "  schedule: oc {} x ic {} x d {} x tiles {}x{} -> {} passes, {} compute cycles\n",
                s.schedule.oc_blocks,
                s.schedule.ic_blocks,
                s.schedule.d_blocks,
                s.schedule.h_tiles,
                s.schedule.w_tiles,
                s.schedule.total_passes(),
                s.compute_cycles(c),
            ));
            out.push_str(&format!("  kernel: {} ({})\n", s.kernel.choice, s.kernel.reason()));
            out.push_str(&format!(
                "  input: {} ({:.1} KiB) | weights: DDR ({:.1} KiB) | output: {} ({:.1} KiB)\n",
                s.input_src,
                s.input_bytes as f64 / 1024.0,
                s.weight_bytes as f64 / 1024.0,
                s.output_dst,
                s.output_bytes as f64 / 1024.0,
            ));
        }
        for (i, m) in self.moves.iter().enumerate() {
            out.push_str(&format!(
                "move {i}: {} ({}) | input: DDR {:.1} KiB | output: {} ({:.1} KiB)\n",
                m.name,
                m.op.mnemonic(),
                m.input_bytes as f64 / 1024.0,
                m.output_dst,
                m.output_bytes as f64 / 1024.0,
            ));
        }
        out.push_str(&format!(
            "summary: {} steps | {} boundary(ies) kept on-chip | peak on-chip {:.1} KiB | DDR {:.2} MiB (isolated {:.2} MiB, saved {:.2} MiB)\n",
            self.steps.len(),
            self.reused_edges(),
            self.peak_onchip_bytes as f64 / 1024.0,
            self.total_dram_bytes() as f64 / (1024.0 * 1024.0),
            self.isolated_dram_bytes() as f64 / (1024.0 * 1024.0),
            self.bytes_saved() as f64 / (1024.0 * 1024.0),
        ));
        out
    }

    /// Machine-readable export (per-step schedules + traffic).
    pub fn to_json(&self) -> String {
        let steps: Vec<String> = self
            .steps
            .iter()
            .map(|s| {
                JsonObj::new()
                    .str("name", &s.name)
                    .int("oc_blocks", s.schedule.oc_blocks as u64)
                    .int("ic_blocks", s.schedule.ic_blocks as u64)
                    .int("d_blocks", s.schedule.d_blocks as u64)
                    .int("h_tiles", s.schedule.h_tiles as u64)
                    .int("w_tiles", s.schedule.w_tiles as u64)
                    .int("compute_cycles", s.compute_cycles(&self.cfg))
                    .str("kernel", &s.kernel.choice.to_string())
                    .int("kernel_scatter_cycles", s.kernel.scatter_cycles)
                    .int("kernel_gather_cycles", s.kernel.gather_cycles)
                    .str("kernel_reason", &s.kernel.reason())
                    .str("input_src", &s.input_src.to_string())
                    .str("output_dst", &s.output_dst.to_string())
                    .int("weight_bytes", s.weight_bytes)
                    .int("input_bytes", s.input_bytes)
                    .int("output_bytes", s.output_bytes)
                    .int("isolated_dram_bytes", s.isolated_dram_bytes)
                    .render()
            })
            .collect();
        let moves: Vec<String> = self
            .moves
            .iter()
            .map(|m| {
                JsonObj::new()
                    .str("name", &m.name)
                    .str("op", m.op.mnemonic())
                    .str("output_dst", &m.output_dst.to_string())
                    .int("input_bytes", m.input_bytes)
                    .int("output_bytes", m.output_bytes)
                    .int("isolated_dram_bytes", m.isolated_dram_bytes)
                    .render()
            })
            .collect();
        JsonObj::new()
            .str("network", &self.network)
            .int("batch", self.cfg.batch as u64)
            .int("total_pes", self.cfg.total_pes() as u64)
            .int("reused_edges", self.reused_edges() as u64)
            .int("peak_onchip_bytes", self.peak_onchip_bytes)
            .int("dram_bytes", self.total_dram_bytes())
            .int("isolated_dram_bytes", self.isolated_dram_bytes())
            .raw("steps", &crate::report::json::array(&steps))
            .raw("moves", &crate::report::json::array(&moves))
            .render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dcnn::zoo;
    use crate::graph::passes::lower;

    fn plan_for(net: &crate::dcnn::Network) -> NetworkPlan {
        let cfg = AccelConfig::paper_for(net.dims);
        let g = lower(&NetworkGraph::from_network(net)).unwrap();
        compile(&cfg, &g).unwrap()
    }

    #[test]
    fn dcgan_reuses_the_first_boundary() {
        // batch 8 × 512×8×8 × 2 B = 512 KiB fits the 512 KiB input
        // buffer exactly; later boundaries are 1 MiB and 2 MiB.
        let p = plan_for(&zoo::dcgan());
        assert_eq!(p.steps.len(), 4);
        assert_eq!(p.steps[0].output_dst, EdgePlace::OnChip);
        assert_eq!(p.steps[1].input_src, EdgePlace::OnChip);
        assert_eq!(p.steps[1].output_dst, EdgePlace::Ddr);
        assert_eq!(p.reused_edges(), 1);
        assert!(p.total_dram_bytes() < p.isolated_dram_bytes());
        // saved exactly the write + the read of the 512 KiB tensor
        assert_eq!(p.bytes_saved(), 2 * 512 * 1024);
    }

    #[test]
    fn traffic_never_exceeds_isolated() {
        for net in zoo::all_benchmarks() {
            let p = plan_for(&net);
            assert!(
                p.total_dram_bytes() <= p.isolated_dram_bytes(),
                "{}",
                net.name
            );
            if p.reused_edges() > 0 {
                assert!(
                    p.total_dram_bytes() < p.isolated_dram_bytes(),
                    "{}: reuse fired but traffic did not drop",
                    net.name
                );
            }
        }
    }

    #[test]
    fn small_batch_reuses_more_boundaries() {
        let net = zoo::gan3d();
        let mut cfg = AccelConfig::paper_for(net.dims);
        let g = lower(&NetworkGraph::from_network(&net)).unwrap();
        let p8 = compile(&cfg, &g).unwrap();
        cfg.batch = 1;
        let p1 = compile(&cfg, &g).unwrap();
        assert!(
            p1.reused_edges() > p8.reused_edges(),
            "batch 1 ({}) should keep more boundaries on-chip than batch 8 ({})",
            p1.reused_edges(),
            p8.reused_edges()
        );
    }

    #[test]
    fn unlowered_graph_is_rejected() {
        let net = zoo::tiny_2d();
        let g = NetworkGraph::from_network_oom(&net);
        let err = compile(&AccelConfig::paper_2d(), &g).unwrap_err();
        assert!(err.contains("lower"), "{err}");
    }

    #[test]
    fn render_and_json_mention_every_step() {
        let p = plan_for(&zoo::gan3d());
        let text = p.render();
        assert!(text.contains("network plan: 3d-gan"));
        for s in &p.steps {
            assert!(text.contains(&s.layer.name), "{}", s.layer.name);
        }
        assert!(text.contains("summary:"));
        let js = p.to_json();
        assert!(js.contains("\"network\": \"3d-gan\""));
        assert!(js.contains("\"steps\""));
    }

    #[test]
    fn auto_kernel_choice_never_loses_to_forced_scatter() {
        for net in zoo::all_benchmarks() {
            let cfg = AccelConfig::paper_for(net.dims);
            let g = lower(&NetworkGraph::from_network(&net)).unwrap();
            let auto = compile(&cfg, &g).unwrap();
            let scatter = compile_forced(&cfg, &g, KernelChoice::Scatter).unwrap();
            let auto_cycles = crate::graph::simulate_plan(&auto).total_cycles;
            let scatter_cycles = crate::graph::simulate_plan(&scatter).total_cycles;
            assert!(
                auto_cycles <= scatter_cycles,
                "{}: auto {auto_cycles} > forced-scatter {scatter_cycles}",
                net.name
            );
            for s in &scatter.steps {
                assert_eq!(s.kernel.choice, KernelChoice::Scatter);
            }
        }
    }

    #[test]
    fn kernel_choice_is_recorded_in_render_and_json() {
        let p = plan_for(&zoo::gan3d());
        assert!(
            p.steps.iter().any(|s| s.kernel.choice == KernelChoice::Gather),
            "stride-2 K=3 3D layers should pick gather somewhere"
        );
        let text = p.render();
        assert!(text.contains("kernel: "), "{text}");
        let js = p.to_json();
        assert!(js.contains("\"kernel\""), "{js}");
        assert!(js.contains("kernel_scatter_cycles"), "{js}");
        assert!(js.contains("kernel_gather_cycles"), "{js}");
    }

    #[test]
    fn weights_always_stream_from_ddr() {
        for net in zoo::all_benchmarks() {
            let p = plan_for(&net);
            for s in &p.steps {
                assert_eq!(
                    s.weight_bytes,
                    s.layer.weight_elems() as u64 * 2,
                    "{}: weights move exactly once",
                    s.name
                );
            }
        }
    }

    /// A small skip DAG: `a` feeds both the chain `b -> c` and the
    /// `Concat` three positions later, so its live range spans the
    /// whole "decoder". A free-after-first-consume allocator would
    /// hand `a`'s bytes to `c` while `cat` still needs them.
    fn skip_dag() -> NetworkGraph {
        use crate::dcnn::Dims;
        use crate::graph::ir::TensorShape;
        let sp = |name: &str, in_c: usize, out_c: usize| {
            crate::dcnn::LayerSpec::new_2d(name, in_c, 16, 16, out_c, 3, 1)
        };
        let mut g = NetworkGraph::new("skip-dag", Dims::D2);
        let inp = g.add_node(
            "input",
            OpKind::Input {
                shape: TensorShape::new(8, 1, 16, 16),
            },
            &[],
        );
        let a = g.add_node("a", OpKind::Deconv { spec: sp("a", 8, 8) }, &[inp]);
        let b = g.add_node("b", OpKind::Deconv { spec: sp("b", 8, 8) }, &[a]);
        let c = g.add_node("c", OpKind::Deconv { spec: sp("c", 8, 8) }, &[b]);
        let cat = g.add_node("cat", OpKind::Concat, &[c, a]);
        g.add_node("head", OpKind::Deconv { spec: sp("head", 16, 4) }, &[cat]);
        g
    }

    #[test]
    fn dag_allocator_never_aliases_a_live_skip_tensor() {
        let g = lower(&skip_dag()).unwrap();
        let cfg = AccelConfig::paper_2d();
        let p = compile(&cfg, &g).unwrap();
        // the skip tensor `a` is placed and stays live until the concat
        let a = p.onchip.iter().find(|al| al.name == "a").expect("skip placed");
        let cat = p.moves.iter().find(|m| m.name == "cat").expect("concat planned");
        assert_eq!(a.last_use, cat.node, "skip lives until its Concat");
        // no two allocations with overlapping live ranges share bytes
        for (i, x) in p.onchip.iter().enumerate() {
            for y in p.onchip.iter().skip(i + 1) {
                let live_overlap = x.node <= y.last_use && y.node <= x.last_use;
                let byte_overlap = x.offset < y.offset + y.bytes && y.offset < x.offset + x.bytes;
                assert!(
                    !(live_overlap && byte_overlap),
                    "'{}' [{}..{}) aliases live '{}' [{}..{})",
                    x.name,
                    x.offset,
                    x.offset + x.bytes,
                    y.name,
                    y.offset,
                    y.offset + y.bytes
                );
            }
        }
        // peak footprint beats materializing every tensor at once
        let all_bytes: u64 = p.onchip.iter().map(|al| al.bytes).sum();
        assert!(p.peak_onchip_bytes > 0);
        assert!(
            p.peak_onchip_bytes < all_bytes,
            "peak {} should be strictly below the {} B sum of all placed tensors",
            p.peak_onchip_bytes,
            all_bytes
        );
        // both concat operands were resident: the merge moves no DDR bytes
        assert_eq!(cat.dram_bytes(), 0, "fully on-chip concat");
        assert!(p.total_dram_bytes() < p.isolated_dram_bytes());
    }

    #[test]
    fn dag_moves_are_planned_and_rendered() {
        let g = lower(&skip_dag()).unwrap();
        let cfg = AccelConfig::paper_2d();
        let p = compile(&cfg, &g).unwrap();
        assert_eq!(p.moves.len(), 1);
        let text = p.render();
        assert!(text.contains("move 0: cat (concat)"), "{text}");
        assert!(text.contains("peak on-chip"), "{text}");
        let js = p.to_json();
        assert!(js.contains("\"moves\""), "{js}");
        assert!(js.contains("peak_onchip_bytes"), "{js}");
    }
}
