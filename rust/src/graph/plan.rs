//! The [`NetworkPlan`] artifact: a lowered graph bound to one
//! accelerator configuration.
//!
//! [`compile`] sequences the deconvolution chain, derives each node's
//! blocking [`Schedule`] and operand [`Residency`], and then runs the
//! **inter-layer buffer-reuse pass**: when the tensor between layer
//! *i* and layer *i+1* fits on-chip (both the producer's output buffer
//! and the consumer's input buffer), the output of layer *i* is never
//! written to DDR and layer *i+1* never reads it back — the output
//! buffer simply becomes the next layer's input buffer. Tensors that
//! do not fit spill to DDR exactly as in the isolated-layer model.
//!
//! The plan records both the adjusted and the isolated traffic so the
//! savings are auditable, renders as human-readable text (the
//! `udcnn compile` dump) and exports as JSON via [`crate::report`].

use crate::accel::buffers::Residency;
use crate::accel::{kernel, AccelConfig, KernelChoice, KernelSelection, Schedule};
use crate::dcnn::LayerSpec;
use crate::report::json::JsonObj;

use super::ir::{Act, NetworkGraph, NodeId, OpKind};

/// Where a step's input/output tensor lives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EdgePlace {
    /// Kept in the on-chip buffers across the layer boundary.
    OnChip,
    /// Streamed through DDR.
    Ddr,
}

impl std::fmt::Display for EdgePlace {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EdgePlace::OnChip => write!(f, "on-chip"),
            EdgePlace::Ddr => write!(f, "DDR"),
        }
    }
}

/// One executable step of a network plan (one deconvolution layer).
#[derive(Clone, Debug)]
pub struct StepPlan {
    /// Node id in the lowered graph.
    pub node: NodeId,
    /// Layer name (from the graph node).
    pub name: String,
    /// Layer geometry.
    pub layer: LayerSpec,
    /// Blocking schedule on the bound configuration.
    pub schedule: Schedule,
    /// Per-layer kernel decision (scatter vs gather) with both
    /// kernels' modeled cycles as machine-readable justification.
    pub kernel: KernelSelection,
    /// Activations fused into this step's write-back.
    pub fused: Vec<Act>,
    /// Where the step reads its input tensor.
    pub input_src: EdgePlace,
    /// Where the step writes its output tensor.
    pub output_dst: EdgePlace,
    /// DDR traffic after reuse adjustment (batch totals).
    pub weight_bytes: u64,
    /// Input bytes after reuse adjustment.
    pub input_bytes: u64,
    /// Output bytes after reuse adjustment.
    pub output_bytes: u64,
    /// What the isolated-layer residency plan would have moved.
    pub isolated_dram_bytes: u64,
}

impl StepPlan {
    /// Total adjusted DDR traffic of this step.
    pub fn dram_bytes(&self) -> u64 {
        self.weight_bytes + self.input_bytes + self.output_bytes
    }

    /// Compute cycles of this step under its chosen kernel.
    pub fn compute_cycles(&self, cfg: &AccelConfig) -> u64 {
        kernel::compute_cycles(cfg, &self.layer, &self.schedule, self.kernel.choice)
    }
}

/// A compiled whole-network execution plan.
#[derive(Clone, Debug)]
pub struct NetworkPlan {
    /// Network name.
    pub network: String,
    /// The configuration the plan is bound to.
    pub cfg: AccelConfig,
    /// Executable steps in chain order.
    pub steps: Vec<StepPlan>,
}

/// Compile a lowered graph onto one configuration.
///
/// The graph must already be through [`super::passes::lower`]: only
/// `Input` and `Deconv` nodes may remain, forming a linear chain (the
/// shape every benchmark decoder has; branching DAGs are rejected with
/// a clear error rather than silently mis-planned).
///
/// Each step also gets a per-layer kernel decision
/// ([`kernel::choose`]): scatter vs zero-skip gather, scored under the
/// step's own compute and DDR terms, with both scores recorded on the
/// step as justification.
pub fn compile(cfg: &AccelConfig, g: &NetworkGraph) -> Result<NetworkPlan, String> {
    compile_with(cfg, g, None)
}

/// [`compile`] with every step pinned to one kernel instead of the
/// per-layer [`kernel::choose`] decision — the baseline the
/// scatter-vs-gather differential tests and benches compare against.
pub fn compile_forced(
    cfg: &AccelConfig,
    g: &NetworkGraph,
    forced: KernelChoice,
) -> Result<NetworkPlan, String> {
    compile_with(cfg, g, Some(forced))
}

fn compile_with(
    cfg: &AccelConfig,
    g: &NetworkGraph,
    forced: Option<KernelChoice>,
) -> Result<NetworkPlan, String> {
    cfg.validate()?;
    let mut steps: Vec<StepPlan> = Vec::new();
    for n in &g.nodes {
        match &n.op {
            OpKind::Input { .. } => {}
            OpKind::Deconv { spec } => {
                let consumers = g.consumers(n.id);
                if consumers.len() > 1 {
                    return Err(format!(
                        "node '{}' has {} consumers; only linear chains are supported",
                        n.name,
                        consumers.len()
                    ));
                }
                // each step must consume the previous step's tensor
                let chained = match steps.last() {
                    Some(prev) => n.inputs[0] == prev.node,
                    None => matches!(g.nodes[n.inputs[0]].op, OpKind::Input { .. }),
                };
                if !chained {
                    return Err(format!(
                        "node '{}' does not consume the previous step's output; \
                         only linear chains are supported",
                        n.name
                    ));
                }
                let schedule = Schedule::new(cfg, spec);
                let mut sel = kernel::choose(cfg, spec, &schedule);
                if let Some(k) = forced {
                    sel.choice = k;
                }
                let res = Residency::plan_kernel(cfg, spec, &schedule, sel.choice);
                steps.push(StepPlan {
                    node: n.id,
                    name: n.name.clone(),
                    layer: spec.clone(),
                    schedule,
                    kernel: sel,
                    fused: n.fused.clone(),
                    input_src: EdgePlace::Ddr,
                    output_dst: EdgePlace::Ddr,
                    weight_bytes: res.weight_bytes,
                    input_bytes: res.input_bytes,
                    output_bytes: res.output_bytes,
                    isolated_dram_bytes: res.dram_bytes,
                });
            }
            other => {
                return Err(format!(
                    "node '{}' is {}; run graph::passes::lower before compile",
                    n.name,
                    other.mnemonic()
                ));
            }
        }
    }
    if steps.is_empty() {
        return Err(format!("graph '{}' has no deconvolution nodes", g.name));
    }

    // Inter-layer buffer-reuse pass. The edge tensor (whole batch) must
    // fit both buffers, and both sides' residency must already move the
    // tensor exactly once (no RMW spill, no per-block re-streaming), so
    // zeroing their traffic is exact.
    let eb = cfg.elem_bytes() as u64;
    let in_cap = cfg.input_buf_kib as u64 * 1024;
    let out_cap = cfg.output_buf_kib as u64 * 1024;
    for i in 0..steps.len().saturating_sub(1) {
        let edge_bytes = cfg.batch as u64 * steps[i].layer.output_elems() as u64 * eb;
        let producer_once =
            steps[i].output_bytes == cfg.batch as u64 * steps[i].layer.output_elems() as u64 * eb;
        let consumer_once = steps[i + 1].input_bytes
            == cfg.batch as u64 * steps[i + 1].layer.input_elems() as u64 * eb;
        if edge_bytes <= in_cap && edge_bytes <= out_cap && producer_once && consumer_once {
            steps[i].output_dst = EdgePlace::OnChip;
            steps[i].output_bytes = 0;
            steps[i + 1].input_src = EdgePlace::OnChip;
            steps[i + 1].input_bytes = 0;
        }
    }

    Ok(NetworkPlan {
        network: g.name.clone(),
        cfg: cfg.clone(),
        steps,
    })
}

/// The canonical plan-cache key for a network under a configuration:
/// `<network>@<config fingerprint>`. Two calls to [`compile`] with the
/// same key produce identical plans, which is what lets
/// [`crate::serve::PlanCache`] compile once per (model, config) pair
/// and share the handle across accelerator instances.
pub fn cache_key_for(network: &str, cfg: &AccelConfig) -> String {
    let mut s = String::new();
    cache_key_into(&mut s, network, cfg);
    s
}

/// Render [`cache_key_for`] into a reused buffer (cleared first) —
/// the allocation-free form the serving hot path uses once the buffer
/// has grown to its fixpoint capacity.
pub fn cache_key_into(buf: &mut String, network: &str, cfg: &AccelConfig) {
    buf.clear();
    buf.push_str(network);
    buf.push('@');
    cfg.write_fingerprint(buf);
}

impl NetworkPlan {
    /// The plan-cache key this plan compiles under — see
    /// [`cache_key_for`].
    pub fn cache_key(&self) -> String {
        cache_key_for(&self.network, &self.cfg)
    }

    /// Total DDR traffic after inter-layer reuse.
    pub fn total_dram_bytes(&self) -> u64 {
        self.steps.iter().map(|s| s.dram_bytes()).sum()
    }

    /// What the isolated-layer model would have moved.
    pub fn isolated_dram_bytes(&self) -> u64 {
        self.steps.iter().map(|s| s.isolated_dram_bytes).sum()
    }

    /// DDR bytes saved by the reuse pass.
    pub fn bytes_saved(&self) -> u64 {
        self.isolated_dram_bytes() - self.total_dram_bytes()
    }

    /// Number of layer boundaries kept on-chip.
    pub fn reused_edges(&self) -> usize {
        self.steps
            .iter()
            .filter(|s| s.output_dst == EdgePlace::OnChip)
            .count()
    }

    /// Dense-equivalent MACs per batch item, all steps.
    pub fn dense_macs(&self) -> u64 {
        self.steps
            .iter()
            .map(|s| crate::accel::metrics::dense_equivalent_macs(&s.layer))
            .sum()
    }

    /// Human-readable plan dump (the `udcnn compile` output).
    pub fn render(&self) -> String {
        let c = &self.cfg;
        let mut out = format!(
            "=== network plan: {} (batch {}, mesh Tm={} Tn={} Tz={} Tr={} Tc={}, {} PEs @ {} MHz) ===\n",
            self.network, c.batch, c.tm, c.tn, c.tz, c.tr, c.tc, c.total_pes(), c.freq_mhz
        );
        for (i, s) in self.steps.iter().enumerate() {
            let fused = if s.fused.is_empty() {
                String::new()
            } else {
                let names: Vec<String> = s.fused.iter().map(|a| a.to_string()).collect();
                format!(" + fused {}", names.join("+"))
            };
            out.push_str(&format!("step {i}: {}{fused}\n", s.layer));
            out.push_str(&format!(
                "  schedule: oc {} x ic {} x d {} x tiles {}x{} -> {} passes, {} compute cycles\n",
                s.schedule.oc_blocks,
                s.schedule.ic_blocks,
                s.schedule.d_blocks,
                s.schedule.h_tiles,
                s.schedule.w_tiles,
                s.schedule.total_passes(),
                s.compute_cycles(c),
            ));
            out.push_str(&format!("  kernel: {} ({})\n", s.kernel.choice, s.kernel.reason()));
            out.push_str(&format!(
                "  input: {} ({:.1} KiB) | weights: DDR ({:.1} KiB) | output: {} ({:.1} KiB)\n",
                s.input_src,
                s.input_bytes as f64 / 1024.0,
                s.weight_bytes as f64 / 1024.0,
                s.output_dst,
                s.output_bytes as f64 / 1024.0,
            ));
        }
        out.push_str(&format!(
            "summary: {} steps | {} boundary(ies) kept on-chip | DDR {:.2} MiB (isolated {:.2} MiB, saved {:.2} MiB)\n",
            self.steps.len(),
            self.reused_edges(),
            self.total_dram_bytes() as f64 / (1024.0 * 1024.0),
            self.isolated_dram_bytes() as f64 / (1024.0 * 1024.0),
            self.bytes_saved() as f64 / (1024.0 * 1024.0),
        ));
        out
    }

    /// Machine-readable export (per-step schedules + traffic).
    pub fn to_json(&self) -> String {
        let steps: Vec<String> = self
            .steps
            .iter()
            .map(|s| {
                JsonObj::new()
                    .str("name", &s.name)
                    .int("oc_blocks", s.schedule.oc_blocks as u64)
                    .int("ic_blocks", s.schedule.ic_blocks as u64)
                    .int("d_blocks", s.schedule.d_blocks as u64)
                    .int("h_tiles", s.schedule.h_tiles as u64)
                    .int("w_tiles", s.schedule.w_tiles as u64)
                    .int("compute_cycles", s.compute_cycles(&self.cfg))
                    .str("kernel", &s.kernel.choice.to_string())
                    .int("kernel_scatter_cycles", s.kernel.scatter_cycles)
                    .int("kernel_gather_cycles", s.kernel.gather_cycles)
                    .str("kernel_reason", &s.kernel.reason())
                    .str("input_src", &s.input_src.to_string())
                    .str("output_dst", &s.output_dst.to_string())
                    .int("weight_bytes", s.weight_bytes)
                    .int("input_bytes", s.input_bytes)
                    .int("output_bytes", s.output_bytes)
                    .int("isolated_dram_bytes", s.isolated_dram_bytes)
                    .render()
            })
            .collect();
        JsonObj::new()
            .str("network", &self.network)
            .int("batch", self.cfg.batch as u64)
            .int("total_pes", self.cfg.total_pes() as u64)
            .int("reused_edges", self.reused_edges() as u64)
            .int("dram_bytes", self.total_dram_bytes())
            .int("isolated_dram_bytes", self.isolated_dram_bytes())
            .raw("steps", &crate::report::json::array(&steps))
            .render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dcnn::zoo;
    use crate::graph::passes::lower;

    fn plan_for(net: &crate::dcnn::Network) -> NetworkPlan {
        let cfg = AccelConfig::paper_for(net.dims);
        let g = lower(&NetworkGraph::from_network(net)).unwrap();
        compile(&cfg, &g).unwrap()
    }

    #[test]
    fn dcgan_reuses_the_first_boundary() {
        // batch 8 × 512×8×8 × 2 B = 512 KiB fits the 512 KiB input
        // buffer exactly; later boundaries are 1 MiB and 2 MiB.
        let p = plan_for(&zoo::dcgan());
        assert_eq!(p.steps.len(), 4);
        assert_eq!(p.steps[0].output_dst, EdgePlace::OnChip);
        assert_eq!(p.steps[1].input_src, EdgePlace::OnChip);
        assert_eq!(p.steps[1].output_dst, EdgePlace::Ddr);
        assert_eq!(p.reused_edges(), 1);
        assert!(p.total_dram_bytes() < p.isolated_dram_bytes());
        // saved exactly the write + the read of the 512 KiB tensor
        assert_eq!(p.bytes_saved(), 2 * 512 * 1024);
    }

    #[test]
    fn traffic_never_exceeds_isolated() {
        for net in zoo::all_benchmarks() {
            let p = plan_for(&net);
            assert!(
                p.total_dram_bytes() <= p.isolated_dram_bytes(),
                "{}",
                net.name
            );
            if p.reused_edges() > 0 {
                assert!(
                    p.total_dram_bytes() < p.isolated_dram_bytes(),
                    "{}: reuse fired but traffic did not drop",
                    net.name
                );
            }
        }
    }

    #[test]
    fn small_batch_reuses_more_boundaries() {
        let net = zoo::gan3d();
        let mut cfg = AccelConfig::paper_for(net.dims);
        let g = lower(&NetworkGraph::from_network(&net)).unwrap();
        let p8 = compile(&cfg, &g).unwrap();
        cfg.batch = 1;
        let p1 = compile(&cfg, &g).unwrap();
        assert!(
            p1.reused_edges() > p8.reused_edges(),
            "batch 1 ({}) should keep more boundaries on-chip than batch 8 ({})",
            p1.reused_edges(),
            p8.reused_edges()
        );
    }

    #[test]
    fn unlowered_graph_is_rejected() {
        let net = zoo::tiny_2d();
        let g = NetworkGraph::from_network_oom(&net);
        let err = compile(&AccelConfig::paper_2d(), &g).unwrap_err();
        assert!(err.contains("lower"), "{err}");
    }

    #[test]
    fn render_and_json_mention_every_step() {
        let p = plan_for(&zoo::gan3d());
        let text = p.render();
        assert!(text.contains("network plan: 3d-gan"));
        for s in &p.steps {
            assert!(text.contains(&s.layer.name), "{}", s.layer.name);
        }
        assert!(text.contains("summary:"));
        let js = p.to_json();
        assert!(js.contains("\"network\": \"3d-gan\""));
        assert!(js.contains("\"steps\""));
    }

    #[test]
    fn auto_kernel_choice_never_loses_to_forced_scatter() {
        for net in zoo::all_benchmarks() {
            let cfg = AccelConfig::paper_for(net.dims);
            let g = lower(&NetworkGraph::from_network(&net)).unwrap();
            let auto = compile(&cfg, &g).unwrap();
            let scatter = compile_forced(&cfg, &g, KernelChoice::Scatter).unwrap();
            let auto_cycles = crate::graph::simulate_plan(&auto).total_cycles;
            let scatter_cycles = crate::graph::simulate_plan(&scatter).total_cycles;
            assert!(
                auto_cycles <= scatter_cycles,
                "{}: auto {auto_cycles} > forced-scatter {scatter_cycles}",
                net.name
            );
            for s in &scatter.steps {
                assert_eq!(s.kernel.choice, KernelChoice::Scatter);
            }
        }
    }

    #[test]
    fn kernel_choice_is_recorded_in_render_and_json() {
        let p = plan_for(&zoo::gan3d());
        assert!(
            p.steps.iter().any(|s| s.kernel.choice == KernelChoice::Gather),
            "stride-2 K=3 3D layers should pick gather somewhere"
        );
        let text = p.render();
        assert!(text.contains("kernel: "), "{text}");
        let js = p.to_json();
        assert!(js.contains("\"kernel\""), "{js}");
        assert!(js.contains("kernel_scatter_cycles"), "{js}");
        assert!(js.contains("kernel_gather_cycles"), "{js}");
    }

    #[test]
    fn weights_always_stream_from_ddr() {
        for net in zoo::all_benchmarks() {
            let p = plan_for(&net);
            for s in &p.steps {
                assert_eq!(
                    s.weight_bytes,
                    s.layer.weight_elems() as u64 * 2,
                    "{}: weights move exactly once",
                    s.name
                );
            }
        }
    }
}
