//! The whole-network graph IR.
//!
//! A [`NetworkGraph`] is a DAG of [`NodeSpec`]s with explicit tensor
//! edges (node inputs reference producer node ids; every node produces
//! exactly one tensor). Ops cover what the uniform architecture runs:
//!
//! * [`OpKind::Deconv`] — IOM deconvolution, the accelerator's native
//!   operation (one [`LayerSpec`] of geometry);
//! * [`OpKind::ZeroInsert`] + [`OpKind::Conv`] — the OOM decomposition
//!   of the same layer (zero-insert, pad `K−1`, dense conv). Front
//!   ends may emit this form; the [`super::passes::lower_oom_to_iom`]
//!   pass rewrites each pair into one `Deconv` node;
//! * [`OpKind::Activation`] — pointwise nonlinearity, fused into its
//!   producer by [`super::passes::fuse_activations`] (the PE writes
//!   back through the activation unit for free);
//! * [`OpKind::Input`] — the network input placeholder;
//! * [`OpKind::Concat`] / [`OpKind::Add`] — multi-input skip merges
//!   (channel concatenation and elementwise addition), plus
//!   [`OpKind::MaxPool`] and [`OpKind::Upsample`] resampling — the
//!   nodes that turn the linear chain into the U-Net / UNETR skip
//!   DAGs. Convolution needs no extra op: a stride-1 `Deconv` inserts
//!   no zeros and *is* the convolution (unified conv+deconv datapath).
//!
//! Builders construct graphs from the [`crate::dcnn::zoo`] networks
//! (or any [`LayerSpec`] chain, e.g. the ones
//! [`crate::dcnn::workload`] generates data for); node ids are
//! assigned in insertion order, which [`NetworkGraph::add_node`]
//! keeps topological by construction.

use std::fmt;

use crate::dcnn::{Dims, LayerSpec, Network};

/// Index of a node in [`NetworkGraph::nodes`].
pub type NodeId = usize;

/// Shape of one tensor edge, `C × D × H × W` (`d = 1` for 2D).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TensorShape {
    /// Channels.
    pub c: usize,
    /// Depth (1 for 2D).
    pub d: usize,
    /// Height.
    pub h: usize,
    /// Width.
    pub w: usize,
}

impl TensorShape {
    /// A shape from raw extents.
    pub fn new(c: usize, d: usize, h: usize, w: usize) -> TensorShape {
        TensorShape { c, d, h, w }
    }

    /// The input tensor of a deconvolution layer.
    pub fn of_layer_input(spec: &LayerSpec) -> TensorShape {
        TensorShape::new(spec.in_c, spec.in_d, spec.in_h, spec.in_w)
    }

    /// The cropped (`I·S`) output tensor of a deconvolution layer.
    pub fn of_layer_output(spec: &LayerSpec) -> TensorShape {
        TensorShape::new(spec.out_c, spec.out_d(), spec.out_h(), spec.out_w())
    }

    /// Total element count.
    pub fn elems(&self) -> usize {
        self.c * self.d * self.h * self.w
    }

    /// Bytes at a given element width.
    pub fn bytes(&self, elem_bytes: usize) -> u64 {
        (self.elems() * elem_bytes) as u64
    }
}

impl fmt::Display for TensorShape {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.d == 1 {
            write!(f, "{}x{}x{}", self.c, self.h, self.w)
        } else {
            write!(f, "{}x{}x{}x{}", self.c, self.d, self.h, self.w)
        }
    }
}

/// Pointwise nonlinearities the PE write-back path applies for free.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Act {
    /// `max(x, 0)`.
    Relu,
    /// Hyperbolic tangent.
    Tanh,
    /// Logistic function.
    Sigmoid,
}

impl fmt::Display for Act {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Act::Relu => write!(f, "relu"),
            Act::Tanh => write!(f, "tanh"),
            Act::Sigmoid => write!(f, "sigmoid"),
        }
    }
}

/// Operation performed by one graph node.
#[derive(Clone, Debug, PartialEq)]
pub enum OpKind {
    /// Network input placeholder.
    Input { shape: TensorShape },
    /// IOM deconvolution — the accelerator's native op. A `spec` with
    /// `S = 1` inserts no zeros, so the same node *is* an ordinary
    /// spatial convolution: U-Net conv blocks lower to stride-1
    /// deconvolutions and run on the identical datapath (the unified
    /// conv+deconv architecture the DAG workloads need).
    Deconv { spec: LayerSpec },
    /// OOM artifact: insert `S−1` zeros + pad `K−1` (geometry of the
    /// eventual layer carried along for shape inference).
    ZeroInsert { spec: LayerSpec },
    /// OOM artifact: dense stride-1 convolution over the inserted map
    /// (output cropped to `I·S` at write-back, like the hardware).
    Conv { spec: LayerSpec },
    /// Pointwise activation.
    Activation { act: Act },
    /// Channel-axis concatenation of two or more tensors with equal
    /// spatial extents — the U-Net skip merge.
    Concat,
    /// Elementwise addition of two or more identically-shaped tensors
    /// — the residual / UNETR-style skip merge.
    Add,
    /// Non-overlapping max-pooling downsample: window = stride = `k`
    /// per spatial axis (depth included on 3D graphs).
    MaxPool { k: usize },
    /// Nearest-neighbour upsample by integer factor `f` per spatial
    /// axis (depth included on 3D graphs).
    Upsample { f: usize },
}

impl OpKind {
    /// Short mnemonic for dumps.
    pub fn mnemonic(&self) -> &'static str {
        match self {
            OpKind::Input { .. } => "input",
            OpKind::Deconv { .. } => "deconv",
            OpKind::ZeroInsert { .. } => "zero_insert",
            OpKind::Conv { .. } => "conv",
            OpKind::Activation { .. } => "activation",
            OpKind::Concat => "concat",
            OpKind::Add => "add",
            OpKind::MaxPool { .. } => "max_pool",
            OpKind::Upsample { .. } => "upsample",
        }
    }

    /// Whether this op merges or resamples tensors without weights —
    /// the nodes a compiled plan carries as data-movement steps
    /// ([`super::plan::MovePlan`]) rather than compute steps.
    pub fn is_move(&self) -> bool {
        matches!(
            self,
            OpKind::Concat | OpKind::Add | OpKind::MaxPool { .. } | OpKind::Upsample { .. }
        )
    }
}

/// One node: an op, its input edges, and (after shape inference) the
/// shape of the tensor it produces.
#[derive(Clone, Debug)]
pub struct NodeSpec {
    /// This node's id (its index in the graph).
    pub id: NodeId,
    /// Human-readable name.
    pub name: String,
    /// The operation.
    pub op: OpKind,
    /// Producer node ids, in argument order.
    pub inputs: Vec<NodeId>,
    /// Activations fused into this node's write-back path
    /// (populated by [`super::passes::fuse_activations`]).
    pub fused: Vec<Act>,
    /// Output tensor shape (populated by
    /// [`super::passes::infer_shapes`]).
    pub out_shape: Option<TensorShape>,
}

/// A whole network as a graph of ops over explicit tensor edges.
#[derive(Clone, Debug)]
pub struct NetworkGraph {
    /// Network name.
    pub name: String,
    /// Dimensionality of the whole graph.
    pub dims: Dims,
    /// Nodes in topological (insertion) order; `nodes[i].id == i`.
    pub nodes: Vec<NodeSpec>,
}

impl NetworkGraph {
    /// An empty graph.
    pub fn new(name: impl Into<String>, dims: Dims) -> NetworkGraph {
        NetworkGraph {
            name: name.into(),
            dims,
            nodes: Vec::new(),
        }
    }

    /// Append a node; inputs must reference already-added nodes, which
    /// keeps node order topological by construction.
    pub fn add_node(&mut self, name: impl Into<String>, op: OpKind, inputs: &[NodeId]) -> NodeId {
        let id = self.nodes.len();
        for &i in inputs {
            assert!(i < id, "node input {i} must precede node {id}");
        }
        self.nodes.push(NodeSpec {
            id,
            name: name.into(),
            op,
            inputs: inputs.to_vec(),
            fused: Vec::new(),
            out_shape: None,
        });
        id
    }

    /// The node with id `id`.
    pub fn node(&self, id: NodeId) -> &NodeSpec {
        &self.nodes[id]
    }

    /// Number of nodes.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    /// Whether the graph has no nodes.
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// All tensor edges as `(producer, consumer)` pairs.
    pub fn edges(&self) -> Vec<(NodeId, NodeId)> {
        let mut out = Vec::new();
        for n in &self.nodes {
            for &src in &n.inputs {
                out.push((src, n.id));
            }
        }
        out
    }

    /// Nodes that consume `id`'s output tensor.
    pub fn consumers(&self, id: NodeId) -> Vec<NodeId> {
        self.nodes
            .iter()
            .filter(|n| n.inputs.contains(&id))
            .map(|n| n.id)
            .collect()
    }

    /// Build the IOM-form graph from a layer chain: one `Input` node,
    /// then one `Deconv` per layer (optionally followed by an
    /// activation after each deconv).
    pub fn from_layers(
        name: impl Into<String>,
        dims: Dims,
        layers: &[LayerSpec],
        act: Option<Act>,
    ) -> NetworkGraph {
        let mut g = NetworkGraph::new(name, dims);
        let Some(first) = layers.first() else {
            return g;
        };
        let mut prev = g.add_node(
            format!("{}.input", g.name),
            OpKind::Input {
                shape: TensorShape::of_layer_input(first),
            },
            &[],
        );
        for spec in layers {
            prev = g.add_node(
                spec.name.clone(),
                OpKind::Deconv { spec: spec.clone() },
                &[prev],
            );
            if let Some(a) = act {
                prev = g.add_node(
                    format!("{}.{}", spec.name, a),
                    OpKind::Activation { act: a },
                    &[prev],
                );
            }
        }
        g
    }

    /// IOM-form graph of a zoo network.
    pub fn from_network(net: &Network) -> NetworkGraph {
        NetworkGraph::from_layers(net.name, net.dims, &net.layers, None)
    }

    /// IOM-form graph with an activation after every deconv (what the
    /// real generators do: ReLU between layers, tanh at the end — the
    /// uniform `act` is enough to exercise the fusion pass).
    pub fn from_network_with_activations(net: &Network, act: Act) -> NetworkGraph {
        NetworkGraph::from_layers(net.name, net.dims, &net.layers, Some(act))
    }

    /// OOM-form graph of a zoo network: each layer appears as a
    /// `ZeroInsert` + `Conv` pair (what a conventional front end would
    /// emit; [`super::passes::lower_oom_to_iom`] rewrites it).
    pub fn from_network_oom(net: &Network) -> NetworkGraph {
        let mut g = NetworkGraph::new(net.name, net.dims);
        let Some(first) = net.layers.first() else {
            return g;
        };
        let mut prev = g.add_node(
            format!("{}.input", g.name),
            OpKind::Input {
                shape: TensorShape::of_layer_input(first),
            },
            &[],
        );
        for spec in &net.layers {
            let zi = g.add_node(
                format!("{}.zero_insert", spec.name),
                OpKind::ZeroInsert { spec: spec.clone() },
                &[prev],
            );
            prev = g.add_node(
                format!("{}.conv", spec.name),
                OpKind::Conv { spec: spec.clone() },
                &[zi],
            );
        }
        g
    }

    /// The deconvolution layer chain, in execution order.
    pub fn deconv_specs(&self) -> Vec<&LayerSpec> {
        self.nodes
            .iter()
            .filter_map(|n| match &n.op {
                OpKind::Deconv { spec } => Some(spec),
                _ => None,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dcnn::zoo;

    #[test]
    fn from_network_builds_linear_chain() {
        let net = zoo::dcgan();
        let g = NetworkGraph::from_network(&net);
        assert_eq!(g.len(), 5, "input + 4 deconvs");
        assert_eq!(g.edges().len(), 4);
        assert_eq!(g.deconv_specs().len(), 4);
        for (i, n) in g.nodes.iter().enumerate().skip(1) {
            assert_eq!(n.inputs, vec![i - 1]);
        }
        assert_eq!(g.consumers(0), vec![1]);
        assert!(g.consumers(4).is_empty(), "output node has no consumers");
    }

    #[test]
    fn oom_form_has_two_nodes_per_layer() {
        let net = zoo::gan3d();
        let g = NetworkGraph::from_network_oom(&net);
        assert_eq!(g.len(), 1 + 2 * 4);
        assert!(g.deconv_specs().is_empty(), "no IOM nodes before lowering");
        let mn: Vec<&str> = g.nodes.iter().map(|n| n.op.mnemonic()).collect();
        assert_eq!(mn[0], "input");
        assert_eq!(mn[1], "zero_insert");
        assert_eq!(mn[2], "conv");
    }

    #[test]
    fn activations_appear_between_layers() {
        let net = zoo::tiny_2d();
        let g = NetworkGraph::from_network_with_activations(&net, Act::Relu);
        assert_eq!(g.len(), 1 + 2 * 2);
        assert_eq!(g.nodes[2].op, OpKind::Activation { act: Act::Relu });
        assert_eq!(g.nodes[2].inputs, vec![1]);
    }

    #[test]
    fn tensor_shape_helpers() {
        let spec = &zoo::dcgan().layers[0];
        let i = TensorShape::of_layer_input(spec);
        let o = TensorShape::of_layer_output(spec);
        assert_eq!((i.c, i.d, i.h, i.w), (1024, 1, 4, 4));
        assert_eq!((o.c, o.h, o.w), (512, 8, 8));
        assert_eq!(i.elems(), 1024 * 16);
        assert_eq!(i.bytes(2), 1024 * 16 * 2);
        assert_eq!(format!("{o}"), "512x8x8");
        let spec3 = &zoo::gan3d().layers[0];
        let o3 = TensorShape::of_layer_output(spec3);
        assert_eq!(format!("{o3}"), "256x8x8x8");
    }

    #[test]
    #[should_panic(expected = "must precede")]
    fn forward_references_rejected() {
        let mut g = NetworkGraph::new("bad", Dims::D2);
        g.add_node("n", OpKind::Activation { act: Act::Relu }, &[3]);
    }
}
