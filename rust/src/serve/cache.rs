//! The fleet-wide plan cache: compile once per (model, config), serve
//! everywhere.
//!
//! Compiling a [`crate::graph::NetworkPlan`] (graph build, pass
//! pipeline, per-node schedules, buffer-reuse analysis) is the
//! expensive per-model step of bringing a network online. A fleet of N
//! instances serving the same model must not pay it N times — and a
//! service re-batching at a handful of distinct batch sizes must not
//! pay it per request. [`PlanCache`] keys compiled plans by
//! `<network>@<config fingerprint>` (see
//! [`crate::accel::AccelConfig::fingerprint`]) and hands out shared
//! [`PlanHandle`]s, so every instance hosting a model executes the
//! *same* compiled artifact.
//!
//! An unbounded cache ([`PlanCache::new`]) suits the classic key space
//! (models × distinct batch sizes). Tuned fleets multiply fingerprints
//! — every per-model [`crate::serve::ConfigPolicy`] choice is its own
//! key, and the fleet-tuned policy
//! ([`crate::serve::ConfigPolicy::TunedFleet`]) may assign a different
//! config to every shard of a heterogeneous mix — so
//! [`PlanCache::with_capacity`] bounds the cache with deterministic
//! least-recently-used eviction: the same lookup sequence always holds
//! the same plans, which keeps repeated serving runs byte-for-byte
//! reproducible. The autoscaled fleet ([`crate::serve::AutoFleet`])
//! shares its inner fleet's cache, so boards provisioned mid-run by
//! the scaler serve from already-compiled plans and bring-up latency
//! models *reconfiguration*, not recompilation.

use std::collections::BTreeMap;

use crate::accel::AccelConfig;
use crate::dcnn::Network;
use crate::graph::{compile_network_obs, PlanHandle};
use crate::obs::Obs;

/// Hit/miss/eviction counters of a [`PlanCache`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups answered from the cache.
    pub hits: u64,
    /// Lookups that had to run the graph compiler.
    pub misses: u64,
    /// Plans evicted to stay inside a bounded cache's capacity.
    pub evictions: u64,
}

impl CacheStats {
    /// Fraction of lookups served without compiling (0.0 when unused).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// One cached plan plus its recency stamp.
#[derive(Debug)]
struct Entry {
    plan: PlanHandle,
    last_used: u64,
}

/// Compiled-plan cache keyed by `(network, accelerator config)`.
#[derive(Debug, Default)]
pub struct PlanCache {
    plans: BTreeMap<String, Entry>,
    stats: CacheStats,
    /// `None` = unbounded; `Some(n)` = hold at most `n` plans.
    capacity: Option<usize>,
    /// Monotonic lookup clock driving LRU recency (deterministic: it
    /// advances once per lookup, never from wall time).
    tick: u64,
}

impl PlanCache {
    /// An empty, unbounded cache.
    pub fn new() -> PlanCache {
        PlanCache::default()
    }

    /// An empty cache holding at most `capacity` plans (minimum 1);
    /// beyond that, the least-recently-used plan is evicted.
    pub fn with_capacity(capacity: usize) -> PlanCache {
        PlanCache {
            capacity: Some(capacity.max(1)),
            ..PlanCache::default()
        }
    }

    /// The configured capacity (`None` = unbounded).
    pub fn capacity(&self) -> Option<usize> {
        self.capacity
    }

    /// The cache key for a network under a configuration (delegates
    /// to the canonical [`crate::graph::plan::cache_key_for`]).
    pub fn key(network: &str, cfg: &AccelConfig) -> String {
        crate::graph::plan::cache_key_for(network, cfg)
    }

    /// Look up the compiled plan for `net` under `cfg`, compiling (and
    /// retaining) it on first use. Compilation errors are not cached:
    /// a failing (network, config) pair errors on every call.
    pub fn get_or_compile(
        &mut self,
        cfg: &AccelConfig,
        net: &Network,
    ) -> Result<PlanHandle, String> {
        self.get_or_compile_obs(cfg, net, &Obs::off())
    }

    /// [`PlanCache::get_or_compile`] with observability: hits, misses
    /// and evictions tick the `plan_cache.*` counters, misses run the
    /// compiler under trace spans
    /// ([`crate::graph::compile_network_obs`]), and the residency /
    /// lookup gauges mirror the side-effect-free
    /// [`PlanCache::resident_keys`] / [`PlanCache::lookups`] probes.
    pub fn get_or_compile_obs(
        &mut self,
        cfg: &AccelConfig,
        net: &Network,
        obs: &Obs,
    ) -> Result<PlanHandle, String> {
        let key = PlanCache::key(net.name, cfg);
        self.get_or_compile_keyed_obs(&key, cfg, net, obs)
    }

    /// [`PlanCache::get_or_compile_obs`] with the cache key rendered by
    /// the caller (it must equal `PlanCache::key(net.name, cfg)`). The
    /// serving hot path renders keys into a reused buffer
    /// ([`crate::graph::plan::cache_key_into`]), so a cache *hit*
    /// performs zero heap allocation — the contract the steady-state
    /// battery in `tests/obs_trace.rs` pins.
    pub fn get_or_compile_keyed_obs(
        &mut self,
        key: &str,
        cfg: &AccelConfig,
        net: &Network,
        obs: &Obs,
    ) -> Result<PlanHandle, String> {
        self.tick += 1;
        obs.gauge("plan_cache.lookups", self.tick as f64);
        if let Some(e) = self.plans.get_mut(key) {
            e.last_used = self.tick;
            self.stats.hits += 1;
            obs.count("plan_cache.hits", 1);
            return Ok(PlanHandle::clone(&e.plan));
        }
        let plan = PlanHandle::new(compile_network_obs(cfg, net, obs)?);
        self.stats.misses += 1;
        obs.count("plan_cache.misses", 1);
        self.plans.insert(
            key.to_string(),
            Entry {
                plan: PlanHandle::clone(&plan),
                last_used: self.tick,
            },
        );
        if let Some(cap) = self.capacity {
            while self.plans.len() > cap {
                let lru = self.plans.iter().min_by_key(|(_, e)| e.last_used);
                let key = lru.map(|(k, _)| k.clone()).expect("entry exists");
                self.plans.remove(&key);
                self.stats.evictions += 1;
                obs.count("plan_cache.evictions", 1);
            }
        }
        if obs.is_enabled() {
            obs.gauge("plan_cache.resident", self.resident_keys().len() as f64);
        }
        Ok(plan)
    }

    /// Number of distinct compiled plans held.
    pub fn len(&self) -> usize {
        self.plans.len()
    }

    /// Keys of the currently resident plans, in sorted order.
    /// Read-only: inspecting residency never advances the LRU clock or
    /// the hit/miss counters (the adversarial LRU battery relies on
    /// probing without perturbing).
    pub fn resident_keys(&self) -> Vec<String> {
        self.plans.keys().cloned().collect()
    }

    /// The LRU recency clock: total lookups served so far. Advances by
    /// exactly one per [`PlanCache::get_or_compile`] call and never
    /// from wall time — eviction order is a pure function of the
    /// lookup sequence.
    pub fn lookups(&self) -> u64 {
        self.tick
    }

    /// Whether the cache holds no plans yet.
    pub fn is_empty(&self) -> bool {
        self.plans.is_empty()
    }

    /// Hit/miss/eviction counters so far.
    pub fn stats(&self) -> CacheStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dcnn::zoo;

    #[test]
    fn first_lookup_misses_second_hits() {
        let mut c = PlanCache::new();
        let net = zoo::tiny_2d();
        let cfg = AccelConfig::paper_for(net.dims);
        let a = c.get_or_compile(&cfg, &net).unwrap();
        assert_eq!(
            c.stats(),
            CacheStats {
                hits: 0,
                misses: 1,
                ..CacheStats::default()
            }
        );
        let b = c.get_or_compile(&cfg, &net).unwrap();
        assert_eq!(
            c.stats(),
            CacheStats {
                hits: 1,
                misses: 1,
                ..CacheStats::default()
            }
        );
        assert!(PlanHandle::ptr_eq(&a, &b), "hit returns the same plan");
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn distinct_batch_sizes_are_distinct_entries() {
        let mut c = PlanCache::new();
        let net = zoo::tiny_2d();
        let mut cfg = AccelConfig::paper_for(net.dims);
        c.get_or_compile(&cfg, &net).unwrap();
        cfg.batch = 2;
        c.get_or_compile(&cfg, &net).unwrap();
        assert_eq!(c.len(), 2);
        assert_eq!(c.stats().misses, 2);
    }

    #[test]
    fn distinct_models_are_distinct_entries() {
        let mut c = PlanCache::new();
        let n2 = zoo::tiny_2d();
        let n3 = zoo::tiny_3d();
        c.get_or_compile(&AccelConfig::paper_for(n2.dims), &n2).unwrap();
        c.get_or_compile(&AccelConfig::paper_for(n3.dims), &n3).unwrap();
        assert_eq!(c.len(), 2);
        assert!(!c.is_empty());
    }

    #[test]
    fn unbounded_cache_never_evicts() {
        let mut c = PlanCache::new();
        let net = zoo::tiny_2d();
        let mut cfg = AccelConfig::paper_for(net.dims);
        for b in 1..=24 {
            cfg.batch = b;
            c.get_or_compile(&cfg, &net).unwrap();
        }
        assert_eq!(c.len(), 24);
        assert_eq!(c.stats().evictions, 0);
        assert_eq!(c.capacity(), None);
    }

    #[test]
    fn bounded_cache_evicts_lru_deterministically() {
        let mut c = PlanCache::with_capacity(4);
        let net = zoo::tiny_2d();
        let mut cfg = AccelConfig::paper_for(net.dims);
        for b in 1..=10 {
            cfg.batch = b;
            c.get_or_compile(&cfg, &net).unwrap();
            assert!(c.len() <= 4, "capacity must bound residency");
        }
        assert_eq!(c.stats().evictions, 6);
        // most-recent entries survive: batches 7..=10 hit, batch 1 misses
        cfg.batch = 10;
        c.get_or_compile(&cfg, &net).unwrap();
        assert_eq!(c.stats().hits, 1);
        cfg.batch = 1;
        c.get_or_compile(&cfg, &net).unwrap();
        assert_eq!(c.stats().misses, 11, "evicted entry recompiles");
    }

    #[test]
    fn lru_respects_recency_not_insertion_order() {
        let mut c = PlanCache::with_capacity(2);
        let net = zoo::tiny_2d();
        let mut cfg = AccelConfig::paper_for(net.dims);
        cfg.batch = 1;
        c.get_or_compile(&cfg, &net).unwrap(); // {1}
        cfg.batch = 2;
        c.get_or_compile(&cfg, &net).unwrap(); // {1, 2}
        cfg.batch = 1;
        c.get_or_compile(&cfg, &net).unwrap(); // touch 1: LRU is now 2
        cfg.batch = 3;
        c.get_or_compile(&cfg, &net).unwrap(); // evicts 2, keeps {1, 3}
        cfg.batch = 1;
        c.get_or_compile(&cfg, &net).unwrap();
        assert_eq!(c.stats().hits, 2, "batch-1 plan survived both rounds");
        assert_eq!(c.stats().evictions, 1);
    }

    #[test]
    fn residency_probe_is_side_effect_free() {
        let mut c = PlanCache::with_capacity(2);
        let net = zoo::tiny_2d();
        let mut cfg = AccelConfig::paper_for(net.dims);
        for b in [1usize, 2] {
            cfg.batch = b;
            c.get_or_compile(&cfg, &net).unwrap();
        }
        assert_eq!(c.lookups(), 2);
        let before = c.resident_keys();
        assert_eq!(before.len(), 2);
        // probing neither ticks the clock nor touches the stats
        let _ = c.resident_keys();
        assert_eq!(c.lookups(), 2);
        assert_eq!(c.stats().hits + c.stats().misses, 2);
        cfg.batch = 3;
        c.get_or_compile(&cfg, &net).unwrap(); // evicts batch-1 (LRU)
        let after = c.resident_keys();
        assert_eq!(after.len(), 2);
        assert!(!after.contains(&before[0]) || !after.contains(&before[1]));
        assert_eq!(c.lookups(), 3);
    }

    #[test]
    fn key_matches_plan_cache_key() {
        let mut c = PlanCache::new();
        let net = zoo::tiny_3d();
        let cfg = AccelConfig::paper_for(net.dims);
        let plan = c.get_or_compile(&cfg, &net).unwrap();
        assert_eq!(plan.cache_key(), PlanCache::key(net.name, &cfg));
    }

    #[test]
    fn deterministic_across_runs() {
        // Two independent caches compile byte-identical plans for the
        // same key (the determinism the serving harness depends on).
        let net = zoo::tiny_2d();
        let cfg = AccelConfig::paper_for(net.dims);
        let a = PlanCache::new().get_or_compile(&cfg, &net).unwrap();
        let b = PlanCache::new().get_or_compile(&cfg, &net).unwrap();
        assert_eq!(a.to_json(), b.to_json());
        assert_eq!(a.total_dram_bytes(), b.total_dram_bytes());
    }
}
