//! Deterministic open-loop load generation and latency summaries.
//!
//! The serving harness drives the fleet with an *open-loop* arrival
//! process: request timestamps are drawn up front from a seeded
//! exponential inter-arrival distribution (a Poisson process of rate
//! `rps`), independent of how fast the fleet drains them. Open-loop is
//! the honest way to measure a service — a closed loop would slow its
//! own offered load down exactly when the system congests, hiding the
//! tail latencies the p99 column exists to expose. Everything is
//! seeded through [`crate::util::prng::Prng`], so a (seed, rps, n,
//! models) tuple always produces the identical workload.

use crate::util::prng::Prng;
use crate::util::stats;

/// One generated request: a model invocation at a simulated timestamp.
#[derive(Clone, Debug, PartialEq)]
pub struct Arrival {
    /// Simulated arrival time in seconds since the run started.
    pub t_s: f64,
    /// Model (network) name the request targets.
    pub model: String,
}

/// Draw `n` Poisson arrivals at `rps` requests/second, each targeting
/// a uniformly chosen model from `models`. Deterministic in `seed`.
///
/// Inter-arrival gaps are exponential: `-ln(1 - u) / rps` for uniform
/// `u` — the textbook inverse-CDF draw, safe because
/// [`Prng::f64`] is in `[0, 1)` so the argument of `ln` never hits 0.
///
/// # Panics
/// Panics if `models` is empty or `rps` is not positive.
pub fn poisson_arrivals(seed: u64, rps: f64, n: usize, models: &[&str]) -> Vec<Arrival> {
    assert!(!models.is_empty(), "need at least one model");
    assert!(rps > 0.0, "arrival rate must be positive");
    let mut rng = Prng::new(seed);
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        t += -(1.0 - rng.f64()).ln() / rps;
        let model = models[rng.below(models.len())].to_string();
        out.push(Arrival { t_s: t, model });
    }
    out
}

/// Draw `n` periodic arrivals for one model: request `i` (1-based)
/// lands at `i·period_s` plus a seeded uniform jitter in
/// `[0, jitter_frac·period_s)` — the arrival shape of a streaming
/// source that captures a fixed-size temporal chunk per period and
/// ships it when complete (the first chunk arrives only after it has
/// been captured). With `jitter_frac ≤ 1` the sequence stays sorted,
/// so it feeds [`crate::serve::Fleet::run`] directly; interleave
/// several sources by merging on `t_s`.
///
/// # Panics
/// Panics unless `period_s` is positive and finite and
/// `jitter_frac ∈ [0, 1]`.
pub fn periodic_arrivals(
    seed: u64,
    model: &str,
    period_s: f64,
    n: usize,
    jitter_frac: f64,
) -> Vec<Arrival> {
    assert!(period_s > 0.0 && period_s.is_finite(), "period must be positive");
    assert!((0.0..=1.0).contains(&jitter_frac), "jitter_frac must be in [0, 1]");
    let mut rng = Prng::new(seed);
    (1..=n)
        .map(|i| Arrival {
            t_s: i as f64 * period_s + rng.f64() * jitter_frac * period_s,
            model: model.to_string(),
        })
        .collect()
}

/// Latency percentiles of one serving run (milliseconds).
#[derive(Clone, Copy, Debug, Default)]
pub struct LatencySummary {
    /// Median request latency.
    pub p50_ms: f64,
    /// 95th-percentile request latency.
    pub p95_ms: f64,
    /// 99th-percentile request latency.
    pub p99_ms: f64,
    /// Mean request latency.
    pub mean_ms: f64,
    /// Worst request latency.
    pub max_ms: f64,
}

impl LatencySummary {
    /// Summarize per-request latencies given in seconds. Returns the
    /// all-zero summary for an empty slice (nothing was served).
    pub fn from_latencies_s(xs: &[f64]) -> LatencySummary {
        if xs.is_empty() {
            return LatencySummary::default();
        }
        LatencySummary {
            p50_ms: stats::percentile(xs, 50.0) * 1e3,
            p95_ms: stats::percentile(xs, 95.0) * 1e3,
            p99_ms: stats::percentile(xs, 99.0) * 1e3,
            mean_ms: stats::mean(xs) * 1e3,
            max_ms: xs.iter().copied().fold(f64::MIN, f64::max) * 1e3,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_are_deterministic_and_ordered() {
        let a = poisson_arrivals(42, 100.0, 200, &["a", "b"]);
        let b = poisson_arrivals(42, 100.0, 200, &["a", "b"]);
        assert_eq!(a, b);
        assert!(a.windows(2).all(|w| w[0].t_s <= w[1].t_s));
        assert!(a.iter().all(|x| x.t_s > 0.0));
    }

    #[test]
    fn different_seeds_differ() {
        let a = poisson_arrivals(1, 100.0, 50, &["m"]);
        let b = poisson_arrivals(2, 100.0, 50, &["m"]);
        assert_ne!(a, b);
    }

    #[test]
    fn mean_rate_roughly_matches() {
        let rps = 250.0;
        let n = 4000;
        let a = poisson_arrivals(7, rps, n, &["m"]);
        let span = a.last().unwrap().t_s;
        let observed = n as f64 / span;
        assert!(
            (observed - rps).abs() / rps < 0.1,
            "observed {observed:.1} rps vs {rps}"
        );
    }

    #[test]
    fn models_all_appear() {
        let a = poisson_arrivals(3, 100.0, 300, &["x", "y", "z"]);
        for m in ["x", "y", "z"] {
            assert!(a.iter().any(|r| r.model == m), "{m} never drawn");
        }
    }

    #[test]
    fn periodic_arrivals_stay_sorted_under_full_jitter() {
        for jitter in [0.0, 0.5, 1.0] {
            let a = periodic_arrivals(11, "cam0", 0.04, 50, jitter);
            assert_eq!(a.len(), 50);
            assert!(a.windows(2).all(|w| w[0].t_s <= w[1].t_s), "jitter={jitter}");
            assert!(a[0].t_s >= 0.04, "first chunk arrives after capture");
            assert!(a.iter().all(|x| x.model == "cam0"));
        }
        // deterministic in the seed; zero jitter is exactly periodic
        assert_eq!(periodic_arrivals(3, "m", 0.1, 9, 0.7), periodic_arrivals(3, "m", 0.1, 9, 0.7));
        let exact = periodic_arrivals(3, "m", 0.5, 4, 0.0);
        for (i, a) in exact.iter().enumerate() {
            assert!((a.t_s - 0.5 * (i + 1) as f64).abs() < 1e-12);
        }
    }

    #[test]
    fn summary_percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64 / 1000.0).collect();
        let s = LatencySummary::from_latencies_s(&xs);
        assert!((s.p50_ms - 50.5).abs() < 1.0);
        assert!(s.p95_ms > s.p50_ms);
        assert!(s.p99_ms >= s.p95_ms);
        assert!((s.max_ms - 100.0).abs() < 1e-9);
        let empty = LatencySummary::from_latencies_s(&[]);
        assert_eq!(empty.p99_ms, 0.0);
    }
}
