//! Deterministic load generation and latency summaries.
//!
//! The serving harness drives the fleet with an *open-loop* arrival
//! process: request timestamps are drawn up front from a seeded
//! exponential inter-arrival distribution (a Poisson process of rate
//! `rps`), independent of how fast the fleet drains them. Open-loop is
//! the honest way to measure a service — a closed loop would slow its
//! own offered load down exactly when the system congests, hiding the
//! tail latencies the p99 column exists to expose. Everything is
//! seeded through [`crate::util::prng::Prng`], so a (seed, rps, n,
//! models) tuple always produces the identical workload.
//!
//! Beyond the constant-rate process, [`modulated_arrivals`] draws a
//! *non-homogeneous* Poisson process against a [`RateProfile`]
//! (diurnal swell, flash crowd) by Lewis–Shedler thinning: candidates
//! are drawn at the profile's peak rate and accepted with probability
//! `rate(t)/peak`, which keeps the draw exact and fully deterministic
//! in the seed. Arrivals carry a tenant tag for the multi-tenant
//! fleet ([`crate::serve::AutoFleet`]); the legacy generators leave it
//! empty (the fleet maps an empty tag to its sole/default tenant).
//!
//! [`ClosedLoopSpec`] describes the one *closed-loop* load shape the
//! autoscaled engine supports: a pool of clients that each submit,
//! wait for their response (or shed notice), think for a fixed time,
//! and submit again. Closed-loop clients model interactive sessions —
//! their offered load backs off exactly when the fleet congests, which
//! is why they are kept separate from (and composable with) the
//! open-loop streams that measure capacity honestly.

use crate::util::prng::Prng;
use crate::util::stats;

/// One generated request: a model invocation at a simulated timestamp.
#[derive(Clone, Debug, PartialEq)]
pub struct Arrival {
    /// Simulated arrival time in seconds since the run started.
    pub t_s: f64,
    /// Model (network) name the request targets.
    pub model: String,
    /// Tenant the request bills to. Empty means "the default tenant":
    /// single-tenant fleets accept it as-is, multi-tenant fleets
    /// require a registered tenant name.
    pub tenant: String,
}

impl Arrival {
    /// An arrival for the default (empty) tenant.
    pub fn new(t_s: f64, model: &str) -> Arrival {
        Arrival {
            t_s,
            model: model.to_string(),
            tenant: String::new(),
        }
    }
}

/// Time-varying offered-load shape for [`modulated_arrivals`]. Rates
/// are in requests/second of simulated time.
#[derive(Clone, Debug, PartialEq)]
pub enum RateProfile {
    /// Homogeneous Poisson at a fixed rate (the classic generator,
    /// expressed as a profile).
    Constant {
        /// Arrival rate.
        rps: f64,
    },
    /// A smooth day/night swell: rate follows a raised cosine from
    /// `base_rps` (trough) to `peak_rps` (crest) with period
    /// `period_s`, starting at the trough.
    Diurnal {
        /// Trough arrival rate.
        base_rps: f64,
        /// Crest arrival rate (must be ≥ `base_rps`).
        peak_rps: f64,
        /// Seconds per full trough→crest→trough cycle.
        period_s: f64,
    },
    /// A flash crowd: `base_rps` everywhere except a step to
    /// `base_rps · spike_mult` during `[start_s, start_s + duration_s)`.
    FlashCrowd {
        /// Baseline arrival rate.
        base_rps: f64,
        /// Rate multiplier during the spike (must be ≥ 1).
        spike_mult: f64,
        /// Spike onset, seconds.
        start_s: f64,
        /// Spike length, seconds.
        duration_s: f64,
    },
}

impl RateProfile {
    /// Instantaneous arrival rate at simulated time `t_s`.
    pub fn rate_at(&self, t_s: f64) -> f64 {
        match *self {
            RateProfile::Constant { rps } => rps,
            RateProfile::Diurnal {
                base_rps,
                peak_rps,
                period_s,
            } => {
                let phase = 2.0 * std::f64::consts::PI * t_s / period_s;
                base_rps + (peak_rps - base_rps) * 0.5 * (1.0 - phase.cos())
            }
            RateProfile::FlashCrowd {
                base_rps,
                spike_mult,
                start_s,
                duration_s,
            } => {
                if t_s >= start_s && t_s < start_s + duration_s {
                    base_rps * spike_mult
                } else {
                    base_rps
                }
            }
        }
    }

    /// The profile's peak rate — the thinning envelope of
    /// [`modulated_arrivals`].
    pub fn peak_rps(&self) -> f64 {
        match *self {
            RateProfile::Constant { rps } => rps,
            RateProfile::Diurnal { peak_rps, .. } => peak_rps,
            RateProfile::FlashCrowd {
                base_rps,
                spike_mult,
                ..
            } => base_rps * spike_mult,
        }
    }

    /// Reject malformed profiles (non-positive or non-finite rates,
    /// inverted diurnal bounds, a sub-unity spike multiplier).
    pub fn validate(&self) -> Result<(), String> {
        let ok = |x: f64| x.is_finite() && x > 0.0;
        match *self {
            RateProfile::Constant { rps } => {
                if !ok(rps) {
                    return Err(format!("constant rate must be positive (got {rps})"));
                }
            }
            RateProfile::Diurnal {
                base_rps,
                peak_rps,
                period_s,
            } => {
                if !ok(base_rps) || !ok(peak_rps) || !ok(period_s) {
                    return Err("diurnal rates and period must be positive".into());
                }
                if peak_rps < base_rps {
                    return Err(format!("diurnal peak {peak_rps} below base {base_rps}"));
                }
            }
            RateProfile::FlashCrowd {
                base_rps,
                spike_mult,
                start_s,
                duration_s,
            } => {
                if !ok(base_rps) || !spike_mult.is_finite() || spike_mult < 1.0 {
                    return Err("flash crowd needs base > 0 and spike_mult >= 1".into());
                }
                if !start_s.is_finite() || start_s < 0.0 || !ok(duration_s) {
                    return Err("flash crowd spike window must be non-negative/positive".into());
                }
            }
        }
        Ok(())
    }
}

/// A pool of closed-loop clients: each submits one request, waits for
/// its completion (or shed notice), thinks for `think_s` simulated
/// seconds, and submits the next — `requests_per_client` submissions
/// in total per client. The autoscaled fleet engine
/// ([`crate::serve::AutoFleet::run`]) executes the dynamics; initial
/// submission times are staggered deterministically from the run seed
/// so the pool does not arrive as one synchronized burst.
#[derive(Clone, Debug, PartialEq)]
pub struct ClosedLoopSpec {
    /// Number of concurrent clients in the pool.
    pub clients: usize,
    /// Think time between receiving a response and the next submission.
    pub think_s: f64,
    /// Submissions per client over the run (shed submissions count —
    /// the client observed an answer, thought, and moved on).
    pub requests_per_client: usize,
    /// Model every client in this pool targets.
    pub model: String,
    /// Tenant the pool bills to (empty = default tenant).
    pub tenant: String,
}

impl ClosedLoopSpec {
    /// Reject empty pools and non-finite think times.
    pub fn validate(&self) -> Result<(), String> {
        if self.clients == 0 || self.requests_per_client == 0 {
            return Err("closed-loop pool needs clients and requests_per_client > 0".into());
        }
        if !self.think_s.is_finite() || self.think_s < 0.0 {
            return Err(format!("think_s must be finite and >= 0 (got {})", self.think_s));
        }
        if self.model.is_empty() {
            return Err("closed-loop pool needs a model".into());
        }
        Ok(())
    }
}

/// Draw `n` Poisson arrivals at `rps` requests/second, each targeting
/// a uniformly chosen model from `models`. Deterministic in `seed`.
///
/// Inter-arrival gaps are exponential: `-ln(1 - u) / rps` for uniform
/// `u` — the textbook inverse-CDF draw, safe because
/// [`Prng::f64`] is in `[0, 1)` so the argument of `ln` never hits 0.
///
/// # Panics
/// Panics if `models` is empty or `rps` is not positive.
pub fn poisson_arrivals(seed: u64, rps: f64, n: usize, models: &[&str]) -> Vec<Arrival> {
    assert!(!models.is_empty(), "need at least one model");
    assert!(rps > 0.0, "arrival rate must be positive");
    let mut rng = Prng::new(seed);
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        t += -(1.0 - rng.f64()).ln() / rps;
        let model = models[rng.below(models.len())].to_string();
        out.push(Arrival {
            t_s: t,
            model,
            tenant: String::new(),
        });
    }
    out
}

/// Draw a non-homogeneous Poisson process against `profile` over
/// `[0, horizon_s)` by Lewis–Shedler thinning: candidate gaps are
/// exponential at the profile's peak rate and each candidate at time
/// `t` is kept with probability `rate_at(t) / peak`. Kept arrivals
/// target a uniformly chosen model and are tagged with `tenant`.
/// Deterministic in `seed`; the arrival *count* varies with the seed
/// (it is the process, not a quota, that is fixed).
///
/// # Panics
/// Panics if `models` is empty, the profile fails
/// [`RateProfile::validate`], or `horizon_s` is not positive/finite.
pub fn modulated_arrivals(
    seed: u64,
    profile: &RateProfile,
    horizon_s: f64,
    models: &[&str],
    tenant: &str,
) -> Vec<Arrival> {
    assert!(!models.is_empty(), "need at least one model");
    assert!(horizon_s > 0.0 && horizon_s.is_finite(), "horizon must be positive");
    profile.validate().expect("valid rate profile");
    let peak = profile.peak_rps();
    let mut rng = Prng::new(seed);
    let mut t = 0.0f64;
    let mut out = Vec::new();
    loop {
        t += -(1.0 - rng.f64()).ln() / peak;
        if t >= horizon_s {
            break;
        }
        if rng.f64() < profile.rate_at(t) / peak {
            let model = models[rng.below(models.len())].to_string();
            out.push(Arrival {
                t_s: t,
                model,
                tenant: tenant.to_string(),
            });
        }
    }
    out
}

/// Merge several arrival streams (e.g. one per tenant) into the single
/// time-sorted workload [`crate::serve::Fleet::run`] and
/// [`crate::serve::AutoFleet::run`] expect. Ties break on
/// (tenant, model) so the merge is a pure function of its inputs.
pub fn merge_arrivals(streams: Vec<Vec<Arrival>>) -> Vec<Arrival> {
    let mut out: Vec<Arrival> = streams.into_iter().flatten().collect();
    out.sort_by(|a, b| {
        a.t_s
            .total_cmp(&b.t_s)
            .then_with(|| a.tenant.cmp(&b.tenant))
            .then_with(|| a.model.cmp(&b.model))
    });
    out
}

/// Draw `n` periodic arrivals for one model: request `i` (1-based)
/// lands at `i·period_s` plus a seeded uniform jitter in
/// `[0, jitter_frac·period_s)` — the arrival shape of a streaming
/// source that captures a fixed-size temporal chunk per period and
/// ships it when complete (the first chunk arrives only after it has
/// been captured). With `jitter_frac ≤ 1` the sequence stays sorted,
/// so it feeds [`crate::serve::Fleet::run`] directly; interleave
/// several sources by merging on `t_s`.
///
/// # Panics
/// Panics unless `period_s` is positive and finite and
/// `jitter_frac ∈ [0, 1]`.
pub fn periodic_arrivals(
    seed: u64,
    model: &str,
    period_s: f64,
    n: usize,
    jitter_frac: f64,
) -> Vec<Arrival> {
    assert!(period_s > 0.0 && period_s.is_finite(), "period must be positive");
    assert!((0.0..=1.0).contains(&jitter_frac), "jitter_frac must be in [0, 1]");
    let mut rng = Prng::new(seed);
    (1..=n)
        .map(|i| Arrival {
            t_s: i as f64 * period_s + rng.f64() * jitter_frac * period_s,
            model: model.to_string(),
            tenant: String::new(),
        })
        .collect()
}

/// Latency percentiles of one serving run (milliseconds).
#[derive(Clone, Copy, Debug, Default)]
pub struct LatencySummary {
    /// Median request latency.
    pub p50_ms: f64,
    /// 95th-percentile request latency.
    pub p95_ms: f64,
    /// 99th-percentile request latency.
    pub p99_ms: f64,
    /// Mean request latency.
    pub mean_ms: f64,
    /// Worst request latency.
    pub max_ms: f64,
}

impl LatencySummary {
    /// Summarize per-request latencies given in seconds. Returns the
    /// all-zero summary for an empty slice (nothing was served).
    pub fn from_latencies_s(xs: &[f64]) -> LatencySummary {
        if xs.is_empty() {
            return LatencySummary::default();
        }
        LatencySummary {
            p50_ms: stats::percentile(xs, 50.0) * 1e3,
            p95_ms: stats::percentile(xs, 95.0) * 1e3,
            p99_ms: stats::percentile(xs, 99.0) * 1e3,
            mean_ms: stats::mean(xs) * 1e3,
            max_ms: xs.iter().copied().fold(f64::MIN, f64::max) * 1e3,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_are_deterministic_and_ordered() {
        let a = poisson_arrivals(42, 100.0, 200, &["a", "b"]);
        let b = poisson_arrivals(42, 100.0, 200, &["a", "b"]);
        assert_eq!(a, b);
        assert!(a.windows(2).all(|w| w[0].t_s <= w[1].t_s));
        assert!(a.iter().all(|x| x.t_s > 0.0));
        assert!(a.iter().all(|x| x.tenant.is_empty()), "legacy arrivals are untagged");
    }

    #[test]
    fn different_seeds_differ() {
        let a = poisson_arrivals(1, 100.0, 50, &["m"]);
        let b = poisson_arrivals(2, 100.0, 50, &["m"]);
        assert_ne!(a, b);
    }

    #[test]
    fn mean_rate_roughly_matches() {
        let rps = 250.0;
        let n = 4000;
        let a = poisson_arrivals(7, rps, n, &["m"]);
        let span = a.last().unwrap().t_s;
        let observed = n as f64 / span;
        assert!(
            (observed - rps).abs() / rps < 0.1,
            "observed {observed:.1} rps vs {rps}"
        );
    }

    #[test]
    fn models_all_appear() {
        let a = poisson_arrivals(3, 100.0, 300, &["x", "y", "z"]);
        for m in ["x", "y", "z"] {
            assert!(a.iter().any(|r| r.model == m), "{m} never drawn");
        }
    }

    #[test]
    fn modulated_constant_matches_poisson_statistics() {
        let profile = RateProfile::Constant { rps: 200.0 };
        let a = modulated_arrivals(9, &profile, 20.0, &["m"], "t0");
        let b = modulated_arrivals(9, &profile, 20.0, &["m"], "t0");
        assert_eq!(a, b, "deterministic in the seed");
        assert!(a.windows(2).all(|w| w[0].t_s <= w[1].t_s));
        assert!(a.iter().all(|x| x.tenant == "t0" && x.t_s < 20.0));
        // a constant profile never thins: the count is a plain Poisson
        // draw at rps·horizon = 4000 expected
        let n = a.len() as f64;
        assert!((n - 4000.0).abs() < 400.0, "got {n} arrivals");
    }

    #[test]
    fn flash_crowd_concentrates_arrivals_in_the_spike() {
        let profile = RateProfile::FlashCrowd {
            base_rps: 50.0,
            spike_mult: 10.0,
            start_s: 4.0,
            duration_s: 2.0,
        };
        let a = modulated_arrivals(11, &profile, 10.0, &["m"], "");
        let in_spike = a.iter().filter(|x| x.t_s >= 4.0 && x.t_s < 6.0).count();
        let outside = a.len() - in_spike;
        // spike window carries 1000 expected arrivals vs 400 outside
        assert!(
            in_spike as f64 > 1.5 * outside as f64,
            "spike {in_spike} vs outside {outside}"
        );
        // the spike is a 10x *rate step*, visible as a 10x density step
        let spike_density = in_spike as f64 / 2.0;
        let base_density = outside as f64 / 8.0;
        let step = spike_density / base_density;
        assert!((step - 10.0).abs() < 3.0, "rate step was {step:.1}x");
    }

    #[test]
    fn diurnal_peaks_mid_period() {
        let profile = RateProfile::Diurnal {
            base_rps: 20.0,
            peak_rps: 400.0,
            period_s: 10.0,
        };
        assert!((profile.rate_at(0.0) - 20.0).abs() < 1e-9);
        assert!((profile.rate_at(5.0) - 400.0).abs() < 1e-9);
        assert!((profile.rate_at(10.0) - 20.0).abs() < 1e-6);
        let a = modulated_arrivals(13, &profile, 10.0, &["m"], "");
        let crest = a.iter().filter(|x| x.t_s >= 3.0 && x.t_s < 7.0).count();
        let trough = a.len() - crest;
        assert!(crest > trough, "crest {crest} vs trough {trough}");
    }

    #[test]
    fn profile_validation_rejects_nonsense() {
        assert!(RateProfile::Constant { rps: 0.0 }.validate().is_err());
        assert!(RateProfile::Diurnal {
            base_rps: 10.0,
            peak_rps: 5.0,
            period_s: 1.0
        }
        .validate()
        .is_err());
        assert!(RateProfile::FlashCrowd {
            base_rps: 10.0,
            spike_mult: 0.5,
            start_s: 0.0,
            duration_s: 1.0
        }
        .validate()
        .is_err());
        assert!(ClosedLoopSpec {
            clients: 0,
            think_s: 0.1,
            requests_per_client: 1,
            model: "m".into(),
            tenant: String::new(),
        }
        .validate()
        .is_err());
    }

    #[test]
    fn merge_is_sorted_and_stable_across_input_order() {
        let a = modulated_arrivals(1, &RateProfile::Constant { rps: 100.0 }, 2.0, &["x"], "a");
        let b = modulated_arrivals(2, &RateProfile::Constant { rps: 100.0 }, 2.0, &["y"], "b");
        let m1 = merge_arrivals(vec![a.clone(), b.clone()]);
        let m2 = merge_arrivals(vec![b, a]);
        assert_eq!(m1, m2, "merge is a pure function of the set of streams");
        assert!(m1.windows(2).all(|w| w[0].t_s <= w[1].t_s));
    }

    #[test]
    fn periodic_arrivals_stay_sorted_under_full_jitter() {
        for jitter in [0.0, 0.5, 1.0] {
            let a = periodic_arrivals(11, "cam0", 0.04, 50, jitter);
            assert_eq!(a.len(), 50);
            assert!(a.windows(2).all(|w| w[0].t_s <= w[1].t_s), "jitter={jitter}");
            assert!(a[0].t_s >= 0.04, "first chunk arrives after capture");
            assert!(a.iter().all(|x| x.model == "cam0"));
        }
        // deterministic in the seed; zero jitter is exactly periodic
        assert_eq!(periodic_arrivals(3, "m", 0.1, 9, 0.7), periodic_arrivals(3, "m", 0.1, 9, 0.7));
        let exact = periodic_arrivals(3, "m", 0.5, 4, 0.0);
        for (i, a) in exact.iter().enumerate() {
            assert!((a.t_s - 0.5 * (i + 1) as f64).abs() < 1e-12);
        }
    }

    #[test]
    fn summary_percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64 / 1000.0).collect();
        let s = LatencySummary::from_latencies_s(&xs);
        assert!((s.p50_ms - 50.5).abs() < 1.0);
        assert!(s.p95_ms > s.p50_ms);
        assert!(s.p99_ms >= s.p95_ms);
        assert!((s.max_ms - 100.0).abs() < 1e-9);
        let empty = LatencySummary::from_latencies_s(&[]);
        assert_eq!(empty.p99_ms, 0.0);
    }
}
