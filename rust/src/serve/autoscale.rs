//! The autoscaled, multi-tenant fleet engine.
//!
//! [`AutoFleet`] wraps the classic fixed-size [`Fleet`] (which it
//! reuses for plan compilation, batch timing/energy memoization and
//! trace narration) with the three behaviours production serving is
//! actually about:
//!
//! * **Autoscaling** — a scaler wakes on a fixed check grid and reads
//!   two signals: total queue depth per ready board, and the p99 of a
//!   sliding window of recent completion latencies. It adds boards
//!   (each paying a configurable *bring-up* latency — FPGA bitstream
//!   reconfiguration — before accepting its first batch) and drains
//!   idle boards gracefully: a draining board takes no new batches and
//!   every in-flight batch runs to completion, so scale-down never
//!   aborts work.
//! * **Tenancy** — every request bills to a [`TenantSpec`] with a
//!   priority class, an SLO and a queue bound. Dispatch favours lower
//!   classes; admission sheds a request whose estimated wait already
//!   blows its tenant's SLO; when the global queue is full, a
//!   newcomer of a strictly higher priority class preempts the
//!   youngest queued request of a lower class (shed with reason
//!   `preempted`) instead of being turned away.
//! * **Failure** — an injected [`FailureSpec`] kills a board
//!   mid-stream. Requests aboard its unfinished batches are returned
//!   to the front of their tenant queues (oldest first) and re-routed;
//!   nothing is silently dropped, so per-tenant conservation
//!   (`submitted == completed + shed`) holds through failures.
//!
//! The engine is a deterministic discrete-event loop in simulated
//! time. Events (batch completions, injected failures, board
//! ready-ups, batch deadlines, scaler checks, open-loop arrivals,
//! closed-loop submissions) are processed in `(time, kind)` order with
//! a fixed kind priority, every container is ordered (`BTreeMap`,
//! `Vec`), and the only randomness is the seeded stagger of
//! closed-loop clients — so a `(workload, options, seed)` triple
//! yields a byte-identical [`FleetReport`] and scaler decision log on
//! every run, on any host.

use std::collections::{BTreeMap, VecDeque};

use crate::dcnn::Network;
use crate::energy::FPGA_STATIC_W;
use crate::obs::Obs;
use crate::report::json::{array, JsonObj};
use crate::resource;
use crate::util::prng::Prng;
use crate::util::stats;

use super::fleet::{Fleet, FleetOptions, FleetReport};
use super::instance::{Instance, InstanceState};
use super::loadgen::{Arrival, ClosedLoopSpec, LatencySummary};
use super::tenant::{TenantReport, TenantSpec};

/// Scaler configuration of an [`AutoFleet`].
#[derive(Clone, Debug)]
pub struct AutoscaleOptions {
    /// Lower bound on board count; the fleet starts here and drain
    /// decisions never go below it. Must be ≥ 1.
    pub min_instances: usize,
    /// Upper bound on board count (lifetime ids may exceed it; *live*
    /// boards never do).
    pub max_instances: usize,
    /// Seconds between a scale-up decision and the new board's first
    /// accepted batch (FPGA reconfiguration + DDR warm-up).
    pub bring_up_s: f64,
    /// Scaler check cadence, simulated seconds.
    pub check_every_s: f64,
    /// Sliding completion-latency window the p99 signal reads.
    pub window_s: f64,
    /// Scale up when total queued requests exceed this many per ready
    /// board.
    pub up_queue_depth: usize,
    /// Scale up when the windowed p99 exceeds this (ms); drain when
    /// the queue is empty and the windowed p99 sits below half of it.
    pub p99_target_ms: f64,
    /// A p99-driven decision (up or drain) requires at least this many
    /// window samples — the guard against scaling on a stale window.
    pub min_window_samples: usize,
    /// Minimum seconds between consecutive scaling decisions
    /// (`below-min` recovery bypasses this).
    pub cooldown_s: f64,
}

impl Default for AutoscaleOptions {
    fn default() -> Self {
        AutoscaleOptions {
            min_instances: 1,
            max_instances: 8,
            bring_up_s: 0.010,
            check_every_s: 0.005,
            window_s: 0.020,
            up_queue_depth: 32,
            p99_target_ms: 50.0,
            min_window_samples: 16,
            cooldown_s: 0.010,
        }
    }
}

impl AutoscaleOptions {
    /// Reject unusable scaler configurations.
    pub fn validate(&self) -> Result<(), String> {
        if self.min_instances == 0 {
            return Err("autoscaler needs min_instances >= 1".into());
        }
        if self.max_instances < self.min_instances {
            return Err(format!(
                "max_instances {} below min_instances {}",
                self.max_instances, self.min_instances
            ));
        }
        let pos = |x: f64| x.is_finite() && x > 0.0;
        if !pos(self.check_every_s) || !pos(self.window_s) {
            return Err("check_every_s and window_s must be positive".into());
        }
        if !self.bring_up_s.is_finite() || self.bring_up_s < 0.0 {
            return Err("bring_up_s must be finite and >= 0".into());
        }
        if !pos(self.p99_target_ms) || !self.cooldown_s.is_finite() || self.cooldown_s < 0.0 {
            return Err("p99_target_ms must be positive, cooldown_s >= 0".into());
        }
        Ok(())
    }
}

/// One scaler decision, as logged.
#[derive(Clone, Debug)]
pub struct ScalerDecision {
    /// Simulated time of the decision.
    pub t_s: f64,
    /// `"scale-up"` or `"drain"`.
    pub action: String,
    /// Signal that fired: `below-min`, `queue-depth`,
    /// `p99-above-target`, or `idle`.
    pub reason: String,
    /// Board the decision created or drained.
    pub instance: usize,
    /// Total queued requests at decision time.
    pub queue_depth: usize,
    /// Windowed p99 (ms) at decision time (0 when the window is empty).
    pub window_p99_ms: f64,
    /// Completion samples in the window at decision time.
    pub window_samples: usize,
    /// Active board count after the decision.
    pub active_after: usize,
}

impl ScalerDecision {
    /// JSON object for the decision log.
    pub fn to_json(&self) -> JsonObj {
        JsonObj::new()
            .num("t_s", self.t_s)
            .str("action", &self.action)
            .str("reason", &self.reason)
            .int("instance", self.instance as u64)
            .int("queue_depth", self.queue_depth as u64)
            .num("window_p99_ms", self.window_p99_ms)
            .int("window_samples", self.window_samples as u64)
            .int("active_after", self.active_after as u64)
    }
}

/// Lifecycle record of one board over a run.
#[derive(Clone, Debug)]
pub struct InstanceLife {
    /// Board id.
    pub id: usize,
    /// Simulated provisioning time.
    pub created_s: f64,
    /// When bring-up completed (`created_s + bring_up_s`).
    pub ready_s: f64,
    /// When the first batch started, if any (always ≥ `ready_s`).
    pub first_start_s: Option<f64>,
    /// When the board left service, if it did.
    pub retired_s: Option<f64>,
    /// Final state label (`active` / `drained` / `failed`).
    pub retirement: String,
}

impl InstanceLife {
    /// JSON object for the lifecycle log.
    pub fn to_json(&self) -> JsonObj {
        JsonObj::new()
            .int("id", self.id as u64)
            .num("created_s", self.created_s)
            .num("ready_s", self.ready_s)
            .num("first_start_s", self.first_start_s.unwrap_or(f64::NAN))
            .num("retired_s", self.retired_s.unwrap_or(f64::NAN))
            .str("state", &self.retirement)
    }
}

/// Scaler outcome of one run: bounds, decision log, board lifecycles.
#[derive(Clone, Debug)]
pub struct ScalerReport {
    /// Configured lower bound.
    pub min_instances: usize,
    /// Configured upper bound.
    pub max_instances: usize,
    /// Configured bring-up latency.
    pub bring_up_s: f64,
    /// Peak simultaneous non-retired boards.
    pub peak_active: usize,
    /// Every decision, in time order.
    pub decisions: Vec<ScalerDecision>,
    /// Every board the run ever provisioned.
    pub lives: Vec<InstanceLife>,
}

impl ScalerReport {
    /// The decision log alone, rendered as a JSON array — the byte
    /// string the determinism property pins.
    pub fn decisions_json(&self) -> String {
        let items: Vec<String> = self.decisions.iter().map(|d| d.to_json().render()).collect();
        array(&items)
    }

    /// JSON object for [`FleetReport::to_json`].
    pub fn to_json(&self) -> JsonObj {
        let lives: Vec<String> = self.lives.iter().map(|l| l.to_json().render()).collect();
        JsonObj::new()
            .int("min_instances", self.min_instances as u64)
            .int("max_instances", self.max_instances as u64)
            .num("bring_up_s", self.bring_up_s)
            .int("peak_active", self.peak_active as u64)
            .raw("decisions", &self.decisions_json())
            .raw("instances", &array(&lives))
    }

    /// Text lines for [`FleetReport::render`].
    pub fn render(&self) -> String {
        let ups = self.decisions.iter().filter(|d| d.action == "scale-up").count();
        let drains = self.decisions.len() - ups;
        let mut out = format!(
            "scaler: [{}, {}] boards | bring-up {:.1} ms | peak {} | {} scale-ups | {} drains\n",
            self.min_instances,
            self.max_instances,
            self.bring_up_s * 1e3,
            self.peak_active,
            ups,
            drains
        );
        for d in &self.decisions {
            out.push_str(&format!(
                "  t={:.4}s {} board {} ({}; depth {}, p99 {:.3} ms, {} active after)\n",
                d.t_s, d.action, d.instance, d.reason, d.queue_depth, d.window_p99_ms,
                d.active_after
            ));
        }
        out
    }
}

/// Cost-normalized figures of one run (the arXiv:2102.00294 axis:
/// throughput per DSP and energy per request, not raw req/s).
#[derive(Clone, Debug)]
pub struct CostReport {
    /// DSP slices of the widest per-model configuration — the
    /// provisioning cost of one board.
    pub board_dsp: u64,
    /// Board-seconds provisioned (creation to retirement or end of
    /// run, summed over boards — bring-up time included; boards cost
    /// money while reconfiguring).
    pub active_board_s: f64,
    /// `active_board_s / makespan`: mean boards provisioned.
    pub mean_active_boards: f64,
    /// Served req/s per provisioned DSP slice
    /// (`throughput_rps / (board_dsp · mean_active_boards)`).
    pub throughput_per_dsp: f64,
    /// Total energy: per-batch activity-scaled energy plus static
    /// power over provisioned-but-idle board time.
    pub energy_j: f64,
    /// `energy_j / served`, in millijoules.
    pub mj_per_request: f64,
}

impl CostReport {
    /// JSON object for [`FleetReport::to_json`].
    pub fn to_json(&self) -> JsonObj {
        JsonObj::new()
            .int("board_dsp", self.board_dsp)
            .num("active_board_s", self.active_board_s)
            .num("mean_active_boards", self.mean_active_boards)
            .num("throughput_per_dsp", self.throughput_per_dsp)
            .num("energy_j", self.energy_j)
            .num("mj_per_request", self.mj_per_request)
    }

    /// Text lines for [`FleetReport::render`].
    pub fn render(&self) -> String {
        format!(
            "cost: {:.4} req/s/DSP ({} DSP/board, mean {:.2} boards) | {:.3} J | {:.3} mJ/req\n",
            self.throughput_per_dsp,
            self.board_dsp,
            self.mean_active_boards,
            self.energy_j,
            self.mj_per_request
        )
    }
}

/// An injected board failure: `instance` dies at `t_s`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FailureSpec {
    /// Simulated failure time.
    pub t_s: f64,
    /// Board id to kill.
    pub instance: usize,
}

/// One queued request.
#[derive(Clone, Copy, Debug)]
struct Req {
    t0: f64,
    tid: u64,
    client: Option<usize>,
}

/// One dispatched, not-yet-completed batch.
#[derive(Clone, Debug)]
struct FlightBatch {
    done_s: f64,
    instance: usize,
    model: String,
    reqs: Vec<(usize, Req)>,
}

/// One closed-loop client.
#[derive(Clone, Debug)]
struct Client {
    model: String,
    tenant_ix: usize,
    think_s: f64,
    /// Submissions still to make (decremented at submission time).
    remaining: usize,
    /// Next submission time; `None` while awaiting a response.
    next_t: Option<f64>,
}

/// Per-tenant running tallies.
#[derive(Clone, Debug, Default)]
struct TenantAcc {
    submitted: u64,
    completed: u64,
    shed: u64,
    reasons: BTreeMap<String, u64>,
    lats: Vec<f64>,
    violations: u64,
}

/// Mutable state of one [`AutoFleet::run`] replay, kept apart from the
/// fleet so engine methods can borrow both without aliasing.
struct EngineState {
    /// model → per-tenant-index FIFO queues.
    pend: BTreeMap<String, Vec<VecDeque<Req>>>,
    /// In-flight batches by dispatch sequence number.
    flight: BTreeMap<u64, FlightBatch>,
    next_seq: u64,
    next_tid: u64,
    /// Sliding `(completion time, latency)` window for the p99 signal.
    window: VecDeque<(f64, f64)>,
    clients: Vec<Client>,
    tacc: Vec<TenantAcc>,
    lats: Vec<f64>,
    per_model: BTreeMap<String, u64>,
    offered: u64,
    batches: u64,
    energy_j: f64,
    last_done_s: f64,
    decisions: Vec<ScalerDecision>,
    last_scale_s: f64,
    peak_active: usize,
    /// `(ready time, board id)` of boards still in bring-up.
    pending_ready: Vec<(f64, usize)>,
}

impl EngineState {
    fn total_queued(&self) -> usize {
        self.pend.values().flatten().map(|q| q.len()).sum()
    }

    fn tenant_queued(&self, ix: usize) -> usize {
        self.pend.values().map(|tqs| tqs[ix].len()).sum()
    }
}

/// An autoscaling, multi-tenant fleet over a composed classic
/// [`Fleet`] (one shared plan cache, latency/energy memo and trace
/// scheme). See the module docs for the model.
pub struct AutoFleet {
    core: Fleet,
    auto: AutoscaleOptions,
    /// Sorted by `(class, name)`: index order IS priority order.
    tenants: Vec<TenantSpec>,
    boards: Vec<Instance>,
}

impl AutoFleet {
    /// Bring an autoscaled fleet online with `auto.min_instances`
    /// boards ready at t = 0. `tenants` may be empty (a sole implicit
    /// [`TenantSpec::default_tenant`] is used); names must be unique.
    /// `opts.shard_models` is rejected — every board hosts every model
    /// so the scaler's boards are interchangeable.
    pub fn new(
        networks: Vec<Network>,
        opts: FleetOptions,
        auto: AutoscaleOptions,
        tenants: Vec<TenantSpec>,
    ) -> Result<AutoFleet, String> {
        AutoFleet::new_obs(networks, opts, auto, tenants, Obs::off())
    }

    /// [`AutoFleet::new`] with an observability handle: batches,
    /// requests and sheds narrate like the classic fleet, and every
    /// scaler decision lands on a dedicated `scaler` track.
    pub fn new_obs(
        networks: Vec<Network>,
        opts: FleetOptions,
        auto: AutoscaleOptions,
        tenants: Vec<TenantSpec>,
        obs: Obs,
    ) -> Result<AutoFleet, String> {
        auto.validate()?;
        if opts.shard_models {
            return Err("autoscaled fleets replicate every model; sharding unsupported".into());
        }
        let mut tenants = if tenants.is_empty() {
            vec![TenantSpec::default_tenant()]
        } else {
            tenants
        };
        for t in &tenants {
            t.validate()?;
        }
        tenants.sort_by(|a, b| a.class.cmp(&b.class).then_with(|| a.name.cmp(&b.name)));
        for pair in tenants.windows(2) {
            if pair[0].name == pair[1].name {
                return Err(format!("tenant '{}' registered twice", pair[0].name));
            }
        }
        let core_opts = FleetOptions {
            instances: 1, // the core's own boards are unused
            ..opts
        };
        let core = Fleet::new_obs(networks, core_opts, obs)?;
        let boards = (0..auto.min_instances).map(|id| Instance::new(id, vec![])).collect();
        Ok(AutoFleet {
            core,
            auto,
            tenants,
            boards,
        })
    }

    /// The tenant roster, in priority order.
    pub fn tenants(&self) -> &[TenantSpec] {
        &self.tenants
    }

    /// The scaler configuration.
    pub fn autoscale_options(&self) -> &AutoscaleOptions {
        &self.auto
    }

    /// Resolve an arrival's tenant tag to a roster index. An empty tag
    /// maps to the sole tenant, or to one literally named `default`.
    fn tenant_ix(&self, tag: &str) -> Result<usize, String> {
        if tag.is_empty() {
            if self.tenants.len() == 1 {
                return Ok(0);
            }
            return self
                .tenants
                .iter()
                .position(|t| t.name == "default")
                .ok_or_else(|| "untagged arrival in a multi-tenant fleet".to_string());
        }
        self.tenants
            .iter()
            .position(|t| t.name == tag)
            .ok_or_else(|| format!("unknown tenant '{tag}'"))
    }

    /// Replay a workload: open-loop `arrivals` (sorted by time, as
    /// [`crate::serve::merge_arrivals`] produces), closed-loop client
    /// pools, and injected board failures. `seed` staggers the
    /// closed-loop clients' first submissions. Deterministic: equal
    /// inputs yield a byte-identical report and decision log.
    pub fn run(
        &mut self,
        arrivals: &[Arrival],
        closed: &[ClosedLoopSpec],
        failures: &[FailureSpec],
        seed: u64,
    ) -> Result<FleetReport, String> {
        if arrivals.windows(2).any(|w| w[0].t_s > w[1].t_s) {
            return Err("arrivals must be sorted by time".into());
        }
        for a in arrivals {
            if self.core.model_config(&a.model).is_none() {
                return Err(format!("unknown model '{}' in workload", a.model));
            }
            self.tenant_ix(&a.tenant)?;
        }
        let mut st = self.init_state(closed, seed)?;
        let mut failures: Vec<FailureSpec> = failures.to_vec();
        failures.sort_by(|a, b| a.t_s.total_cmp(&b.t_s).then(a.instance.cmp(&b.instance)));
        for f in &failures {
            if f.instance >= self.boards.len() {
                return Err(format!("failure targets unknown board {}", f.instance));
            }
        }

        let first_event_s = arrivals
            .first()
            .map(|a| a.t_s)
            .into_iter()
            .chain(st.clients.iter().filter_map(|c| c.next_t))
            .fold(f64::INFINITY, f64::min);
        let mut arr_ix = 0usize;
        let mut fail_ix = 0usize;
        let mut next_check = self.auto.check_every_s;
        let mut last_now = 0.0f64;
        let max_wait = self.core.options().policy.max_wait.as_secs_f64();

        loop {
            let work_remains = arr_ix < arrivals.len()
                || st.clients.iter().any(|c| c.next_t.is_some())
                || st.total_queued() > 0
                || !st.flight.is_empty();
            if !work_remains {
                break;
            }
            // candidate events as (time, kind); kind breaks time ties:
            // 0 completion, 1 failure, 2 ready, 3 deadline, 4 check,
            // 5 arrival, 6 closed-loop submission
            let mut best: Option<(f64, u8)> = None;
            let offer = |t: f64, kind: u8, best: &mut Option<(f64, u8)>| {
                let better = match *best {
                    None => true,
                    Some((bt, bk)) => t < bt || (t == bt && kind < bk),
                };
                if better {
                    *best = Some((t, kind));
                }
            };
            let done_t = st.flight.values().map(|f| f.done_s).fold(f64::INFINITY, f64::min);
            if done_t.is_finite() {
                offer(done_t, 0, &mut best);
            }
            if fail_ix < failures.len() {
                offer(failures[fail_ix].t_s, 1, &mut best);
            }
            let ready_t = st.pending_ready.iter().map(|&(t, _)| t).fold(f64::INFINITY, f64::min);
            if ready_t.is_finite() {
                offer(ready_t, 2, &mut best);
            }
            let deadline = st
                .pend
                .values()
                .flatten()
                .filter_map(|q| q.front())
                .map(|r| r.t0 + max_wait)
                .fold(f64::INFINITY, f64::min);
            if deadline.is_finite() && deadline > last_now {
                offer(deadline, 3, &mut best);
            }
            offer(next_check, 4, &mut best);
            if arr_ix < arrivals.len() {
                offer(arrivals[arr_ix].t_s, 5, &mut best);
            }
            let client_t = st
                .clients
                .iter()
                .filter_map(|c| c.next_t)
                .fold(f64::INFINITY, f64::min);
            if client_t.is_finite() {
                offer(client_t, 6, &mut best);
            }
            let Some((now, kind)) = best else { break };
            last_now = last_now.max(now);
            match kind {
                0 => self.handle_completion(&mut st, now)?,
                1 => {
                    let f = failures[fail_ix];
                    fail_ix += 1;
                    self.handle_failure(&mut st, now, f.instance)?;
                }
                2 => {
                    st.pending_ready.retain(|&(t, _)| t > now);
                    self.pump(&mut st, now)?;
                }
                3 => self.pump(&mut st, now)?,
                4 => {
                    next_check += self.auto.check_every_s;
                    self.check_scaler(&mut st, now);
                    self.pump(&mut st, now)?;
                }
                5 => {
                    let a = arrivals[arr_ix].clone();
                    arr_ix += 1;
                    let tix = self.tenant_ix(&a.tenant)?;
                    self.admit(&mut st, now, &a.model, tix, None)?;
                }
                _ => {
                    let cix = self
                        .next_client(&st)
                        .expect("client event offered without a due client");
                    let (model, tix) = {
                        let c = &mut st.clients[cix];
                        c.next_t = None;
                        c.remaining -= 1;
                        (c.model.clone(), c.tenant_ix)
                    };
                    self.admit(&mut st, now, &model, tix, Some(cix))?;
                }
            }
        }

        self.finish_report(st, first_event_s)
    }

    /// Build the initial engine state: empty queues for every
    /// registered model × tenant, and closed-loop clients staggered
    /// uniformly over their think time from `seed`.
    fn init_state(&mut self, closed: &[ClosedLoopSpec], seed: u64) -> Result<EngineState, String> {
        let mut pend = BTreeMap::new();
        let models: Vec<String> = self.core.models().iter().map(|m| m.to_string()).collect();
        for m in &models {
            pend.insert(m.clone(), vec![VecDeque::new(); self.tenants.len()]);
        }
        let mut rng = Prng::new(seed);
        let mut clients = Vec::new();
        for spec in closed {
            spec.validate()?;
            if !models.iter().any(|m| m == &spec.model) {
                return Err(format!("closed-loop pool targets unknown model '{}'", spec.model));
            }
            let tix = self.tenant_ix(&spec.tenant)?;
            for _ in 0..spec.clients {
                let stagger = if spec.think_s > 0.0 {
                    rng.f64() * spec.think_s
                } else {
                    0.0
                };
                clients.push(Client {
                    model: spec.model.clone(),
                    tenant_ix: tix,
                    think_s: spec.think_s,
                    remaining: spec.requests_per_client,
                    next_t: Some(stagger),
                });
            }
        }
        Ok(EngineState {
            pend,
            flight: BTreeMap::new(),
            next_seq: 0,
            next_tid: 0,
            window: VecDeque::new(),
            clients,
            tacc: vec![TenantAcc::default(); self.tenants.len()],
            lats: Vec::new(),
            per_model: BTreeMap::new(),
            offered: 0,
            batches: 0,
            energy_j: 0.0,
            last_done_s: 0.0,
            decisions: Vec::new(),
            last_scale_s: f64::NEG_INFINITY,
            peak_active: self.boards.len(),
            pending_ready: Vec::new(),
        })
    }

    /// The due client with the earliest `next_t` (ties to the lowest
    /// index).
    fn next_client(&self, st: &EngineState) -> Option<usize> {
        let mut best: Option<(f64, usize)> = None;
        for (ix, c) in st.clients.iter().enumerate() {
            if let Some(t) = c.next_t {
                let better = best.is_none_or(|(bt, _)| t < bt);
                if better {
                    best = Some((t, ix));
                }
            }
        }
        best.map(|(_, ix)| ix)
    }

    /// Process the earliest batch completion.
    fn handle_completion(&mut self, st: &mut EngineState, now: f64) -> Result<(), String> {
        let seq = st
            .flight
            .iter()
            .min_by(|a, b| a.1.done_s.total_cmp(&b.1.done_s).then(a.0.cmp(b.0)))
            .map(|(s, _)| *s)
            .expect("completion event without a flight");
        let fb = st.flight.remove(&seq).expect("flight vanished");
        st.last_done_s = st.last_done_s.max(fb.done_s);
        for (tix, req) in &fb.reqs {
            let lat = fb.done_s - req.t0;
            st.lats.push(lat);
            st.window.push_back((fb.done_s, lat));
            let acc = &mut st.tacc[*tix];
            acc.completed += 1;
            acc.lats.push(lat);
            if lat * 1e3 > self.tenants[*tix].slo_ms {
                acc.violations += 1;
            }
            *st.per_model.entry(fb.model.clone()).or_insert(0) += 1;
            if let Some(cix) = req.client {
                let c = &mut st.clients[cix];
                if c.remaining > 0 {
                    c.next_t = Some(fb.done_s + c.think_s);
                }
            }
        }
        // a draining board retires the moment its last batch lands
        let b = &mut self.boards[fb.instance];
        if b.state() == InstanceState::Draining {
            b.try_finish_drain(now);
        }
        self.pump(st, now)
    }

    /// Kill a board: requeue the requests aboard its unfinished
    /// batches (front of their tenant queues, oldest first) and
    /// re-route via the pump. Conservation holds — nothing is dropped.
    fn handle_failure(&mut self, st: &mut EngineState, now: f64, id: usize) -> Result<(), String> {
        let b = &mut self.boards[id];
        if matches!(b.state(), InstanceState::Drained | InstanceState::Failed) {
            return self.pump(st, now); // already gone; nothing to kill
        }
        b.fail(now);
        let seqs: Vec<u64> = st
            .flight
            .iter()
            .filter(|(_, fb)| fb.instance == id)
            .map(|(s, _)| *s)
            .collect();
        let mut wreck: Vec<(String, usize, Req)> = Vec::new();
        for s in seqs {
            let fb = st.flight.remove(&s).expect("flight vanished");
            for (tix, r) in fb.reqs {
                wreck.push((fb.model.clone(), tix, r));
            }
        }
        wreck.sort_by(|a, b| a.2.t0.total_cmp(&b.2.t0).then(a.2.tid.cmp(&b.2.tid)));
        let requeued = wreck.len();
        for (model, tix, r) in wreck.into_iter().rev() {
            st.pend.get_mut(&model).expect("model queue")[tix].push_front(r);
        }
        let obs = self.core.obs();
        if obs.is_enabled() {
            let strack = obs.track("scaler");
            obs.instant(
                strack,
                "failure",
                &format!("board {id} failed"),
                now * 1e6,
                Some(
                    JsonObj::new()
                        .int("instance", id as u64)
                        .int("requeued", requeued as u64),
                ),
            );
            obs.count("fleet.instance_failures", 1);
        }
        self.pump(st, now)
    }

    /// Admit one request at `now`: estimated-wait shed against the
    /// tenant SLO, per-tenant queue bound, global bound with
    /// cross-class preemption, then enqueue and pump.
    fn admit(
        &mut self,
        st: &mut EngineState,
        now: f64,
        model: &str,
        tix: usize,
        client: Option<usize>,
    ) -> Result<(), String> {
        let tid = st.next_tid;
        st.next_tid += 1;
        st.offered += 1;
        st.tacc[tix].submitted += 1;
        let max_batch = self.core.options().policy.max_batch;
        let my_class = self.tenants[tix].class;

        // estimated-wait shed: with R ready boards and A queued
        // requests of my class or better ahead of me, my batch starts
        // after roughly ceil((A+1)/B)·batch_s/R seconds
        let ready_n = self.boards.iter().filter(|b| b.accepts(now)).count();
        if ready_n > 0 {
            let ahead: usize = st
                .pend
                .get(model)
                .map(|tqs| {
                    tqs.iter()
                        .enumerate()
                        .filter(|(ix, _)| self.tenants[*ix].class <= my_class)
                        .map(|(_, q)| q.len())
                        .sum()
                })
                .unwrap_or(0);
            let batch_s = self.core.batch_latency_s(model, max_batch)?;
            let est = (ahead / max_batch + 1) as f64 * batch_s / ready_n as f64;
            let bound = (self.tenants[tix].slo_ms / 1e3).min(self.core.options().latency_budget_s);
            if est > bound {
                self.shed(st, tix, model, tid, now, "budget-exceeded", client);
                return Ok(());
            }
        }
        // per-tenant queue bound
        if st.tenant_queued(tix) >= self.tenants[tix].queue_cap {
            self.shed(st, tix, model, tid, now, "queue-full", client);
            return Ok(());
        }
        // global bound: a higher-priority newcomer preempts the
        // youngest queued request of a strictly lower class
        if st.total_queued() >= self.core.options().queue_cap {
            let mut victim: Option<(f64, u64, String, usize)> = None;
            for (m, tqs) in &st.pend {
                for (ix, q) in tqs.iter().enumerate() {
                    if self.tenants[ix].class <= my_class {
                        continue;
                    }
                    if let Some(back) = q.back() {
                        let better = victim
                            .as_ref()
                            .is_none_or(|(t0, id, _, _)| (back.t0, back.tid) > (*t0, *id));
                        if better {
                            victim = Some((back.t0, back.tid, m.clone(), ix));
                        }
                    }
                }
            }
            match victim {
                Some((_, _, vm, vix)) => {
                    let vr = st.pend.get_mut(&vm).expect("model queue")[vix]
                        .pop_back()
                        .expect("victim vanished");
                    self.shed(st, vix, &vm, vr.tid, now, "preempted", vr.client);
                }
                None => {
                    self.shed(st, tix, model, tid, now, "queue-full", client);
                    return Ok(());
                }
            }
        }
        st.pend.get_mut(model).expect("model queue")[tix].push_back(Req { t0: now, tid, client });
        let obs = self.core.obs();
        if obs.is_enabled() {
            let depth = st.total_queued();
            let ftrack = obs.track("fleet");
            obs.sample(ftrack, "queue_depth", now * 1e6, depth as f64);
        }
        self.pump(st, now)
    }

    /// Record one shed: tenant accounting, the tagged trace event, and
    /// the client's next think (a shed response is still a response).
    #[allow(clippy::too_many_arguments)]
    fn shed(
        &mut self,
        st: &mut EngineState,
        tix: usize,
        model: &str,
        tid: u64,
        t_s: f64,
        reason: &str,
        client: Option<usize>,
    ) {
        let acc = &mut st.tacc[tix];
        acc.shed += 1;
        *acc.reasons.entry(reason.to_string()).or_insert(0) += 1;
        let tenant = self.tenants[tix].name.clone();
        self.core.trace_shed(model, tid, t_s, reason, &tenant);
        if let Some(cix) = client {
            let c = &mut st.clients[cix];
            if c.remaining > 0 {
                c.next_t = Some(t_s + c.think_s);
            }
        }
    }

    /// Late-binding dispatcher: while a batch is *due* (full, or its
    /// oldest request has waited `max_wait`) and an eligible board
    /// exists (ready, ≤ 1 batch in flight — one running, one queued),
    /// form the batch by priority `(class, age)` across tenant queues
    /// and send it.
    fn pump(&mut self, st: &mut EngineState, now: f64) -> Result<(), String> {
        let max_batch = self.core.options().policy.max_batch;
        let max_wait = self.core.options().policy.max_wait.as_secs_f64();
        loop {
            let Some(model) = self.due_model(st, now, max_batch, max_wait) else {
                return Ok(());
            };
            let Some(bix) = self.eligible_board(now) else {
                return Ok(());
            };
            let reqs = Self::pop_batch(st, &self.tenants, &model, max_batch);
            debug_assert!(!reqs.is_empty(), "due model with empty queues");
            let bsize = reqs.len();
            let latency = self.core.batch_latency_s(&model, bsize)?;
            st.energy_j += self.core.batch_energy_j(&model, bsize)?;
            let done = self.boards[bix].run_batch(now, bsize, latency);
            if self.core.obs().is_enabled() {
                let submitted: Vec<(f64, u64)> = reqs.iter().map(|(_, r)| (r.t0, r.tid)).collect();
                self.core.trace_batch(&model, bix, bsize, done, latency, &submitted);
            }
            st.batches += 1;
            let seq = st.next_seq;
            st.next_seq += 1;
            st.flight.insert(
                seq,
                FlightBatch {
                    done_s: done,
                    instance: bix,
                    model,
                    reqs,
                },
            );
        }
    }

    /// The due model with the best `(priority class, oldest request,
    /// name)` key, if any batch is due at `now`.
    fn due_model(
        &self,
        st: &EngineState,
        now: f64,
        max_batch: usize,
        max_wait: f64,
    ) -> Option<String> {
        let mut best: Option<(u8, f64, &String)> = None;
        for (model, tqs) in &st.pend {
            let total: usize = tqs.iter().map(|q| q.len()).sum();
            if total == 0 {
                continue;
            }
            let oldest = tqs
                .iter()
                .filter_map(|q| q.front())
                .map(|r| r.t0)
                .fold(f64::INFINITY, f64::min);
            if total < max_batch && oldest + max_wait > now {
                continue;
            }
            let class = tqs
                .iter()
                .enumerate()
                .find(|(_, q)| !q.is_empty())
                .map(|(ix, _)| self.tenants[ix].class)
                .expect("nonempty model with empty queues");
            let better = match &best {
                None => true,
                Some((bc, bo, bm)) => {
                    class < *bc
                        || (class == *bc && oldest < *bo)
                        || (class == *bc && oldest == *bo && model < *bm)
                }
            };
            if better {
                best = Some((class, oldest, model));
            }
        }
        best.map(|(_, _, m)| m.clone())
    }

    /// The eligible board with the least backlog (ties to the lowest
    /// id): accepting, with at most one batch already in flight.
    /// Index loop: `inflight_batches` prunes (`&mut`), so iterator
    /// adapters cannot hold the simultaneous borrows this scan needs.
    #[allow(clippy::needless_range_loop)]
    fn eligible_board(&mut self, now: f64) -> Option<usize> {
        let mut best: Option<usize> = None;
        for i in 0..self.boards.len() {
            if !self.boards[i].accepts(now) || self.boards[i].inflight_batches(now) > 1 {
                continue;
            }
            let better = match best {
                None => true,
                Some(j) => self.boards[i].busy_until_s < self.boards[j].busy_until_s,
            };
            if better {
                best = Some(i);
            }
        }
        best
    }

    /// Pop up to `max_batch` requests for `model`, best `(class, age)`
    /// first across its tenant queues.
    fn pop_batch(
        st: &mut EngineState,
        tenants: &[TenantSpec],
        model: &str,
        max_batch: usize,
    ) -> Vec<(usize, Req)> {
        let tqs = st.pend.get_mut(model).expect("model queue");
        let mut out = Vec::new();
        while out.len() < max_batch {
            let mut pick: Option<usize> = None;
            for (ix, q) in tqs.iter().enumerate() {
                let Some(front) = q.front() else { continue };
                let better = match pick {
                    None => true,
                    Some(p) => {
                        let pf = tqs[p].front().expect("picked queue emptied");
                        let (ca, cb) = (tenants[ix].class, tenants[p].class);
                        ca < cb || (ca == cb && (front.t0, front.tid) < (pf.t0, pf.tid))
                    }
                };
                if better {
                    pick = Some(ix);
                }
            }
            match pick {
                Some(ix) => out.push((ix, tqs[ix].pop_front().expect("front vanished"))),
                None => break,
            }
        }
        out
    }

    /// Boards in [`InstanceState::Active`] (bring-up included — a
    /// provisioned board counts against the scaler bounds immediately).
    fn active_count(&self) -> usize {
        self.boards.iter().filter(|b| b.state() == InstanceState::Active).count()
    }

    /// One scaler check at `now`: prune the latency window, read the
    /// queue-depth and windowed-p99 signals, and decide.
    fn check_scaler(&mut self, st: &mut EngineState, now: f64) {
        while matches!(st.window.front(), Some(&(t, _)) if t < now - self.auto.window_s) {
            st.window.pop_front();
        }
        let depth = st.total_queued();
        let samples = st.window.len();
        let p99_ms = if samples > 0 {
            let lats: Vec<f64> = st.window.iter().map(|&(_, l)| l).collect();
            stats::percentile(&lats, 99.0) * 1e3
        } else {
            0.0
        };
        let mut active = self.active_count();
        let ready_n = self.boards.iter().filter(|b| b.accepts(now)).count();

        // below-min recovery (after failures) bypasses the cooldown
        while active < self.auto.min_instances {
            self.scale_up(st, now, "below-min", depth, p99_ms, samples);
            active += 1;
        }
        if now - st.last_scale_s < self.auto.cooldown_s {
            return;
        }
        let fresh = samples >= self.auto.min_window_samples;
        if depth > self.auto.up_queue_depth * ready_n.max(1) && active < self.auto.max_instances {
            self.scale_up(st, now, "queue-depth", depth, p99_ms, samples);
        } else if fresh && p99_ms > self.auto.p99_target_ms && active < self.auto.max_instances {
            self.scale_up(st, now, "p99-above-target", depth, p99_ms, samples);
        } else if depth == 0
            && active > self.auto.min_instances
            && fresh
            && p99_ms <= self.auto.p99_target_ms / 2.0
        {
            self.drain_one(st, now, depth, p99_ms, samples);
        }
    }

    /// Provision a new board (ready after bring-up) and log it.
    fn scale_up(
        &mut self,
        st: &mut EngineState,
        now: f64,
        reason: &str,
        depth: usize,
        p99_ms: f64,
        samples: usize,
    ) {
        let id = self.boards.len();
        let b = Instance::with_bring_up(id, vec![], now, self.auto.bring_up_s);
        st.pending_ready.push((b.ready_at_s, id));
        self.boards.push(b);
        st.last_scale_s = now;
        let active = self.active_count();
        st.peak_active = st.peak_active.max(active);
        self.log_decision(st, now, "scale-up", reason, id, depth, p99_ms, samples, active);
    }

    /// Begin a graceful drain of the highest-id ready board, if any.
    fn drain_one(
        &mut self,
        st: &mut EngineState,
        now: f64,
        depth: usize,
        p99_ms: f64,
        samples: usize,
    ) {
        let Some(id) = self.boards.iter().filter(|b| b.accepts(now)).map(|b| b.id).max() else {
            return;
        };
        self.boards[id].begin_drain();
        self.boards[id].try_finish_drain(now); // idle boards retire now
        st.last_scale_s = now;
        let active = self.active_count();
        self.log_decision(st, now, "drain", "idle", id, depth, p99_ms, samples, active);
    }

    /// Append to the decision log and the `scaler` trace track.
    #[allow(clippy::too_many_arguments)]
    fn log_decision(
        &self,
        st: &mut EngineState,
        t_s: f64,
        action: &str,
        reason: &str,
        instance: usize,
        queue_depth: usize,
        window_p99_ms: f64,
        window_samples: usize,
        active_after: usize,
    ) {
        st.decisions.push(ScalerDecision {
            t_s,
            action: action.to_string(),
            reason: reason.to_string(),
            instance,
            queue_depth,
            window_p99_ms,
            window_samples,
            active_after,
        });
        let obs = self.core.obs();
        if obs.is_enabled() {
            let strack = obs.track("scaler");
            obs.instant(
                strack,
                "scaler",
                &format!("{action} board {instance}"),
                t_s * 1e6,
                Some(
                    JsonObj::new()
                        .str("action", action)
                        .str("reason", reason)
                        .int("instance", instance as u64)
                        .int("queue_depth", queue_depth as u64)
                        .num("window_p99_ms", window_p99_ms)
                        .int("active_after", active_after as u64),
                ),
            );
            obs.count(&format!("fleet.scaler.{action}"), 1);
        }
    }

    /// Assemble the [`FleetReport`] (per-tenant sections, scaler log,
    /// cost normalization) from the finished engine state.
    fn finish_report(
        &mut self,
        st: EngineState,
        first_event_s: f64,
    ) -> Result<FleetReport, String> {
        let served = st.lats.len() as u64;
        let makespan = if first_event_s.is_finite() {
            (st.last_done_s - first_event_s).max(0.0)
        } else {
            0.0
        };
        let mut per_tenant = Vec::new();
        let mut shed = 0u64;
        let mut shed_budget = 0u64;
        for (ix, t) in self.tenants.iter().enumerate() {
            let acc = &st.tacc[ix];
            shed += acc.shed;
            shed_budget += acc.reasons.get("budget-exceeded").copied().unwrap_or(0);
            per_tenant.push(TenantReport {
                name: t.name.clone(),
                class: t.class,
                slo_ms: t.slo_ms,
                submitted: acc.submitted,
                completed: acc.completed,
                shed: acc.shed,
                shed_reasons: acc.reasons.clone(),
                latency: LatencySummary::from_latencies_s(&acc.lats),
                slo_violations: acc.violations,
            });
        }
        let lives: Vec<InstanceLife> = self
            .boards
            .iter()
            .map(|b| InstanceLife {
                id: b.id,
                created_s: b.created_s,
                ready_s: b.ready_at_s,
                first_start_s: b.first_start_s,
                retired_s: b.retired_s,
                retirement: b.state().label().to_string(),
            })
            .collect();
        let scaler = ScalerReport {
            min_instances: self.auto.min_instances,
            max_instances: self.auto.max_instances,
            bring_up_s: self.auto.bring_up_s,
            peak_active: st.peak_active,
            decisions: st.decisions,
            lives,
        };
        let board_dsp = self
            .core
            .models()
            .iter()
            .filter_map(|m| self.core.model_config(m))
            .map(|c| resource::estimate(c).dsp as u64)
            .max()
            .unwrap_or(0);
        let active_board_s: f64 = self
            .boards
            .iter()
            .map(|b| b.retired_s.unwrap_or(st.last_done_s.max(b.created_s)) - b.created_s)
            .sum();
        let busy_s: f64 = self.boards.iter().map(|b| b.stats().busy_s).sum();
        let energy_j = st.energy_j + FPGA_STATIC_W * (active_board_s - busy_s).max(0.0);
        let mean_active_boards = if makespan > 0.0 {
            active_board_s / makespan
        } else {
            0.0
        };
        let throughput_rps = if makespan > 0.0 {
            served as f64 / makespan
        } else {
            0.0
        };
        let cost = CostReport {
            board_dsp,
            active_board_s,
            mean_active_boards,
            throughput_per_dsp: if board_dsp > 0 && mean_active_boards > 0.0 {
                throughput_rps / (board_dsp as f64 * mean_active_boards)
            } else {
                0.0
            },
            energy_j,
            mj_per_request: if served > 0 {
                energy_j / served as f64 * 1e3
            } else {
                0.0
            },
        };
        let mut model_configs = BTreeMap::new();
        for m in self.core.models() {
            if let Some(c) = self.core.model_config(m) {
                model_configs.insert(m.to_string(), c.fingerprint());
            }
        }
        let obs = self.core.obs();
        obs.count("fleet.offered", st.offered);
        let metrics = obs.recorder().map(|r| r.metrics_json());
        Ok(FleetReport {
            instances: st.peak_active,
            offered: st.offered,
            served,
            shed,
            shed_budget,
            shed_queue_full: shed - shed_budget,
            batches: st.batches,
            latency: LatencySummary::from_latencies_s(&st.lats),
            throughput_rps,
            makespan_s: makespan,
            per_model: st.per_model,
            per_instance: self.boards.iter().map(|b| b.stats()).collect(),
            cache: self.core.cache_stats(),
            config_policy: self.core.options().config_policy.label().to_string(),
            model_configs,
            metrics,
            per_tenant,
            scaler: Some(scaler),
            cost: Some(cost),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dcnn::zoo;
    use crate::serve::loadgen::{modulated_arrivals, RateProfile};

    fn nets() -> Vec<Network> {
        vec![zoo::tiny_2d(), zoo::tiny_3d()]
    }

    fn small_auto() -> AutoscaleOptions {
        AutoscaleOptions {
            min_instances: 1,
            max_instances: 4,
            bring_up_s: 0.002,
            check_every_s: 0.001,
            window_s: 0.004,
            up_queue_depth: 8,
            p99_target_ms: 5.0,
            min_window_samples: 8,
            cooldown_s: 0.002,
        }
    }

    fn burst(n: usize) -> Vec<Arrival> {
        let profile = RateProfile::Constant { rps: n as f64 * 200.0 };
        modulated_arrivals(0xA57, &profile, 0.005, &["tiny-2d", "tiny-3d"], "")
    }

    #[test]
    fn conservation_and_determinism_hold() {
        let work = burst(256);
        let mut f = AutoFleet::new(nets(), FleetOptions::default(), small_auto(), vec![]).unwrap();
        let r = f.run(&work, &[], &[], 7).unwrap();
        assert_eq!(r.offered, work.len() as u64);
        assert_eq!(r.offered, r.served + r.shed);
        for t in &r.per_tenant {
            assert!(t.conserved(), "{t:?}");
        }
        let mut g = AutoFleet::new(nets(), FleetOptions::default(), small_auto(), vec![]).unwrap();
        let r2 = g.run(&work, &[], &[], 7).unwrap();
        assert_eq!(r.to_json(), r2.to_json(), "byte-identical reports");
        let d1 = r.scaler.as_ref().unwrap().decisions_json();
        let d2 = r2.scaler.as_ref().unwrap().decisions_json();
        assert_eq!(d1, d2, "byte-identical decision logs");
    }

    #[test]
    fn scaler_grows_under_load_and_respects_max() {
        let work = burst(512);
        let mut f = AutoFleet::new(nets(), FleetOptions::default(), small_auto(), vec![]).unwrap();
        let r = f.run(&work, &[], &[], 1).unwrap();
        let s = r.scaler.as_ref().unwrap();
        assert!(
            s.decisions.iter().any(|d| d.action == "scale-up"),
            "a burst at this size must trigger scale-up"
        );
        for d in &s.decisions {
            assert!(d.active_after >= 1 && d.active_after <= 4, "{d:?}");
        }
        assert!(s.peak_active <= 4);
        assert!(s.peak_active > 1);
    }

    #[test]
    fn bring_up_delays_first_batch() {
        let work = burst(512);
        let mut f = AutoFleet::new(nets(), FleetOptions::default(), small_auto(), vec![]).unwrap();
        let r = f.run(&work, &[], &[], 1).unwrap();
        for l in &r.scaler.as_ref().unwrap().lives {
            assert!((l.ready_s - l.created_s) >= 0.0);
            if let Some(fs) = l.first_start_s {
                assert!(fs >= l.ready_s, "board {} served during bring-up", l.id);
            }
        }
    }

    #[test]
    fn failure_requeues_and_conserves() {
        let work = burst(256);
        let auto = AutoscaleOptions {
            min_instances: 2,
            ..small_auto()
        };
        let mut f = AutoFleet::new(nets(), FleetOptions::default(), auto, vec![]).unwrap();
        let r = f.run(&work, &[], &[FailureSpec { t_s: 0.0005, instance: 1 }], 3).unwrap();
        assert_eq!(r.offered, r.served + r.shed);
        let s = r.scaler.as_ref().unwrap();
        let failed = s.lives.iter().find(|l| l.id == 1).unwrap();
        assert_eq!(failed.retirement, "failed");
        assert!(failed.retired_s.is_some());
    }

    #[test]
    fn closed_loop_accounts_every_submission() {
        let spec = ClosedLoopSpec {
            clients: 6,
            think_s: 0.001,
            requests_per_client: 5,
            model: "tiny-2d".into(),
            tenant: String::new(),
        };
        let mut f = AutoFleet::new(nets(), FleetOptions::default(), small_auto(), vec![]).unwrap();
        let r = f.run(&[], &[spec], &[], 11).unwrap();
        assert_eq!(r.offered, 30, "clients x requests_per_client submissions");
        assert_eq!(r.offered, r.served + r.shed);
    }

    #[test]
    fn rejects_bad_configurations() {
        let bad_auto = AutoscaleOptions {
            min_instances: 0,
            ..AutoscaleOptions::default()
        };
        assert!(AutoFleet::new(nets(), FleetOptions::default(), bad_auto, vec![]).is_err());
        let sharded = FleetOptions {
            shard_models: true,
            ..FleetOptions::default()
        };
        assert!(AutoFleet::new(nets(), sharded, AutoscaleOptions::default(), vec![]).is_err());
        let mut f = AutoFleet::new(nets(), FleetOptions::default(), small_auto(), vec![]).unwrap();
        assert!(f.run(&[Arrival::new(0.0, "nope")], &[], &[], 0).is_err());
        let mut tagged = Arrival::new(0.0, "tiny-2d");
        tagged.tenant = "ghost".into();
        assert!(f.run(&[tagged], &[], &[], 0).is_err(), "unknown tenant");
    }
}
