//! One simulated accelerator instance of the fleet.
//!
//! An [`Instance`] models a single VC709-class board running the
//! paper's uniform bitstream: it owns the set of models it can serve
//! (each bound to a compiled-plan handle from the fleet's
//! [`crate::serve::PlanCache`]) and a one-deep execution pipeline in
//! *simulated* time — batches execute back-to-back, so the instance's
//! state is simply the simulated timestamp at which its queue drains
//! (`busy_until_s`) plus the set of in-flight batches used for
//! queue-depth tracking. The shard scheduler reads
//! [`Instance::backlog_s`] / [`Instance::queue_depth`] to route each
//! batch to the least-loaded board and to shed load past the latency
//! budget.

use std::collections::VecDeque;

/// Lifetime counters of one instance.
#[derive(Clone, Copy, Debug, Default)]
pub struct InstanceStats {
    /// Batches executed.
    pub batches: u64,
    /// Requests served (sum of batch sizes).
    pub requests: u64,
    /// Simulated seconds spent executing batches.
    pub busy_s: f64,
}

/// One simulated accelerator instance.
#[derive(Clone, Debug)]
pub struct Instance {
    /// Fleet-wide instance id (also the routing tie-breaker).
    pub id: usize,
    /// Models this instance hosts. An empty list means "all models" —
    /// the fleet's default replication policy.
    pub models: Vec<String>,
    /// Simulated time at which every accepted batch has completed.
    pub busy_until_s: f64,
    /// In-flight batches as `(completion time, batch size)`, oldest
    /// first; pruned as simulated time advances.
    inflight: VecDeque<(f64, usize)>,
    stats: InstanceStats,
}

impl Instance {
    /// A fresh, idle instance. `models` lists the networks it hosts;
    /// pass an empty vec to host every registered model.
    pub fn new(id: usize, models: Vec<String>) -> Instance {
        Instance {
            id,
            models,
            busy_until_s: 0.0,
            inflight: VecDeque::new(),
            stats: InstanceStats::default(),
        }
    }

    /// Whether this instance hosts `model`.
    pub fn supports(&self, model: &str) -> bool {
        self.models.is_empty() || self.models.iter().any(|m| m == model)
    }

    /// Seconds of work already queued ahead of a batch arriving at
    /// simulated time `now_s` (0.0 when idle).
    pub fn backlog_s(&self, now_s: f64) -> f64 {
        (self.busy_until_s - now_s).max(0.0)
    }

    /// Requests admitted but not yet completed at simulated `now_s`.
    pub fn queue_depth(&mut self, now_s: f64) -> usize {
        self.prune(now_s);
        self.inflight.iter().map(|&(_, n)| n).sum()
    }

    /// Execute a batch of `bsize` requests taking `latency_s` of
    /// accelerator time, submitted at simulated `now_s`. The batch
    /// starts when the instance frees up; returns its completion time.
    pub fn run_batch(&mut self, now_s: f64, bsize: usize, latency_s: f64) -> f64 {
        self.prune(now_s);
        let start = self.busy_until_s.max(now_s);
        let done = start + latency_s;
        self.busy_until_s = done;
        self.inflight.push_back((done, bsize));
        self.stats.batches += 1;
        self.stats.requests += bsize as u64;
        self.stats.busy_s += latency_s;
        done
    }

    /// Lifetime counters.
    pub fn stats(&self) -> InstanceStats {
        self.stats
    }

    /// Drop in-flight records whose completion time has passed.
    fn prune(&mut self, now_s: f64) {
        while matches!(self.inflight.front(), Some(&(done, _)) if done <= now_s) {
            self.inflight.pop_front();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_model_list_hosts_everything() {
        let i = Instance::new(0, vec![]);
        assert!(i.supports("dcgan"));
        assert!(i.supports("anything"));
        let j = Instance::new(1, vec!["dcgan".into()]);
        assert!(j.supports("dcgan"));
        assert!(!j.supports("v-net"));
    }

    #[test]
    fn batches_serialize_on_one_instance() {
        let mut i = Instance::new(0, vec![]);
        let d1 = i.run_batch(0.0, 4, 0.010);
        assert!((d1 - 0.010).abs() < 1e-12);
        // submitted while busy: starts when the first batch drains
        let d2 = i.run_batch(0.001, 4, 0.010);
        assert!((d2 - 0.020).abs() < 1e-12);
        assert!((i.backlog_s(0.001) - 0.019).abs() < 1e-12);
        assert_eq!(i.stats().batches, 2);
        assert_eq!(i.stats().requests, 8);
        assert!((i.stats().busy_s - 0.020).abs() < 1e-12);
    }

    #[test]
    fn idle_gap_resets_start_time() {
        let mut i = Instance::new(0, vec![]);
        i.run_batch(0.0, 1, 0.010);
        // long idle gap: the next batch starts at its submit time
        let done = i.run_batch(5.0, 1, 0.010);
        assert!((done - 5.010).abs() < 1e-12);
        assert_eq!(i.backlog_s(10.0), 0.0);
    }

    #[test]
    fn queue_depth_tracks_inflight_requests() {
        let mut i = Instance::new(0, vec![]);
        i.run_batch(0.0, 4, 0.010);
        i.run_batch(0.0, 2, 0.010);
        assert_eq!(i.queue_depth(0.005), 6);
        assert_eq!(i.queue_depth(0.015), 2, "first batch completed");
        assert_eq!(i.queue_depth(0.025), 0, "all drained");
    }
}
