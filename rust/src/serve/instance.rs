//! One simulated accelerator instance of the fleet.
//!
//! An [`Instance`] models a single VC709-class board running the
//! paper's uniform bitstream: it owns the set of models it can serve
//! (each bound to a compiled-plan handle from the fleet's
//! [`crate::serve::PlanCache`]) and a one-deep execution pipeline in
//! *simulated* time — batches execute back-to-back, so the instance's
//! state is simply the simulated timestamp at which its queue drains
//! (`busy_until_s`) plus the set of in-flight batches used for
//! queue-depth tracking. The shard scheduler reads
//! [`Instance::backlog_s`] / [`Instance::queue_depth`] to route each
//! batch to the least-loaded board and to shed load past the latency
//! budget.
//!
//! For the autoscaled fleet ([`crate::serve::AutoFleet`]) an instance
//! additionally carries a lifecycle: it is created at some simulated
//! time, becomes able to accept batches only after its *bring-up*
//! window (FPGA bitstream reconfiguration plus DDR warm-up — the cost
//! that makes scale-up policy a genuine tradeoff), can be put into a
//! graceful *drain* (no new batches, in-flight work runs to
//! completion), and can *fail* (in-flight work is lost to the board
//! and must be re-routed or shed by the scheduler — never silently
//! dropped). [`Instance::new`] keeps the legacy fixed-fleet semantics:
//! active from t = 0 with zero bring-up.

use std::collections::VecDeque;

/// Lifecycle state of one instance.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InstanceState {
    /// Accepting batches (once past its bring-up window).
    Active,
    /// Graceful shutdown: no new batches; in-flight batches complete.
    Draining,
    /// Drain finished; the board is released.
    Drained,
    /// Failed mid-run; in-flight work was lost to the board.
    Failed,
}

impl InstanceState {
    /// Lower-case label used in reports and traces.
    pub fn label(&self) -> &'static str {
        match self {
            InstanceState::Active => "active",
            InstanceState::Draining => "draining",
            InstanceState::Drained => "drained",
            InstanceState::Failed => "failed",
        }
    }
}

/// Lifetime counters of one instance.
#[derive(Clone, Copy, Debug, Default)]
pub struct InstanceStats {
    /// Batches executed.
    pub batches: u64,
    /// Requests served (sum of batch sizes).
    pub requests: u64,
    /// Simulated seconds spent executing batches.
    pub busy_s: f64,
}

/// One simulated accelerator instance.
#[derive(Clone, Debug)]
pub struct Instance {
    /// Fleet-wide instance id (also the routing tie-breaker).
    pub id: usize,
    /// Models this instance hosts. An empty list means "all models" —
    /// the fleet's default replication policy.
    pub models: Vec<String>,
    /// Simulated time at which every accepted batch has completed.
    pub busy_until_s: f64,
    /// Simulated time this board was provisioned.
    pub created_s: f64,
    /// Simulated time the board finishes bring-up and may accept its
    /// first batch (`created_s` + bring-up latency).
    pub ready_at_s: f64,
    /// When the first batch actually started executing, if any — the
    /// bring-up accounting hook (`first_start_s >= ready_at_s` always).
    pub first_start_s: Option<f64>,
    /// When the board left service (drain completed or failure), if it
    /// has.
    pub retired_s: Option<f64>,
    /// In-flight batches as `(completion time, batch size)`, oldest
    /// first; pruned as simulated time advances.
    inflight: VecDeque<(f64, usize)>,
    state: InstanceState,
    stats: InstanceStats,
}

impl Instance {
    /// A fresh, idle instance, active from t = 0 with no bring-up —
    /// the legacy fixed-fleet semantics. `models` lists the networks
    /// it hosts; pass an empty vec to host every registered model.
    pub fn new(id: usize, models: Vec<String>) -> Instance {
        Instance::with_bring_up(id, models, 0.0, 0.0)
    }

    /// A board provisioned at simulated `created_s` that accepts its
    /// first batch only after `bring_up_s` seconds of reconfiguration.
    pub fn with_bring_up(
        id: usize,
        models: Vec<String>,
        created_s: f64,
        bring_up_s: f64,
    ) -> Instance {
        Instance {
            id,
            models,
            busy_until_s: created_s + bring_up_s,
            created_s,
            ready_at_s: created_s + bring_up_s,
            first_start_s: None,
            retired_s: None,
            inflight: VecDeque::new(),
            state: InstanceState::Active,
            stats: InstanceStats::default(),
        }
    }

    /// Current lifecycle state.
    pub fn state(&self) -> InstanceState {
        self.state
    }

    /// Whether this instance hosts `model`.
    pub fn supports(&self, model: &str) -> bool {
        self.models.is_empty() || self.models.iter().any(|m| m == model)
    }

    /// Whether the board may accept a new batch at simulated `now_s`:
    /// it must be [`InstanceState::Active`] and past its bring-up
    /// window.
    pub fn accepts(&self, now_s: f64) -> bool {
        self.state == InstanceState::Active && now_s >= self.ready_at_s
    }

    /// Seconds of work already queued ahead of a batch arriving at
    /// simulated time `now_s` (0.0 when idle).
    pub fn backlog_s(&self, now_s: f64) -> f64 {
        (self.busy_until_s - now_s).max(0.0)
    }

    /// Requests admitted but not yet completed at simulated `now_s`.
    pub fn queue_depth(&mut self, now_s: f64) -> usize {
        self.prune(now_s);
        self.inflight.iter().map(|&(_, n)| n).sum()
    }

    /// Batches admitted but not yet completed at simulated `now_s` —
    /// the late-binding dispatcher's eligibility signal (a board with
    /// ≤ 1 in-flight batch keeps its pipeline fed without building a
    /// head-of-line queue).
    pub fn inflight_batches(&mut self, now_s: f64) -> usize {
        self.prune(now_s);
        self.inflight.len()
    }

    /// Execute a batch of `bsize` requests taking `latency_s` of
    /// accelerator time, submitted at simulated `now_s`. The batch
    /// starts when the instance frees up; returns its completion time.
    pub fn run_batch(&mut self, now_s: f64, bsize: usize, latency_s: f64) -> f64 {
        debug_assert!(
            self.state == InstanceState::Active,
            "batch sent to a {} board",
            self.state.label()
        );
        self.prune(now_s);
        let start = self.busy_until_s.max(now_s);
        debug_assert!(
            start >= self.ready_at_s,
            "batch started during bring-up ({start} < {})",
            self.ready_at_s
        );
        if self.first_start_s.is_none() {
            self.first_start_s = Some(start);
        }
        let done = start + latency_s;
        self.busy_until_s = done;
        self.inflight.push_back((done, bsize));
        self.stats.batches += 1;
        self.stats.requests += bsize as u64;
        self.stats.busy_s += latency_s;
        done
    }

    /// Begin a graceful drain: the board accepts no further batches
    /// but every in-flight batch runs to completion. No-op unless the
    /// board is [`InstanceState::Active`].
    pub fn begin_drain(&mut self) {
        if self.state == InstanceState::Active {
            self.state = InstanceState::Draining;
        }
    }

    /// Complete a drain if all in-flight work has finished by `now_s`.
    /// Returns true when the board transitioned to
    /// [`InstanceState::Drained`] (now or earlier).
    pub fn try_finish_drain(&mut self, now_s: f64) -> bool {
        if self.state == InstanceState::Draining && self.inflight_batches(now_s) == 0 {
            self.state = InstanceState::Drained;
            self.retired_s = Some(now_s);
        }
        self.state == InstanceState::Drained
    }

    /// Fail the board at `now_s`: in-flight batch records are cleared
    /// (the *scheduler* owns the requests that were aboard and must
    /// re-route or shed them) and the board leaves service permanently.
    pub fn fail(&mut self, now_s: f64) {
        self.inflight.clear();
        self.busy_until_s = now_s;
        self.state = InstanceState::Failed;
        self.retired_s = Some(now_s);
    }

    /// Lifetime counters.
    pub fn stats(&self) -> InstanceStats {
        self.stats
    }

    /// Drop in-flight records whose completion time has passed.
    fn prune(&mut self, now_s: f64) {
        while matches!(self.inflight.front(), Some(&(done, _)) if done <= now_s) {
            self.inflight.pop_front();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_model_list_hosts_everything() {
        let i = Instance::new(0, vec![]);
        assert!(i.supports("dcgan"));
        assert!(i.supports("anything"));
        let j = Instance::new(1, vec!["dcgan".into()]);
        assert!(j.supports("dcgan"));
        assert!(!j.supports("v-net"));
    }

    #[test]
    fn batches_serialize_on_one_instance() {
        let mut i = Instance::new(0, vec![]);
        let d1 = i.run_batch(0.0, 4, 0.010);
        assert!((d1 - 0.010).abs() < 1e-12);
        // submitted while busy: starts when the first batch drains
        let d2 = i.run_batch(0.001, 4, 0.010);
        assert!((d2 - 0.020).abs() < 1e-12);
        assert!((i.backlog_s(0.001) - 0.019).abs() < 1e-12);
        assert_eq!(i.stats().batches, 2);
        assert_eq!(i.stats().requests, 8);
        assert!((i.stats().busy_s - 0.020).abs() < 1e-12);
    }

    #[test]
    fn idle_gap_resets_start_time() {
        let mut i = Instance::new(0, vec![]);
        i.run_batch(0.0, 1, 0.010);
        // long idle gap: the next batch starts at its submit time
        let done = i.run_batch(5.0, 1, 0.010);
        assert!((done - 5.010).abs() < 1e-12);
        assert_eq!(i.backlog_s(10.0), 0.0);
    }

    #[test]
    fn queue_depth_tracks_inflight_requests() {
        let mut i = Instance::new(0, vec![]);
        i.run_batch(0.0, 4, 0.010);
        i.run_batch(0.0, 2, 0.010);
        assert_eq!(i.queue_depth(0.005), 6);
        assert_eq!(i.queue_depth(0.015), 2, "first batch completed");
        assert_eq!(i.queue_depth(0.025), 0, "all drained");
        assert_eq!(i.inflight_batches(0.005), 2);
        assert_eq!(i.inflight_batches(0.015), 1);
    }

    #[test]
    fn bring_up_gates_acceptance_and_first_start() {
        let mut i = Instance::with_bring_up(3, vec![], 1.0, 0.5);
        assert!(!i.accepts(1.0), "still reconfiguring");
        assert!(!i.accepts(1.499));
        assert!(i.accepts(1.5));
        // a batch "submitted" at 1.2 cannot start before ready_at_s:
        // busy_until_s is initialized to the bring-up deadline
        let done = i.run_batch(1.2, 2, 0.1);
        assert!((done - 1.6).abs() < 1e-12);
        assert_eq!(i.first_start_s, Some(1.5));
        assert_eq!(i.ready_at_s, 1.5);
        // legacy constructor: ready immediately
        let legacy = Instance::new(0, vec![]);
        assert!(legacy.accepts(0.0));
        assert_eq!(legacy.ready_at_s, 0.0);
    }

    #[test]
    fn drain_waits_for_inflight_then_retires() {
        let mut i = Instance::new(0, vec![]);
        i.run_batch(0.0, 4, 0.010);
        i.begin_drain();
        assert_eq!(i.state(), InstanceState::Draining);
        assert!(!i.accepts(0.005), "draining boards accept nothing");
        assert!(!i.try_finish_drain(0.005), "batch still aboard");
        assert!(i.try_finish_drain(0.010), "batch completed — drained");
        assert_eq!(i.state(), InstanceState::Drained);
        assert_eq!(i.retired_s, Some(0.010));
        // idempotent once drained
        assert!(i.try_finish_drain(0.020));
        // drain of an already-failed board is a no-op
        let mut f = Instance::new(1, vec![]);
        f.fail(0.0);
        f.begin_drain();
        assert_eq!(f.state(), InstanceState::Failed);
    }

    #[test]
    fn failure_clears_inflight_and_retires() {
        let mut i = Instance::new(0, vec![]);
        i.run_batch(0.0, 4, 0.010);
        i.run_batch(0.0, 2, 0.010);
        i.fail(0.005);
        assert_eq!(i.state(), InstanceState::Failed);
        assert!(!i.accepts(0.005));
        assert_eq!(i.inflight_batches(0.005), 0, "wreckage belongs to the scheduler");
        assert_eq!(i.retired_s, Some(0.005));
        assert_eq!(i.backlog_s(0.005), 0.0);
        // counters keep what it did serve before failing
        assert_eq!(i.stats().batches, 2);
    }
}
