//! The fleet: shard-scheduled serving across N simulated instances.
//!
//! A [`Fleet`] owns a set of registered models, a pool of
//! [`Instance`]s, and one fleet-wide [`PlanCache`]. [`Fleet::run`]
//! replays an open-loop workload (see
//! [`crate::serve::poisson_arrivals`]) through a deterministic
//! discrete-event loop in *simulated* time:
//!
//! 1. **Batching** — per-model queues close a batch when it reaches
//!    `max_batch` or when the oldest request has waited `max_wait`,
//!    the same [`BatchPolicy`] contract as the live
//!    [`crate::coordinator::Batcher`], transplanted from wall-clock
//!    into simulated time.
//! 2. **Shard scheduling** — each closed batch is routed to the
//!    least-loaded instance hosting the model (smallest simulated
//!    backlog; ties break on instance id), generalizing the
//!    [`crate::coordinator::Router`]'s model→queue map to a
//!    model→*set-of-instances* map with per-instance load.
//! 3. **Admission control** — a request whose best-case queueing delay
//!    already exceeds the latency budget is shed at arrival instead of
//!    poisoning the tail.
//!
//! Batch execution time comes from [`crate::graph::simulate_plan`] on
//! the cached compiled plan at the actual batch size, so the reported
//! p50/p95/p99 and throughput are the numbers a rack of real VC709s
//! running the paper's architecture would produce. Everything —
//! arrivals, routing, batching — is deterministic: the same workload
//! against the same options yields a byte-identical report.
//!
//! With an observability handle attached ([`Fleet::new_obs`]), the
//! event loop narrates itself onto the simulated timeline: every
//! dispatched batch becomes a span on its instance's track with
//! nested per-layer cycle spans (from [`simulate_plan`]'s step
//! metrics), every admitted request gets an arrival→completion span
//! carrying its trace id, sheds appear as instant events tagged with
//! their reason, and the fleet track samples total queue depth at
//! each admission. Because every timestamp is simulated, the trace is
//! byte-identical across runs.

use std::collections::{BTreeMap, VecDeque};

use crate::accel::dse::tune::{tune_fleet, tune_network, TuneOptions};
use crate::accel::AccelConfig;
use crate::coordinator::BatchPolicy;
use crate::dcnn::Network;
use crate::energy::fpga_watts;
use crate::graph::simulate_plan;
use crate::obs::Obs;
use crate::report::json::{array, JsonObj};

use super::autoscale::{CostReport, ScalerReport};
use super::cache::{CacheStats, PlanCache};
use super::instance::{Instance, InstanceStats};
use super::loadgen::{Arrival, LatencySummary};
use super::tenant::{tenants_to_json, TenantReport};

/// Plan-cache capacity of a fleet. Generous against the classic key
/// space (models × distinct batch sizes), but a hard bound once tuned
/// or heterogeneous fleets start multiplying config fingerprints.
const FLEET_PLAN_CACHE_CAP: usize = 256;

/// How a fleet picks the accelerator configuration each model's plans
/// compile under — the knob behind `udcnn serve --tuned`.
#[derive(Clone, Debug, Default, PartialEq)]
pub enum ConfigPolicy {
    /// The paper's Table-II operating point for the model's
    /// dimensionality ([`AccelConfig::paper_for`]) — the historical
    /// behaviour.
    #[default]
    Paper,
    /// Run the autotuner ([`crate::accel::dse::tune`]) once per model
    /// at bring-up, at the batch policy's full batch size, and serve
    /// every batch from plans compiled under the winning config.
    Tuned,
    /// Explicit per-model configurations — heterogeneous fleets where
    /// each model shard runs its own operating point. Every registered
    /// model must have an entry.
    Explicit(BTreeMap<String, AccelConfig>),
    /// Fleet-level autotuning ([`crate::accel::dse::tune::tune_fleet`]):
    /// the DSE considers the *whole* registered model mix at once and
    /// either assigns each model its own tuned config (a heterogeneous
    /// fleet) or falls back to the best single uniform config when
    /// uniformity wins cost-normalized throughput (req/s per DSP).
    /// Guaranteed never worse than the best uniform config, and
    /// identical to [`ConfigPolicy::Tuned`] for a single-model fleet.
    TunedFleet,
}

impl ConfigPolicy {
    /// Short label for reports (`"paper"` / `"tuned"` / `"explicit"` /
    /// `"tuned-fleet"`).
    pub fn label(&self) -> &'static str {
        match self {
            ConfigPolicy::Paper => "paper",
            ConfigPolicy::Tuned => "tuned",
            ConfigPolicy::Explicit(_) => "explicit",
            ConfigPolicy::TunedFleet => "tuned-fleet",
        }
    }

    /// Resolve the accelerator configuration one model serves under.
    /// The tuned policy runs the autotuner on `net` at `batch` (a
    /// fleet passes its `BatchPolicy::max_batch`, since full batches
    /// dominate a saturated fleet); the result is validated before use.
    pub fn resolve(&self, net: &Network, batch: usize) -> Result<AccelConfig, String> {
        let cfg = match self {
            ConfigPolicy::Paper => AccelConfig::paper_for(net.dims),
            ConfigPolicy::Tuned => {
                let topts = TuneOptions {
                    batch,
                    ..TuneOptions::default()
                };
                tune_network(net, &topts)
                    .map_err(|e| format!("tuning '{}': {e}", net.name))?
                    .best()
                    .cfg
                    .clone()
            }
            ConfigPolicy::Explicit(cfgs) => cfgs
                .get(net.name)
                .cloned()
                .ok_or_else(|| format!("no explicit config for model '{}'", net.name))?,
            // a single-model "fleet" — degenerates to the per-network
            // tuner by construction (tested in tests/prop_dse.rs)
            ConfigPolicy::TunedFleet => {
                let mut all = self.resolve_all(std::slice::from_ref(net), batch)?;
                all.remove(net.name)
                    .ok_or_else(|| format!("fleet tuner returned nothing for '{}'", net.name))?
            }
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Resolve accelerator configurations for a whole model mix at
    /// once. For every policy except [`ConfigPolicy::TunedFleet`] this
    /// is [`ConfigPolicy::resolve`] per model; the fleet-tuned policy
    /// hands the full mix to [`tune_fleet`] so the DSE can trade
    /// per-model specialization against the best uniform config on
    /// cost-normalized throughput.
    pub fn resolve_all(
        &self,
        nets: &[Network],
        batch: usize,
    ) -> Result<BTreeMap<String, AccelConfig>, String> {
        if let ConfigPolicy::TunedFleet = self {
            let topts = TuneOptions {
                batch,
                ..TuneOptions::default()
            };
            let ft = tune_fleet(nets, &topts).map_err(|e| format!("fleet tuning: {e}"))?;
            let mut out = BTreeMap::new();
            for (name, tuned) in &ft.assignments {
                tuned.cfg.validate()?;
                out.insert(name.clone(), tuned.cfg.clone());
            }
            return Ok(out);
        }
        let mut out = BTreeMap::new();
        for net in nets {
            out.insert(net.name.to_string(), self.resolve(net, batch)?);
        }
        Ok(out)
    }
}

/// Configuration of a [`Fleet`].
#[derive(Clone, Debug)]
pub struct FleetOptions {
    /// Number of simulated accelerator instances.
    pub instances: usize,
    /// Batch-closing policy, shared with the live coordinator.
    pub policy: BatchPolicy,
    /// Admission control: shed a request whose best-case queueing
    /// delay (smallest backlog among instances hosting its model)
    /// already exceeds this. `f64::INFINITY` disables shedding.
    pub latency_budget_s: f64,
    /// When `true`, models are sharded round-robin across instances
    /// (instance *i* hosts model *i mod M*) instead of every instance
    /// replicating every model. Sharding keeps each board's weight
    /// working set smaller at the cost of routing freedom.
    pub shard_models: bool,
    /// Per-model accelerator-config selection (paper point, autotuned,
    /// or explicit heterogeneous configs).
    pub config_policy: ConfigPolicy,
    /// Admission control: shed an arrival whose model already has this
    /// many requests pending (unbatched). `usize::MAX` disables the
    /// bound. Sheds for this reason are reported separately from
    /// budget sheds ([`FleetReport::shed_queue_full`]).
    pub queue_cap: usize,
}

impl Default for FleetOptions {
    fn default() -> Self {
        FleetOptions {
            instances: 1,
            policy: BatchPolicy::default(),
            latency_budget_s: f64::INFINITY,
            shard_models: false,
            config_policy: ConfigPolicy::Paper,
            queue_cap: usize::MAX,
        }
    }
}

/// Result of replaying one workload through a [`Fleet`].
#[derive(Clone, Debug)]
pub struct FleetReport {
    /// Instance count the workload ran against.
    pub instances: usize,
    /// Requests offered by the workload.
    pub offered: u64,
    /// Requests served to completion.
    pub served: u64,
    /// Requests shed by admission control (all reasons;
    /// `shed == shed_budget + shed_queue_full`).
    pub shed: u64,
    /// Requests shed because the best-case queueing delay already
    /// exceeded the latency budget.
    pub shed_budget: u64,
    /// Requests shed because the model's pending queue was at
    /// [`FleetOptions::queue_cap`].
    pub shed_queue_full: u64,
    /// Batches executed across all instances.
    pub batches: u64,
    /// Latency percentiles over served requests (arrival → completion).
    pub latency: LatencySummary,
    /// Served requests per second of makespan.
    pub throughput_rps: f64,
    /// First arrival to last completion, simulated seconds.
    pub makespan_s: f64,
    /// Served-request counts per model.
    pub per_model: BTreeMap<String, u64>,
    /// Lifetime counters of each instance, by instance id.
    pub per_instance: Vec<InstanceStats>,
    /// Plan-cache hit/miss/eviction counters accumulated by the run.
    pub cache: CacheStats,
    /// Config-policy label the fleet ran under (`"paper"`, `"tuned"`,
    /// `"explicit"`).
    pub config_policy: String,
    /// Per-model accelerator-config fingerprints — the identity of the
    /// plans every batch was served from ([`crate::serve::PlanCache`]
    /// keys are `<model>@<fingerprint>` with the batch size folded in).
    pub model_configs: BTreeMap<String, String>,
    /// Flat metrics snapshot of the run's recorder
    /// ([`crate::obs::Recorder::metrics_json`]); `None` when the fleet
    /// ran without observability (the historical report is unchanged).
    pub metrics: Option<String>,
    /// Per-tenant accounting (submitted/completed/shed with tagged
    /// reasons, latency, SLO violations). Empty for the classic
    /// single-tenant [`Fleet::run`]; populated by the multi-tenant
    /// [`crate::serve::AutoFleet`].
    pub per_tenant: Vec<TenantReport>,
    /// Autoscaler decision log and instance lifecycle records; `None`
    /// for fixed-size fleets.
    pub scaler: Option<ScalerReport>,
    /// Cost-normalized figures (throughput per DSP, mJ/request);
    /// `None` for the classic fixed fleet.
    pub cost: Option<CostReport>,
}

impl FleetReport {
    /// Mean batch size over the run (0.0 when nothing was served).
    pub fn avg_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.served as f64 / self.batches as f64
        }
    }

    /// Human-readable summary (the `udcnn serve` text output).
    pub fn render(&self) -> String {
        let mut out = format!(
            "=== fleet: {} instance(s) | offered {} | served {} | shed {} (budget {}, \
             queue-full {}) ===\n",
            self.instances, self.offered, self.served, self.shed, self.shed_budget,
            self.shed_queue_full
        );
        out.push_str(&format!(
            "throughput: {:.1} req/s over {:.3} s makespan | {} batches (avg {:.2})\n",
            self.throughput_rps,
            self.makespan_s,
            self.batches,
            self.avg_batch()
        ));
        out.push_str(&format!(
            "latency: p50 {:.3} ms | p95 {:.3} ms | p99 {:.3} ms | mean {:.3} ms | max {:.3} ms\n",
            self.latency.p50_ms,
            self.latency.p95_ms,
            self.latency.p99_ms,
            self.latency.mean_ms,
            self.latency.max_ms
        ));
        out.push_str(&format!(
            "plan cache: {} hits / {} misses / {} evictions ({:.1}% hit rate)\n",
            self.cache.hits,
            self.cache.misses,
            self.cache.evictions,
            100.0 * self.cache.hit_rate()
        ));
        out.push_str(&format!("configs: {} policy\n", self.config_policy));
        for (model, fp) in &self.model_configs {
            out.push_str(&format!("  config {model}: {fp}\n"));
        }
        for (model, n) in &self.per_model {
            out.push_str(&format!("  model {model}: {n} served\n"));
        }
        for (id, s) in self.per_instance.iter().enumerate() {
            let util = if self.makespan_s > 0.0 {
                100.0 * s.busy_s / self.makespan_s
            } else {
                0.0
            };
            out.push_str(&format!(
                "  instance {id}: {} batches | {} requests | busy {:.3} s ({util:.1}%)\n",
                s.batches, s.requests, s.busy_s
            ));
        }
        for t in &self.per_tenant {
            let slo = if t.slo_ms.is_finite() {
                format!("{:.1} ms", t.slo_ms)
            } else {
                "best-effort".to_string()
            };
            out.push_str(&format!(
                "  tenant {} (class {}, slo {slo}): {} submitted | {} completed | {} shed | \
                 p99 {:.3} ms | {} slo violations\n",
                t.name, t.class, t.submitted, t.completed, t.shed, t.latency.p99_ms,
                t.slo_violations
            ));
        }
        if let Some(s) = &self.scaler {
            out.push_str(&s.render());
        }
        if let Some(c) = &self.cost {
            out.push_str(&c.render());
        }
        out
    }

    /// Machine-readable export (the `udcnn serve --json` output and
    /// the shape `BENCH_serving.json` embeds).
    pub fn to_json(&self) -> String {
        let per_model: Vec<String> = self
            .per_model
            .iter()
            .map(|(m, n)| JsonObj::new().str("model", m).int("served", *n).render())
            .collect();
        let model_configs: Vec<String> = self
            .model_configs
            .iter()
            .map(|(m, fp)| JsonObj::new().str("model", m).str("config", fp).render())
            .collect();
        let per_instance: Vec<String> = self
            .per_instance
            .iter()
            .enumerate()
            .map(|(id, s)| {
                JsonObj::new()
                    .int("instance", id as u64)
                    .int("batches", s.batches)
                    .int("requests", s.requests)
                    .num("busy_s", s.busy_s)
                    .render()
            })
            .collect();
        let mut obj = JsonObj::new()
            .int("instances", self.instances as u64)
            .int("offered", self.offered)
            .int("served", self.served)
            .int("shed", self.shed)
            .int("shed_budget", self.shed_budget)
            .int("shed_queue_full", self.shed_queue_full)
            .int("batches", self.batches)
            .num("avg_batch", self.avg_batch())
            .num("throughput_rps", self.throughput_rps)
            .num("makespan_s", self.makespan_s)
            .num("p50_ms", self.latency.p50_ms)
            .num("p95_ms", self.latency.p95_ms)
            .num("p99_ms", self.latency.p99_ms)
            .num("mean_ms", self.latency.mean_ms)
            .num("max_ms", self.latency.max_ms)
            .int("cache_hits", self.cache.hits)
            .int("cache_misses", self.cache.misses)
            .int("cache_evictions", self.cache.evictions)
            .str("config_policy", &self.config_policy)
            .raw("model_configs", &array(&model_configs))
            .raw("per_model", &array(&per_model))
            .raw("per_instance", &array(&per_instance));
        if !self.per_tenant.is_empty() {
            obj = obj.raw("per_tenant", &tenants_to_json(&self.per_tenant));
        }
        if let Some(s) = &self.scaler {
            obj = obj.raw("scaler", &s.to_json().render());
        }
        if let Some(c) = &self.cost {
            obj = obj.raw("cost", &c.to_json().render());
        }
        if let Some(m) = &self.metrics {
            obj = obj.raw("metrics", m);
        }
        obj.render()
    }
}

/// Running tallies of one [`Fleet::run`] replay.
#[derive(Default)]
struct RunAccum {
    latencies: Vec<f64>,
    shed_budget: u64,
    shed_queue: u64,
    batches: u64,
    per_model: BTreeMap<String, u64>,
    last_done_s: f64,
}

/// Per-layer slice of a simulated batch, memoized per plan-cache key
/// so dispatch can emit nested layer spans without re-simulating.
#[derive(Clone, Debug)]
struct StepTrace {
    name: String,
    dur_s: f64,
    cycles: u64,
    util: f64,
    bound: String,
    macs: u64,
}

/// A fleet of simulated accelerator instances behind one front door.
#[derive(Debug)]
pub struct Fleet {
    networks: BTreeMap<String, Network>,
    instances: Vec<Instance>,
    cache: PlanCache,
    /// The accelerator configuration each model's plans compile under,
    /// resolved once at bring-up from the [`ConfigPolicy`] (batch is
    /// overridden per dispatched batch size).
    model_cfgs: BTreeMap<String, AccelConfig>,
    /// Memoized `simulate_plan(..).time_s()` per plan-cache key, so
    /// the event loop's hot path never re-simulates a plan it has
    /// already timed (the result is deterministic per key).
    sim_memo_s: BTreeMap<String, f64>,
    /// Memoized per-batch energy (joules) per plan-cache key, filled
    /// lazily by [`Fleet::batch_energy_j`] for cost-normalized
    /// reporting; deterministic per key like the latency memo.
    energy_memo_j: BTreeMap<String, f64>,
    /// Per-layer step metrics per plan-cache key, kept only when
    /// observability is on (feeds the nested layer spans of each
    /// dispatched batch).
    step_memo: BTreeMap<String, Vec<StepTrace>>,
    /// Reused plan-cache key buffer: [`Fleet::batch_latency_s`] renders
    /// `<model>@<fingerprint>` in place, so the steady-state request
    /// path (warm caches) allocates nothing.
    key_buf: String,
    opts: FleetOptions,
    obs: Obs,
}

impl Fleet {
    /// Bring a fleet online: register `networks`, resolve each model's
    /// accelerator configuration from the [`ConfigPolicy`] (the tuned
    /// policy runs the autotuner here, once per model), create the
    /// instances, and warm the plan cache at the policy's full batch
    /// size so per-model compilation cost is paid once, up front.
    ///
    /// Errors on an empty model list, zero instances, a duplicate
    /// model name, a network the graph compiler rejects, a tuner
    /// failure, or an explicit config map missing a registered model.
    pub fn new(networks: Vec<Network>, opts: FleetOptions) -> Result<Fleet, String> {
        Fleet::new_obs(networks, opts, Obs::off())
    }

    /// [`Fleet::new`] with an observability handle: bring-up compiles
    /// run under trace spans, and every subsequent [`Fleet::run`]
    /// narrates batches, requests, sheds and queue depth onto the
    /// recorder (see the module docs for the track scheme).
    pub fn new_obs(networks: Vec<Network>, opts: FleetOptions, obs: Obs) -> Result<Fleet, String> {
        if networks.is_empty() {
            return Err("fleet needs at least one network".into());
        }
        if opts.instances == 0 {
            return Err("fleet needs at least one instance".into());
        }
        if opts.policy.max_batch == 0 {
            return Err("max_batch must be positive".into());
        }
        let mut map: BTreeMap<String, Network> = BTreeMap::new();
        for net in networks {
            if map.insert(net.name.to_string(), net.clone()).is_some() {
                return Err(format!("model '{}' registered twice", net.name));
            }
        }
        let names: Vec<String> = map.keys().cloned().collect();
        if opts.shard_models && opts.instances < names.len() {
            return Err(format!(
                "sharding {} models needs at least {} instances (got {})",
                names.len(),
                names.len(),
                opts.instances
            ));
        }
        let instances = (0..opts.instances)
            .map(|id| {
                let hosted = if opts.shard_models {
                    vec![names[id % names.len()].clone()]
                } else {
                    Vec::new() // empty = hosts every model
                };
                Instance::new(id, hosted)
            })
            .collect();
        let max_batch = opts.policy.max_batch;
        let nets: Vec<Network> = map.values().cloned().collect();
        let model_cfgs = opts.config_policy.resolve_all(&nets, max_batch)?;
        let mut fleet = Fleet {
            networks: map,
            instances,
            cache: PlanCache::with_capacity(FLEET_PLAN_CACHE_CAP),
            model_cfgs,
            sim_memo_s: BTreeMap::new(),
            energy_memo_j: BTreeMap::new(),
            step_memo: BTreeMap::new(),
            key_buf: String::new(),
            opts,
            obs,
        };
        for name in &names {
            fleet.batch_latency_s(name, max_batch)?;
        }
        Ok(fleet)
    }

    /// The instances, by id.
    pub fn instances(&self) -> &[Instance] {
        &self.instances
    }

    /// Registered model names, sorted.
    pub fn models(&self) -> Vec<&str> {
        self.networks.keys().map(|s| s.as_str()).collect()
    }

    /// The options the fleet was built with.
    pub fn options(&self) -> &FleetOptions {
        &self.opts
    }

    /// Plan-cache counters so far.
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// The accelerator configuration `model`'s plans compile under
    /// (resolved from the [`ConfigPolicy`] at bring-up).
    pub fn model_config(&self, model: &str) -> Option<&AccelConfig> {
        self.model_cfgs.get(model)
    }

    /// Simulated accelerator seconds for one batch of `bsize` requests
    /// against `model`: the cached compiled plan at that batch size,
    /// executed by [`simulate_plan`]. Compiles on first use. The
    /// steady state (plan cache and simulation memo warm) renders the
    /// cache key into the reused [`Fleet::key_buf`] and performs zero
    /// heap allocation per call — pinned by `tests/obs_trace.rs`.
    pub fn batch_latency_s(&mut self, model: &str, bsize: usize) -> Result<f64, String> {
        let net = self
            .networks
            .get(model)
            .ok_or_else(|| format!("unknown model '{model}'"))?;
        let mut cfg = self
            .model_cfgs
            .get(model)
            .cloned()
            .ok_or_else(|| format!("no resolved config for model '{model}'"))?;
        cfg.batch = bsize.max(1);
        let mut key_buf = std::mem::take(&mut self.key_buf);
        crate::graph::plan::cache_key_into(&mut key_buf, net.name, &cfg);
        let plan = match self
            .cache
            .get_or_compile_keyed_obs(&key_buf, &cfg, net, &self.obs)
        {
            Ok(p) => p,
            Err(e) => {
                self.key_buf = key_buf;
                return Err(e);
            }
        };
        if let Some(&lat) = self.sim_memo_s.get(key_buf.as_str()) {
            self.key_buf = key_buf;
            return Ok(lat);
        }
        let metrics = simulate_plan(&plan);
        let lat = metrics.time_s();
        if self.obs.is_enabled() {
            let steps: Vec<StepTrace> = metrics
                .steps
                .iter()
                .map(|s| StepTrace {
                    name: s.layer_name.clone(),
                    dur_s: s.time_s(),
                    cycles: s.total_cycles,
                    util: s.pe_utilization(),
                    bound: s.bound_by.to_string(),
                    macs: s.useful_macs,
                })
                .collect();
            if self.step_memo.len() >= 4 * FLEET_PLAN_CACHE_CAP {
                self.step_memo.clear();
            }
            self.step_memo.insert(key_buf.clone(), steps);
        }
        // Bound the memo alongside the bounded plan cache: a reset is
        // deterministic (simulate_plan is pure) and only costs a
        // re-simulation on the next lookup of each key.
        if self.sim_memo_s.len() >= 4 * FLEET_PLAN_CACHE_CAP {
            self.sim_memo_s.clear();
        }
        self.sim_memo_s.insert(key_buf.clone(), lat);
        self.key_buf = key_buf;
        Ok(lat)
    }

    /// Simulated accelerator energy (joules) for one batch of `bsize`
    /// requests against `model`: per-layer activity-scaled power
    /// ([`crate::energy::fpga_watts`]) integrated over each layer's
    /// simulated duration. Memoized per plan-cache key; feeds the
    /// autoscaled fleet's mJ/request cost report.
    pub fn batch_energy_j(&mut self, model: &str, bsize: usize) -> Result<f64, String> {
        let net = self
            .networks
            .get(model)
            .ok_or_else(|| format!("unknown model '{model}'"))?;
        let mut cfg = self
            .model_cfgs
            .get(model)
            .cloned()
            .ok_or_else(|| format!("no resolved config for model '{model}'"))?;
        cfg.batch = bsize.max(1);
        let key = PlanCache::key(net.name, &cfg);
        if let Some(&e) = self.energy_memo_j.get(&key) {
            return Ok(e);
        }
        let plan = self
            .cache
            .get_or_compile_keyed_obs(&key, &cfg, net, &self.obs)?;
        let metrics = simulate_plan(&plan);
        let energy: f64 = metrics
            .steps
            .iter()
            .map(|s| fpga_watts(&cfg, s) * s.time_s())
            .sum();
        if self.energy_memo_j.len() >= 4 * FLEET_PLAN_CACHE_CAP {
            self.energy_memo_j.clear();
        }
        self.energy_memo_j.insert(key, energy);
        Ok(energy)
    }

    /// The fleet's observability handle (shared with the autoscaled
    /// engine so both narrate onto one recorder).
    pub(crate) fn obs(&self) -> &Obs {
        &self.obs
    }

    /// Smallest backlog among instances hosting `model` at `now_s`
    /// (`f64::INFINITY` when no instance hosts it).
    fn min_backlog_s(&self, model: &str, now_s: f64) -> f64 {
        self.instances
            .iter()
            .filter(|i| i.supports(model))
            .map(|i| i.backlog_s(now_s))
            .fold(f64::INFINITY, f64::min)
    }

    /// Index of the least-loaded instance hosting `model` (smallest
    /// `busy_until_s`, ties to the lowest id).
    fn least_loaded(&self, model: &str) -> Option<usize> {
        self.instances
            .iter()
            .filter(|i| i.supports(model))
            .min_by(|a, b| {
                a.busy_until_s
                    .partial_cmp(&b.busy_until_s)
                    .expect("backlog is never NaN")
                    .then(a.id.cmp(&b.id))
            })
            .map(|i| i.id)
    }

    /// Close a batch for `model` at simulated `now_s`: take up to
    /// `max_batch` oldest pending requests, route them to the
    /// least-loaded hosting instance, and record per-request latency.
    fn dispatch(
        &mut self,
        model: &str,
        now_s: f64,
        pending: &mut BTreeMap<String, VecDeque<(f64, u64)>>,
        acc: &mut RunAccum,
    ) -> Result<(), String> {
        let max_batch = self.opts.policy.max_batch;
        let q = pending.get_mut(model).expect("dispatch without a queue");
        let bsize = q.len().min(max_batch);
        debug_assert!(bsize > 0, "dispatch of an empty batch");
        let submitted: Vec<(f64, u64)> = q.drain(..bsize).collect();
        let latency = self.batch_latency_s(model, bsize)?;
        let idx = self
            .least_loaded(model)
            .ok_or_else(|| format!("no instance hosts '{model}'"))?;
        let done = self.instances[idx].run_batch(now_s, bsize, latency);
        for &(t0, _) in &submitted {
            acc.latencies.push(done - t0);
        }
        acc.batches += 1;
        *acc.per_model.entry(model.to_string()).or_insert(0) += bsize as u64;
        acc.last_done_s = acc.last_done_s.max(done);
        if self.obs.is_enabled() {
            self.trace_batch(model, idx, bsize, done, latency, &submitted);
        }
        Ok(())
    }

    /// Narrate one dispatched batch onto the simulated timeline: a
    /// batch span on the instance's track, nested per-layer cycle
    /// spans (cycles, binding resource, PE utilization — the per-batch
    /// Fig. 6 answer), and one arrival→completion span per request on
    /// the `requests` track, keyed by trace id. `pub(crate)` so the
    /// autoscaled engine reuses the exact span scheme.
    pub(crate) fn trace_batch(
        &self,
        model: &str,
        idx: usize,
        bsize: usize,
        done_s: f64,
        latency_s: f64,
        submitted: &[(f64, u64)],
    ) {
        let start_s = done_s - latency_s;
        let itrack = self.obs.track(&format!("instance {idx}"));
        self.obs.span(
            itrack,
            "batch",
            &format!("{model} x{bsize}"),
            start_s * 1e6,
            latency_s * 1e6,
            Some(
                JsonObj::new()
                    .str("model", model)
                    .int("batch", bsize as u64)
                    .int("instance", idx as u64),
            ),
        );
        let mut cfg = self.model_cfgs.get(model).cloned().expect("resolved config");
        cfg.batch = bsize.max(1);
        if let Some(steps) = self.step_memo.get(&PlanCache::key(model, &cfg)) {
            let mut t = start_s;
            for st in steps {
                self.obs.span(
                    itrack,
                    "layer",
                    &st.name,
                    t * 1e6,
                    st.dur_s * 1e6,
                    Some(
                        JsonObj::new()
                            .int("cycles", st.cycles)
                            .num("pe_utilization", st.util)
                            .str("bound_by", &st.bound)
                            .int("useful_macs", st.macs),
                    ),
                );
                t += st.dur_s;
            }
        }
        let rtrack = self.obs.track("requests");
        for &(t0, tid) in submitted {
            self.obs.span(
                rtrack,
                "request",
                &format!("{model} #{tid}"),
                t0 * 1e6,
                (done_s - t0) * 1e6,
                Some(
                    JsonObj::new()
                        .int("trace_id", tid)
                        .str("model", model)
                        .int("batch", bsize as u64)
                        .int("instance", idx as u64)
                        .num("queue_ms", (start_s - t0) * 1e3),
                ),
            );
        }
        self.obs.count("fleet.batches", 1);
        self.obs.count("fleet.served", bsize as u64);
        self.obs.observe("fleet.batch_size", bsize as f64);
    }

    /// Record one shed arrival: an instant event on the fleet track
    /// tagged with the shed *reason*, plus the matching
    /// `fleet.shed.<reason>` counter. `tenant` is empty for the
    /// classic single-tenant fleet (no arg emitted) and names the
    /// billed tenant under the autoscaled engine.
    pub(crate) fn trace_shed(
        &self,
        model: &str,
        trace_id: u64,
        t_s: f64,
        reason: &str,
        tenant: &str,
    ) {
        if !self.obs.is_enabled() {
            return;
        }
        let ftrack = self.obs.track("fleet");
        let mut args = JsonObj::new()
            .int("trace_id", trace_id)
            .str("model", model)
            .str("reason", reason);
        if !tenant.is_empty() {
            args = args.str("tenant", tenant);
        }
        self.obs.instant(
            ftrack,
            "shed",
            &format!("shed {model} #{trace_id}"),
            t_s * 1e6,
            Some(args),
        );
        self.obs.count(&format!("fleet.shed.{reason}"), 1);
    }

    /// Dispatch every pending batch whose `max_wait` deadline falls at
    /// or before `until_s`, in deadline order (ties on model name).
    fn flush_due(
        &mut self,
        until_s: f64,
        pending: &mut BTreeMap<String, VecDeque<(f64, u64)>>,
        acc: &mut RunAccum,
    ) -> Result<(), String> {
        let max_wait = self.opts.policy.max_wait.as_secs_f64();
        loop {
            let next = pending
                .iter()
                .filter_map(|(m, q)| q.front().map(|&(t0, _)| (t0 + max_wait, m.clone())))
                .min_by(|a, b| {
                    a.0.partial_cmp(&b.0)
                        .expect("deadlines are never NaN")
                        .then_with(|| a.1.cmp(&b.1))
                });
            match next {
                Some((deadline, model)) if deadline <= until_s => {
                    self.dispatch(&model, deadline, pending, acc)?;
                }
                _ => return Ok(()),
            }
        }
    }

    /// Replay an open-loop workload through the fleet and report
    /// latency percentiles, throughput, shed counts and per-instance
    /// utilization. `arrivals` must be sorted by arrival time (as
    /// [`crate::serve::poisson_arrivals`] produces them) and may only
    /// reference registered models. Deterministic: equal inputs yield
    /// a byte-identical report.
    pub fn run(&mut self, arrivals: &[Arrival]) -> Result<FleetReport, String> {
        let budget = self.opts.latency_budget_s;
        let max_batch = self.opts.policy.max_batch;
        let queue_cap = self.opts.queue_cap;
        let mut pending: BTreeMap<String, VecDeque<(f64, u64)>> = BTreeMap::new();
        let mut acc = RunAccum::default();

        for (tid, a) in arrivals.iter().enumerate() {
            let tid = tid as u64;
            if !self.networks.contains_key(&a.model) {
                return Err(format!("unknown model '{}' in workload", a.model));
            }
            // close every batch that timed out before this arrival
            self.flush_due(a.t_s, &mut pending, &mut acc)?;
            // admission control: shed if even the best instance cannot
            // start this request inside the latency budget
            if self.min_backlog_s(&a.model, a.t_s) > budget {
                acc.shed_budget += 1;
                self.trace_shed(&a.model, tid, a.t_s, "budget-exceeded", "");
                continue;
            }
            let q = pending.entry(a.model.clone()).or_default();
            // admission control: bounded per-model pending queue
            if q.len() >= queue_cap {
                acc.shed_queue += 1;
                self.trace_shed(&a.model, tid, a.t_s, "queue-full", "");
                continue;
            }
            q.push_back((a.t_s, tid));
            let q_len = q.len();
            if self.obs.is_enabled() {
                let depth: usize = pending.values().map(|p| p.len()).sum();
                let ftrack = self.obs.track("fleet");
                self.obs.sample(ftrack, "queue_depth", a.t_s * 1e6, depth as f64);
            }
            if q_len >= max_batch {
                self.dispatch(&a.model, a.t_s, &mut pending, &mut acc)?;
            }
        }
        // drain the stragglers at their deadlines
        self.flush_due(f64::INFINITY, &mut pending, &mut acc)?;

        let first_arrival = arrivals.first().map(|a| a.t_s).unwrap_or(0.0);
        let makespan = (acc.last_done_s - first_arrival).max(0.0);
        let served = acc.latencies.len() as u64;
        let mut model_configs = BTreeMap::new();
        for (m, c) in &self.model_cfgs {
            model_configs.insert(m.clone(), c.fingerprint());
        }
        self.obs.count("fleet.offered", arrivals.len() as u64);
        let metrics = self.obs.recorder().map(|r| r.metrics_json());
        Ok(FleetReport {
            instances: self.instances.len(),
            offered: arrivals.len() as u64,
            served,
            shed: acc.shed_budget + acc.shed_queue,
            shed_budget: acc.shed_budget,
            shed_queue_full: acc.shed_queue,
            batches: acc.batches,
            latency: LatencySummary::from_latencies_s(&acc.latencies),
            throughput_rps: if makespan > 0.0 {
                served as f64 / makespan
            } else {
                0.0
            },
            makespan_s: makespan,
            per_model: acc.per_model,
            per_instance: self.instances.iter().map(|i| i.stats()).collect(),
            cache: self.cache.stats(),
            config_policy: self.opts.config_policy.label().to_string(),
            model_configs,
            metrics,
            per_tenant: Vec::new(),
            scaler: None,
            cost: None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dcnn::zoo;
    use crate::serve::loadgen::poisson_arrivals;

    fn burst_workload(n: usize) -> Vec<Arrival> {
        // effectively-simultaneous arrivals: saturates any fleet size
        poisson_arrivals(0xF1EE7, 1e9, n, &["tiny-2d", "tiny-3d"])
    }

    fn fleet(instances: usize) -> Fleet {
        Fleet::new(
            vec![zoo::tiny_2d(), zoo::tiny_3d()],
            FleetOptions {
                instances,
                ..FleetOptions::default()
            },
        )
        .unwrap()
    }

    #[test]
    fn four_instances_scale_throughput() {
        let work = burst_workload(512);
        let r1 = fleet(1).run(&work).unwrap();
        let r4 = fleet(4).run(&work).unwrap();
        assert_eq!(r1.served, 512);
        assert_eq!(r4.served, 512);
        let speedup = r4.throughput_rps / r1.throughput_rps;
        assert!(
            speedup >= 3.5,
            "4 instances gave only {speedup:.2}x over one"
        );
        assert!(r4.latency.p99_ms > 0.0);
    }

    #[test]
    fn runs_are_deterministic() {
        let work = burst_workload(128);
        let a = fleet(3).run(&work).unwrap();
        let b = fleet(3).run(&work).unwrap();
        assert_eq!(a.to_json(), b.to_json());
    }

    #[test]
    fn admission_control_sheds_past_budget() {
        let work = burst_workload(256);
        let mut f = Fleet::new(
            vec![zoo::tiny_2d(), zoo::tiny_3d()],
            FleetOptions {
                instances: 1,
                latency_budget_s: 0.0,
                ..FleetOptions::default()
            },
        )
        .unwrap();
        let r = f.run(&work).unwrap();
        assert!(r.shed > 0, "zero budget must shed under a burst");
        assert_eq!(r.served + r.shed, r.offered);
        // shedding keeps the tail bounded vs. the unlimited queue
        let unlimited = fleet(1).run(&work).unwrap();
        assert!(r.latency.p99_ms <= unlimited.latency.p99_ms);
    }

    #[test]
    fn least_loaded_routing_uses_every_instance() {
        let work = burst_workload(256);
        let r = fleet(4).run(&work).unwrap();
        for (id, s) in r.per_instance.iter().enumerate() {
            assert!(s.batches > 0, "instance {id} never used");
        }
    }

    #[test]
    fn sharded_models_stay_on_their_instances() {
        let work = burst_workload(256);
        let mut f = Fleet::new(
            vec![zoo::tiny_2d(), zoo::tiny_3d()],
            FleetOptions {
                instances: 2,
                shard_models: true,
                ..FleetOptions::default()
            },
        )
        .unwrap();
        assert!(f.instances()[0].supports("tiny-2d"));
        assert!(!f.instances()[0].supports("tiny-3d"));
        assert!(f.instances()[1].supports("tiny-3d"));
        let r = f.run(&work).unwrap();
        assert_eq!(r.served, 256);
    }

    #[test]
    fn cache_compiles_once_per_model_and_batch_size() {
        let work = burst_workload(512);
        let mut f = fleet(2);
        let r = f.run(&work).unwrap();
        // a burst at max_batch=8 should mostly see full batches: very
        // few distinct batch sizes, so misses stay tiny while hits grow
        assert!(r.cache.misses <= 2 * 8, "misses: {}", r.cache.misses);
        assert!(r.cache.hits > r.cache.misses, "{:?}", r.cache);
    }

    #[test]
    fn tuned_policy_resolves_per_model_configs() {
        let mut f = Fleet::new(
            vec![zoo::tiny_2d(), zoo::tiny_3d()],
            FleetOptions {
                instances: 2,
                config_policy: ConfigPolicy::Tuned,
                ..FleetOptions::default()
            },
        )
        .unwrap();
        for m in ["tiny-2d", "tiny-3d"] {
            let cfg = f.model_config(m).expect("tuned config resolved");
            assert!(cfg.validate().is_ok());
        }
        let r = f.run(&burst_workload(64)).unwrap();
        assert_eq!(r.config_policy, "tuned");
        assert_eq!(r.model_configs.len(), 2);
        let js = r.to_json();
        assert!(js.contains("\"config_policy\": \"tuned\""));
        assert!(js.contains("\"model_configs\""));
    }

    #[test]
    fn explicit_policy_builds_heterogeneous_fleets() {
        let mut cfgs = BTreeMap::new();
        cfgs.insert("tiny-2d".to_string(), AccelConfig::paper_2d());
        cfgs.insert("tiny-3d".to_string(), AccelConfig::paper_3d());
        let f = Fleet::new(
            vec![zoo::tiny_2d(), zoo::tiny_3d()],
            FleetOptions {
                config_policy: ConfigPolicy::Explicit(cfgs),
                ..FleetOptions::default()
            },
        )
        .unwrap();
        assert_eq!(
            f.model_config("tiny-2d").unwrap().fingerprint(),
            AccelConfig::paper_2d().fingerprint()
        );
        // a registered model missing from the map is an error
        let mut partial = BTreeMap::new();
        partial.insert("tiny-2d".to_string(), AccelConfig::paper_2d());
        let err = Fleet::new(
            vec![zoo::tiny_2d(), zoo::tiny_3d()],
            FleetOptions {
                config_policy: ConfigPolicy::Explicit(partial),
                ..FleetOptions::default()
            },
        )
        .unwrap_err();
        assert!(err.contains("tiny-3d"), "{err}");
    }

    #[test]
    fn rejects_bad_configurations() {
        assert!(Fleet::new(vec![], FleetOptions::default()).is_err());
        assert!(Fleet::new(
            vec![zoo::tiny_2d()],
            FleetOptions {
                instances: 0,
                ..FleetOptions::default()
            }
        )
        .is_err());
        assert!(Fleet::new(
            vec![zoo::tiny_2d(), zoo::tiny_2d()],
            FleetOptions::default()
        )
        .is_err());
        let mut f = fleet(1);
        assert!(f
            .run(&[Arrival::new(0.0, "nope")])
            .is_err());
    }

    #[test]
    fn shed_reasons_are_separated() {
        let work = burst_workload(256);
        // budget sheds only
        let mut f = Fleet::new(
            vec![zoo::tiny_2d(), zoo::tiny_3d()],
            FleetOptions {
                instances: 1,
                latency_budget_s: 0.0,
                ..FleetOptions::default()
            },
        )
        .unwrap();
        let r = f.run(&work).unwrap();
        assert!(r.shed_budget > 0);
        assert_eq!(r.shed_queue_full, 0);
        assert_eq!(r.shed, r.shed_budget + r.shed_queue_full);
        assert_eq!(r.served + r.shed, r.offered);
        // queue sheds only: infinite budget, queue capped at 1
        let mut f = Fleet::new(
            vec![zoo::tiny_2d(), zoo::tiny_3d()],
            FleetOptions {
                instances: 1,
                queue_cap: 1,
                ..FleetOptions::default()
            },
        )
        .unwrap();
        let r = f.run(&work).unwrap();
        assert!(r.shed_queue_full > 0, "cap of 1 under a burst must shed");
        assert_eq!(r.shed_budget, 0, "infinite budget never budget-sheds");
        assert_eq!(r.shed, r.shed_budget + r.shed_queue_full);
        assert_eq!(r.served + r.shed, r.offered);
        let js = r.to_json();
        assert!(js.contains("\"shed_budget\": 0"));
        assert!(js.contains("\"shed_queue_full\""));
        assert!(r.render().contains("queue-full"));
    }

    #[test]
    fn observed_run_reports_metrics_and_shed_reasons() {
        let work = burst_workload(128);
        let obs = Obs::deterministic();
        let mut f = Fleet::new_obs(
            vec![zoo::tiny_2d(), zoo::tiny_3d()],
            FleetOptions {
                instances: 2,
                queue_cap: 4,
                ..FleetOptions::default()
            },
            obs.clone(),
        )
        .unwrap();
        let r = f.run(&work).unwrap();
        let metrics = r.metrics.as_deref().expect("observed run exports metrics");
        assert!(metrics.contains("fleet.served"));
        assert!(metrics.contains("plan_cache.misses"));
        let m = obs.recorder().unwrap().metrics();
        assert_eq!(m.counter("fleet.served"), r.served);
        assert_eq!(m.counter("fleet.batches"), r.batches);
        assert_eq!(m.counter("fleet.shed.queue-full"), r.shed_queue_full);
        assert_eq!(m.counter("fleet.shed.budget-exceeded"), r.shed_budget);
        let trace = obs.recorder().unwrap().trace_json();
        for needle in ["\"cat\": \"batch\"", "\"cat\": \"layer\"", "\"cat\": \"request\""] {
            assert!(trace.contains(needle), "trace missing {needle}");
        }
        assert!(trace.contains("queue_depth"));
    }

    #[test]
    fn unobserved_report_omits_metrics() {
        let r = fleet(1).run(&burst_workload(16)).unwrap();
        assert!(r.metrics.is_none());
        assert!(!r.to_json().contains("\"metrics\""));
    }
}
