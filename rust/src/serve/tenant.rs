//! Tenants: priority classes, SLOs, and per-tenant accounting.
//!
//! The multi-tenant fleet ([`crate::serve::AutoFleet`]) bills every
//! request to a [`TenantSpec`]: a named principal with a *priority
//! class* (0 is highest — dispatched first, shed last), a latency SLO
//! in milliseconds (admission sheds a request whose estimated wait
//! already blows the SLO, so a greedy tenant's backlog cannot smear a
//! compliant tenant's tail), and a per-tenant queue bound. The
//! scheduler's contract, enforced by the adversarial battery
//! (`tests/adversarial_fleet.rs`), is exact conservation per tenant:
//! `submitted == completed + shed`, with every shed tagged by reason —
//! requests never vanish silently.
//!
//! [`parse_tenant_specs`] parses the `udcnn serve --tenants` CLI
//! syntax: `name:class:slo_ms[:queue_cap]` entries joined by commas,
//! e.g. `gold:0:50,batch:2:inf:128`. `inf` (or `-`) means "no SLO" /
//! "no cap".

use crate::report::json::{array, JsonObj};
use crate::serve::loadgen::LatencySummary;
use std::collections::BTreeMap;

/// One tenant of the fleet.
#[derive(Clone, Debug, PartialEq)]
pub struct TenantSpec {
    /// Tenant name; keys arrivals ([`crate::serve::Arrival::tenant`])
    /// to this spec.
    pub name: String,
    /// Priority class: 0 is highest. Dispatch favors lower classes;
    /// shedding under pressure hits higher classes first.
    pub class: u8,
    /// Latency SLO in milliseconds; `f64::INFINITY` means best-effort.
    pub slo_ms: f64,
    /// Max requests this tenant may have queued (excess is shed with
    /// reason `queue-full`); `usize::MAX` means unbounded.
    pub queue_cap: usize,
}

impl TenantSpec {
    /// The implicit sole tenant of single-tenant runs: class 0,
    /// best-effort SLO, unbounded queue.
    pub fn default_tenant() -> TenantSpec {
        TenantSpec {
            name: "default".to_string(),
            class: 0,
            slo_ms: f64::INFINITY,
            queue_cap: usize::MAX,
        }
    }

    /// Reject unusable specs (empty name, names with the spec
    /// delimiters, non-positive SLO, zero queue cap).
    pub fn validate(&self) -> Result<(), String> {
        if self.name.is_empty() {
            return Err("tenant name must be non-empty".into());
        }
        if self.name.contains([':', ',']) {
            return Err(format!("tenant name '{}' may not contain ':' or ','", self.name));
        }
        if !(self.slo_ms > 0.0) {
            return Err(format!("tenant '{}' SLO must be positive", self.name));
        }
        if self.queue_cap == 0 {
            return Err(format!("tenant '{}' queue_cap must be > 0", self.name));
        }
        Ok(())
    }
}

/// Parse a `--tenants` spec: comma-joined `name:class:slo_ms[:queue_cap]`
/// entries. `slo_ms` and `queue_cap` accept `inf` or `-` for
/// "unbounded"; `queue_cap` defaults to unbounded when omitted.
///
/// ```
/// use udcnn::serve::parse_tenant_specs;
/// let ts = parse_tenant_specs("gold:0:50,batch:2:inf:128").unwrap();
/// assert_eq!(ts.len(), 2);
/// assert_eq!(ts[0].name, "gold");
/// assert_eq!(ts[1].queue_cap, 128);
/// ```
pub fn parse_tenant_specs(spec: &str) -> Result<Vec<TenantSpec>, String> {
    let mut out = Vec::new();
    for entry in spec.split(',') {
        let entry = entry.trim();
        if entry.is_empty() {
            continue;
        }
        let parts: Vec<&str> = entry.split(':').collect();
        if parts.len() < 3 || parts.len() > 4 {
            return Err(format!(
                "tenant entry '{entry}' is not name:class:slo_ms[:queue_cap]"
            ));
        }
        let class: u8 = parts[1]
            .parse()
            .map_err(|_| format!("tenant '{}': bad class '{}'", parts[0], parts[1]))?;
        let slo_ms = match parts[2] {
            "inf" | "-" => f64::INFINITY,
            s => s
                .parse::<f64>()
                .map_err(|_| format!("tenant '{}': bad slo_ms '{s}'", parts[0]))?,
        };
        let queue_cap = match parts.get(3).copied() {
            None | Some("inf") | Some("-") => usize::MAX,
            Some(s) => s
                .parse::<usize>()
                .map_err(|_| format!("tenant '{}': bad queue_cap '{s}'", parts[0]))?,
        };
        let t = TenantSpec {
            name: parts[0].to_string(),
            class,
            slo_ms,
            queue_cap,
        };
        t.validate()?;
        out.push(t);
    }
    if out.is_empty() {
        return Err("tenant spec is empty".into());
    }
    Ok(out)
}

/// Per-tenant outcome of one fleet run.
#[derive(Clone, Debug, Default)]
pub struct TenantReport {
    /// Tenant name.
    pub name: String,
    /// Priority class the run used.
    pub class: u8,
    /// SLO the run enforced (ms; infinite = best-effort).
    pub slo_ms: f64,
    /// Requests this tenant submitted (admitted or shed — everything).
    pub submitted: u64,
    /// Requests completed.
    pub completed: u64,
    /// Requests shed, any reason.
    pub shed: u64,
    /// Shed counts by tagged reason (`budget-exceeded`, `queue-full`,
    /// `preempted`, ...).
    pub shed_reasons: BTreeMap<String, u64>,
    /// Latency percentiles over this tenant's completed requests.
    pub latency: LatencySummary,
    /// Completed requests whose latency exceeded the SLO (0 when the
    /// SLO is infinite).
    pub slo_violations: u64,
}

impl TenantReport {
    /// The conservation law every scenario asserts: each submitted
    /// request is accounted for exactly once.
    pub fn conserved(&self) -> bool {
        self.submitted == self.completed + self.shed
            && self.shed == self.shed_reasons.values().sum::<u64>()
    }

    /// JSON object for reports (infinite SLO renders as `null`).
    pub fn to_json(&self) -> JsonObj {
        let mut reasons = JsonObj::new();
        for (r, n) in &self.shed_reasons {
            reasons = reasons.int(r, *n);
        }
        JsonObj::new()
            .str("tenant", &self.name)
            .int("class", self.class as u64)
            .num("slo_ms", self.slo_ms)
            .int("submitted", self.submitted)
            .int("completed", self.completed)
            .int("shed", self.shed)
            .raw("shed_reasons", &reasons.render())
            .num("p50_ms", self.latency.p50_ms)
            .num("p99_ms", self.latency.p99_ms)
            .num("max_ms", self.latency.max_ms)
            .int("slo_violations", self.slo_violations)
    }
}

/// Render a list of tenant reports as a JSON array string.
pub fn tenants_to_json(reports: &[TenantReport]) -> String {
    let items: Vec<String> = reports.iter().map(|t| t.to_json().render()).collect();
    array(&items)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_and_short_entries() {
        let ts = parse_tenant_specs("gold:0:50,silver:1:200:64,batch:3:inf").unwrap();
        assert_eq!(ts.len(), 3);
        assert_eq!(ts[0], TenantSpec {
            name: "gold".into(),
            class: 0,
            slo_ms: 50.0,
            queue_cap: usize::MAX,
        });
        assert_eq!(ts[1].queue_cap, 64);
        assert!(ts[2].slo_ms.is_infinite());
        assert_eq!(ts[2].class, 3);
    }

    #[test]
    fn rejects_malformed_specs() {
        assert!(parse_tenant_specs("").is_err());
        assert!(parse_tenant_specs("noclass:fast").is_err());
        assert!(parse_tenant_specs("g:x:50").is_err());
        assert!(parse_tenant_specs("g:0:0").is_err(), "zero SLO");
        assert!(parse_tenant_specs("g:0:50:0").is_err(), "zero cap");
        assert!(parse_tenant_specs("g:0:-5").is_err(), "negative SLO");
    }

    #[test]
    fn conservation_checks_reasons_too() {
        let mut t = TenantReport {
            name: "t".into(),
            submitted: 10,
            completed: 7,
            shed: 3,
            ..TenantReport::default()
        };
        assert!(!t.conserved(), "3 sheds but no tagged reasons");
        t.shed_reasons.insert("queue-full".into(), 2);
        t.shed_reasons.insert("budget-exceeded".into(), 1);
        assert!(t.conserved());
        t.completed = 8;
        assert!(!t.conserved(), "over-accounted");
    }

    #[test]
    fn json_renders_infinite_slo_as_null() {
        let t = TenantReport {
            name: "best-effort".into(),
            slo_ms: f64::INFINITY,
            ..TenantReport::default()
        };
        let j = t.to_json().render();
        assert!(j.contains("\"slo_ms\": null"), "{j}");
        assert!(j.contains("\"tenant\": \"best-effort\""));
    }
}
