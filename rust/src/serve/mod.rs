//! Fleet serving: shard compiled plans across simulated FPGA
//! instances.
//!
//! The paper demonstrates the uniform 2D/3D architecture on a single
//! VC709; the production question is what a *rack* of them does behind
//! one front door. This subsystem answers it with a deterministic
//! serving simulator layered on the graph compiler:
//!
//! * [`cache`] — [`PlanCache`]: compiled [`crate::graph::NetworkPlan`]s
//!   keyed by `(network, accelerator-config fingerprint)`, so
//!   compilation happens once per model/batch-size rather than once
//!   per request or per instance;
//! * [`instance`] — [`Instance`]: one simulated board with a
//!   simulated-time backlog and queue-depth tracking;
//! * [`fleet`] — [`Fleet`]: the shard scheduler. Batches requests per
//!   model under the coordinator's [`crate::coordinator::BatchPolicy`]
//!   contract, routes each batch to the least-loaded instance hosting
//!   the model, and sheds requests whose best-case queueing delay
//!   exceeds the latency budget. Each model's plans compile under a
//!   [`ConfigPolicy`]-selected accelerator config: the paper operating
//!   point, the autotuner's per-network pick
//!   ([`crate::accel::dse::tune`], `udcnn serve --tuned`), or explicit
//!   heterogeneous configs per model shard;
//! * [`loadgen`] — seeded open-loop Poisson arrivals
//!   ([`poisson_arrivals`]), periodic per-source chunk cadences for
//!   streaming jobs ([`periodic_arrivals`], consumed by
//!   [`crate::stream::serve_streams`]), and the p50/p95/p99
//!   [`LatencySummary`].
//!
//! **IOM vs OOM.** Every latency this tier reports is an
//! *input-oriented-mapping* (IOM) number: the cached plans schedule
//! only useful multiplies (each input activation × the kernel, with
//! overlap accumulation). Under the *output-oriented* (OOM)
//! zero-insertion formulation the same boards would burn 4× (2D) to 8×
//! (3D) the cycles scanning inserted zeros — which is why fleet
//! capacity, and therefore every admission and routing decision here,
//! is defined in IOM terms.
//!
//! Batch latencies come from [`crate::graph::simulate_plan`], so a
//! [`FleetReport`] is the throughput/latency profile a real deployment
//! of the paper's accelerator would exhibit. The front ends are
//! [`crate::coordinator::service::serve_fleet`] (the coordinator
//! delegates multi-instance serving here), the `udcnn serve` CLI
//! subcommand, and `benches/serving.rs` → `reports/BENCH_serving.json`.

pub mod cache;
pub mod fleet;
pub mod instance;
pub mod loadgen;

pub use cache::{CacheStats, PlanCache};
pub use fleet::{ConfigPolicy, Fleet, FleetOptions, FleetReport};
pub use instance::{Instance, InstanceStats};
pub use loadgen::{periodic_arrivals, poisson_arrivals, Arrival, LatencySummary};
