//! Fleet serving: shard compiled plans across simulated FPGA
//! instances.
//!
//! The paper demonstrates the uniform 2D/3D architecture on a single
//! VC709; the production question is what a *rack* of them does behind
//! one front door. This subsystem answers it with a deterministic
//! serving simulator layered on the graph compiler:
//!
//! * [`cache`] — [`PlanCache`]: compiled [`crate::graph::NetworkPlan`]s
//!   keyed by `(network, accelerator-config fingerprint)`, so
//!   compilation happens once per model/batch-size rather than once
//!   per request or per instance;
//! * [`instance`] — [`Instance`]: one simulated board with a
//!   simulated-time backlog and queue-depth tracking;
//! * [`fleet`] — [`Fleet`]: the shard scheduler. Batches requests per
//!   model under the coordinator's [`crate::coordinator::BatchPolicy`]
//!   contract, routes each batch to the least-loaded instance hosting
//!   the model, and sheds requests whose best-case queueing delay
//!   exceeds the latency budget. Each model's plans compile under a
//!   [`ConfigPolicy`]-selected accelerator config: the paper operating
//!   point, the autotuner's per-network pick
//!   ([`crate::accel::dse::tune`], `udcnn serve --tuned`), or explicit
//!   heterogeneous configs per model shard;
//! * [`loadgen`] — seeded open-loop Poisson arrivals
//!   ([`poisson_arrivals`]), time-varying diurnal / flash-crowd
//!   profiles ([`RateProfile`], [`modulated_arrivals`]), closed-loop
//!   client pools with think time ([`ClosedLoopSpec`]), periodic
//!   per-source chunk cadences for streaming jobs
//!   ([`periodic_arrivals`], consumed by
//!   [`crate::stream::serve_streams`]), and the p50/p95/p99
//!   [`LatencySummary`];
//! * [`tenant`] — [`TenantSpec`]: priority classes, per-tenant SLOs
//!   and queue bounds, with exact per-tenant conservation
//!   (`submitted == completed + shed`, every shed tagged by reason)
//!   reported per run in [`TenantReport`];
//! * [`autoscale`] — [`AutoFleet`]: the production-shaped engine.
//!   Wraps the classic fleet with an autoscaler (queue-depth and
//!   windowed-p99 signals, configurable FPGA-reconfiguration bring-up,
//!   graceful drain), SLO-aware multi-tenant scheduling and shedding,
//!   injected instance failures with request re-routing, and
//!   cost-normalized reporting (throughput per DSP, mJ/request);
//! * [`scenario`] — the named adversarial battery behind
//!   `udcnn serve --autoscale --scenario <name>`: flash crowds,
//!   one-tenant overload, mid-stream instance failure,
//!   scale-down-under-load, closed-loop pools — all capacity-probe
//!   parameterized and byte-replayable.
//!
//! **IOM vs OOM.** Every latency this tier reports is an
//! *input-oriented-mapping* (IOM) number: the cached plans schedule
//! only useful multiplies (each input activation × the kernel, with
//! overlap accumulation). Under the *output-oriented* (OOM)
//! zero-insertion formulation the same boards would burn 4× (2D) to 8×
//! (3D) the cycles scanning inserted zeros — which is why fleet
//! capacity, and therefore every admission and routing decision here,
//! is defined in IOM terms.
//!
//! Batch latencies come from [`crate::graph::simulate_plan`], so a
//! [`FleetReport`] is the throughput/latency profile a real deployment
//! of the paper's accelerator would exhibit. The front ends are
//! [`crate::coordinator::service::serve_fleet`] (the coordinator
//! delegates multi-instance serving here), the `udcnn serve` CLI
//! subcommand, and `benches/serving.rs` → `reports/BENCH_serving.json`.

pub mod autoscale;
pub mod cache;
pub mod fleet;
pub mod instance;
pub mod loadgen;
pub mod scenario;
pub mod tenant;

pub use autoscale::{
    AutoFleet, AutoscaleOptions, CostReport, FailureSpec, InstanceLife, ScalerDecision,
    ScalerReport,
};
pub use cache::{CacheStats, PlanCache};
pub use fleet::{ConfigPolicy, Fleet, FleetOptions, FleetReport};
pub use instance::{Instance, InstanceState, InstanceStats};
pub use loadgen::{
    merge_arrivals, modulated_arrivals, periodic_arrivals, poisson_arrivals, Arrival,
    ClosedLoopSpec, LatencySummary, RateProfile,
};
pub use scenario::{run_scenario, run_scenario_obs, ScenarioOverrides, ScenarioRun, SCENARIO_NAMES};
pub use tenant::{parse_tenant_specs, tenants_to_json, TenantReport, TenantSpec};
