//! Named serving scenarios: the adversarial battery behind
//! `udcnn serve --autoscale --scenario <name>`.
//!
//! A scenario is a fully specified stress test for the autoscaled
//! multi-tenant fleet — workload shape, tenant roster, scaler bounds,
//! and (where relevant) injected failures — parameterized by a
//! *capacity probe* rather than absolute numbers. The probe runs one
//! full batch of each registered model through a single paper-config
//! board and derives two constants: `b`, the slowest full-batch
//! latency, and `c1`, the aggregate one-board request throughput at
//! full batches. Every time constant in a scenario is a multiple of
//! `b` and every rate a multiple of `c1`, so the same scenario is a
//! comparable stress whether the fleet serves `tiny-2d` in a unit
//! test or DCGAN + 3D-GAN from the CLI.
//!
//! Scenarios are deterministic end to end: arrivals come from seeded
//! generators ([`crate::serve::modulated_arrivals`]), the engine runs
//! on the discrete-event clock, and [`ScenarioRun::to_json`] is
//! byte-identical across repeats and hosts — the CI determinism gate
//! `cmp`s two runs.

use crate::dcnn::Network;
use crate::obs::Obs;
use crate::report::json::JsonObj;
use std::time::Duration;

use super::autoscale::{AutoFleet, AutoscaleOptions, FailureSpec};
use super::fleet::{Fleet, FleetOptions, FleetReport};
use super::loadgen::{merge_arrivals, modulated_arrivals, Arrival, ClosedLoopSpec, RateProfile};
use super::tenant::TenantSpec;

/// Every scenario name `run_scenario` accepts, in display order.
pub const SCENARIO_NAMES: &[&str] = &[
    "steady",
    "diurnal",
    "flash-crowd",
    "one-tenant-overload",
    "instance-failure",
    "scale-down",
    "closed-loop",
];

/// CLI-level overrides applied on top of a scenario's defaults.
#[derive(Clone, Debug, Default)]
pub struct ScenarioOverrides {
    /// Replace the scenario's scaler lower bound.
    pub min_instances: Option<usize>,
    /// Replace the scenario's scaler upper bound.
    pub max_instances: Option<usize>,
    /// Replace the scenario's bring-up latency (seconds).
    pub bring_up_s: Option<f64>,
    /// Replace the scenario's tenant roster. Scenarios that tag
    /// arrivals (`flash-crowd`, `one-tenant-overload`) need the
    /// override to keep tenants of the same names.
    pub tenants: Option<Vec<TenantSpec>>,
}

/// Outcome of one scenario run.
#[derive(Clone, Debug)]
pub struct ScenarioRun {
    /// Scenario name.
    pub name: String,
    /// Seed the workload and client stagger derived from.
    pub seed: u64,
    /// The autoscaled fleet's report.
    pub report: FleetReport,
    /// For `flash-crowd`: the same workload replayed against a fleet
    /// pinned to the scenario's minimum size — the fixed-capacity
    /// baseline the 2x completion claim is asserted against.
    pub fixed_baseline: Option<FleetReport>,
}

impl ScenarioRun {
    /// Machine-readable export (`udcnn serve --scenario ... --json`).
    pub fn to_json(&self) -> String {
        let mut obj = JsonObj::new()
            .str("scenario", &self.name)
            .int("seed", self.seed)
            .raw("report", &self.report.to_json());
        if let Some(b) = &self.fixed_baseline {
            obj = obj.raw("fixed_baseline", &b.to_json());
        }
        obj.render()
    }

    /// Human-readable summary (`udcnn serve --scenario ...`).
    pub fn render(&self) -> String {
        let mut out = format!("=== scenario: {} (seed {}) ===\n", self.name, self.seed);
        out.push_str(&self.report.render());
        if let Some(b) = &self.fixed_baseline {
            out.push_str(&format!(
                "--- fixed baseline ({} boards): {} served | {} shed ---\n",
                b.instances, b.served, b.shed
            ));
        }
        out
    }
}

/// The capacity probe: `b` (slowest full-batch latency, seconds) and
/// `c1` (one-board full-batch throughput over the uniform model mix,
/// requests/second).
fn probe(networks: &[Network]) -> Result<(f64, f64), String> {
    let mut fleet = Fleet::new(networks.to_vec(), FleetOptions::default())?;
    let max_batch = fleet.options().policy.max_batch;
    let models: Vec<String> = fleet.models().iter().map(|m| m.to_string()).collect();
    let mut b = 0.0f64;
    let mut per_req_s = 0.0f64;
    for m in &models {
        let s = fleet.batch_latency_s(m, max_batch)?;
        b = b.max(s);
        per_req_s += s / max_batch as f64;
    }
    let c1 = models.len() as f64 / per_req_s;
    Ok((b, c1))
}

/// Everything one scenario feeds the engine.
struct ScenarioSpec {
    opts: FleetOptions,
    auto: AutoscaleOptions,
    tenants: Vec<TenantSpec>,
    arrivals: Vec<Arrival>,
    closed: Vec<ClosedLoopSpec>,
    failures: Vec<FailureSpec>,
    /// Run the same arrivals against a fleet pinned at `min` boards.
    wants_fixed_baseline: bool,
}

/// Scaler defaults shared by the open-loop scenarios, in probe units.
fn base_auto(b: f64) -> AutoscaleOptions {
    AutoscaleOptions {
        min_instances: 1,
        max_instances: 6,
        bring_up_s: 8.0 * b,
        check_every_s: 4.0 * b,
        window_s: 20.0 * b,
        up_queue_depth: 32,
        p99_target_ms: 30.0 * b * 1e3,
        min_window_samples: 16,
        cooldown_s: 8.0 * b,
    }
}

/// Fleet options shared by every scenario: default batching with a
/// `2b` closing deadline, no global admission budget (tenant SLOs and
/// queue bounds rule).
fn base_opts(b: f64) -> FleetOptions {
    FleetOptions {
        policy: crate::coordinator::BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_secs_f64(2.0 * b),
        },
        latency_budget_s: f64::INFINITY,
        ..FleetOptions::default()
    }
}

/// Build the named scenario's full specification from the probe
/// constants. `models` are the registered model names.
fn build(name: &str, seed: u64, b: f64, c1: f64, models: &[&str]) -> Result<ScenarioSpec, String> {
    let opts = base_opts(b);
    let mut auto = base_auto(b);
    let mut tenants = Vec::new();
    let mut arrivals = Vec::new();
    let mut closed = Vec::new();
    let mut failures = Vec::new();
    let mut wants_fixed_baseline = false;
    match name {
        "steady" => {
            auto.min_instances = 2;
            let profile = RateProfile::Constant { rps: 3.0 * c1 };
            arrivals = modulated_arrivals(seed, &profile, 120.0 * b, models, "");
        }
        "diurnal" => {
            let profile = RateProfile::Diurnal {
                base_rps: 0.4 * c1,
                peak_rps: 3.5 * c1,
                period_s: 60.0 * b,
            };
            arrivals = modulated_arrivals(seed, &profile, 120.0 * b, models, "");
        }
        "flash-crowd" => {
            // The crowd's queue bound must sit well above the
            // queue-depth trip wire (`up_queue_depth × ready boards`)
            // or the backlog saturates at the cap before the scaler
            // ever sees a signal; the cooldown matches the check
            // cadence so the ramp is one board per check — fast enough
            // that the autoscaled fleet clears ≥ 2× the fixed fleet's
            // completions at the same per-tenant shed bound.
            auto.min_instances = 2;
            auto.max_instances = 10;
            auto.bring_up_s = 6.0 * b;
            auto.check_every_s = 2.0 * b;
            auto.window_s = 10.0 * b;
            auto.cooldown_s = 2.0 * b;
            auto.up_queue_depth = 16;
            tenants.push(TenantSpec {
                name: "crowd".to_string(),
                class: 0,
                slo_ms: f64::INFINITY,
                queue_cap: 512,
            });
            let profile = RateProfile::FlashCrowd {
                base_rps: c1,
                spike_mult: 10.0,
                start_s: 20.0 * b,
                duration_s: 60.0 * b,
            };
            arrivals = modulated_arrivals(seed, &profile, 100.0 * b, models, "crowd");
            wants_fixed_baseline = true;
        }
        "one-tenant-overload" => {
            // fixed capacity: the assertion isolates *scheduling*, not
            // scaling — the greedy tenant must be contained by class
            // priority and its queue bound alone
            auto.min_instances = 2;
            auto.max_instances = 2;
            tenants.push(TenantSpec {
                name: "gold".to_string(),
                class: 0,
                slo_ms: 30.0 * b * 1e3,
                queue_cap: 64,
            });
            tenants.push(TenantSpec {
                name: "greedy".to_string(),
                class: 3,
                slo_ms: f64::INFINITY,
                queue_cap: 32,
            });
            let gold = modulated_arrivals(
                seed,
                &RateProfile::Constant { rps: 0.6 * c1 },
                80.0 * b,
                models,
                "gold",
            );
            let greedy = modulated_arrivals(
                seed ^ 0x9E37_79B9_7F4A_7C15,
                &RateProfile::Constant { rps: 8.0 * c1 },
                80.0 * b,
                models,
                "greedy",
            );
            arrivals = merge_arrivals(vec![gold, greedy]);
        }
        "instance-failure" => {
            auto.min_instances = 2;
            auto.max_instances = 4;
            auto.bring_up_s = 5.0 * b;
            let profile = RateProfile::Constant { rps: 2.8 * c1 };
            arrivals = modulated_arrivals(seed, &profile, 80.0 * b, models, "");
            failures.push(FailureSpec { t_s: 30.0 * b, instance: 1 });
        }
        "scale-down" => {
            // front-loaded spike, then a long quiet tail: the scaler
            // must grow early and drain gracefully without aborting
            // in-flight batches
            let profile = RateProfile::FlashCrowd {
                base_rps: 0.5 * c1,
                spike_mult: 8.0,
                start_s: 0.0,
                duration_s: 40.0 * b,
            };
            arrivals = modulated_arrivals(seed, &profile, 140.0 * b, models, "");
        }
        "closed-loop" => {
            auto.max_instances = 4;
            let per_model = (24 / models.len().max(1)).max(1);
            for m in models {
                closed.push(ClosedLoopSpec {
                    clients: per_model,
                    think_s: 4.0 * b,
                    requests_per_client: 20,
                    model: m.to_string(),
                    tenant: String::new(),
                });
            }
        }
        other => {
            return Err(format!(
                "unknown scenario '{other}' (known: {})",
                SCENARIO_NAMES.join(", ")
            ));
        }
    }
    Ok(ScenarioSpec {
        opts,
        auto,
        tenants,
        arrivals,
        closed,
        failures,
        wants_fixed_baseline,
    })
}

/// Run a named scenario against `networks` without observability.
pub fn run_scenario(
    name: &str,
    seed: u64,
    networks: &[Network],
    ov: &ScenarioOverrides,
) -> Result<ScenarioRun, String> {
    run_scenario_obs(name, seed, networks, ov, Obs::off())
}

/// [`run_scenario`] with an observability handle: batches, sheds and
/// scaler decisions narrate onto the recorder's simulated timeline.
pub fn run_scenario_obs(
    name: &str,
    seed: u64,
    networks: &[Network],
    ov: &ScenarioOverrides,
    obs: Obs,
) -> Result<ScenarioRun, String> {
    if networks.is_empty() {
        return Err("scenario needs at least one network".into());
    }
    let (b, c1) = probe(networks)?;
    let names: Vec<&str> = networks.iter().map(|n| n.name).collect();
    let mut spec = build(name, seed, b, c1, &names)?;
    if let Some(m) = ov.min_instances {
        spec.auto.min_instances = m;
        spec.auto.max_instances = spec.auto.max_instances.max(m);
    }
    if let Some(m) = ov.max_instances {
        spec.auto.max_instances = m;
    }
    if let Some(s) = ov.bring_up_s {
        spec.auto.bring_up_s = s;
    }
    if let Some(t) = &ov.tenants {
        spec.tenants = t.clone();
    }
    let mut fleet = AutoFleet::new_obs(
        networks.to_vec(),
        spec.opts.clone(),
        spec.auto.clone(),
        spec.tenants.clone(),
        obs,
    )?;
    let report = fleet.run(&spec.arrivals, &spec.closed, &spec.failures, seed)?;
    let fixed_baseline = if spec.wants_fixed_baseline {
        let pinned = AutoscaleOptions {
            min_instances: spec.auto.min_instances,
            max_instances: spec.auto.min_instances,
            ..spec.auto.clone()
        };
        let mut fixed = AutoFleet::new(
            networks.to_vec(),
            spec.opts.clone(),
            pinned,
            spec.tenants.clone(),
        )?;
        Some(fixed.run(&spec.arrivals, &spec.closed, &spec.failures, seed)?)
    } else {
        None
    };
    Ok(ScenarioRun {
        name: name.to_string(),
        seed,
        report,
        fixed_baseline,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dcnn::zoo;

    fn nets() -> Vec<Network> {
        vec![zoo::tiny_2d(), zoo::tiny_3d()]
    }

    #[test]
    fn every_named_scenario_runs_and_conserves() {
        for name in SCENARIO_NAMES {
            let run = run_scenario(name, 42, &nets(), &ScenarioOverrides::default())
                .unwrap_or_else(|e| panic!("scenario {name}: {e}"));
            let r = &run.report;
            assert!(r.offered > 0, "{name}: empty workload");
            assert_eq!(r.offered, r.served + r.shed, "{name}: conservation");
            for t in &r.per_tenant {
                assert!(t.conserved(), "{name}: tenant {} leaks requests", t.name);
            }
            assert!(r.scaler.is_some() && r.cost.is_some(), "{name}");
        }
    }

    #[test]
    fn unknown_scenario_is_an_error() {
        let e = run_scenario("nope", 1, &nets(), &ScenarioOverrides::default()).unwrap_err();
        assert!(e.contains("unknown scenario"), "{e}");
        assert!(e.contains("flash-crowd"), "lists the known names: {e}");
    }

    #[test]
    fn scenario_json_is_deterministic() {
        let ov = ScenarioOverrides::default();
        let a = run_scenario("diurnal", 7, &nets(), &ov).unwrap();
        let b = run_scenario("diurnal", 7, &nets(), &ov).unwrap();
        assert_eq!(a.to_json(), b.to_json());
    }

    #[test]
    fn overrides_replace_scaler_bounds() {
        let ov = ScenarioOverrides {
            min_instances: Some(3),
            max_instances: Some(3),
            bring_up_s: Some(0.0),
            tenants: None,
        };
        let run = run_scenario("steady", 5, &nets(), &ov).unwrap();
        let s = run.report.scaler.as_ref().unwrap();
        assert_eq!(s.min_instances, 3);
        assert_eq!(s.max_instances, 3);
        assert_eq!(s.bring_up_s, 0.0);
    }

    #[test]
    fn flash_crowd_carries_a_fixed_baseline() {
        let run = run_scenario("flash-crowd", 9, &nets(), &ScenarioOverrides::default()).unwrap();
        let base = run.fixed_baseline.as_ref().expect("baseline attached");
        assert_eq!(base.offered, run.report.offered, "same workload");
        assert!(run.to_json().contains("\"fixed_baseline\""));
    }
}
