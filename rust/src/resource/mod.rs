//! VC709 (Virtex-7 XC7VX690T) resource model — Table III.
//!
//! The paper reports a single fixed bitstream whose utilization we
//! model as an explicit function of the architecture parameters. The
//! per-unit constants are calibrated so the Table-II configuration
//! reproduces Table III *exactly*; the same functions then extrapolate
//! to any DSE candidate (used as the fit constraint in
//! [`crate::accel::dse`]).
//!
//! | Resource | model | Table III |
//! |---|---|---|
//! | DSP48E | one per PE multiplier + two per output-channel lane (`T_m·T_n·T_z`) accumulate/scale stage | 2304 (64.00 %) |
//! | BRAM36 | input/weight/output buffers at 4.5 KiB each + 28 for the memory controller FIFOs | 712 (48.44 %) |
//! | FF | 270 per PE (Ra/Rw/acc/FIFO pointers) + 64 per adder-tree adder + 5030 control | 566182 (65.34 %) |
//! | LUT | 135 per PE (mux/route/FIFO RAM) + 96 per adder + 3524 control | 292292 (67.48 %) |

use crate::accel::AccelConfig;
use crate::util::{ceil_div, ceil_log2};

/// XC7VX690T device capacities.
pub const VC709_DSP: usize = 3600;
/// BRAM36 blocks on the XC7VX690T.
pub const VC709_BRAM36: usize = 1470;
/// Flip-flops on the XC7VX690T.
pub const VC709_FF: usize = 866_400;
/// LUTs on the XC7VX690T.
pub const VC709_LUT: usize = 433_200;

/// Calibrated per-unit costs (see module docs).
pub const FF_PER_PE: usize = 270;
/// FFs per adder-tree adder.
pub const FF_PER_ADDER: usize = 64;
/// Fixed FF control overhead.
pub const FF_CONTROL: usize = 5030;
/// LUTs per PE.
pub const LUT_PER_PE: usize = 135;
/// LUTs per adder-tree adder.
pub const LUT_PER_ADDER: usize = 96;
/// Fixed LUT control overhead.
pub const LUT_CONTROL: usize = 3524;
/// BRAM36 blocks for the memory-controller FIFOs.
pub const BRAM_MISC: usize = 28;
/// Bytes per BRAM36 (36 Kbit).
pub const BRAM36_BYTES: usize = 4608;

/// A resource estimate for one configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ResourceEstimate {
    /// DSP48E slices.
    pub dsp: usize,
    /// BRAM36 blocks.
    pub bram36: usize,
    /// Flip-flops.
    pub ff: usize,
    /// LUTs.
    pub lut: usize,
}

impl ResourceEstimate {
    /// Utilization percentages against the VC709.
    pub fn percentages(&self) -> [f64; 4] {
        [
            100.0 * self.dsp as f64 / VC709_DSP as f64,
            100.0 * self.bram36 as f64 / VC709_BRAM36 as f64,
            100.0 * self.ff as f64 / VC709_FF as f64,
            100.0 * self.lut as f64 / VC709_LUT as f64,
        ]
    }

    /// Does the design fit the device?
    pub fn fits_vc709(&self) -> bool {
        self.dsp <= VC709_DSP
            && self.bram36 <= VC709_BRAM36
            && self.ff <= VC709_FF
            && self.lut <= VC709_LUT
    }
}

/// Physical adder count for a bitstream that must serve both operating
/// points of the uniform architecture: `T_m·T_c·max(T_z·log₂T_n)` over
/// the supported modes. For the paper's fixed engine (T_z·T_n = 64
/// lanes reconfigured between 64×1 and 16×4) this is
/// `2·4·max(6, 16) = 128`.
pub fn physical_adders(cfg: &AccelConfig) -> usize {
    let lanes_3d = cfg.tz * ceil_log2(cfg.tn) as usize;
    // 2D fold: tz merges into tn -> 1 · log2(tn · tz)
    let lanes_2d = ceil_log2(cfg.tn * cfg.tz) as usize;
    cfg.tm * cfg.tc * lanes_3d.max(lanes_2d)
}

/// Estimate resources for a configuration.
pub fn estimate(cfg: &AccelConfig) -> ResourceEstimate {
    let pes = cfg.total_pes();
    let adders = physical_adders(cfg);
    let dsp = pes + 2 * cfg.tm * cfg.tn * cfg.tz;
    let buffer_bytes =
        (cfg.input_buf_kib + cfg.weight_buf_kib + cfg.output_buf_kib) * 1024;
    let bram36 = ceil_div(cfg.input_buf_kib * 1024, BRAM36_BYTES)
        + ceil_div(cfg.weight_buf_kib * 1024, BRAM36_BYTES)
        + ceil_div(cfg.output_buf_kib * 1024, BRAM36_BYTES)
        + BRAM_MISC;
    let _ = buffer_bytes;
    let ff = pes * FF_PER_PE + adders * FF_PER_ADDER + FF_CONTROL;
    let lut = pes * LUT_PER_PE + adders * LUT_PER_ADDER + LUT_CONTROL;
    ResourceEstimate {
        dsp,
        bram36,
        ff,
        lut,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_dsp_exact() {
        let est = estimate(&AccelConfig::paper_3d());
        assert_eq!(est.dsp, 2304, "Table III: 2304 DSP48Es");
        // the 2D operating point shares the bitstream: same count
        let est2 = estimate(&AccelConfig::paper_2d());
        assert_eq!(est2.dsp, 2304);
    }

    #[test]
    fn table3_bram_exact() {
        let est = estimate(&AccelConfig::paper_3d());
        assert_eq!(est.bram36, 712, "Table III: 712 BRAMs");
    }

    #[test]
    fn table3_ff_lut_exact() {
        let est = estimate(&AccelConfig::paper_3d());
        assert_eq!(est.ff, 566_182, "Table III: 566182 FFs");
        assert_eq!(est.lut, 292_292, "Table III: 292292 LUTs");
    }

    #[test]
    fn table3_percentages() {
        let est = estimate(&AccelConfig::paper_3d());
        let p = est.percentages();
        assert!((p[0] - 64.00).abs() < 0.01, "DSP {:.2}%", p[0]);
        assert!((p[1] - 48.44).abs() < 0.01, "BRAM {:.2}%", p[1]);
        assert!((p[2] - 65.34).abs() < 0.01, "FF {:.2}%", p[2]);
        assert!((p[3] - 67.48).abs() < 0.01, "LUT {:.2}%", p[3]);
        assert!(est.fits_vc709());
    }

    #[test]
    fn oversized_design_does_not_fit() {
        let mut cfg = AccelConfig::paper_2d();
        cfg.tn = 128; // 4096 PEs
        let est = estimate(&cfg);
        assert!(!est.fits_vc709(), "4096-PE design exceeds the DSP budget");
    }

    #[test]
    fn physical_adder_count_serves_both_modes() {
        assert_eq!(physical_adders(&AccelConfig::paper_3d()), 128);
        // 2D point: max(1·6, 6) = 6 -> 2·4·6 = 48
        assert_eq!(physical_adders(&AccelConfig::paper_2d()), 48);
    }
}
