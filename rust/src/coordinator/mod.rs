//! L3 coordinator: the service face of the accelerator.
//!
//! A thread-based (the offline build has no tokio; see DESIGN.md §1)
//! batched-inference service: requests are routed by model name to an
//! accelerator instance, gathered into batches (the accelerator
//! amortizes weight traffic across a batch — the same `cfg.batch` the
//! timing tier models), executed, and answered with both the numeric
//! output and the simulated on-accelerator latency.
//!
//! **IOM vs OOM.** The numerics workers run are the *input-oriented*
//! (IOM) golden models: each real input activation is scattered
//! against the kernel and overlaps are accumulated, which is exactly
//! what the simulated hardware computes. The *output-oriented* (OOM)
//! formulation — zero-insert then dense convolution — produces the
//! same outputs but wastes most multiplies on inserted zeros; it
//! survives here only as the CPU baseline and as a front-end form the
//! graph compiler lowers away, so a served request never pays for it.
//!
//! Multi-instance serving comes in two forms: the live service can
//! shard each model across several worker instances
//! ([`service::InferenceService::start_sharded`], built on
//! [`router::ShardRouter`]'s queue-depth tracking), and capacity
//! questions are delegated to the deterministic simulated-time fleet
//! ([`service::serve_fleet`] → [`crate::serve::Fleet`]), which shares
//! this module's [`BatchPolicy`] contract. The autoscaling
//! multi-tenant scenarios ride the same delegation
//! ([`service::serve_scenario`] → [`crate::serve::AutoFleet`]).

pub mod batcher;
pub mod router;
pub mod service;

pub use batcher::{Batcher, BatchPolicy};
pub use router::{QueueDepth, Router, ShardRouter};
pub use service::{
    forward_uniform, forward_uniform_obs, serve_fleet, serve_fleet_obs, serve_scenario,
    serve_scenario_obs, InferenceService, Request, Response, ServiceStats,
};
