//! L3 coordinator: the service face of the accelerator.
//!
//! A thread-based (the offline build has no tokio; see DESIGN.md §1)
//! batched-inference service: requests are routed by model name to a
//! per-model accelerator instance, gathered into batches (the
//! accelerator amortizes weight traffic across a batch — the same
//! `cfg.batch` the timing tier models), executed, and answered with
//! both the numeric output and the simulated on-accelerator latency.

pub mod batcher;
pub mod router;
pub mod service;

pub use batcher::{Batcher, BatchPolicy};
pub use router::Router;
pub use service::{InferenceService, Request, Response, ServiceStats};
