//! Request routing: model name → accelerator instance queue(s).
//!
//! [`Router`] is the original single-queue map (one worker per model).
//! [`ShardRouter`] extends it for fleets: a model maps to *several*
//! instance queues with live queue-depth tracking, dispatch picks the
//! least-loaded instance, and a bounded dispatch sheds load once every
//! instance's queue is past the admission cap — the live (wall-clock)
//! counterpart of the simulated-time scheduler in
//! [`crate::serve::Fleet`].

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::Sender;
use std::sync::Arc;

use anyhow::{bail, Result};

/// Routes items to per-model senders.
pub struct Router<T> {
    routes: BTreeMap<String, Sender<T>>,
    /// Per-route dispatch counters.
    pub dispatched: BTreeMap<String, u64>,
}

impl<T> Router<T> {
    /// An empty router.
    pub fn new() -> Router<T> {
        Router {
            routes: BTreeMap::new(),
            dispatched: BTreeMap::new(),
        }
    }

    /// Register (or replace) the worker for `model`, returning the
    /// previous sender when re-registering. The dispatch counter is
    /// preserved across re-registration, so counters never drift from
    /// the route table: one counter per model ever routed, counting
    /// all dispatches regardless of worker generation.
    pub fn add_route(&mut self, model: &str, tx: Sender<T>) -> Option<Sender<T>> {
        self.dispatched.entry(model.to_string()).or_insert(0);
        self.routes.insert(model.to_string(), tx)
    }

    /// Registered model names, sorted.
    pub fn models(&self) -> Vec<&str> {
        self.routes.keys().map(|s| s.as_str()).collect()
    }

    /// Dispatch one item; errors on unknown model or closed worker.
    pub fn dispatch(&mut self, model: &str, item: T) -> Result<()> {
        match self.routes.get(model) {
            None => bail!(
                "unknown model '{model}' (available: {:?})",
                self.models()
            ),
            Some(tx) => {
                if tx.send(item).is_err() {
                    bail!("worker for '{model}' has shut down");
                }
                *self.dispatched.get_mut(model).unwrap() += 1;
                Ok(())
            }
        }
    }
}

impl<T> Default for Router<T> {
    fn default() -> Self {
        Self::new()
    }
}

/// One instance queue of a [`ShardRouter`] route.
struct Shard<T> {
    /// Fleet-wide instance id (stable tie-breaker).
    instance: usize,
    tx: Sender<T>,
    /// Items sent but not yet reported served by the worker.
    depth: Arc<AtomicUsize>,
}

/// Routes items to the least-loaded of several per-model instance
/// queues, with queue-depth-based admission control.
///
/// Workers acknowledge completed items by decrementing the
/// [`QueueDepth`] handed out at registration; the router reads the
/// depths to pick the shard and to decide admission.
pub struct ShardRouter<T> {
    shards: BTreeMap<String, Vec<Shard<T>>>,
    /// Per-model dispatch counters (all shards of the model).
    pub dispatched: BTreeMap<String, u64>,
}

/// Shared outstanding-item counter of one instance queue. The worker
/// side calls [`QueueDepth::done`] once per item it finishes.
#[derive(Clone, Debug, Default)]
pub struct QueueDepth(Arc<AtomicUsize>);

impl QueueDepth {
    /// Current number of outstanding items.
    pub fn get(&self) -> usize {
        self.0.load(Ordering::SeqCst)
    }

    /// Record `n` items as completed.
    pub fn done(&self, n: usize) {
        self.0.fetch_sub(n, Ordering::SeqCst);
    }
}

impl<T> ShardRouter<T> {
    /// An empty shard router.
    pub fn new() -> ShardRouter<T> {
        ShardRouter {
            shards: BTreeMap::new(),
            dispatched: BTreeMap::new(),
        }
    }

    /// Register one instance queue for `model` and return the depth
    /// counter its worker must decrement per served item.
    pub fn add_shard(&mut self, model: &str, instance: usize, tx: Sender<T>) -> QueueDepth {
        let depth = Arc::new(AtomicUsize::new(0));
        self.shards.entry(model.to_string()).or_default().push(Shard {
            instance,
            tx,
            depth: Arc::clone(&depth),
        });
        self.dispatched.entry(model.to_string()).or_insert(0);
        QueueDepth(depth)
    }

    /// Registered model names, sorted.
    pub fn models(&self) -> Vec<&str> {
        self.shards.keys().map(|s| s.as_str()).collect()
    }

    /// Total outstanding items across all shards of `model`.
    pub fn queue_depth(&self, model: &str) -> usize {
        self.shards
            .get(model)
            .map(|s| s.iter().map(|x| x.depth.load(Ordering::SeqCst)).sum())
            .unwrap_or(0)
    }

    /// Outstanding items of the *least-loaded* instance hosting
    /// `model` (`None` for an unknown model). This is the admission
    /// signal: if even the emptiest queue is past the cap, the request
    /// cannot be placed anywhere useful.
    pub fn min_depth(&self, model: &str) -> Option<usize> {
        self.shards
            .get(model)?
            .iter()
            .map(|s| s.depth.load(Ordering::SeqCst))
            .min()
    }

    /// Dispatch to the least-loaded instance hosting `model`; returns
    /// the chosen instance id. Unbounded (no admission control).
    pub fn dispatch(&mut self, model: &str, item: T) -> Result<usize> {
        self.dispatch_bounded(model, item, usize::MAX)
    }

    /// Dispatch to the least-loaded instance hosting `model`, shedding
    /// (with an error) when even that instance already has `max_depth`
    /// or more outstanding items. Returns the chosen instance id.
    pub fn dispatch_bounded(&mut self, model: &str, item: T, max_depth: usize) -> Result<usize> {
        let shards = match self.shards.get(model) {
            Some(s) if !s.is_empty() => s,
            _ => bail!(
                "unknown model '{model}' (available: {:?})",
                self.models()
            ),
        };
        // least-loaded shard, ties to the lowest instance id
        let best = shards
            .iter()
            .min_by_key(|s| (s.depth.load(Ordering::SeqCst), s.instance))
            .unwrap();
        let depth = best.depth.load(Ordering::SeqCst);
        if depth >= max_depth {
            bail!(
                "shedding '{model}': all {} instance queue(s) at depth >= {max_depth}",
                shards.len()
            );
        }
        // count the item BEFORE sending: once sent, the worker may
        // finish it (and decrement) at any moment, and a decrement
        // racing an un-incremented counter would wrap it to ~2^64
        best.depth.fetch_add(1, Ordering::SeqCst);
        if best.tx.send(item).is_err() {
            best.depth.fetch_sub(1, Ordering::SeqCst);
            bail!("instance {} for '{model}' has shut down", best.instance);
        }
        *self.dispatched.get_mut(model).unwrap() += 1;
        Ok(best.instance)
    }
}

impl<T> Default for ShardRouter<T> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn routes_by_model() {
        let (tx_a, rx_a) = channel();
        let (tx_b, rx_b) = channel();
        let mut r = Router::new();
        r.add_route("dcgan", tx_a);
        r.add_route("v-net", tx_b);
        r.dispatch("dcgan", 1).unwrap();
        r.dispatch("v-net", 2).unwrap();
        r.dispatch("dcgan", 3).unwrap();
        assert_eq!(rx_a.try_recv().unwrap(), 1);
        assert_eq!(rx_a.try_recv().unwrap(), 3);
        assert_eq!(rx_b.try_recv().unwrap(), 2);
        assert_eq!(r.dispatched["dcgan"], 2);
    }

    #[test]
    fn unknown_model_rejected() {
        let mut r: Router<u32> = Router::new();
        let err = r.dispatch("nope", 1).unwrap_err();
        assert!(err.to_string().contains("unknown model"));
    }

    #[test]
    fn closed_worker_detected() {
        let (tx, rx) = channel();
        drop(rx);
        let mut r = Router::new();
        r.add_route("m", tx);
        let err = r.dispatch("m", 5).unwrap_err();
        assert!(err.to_string().contains("shut down"));
    }

    #[test]
    fn default_is_empty() {
        let r: Router<u32> = Router::default();
        assert!(r.models().is_empty());
        let s: ShardRouter<u32> = ShardRouter::default();
        assert!(s.models().is_empty());
    }

    #[test]
    fn reregistration_replaces_and_preserves_counter() {
        let (tx1, rx1) = channel();
        let mut r = Router::new();
        assert!(r.add_route("m", tx1).is_none());
        r.dispatch("m", 1).unwrap();
        assert_eq!(r.dispatched["m"], 1);
        // re-register: old sender returned, counter NOT reset
        let (tx2, rx2) = channel();
        let old = r.add_route("m", tx2);
        assert!(old.is_some());
        r.dispatch("m", 2).unwrap();
        assert_eq!(r.dispatched["m"], 2, "counter survives re-registration");
        assert_eq!(rx1.try_recv().unwrap(), 1);
        assert_eq!(rx2.try_recv().unwrap(), 2);
        assert_eq!(r.models(), vec!["m"], "no duplicate routes");
    }

    #[test]
    fn shard_router_balances_by_depth() {
        let (tx0, rx0) = channel();
        let (tx1, rx1) = channel();
        let mut r = ShardRouter::new();
        let d0 = r.add_shard("m", 0, tx0);
        let d1 = r.add_shard("m", 1, tx1);
        // both idle: lowest instance id wins, then depths alternate
        assert_eq!(r.dispatch("m", 10).unwrap(), 0);
        assert_eq!(r.dispatch("m", 11).unwrap(), 1);
        assert_eq!(r.dispatch("m", 12).unwrap(), 0);
        assert_eq!(r.queue_depth("m"), 3);
        assert_eq!(rx0.try_recv().unwrap(), 10);
        assert_eq!(rx1.try_recv().unwrap(), 11);
        assert_eq!(rx0.try_recv().unwrap(), 12);
        // worker 0 finishes its two items: it becomes least-loaded
        d0.done(2);
        assert_eq!(d0.get(), 0);
        assert_eq!(d1.get(), 1);
        assert_eq!(r.dispatch("m", 13).unwrap(), 0);
    }

    #[test]
    fn shard_router_sheds_at_cap() {
        let (tx, _rx) = channel();
        let mut r = ShardRouter::new();
        r.add_shard("m", 0, tx);
        r.dispatch_bounded("m", 1, 2).unwrap();
        r.dispatch_bounded("m", 2, 2).unwrap();
        let err = r.dispatch_bounded("m", 3, 2).unwrap_err();
        assert!(err.to_string().contains("shedding"), "{err}");
        assert_eq!(r.dispatched["m"], 2, "shed items are not counted");
    }

    #[test]
    fn shard_router_unknown_model() {
        let mut r: ShardRouter<u32> = ShardRouter::new();
        assert!(r.dispatch("nope", 1).is_err());
        assert_eq!(r.queue_depth("nope"), 0);
    }
}
