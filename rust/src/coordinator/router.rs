//! Request routing: model name → accelerator instance queue.

use std::collections::BTreeMap;
use std::sync::mpsc::Sender;

use anyhow::{bail, Result};

/// Routes items to per-model senders.
pub struct Router<T> {
    routes: BTreeMap<String, Sender<T>>,
    /// Per-route dispatch counters.
    pub dispatched: BTreeMap<String, u64>,
}

impl<T> Router<T> {
    pub fn new() -> Router<T> {
        Router {
            routes: BTreeMap::new(),
            dispatched: BTreeMap::new(),
        }
    }

    pub fn add_route(&mut self, model: &str, tx: Sender<T>) {
        self.routes.insert(model.to_string(), tx);
        self.dispatched.insert(model.to_string(), 0);
    }

    pub fn models(&self) -> Vec<&str> {
        self.routes.keys().map(|s| s.as_str()).collect()
    }

    /// Dispatch one item; errors on unknown model or closed worker.
    pub fn dispatch(&mut self, model: &str, item: T) -> Result<()> {
        match self.routes.get(model) {
            None => bail!(
                "unknown model '{model}' (available: {:?})",
                self.models()
            ),
            Some(tx) => {
                if tx.send(item).is_err() {
                    bail!("worker for '{model}' has shut down");
                }
                *self.dispatched.get_mut(model).unwrap() += 1;
                Ok(())
            }
        }
    }
}

impl<T> Default for Router<T> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn routes_by_model() {
        let (tx_a, rx_a) = channel();
        let (tx_b, rx_b) = channel();
        let mut r = Router::new();
        r.add_route("dcgan", tx_a);
        r.add_route("v-net", tx_b);
        r.dispatch("dcgan", 1).unwrap();
        r.dispatch("v-net", 2).unwrap();
        r.dispatch("dcgan", 3).unwrap();
        assert_eq!(rx_a.try_recv().unwrap(), 1);
        assert_eq!(rx_a.try_recv().unwrap(), 3);
        assert_eq!(rx_b.try_recv().unwrap(), 2);
        assert_eq!(r.dispatched["dcgan"], 2);
    }

    #[test]
    fn unknown_model_rejected() {
        let mut r: Router<u32> = Router::new();
        let err = r.dispatch("nope", 1).unwrap_err();
        assert!(err.to_string().contains("unknown model"));
    }

    #[test]
    fn closed_worker_detected() {
        let (tx, rx) = channel();
        drop(rx);
        let mut r = Router::new();
        r.add_route("m", tx);
        let err = r.dispatch("m", 5).unwrap_err();
        assert!(err.to_string().contains("shut down"));
    }
}
