//! The batched inference service: router → batcher → accelerator
//! worker(s) per model.
//!
//! Numerics run through the f32 golden IOM pipeline (bit-compatible
//! with the artifacts — see `integration_runtime.rs`); latency is the
//! *simulated accelerator time* of the compiled
//! [`crate::graph::NetworkPlan`] at the actual batch size (inter-layer
//! buffer reuse + cross-layer prefetch overlap), which is what a
//! hardware deployment would report.
//!
//! Two serving shapes live here:
//!
//! * [`InferenceService`] — the live, wall-clock service: real threads
//!   and channels, one *or several* worker instances per model
//!   ([`InferenceService::start_sharded`]), dispatched least-loaded
//!   through [`ShardRouter`] with optional queue-depth admission
//!   control.
//! * [`serve_fleet`] — capacity planning: the coordinator delegates
//!   multi-instance serving questions ("what does a rack of N boards
//!   do under R req/s?") to the deterministic simulated-time
//!   [`crate::serve::Fleet`], which shares the [`BatchPolicy`]
//!   contract and the plan cache with this module.
//! * [`serve_scenario`] — production-shaped capacity planning: the
//!   named adversarial scenarios (flash crowd, one-tenant overload,
//!   instance failure, …) run through the autoscaling multi-tenant
//!   [`crate::serve::AutoFleet`], again on simulated time and again
//!   sharing the [`BatchPolicy`] contract.

use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::accel::{AccelConfig, Schedule};
use crate::dcnn::{LayerData, Network};
use crate::func::{uniform, workspace};
use crate::serve::{
    Arrival, ConfigPolicy, Fleet, FleetOptions, FleetReport, ScenarioOverrides, ScenarioRun,
};
use crate::tensor::WeightsOIDHW;

use super::batcher::{BatchPolicy, Batcher};
use super::router::ShardRouter;

/// One inference request: the layer-0 input for `model`.
pub struct Request {
    /// Target model (network) name.
    pub model: String,
    /// Flat input for the network's first layer (C·D·H·W order).
    pub input: Vec<f32>,
    /// Where the worker sends the [`Response`].
    pub resp: Sender<Response>,
    /// Submission timestamp (wall clock).
    pub submitted: Instant,
}

/// The reply.
#[derive(Clone, Debug)]
pub struct Response {
    /// Model that served the request.
    pub model: String,
    /// Flat final-layer output.
    pub output: Vec<f32>,
    /// Simulated on-accelerator latency for the batch this request
    /// rode in (seconds).
    pub accel_latency_s: f64,
    /// Host wall-clock from submit to reply.
    pub wall_latency_s: f64,
    /// Size of the batch this request was served in.
    pub batch_size: usize,
    /// Worker instance that served the batch.
    pub instance: usize,
}

/// Aggregate service statistics.
#[derive(Clone, Debug, Default)]
pub struct ServiceStats {
    /// Requests served (or in flight).
    pub requests: u64,
    /// Batches executed.
    pub batches: u64,
    /// Requests rejected (unknown model / dead worker).
    pub rejected: u64,
    /// Requests shed by queue-depth admission control.
    pub shed: u64,
    /// Served-request counts per model.
    pub per_model: BTreeMap<String, u64>,
}

impl ServiceStats {
    /// Mean batch size so far (0.0 before the first batch).
    pub fn avg_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }
}

/// The running service.
pub struct InferenceService {
    router: ShardRouter<Request>,
    workers: Vec<JoinHandle<()>>,
    stats: Arc<Mutex<ServiceStats>>,
    /// Admission cap: shed when every instance queue of the model has
    /// at least this many outstanding requests (`None` = unbounded).
    admission_cap: Option<usize>,
}

impl InferenceService {
    /// Spawn one worker per network. Each worker owns synthetic
    /// weights (seeded per model) and an accelerator config chosen by
    /// dimensionality.
    pub fn start(networks: Vec<Network>, policy: BatchPolicy) -> InferenceService {
        InferenceService::start_sharded(networks, policy, 1, None)
    }

    /// Spawn `replicas` worker instances per network, dispatched
    /// least-loaded via [`ShardRouter`]. With `admission_cap` set, a
    /// request is shed when every instance queue of its model already
    /// holds that many outstanding requests. Replica weights are
    /// seeded per model (not per replica), so every instance of a
    /// model computes identical outputs. Workers serve on the paper
    /// operating points; see [`InferenceService::start_with_policy`]
    /// for the tuned/heterogeneous mode.
    pub fn start_sharded(
        networks: Vec<Network>,
        policy: BatchPolicy,
        replicas: usize,
        admission_cap: Option<usize>,
    ) -> InferenceService {
        InferenceService::start_with_policy(
            networks,
            policy,
            replicas,
            admission_cap,
            ConfigPolicy::Paper,
        )
        .expect("the paper config policy is infallible")
    }

    /// [`InferenceService::start_sharded`] with an explicit
    /// [`ConfigPolicy`]: each model's workers report simulated
    /// latencies from plans compiled under the policy-resolved config
    /// — the paper point, the autotuner's pick
    /// ([`ConfigPolicy::Tuned`], tuned at the batch policy's full
    /// batch), or explicit per-model configs. Numerics are identical
    /// under every policy (the config changes schedules and plan
    /// fingerprints, never output bits). Errors when the policy cannot
    /// resolve a config (tuner failure, missing explicit entry).
    pub fn start_with_policy(
        networks: Vec<Network>,
        policy: BatchPolicy,
        replicas: usize,
        admission_cap: Option<usize>,
        config_policy: ConfigPolicy,
    ) -> Result<InferenceService> {
        assert!(replicas >= 1, "need at least one replica per model");
        let stats = Arc::new(Mutex::new(ServiceStats::default()));
        let mut router = ShardRouter::new();
        let mut workers = Vec::new();
        for net in networks {
            let cfg_base = config_policy
                .resolve(&net, policy.max_batch)
                .map_err(anyhow::Error::msg)?;
            for instance in 0..replicas {
                let (tx, rx) = channel::<Request>();
                let depth = router.add_shard(net.name, instance, tx);
                let stats = Arc::clone(&stats);
                let net = net.clone();
                let cfg_base = cfg_base.clone();
                workers.push(std::thread::spawn(move || {
                    let mut batcher = Batcher::new(rx, policy);
                    // synth once per worker, folded to the uniform
                    // layout so the forward pass never re-converts
                    let weights: Vec<WeightsOIDHW<f32>> = net
                        .layers
                        .iter()
                        .enumerate()
                        .map(|(i, l)| LayerData::synth(l, 0x5EED ^ (i as u64)).uniform_weights())
                        .collect();
                    while let Some(batch) = batcher.next_batch() {
                        let n = batch.len();
                        serve_batch(&net, &cfg_base, &weights, batch, instance, &stats);
                        depth.done(n);
                    }
                }));
            }
        }
        Ok(InferenceService {
            router,
            workers,
            stats,
            admission_cap,
        })
    }

    /// Submit a request; the response arrives on the returned channel.
    pub fn submit(&mut self, model: &str, input: Vec<f32>) -> Result<std::sync::mpsc::Receiver<Response>> {
        if let Some(cap) = self.admission_cap {
            if self.router.min_depth(model).is_some_and(|d| d >= cap) {
                self.stats.lock().unwrap().shed += 1;
                bail!("shedding '{model}': every instance queue at depth >= {cap}");
            }
        }
        let (tx, rx) = channel();
        let req = Request {
            model: model.to_string(),
            input,
            resp: tx,
            submitted: Instant::now(),
        };
        if let Err(e) = self.router.dispatch(model, req) {
            self.stats.lock().unwrap().rejected += 1;
            return Err(e);
        }
        Ok(rx)
    }

    /// Convenience: submit and wait.
    pub fn infer(&mut self, model: &str, input: Vec<f32>, timeout: Duration) -> Result<Response> {
        let rx = self.submit(model, input)?;
        Ok(rx.recv_timeout(timeout)?)
    }

    /// Snapshot of the aggregate counters.
    pub fn stats(&self) -> ServiceStats {
        self.stats.lock().unwrap().clone()
    }

    /// Total outstanding requests across all instances of `model`.
    pub fn queue_depth(&self, model: &str) -> usize {
        self.router.queue_depth(model)
    }

    /// Drop the routes (closing worker channels) and join workers.
    pub fn shutdown(self) {
        drop(self.router);
        for w in self.workers {
            let _ = w.join();
        }
    }
}

/// Capacity planning: replay `workload` against a fleet of simulated
/// accelerator instances. The coordinator delegates everything —
/// plan compilation and caching, least-loaded shard scheduling,
/// admission control, latency accounting — to [`crate::serve::Fleet`];
/// this wrapper only exists so callers can stay on the coordinator
/// API. See [`crate::serve`] for the moving parts.
pub fn serve_fleet(
    networks: Vec<Network>,
    opts: FleetOptions,
    workload: &[Arrival],
) -> Result<FleetReport, String> {
    serve_fleet_obs(networks, opts, workload, crate::obs::Obs::off())
}

/// [`serve_fleet`] with an observability handle threaded into the
/// fleet: bring-up compiles, batches, per-layer cycles, requests,
/// sheds and queue depth all land on the recorder, and the returned
/// report carries the metrics snapshot (the `udcnn serve --trace`
/// path).
pub fn serve_fleet_obs(
    networks: Vec<Network>,
    opts: FleetOptions,
    workload: &[Arrival],
    obs: crate::obs::Obs,
) -> Result<FleetReport, String> {
    Fleet::new_obs(networks, opts, obs)?.run(workload)
}

/// Run a named adversarial serving scenario (`flash-crowd`,
/// `one-tenant-overload`, `instance-failure`, …; see
/// [`crate::serve::SCENARIO_NAMES`]) against `networks` on the
/// autoscaling multi-tenant fleet. Like [`serve_fleet`] this is a thin
/// delegation so callers can stay on the coordinator API — scenario
/// construction, autoscaling, SLO scheduling and cost normalization
/// all live in [`crate::serve::scenario`] and
/// [`crate::serve::AutoFleet`]. The `udcnn serve --autoscale
/// --scenario <name>` path.
pub fn serve_scenario(
    name: &str,
    seed: u64,
    networks: &[Network],
    overrides: &ScenarioOverrides,
) -> Result<ScenarioRun, String> {
    crate::serve::run_scenario(name, seed, networks, overrides)
}

/// [`serve_scenario`] with an observability handle threaded into the
/// autoscaling fleet: batches, sheds, scaler decisions and instance
/// failures narrate onto the recorder's simulated timeline (the
/// `udcnn serve --autoscale --trace` path).
pub fn serve_scenario_obs(
    name: &str,
    seed: u64,
    networks: &[Network],
    overrides: &ScenarioOverrides,
    obs: crate::obs::Obs,
) -> Result<ScenarioRun, String> {
    crate::serve::run_scenario_obs(name, seed, networks, overrides, obs)
}

/// Run one batch through the network: golden numerics + simulated
/// accelerator latency at the real batch size, under the worker's
/// policy-resolved configuration.
fn serve_batch(
    net: &Network,
    cfg_base: &AccelConfig,
    weights: &[WeightsOIDHW<f32>],
    batch: Vec<Request>,
    instance: usize,
    stats: &Arc<Mutex<ServiceStats>>,
) {
    let bsize = batch.len();
    // simulated accelerator time for this batch: the compiled
    // whole-network plan, not a sum of isolated layers. Networks the
    // graph compiler rejects (e.g. a registered chain whose declared
    // geometries don't compose) fall back to the isolated-layer sum
    // rather than killing this model's worker.
    let mut cfg = cfg_base.clone();
    cfg.batch = bsize;
    let accel_s = match crate::graph::compile_network(&cfg, net) {
        Ok(plan) => crate::graph::simulate_plan(&plan).time_s(),
        Err(_) => crate::accel::simulate_network(&cfg, net).total_time_s(),
    };

    // Account the batch before replying so callers observing their
    // response always see it reflected in the stats.
    {
        let mut s = stats.lock().unwrap();
        s.requests += bsize as u64;
        s.batches += 1;
        *s.per_model.entry(net.name.to_string()).or_insert(0) += bsize as u64;
    }

    for req in batch {
        let output = forward_uniform(net, weights, &req.input);
        let resp = Response {
            model: req.model.clone(),
            output,
            accel_latency_s: accel_s,
            wall_latency_s: req.submitted.elapsed().as_secs_f64(),
            batch_size: bsize,
            instance,
        };
        let _ = req.resp.send(resp);
    }
}

/// Minimum useful MACs per worker thread in the golden forward: below
/// this, scoped-thread spawn/join overhead rivals the kernel work (the
/// early 4×4 zoo layers), and service workers already run concurrently
/// per model instance — so small layers stay single-threaded.
const FORWARD_MACS_PER_THREAD: u64 = 2_000_000;

/// Golden f32 forward pass through every deconv layer of the network —
/// the serving hot path. One dimension-uniform code path (a 2D network
/// runs as the depth-1 fold, §IV-C). Each layer runs the kernel the
/// per-layer model picks ([`crate::accel::kernel::choose_for_layer`]):
/// the IOM scatter sharded over output channels, or the zero-skip
/// gather sharded over output rows (which keeps 1- and 3-channel GAN
/// heads parallel). The thread count scales with the layer's useful
/// work (capped at the machine parallelism) so tiny layers pay no
/// spawn overhead and concurrent workers do not oversubscribe the
/// host. Both kernels and all thread counts are bit-identical by the
/// accumulation-order contract in [`crate::func::uniform`].
pub fn forward_uniform(net: &Network, weights: &[WeightsOIDHW<f32>], input: &[f32]) -> Vec<f32> {
    forward_uniform_obs(net, weights, input, &crate::obs::Obs::off())
}

/// [`forward_uniform`] with observability: each layer's kernel
/// invocation runs under a scoped span (track `kernel`) carrying the
/// kernel chosen for the layer shape
/// ([`crate::accel::kernel::choose_for_layer`] under the dims-matched
/// paper configuration — scatter, or the zero-skip gather), the MACs
/// that kernel *actually executes* (`actual_macs`: gather skips the
/// cropped border's taps, so this is below `useful_macs` when gather
/// wins), and the structural-zero ratio of the equivalent
/// zero-inserted map ([`crate::dcnn::LayerSpec::inserted_sparsity`],
/// the analytic form the `dcnn::sparsity` battery pins down). The
/// thread count is host-dependent, so it is only recorded under the
/// wall clock — deterministic traces stay thread-count invariant. A
/// disabled handle costs one discriminant load per layer and
/// allocates nothing (pinned by the zero-overhead battery).
pub fn forward_uniform_obs(
    net: &Network,
    weights: &[WeightsOIDHW<f32>],
    input: &[f32],
    obs: &crate::obs::Obs,
) -> Vec<f32> {
    use crate::obs::Clock;
    use crate::report::json::JsonObj;
    let l0 = &net.layers[0];
    assert_eq!(input.len(), l0.input_elems(), "bad input size");
    assert_eq!(weights.len(), net.layers.len(), "one weight set per layer");
    let max_threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4);
    let ktrack = obs.track("kernel");
    let kcfg = AccelConfig::paper_for(net.dims);
    // Skip topologies run through the lowered-graph executor: the same
    // uniform kernels per weighted node, plus the weight-free
    // merge/resample nodes the chain walk below cannot express.
    if net.topology != crate::dcnn::Topology::Chain {
        let work = net.total_useful_macs();
        let threads = ((work / FORWARD_MACS_PER_THREAD) as usize).clamp(1, max_threads);
        let mut span = obs.scope(ktrack, "kernel", net.name);
        if obs.is_enabled() {
            span.set_args(
                JsonObj::new()
                    .str("kernel", "graph")
                    .int("useful_macs", work),
            );
            obs.count("kernel.invocations", net.layers.len() as u64);
            obs.count("kernel.useful_macs", work);
        }
        let g = crate::graph::passes::lower(&net.graph()).expect("zoo skip graphs lower");
        let mut vin = crate::tensor::Volume::zeros(l0.in_c, l0.in_d, l0.in_h, l0.in_w);
        vin.data_mut().copy_from_slice(input);
        let out = crate::graph::execute_f32(&g, weights, &vin, threads)
            .expect("zoo skip graphs execute");
        drop(span);
        return out.into_vec();
    }
    // pooled staging copy of the input (the final layer's volume
    // escapes via `into_vec`; everything in between round-trips
    // through the pool)
    let mut cur = workspace::take_volume_f32(l0.in_c, l0.in_d, l0.in_h, l0.in_w);
    cur.data_mut().copy_from_slice(input);
    for (layer, w) in net.layers.iter().zip(weights) {
        let work = layer.op_counts().useful_macs;
        let choice = crate::accel::kernel::choose_for_layer(&kcfg, layer).choice;
        let actual = match choice {
            crate::accel::KernelChoice::Scatter => work,
            crate::accel::KernelChoice::Gather => layer.gather_macs(),
        };
        let threads = ((work / FORWARD_MACS_PER_THREAD) as usize).clamp(1, max_threads);
        let mut span = obs.scope(ktrack, "kernel", &layer.name);
        if obs.is_enabled() {
            let mut args = JsonObj::new()
                .str("kernel", &choice.to_string())
                .int("useful_macs", work)
                .int("actual_macs", actual)
                .num("structural_zero_ratio", layer.inserted_sparsity());
            if obs.clock() == Some(Clock::Wall) {
                args = args.int("threads", threads as u64);
            }
            span.set_args(args);
            obs.count("kernel.invocations", 1);
            obs.count("kernel.useful_macs", work);
            obs.count("kernel.actual_macs", actual);
        }
        let next = match choice {
            crate::accel::KernelChoice::Scatter => {
                let full = uniform::deconv_iom_threaded(&cur, w, layer.s, threads);
                let cropped = uniform::crop_window_pooled(
                    &full,
                    0,
                    layer.out_d(),
                    layer.out_h(),
                    layer.out_w(),
                );
                workspace::give_volume_f32(full);
                cropped
            }
            crate::accel::KernelChoice::Gather => uniform::deconv_gather_window_threaded(
                &cur,
                w,
                layer.s,
                0,
                layer.out_d(),
                layer.out_h(),
                layer.out_w(),
                threads,
            ),
        };
        // the consumed activation volume goes back to the scratch pool
        workspace::give_volume_f32(std::mem::replace(&mut cur, next));
        drop(span);
    }
    cur.into_vec()
}

/// Golden f32 forward pass for callers holding typed [`LayerData`]
/// weights: folds them to the uniform layout and delegates to
/// [`forward_uniform`]. (The service workers pre-fold once at startup
/// instead.)
pub fn forward(net: &Network, weights: &[LayerData], input: &[f32]) -> Vec<f32> {
    let uw: Vec<WeightsOIDHW<f32>> = weights.iter().map(LayerData::uniform_weights).collect();
    forward_uniform(net, &uw, input)
}

/// Schedule sanity used by property tests: the batch the service uses
/// must keep the working set on-chip.
pub fn batch_fits(net: &Network, bsize: usize) -> bool {
    let mut cfg = AccelConfig::paper_for(net.dims);
    cfg.batch = bsize.max(1);
    net.layers.iter().all(|l| {
        let s = Schedule::new(&cfg, l);
        crate::accel::buffers::working_set_fits(&cfg, l, &s)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dcnn::zoo;

    #[test]
    fn end_to_end_tiny_2d() {
        let net = zoo::tiny_2d();
        let l0 = net.layers[0].clone();
        let last = net.layers.last().unwrap().clone();
        let mut svc = InferenceService::start(vec![net], BatchPolicy::default());
        let input = vec![0.5f32; l0.input_elems()];
        let resp = svc
            .infer("tiny-2d", input, Duration::from_secs(10))
            .unwrap();
        assert_eq!(resp.output.len(), last.output_elems());
        assert!(resp.accel_latency_s > 0.0);
        assert_eq!(resp.model, "tiny-2d");
        assert_eq!(resp.instance, 0);
        let stats = svc.stats();
        assert_eq!(stats.requests, 1);
        svc.shutdown();
    }

    #[test]
    fn unknown_model_is_rejected() {
        let mut svc = InferenceService::start(vec![zoo::tiny_2d()], BatchPolicy::default());
        let err = svc.infer("nope", vec![0.0], Duration::from_secs(1));
        assert!(err.is_err());
        assert_eq!(svc.stats().rejected, 1);
        svc.shutdown();
    }

    #[test]
    fn batching_amortizes() {
        let net = zoo::tiny_2d();
        let l0 = net.layers[0].clone();
        let mut svc = InferenceService::start(
            vec![net],
            BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(50),
            },
        );
        let mut rxs = Vec::new();
        for _ in 0..4 {
            rxs.push(
                svc.submit("tiny-2d", vec![0.25f32; l0.input_elems()])
                    .unwrap(),
            );
        }
        let responses: Vec<Response> = rxs
            .into_iter()
            .map(|rx| rx.recv_timeout(Duration::from_secs(10)).unwrap())
            .collect();
        assert!(responses.iter().any(|r| r.batch_size > 1), "requests batched");
        let stats = svc.stats();
        assert_eq!(stats.requests, 4);
        assert!(stats.batches < 4, "fewer batches than requests");
        svc.shutdown();
    }

    #[test]
    fn sharded_replicas_all_serve() {
        let net = zoo::tiny_2d();
        let l0 = net.layers[0].clone();
        let mut svc = InferenceService::start_sharded(
            vec![net],
            BatchPolicy {
                max_batch: 1,
                max_wait: Duration::from_millis(1),
            },
            2,
            None,
        );
        let mut rxs = Vec::new();
        for _ in 0..6 {
            rxs.push(
                svc.submit("tiny-2d", vec![0.1f32; l0.input_elems()])
                    .unwrap(),
            );
        }
        let instances: Vec<usize> = rxs
            .into_iter()
            .map(|rx| rx.recv_timeout(Duration::from_secs(10)).unwrap().instance)
            .collect();
        assert!(instances.contains(&0), "{instances:?}");
        assert!(instances.contains(&1), "{instances:?}");
        // same model + same seed: replicas answer identically, so the
        // caller cannot tell which instance served it (checked via the
        // forward determinism test below); here we only assert spread.
        assert_eq!(svc.stats().requests, 6);
        svc.shutdown();
    }

    #[test]
    fn admission_cap_sheds_when_saturated() {
        // one replica, cap 1: the second unserved submit must shed
        let net = zoo::tiny_3d();
        let l0 = net.layers[0].clone();
        let mut svc = InferenceService::start_sharded(
            vec![net],
            BatchPolicy {
                max_batch: 8,
                // batches wait long enough that queued items are still
                // outstanding when the next submit checks the depth
                max_wait: Duration::from_millis(250),
            },
            1,
            Some(1),
        );
        let rx1 = svc.submit("tiny-3d", vec![0.1f32; l0.input_elems()]).unwrap();
        let err = svc.submit("tiny-3d", vec![0.2f32; l0.input_elems()]);
        assert!(err.is_err(), "second submit should shed at cap 1");
        assert_eq!(svc.stats().shed, 1);
        let r = rx1.recv_timeout(Duration::from_secs(10)).unwrap();
        assert_eq!(r.model, "tiny-3d");
        svc.shutdown();
    }

    #[test]
    fn tuned_policy_serves_identical_bits() {
        // The config policy changes plan schedules and latencies,
        // never numerics: a tuned service answers with exactly the
        // bits the paper-config service produces.
        let net = zoo::tiny_2d();
        let l0 = net.layers[0].clone();
        let input = vec![0.37f32; l0.input_elems()];
        let mut paper = InferenceService::start(vec![net.clone()], BatchPolicy::default());
        let mut tuned = InferenceService::start_with_policy(
            vec![net],
            BatchPolicy::default(),
            1,
            None,
            ConfigPolicy::Tuned,
        )
        .unwrap();
        let a = paper
            .infer("tiny-2d", input.clone(), Duration::from_secs(10))
            .unwrap();
        let b = tuned
            .infer("tiny-2d", input, Duration::from_secs(10))
            .unwrap();
        assert_eq!(a.output, b.output, "tuning must never change output bits");
        assert!(b.accel_latency_s > 0.0);
        paper.shutdown();
        tuned.shutdown();
    }

    #[test]
    fn forward_is_deterministic() {
        let net = zoo::tiny_3d();
        let weights: Vec<LayerData> = net
            .layers
            .iter()
            .enumerate()
            .map(|(i, l)| LayerData::synth(l, 0x5EED ^ (i as u64)))
            .collect();
        let input = vec![0.1f32; net.layers[0].input_elems()];
        let a = forward(&net, &weights, &input);
        let b = forward(&net, &weights, &input);
        assert_eq!(a, b);
        assert_eq!(a.len(), net.layers.last().unwrap().output_elems());
    }

    #[test]
    fn serve_fleet_delegates_to_the_fleet() {
        let work = crate::serve::poisson_arrivals(7, 1e6, 64, &["tiny-2d"]);
        let r = serve_fleet(
            vec![zoo::tiny_2d()],
            FleetOptions {
                instances: 2,
                ..FleetOptions::default()
            },
            &work,
        )
        .unwrap();
        assert_eq!(r.served + r.shed, 64);
        assert_eq!(r.instances, 2);
    }
}
