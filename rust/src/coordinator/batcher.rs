//! Dynamic batching: gather requests until the batch is full or the
//! oldest request has waited long enough.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Close a batch at this many items.
    pub max_batch: usize,
    /// ... or when the oldest item has waited this long.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
        }
    }
}

/// Pulls from a channel and yields batches according to the policy.
pub struct Batcher<T> {
    rx: Receiver<T>,
    /// The policy batches are closed under.
    pub policy: BatchPolicy,
}

impl<T> Batcher<T> {
    /// Wrap a receiver. Panics if `policy.max_batch` is zero.
    pub fn new(rx: Receiver<T>, policy: BatchPolicy) -> Batcher<T> {
        assert!(policy.max_batch >= 1);
        Batcher { rx, policy }
    }

    /// Block for the next batch. Returns `None` when the channel is
    /// closed and drained.
    pub fn next_batch(&mut self) -> Option<Vec<T>> {
        // Block for the first item.
        let first = match self.rx.recv() {
            Ok(v) => v,
            Err(_) => return None,
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + self.policy.max_wait;
        while batch.len() < self.policy.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok(v) => batch.push(v),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn full_batch_closes_immediately() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let mut b = Batcher::new(
            rx,
            BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_secs(10),
            },
        );
        let batch = b.next_batch().unwrap();
        assert_eq!(batch, vec![0, 1, 2, 3]);
        let batch = b.next_batch().unwrap();
        assert_eq!(batch, vec![4, 5, 6, 7]);
    }

    #[test]
    fn timeout_closes_partial_batch() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        let mut b = Batcher::new(
            rx,
            BatchPolicy {
                max_batch: 100,
                max_wait: Duration::from_millis(20),
            },
        );
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch, vec![1, 2]);
        assert!(t0.elapsed() < Duration::from_secs(1));
    }

    #[test]
    fn closed_channel_yields_none_after_drain() {
        let (tx, rx) = channel();
        tx.send(7).unwrap();
        drop(tx);
        let mut b = Batcher::new(rx, BatchPolicy::default());
        assert_eq!(b.next_batch().unwrap(), vec![7]);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn max_batch_one_never_waits() {
        // batch size 1 must close on the first item immediately, even
        // with a generous max_wait — the deadline loop must not run.
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        let mut b = Batcher::new(
            rx,
            BatchPolicy {
                max_batch: 1,
                max_wait: Duration::from_secs(60),
            },
        );
        let t0 = Instant::now();
        assert_eq!(b.next_batch().unwrap(), vec![1]);
        assert_eq!(b.next_batch().unwrap(), vec![2]);
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "singleton batches must not wait out max_wait"
        );
    }

    #[test]
    fn channel_closed_mid_batch_yields_partial() {
        // the sender dies while the batcher is waiting to fill a
        // batch: what was gathered is delivered, then None.
        let (tx, rx) = channel();
        tx.send(10).unwrap();
        let handle = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(5));
            tx.send(11).unwrap();
            drop(tx); // hang up mid-batch
        });
        let mut b = Batcher::new(
            rx,
            BatchPolicy {
                max_batch: 100,
                max_wait: Duration::from_secs(30),
            },
        );
        let batch = b.next_batch().unwrap();
        handle.join().unwrap();
        assert_eq!(batch, vec![10, 11], "partial batch on disconnect");
        assert!(b.next_batch().is_none(), "closed channel ends the stream");
    }

    #[test]
    fn max_wait_expiry_then_empty_follow_up_blocks() {
        // a timeout-closed batch must not leave the batcher in a state
        // where the next call spins or returns an empty batch: with
        // nothing queued it blocks until a genuinely new item arrives.
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        let mut b = Batcher::new(
            rx,
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(10),
            },
        );
        let first = b.next_batch().unwrap();
        assert_eq!(first, vec![1], "closed by expiry, not by fill");
        let handle = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            tx.send(2).unwrap();
            // keep tx alive until after the send
        });
        let t0 = Instant::now();
        let second = b.next_batch().unwrap();
        handle.join().unwrap();
        assert_eq!(second, vec![2]);
        assert!(
            t0.elapsed() >= Duration::from_millis(25),
            "second call must block for the late item, not poll-spin"
        );
    }

    #[test]
    fn late_arrivals_join_until_deadline() {
        let (tx, rx) = channel();
        tx.send(0).unwrap();
        let handle = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(5));
            let _ = tx.send(1);
        });
        let mut b = Batcher::new(
            rx,
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(200),
            },
        );
        let batch = b.next_batch().unwrap();
        handle.join().unwrap();
        assert_eq!(batch.len(), 2, "late item joined the batch");
    }
}
