//! Minimal property-based testing framework (offline substitute for
//! proptest): seeded generators, configurable case counts, and
//! input reporting on failure. Shrinking is size-directed: generators
//! draw from a size budget that the runner sweeps from small to large,
//! so the first failing case is already near-minimal.

use crate::dcnn::{Dims, LayerSpec};
use crate::graph::{Act, NetworkGraph, NodeId, OpKind, TensorShape};
use crate::util::Prng;

/// A generation context: PRNG + size budget.
pub struct Gen {
    /// Seeded random source.
    pub rng: Prng,
    /// Current size budget (grows across cases).
    pub size: usize,
}

impl Gen {
    /// A context with the given seed and size budget.
    pub fn new(seed: u64, size: usize) -> Gen {
        Gen {
            rng: Prng::new(seed),
            size,
        }
    }

    /// Integer in `[lo, hi]`, biased toward the low end at small sizes.
    pub fn int(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        let span = (hi - lo).min(self.size.max(1));
        self.rng.range(lo, lo + span)
    }

    /// Uniform f32 in [lo, hi).
    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.f32_range(lo, hi)
    }

    /// Pick one of the provided choices.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }

    /// A vector of length `n` built by `f`.
    pub fn vec<T>(&mut self, n: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        (0..n).map(|_| f(self)).collect()
    }

    /// A seeded random skip-topology [`NetworkGraph`] (native IOM
    /// form) with guaranteed-valid shapes, plus the constructively
    /// computed output shape of every node (indexed by [`NodeId`]) for
    /// differential checks against `graph::passes::infer_shapes`.
    ///
    /// The generator grows a trunk by composing motifs — stride-1 and
    /// stride-2 deconvolutions, fuse-able activations, add-diamonds
    /// (two parallel convolutions merged elementwise), concat skips,
    /// and pool→conv→upsample U-dips reclosed by concat — so shapes
    /// are valid by construction rather than by rejection sampling.
    /// The final motif is always a concat skip: every generated graph
    /// has at least one multi-input merge node and at least one
    /// weighted (deconvolution) node.
    pub fn dag(&mut self, dims: Dims) -> (NetworkGraph, Vec<TensorShape>) {
        let d3 = dims == Dims::D3;
        let spec = |s: &TensorShape, name: String, out_c: usize, stride: usize| {
            if d3 {
                LayerSpec::new_3d(name, s.c, s.d, s.h, s.w, out_c, 3, stride)
            } else {
                LayerSpec::new_2d(name, s.c, s.h, s.w, out_c, 3, stride)
            }
        };
        // cropped deconv output: `I·S` per spatial axis (depth only in 3D)
        let out_of = |s: &TensorShape, out_c: usize, stride: usize| {
            TensorShape::new(
                out_c,
                if d3 { s.d * stride } else { s.d },
                s.h * stride,
                s.w * stride,
            )
        };
        fn push(
            g: &mut NetworkGraph,
            shapes: &mut Vec<TensorShape>,
            name: String,
            op: OpKind,
            inputs: &[NodeId],
            out: TensorShape,
        ) -> NodeId {
            let id = g.add_node(name, op, inputs);
            shapes.push(out);
            id
        }
        let mut g = NetworkGraph::new("prop-dag", dims);
        let mut shapes = Vec::new();
        let s_in = TensorShape::new(
            self.int(1, 3),
            if d3 { 2 * self.int(1, 2) } else { 1 },
            2 * self.int(1, 3),
            2 * self.int(1, 3),
        );
        let mut trunk = g.add_node("input", OpKind::Input { shape: s_in }, &[]);
        shapes.push(s_in);
        let mut cur = s_in;
        let steps = 2 + self.int(0, self.size.min(8));
        for step in 0..=steps {
            // the last motif is always a concat skip (see docs)
            let kind = if step == steps { 4 } else { self.int(0, 5) };
            let grown = cur.h >= 16 || cur.w >= 16 || (d3 && cur.d >= 8);
            match kind {
                // stride-2 deconvolution (an upsampling trunk stage)
                1 if !grown => {
                    let oc = self.int(1, 4);
                    let sp = spec(&cur, format!("dc{}", g.len()), oc, 2);
                    let out = out_of(&cur, oc, 2);
                    trunk = push(
                        &mut g,
                        &mut shapes,
                        format!("dc{}", g.len()),
                        OpKind::Deconv { spec: sp },
                        &[trunk],
                        out,
                    );
                    cur = out;
                }
                // fuse-able activation on the trunk (never directly on
                // the input node: that would survive lowering unfused)
                2 if g.len() > 1 => {
                    let act = *self.choose(&[Act::Relu, Act::Tanh]);
                    trunk = push(
                        &mut g,
                        &mut shapes,
                        format!("act{}", g.len()),
                        OpKind::Activation { act },
                        &[trunk],
                        cur,
                    );
                }
                // add-diamond: two parallel convolutions, merged elementwise
                3 => {
                    let oc = self.int(1, 4);
                    let out = out_of(&cur, oc, 1);
                    let la = spec(&cur, format!("dia{}", g.len()), oc, 1);
                    let a = push(
                        &mut g,
                        &mut shapes,
                        format!("dia{}", g.len()),
                        OpKind::Deconv { spec: la },
                        &[trunk],
                        out,
                    );
                    let lb = spec(&cur, format!("dib{}", g.len()), oc, 1);
                    let b = push(
                        &mut g,
                        &mut shapes,
                        format!("dib{}", g.len()),
                        OpKind::Deconv { spec: lb },
                        &[trunk],
                        out,
                    );
                    trunk = push(
                        &mut g,
                        &mut shapes,
                        format!("add{}", g.len()),
                        OpKind::Add,
                        &[a, b],
                        out,
                    );
                    cur = out;
                }
                // U-dip: pool, convolve, upsample back, reclose by concat
                5 if cur.h % 2 == 0 && cur.w % 2 == 0 && (!d3 || cur.d % 2 == 0) => {
                    let (skip, skip_shape) = (trunk, cur);
                    let pooled = TensorShape::new(
                        cur.c,
                        if d3 { cur.d / 2 } else { cur.d },
                        cur.h / 2,
                        cur.w / 2,
                    );
                    let p = push(
                        &mut g,
                        &mut shapes,
                        format!("pool{}", g.len()),
                        OpKind::MaxPool { k: 2 },
                        &[trunk],
                        pooled,
                    );
                    let oc = self.int(1, 3);
                    let mid = out_of(&pooled, oc, 1);
                    let lc = spec(&pooled, format!("dip{}", g.len()), oc, 1);
                    let c = push(
                        &mut g,
                        &mut shapes,
                        format!("dip{}", g.len()),
                        OpKind::Deconv { spec: lc },
                        &[p],
                        mid,
                    );
                    let up = if *self.choose(&[true, false]) {
                        let us = TensorShape::new(
                            mid.c,
                            if d3 { mid.d * 2 } else { mid.d },
                            mid.h * 2,
                            mid.w * 2,
                        );
                        push(
                            &mut g,
                            &mut shapes,
                            format!("up{}", g.len()),
                            OpKind::Upsample { f: 2 },
                            &[c],
                            us,
                        )
                    } else {
                        let lu = spec(&mid, format!("du{}", g.len()), oc, 2);
                        let us = out_of(&mid, oc, 2);
                        push(
                            &mut g,
                            &mut shapes,
                            format!("du{}", g.len()),
                            OpKind::Deconv { spec: lu },
                            &[c],
                            us,
                        )
                    };
                    let cat = TensorShape::new(
                        shapes[up].c + skip_shape.c,
                        skip_shape.d,
                        skip_shape.h,
                        skip_shape.w,
                    );
                    trunk = push(
                        &mut g,
                        &mut shapes,
                        format!("cat{}", g.len()),
                        OpKind::Concat,
                        &[up, skip],
                        cat,
                    );
                    cur = cat;
                }
                // concat skip: a convolution alongside the saved trunk
                4 => {
                    let (skip, skip_shape) = (trunk, cur);
                    let oc = self.int(1, 3);
                    let out = out_of(&cur, oc, 1);
                    let lc = spec(&cur, format!("sc{}", g.len()), oc, 1);
                    let c = push(
                        &mut g,
                        &mut shapes,
                        format!("sc{}", g.len()),
                        OpKind::Deconv { spec: lc },
                        &[trunk],
                        out,
                    );
                    let cat = TensorShape::new(out.c + skip_shape.c, out.d, out.h, out.w);
                    trunk = push(
                        &mut g,
                        &mut shapes,
                        format!("cat{}", g.len()),
                        OpKind::Concat,
                        &[c, skip],
                        cat,
                    );
                    cur = cat;
                }
                // default: a stride-1 convolution trunk stage
                _ => {
                    let oc = self.int(1, 4);
                    let out = out_of(&cur, oc, 1);
                    let lc = spec(&cur, format!("cv{}", g.len()), oc, 1);
                    trunk = push(
                        &mut g,
                        &mut shapes,
                        format!("cv{}", g.len()),
                        OpKind::Deconv { spec: lc },
                        &[trunk],
                        out,
                    );
                    cur = out;
                }
            }
        }
        (g, shapes)
    }
}

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    /// Number of cases to run.
    pub cases: usize,
    /// Base seed.
    pub seed: u64,
    /// Size budget starts here and ramps to `max_size`.
    pub min_size: usize,
    /// Size budget ceiling.
    pub max_size: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            cases: 64,
            seed: 0x5EED,
            min_size: 1,
            max_size: 16,
        }
    }
}

/// Run `prop` over `cfg.cases` generated inputs. `prop` returns
/// `Err(description)` (or panics) on failure; the runner reports the
/// case number, seed and size so the case is replayable.
pub fn check<F>(cfg: Config, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        // ramp the size budget from min to max across the run
        let size = cfg.min_size
            + (cfg.max_size - cfg.min_size) * case / cfg.cases.max(1).max(1);
        let seed = cfg.seed.wrapping_add(case as u64 * 0x9E37_79B9);
        let mut g = Gen::new(seed, size);
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property failed at case {case}/{} (seed={seed:#x}, size={size}): {msg}",
                cfg.cases
            );
        }
    }
}

/// Convenience: `check` with default config.
pub fn quickcheck<F>(prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    check(Config::default(), prop);
}

/// ULP distance between two f32 values: how many representable floats
/// sit between them, inclusive of one endpoint. `+0.0` and `-0.0` are
/// 0 apart; opposite-sign values count the floats through zero; any
/// comparison involving exactly one NaN is `u64::MAX`, two NaNs are 0
/// apart (a reassociated sum that NaNs must NaN in both orders).
pub fn ulp_distance(a: f32, b: f32) -> u64 {
    match (a.is_nan(), b.is_nan()) {
        (true, true) => return 0,
        (false, false) => {}
        _ => return u64::MAX,
    }
    // Map the float line onto a monotone integer line: negative
    // floats mirror below zero, so ordinary subtraction counts the
    // representable values between any two points.
    fn key(x: f32) -> i64 {
        let b = x.to_bits();
        if b & 0x8000_0000 != 0 {
            -((b & 0x7fff_ffff) as i64)
        } else {
            b as i64
        }
    }
    key(a).abs_diff(key(b))
}

/// Assert two f32 slices match element-wise within `max_ulps` units in
/// the last place, with worst-offender reporting: the panic names the
/// index, both values and the ULP distance of the worst mismatch plus
/// how many elements exceeded the bound. `max_ulps = 0` is exact
/// bit-sameness up to `±0.0` and NaN-vs-NaN equivalence — strictly
/// looser than `assert_eq!` on bits, strictly tighter than any
/// epsilon. The comparator the (order-insensitive) fast-path kernels
/// will be judged by; the gather kernels need none of this slack —
/// they are bit-exact — but the battery uses it to *prove* that claim
/// with `max_ulps = 0`.
pub fn assert_ulps_within(got: &[f32], want: &[f32], max_ulps: u64) {
    assert_eq!(
        got.len(),
        want.len(),
        "length mismatch: got {} vs want {}",
        got.len(),
        want.len()
    );
    let mut worst: Option<(usize, u64)> = None;
    let mut offenders = 0usize;
    for (i, (&g, &w)) in got.iter().zip(want).enumerate() {
        let d = ulp_distance(g, w);
        if d > max_ulps {
            offenders += 1;
            if worst.map(|(_, wd)| d > wd).unwrap_or(true) {
                worst = Some((i, d));
            }
        }
    }
    if let Some((i, d)) = worst {
        panic!(
            "{offenders} of {} elements exceed {max_ulps} ULPs; worst at [{i}]: \
             got {:?} (bits {:#010x}) vs want {:?} (bits {:#010x}), {d} ULPs apart",
            got.len(),
            got[i],
            got[i].to_bits(),
            want[i],
            want[i].to_bits(),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        quickcheck(|g| {
            let a = g.int(0, 100);
            let b = g.int(0, 100);
            if a + b >= a {
                Ok(())
            } else {
                Err("addition broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failing_case() {
        check(
            Config {
                cases: 200,
                min_size: 16,
                max_size: 16,
                ..Default::default()
            },
            |g| {
                let v = g.int(0, 20);
                if v < 8 {
                    Ok(())
                } else {
                    Err(format!("v={v}"))
                }
            },
        );
    }

    #[test]
    fn size_ramp_reaches_max() {
        let mut max_seen = 0;
        check(
            Config {
                cases: 32,
                min_size: 1,
                max_size: 10,
                seed: 1,
            },
            |g| {
                max_seen = max_seen.max(g.size);
                Ok(())
            },
        );
        assert!(max_seen >= 9);
    }

    #[test]
    fn deterministic_across_runs() {
        let mut first = Vec::new();
        check(
            Config {
                cases: 8,
                ..Default::default()
            },
            |g| {
                first.push(g.int(0, 1000));
                Ok(())
            },
        );
        let mut second = Vec::new();
        check(
            Config {
                cases: 8,
                ..Default::default()
            },
            |g| {
                second.push(g.int(0, 1000));
                Ok(())
            },
        );
        assert_eq!(first, second);
    }
}
