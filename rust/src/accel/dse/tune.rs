//! The per-network autotuner: pick the best [`AccelConfig`] for one
//! workload under the VC709 resource budget.
//!
//! The paper's headline numbers come from choosing the Table-II
//! mapping parameters *well for the benchmark set*; this module does
//! the same per network, automatically:
//!
//! 1. **Enumerate** — the mesh tilings of [`super::candidates`]
//!    crossed with a set of on-chip buffer splits, each candidate
//!    filtered against the full VC709 resource model
//!    ([`crate::resource::estimate`] must fit the device) and the
//!    per-layer working-set check
//!    ([`crate::accel::buffers::working_set_fits`]).
//! 2. **Prune** — candidates are ranked by their analytical roofline
//!    lower bound ([`super::roofline`]); the search walks them in
//!    bound order and stops as soon as the next bound cannot beat the
//!    worst design already in the top-`keep` set (branch and bound —
//!    everything after is provably no better).
//! 3. **Evaluate** — survivors run the *exact* cost model: the graph
//!    compiler plus [`crate::graph::simulate_plan`], i.e. the same
//!    compiled-plan path the serving tier executes. That path scores
//!    both deconvolution kernels per layer shape
//!    ([`crate::accel::kernel::choose`]: the zero-skip gather changes
//!    the useful-MAC and DDR-bandwidth terms) and the winning
//!    per-layer `KernelChoice` is recorded on the [`TunedConfig`]
//!    with both kernels' cycles as justification.
//!
//! The search is fully deterministic (pure arithmetic over a canonical
//! candidate order), and the selected [`TunedConfig`] is guaranteed to
//! simulate no slower than [`AccelConfig::default`] on the target
//! network: the default point is always evaluated and ranks with the
//! rest. Each result carries a machine-readable justification — which
//! roofline binds, the utilization estimate, the resource footprint
//! and the required overlap-FIFO depth — so `udcnn tune --json`,
//! `benches/dse_autotune.rs` and the fleet's tuned mode all consume
//! the same record.

use crate::accel::buffers::working_set_fits;
use crate::accel::metrics::BoundBy;
use crate::accel::{AccelConfig, Schedule};
use crate::dcnn::{Dims, Network};
use crate::graph;
use crate::report::json::{array, JsonObj};
use crate::resource::{self, ResourceEstimate};

use super::roofline::{network_lower_bound, RooflineEstimate};
use super::{dedupe_and_order, DseBudget, DseError};

/// On-chip buffer splits (input / weight / output KiB) the tuner
/// explores. The first row is the paper's Table-II split; the rest
/// trade BRAM between the three buffers inside the device budget
/// (every row fits the XC7VX690T with margin — asserted in tests).
pub const BUFFER_SPLITS: [(usize, usize, usize); 4] = [
    (512, 1536, 1024),
    (1024, 1536, 1024),
    (1024, 1536, 2048),
    (2048, 1536, 2048),
];

/// Options of one tuner run.
#[derive(Clone, Debug)]
pub struct TuneOptions {
    /// Mesh budget for the tiling enumeration.
    pub budget: DseBudget,
    /// Batch size to tune at (the serving tier tunes at its
    /// `BatchPolicy::max_batch`, since full batches dominate a
    /// saturated fleet).
    pub batch: usize,
    /// How many ranked configurations to keep in the result.
    pub keep: usize,
}

impl Default for TuneOptions {
    fn default() -> Self {
        TuneOptions {
            budget: DseBudget::default(),
            batch: AccelConfig::platform_defaults().batch,
            keep: 5,
        }
    }
}

/// One tuned design point with its machine-readable justification.
#[derive(Clone, Debug)]
pub struct TunedConfig {
    /// The configuration (tiling + buffer split, batch folded in).
    pub cfg: AccelConfig,
    /// Exact compiled-plan cycles for the whole batch.
    pub total_cycles: u64,
    /// Wall-clock seconds for the whole batch.
    pub time_s: f64,
    /// Dense-equivalent TOPS on the target network.
    pub effective_tops: f64,
    /// Which resource bounds the exact simulation (summed over steps).
    pub bound_by: BoundBy,
    /// Time-weighted PE utilization of the exact simulation.
    pub utilization: f64,
    /// VC709 resource footprint of the configuration.
    pub resources: ResourceEstimate,
    /// The roofline bound that ranked this candidate before exact
    /// evaluation.
    pub roofline: RooflineEstimate,
    /// Per-layer kernel decisions `(layer name, selection)` recorded
    /// by the compiled plan the exact evaluation scored: the choice
    /// plus both kernels' modeled cycles (the machine-readable
    /// justification).
    pub kernels: Vec<(String, crate::accel::KernelSelection)>,
}

impl TunedConfig {
    /// Machine-readable record (one element of `udcnn tune --json` and
    /// `reports/BENCH_dse.json`).
    pub fn to_json(&self) -> String {
        let c = &self.cfg;
        let kernels: Vec<String> = self
            .kernels
            .iter()
            .map(|(layer, sel)| {
                JsonObj::new()
                    .str("layer", layer)
                    .str("kernel", &sel.choice.to_string())
                    .int("scatter_cycles", sel.scatter_cycles)
                    .int("gather_cycles", sel.gather_cycles)
                    .str("reason", &sel.reason())
                    .render()
            })
            .collect();
        JsonObj::new()
            .str("fingerprint", &c.fingerprint())
            .int("tm", c.tm as u64)
            .int("tn", c.tn as u64)
            .int("tz", c.tz as u64)
            .int("tr", c.tr as u64)
            .int("tc", c.tc as u64)
            .int("total_pes", c.total_pes() as u64)
            .int("input_buf_kib", c.input_buf_kib as u64)
            .int("weight_buf_kib", c.weight_buf_kib as u64)
            .int("output_buf_kib", c.output_buf_kib as u64)
            .int("batch", c.batch as u64)
            .int("total_cycles", self.total_cycles)
            .num("time_ms", self.time_s * 1e3)
            .num("effective_tops", self.effective_tops)
            .str("bound_by", &self.bound_by.to_string())
            .num("utilization", self.utilization)
            .int("dsp", self.resources.dsp as u64)
            .int("bram36", self.resources.bram36 as u64)
            .int("roofline_cycles", self.roofline.lower_bound_cycles())
            .str("roofline_bound", &self.roofline.bound_by.to_string())
            .num("roofline_utilization_bound", self.roofline.utilization_bound())
            .raw("kernels", &array(&kernels))
            .render()
    }
}

/// Result of tuning one network: the ranked top-`keep` designs plus
/// the search's audit trail.
#[derive(Clone, Debug)]
pub struct TuneResult {
    /// The tuned network's name.
    pub network: String,
    /// Ranked designs, best (fewest cycles) first. Never empty.
    pub ranked: Vec<TunedConfig>,
    /// [`AccelConfig::default`] evaluated on the same network/batch —
    /// the comparison baseline.
    pub default_point: TunedConfig,
    /// Candidates evaluated exactly (compiled + simulated).
    pub evaluated: usize,
    /// Candidates discarded by the roofline bound without evaluation.
    pub pruned: usize,
    /// Candidates the graph compiler rejected (neither evaluated nor
    /// pruned; together the three counters account for every
    /// working-set-feasible candidate the search walked).
    pub rejected: usize,
    /// Overlap-FIFO depth this network requires of any candidate
    /// mapping (`K²·(K−S)` products crossing FIFO-D per activation for
    /// 3D layers, `K·(K−S)` across FIFO-V for 2D) — a property of the
    /// workload's kernel geometry, identical for every ranked design.
    pub fifo_depth: usize,
}

impl TuneResult {
    /// The winning design.
    pub fn best(&self) -> &TunedConfig {
        &self.ranked[0]
    }

    /// Simulated speedup of the winner over [`AccelConfig::default`]
    /// (`>= 1.0` by construction).
    pub fn speedup_vs_default(&self) -> f64 {
        self.default_point.total_cycles as f64 / self.best().total_cycles as f64
    }

    /// Machine-readable export (the `udcnn tune --json` shape).
    pub fn to_json(&self) -> String {
        let ranked: Vec<String> = self.ranked.iter().map(TunedConfig::to_json).collect();
        JsonObj::new()
            .str("network", &self.network)
            .num("speedup_vs_default", self.speedup_vs_default())
            .int("evaluated", self.evaluated as u64)
            .int("pruned", self.pruned as u64)
            .int("rejected", self.rejected as u64)
            .int("fifo_depth", self.fifo_depth as u64)
            .raw("default", &self.default_point.to_json())
            .raw("ranked", &array(&ranked))
            .render()
    }
}

/// Overlap-FIFO depth required by the worst layer of `net` (see
/// [`TuneResult::fifo_depth`]).
fn required_fifo_depth(net: &Network) -> usize {
    net.layers
        .iter()
        .map(|l| {
            let off = l.k.saturating_sub(l.s);
            match l.dims {
                Dims::D2 => l.k * off,
                Dims::D3 => l.k * l.k * off,
            }
        })
        .max()
        .unwrap_or(0)
}

/// Exact evaluation of one candidate: compile the network onto it and
/// simulate the plan. `None` when the graph compiler rejects the pair.
fn evaluate_exact(cfg: &AccelConfig, net: &Network) -> Option<TunedConfig> {
    let plan = graph::compile_network(cfg, net).ok()?;
    let m = graph::simulate_plan(&plan);
    let compute: u64 = m.steps.iter().map(|s| s.compute_cycles).sum();
    let memory: u64 = m.steps.iter().map(|s| s.memory_cycles).sum();
    let kernels = plan
        .steps
        .iter()
        .map(|s| (s.name.clone(), s.kernel.clone()))
        .collect();
    Some(TunedConfig {
        cfg: cfg.clone(),
        total_cycles: m.total_cycles,
        time_s: m.time_s(),
        effective_tops: m.effective_tops(),
        bound_by: if memory > compute {
            BoundBy::Memory
        } else {
            BoundBy::Compute
        },
        utilization: m.avg_pe_utilization(),
        resources: resource::estimate(cfg),
        roofline: network_lower_bound(cfg, net),
        kernels,
    })
}

/// The tuner's candidate space: mesh tilings × buffer splits, filtered
/// to configurations that fit the VC709 (DSP, BRAM, FF, LUT) and move
/// no more than the platform's DDR bandwidth. Deduplicated and in
/// canonical order like [`super::candidates`].
pub fn tuner_candidates(opts: &TuneOptions) -> Result<Vec<AccelConfig>, DseError> {
    let tilings = super::candidates(&opts.budget)?;
    let mut out = Vec::with_capacity(tilings.len() * BUFFER_SPLITS.len());
    for t in &tilings {
        for &(input, weight, output) in &BUFFER_SPLITS {
            let mut cfg = t.clone();
            cfg.input_buf_kib = input;
            cfg.weight_buf_kib = weight;
            cfg.output_buf_kib = output;
            cfg.batch = opts.batch.max(1);
            if resource::estimate(&cfg).fits_vc709() {
                out.push(cfg);
            }
        }
    }
    dedupe_and_order(&mut out);
    if out.is_empty() {
        return Err(DseError::NoFeasibleConfig {
            max_pes: opts.budget.max_pes,
        });
    }
    Ok(out)
}

/// Tune one network: roofline-pruned branch-and-bound over
/// [`tuner_candidates`], exact evaluation on the compiled-plan path.
///
/// The returned ranking always satisfies
/// `best().total_cycles <= default_point.total_cycles`.
pub fn tune_network(net: &Network, opts: &TuneOptions) -> Result<TuneResult, DseError> {
    let keep = opts.keep.max(1);
    let default_cfg = AccelConfig {
        batch: opts.batch.max(1),
        ..AccelConfig::default()
    };
    let default_point =
        evaluate_exact(&default_cfg, net).ok_or_else(|| DseError::NoCandidateFits {
            network: net.name.to_string(),
        })?;

    // Rank candidates by their roofline bound; walk in bound order.
    let mut bounded: Vec<(u64, AccelConfig)> = tuner_candidates(opts)?
        .into_iter()
        .filter(|cfg| {
            net.layers
                .iter()
                .all(|l| working_set_fits(cfg, l, &Schedule::new(cfg, l)))
        })
        .map(|cfg| (network_lower_bound(&cfg, net).lower_bound_cycles(), cfg))
        .collect();
    // stable: ties keep the canonical candidate order
    bounded.sort_by_key(|(lb, _)| *lb);

    let mut ranked: Vec<TunedConfig> = Vec::new();
    let mut evaluated = 0usize;
    let mut pruned = 0usize;
    let mut rejected = 0usize;
    for (i, (lb, cfg)) in bounded.iter().enumerate() {
        let cutoff = if ranked.len() >= keep {
            ranked[keep - 1].total_cycles
        } else {
            u64::MAX
        };
        if *lb >= cutoff {
            // bounds are sorted: every remaining candidate is provably
            // no better than the current top-`keep` set
            pruned += bounded.len() - i;
            break;
        }
        let Some(point) = evaluate_exact(cfg, net) else {
            rejected += 1;
            continue;
        };
        evaluated += 1;
        let pos = ranked
            .binary_search_by(|p| {
                p.total_cycles
                    .cmp(&point.total_cycles)
                    .then(std::cmp::Ordering::Less) // equal cycles: first-found wins
            })
            .unwrap_err();
        ranked.insert(pos, point);
        ranked.truncate(keep);
    }
    if ranked.is_empty() {
        return Err(DseError::NoCandidateFits {
            network: net.name.to_string(),
        });
    }
    // The guarantee: never slower than the default operating point,
    // nor than the dims-matched paper point the untuned serving tier
    // uses (the paper point is normally in the candidate space, but a
    // filter change must never let tuning regress `serve --tuned`).
    let paper_cfg = AccelConfig {
        batch: opts.batch.max(1),
        ..AccelConfig::paper_for(net.dims)
    };
    if let Some(paper_point) = evaluate_exact(&paper_cfg, net) {
        if ranked[0].total_cycles > paper_point.total_cycles {
            ranked.insert(0, paper_point);
            ranked.truncate(keep);
        }
    }
    if ranked[0].total_cycles > default_point.total_cycles {
        ranked.insert(0, default_point.clone());
        ranked.truncate(keep);
    }
    Ok(TuneResult {
        network: net.name.to_string(),
        ranked,
        default_point,
        evaluated,
        pruned,
        rejected,
        fifo_depth: required_fifo_depth(net),
    })
}

/// Result of tuning a whole model mix at once ([`tune_fleet`]): a
/// per-model config assignment plus the heterogeneous-vs-uniform
/// decision record, scored in cost-normalized throughput (requests per
/// second per DSP slice, with every model given one board of its
/// assigned configuration).
#[derive(Clone, Debug)]
pub struct FleetTuneResult {
    /// The chosen configuration per model. Heterogeneous when the
    /// per-model winners beat the best uniform config cost-normalized;
    /// otherwise every entry carries the same uniform configuration.
    pub assignments: std::collections::BTreeMap<String, TunedConfig>,
    /// Whether the assignment is per-model (true) or the single best
    /// uniform config (false).
    pub heterogeneous: bool,
    /// Cost-normalized throughput of the per-model-winner assignment.
    pub hetero_throughput_per_dsp: f64,
    /// Cost-normalized throughput of the best uniform candidate.
    pub best_uniform_throughput_per_dsp: f64,
    /// Fingerprint of the best uniform candidate, when one exists that
    /// compiles for every model in the mix.
    pub uniform_fingerprint: Option<String>,
}

impl FleetTuneResult {
    /// Cost-normalized throughput of the assignment actually chosen.
    pub fn chosen_throughput_per_dsp(&self) -> f64 {
        if self.heterogeneous {
            self.hetero_throughput_per_dsp
        } else {
            self.best_uniform_throughput_per_dsp
        }
    }

    /// Machine-readable record (embedded by scenario reports).
    pub fn to_json(&self) -> String {
        let assignments: Vec<String> = self
            .assignments
            .iter()
            .map(|(m, t)| {
                JsonObj::new()
                    .str("model", m)
                    .str("fingerprint", &t.cfg.fingerprint())
                    .num("time_ms", t.time_s * 1e3)
                    .int("dsp", t.resources.dsp as u64)
                    .render()
            })
            .collect();
        let mut obj = JsonObj::new()
            .raw("heterogeneous", if self.heterogeneous { "true" } else { "false" })
            .num("hetero_throughput_per_dsp", self.hetero_throughput_per_dsp)
            .num("best_uniform_throughput_per_dsp", self.best_uniform_throughput_per_dsp)
            .raw("assignments", &array(&assignments));
        if let Some(fp) = &self.uniform_fingerprint {
            obj = obj.str("uniform_fingerprint", fp);
        }
        obj.render()
    }
}

/// Tune a whole model mix: run the per-network tuner on every model,
/// then decide whether the *heterogeneous* assignment (each model on
/// its own winner) actually beats the best *uniform* configuration
/// once throughput is cost-normalized by DSP footprint — the
/// fleet-provisioning question behind `ConfigPolicy::TunedFleet`.
///
/// Scoring gives each model one board of its assigned config, so the
/// heterogeneous score is `Σ_i rate_i / Σ_i dsp_i` and a uniform
/// config `c` scores `Σ_i rate_i(c) / (M · dsp(c))`. The uniform
/// candidate set is every distinct per-model winner, the paper points
/// of the dimensionalities present, and the platform default; a
/// candidate must compile for *every* model to qualify. The chosen
/// assignment therefore never scores below the best uniform candidate
/// (ties go to heterogeneous), and a single-model mix returns exactly
/// the per-network [`tune_network`] winner.
pub fn tune_fleet(nets: &[Network], opts: &TuneOptions) -> Result<FleetTuneResult, DseError> {
    use std::collections::BTreeMap;
    if nets.is_empty() {
        return Err(DseError::NoCandidateFits {
            network: "(empty fleet)".to_string(),
        });
    }
    let batch = opts.batch.max(1) as f64;
    let mut winners: BTreeMap<String, TunedConfig> = BTreeMap::new();
    for net in nets {
        let r = tune_network(net, opts)?;
        winners.insert(net.name.to_string(), r.best().clone());
    }
    let tpd = |points: &BTreeMap<String, TunedConfig>| -> f64 {
        let rate: f64 = points.values().map(|t| batch / t.time_s).sum();
        let dsp: f64 = points.values().map(|t| t.resources.dsp as f64).sum();
        rate / dsp
    };
    let hetero_tpd = tpd(&winners);

    // single-model degeneracy: the per-network winner IS the fleet
    // answer (the cycle-optimal point; no mix to trade against)
    if nets.len() == 1 {
        let fp = winners.values().next().map(|t| t.cfg.fingerprint());
        return Ok(FleetTuneResult {
            assignments: winners,
            heterogeneous: false,
            hetero_throughput_per_dsp: hetero_tpd,
            best_uniform_throughput_per_dsp: hetero_tpd,
            uniform_fingerprint: fp,
        });
    }

    // uniform candidates, canonical order: distinct winner configs
    // (model-name order), then the paper points of the present
    // dimensionalities, then the platform default — first-found wins
    // ties so the search is deterministic
    let mut candidates: Vec<AccelConfig> = Vec::new();
    let mut push = |cfg: AccelConfig, seen: &mut Vec<String>| {
        let fp = cfg.fingerprint();
        if !seen.contains(&fp) {
            seen.push(fp);
            candidates.push(cfg);
        }
    };
    let mut seen: Vec<String> = Vec::new();
    for t in winners.values() {
        push(t.cfg.clone(), &mut seen);
    }
    for dims in [Dims::D2, Dims::D3] {
        if nets.iter().any(|n| n.dims == dims) {
            let cfg = AccelConfig {
                batch: opts.batch.max(1),
                ..AccelConfig::paper_for(dims)
            };
            push(cfg, &mut seen);
        }
    }
    push(
        AccelConfig {
            batch: opts.batch.max(1),
            ..AccelConfig::default()
        },
        &mut seen,
    );

    let mut best_uniform: Option<(f64, AccelConfig, BTreeMap<String, TunedConfig>)> = None;
    for cfg in candidates {
        let mut points = BTreeMap::new();
        let mut feasible = true;
        for net in nets {
            match evaluate_exact(&cfg, net) {
                Some(p) => {
                    points.insert(net.name.to_string(), p);
                }
                None => {
                    feasible = false;
                    break;
                }
            }
        }
        if !feasible {
            continue;
        }
        let score = tpd(&points);
        if best_uniform.as_ref().is_none_or(|(s, _, _)| score > *s) {
            best_uniform = Some((score, cfg, points));
        }
    }

    match best_uniform {
        Some((uniform_tpd, cfg, points)) if uniform_tpd > hetero_tpd => Ok(FleetTuneResult {
            assignments: points,
            heterogeneous: false,
            hetero_throughput_per_dsp: hetero_tpd,
            best_uniform_throughput_per_dsp: uniform_tpd,
            uniform_fingerprint: Some(cfg.fingerprint()),
        }),
        Some((uniform_tpd, cfg, _)) => Ok(FleetTuneResult {
            assignments: winners,
            heterogeneous: true,
            hetero_throughput_per_dsp: hetero_tpd,
            best_uniform_throughput_per_dsp: uniform_tpd,
            uniform_fingerprint: Some(cfg.fingerprint()),
        }),
        // no uniform candidate compiles for every model: the mix is
        // heterogeneous by necessity
        None => Ok(FleetTuneResult {
            assignments: winners,
            heterogeneous: true,
            hetero_throughput_per_dsp: hetero_tpd,
            best_uniform_throughput_per_dsp: 0.0,
            uniform_fingerprint: None,
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dcnn::zoo;

    #[test]
    fn buffer_splits_fit_the_device() {
        for &(i, w, o) in &BUFFER_SPLITS {
            let mut cfg = AccelConfig::paper_3d();
            cfg.input_buf_kib = i;
            cfg.weight_buf_kib = w;
            cfg.output_buf_kib = o;
            let est = resource::estimate(&cfg);
            assert!(est.fits_vc709(), "split ({i},{w},{o}) KiB busts BRAM: {est:?}");
        }
    }

    #[test]
    fn tuned_beats_or_ties_default_on_every_zoo_network() {
        for net in zoo::all_benchmarks() {
            let r = tune_network(&net, &TuneOptions::default()).unwrap();
            assert!(
                r.best().total_cycles <= r.default_point.total_cycles,
                "{}: tuned {} > default {}",
                net.name,
                r.best().total_cycles,
                r.default_point.total_cycles
            );
            assert!(r.speedup_vs_default() >= 1.0);
            assert!(!r.ranked.is_empty());
        }
    }

    #[test]
    fn ranking_is_sorted_and_within_keep() {
        let r = tune_network(&zoo::gan3d(), &TuneOptions::default()).unwrap();
        assert!(r.ranked.len() <= 5);
        for pair in r.ranked.windows(2) {
            assert!(pair[0].total_cycles <= pair[1].total_cycles);
        }
        // the audit trail covers the whole space
        assert!(r.evaluated > 0);
        assert!(r.evaluated + r.pruned > 0);
    }

    #[test]
    fn pruning_never_changes_the_winner() {
        // Exhaustive reference: evaluate every candidate, no pruning.
        let net = zoo::tiny_3d();
        let opts = TuneOptions::default();
        let exhaustive_best = tuner_candidates(&opts)
            .unwrap()
            .into_iter()
            .filter(|cfg| {
                net.layers
                    .iter()
                    .all(|l| working_set_fits(cfg, l, &Schedule::new(cfg, l)))
            })
            .filter_map(|cfg| evaluate_exact(&cfg, &net))
            .map(|p| p.total_cycles)
            .min()
            .unwrap();
        let r = tune_network(&net, &opts).unwrap();
        assert_eq!(r.best().total_cycles, exhaustive_best);
    }

    #[test]
    fn json_shapes_are_well_formed() {
        let r = tune_network(&zoo::tiny_2d(), &TuneOptions::default()).unwrap();
        let js = r.to_json();
        assert!(js.contains("\"network\": \"tiny-2d\""));
        assert!(js.contains("\"ranked\""));
        assert!(js.contains("\"fingerprint\""));
        assert!(js.contains("\"roofline_cycles\""));
        assert!(js.contains("\"kernels\""));
        assert!(js.contains("\"reason\""));
    }

    #[test]
    fn fleet_tuning_covers_the_mix_and_never_loses_to_uniform() {
        let nets = vec![zoo::tiny_2d(), zoo::tiny_3d()];
        let r = tune_fleet(&nets, &TuneOptions::default()).unwrap();
        assert_eq!(r.assignments.len(), 2);
        assert!(r.assignments.contains_key("tiny-2d"));
        assert!(r.assignments.contains_key("tiny-3d"));
        assert!(r.chosen_throughput_per_dsp() > 0.0);
        assert!(
            r.chosen_throughput_per_dsp() >= r.best_uniform_throughput_per_dsp,
            "chosen {} < uniform {}",
            r.chosen_throughput_per_dsp(),
            r.best_uniform_throughput_per_dsp
        );
        let js = r.to_json();
        assert!(js.contains("\"heterogeneous\""));
        assert!(js.contains("\"assignments\""));
        // deterministic: re-running yields the identical record
        let again = tune_fleet(&nets, &TuneOptions::default()).unwrap();
        assert_eq!(js, again.to_json());
    }

    #[test]
    fn single_model_fleet_degenerates_to_the_per_network_winner() {
        let net = zoo::tiny_3d();
        let opts = TuneOptions::default();
        let fleet = tune_fleet(std::slice::from_ref(&net), &opts).unwrap();
        let solo = tune_network(&net, &opts).unwrap();
        assert_eq!(fleet.assignments.len(), 1);
        assert_eq!(
            fleet.assignments["tiny-3d"].cfg.fingerprint(),
            solo.best().cfg.fingerprint()
        );
        assert!(!fleet.heterogeneous);
    }

    #[test]
    fn empty_fleet_is_an_error() {
        assert!(tune_fleet(&[], &TuneOptions::default()).is_err());
    }

    #[test]
    fn tuned_configs_record_a_kernel_choice_per_layer() {
        for net in [zoo::tiny_2d(), zoo::gan3d()] {
            let r = tune_network(&net, &TuneOptions::default()).unwrap();
            for point in r.ranked.iter().chain([&r.default_point]) {
                assert_eq!(point.kernels.len(), net.layers.len(), "{}", net.name);
                for ((name, sel), layer) in point.kernels.iter().zip(&net.layers) {
                    assert_eq!(name, &layer.name);
                    // the recorded choice is the argmin of its own scores
                    assert!(sel.chosen_cycles() <= sel.scatter_cycles);
                    assert!(sel.chosen_cycles() <= sel.gather_cycles);
                }
            }
        }
    }
}
