//! Design-space exploration: why Table II's operating points win, and
//! the per-network autotuner that picks better ones.
//!
//! Two tiers live here:
//!
//! * the flat **sweep** (this module) — enumerate `(T_m, T_n, T_z,
//!   T_r, T_c)` under the VC709 resource budget (DSP count caps total
//!   PEs; BRAM caps buffers — see [`crate::resource`]) and rank
//!   configurations by aggregate isolated-layer runtime. The
//!   `table2_configs` bench prints the resulting frontier next to the
//!   paper's chosen points.
//! * the **autotuner** ([`tune`]) — a roofline-pruned branch-and-bound
//!   search ([`roofline`] supplies the pruning bounds) over the same
//!   tiling space *times* on-chip buffer splits, evaluated on the
//!   compiled-plan path ([`crate::graph::simulate_plan`]) for one
//!   target network. This is what the serving tier consumes (see
//!   [`crate::serve::ConfigPolicy::Tuned`]).

pub mod roofline;
pub mod tune;

pub use roofline::{network_lower_bound, RooflineEstimate};
pub use tune::{tune_network, TuneOptions, TuneResult, TunedConfig};

use crate::dcnn::Network;

use super::config::AccelConfig;
use super::timing;

/// Typed failure of a design-space enumeration or search.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DseError {
    /// The budget admits no legal configuration at all (e.g. a PE cap
    /// below the smallest enumerable mesh).
    NoFeasibleConfig {
        /// The PE cap that excluded every candidate.
        max_pes: usize,
    },
    /// Candidates existed, but none survived the target network's
    /// feasibility checks (working sets, plan compilation).
    NoCandidateFits {
        /// The network every candidate failed on.
        network: String,
    },
}

impl std::fmt::Display for DseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DseError::NoFeasibleConfig { max_pes } => {
                write!(f, "no legal configuration under a {max_pes}-PE budget")
            }
            DseError::NoCandidateFits { network } => {
                write!(f, "no candidate configuration fits network '{network}'")
            }
        }
    }
}

impl std::error::Error for DseError {}

/// One evaluated design point.
#[derive(Clone, Debug)]
pub struct DsePoint {
    /// The configuration evaluated.
    pub cfg: AccelConfig,
    /// Total cycles across all layers of all supplied networks.
    pub total_cycles: u64,
    /// Time-weighted PE utilization.
    pub avg_utilization: f64,
    /// Whether the point fits the resource budget.
    pub fits: bool,
}

/// Constraints for the sweep. `T_n` is a power of two by construction
/// (the adder tree requires it, and [`AccelConfig::validate`] rejects
/// anything else), so the only free knob is the PE budget.
#[derive(Clone, Copy, Debug)]
pub struct DseBudget {
    /// Max PEs (≈ DSP budget; VC709: 3600 DSP48E → the paper uses 2048
    /// PEs + adder-tree DSPs).
    pub max_pes: usize,
}

impl Default for DseBudget {
    fn default() -> Self {
        DseBudget { max_pes: 2048 }
    }
}

/// Enumerate candidate configurations: deduplicated, in a fixed
/// deterministic order (lexicographic over `(T_m, T_n, T_z, T_r,
/// T_c)`), and non-empty — a budget that admits no legal configuration
/// is a typed [`DseError::NoFeasibleConfig`], not a silent `vec![]`.
pub fn candidates(budget: &DseBudget) -> Result<Vec<AccelConfig>, DseError> {
    let mut out = Vec::new();
    for tm in [1usize, 2, 4] {
        for tn_log in 2..=7 {
            let tn = 1usize << tn_log;
            for tz in [1usize, 2, 4, 8] {
                for tr in [2usize, 4, 8] {
                    for tc in [2usize, 4, 8] {
                        let cfg = AccelConfig {
                            tm,
                            tn,
                            tz,
                            tr,
                            tc,
                            ..AccelConfig::platform_defaults()
                        };
                        if cfg.total_pes() > budget.max_pes {
                            continue;
                        }
                        if cfg.validate().is_ok() {
                            out.push(cfg);
                        }
                    }
                }
            }
        }
    }
    dedupe_and_order(&mut out);
    if out.is_empty() {
        return Err(DseError::NoFeasibleConfig {
            max_pes: budget.max_pes,
        });
    }
    Ok(out)
}

/// Canonical candidate ordering + dedup: sort lexicographically over
/// the full identity (tiling, then buffer split) and drop fingerprint
/// duplicates. Every enumeration in this module funnels through here,
/// so candidate lists are deterministic regardless of how the space
/// was generated.
pub(crate) fn dedupe_and_order(cfgs: &mut Vec<AccelConfig>) {
    cfgs.sort_by_key(|c| {
        (
            c.tm,
            c.tn,
            c.tz,
            c.tr,
            c.tc,
            c.input_buf_kib,
            c.weight_buf_kib,
            c.output_buf_kib,
            c.batch,
        )
    });
    cfgs.dedup_by_key(|c| c.fingerprint());
}

/// Evaluate one configuration over a benchmark set.
pub fn evaluate(cfg: &AccelConfig, nets: &[Network], budget: &DseBudget) -> DsePoint {
    let mut total_cycles = 0u64;
    let mut util_weighted = 0.0;
    for net in nets {
        for layer in &net.layers {
            let m = timing::simulate(cfg, layer);
            total_cycles += m.total_cycles;
            util_weighted += m.pe_utilization() * m.total_cycles as f64;
        }
    }
    DsePoint {
        cfg: cfg.clone(),
        total_cycles,
        avg_utilization: if total_cycles > 0 {
            util_weighted / total_cycles as f64
        } else {
            0.0
        },
        fits: cfg.total_pes() <= budget.max_pes,
    }
}

/// Full sweep: evaluate all candidates, best (fewest cycles) first.
/// Ties break on the candidate order, so the ranking is deterministic.
pub fn sweep(nets: &[Network], budget: &DseBudget) -> Result<Vec<DsePoint>, DseError> {
    let mut points: Vec<DsePoint> = candidates(budget)?
        .iter()
        .map(|c| evaluate(c, nets, budget))
        .collect();
    points.sort_by_key(|p| p.total_cycles);
    Ok(points)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dcnn::zoo;

    #[test]
    fn candidates_respect_budget() {
        let budget = DseBudget::default();
        for c in candidates(&budget).unwrap() {
            assert!(c.total_pes() <= budget.max_pes);
            assert!(c.tn.is_power_of_two());
        }
    }

    #[test]
    fn candidates_are_deduped_and_ordered() {
        let budget = DseBudget::default();
        let cs = candidates(&budget).unwrap();
        let keys: Vec<(usize, usize, usize, usize, usize)> =
            cs.iter().map(|c| (c.tm, c.tn, c.tz, c.tr, c.tc)).collect();
        let mut sorted = keys.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(keys, sorted, "candidates must be sorted and unique");
        // and the enumeration is reproducible call to call
        let again = candidates(&budget).unwrap();
        assert_eq!(cs.len(), again.len());
        for (a, b) in cs.iter().zip(&again) {
            assert_eq!(a.fingerprint(), b.fingerprint());
        }
    }

    #[test]
    fn impossible_budget_is_a_typed_error() {
        // smallest enumerable mesh: 1·4·1·2·2 = 16 PEs
        let budget = DseBudget { max_pes: 8 };
        assert_eq!(
            candidates(&budget).unwrap_err(),
            DseError::NoFeasibleConfig { max_pes: 8 }
        );
        let err = sweep(&[zoo::tiny_2d()], &budget).unwrap_err();
        assert!(err.to_string().contains("8-PE"), "{err}");
    }

    #[test]
    fn paper_3d_point_is_near_optimal_for_3d_nets() {
        // Rank the paper's 3D point against the sweep on 3D benchmarks.
        let nets = [zoo::gan3d()];
        let budget = DseBudget::default();
        let points = sweep(&nets, &budget).unwrap();
        let paper = evaluate(&AccelConfig::paper_3d(), &nets, &budget);
        let better = points
            .iter()
            .filter(|p| p.total_cycles < paper.total_cycles)
            .count();
        // The paper's point should sit in the top quartile of the space.
        assert!(
            better <= points.len() / 4,
            "paper 3D point beaten by {better}/{} candidates",
            points.len()
        );
    }

    #[test]
    fn full_pe_budget_beats_half() {
        let nets = [zoo::dcgan()];
        let budget = DseBudget::default();
        let full = evaluate(&AccelConfig::paper_2d(), &nets, &budget);
        let mut half_cfg = AccelConfig::paper_2d();
        half_cfg.tn = 32; // 1024 PEs
        let half = evaluate(&half_cfg, &nets, &budget);
        assert!(full.total_cycles < half.total_cycles);
    }
}
