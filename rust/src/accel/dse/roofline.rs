//! Analytical roofline bounds used to prune the autotuner's search.
//!
//! For a candidate [`AccelConfig`] and a target network the exact cost
//! is the compiled-plan simulation ([`crate::graph::simulate_plan`]).
//! That is cheap, but the candidate space (tilings × buffer splits) is
//! large, so the tuner first computes a *provable lower bound* on the
//! plan's cycle count from two rooflines:
//!
//! * **compute** — the mesh cannot finish a layer in fewer cycles than
//!   `⌈batch · min(useful_MACs, gather_MACs) / total_PEs⌉`: every
//!   blocking schedule rounds its loop bounds *up*, so
//!   `passes · K^d · PEs ≥ batch · useful_MACs` holds for any legal
//!   [`crate::accel::Schedule`], and the gather kernel's cycle model
//!   scales those stall-free passes by `gather_MACs / useful_MACs`
//!   rounding up, so its cycles dominate
//!   `⌈batch · gather_MACs / PEs⌉`. Taking the per-layer *min* keeps
//!   the bound sound whichever kernel the compiler picks
//!   ([`crate::accel::kernel`]);
//! * **bandwidth** — DDR must move at least the weights once plus the
//!   network input and final output once per batch item. Interior
//!   layer boundaries may be kept entirely on-chip by the reuse pass,
//!   so they contribute `0` to the bound (which keeps it sound for any
//!   buffer split).
//!
//! The plan's total is a per-step `max(compute, memory)` sum, which is
//! `≥ max(Σ compute lower bounds, network bandwidth bound)` — the
//! value [`network_lower_bound`] reports. Candidates whose bound
//! already exceeds the best exact cycle count found so far can be
//! discarded without ever compiling them (see [`super::tune`]).

use crate::accel::memory::DdrModel;
use crate::accel::metrics::BoundBy;
use crate::accel::AccelConfig;
use crate::dcnn::Network;

/// A provable lower bound on a network's compiled-plan cycle count
/// under one configuration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RooflineEstimate {
    /// Compute roofline: Σ over layers of
    /// `⌈batch · min(useful_MACs, gather_MACs) / total_PEs⌉` — sound
    /// for either per-layer kernel choice.
    pub compute_cycles: u64,
    /// Bandwidth roofline: minimal DDR traffic (weights once + network
    /// input/output once per batch item) at full effective bandwidth.
    pub memory_cycles: u64,
    /// Minimal DDR bytes behind [`RooflineEstimate::memory_cycles`].
    pub min_dram_bytes: u64,
    /// Which roofline dominates the bound.
    pub bound_by: BoundBy,
}

impl RooflineEstimate {
    /// The lower bound itself: the binding roofline.
    pub fn lower_bound_cycles(&self) -> u64 {
        self.compute_cycles.max(self.memory_cycles)
    }

    /// Upper bound on achievable PE utilization implied by the
    /// rooflines: compute cycles over the binding roofline (1.0 when
    /// compute-bound, `< 1.0` when bandwidth limits the mesh).
    pub fn utilization_bound(&self) -> f64 {
        if self.lower_bound_cycles() == 0 {
            return 0.0;
        }
        self.compute_cycles as f64 / self.lower_bound_cycles() as f64
    }
}

/// Compute the roofline lower bound of `net` on `cfg` (at `cfg.batch`).
pub fn network_lower_bound(cfg: &AccelConfig, net: &Network) -> RooflineEstimate {
    let pes = cfg.total_pes() as u64;
    let batch = cfg.batch as u64;
    let eb = cfg.elem_bytes() as u64;

    let mut compute = 0u64;
    let mut weight_bytes = 0u64;
    for layer in &net.layers {
        // min over the two kernels the compiler may pick per layer
        let macs = layer.op_counts().useful_macs.min(layer.gather_macs());
        compute += (batch * macs).div_ceil(pes);
        weight_bytes += layer.weight_elems() as u64 * eb;
    }
    let edge_bytes = match (net.layers.first(), net.layers.last()) {
        (Some(first), Some(last)) => {
            batch * (first.input_elems() as u64 + last.output_elems() as u64) * eb
        }
        _ => 0,
    };
    let min_bytes = weight_bytes + edge_bytes;
    let ddr = DdrModel::from_config(cfg);
    let memory = ddr.transfer_cycles(min_bytes, cfg.freq_mhz);

    RooflineEstimate {
        compute_cycles: compute,
        memory_cycles: memory,
        min_dram_bytes: min_bytes,
        bound_by: if memory > compute {
            BoundBy::Memory
        } else {
            BoundBy::Compute
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dcnn::zoo;
    use crate::graph;

    #[test]
    fn bound_never_exceeds_exact_plan_cycles() {
        // The whole point: the bound must be sound for every network
        // and for configurations with very different tilings/buffers.
        let mut cfgs = vec![
            AccelConfig::paper_2d(),
            AccelConfig::paper_3d(),
            AccelConfig::tiny(1, 4, 1, 2, 2),
        ];
        let mut big_buf = AccelConfig::paper_2d();
        big_buf.input_buf_kib = 2048;
        big_buf.output_buf_kib = 2048;
        cfgs.push(big_buf);
        for net in zoo::all_benchmarks() {
            for cfg in &cfgs {
                let lb = network_lower_bound(cfg, &net).lower_bound_cycles();
                let exact = graph::compile_network(cfg, &net)
                    .map(|p| graph::simulate_plan(&p).total_cycles)
                    .unwrap();
                assert!(
                    lb <= exact,
                    "{} on {}: bound {lb} > exact {exact}",
                    net.name,
                    cfg.fingerprint()
                );
            }
        }
    }

    #[test]
    fn compute_bound_matches_saturated_layer() {
        // DCGAN layer 1 divides the paper mesh exactly: the compute
        // roofline equals useful work / PEs with no rounding slack.
        let cfg = AccelConfig::paper_2d();
        let net = zoo::dcgan();
        let est = network_lower_bound(&cfg, &net);
        let by_hand: u64 = net
            .layers
            .iter()
            .map(|l| {
                let macs = l.op_counts().useful_macs.min(l.gather_macs());
                (cfg.batch as u64 * macs).div_ceil(cfg.total_pes() as u64)
            })
            .sum();
        assert_eq!(est.compute_cycles, by_hand);
        assert!(est.min_dram_bytes > 0);
    }

    #[test]
    fn halving_bandwidth_raises_the_memory_roofline() {
        let net = zoo::dcgan();
        let cfg = AccelConfig::paper_2d();
        let mut slow = cfg.clone();
        slow.ddr_gbps /= 2.0;
        let a = network_lower_bound(&cfg, &net);
        let b = network_lower_bound(&slow, &net);
        assert!(b.memory_cycles > a.memory_cycles);
        assert_eq!(a.min_dram_bytes, b.min_dram_bytes, "bytes are bw-independent");
        assert!(a.utilization_bound() <= 1.0 + 1e-12);
    }
}
