//! The adder trees (§IV-A): reduce the `T_n` per-channel partial
//! results into one accumulated output block per group.
//!
//! `T_m · T_c · T_z · log₂(T_n)` physical adders give a pipelined
//! binary tree of depth `log₂(T_n)`; the timing tier charges its drain
//! latency once per accumulation group, the functional tier performs
//! the actual reduction here (in 48-bit, matching the hardware's
//! wide accumulation — no intermediate rounding).

use crate::fixed::Acc48;
use crate::util::ceil_log2;

/// Reduce a slice of partial accumulators with a binary tree,
/// returning the sum and the tree depth (pipeline stages).
pub fn reduce(parts: &[Acc48]) -> (Acc48, u32) {
    let depth = ceil_log2(parts.len().max(1));
    let mut level: Vec<Acc48> = parts.to_vec();
    while level.len() > 1 {
        let mut next = Vec::with_capacity(level.len().div_ceil(2));
        for pair in level.chunks(2) {
            let mut a = pair[0];
            if pair.len() == 2 {
                a.add(pair[1]);
            }
            next.push(a);
        }
        level = next;
    }
    (level.first().copied().unwrap_or(Acc48::ZERO), depth)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::Q88;

    fn acc(v: f32) -> Acc48 {
        let mut a = Acc48::ZERO;
        a.mac(Q88::from_f32(v), Q88::ONE);
        a
    }

    #[test]
    fn reduce_sums_exactly() {
        let parts: Vec<Acc48> = (1..=8).map(|i| acc(i as f32)).collect();
        let (sum, depth) = reduce(&parts);
        assert_eq!(sum.to_q88().to_f32(), 36.0);
        assert_eq!(depth, 3);
    }

    #[test]
    fn reduce_non_power_of_two() {
        let parts: Vec<Acc48> = (1..=5).map(|i| acc(i as f32)).collect();
        let (sum, depth) = reduce(&parts);
        assert_eq!(sum.to_q88().to_f32(), 15.0);
        assert_eq!(depth, 3); // ceil(log2 5)
    }

    #[test]
    fn reduce_single_and_empty() {
        let (s, d) = reduce(&[acc(4.0)]);
        assert_eq!(s.to_q88().to_f32(), 4.0);
        assert_eq!(d, 0);
        let (s, d) = reduce(&[]);
        assert_eq!(s, Acc48::ZERO);
        assert_eq!(d, 0);
    }

    #[test]
    fn tree_order_matches_sequential_sum() {
        // integer adds are associative: tree == sequential, bit for bit
        let parts: Vec<Acc48> = (0..16).map(|i| acc(i as f32 * 0.37 - 2.0)).collect();
        let (tree, _) = reduce(&parts);
        let mut seq = Acc48::ZERO;
        for p in &parts {
            seq.add(*p);
        }
        assert_eq!(tree, seq);
    }
}
