//! Simulation metrics: everything Fig. 6 and Fig. 7 plot.

use crate::dcnn::LayerSpec;

use super::config::AccelConfig;

/// What limits a layer's runtime.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BoundBy {
    /// The compute pipeline is the bottleneck.
    Compute,
    /// DDR traffic is the bottleneck.
    Memory,
}

impl std::fmt::Display for BoundBy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BoundBy::Compute => write!(f, "compute"),
            BoundBy::Memory => write!(f, "memory"),
        }
    }
}

/// Per-layer simulation result.
#[derive(Clone, Debug)]
pub struct LayerMetrics {
    /// Layer the metrics describe.
    pub layer_name: String,
    /// Cycles the compute pipeline needs.
    pub compute_cycles: u64,
    /// Cycles the DDR needs for all traffic.
    pub memory_cycles: u64,
    /// End-to-end cycles (max of the above + un-overlapped edges).
    pub total_cycles: u64,
    /// Useful MAC-cycles (batch included).
    pub ideal_mac_cycles: u64,
    /// Total PE count of the configuration.
    pub total_pes: usize,
    /// Batch the numbers cover.
    pub batch: usize,
    /// Dense-equivalent MACs per batch item (paper TOPS convention:
    /// the zero-inserted convolution over the *cropped* output map).
    pub dense_macs: u64,
    /// Useful MACs per batch item.
    pub useful_macs: u64,
    /// DDR traffic (batch total).
    pub dram_bytes: u64,
    /// Which resource bounds the layer.
    pub bound_by: BoundBy,
    /// Clock for time conversion.
    pub freq_mhz: f64,
}

impl LayerMetrics {
    /// Fig. 6(a): computation time over total time × mesh occupancy.
    /// Equivalently: useful MAC-cycles / (PEs × total cycles).
    pub fn pe_utilization(&self) -> f64 {
        self.ideal_mac_cycles as f64 / (self.total_pes as f64 * self.total_cycles as f64)
    }

    /// Utilization of the compute pipeline alone (no memory stalls) —
    /// isolates schedule quality from bandwidth.
    pub fn compute_utilization(&self) -> f64 {
        self.ideal_mac_cycles as f64 / (self.total_pes as f64 * self.compute_cycles as f64)
    }

    /// Wall-clock seconds for the whole batch.
    pub fn time_s(&self) -> f64 {
        self.total_cycles as f64 / (self.freq_mhz * 1e6)
    }

    /// Seconds per single inference.
    pub fn time_per_item_s(&self) -> f64 {
        self.time_s() / self.batch as f64
    }

    /// Fig. 6(b): dense-equivalent TOPS (2 ops per MAC; the zero-
    /// inserted convolution an OOM engine would have performed in the
    /// same wall-clock time).
    pub fn effective_tops(&self, _cfg: &AccelConfig) -> f64 {
        2.0 * self.dense_macs as f64 * self.batch as f64 / self.time_s() / 1e12
    }

    /// Useful TOPS (2 × useful MACs / time) — bounded by the 0.82 peak.
    pub fn useful_tops(&self) -> f64 {
        2.0 * self.useful_macs as f64 * self.batch as f64 / self.time_s() / 1e12
    }

    /// Effective DRAM bandwidth demand in GB/s.
    pub fn dram_gbps(&self) -> f64 {
        self.dram_bytes as f64 / self.time_s() / 1e9
    }
}

/// Whole-network rollup.
#[derive(Clone, Debug)]
pub struct NetworkMetrics {
    /// Network name.
    pub network: String,
    /// Per-layer metrics in execution order.
    pub layers: Vec<LayerMetrics>,
}

impl NetworkMetrics {
    /// Wrap per-layer metrics into a network rollup.
    pub fn new(network: &str, layers: Vec<LayerMetrics>) -> NetworkMetrics {
        NetworkMetrics {
            network: network.to_string(),
            layers,
        }
    }

    /// Total seconds for the batch across all layers.
    pub fn total_time_s(&self) -> f64 {
        self.layers.iter().map(|l| l.time_s()).sum()
    }

    /// Total cycles across all layers.
    pub fn total_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.total_cycles).sum()
    }

    /// Network-level dense-equivalent TOPS.
    pub fn effective_tops(&self) -> f64 {
        let batch = self.layers.first().map(|l| l.batch).unwrap_or(1) as f64;
        let dense: u64 = self.layers.iter().map(|l| l.dense_macs).sum();
        2.0 * dense as f64 * batch / self.total_time_s() / 1e12
    }

    /// Time-weighted average PE utilization.
    pub fn avg_pe_utilization(&self) -> f64 {
        let total: u64 = self.layers.iter().map(|l| l.total_cycles).sum();
        if total == 0 {
            return 0.0;
        }
        self.layers
            .iter()
            .map(|l| l.pe_utilization() * l.total_cycles as f64)
            .sum::<f64>()
            / total as f64
    }
}

/// Dense-equivalent MACs for one batch item under the paper's
/// convention (cropped output extent → asymptotically exactly `S^d` ×
/// the useful MACs).
pub fn dense_equivalent_macs(layer: &LayerSpec) -> u64 {
    layer.in_c as u64
        * layer.out_spatial() as u64
        * layer.kernel_volume() as u64
        * layer.out_c as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dummy(total_cycles: u64, compute: u64, memory: u64) -> LayerMetrics {
        LayerMetrics {
            layer_name: "t".into(),
            compute_cycles: compute,
            memory_cycles: memory,
            total_cycles,
            ideal_mac_cycles: 1000,
            total_pes: 10,
            batch: 2,
            dense_macs: 4000,
            useful_macs: 1000,
            dram_bytes: 512,
            bound_by: BoundBy::Compute,
            freq_mhz: 200.0,
        }
    }

    #[test]
    fn utilization_formula() {
        let m = dummy(200, 200, 100);
        assert!((m.pe_utilization() - 1000.0 / 2000.0).abs() < 1e-12);
    }

    #[test]
    fn time_and_tops() {
        let m = dummy(200, 200, 100);
        let t = m.time_s();
        assert!((t - 200.0 / 200e6).abs() < 1e-18);
        let cfg = AccelConfig::paper_2d();
        // 2*4000*2 / 1e-6 s / 1e12
        assert!((m.effective_tops(&cfg) - 2.0 * 4000.0 * 2.0 / t / 1e12).abs() < 1e-9);
        assert!(m.useful_tops() < m.effective_tops(&cfg));
    }

    #[test]
    fn network_rollup() {
        let nm = NetworkMetrics::new("x", vec![dummy(100, 100, 50), dummy(300, 300, 50)]);
        assert_eq!(nm.total_cycles(), 400);
        let u = nm.avg_pe_utilization();
        // layer utils: 1000/1000=1.0? no: 1000/(10*100)=1.0 and 1000/(10*300)=0.333
        let expect = (1.0 * 100.0 + (1.0 / 3.0) * 300.0) / 400.0;
        assert!((u - expect).abs() < 1e-9);
    }

    #[test]
    fn dense_equivalent_is_s_pow_d_asymptotically() {
        use crate::dcnn::LayerSpec;
        let l = LayerSpec::new_2d("t", 1, 64, 64, 1, 3, 2);
        let d = dense_equivalent_macs(&l);
        let u = l.op_counts().useful_macs;
        assert_eq!(d, 4 * u, "cropped extent gives exactly S^2");
        let l3 = LayerSpec::new_3d("t3", 1, 16, 16, 16, 1, 3, 2);
        assert_eq!(dense_equivalent_macs(&l3), 8 * l3.op_counts().useful_macs);
    }
}
