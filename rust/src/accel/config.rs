//! Accelerator configuration (Table II) and platform constants.

use crate::dcnn::Dims;

/// Full configuration of the computation engine plus platform numbers.
///
/// `T_m × T_n × T_z × T_r × T_c` PEs in total (Table II uses 2048 for
/// both the 2D and 3D operating points of the same bitstream).
#[derive(Clone, Debug, PartialEq)]
pub struct AccelConfig {
    /// PE groups — output channels computed in parallel (`T_m`).
    pub tm: usize,
    /// PE arrays per group along input channels (`T_n`).
    pub tn: usize,
    /// PE arrays per group along input depth (`T_z`; 1 for the 2D
    /// operating point, where the Z dimension folds into channels —
    /// §IV-C).
    pub tz: usize,
    /// PE array rows (`T_r`).
    pub tr: usize,
    /// PE array columns (`T_c`).
    pub tc: usize,
    /// Clock (paper: 200 MHz on the VC709).
    pub freq_mhz: f64,
    /// Datapath width in bits (paper: 16-bit fixed).
    pub data_width_bits: usize,
    /// Effective DDR bandwidth in GB/s. VC709 has two DDR3 SODIMMs;
    /// we default to 2 × 12.8 GB/s peak × 75 % efficiency = 19.2 GB/s.
    pub ddr_gbps: f64,
    /// On-chip buffer capacities in KiB (input / weight / output).
    pub input_buf_kib: usize,
    /// Weight-buffer capacity in KiB.
    pub weight_buf_kib: usize,
    /// Output-buffer capacity in KiB.
    pub output_buf_kib: usize,
    /// Batch size the accelerator pipelines (weights are re-used across
    /// the batch; the paper's >90 % PE utilization on weight-heavy
    /// early GAN layers is only reachable with batching — see
    /// DESIGN.md §5).
    pub batch: usize,
    /// When `true`, a PE stalls `K²·(K−S)` cycles per activation in 3D
    /// mode to serialize FIFO-D depth-overlap traffic through a single
    /// shared port. Default `false`: the FIFO-D port runs concurrently
    /// with the multiplier (dual-ported register files), which is what
    /// the paper's ">90 % PE utilization" for 3D nets requires. The
    /// `ablation_iom_vs_oom` bench quantifies the serialized variant.
    pub depth_overlap_stall: bool,
}

impl Default for AccelConfig {
    /// The untuned operating point — [`AccelConfig::platform_defaults`].
    /// The autotuner ([`crate::accel::dse::tune`]) measures its wins
    /// against this, and guarantees it never selects anything slower.
    fn default() -> Self {
        AccelConfig::platform_defaults()
    }
}

impl AccelConfig {
    /// Table II, row "2D DCNNs": T_m=2, T_n=64, T_z=1, T_r=4, T_c=4.
    pub fn paper_2d() -> AccelConfig {
        AccelConfig {
            tm: 2,
            tn: 64,
            tz: 1,
            tr: 4,
            tc: 4,
            ..AccelConfig::platform_defaults()
        }
    }

    /// Table II, row "3D DCNNs": T_m=2, T_n=16, T_z=4, T_r=4, T_c=4.
    pub fn paper_3d() -> AccelConfig {
        AccelConfig {
            tm: 2,
            tn: 16,
            tz: 4,
            tr: 4,
            tc: 4,
            ..AccelConfig::platform_defaults()
        }
    }

    /// Pick the paper operating point matching a layer's dimensionality.
    pub fn paper_for(dims: Dims) -> AccelConfig {
        match dims {
            Dims::D2 => AccelConfig::paper_2d(),
            Dims::D3 => AccelConfig::paper_3d(),
        }
    }

    /// Platform constants shared by both operating points.
    pub fn platform_defaults() -> AccelConfig {
        AccelConfig {
            tm: 2,
            tn: 64,
            tz: 1,
            tr: 4,
            tc: 4,
            freq_mhz: 200.0,
            data_width_bits: 16,
            ddr_gbps: 19.2,
            input_buf_kib: 512,
            weight_buf_kib: 1536,
            output_buf_kib: 1024,
            batch: 8,
            depth_overlap_stall: false,
        }
    }

    /// A tiny configuration for exact functional simulation in tests.
    pub fn tiny(tm: usize, tn: usize, tz: usize, tr: usize, tc: usize) -> AccelConfig {
        AccelConfig {
            tm,
            tn,
            tz,
            tr,
            tc,
            batch: 1,
            ..AccelConfig::platform_defaults()
        }
    }

    /// Total PE count `T_m·T_n·T_z·T_r·T_c`.
    pub fn total_pes(&self) -> usize {
        self.tm * self.tn * self.tz * self.tr * self.tc
    }

    /// Peak MACs per cycle (one multiplier per PE).
    pub fn peak_macs_per_cycle(&self) -> usize {
        self.total_pes()
    }

    /// Peak *useful* arithmetic throughput in TOPS (2 ops per MAC).
    pub fn peak_tops(&self) -> f64 {
        2.0 * self.total_pes() as f64 * self.freq_mhz * 1e6 / 1e12
    }

    /// Bytes per element of the datapath.
    pub fn elem_bytes(&self) -> usize {
        self.data_width_bits / 8
    }

    /// Number of adders in the adder trees:
    /// `T_m · T_c · T_z · log₂(T_n)` (§IV-A).
    pub fn adder_tree_adders(&self) -> usize {
        self.tm * self.tc * self.tz * crate::util::ceil_log2(self.tn) as usize
    }

    /// Cycle time in seconds.
    pub fn cycle_s(&self) -> f64 {
        1.0 / (self.freq_mhz * 1e6)
    }

    /// Stable identity string of this configuration.
    ///
    /// Every field that can change a compiled [`crate::graph::NetworkPlan`]
    /// participates, so `(network, fingerprint)` is a sound plan-cache
    /// key (see [`crate::serve::PlanCache`]): two configs with equal
    /// fingerprints compile byte-identical plans.
    pub fn fingerprint(&self) -> String {
        let mut s = String::new();
        self.write_fingerprint(&mut s);
        s
    }

    /// Append [`AccelConfig::fingerprint`] to `buf` without allocating
    /// a fresh `String` — the serving hot path renders plan-cache keys
    /// into a reused buffer (`serve::Fleet`), which the zero-allocation
    /// battery in `tests/obs_trace.rs` pins.
    pub fn write_fingerprint(&self, buf: &mut String) {
        use std::fmt::Write;
        write!(
            buf,
            "tm{}.tn{}.tz{}.tr{}.tc{}.f{}.dw{}.bw{}.ib{}.wb{}.ob{}.b{}.st{}",
            self.tm,
            self.tn,
            self.tz,
            self.tr,
            self.tc,
            self.freq_mhz,
            self.data_width_bits,
            self.ddr_gbps,
            self.input_buf_kib,
            self.weight_buf_kib,
            self.output_buf_kib,
            self.batch,
            u8::from(self.depth_overlap_stall),
        )
        .expect("String write is infallible");
    }

    /// Compact human-readable identity — tiling plus buffer split,
    /// e.g. `Tm2 Tn64 Tz1 Tr4 Tc4 b512/1536/1024`. The display the
    /// `udcnn tune` table and `benches/dse_autotune.rs` share;
    /// [`AccelConfig::fingerprint`] remains the cache identity.
    pub fn describe(&self) -> String {
        let b = format!("b{}/{}/{}", self.input_buf_kib, self.weight_buf_kib, self.output_buf_kib);
        format!("Tm{} Tn{} Tz{} Tr{} Tc{} {b}", self.tm, self.tn, self.tz, self.tr, self.tc)
    }

    /// Validate structural invariants.
    pub fn validate(&self) -> Result<(), String> {
        if self.tm == 0 || self.tn == 0 || self.tz == 0 || self.tr == 0 || self.tc == 0 {
            return Err("all T_* must be positive".into());
        }
        if !self.tn.is_power_of_two() {
            return Err(format!("T_n={} must be a power of two (adder tree)", self.tn));
        }
        if self.data_width_bits % 8 != 0 {
            return Err("data width must be byte-aligned".into());
        }
        if self.batch == 0 {
            return Err("batch must be positive".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_configs_have_2048_pes() {
        assert_eq!(AccelConfig::paper_2d().total_pes(), 2048);
        assert_eq!(AccelConfig::paper_3d().total_pes(), 2048);
    }

    #[test]
    fn peak_tops_is_0_82() {
        let t = AccelConfig::paper_2d().peak_tops();
        assert!((t - 0.8192).abs() < 1e-9, "peak useful TOPS {t}");
    }

    #[test]
    fn adder_tree_counts() {
        // 2D point: 2*4*1*log2(64)=48; 3D point: 2*4*4*log2(16)=128
        assert_eq!(AccelConfig::paper_2d().adder_tree_adders(), 48);
        assert_eq!(AccelConfig::paper_3d().adder_tree_adders(), 128);
    }

    #[test]
    fn validation() {
        assert!(AccelConfig::paper_2d().validate().is_ok());
        assert!(AccelConfig::paper_3d().validate().is_ok());
        let mut bad = AccelConfig::paper_2d();
        bad.tn = 48; // not a power of two
        assert!(bad.validate().is_err());
        let mut bad = AccelConfig::paper_2d();
        bad.tr = 0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn default_is_the_platform_operating_point() {
        assert_eq!(AccelConfig::default(), AccelConfig::platform_defaults());
        assert_eq!(AccelConfig::default(), AccelConfig::paper_2d());
    }

    #[test]
    fn paper_for_selects_by_dims() {
        assert_eq!(AccelConfig::paper_for(Dims::D2).tn, 64);
        assert_eq!(AccelConfig::paper_for(Dims::D3).tz, 4);
    }
}
