//! DDR + memory controller model (§IV-A).
//!
//! The VC709 carries two 4 GB DDR3 SODIMMs. We model the controller as
//! a bandwidth server with a fixed efficiency factor and per-burst
//! latency; the timing tier overlaps memory time with compute time
//! (double buffering), taking the max plus the un-overlappable
//! first-load / last-store edges.

use super::config::AccelConfig;

/// A DDR transfer request (direction only matters for stats).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dir {
    /// DDR → chip.
    Read,
    /// Chip → DDR.
    Write,
}

/// Simple bandwidth-server DDR model.
#[derive(Clone, Debug)]
pub struct DdrModel {
    /// Effective bandwidth, bytes per second.
    pub bytes_per_s: f64,
    /// Fixed latency per burst (row activation + controller), seconds.
    pub burst_latency_s: f64,
    /// Burst size in bytes (one BL8 × 64-bit channel).
    pub burst_bytes: usize,
}

impl DdrModel {
    /// Model the VC709 DDR3 system of a configuration.
    pub fn from_config(cfg: &AccelConfig) -> DdrModel {
        DdrModel {
            bytes_per_s: cfg.ddr_gbps * 1e9,
            burst_latency_s: 50e-9,
            burst_bytes: 64,
        }
    }

    /// Seconds to move `bytes` (streaming, latency amortized across
    /// bursts in flight — only the first burst's latency is exposed).
    pub fn transfer_s(&self, bytes: u64) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        self.burst_latency_s + bytes as f64 / self.bytes_per_s
    }

    /// Cycles (at `freq_mhz`) to move `bytes`.
    pub fn transfer_cycles(&self, bytes: u64, freq_mhz: f64) -> u64 {
        (self.transfer_s(bytes) * freq_mhz * 1e6).ceil() as u64
    }
}

/// Aggregate DDR traffic statistics collected by a simulation.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DdrStats {
    /// Bytes read from DDR.
    pub read_bytes: u64,
    /// Bytes written to DDR.
    pub write_bytes: u64,
    /// Number of recorded transfers.
    pub transactions: u64,
}

impl DdrStats {
    /// Record one transfer.
    pub fn record(&mut self, dir: Dir, bytes: u64) {
        match dir {
            Dir::Read => self.read_bytes += bytes,
            Dir::Write => self.write_bytes += bytes,
        }
        self.transactions += 1;
    }

    /// Total traffic in both directions.
    pub fn total_bytes(&self) -> u64 {
        self.read_bytes + self.write_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_linear_in_bytes() {
        let m = DdrModel {
            bytes_per_s: 1e9,
            burst_latency_s: 0.0,
            burst_bytes: 64,
        };
        assert!((m.transfer_s(1_000_000) - 1e-3).abs() < 1e-12);
        assert_eq!(m.transfer_s(0), 0.0);
    }

    #[test]
    fn latency_exposed_once() {
        let m = DdrModel {
            bytes_per_s: 1e9,
            burst_latency_s: 100e-9,
            burst_bytes: 64,
        };
        let t = m.transfer_s(64);
        assert!(t > 100e-9 && t < 200e-9);
    }

    #[test]
    fn cycles_round_up() {
        let m = DdrModel {
            bytes_per_s: 19.2e9,
            burst_latency_s: 0.0,
            burst_bytes: 64,
        };
        // 19.2 GB/s at 200 MHz = 96 B/cycle
        assert_eq!(m.transfer_cycles(96, 200.0), 1);
        assert_eq!(m.transfer_cycles(97, 200.0), 2);
    }

    #[test]
    fn stats_accumulate() {
        let mut s = DdrStats::default();
        s.record(Dir::Read, 100);
        s.record(Dir::Write, 50);
        assert_eq!(s.total_bytes(), 150);
        assert_eq!(s.transactions, 2);
    }
}
