//! A `T_r × T_c` PE array (one "PE plane" of the 3D mesh) and the
//! per-pass dataflow: multiply, route overlaps (FIFO-V within a
//! column's rows, FIFO-H along a row, FIFO-D across planes), drain.

use crate::fixed::Q88;

use super::fifo::OverlapDir;
use super::pe::{OverlapMsg, Pe};

/// Static geometry of one pass (shared by every array in the mesh).
#[derive(Clone, Copy, Debug)]
pub struct PassCtx {
    /// Tile origin in input coordinates.
    pub d: usize, // this array's input depth plane
    /// Tile origin row.
    pub h0: usize,
    /// Tile origin column.
    pub w0: usize,
    /// Input extents.
    pub in_d: usize,
    /// Input height.
    pub in_h: usize,
    /// Input width.
    pub in_w: usize,
    /// Kernel extents: `kd` is 1 for 2D layers, `k` otherwise.
    pub k: usize,
    /// Kernel depth extent (1 for 2D).
    pub kd: usize,
    /// Stride.
    pub s: usize,
    /// Depth-plane range resident in this pass (for FIFO-D routing):
    /// planes `[d_lo, d_hi)` are on adjacent arrays.
    pub d_lo: usize,
    /// Exclusive end of the resident depth-plane range.
    pub d_hi: usize,
}

/// Owner input index for output coordinate `o` along one axis: the
/// *smallest* `i` with `i·s ≤ o < i·s + k_ext` (the paper sends
/// overlaps from I2/I3 back to I1 — Fig. 5).
#[inline]
pub fn owner_index(o: usize, k_ext: usize, s: usize, in_ext: usize) -> usize {
    let i_min = if o + 1 > k_ext {
        (o + 1 - k_ext).div_ceil(s)
    } else {
        0
    };
    debug_assert!(i_min * s <= o && o < i_min * s + k_ext && i_min < in_ext);
    i_min
}

/// Result of routing one product.
#[derive(Debug)]
pub enum Routed {
    /// Accumulated locally or delivered to an in-array FIFO.
    Internal,
    /// Crosses to an adjacent depth plane: deliver to array `target_d`.
    Depth { target_d: usize, msg: OverlapMsg },
    /// Owner is outside the resident pass: accumulate in the output
    /// buffer (the mesh's global grid).
    Spill(OverlapMsg),
}

/// One PE array.
#[derive(Clone, Debug)]
pub struct PeArray {
    /// Rows.
    pub tr: usize,
    /// Columns.
    pub tc: usize,
    /// PEs, row-major `tr × tc`.
    pub pes: Vec<Pe>,
    /// Statistic: products routed through V/H FIFOs.
    pub v_pushes: u64,
    /// Products routed through H FIFOs.
    pub h_pushes: u64,
}

impl PeArray {
    /// An array of idle PEs sized for kernel volume `k_vol`.
    pub fn new(tr: usize, tc: usize, k_vol: usize, fifo_cap: usize) -> PeArray {
        PeArray {
            tr,
            tc,
            pes: (0..tr * tc).map(|_| Pe::new(k_vol, fifo_cap)).collect(),
            v_pushes: 0,
            h_pushes: 0,
        }
    }

    #[inline]
    /// The PE at `(r, c)`.
    pub fn pe(&self, r: usize, c: usize) -> &Pe {
        &self.pes[r * self.tc + c]
    }

    #[inline]
    /// Mutable access to the PE at `(r, c)`.
    pub fn pe_mut(&mut self, r: usize, c: usize) -> &mut Pe {
        &mut self.pes[r * self.tc + c]
    }

    /// Load activations (None where the tile overhangs the input edge)
    /// and the kernel into every PE.
    pub fn load_pass(
        &mut self,
        ctx: &PassCtx,
        kernel: &[Q88],
        mut activation: impl FnMut(usize, usize) -> Option<Q88>,
    ) {
        for r in 0..self.tr {
            for c in 0..self.tc {
                let h = ctx.h0 + r;
                let w = ctx.w0 + c;
                let a = if h < ctx.in_h && w < ctx.in_w {
                    activation(h, w)
                } else {
                    None
                };
                self.pe_mut(r, c).load(a, kernel);
            }
        }
    }

    /// Multiply every resident activation by every kernel element and
    /// route the products. In-array overlaps are pushed into the
    /// target PE's FIFO-V/FIFO-H; depth overlaps and out-of-pass
    /// products are returned for the mesh to deliver.
    pub fn compute_pass(&mut self, ctx: &PassCtx) -> Vec<Routed> {
        let mut external = Vec::new();
        let k = ctx.k;
        let kd = ctx.kd;
        for r in 0..self.tr {
            for c in 0..self.tc {
                if self.pe(r, c).ra.is_none() {
                    continue;
                }
                let h = ctx.h0 + r;
                let w = ctx.w0 + c;
                for kz in 0..kd {
                    for ky in 0..k {
                        for kx in 0..k {
                            let k_idx = (kz * k + ky) * k + kx;
                            let wide = match self.pe_mut(r, c).multiply(k_idx) {
                                Some(p) => p,
                                None => continue,
                            };
                            let oz = ctx.d * ctx.s * (kd > 1) as usize
                                + if kd > 1 { kz } else { 0 };
                            let oy = h * ctx.s + ky;
                            let ox = w * ctx.s + kx;
                            let od_own = if kd > 1 {
                                owner_index(oz, kd, ctx.s, ctx.in_d)
                            } else {
                                ctx.d
                            };
                            let oh_own = owner_index(oy, k, ctx.s, ctx.in_h);
                            let ow_own = owner_index(ox, k, ctx.s, ctx.in_w);
                            let msg = OverlapMsg { oz, oy, ox, wide };

                            let in_tile_hw = oh_own >= ctx.h0
                                && oh_own < ctx.h0 + self.tr
                                && ow_own >= ctx.w0
                                && ow_own < ctx.w0 + self.tc;
                            if od_own == ctx.d && oh_own == h && ow_own == w {
                                // local product
                                self.pe_mut(r, c).accumulate_local(k_idx, wide);
                            } else if od_own != ctx.d {
                                // depth overlap: leaves this plane
                                if od_own >= ctx.d_lo && od_own < ctx.d_hi && in_tile_hw {
                                    external.push(Routed::Depth {
                                        target_d: od_own,
                                        msg,
                                    });
                                } else {
                                    external.push(Routed::Spill(msg));
                                }
                            } else if oh_own >= ctx.h0
                                && oh_own < ctx.h0 + self.tr
                                && ow_own >= ctx.w0
                                && ow_own < ctx.w0 + self.tc
                            {
                                // in-array overlap: vertical first, then
                                // horizontal (dimension-ordered)
                                let tr_ = oh_own - ctx.h0;
                                let tc_ = ow_own - ctx.w0;
                                let dir = if oh_own != h {
                                    self.v_pushes += 1;
                                    OverlapDir::Vertical
                                } else {
                                    self.h_pushes += 1;
                                    OverlapDir::Horizontal
                                };
                                self.pe_mut(tr_, tc_)
                                    .receive(dir, msg)
                                    .expect("overlap FIFO overflow: undersized FIFO");
                            } else {
                                external.push(Routed::Spill(msg));
                            }
                        }
                    }
                }
            }
        }
        external
    }

    /// Drain every PE's FIFOs into its local block (the owner PE adds
    /// received overlaps at the right kernel offset).
    pub fn drain_pass(&mut self, ctx: &PassCtx) {
        let k = ctx.k;
        for r in 0..self.tr {
            for c in 0..self.tc {
                let h = ctx.h0 + r;
                let w = ctx.w0 + c;
                let pe = self.pe_mut(r, c);
                let mut msgs = Vec::new();
                pe.drain_fifos(|m| msgs.push(m));
                for m in msgs {
                    // local offset inside the owner's K^d block
                    let kz = if ctx.kd > 1 { m.oz - ctx.d * ctx.s } else { 0 };
                    let ky = m.oy - h * ctx.s;
                    let kx = m.ox - w * ctx.s;
                    let k_idx = (kz * k + ky) * k + kx;
                    pe.accumulate_local(k_idx, m.wide);
                }
            }
        }
    }

    /// Total MACs across the array.
    pub fn total_macs(&self) -> u64 {
        self.pes.iter().map(|p| p.macs).sum()
    }

    /// Max FIFO occupancy seen across all PEs.
    pub fn max_fifo_occupancy(&self) -> usize {
        self.pes
            .iter()
            .map(|p| {
                p.fifo_v
                    .max_occupancy
                    .max(p.fifo_h.max_occupancy)
                    .max(p.fifo_d.max_occupancy)
            })
            .max()
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn owner_index_basics() {
        // K=3, S=2: output 0,1 owned by input 0; 2 overlaps (owner 0);
        // 3 owned by 1; 4 overlap (owner 1)...
        assert_eq!(owner_index(0, 3, 2, 4), 0);
        assert_eq!(owner_index(1, 3, 2, 4), 0);
        assert_eq!(owner_index(2, 3, 2, 4), 0, "overlap goes to the earlier PE");
        assert_eq!(owner_index(3, 3, 2, 4), 1);
        assert_eq!(owner_index(4, 3, 2, 4), 1);
        assert_eq!(owner_index(5, 3, 2, 4), 2);
    }

    #[test]
    fn owner_index_stride_1() {
        // S=1, K=2: every output except the first overlaps
        assert_eq!(owner_index(0, 2, 1, 4), 0);
        assert_eq!(owner_index(1, 2, 1, 4), 0);
        assert_eq!(owner_index(2, 2, 1, 4), 1);
        assert_eq!(owner_index(3, 2, 1, 4), 2);
    }

    fn simple_ctx() -> PassCtx {
        PassCtx {
            d: 0,
            h0: 0,
            w0: 0,
            in_d: 1,
            in_h: 2,
            in_w: 2,
            k: 3,
            kd: 1,
            s: 2,
            d_lo: 0,
            d_hi: 1,
        }
    }

    #[test]
    fn pass_routes_overlaps_to_earlier_pes() {
        let mut arr = PeArray::new(2, 2, 9, 32);
        let ctx = simple_ctx();
        let kernel = vec![Q88::ONE; 9];
        arr.load_pass(&ctx, &kernel, |_, _| Some(Q88::ONE));
        let ext = arr.compute_pass(&ctx);
        // 2x2 inputs, all in one tile: no spills, no depth traffic
        assert!(ext.is_empty(), "{ext:?}");
        // overlap column (ox=2) from PEs at w=1 -> pushed to w=0 PEs;
        // overlap row (oy=2) from PEs at h=1 -> pushed to h=0 PEs.
        assert!(arr.v_pushes > 0);
        assert!(arr.h_pushes > 0);
        arr.drain_pass(&ctx);
        // each PE performed 9 MACs
        assert_eq!(arr.total_macs(), 4 * 9);
    }

    #[test]
    fn edge_tile_leaves_pes_idle() {
        let mut arr = PeArray::new(4, 4, 9, 32);
        let ctx = PassCtx {
            in_h: 2,
            in_w: 3,
            ..simple_ctx()
        };
        let kernel = vec![Q88::ONE; 9];
        arr.load_pass(&ctx, &kernel, |_, _| Some(Q88::ONE));
        arr.compute_pass(&ctx);
        assert_eq!(arr.total_macs(), (2 * 3) * 9, "only 6 of 16 PEs active");
    }

    #[test]
    #[should_panic(expected = "overlap FIFO overflow")]
    fn undersized_fifo_is_a_design_error() {
        // Failure injection: a FIFO too small for the overlap traffic
        // must fail loudly (hardware would deadlock/drop silently —
        // the simulator turns that into a panic the sizing tests and
        // DSE can rely on).
        let mut arr = PeArray::new(2, 2, 9, 1); // capacity 1
        let ctx = PassCtx {
            s: 1, // S=1: every activation overlaps heavily
            ..simple_ctx()
        };
        let kernel = vec![Q88::ONE; 9];
        arr.load_pass(&ctx, &kernel, |_, _| Some(Q88::ONE));
        let _ = arr.compute_pass(&ctx);
    }

    #[test]
    fn out_of_tile_products_spill() {
        // tile at origin (2,2) of a 4x4 input: products owned by
        // activations in the previous tile must spill.
        let mut arr = PeArray::new(2, 2, 9, 32);
        let ctx = PassCtx {
            h0: 2,
            w0: 2,
            in_h: 4,
            in_w: 4,
            ..simple_ctx()
        };
        let kernel = vec![Q88::ONE; 9];
        arr.load_pass(&ctx, &kernel, |_, _| Some(Q88::ONE));
        let ext = arr.compute_pass(&ctx);
        let spills = ext
            .iter()
            .filter(|r| matches!(r, Routed::Spill(_)))
            .count();
        assert!(spills > 0, "boundary overlaps leave the tile");
    }
}
