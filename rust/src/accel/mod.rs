//! The paper's system contribution: a uniform 2D/3D deconvolution
//! accelerator (Fig. 2), modelled at two fidelity tiers.
//!
//! * [`functional`] — an event-level simulation of the PE mesh on real
//!   Q8.8 data: every product, every overlap-FIFO transfer, every
//!   adder-tree reduction. Bit-exact against
//!   [`crate::func::deconv_q`]; used on small layers and in tests.
//! * [`timing`] — an analytic cycle model driven by the *same*
//!   schedule enumeration ([`schedule`]). Used for the full benchmark
//!   layers of Fig. 6/7 (simulating 3D-GAN product-by-product would be
//!   pointless: the functional tier proves the timing tier's cycle
//!   arithmetic on small shapes, and cycles are additive over the
//!   schedule).
//!
//! Components map 1:1 onto Fig. 2: [`pe::Pe`] (Ra/Rw register files,
//! multiplier, overlap FIFOs), [`pe_array::PeArray`] (T_r × T_c PEs),
//! [`mesh::Mesh`] (T_m groups of T_n × T_z arrays), [`adder_tree`]
//! (T_m·T_c·T_z·log₂T_n adders), [`buffers`] (input/weight/output
//! on-chip buffers), [`memory`] (DDR + memory controller).

pub mod adder_tree;
pub mod buffers;
pub mod config;
pub mod dse;
pub mod fifo;
pub mod functional;
pub mod kernel;
pub mod mapping;
pub mod memory;
pub mod mesh;
pub mod metrics;
pub mod oom;
pub mod pe;
pub mod plan;
pub mod pe_array;
pub mod schedule;
pub mod timing;

pub use config::AccelConfig;
pub use kernel::{KernelChoice, KernelSelection};
pub use mapping::Mapping;
pub use metrics::{BoundBy, LayerMetrics, NetworkMetrics};
pub use schedule::Schedule;

use crate::dcnn::LayerSpec;

/// Simulate one layer on the accelerator (timing tier, batch from
/// `cfg.batch`). The one-call entry point used by benches and the CLI.
pub fn simulate_layer(cfg: &AccelConfig, layer: &LayerSpec) -> LayerMetrics {
    timing::simulate(cfg, layer)
}

/// Simulate a whole network layer-by-layer (isolated layers, no
/// cross-layer overlap — the Fig. 6/7 baseline).
pub fn simulate_network(cfg: &AccelConfig, net: &crate::dcnn::Network) -> NetworkMetrics {
    let layers = net.layers.iter().map(|l| timing::simulate(cfg, l)).collect();
    NetworkMetrics::new(net.name, layers)
}

/// Simulate a whole network through the graph compiler: build the IR,
/// lower it, compile a [`crate::graph::NetworkPlan`] (inter-layer
/// buffer reuse + per-node tiling) and execute it with cross-layer
/// prefetch overlap. End-to-end latency/TOPS/traffic at network
/// granularity. Errors if the layer chain does not compose.
pub fn simulate_network_pipelined(
    cfg: &AccelConfig,
    net: &crate::dcnn::Network,
) -> Result<crate::graph::NetworkRunMetrics, String> {
    let plan = crate::graph::compile_network(cfg, net)?;
    Ok(crate::graph::simulate_plan(&plan))
}
