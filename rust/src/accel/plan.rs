//! Schedule explainer: renders the full execution plan the simulator
//! derives for a layer — blocking factors, residency decisions,
//! traffic breakdown, cycle budget — as human-readable text. The
//! `udcnn plan` subcommand exposes it; it is the first thing to look
//! at when a layer's utilization surprises you.

use crate::dcnn::LayerSpec;

use super::buffers::{OperandPlace, Residency};
use super::config::AccelConfig;
use super::memory::DdrModel;
use super::schedule::Schedule;
use super::timing;

fn place(p: OperandPlace) -> &'static str {
    match p {
        OperandPlace::Resident => "resident",
        OperandPlace::Streamed => "streamed",
    }
}

/// Render the execution plan for one layer.
pub fn explain(cfg: &AccelConfig, layer: &LayerSpec) -> String {
    let sched = Schedule::new(cfg, layer);
    let res = Residency::plan(cfg, layer, &sched);
    let ddr = DdrModel::from_config(cfg);
    let m = timing::simulate_with_schedule(cfg, layer, &sched);
    let mut s = String::new();
    let p = |s: &mut String, line: String| {
        s.push_str(&line);
        s.push('\n');
    };

    p(&mut s, format!("plan for {layer}"));
    p(&mut s, format!(
        "  mesh: Tm={} Tn={} Tz={} Tr={} Tc={} ({} PEs @ {} MHz), batch {}",
        cfg.tm, cfg.tn, cfg.tz, cfg.tr, cfg.tc, cfg.total_pes(), cfg.freq_mhz, cfg.batch
    ));
    p(&mut s, format!(
        "  mapping: {} | chan_par={} depth_par={} | {} MACs/activation{}",
        layer.dims,
        sched.mapping.chan_par,
        sched.mapping.depth_par,
        sched.mapping.macs_per_activation,
        if sched.mapping.fifo_d_enabled { " | FIFO-D on" } else { " | FIFO-D off" },
    ));
    p(&mut s, format!(
        "  blocking: oc {} x ic {} x depth {} x tiles {}x{}  => {} passes",
        sched.oc_blocks, sched.ic_blocks, sched.d_blocks, sched.h_tiles, sched.w_tiles,
        sched.total_passes(),
    ));
    p(&mut s, format!(
        "  residency: weights {} ({:.1} KiB) | inputs {} | outputs {}",
        place(res.weights),
        layer.weight_elems() as f64 * cfg.elem_bytes() as f64 / 1024.0,
        place(res.inputs),
        place(res.outputs),
    ));
    p(&mut s, format!(
        "  DDR traffic: weights {:.1} KiB + inputs {:.1} KiB + outputs {:.1} KiB = {:.2} MiB ({} cycles)",
        res.weight_bytes as f64 / 1024.0,
        res.input_bytes as f64 / 1024.0,
        res.output_bytes as f64 / 1024.0,
        res.dram_bytes as f64 / (1024.0 * 1024.0),
        ddr.transfer_cycles(res.dram_bytes, cfg.freq_mhz),
    ));
    p(&mut s, format!(
        "  cycles: compute {} (pass {} + fill {} + drain {}) vs memory {} -> total {} ({}-bound)",
        sched.compute_cycles(cfg),
        sched.pass_cycles(),
        sched.fill_cycles(cfg),
        sched.drain_cycles(cfg),
        m.memory_cycles,
        m.total_cycles,
        m.bound_by,
    ));
    p(&mut s, format!(
        "  result: {:.3} ms/batch | util {:.1}% | {:.2} effective TOPS | {:.2} useful TOPS | {:.1} GB/s",
        m.time_s() * 1e3,
        100.0 * m.pe_utilization(),
        m.effective_tops(cfg),
        m.useful_tops(),
        m.dram_gbps(),
    ));
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dcnn::zoo;

    #[test]
    fn explains_compute_bound_layer() {
        let cfg = AccelConfig::paper_2d();
        let text = explain(&cfg, &zoo::dcgan().layers[0]);
        assert!(text.contains("compute-bound"), "{text}");
        assert!(text.contains("oc 256 x ic 16"));
        assert!(text.contains("weights streamed"));
        assert!(text.contains("FIFO-D off"));
    }

    #[test]
    fn explains_memory_bound_layer() {
        let cfg = AccelConfig::paper_2d();
        let text = explain(&cfg, &zoo::dcgan().layers[3]);
        assert!(text.contains("memory-bound"), "{text}");
        assert!(text.contains("weights resident"));
    }

    #[test]
    fn explains_3d_layer() {
        let cfg = AccelConfig::paper_3d();
        let text = explain(&cfg, &zoo::gan3d().layers[0]);
        assert!(text.contains("FIFO-D on"));
        assert!(text.contains("27 MACs/activation"));
    }

    #[test]
    fn totals_match_timing_tier() {
        // the explainer must never drift from the simulator
        let cfg = AccelConfig::paper_3d();
        for layer in &zoo::vnet().layers {
            let text = explain(&cfg, layer);
            let m = timing::simulate(&cfg, layer);
            assert!(
                text.contains(&format!("total {}", m.total_cycles)),
                "{}: explainer drifted",
                layer.name
            );
        }
    }
}
