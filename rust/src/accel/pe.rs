//! One processing element (Fig. 2, right).
//!
//! A PE holds one input activation in its `Ra` register file, the
//! current `K^d` kernel in `Rw`, multiplies them (one product per
//! cycle), and accumulates into a local result block. Products that
//! belong to a *neighbouring* PE's output block (the overlap of
//! Fig. 5) are emitted as [`OverlapMsg`]s; incoming overlaps arrive
//! through the FIFO-V / FIFO-H / FIFO-D queues and are added into the
//! local block ("conditionally added with the data from the Overlap
//! FIFOs").

use crate::fixed::{Acc48, Q88};

use super::fifo::{Fifo, OverlapDir};

/// An overlap product in flight between PEs: the *global* output
/// coordinate it lands on plus the wide (Q16.16) product value.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OverlapMsg {
    /// Global output coordinates (z, y, x) over the full Eq. (1) extent.
    pub oz: usize,
    /// Global output row.
    pub oy: usize,
    /// Global output column.
    pub ox: usize,
    /// The Q16.16 product.
    pub wide: i32,
}

/// Processing element state.
#[derive(Clone, Debug)]
pub struct Pe {
    /// Ra register: the resident activation (None when the PE is idle
    /// in an edge pass — mesh occupancy accounting).
    pub ra: Option<Q88>,
    /// Rw register file: the resident `K^d` kernel.
    pub rw: Vec<Q88>,
    /// Local result block, one 48-bit accumulator per kernel offset.
    pub local: Vec<Acc48>,
    /// Incoming overlap FIFOs.
    pub fifo_v: Fifo<OverlapMsg>,
    /// Incoming horizontal overlap FIFO.
    pub fifo_h: Fifo<OverlapMsg>,
    /// Incoming depth overlap FIFO.
    pub fifo_d: Fifo<OverlapMsg>,
    /// Lifetime MAC counter.
    pub macs: u64,
}

impl Pe {
    /// `k_vol` = kernel volume; `fifo_cap` sizes each overlap FIFO.
    pub fn new(k_vol: usize, fifo_cap: usize) -> Pe {
        Pe {
            ra: None,
            rw: vec![Q88::ZERO; k_vol],
            local: vec![Acc48::ZERO; k_vol],
            fifo_v: Fifo::new(fifo_cap),
            fifo_h: Fifo::new(fifo_cap),
            fifo_d: Fifo::new(fifo_cap),
            macs: 0,
        }
    }

    /// Load a new activation + kernel; clears the local block.
    pub fn load(&mut self, activation: Option<Q88>, kernel: &[Q88]) {
        debug_assert_eq!(kernel.len(), self.rw.len());
        self.ra = activation;
        self.rw.copy_from_slice(kernel);
        for a in &mut self.local {
            *a = Acc48::ZERO;
        }
    }

    /// Multiply the resident activation by kernel element `k_idx`,
    /// returning the wide product (caller routes it). `None` if idle.
    #[inline]
    pub fn multiply(&mut self, k_idx: usize) -> Option<i32> {
        let a = self.ra?;
        self.macs += 1;
        Some(a.wide_mul(self.rw[k_idx]))
    }

    /// Accumulate a wide product into the local block at `k_idx`.
    #[inline]
    pub fn accumulate_local(&mut self, k_idx: usize, wide: i32) {
        self.local[k_idx].add_wide(wide);
    }

    /// Push an incoming overlap message (hardware: a neighbour writes
    /// into this PE's FIFO).
    pub fn receive(&mut self, dir: OverlapDir, msg: OverlapMsg) -> Result<(), super::fifo::FifoFull> {
        match dir {
            OverlapDir::Vertical => self.fifo_v.push(msg),
            OverlapDir::Horizontal => self.fifo_h.push(msg),
            OverlapDir::Depth => self.fifo_d.push(msg),
        }
    }

    /// Drain all FIFOs, handing each message to `sink` (the mesh
    /// resolves global coordinates to a local offset or forwards to
    /// the output buffer).
    pub fn drain_fifos(&mut self, mut sink: impl FnMut(OverlapMsg)) {
        for m in self.fifo_v.drain_all() {
            sink(m);
        }
        for m in self.fifo_h.drain_all() {
            sink(m);
        }
        for m in self.fifo_d.drain_all() {
            sink(m);
        }
    }

    /// Total overlap pushes this PE has received.
    pub fn overlap_pushes(&self) -> u64 {
        self.fifo_v.total_pushes + self.fifo_h.total_pushes + self.fifo_d.total_pushes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn multiply_accumulate_round_trip() {
        let mut pe = Pe::new(9, 16);
        let kernel: Vec<Q88> = (0..9).map(|i| Q88::from_f32(i as f32 * 0.1)).collect();
        pe.load(Some(Q88::from_f32(2.0)), &kernel);
        let w = pe.multiply(3).unwrap();
        pe.accumulate_local(3, w);
        let got = pe.local[3].to_q88().to_f32();
        let want = (Q88::from_f32(2.0).to_f32()) * kernel[3].to_f32();
        assert!((got - want).abs() < 1.0 / 256.0);
        assert_eq!(pe.macs, 1);
    }

    #[test]
    fn idle_pe_multiplies_nothing() {
        let mut pe = Pe::new(9, 16);
        pe.load(None, &vec![Q88::ONE; 9]);
        assert_eq!(pe.multiply(0), None);
        assert_eq!(pe.macs, 0);
    }

    #[test]
    fn load_clears_local_block() {
        let mut pe = Pe::new(4, 8);
        pe.load(Some(Q88::ONE), &vec![Q88::ONE; 4]);
        let w = pe.multiply(0).unwrap();
        pe.accumulate_local(0, w);
        assert_ne!(pe.local[0], Acc48::ZERO);
        pe.load(Some(Q88::ONE), &vec![Q88::ONE; 4]);
        assert_eq!(pe.local[0], Acc48::ZERO);
    }

    #[test]
    fn receive_and_drain() {
        let mut pe = Pe::new(4, 8);
        let m = OverlapMsg {
            oz: 0,
            oy: 1,
            ox: 2,
            wide: 77,
        };
        pe.receive(OverlapDir::Vertical, m).unwrap();
        pe.receive(OverlapDir::Depth, m).unwrap();
        let mut got = Vec::new();
        pe.drain_fifos(|m| got.push(m));
        assert_eq!(got.len(), 2);
        assert_eq!(pe.overlap_pushes(), 2);
        assert!(pe.fifo_v.is_empty() && pe.fifo_d.is_empty());
    }
}
