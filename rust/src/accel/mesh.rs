//! The full computation engine: `T_m` groups × `T_n × T_z` PE arrays,
//! walked by the [`Schedule`], reduced by the adder trees, accumulated
//! into the output buffer — the functional tier's core.
//!
//! Unifies 2D and 3D exactly as §IV-C describes: a 2D layer is run
//! with `kd = 1`, depth folded out, and the `T_z` arrays re-purposed
//! as extra channel parallelism (FIFO-D never fires — asserted in
//! tests).

use crate::dcnn::{Dims, LayerSpec};
use crate::fixed::{Acc48, Q88};
use crate::tensor::{Volume, WeightsOIDHW};
use crate::util::ceil_log2;

use super::config::AccelConfig;
use super::fifo::OverlapDir;
use super::pe_array::{owner_index, PassCtx, PeArray, Routed};
use super::schedule::Schedule;

/// Event-level statistics from a functional run.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FunctionalStats {
    /// Compute cycles, incremented with the same granularity the
    /// timing tier charges (asserted equal in the cross-check test).
    pub compute_cycles: u64,
    /// Useful MACs performed.
    pub macs: u64,
    /// Products routed through FIFO-V.
    pub fifo_v_pushes: u64,
    /// Products routed through FIFO-H.
    pub fifo_h_pushes: u64,
    /// Products routed through FIFO-D.
    pub fifo_d_pushes: u64,
    /// Products accumulated directly in the output buffer because the
    /// owner activation was not resident in the pass.
    pub spills: u64,
    /// High-water mark of occupancy across all FIFOs.
    pub max_fifo_occupancy: usize,
    /// Passes executed.
    pub passes: u64,
}

/// The functional mesh.
pub struct Mesh {
    cfg: AccelConfig,
    sched: Schedule,
    /// Arrays indexed `[m][n][z]` (flattened).
    arrays: Vec<PeArray>,
    /// Event statistics of the run.
    pub stats: FunctionalStats,
}

impl Mesh {
    /// Build the mesh for one layer (requires `cfg.batch == 1`).
    pub fn new(cfg: &AccelConfig, layer: &LayerSpec) -> Mesh {
        assert_eq!(
            cfg.batch, 1,
            "functional tier simulates one inference at a time"
        );
        let sched = Schedule::new(cfg, layer);
        let k_vol = layer.kernel_volume();
        // FIFO sized for the worst case: all K^d products of one
        // activation overlap (S=1).
        let fifo_cap = k_vol * 4 + 8;
        let n_arrays = cfg.tm * cfg.tn * cfg.tz;
        Mesh {
            cfg: cfg.clone(),
            sched,
            arrays: (0..n_arrays)
                .map(|_| PeArray::new(cfg.tr, cfg.tc, k_vol, fifo_cap))
                .collect(),
            stats: FunctionalStats::default(),
        }
    }

    #[inline]
    fn array_index(&self, m: usize, n: usize, z: usize) -> usize {
        (m * self.cfg.tn + n) * self.cfg.tz + z
    }

    /// Run a full layer. `input` is `C×D×H×W` (D = 1 for 2D layers);
    /// `weights` are `O×I×Kd×Kh×Kw` (`Kd = 1` for 2D). Returns the
    /// output over the **full** Eq. (1) extent (crop is the caller's
    /// write-back step, as in the hardware).
    pub fn run(
        &mut self,
        layer: &LayerSpec,
        input: &Volume<Q88>,
        weights: &WeightsOIDHW<Q88>,
    ) -> Volume<Q88> {
        assert_eq!(input.c, layer.in_c);
        assert_eq!(input.d, layer.in_d);
        let kd = if layer.dims == Dims::D3 { layer.k } else { 1 };
        assert_eq!(weights.kd, kd, "2D layers carry kd=1 weights");

        let out_d = layer.out_full_d();
        let out_h = layer.out_full_h();
        let out_w = layer.out_full_w();
        let mut grid: Vec<Acc48> = vec![Acc48::ZERO; layer.out_c * out_d * out_h * out_w];

        let sched = self.sched.clone();
        let mapping = sched.mapping;
        let cpa = mapping.cycles_per_activation() as u64;
        let (tr, tc, tn) = (self.cfg.tr, self.cfg.tc, self.cfg.tn);

        for oc_blk in 0..sched.oc_blocks {
            // weight-barrier pipeline refill
            self.stats.compute_cycles += tc as u64;
            for ic_blk in 0..sched.ic_blocks {
                for d_blk in 0..sched.d_blocks {
                    let d_lo = d_blk * mapping.depth_par;
                    let d_hi = (d_lo + mapping.depth_par).min(layer.in_d);
                    for ht in 0..sched.h_tiles {
                        for wt in 0..sched.w_tiles {
                            self.run_one_pass(
                                layer,
                                input,
                                weights,
                                &mut grid,
                                (out_d, out_h, out_w),
                                oc_blk,
                                ic_blk,
                                d_lo,
                                d_hi,
                                ht * tr,
                                wt * tc,
                                kd,
                            );
                            self.stats.compute_cycles += cpa;
                            self.stats.passes += 1;
                        }
                    }
                }
            }
            // adder-tree drain per accumulation group
            self.stats.compute_cycles += sched.d_blocks as u64 * ceil_log2(tn) as u64;
        }

        // Collect statistics from the hardware structures.
        let mut macs = 0;
        let mut v = 0;
        let mut h = 0;
        let mut occ = 0;
        for arr in &self.arrays {
            v += arr.v_pushes;
            h += arr.h_pushes;
            macs += arr.total_macs();
            occ = occ.max(arr.max_fifo_occupancy());
        }
        self.stats.macs = macs;
        self.stats.fifo_v_pushes = v;
        self.stats.fifo_h_pushes = h;
        self.stats.max_fifo_occupancy = occ;

        Volume::from_vec(
            layer.out_c,
            out_d,
            out_h,
            out_w,
            grid.into_iter().map(|a| a.to_q88()).collect(),
        )
    }

    #[allow(clippy::too_many_arguments)]
    fn run_one_pass(
        &mut self,
        layer: &LayerSpec,
        input: &Volume<Q88>,
        weights: &WeightsOIDHW<Q88>,
        grid: &mut [Acc48],
        out_ext: (usize, usize, usize),
        oc_blk: usize,
        ic_blk: usize,
        d_lo: usize,
        d_hi: usize,
        h0: usize,
        w0: usize,
        kd: usize,
    ) {
        let (out_d, out_h, out_w) = out_ext;
        let grid_at =
            |o: usize, z: usize, y: usize, x: usize| ((o * out_d + z) * out_h + y) * out_w + x;
        let mapping = self.sched.mapping;
        let (tm, tn, tz, tr, tc) = (
            self.cfg.tm,
            self.cfg.tn,
            self.cfg.tz,
            self.cfg.tr,
            self.cfg.tc,
        );
        let fold_2d = layer.dims == Dims::D2;
        let mk_ctx = |d: usize| PassCtx {
            d,
            h0,
            w0,
            in_d: layer.in_d,
            in_h: layer.in_h,
            in_w: layer.in_w,
            k: layer.k,
            kd,
            s: layer.s,
            d_lo,
            d_hi,
        };

        for m in 0..tm {
            let oc = oc_blk * tm + m;
            if oc >= layer.out_c {
                continue; // edge oc block: whole group idle
            }
            let mut depth_msgs: Vec<(usize, Routed)> = Vec::new(); // (n, routed)
            for n in 0..tn {
                for z in 0..tz {
                    // channel and depth plane this array serves
                    let chan = if fold_2d {
                        ic_blk * mapping.chan_par + z * tn + n
                    } else {
                        ic_blk * mapping.chan_par + n
                    };
                    let d = if fold_2d { 0 } else { d_lo + z };
                    let idx = self.array_index(m, n, z);
                    let active = chan < layer.in_c && (fold_2d || d < d_hi);
                    if !active {
                        let ctx = mk_ctx(d.min(layer.in_d - 1));
                        self.arrays[idx].load_pass(&ctx, weights.kernel(0, 0), |_, _| None);
                        continue;
                    }
                    let ctx = mk_ctx(d);
                    let kernel = weights.kernel(oc, chan);
                    self.arrays[idx]
                        .load_pass(&ctx, kernel, |hh, ww| Some(input.at(chan, d, hh, ww)));
                    let external = self.arrays[idx].compute_pass(&ctx);
                    for r in external {
                        depth_msgs.push((n, r));
                    }
                }
            }

            // Deliver depth overlaps to the adjacent plane's array
            // (same group, same channel slot) or spill to the grid.
            for (n, routed) in depth_msgs {
                match routed {
                    Routed::Internal => {}
                    Routed::Depth { target_d, msg } => {
                        debug_assert!(!fold_2d);
                        let tz_slot = target_d - d_lo;
                        debug_assert!(tz_slot < tz);
                        let idx = self.array_index(m, n, tz_slot);
                        let oh_own = owner_index(msg.oy, layer.k, layer.s, layer.in_h);
                        let ow_own = owner_index(msg.ox, layer.k, layer.s, layer.in_w);
                        let (r, c) = (oh_own - h0, ow_own - w0);
                        self.arrays[idx]
                            .pe_mut(r, c)
                            .receive(OverlapDir::Depth, msg)
                            .expect("FIFO-D overflow");
                        self.stats.fifo_d_pushes += 1;
                    }
                    Routed::Spill(msg) => {
                        grid[grid_at(oc, msg.oz, msg.oy, msg.ox)].add_wide(msg.wide);
                        self.stats.spills += 1;
                    }
                }
            }

            // Drain FIFOs, then adder-tree-reduce across T_n and
            // accumulate into the output grid.
            for z in 0..tz {
                let d = if fold_2d { 0 } else { d_lo + z };
                if !fold_2d && d >= d_hi {
                    continue;
                }
                for n in 0..tn {
                    let ctx = mk_ctx(d);
                    let idx = self.array_index(m, n, z);
                    self.arrays[idx].drain_pass(&ctx);
                }
                let k = layer.k;
                for r in 0..tr {
                    for c in 0..tc {
                        let h = h0 + r;
                        let w = w0 + c;
                        if h >= layer.in_h || w >= layer.in_w {
                            continue;
                        }
                        for kz in 0..kd {
                            for ky in 0..k {
                                for kx in 0..k {
                                    let k_idx = (kz * k + ky) * k + kx;
                                    // adder tree: binary reduction over
                                    // T_n partials. Integer adds are
                                    // associative, so a running sum is
                                    // bit-identical to the tree
                                    // (asserted in adder_tree tests);
                                    // no per-element Vec (§Perf).
                                    let mut sum = Acc48::ZERO;
                                    for n in 0..tn {
                                        sum.add(
                                            self.arrays[self.array_index(m, n, z)].pe(r, c).local
                                                [k_idx],
                                        );
                                    }
                                    if sum != Acc48::ZERO {
                                        let oz = if kd > 1 { d * layer.s + kz } else { 0 };
                                        let oy = h * layer.s + ky;
                                        let ox = w * layer.s + kx;
                                        grid[grid_at(oc, oz, oy, ox)].add(sum);
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dcnn::zoo;
    use crate::dcnn::{LayerData, LayerDataQ};
    use crate::func::deconv_q::{deconv2d_iom_q, deconv3d_iom_q};
    use crate::tensor::FeatureMap;

    /// Promote 2D data to the unified D=1 / kd=1 representation.
    pub(crate) fn promote_2d(
        input: &FeatureMap<Q88>,
        w: &crate::tensor::WeightsOIHW<Q88>,
    ) -> (Volume<Q88>, WeightsOIDHW<Q88>) {
        let vol = Volume::from_vec(input.c, 1, input.h, input.w, input.data().to_vec());
        let w3 = WeightsOIDHW::from_vec(w.o, w.i, 1, w.kh, w.kw, w.data().to_vec());
        (vol, w3)
    }

    #[test]
    fn mesh_matches_golden_2d() {
        let spec = &zoo::tiny_2d().layers[0]; // 4ch 4x4 -> 4ch
        let q = LayerData::synth(spec, 11).quantize();
        let (input, weights) = match &q {
            LayerDataQ::D2 { input, weights } => (input, weights),
            _ => unreachable!(),
        };
        let golden = deconv2d_iom_q(input, weights, spec.s);
        let (vol, w3) = promote_2d(input, weights);
        let cfg = AccelConfig::tiny(2, 2, 1, 2, 2);
        let mut mesh = Mesh::new(&cfg, spec);
        let out = mesh.run(spec, &vol, &w3);
        assert_eq!(out.c, golden.c);
        for o in 0..out.c {
            for y in 0..out.h {
                for x in 0..out.w {
                    assert_eq!(
                        out.at(o, 0, y, x),
                        golden.at(o, y, x),
                        "mismatch at ({o},{y},{x})"
                    );
                }
            }
        }
        assert!(mesh.stats.macs > 0);
        assert_eq!(mesh.stats.fifo_d_pushes, 0, "FIFO-D disabled in 2D mode");
    }

    #[test]
    fn mesh_matches_golden_3d() {
        let spec = &zoo::tiny_3d().layers[0]; // 4ch 2^3 -> 4ch
        let q = LayerData::synth(spec, 13).quantize();
        let (input, weights) = match &q {
            LayerDataQ::D3 { input, weights } => (input, weights),
            _ => unreachable!(),
        };
        let golden = deconv3d_iom_q(input, weights, spec.s);
        let cfg = AccelConfig::tiny(2, 2, 2, 2, 2);
        let mut mesh = Mesh::new(&cfg, spec);
        let out = mesh.run(spec, input, weights);
        for o in 0..out.c {
            for z in 0..out.d {
                for y in 0..out.h {
                    for x in 0..out.w {
                        assert_eq!(
                            out.at(o, z, y, x),
                            golden.at(o, z, y, x),
                            "mismatch at ({o},{z},{y},{x})"
                        );
                    }
                }
            }
        }
        assert!(
            mesh.stats.fifo_d_pushes > 0,
            "3D runs move depth overlaps through FIFO-D"
        );
    }

    #[test]
    fn mac_count_equals_useful_macs() {
        let spec = &zoo::tiny_2d().layers[0];
        let q = LayerData::synth(spec, 3).quantize();
        let (input, weights) = match &q {
            LayerDataQ::D2 { input, weights } => (input, weights),
            _ => unreachable!(),
        };
        let (vol, w3) = promote_2d(input, weights);
        let cfg = AccelConfig::tiny(2, 4, 1, 4, 4);
        let mut mesh = Mesh::new(&cfg, spec);
        mesh.run(spec, &vol, &w3);
        assert_eq!(mesh.stats.macs, spec.op_counts().useful_macs);
    }

    #[test]
    fn cycles_match_timing_tier() {
        // the cross-check that licenses the timing tier for the paper
        // figures
        for (spec, cfg) in [
            (&zoo::tiny_2d().layers[0], AccelConfig::tiny(2, 2, 1, 2, 2)),
            (&zoo::tiny_3d().layers[0], AccelConfig::tiny(2, 2, 2, 2, 2)),
        ] {
            let sched = Schedule::new(&cfg, spec);
            let q = LayerData::synth(spec, 3).quantize();
            let mut mesh = Mesh::new(&cfg, spec);
            match &q {
                LayerDataQ::D2 { input, weights } => {
                    let (vol, w3) = promote_2d(input, weights);
                    mesh.run(spec, &vol, &w3);
                }
                LayerDataQ::D3 { input, weights } => {
                    mesh.run(spec, input, weights);
                }
            }
            assert_eq!(
                mesh.stats.compute_cycles,
                sched.compute_cycles(&cfg),
                "{}: functional cycles == analytic cycles",
                spec.name
            );
        }
    }
}
