//! High-level entry points for the functional (event-level) tier.

use crate::dcnn::{Dims, LayerSpec};
use crate::fixed::Q88;
use crate::func::uniform;
use crate::tensor::{FeatureMap, Volume, WeightsOIDHW, WeightsOIHW};

use super::config::AccelConfig;
use super::mesh::{FunctionalStats, Mesh};

/// Result of a functional layer run: cropped output + event stats.
pub struct FunctionalRun2d {
    /// Cropped (`I·S`) output map.
    pub output: FeatureMap<Q88>,
    /// Event statistics of the run.
    pub stats: FunctionalStats,
}

/// Result of a functional 3D layer run.
pub struct FunctionalRun3d {
    /// Cropped (`I·S`) output volume.
    pub output: Volume<Q88>,
    /// Event statistics of the run.
    pub stats: FunctionalStats,
}

/// Run a 2D layer through the functional mesh; returns the cropped
/// (`I·S`) output, like the hardware write-back. The layer is folded
/// onto the uniform depth-1 representation (§IV-C) before it enters
/// the mesh — the same fold the compute kernels use.
pub fn run_layer_2d(
    cfg: &AccelConfig,
    layer: &LayerSpec,
    input: &FeatureMap<Q88>,
    weights: &WeightsOIHW<Q88>,
) -> FunctionalRun2d {
    assert_eq!(layer.dims, Dims::D2);
    let vol = input.to_volume();
    let w3 = weights.to_oidhw();
    let mut mesh = Mesh::new(cfg, layer);
    let full = mesh.run(layer, &vol, &w3);
    let output = uniform::crop(&full, 1, layer.out_h(), layer.out_w()).into_feature_map();
    FunctionalRun2d {
        output,
        stats: mesh.stats,
    }
}

/// Run a 3D layer through the functional mesh; returns the cropped
/// (`I·S`) output volume.
pub fn run_layer_3d(
    cfg: &AccelConfig,
    layer: &LayerSpec,
    input: &Volume<Q88>,
    weights: &WeightsOIDHW<Q88>,
) -> FunctionalRun3d {
    assert_eq!(layer.dims, Dims::D3);
    let mut mesh = Mesh::new(cfg, layer);
    let full = mesh.run(layer, input, weights);
    let output = uniform::crop(&full, layer.out_d(), layer.out_h(), layer.out_w());
    FunctionalRun3d {
        output,
        stats: mesh.stats,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dcnn::{zoo, LayerData, LayerDataQ};
    use crate::func::deconv_q::{crop_2d_q, crop_3d_q, deconv2d_iom_q, deconv3d_iom_q};

    #[test]
    fn cropped_2d_matches_golden() {
        let spec = &zoo::tiny_2d().layers[1]; // 4ch 8x8 -> 2ch (multi-tile)
        let q = LayerData::synth(spec, 21).quantize();
        let (input, weights) = match &q {
            LayerDataQ::D2 { input, weights } => (input, weights),
            _ => unreachable!(),
        };
        let cfg = AccelConfig::tiny(2, 2, 1, 4, 4);
        let run = run_layer_2d(&cfg, spec, input, weights);
        let golden = crop_2d_q(
            &deconv2d_iom_q(input, weights, spec.s),
            spec.out_h(),
            spec.out_w(),
        );
        assert_eq!(run.output.data(), golden.data());
        assert!(run.stats.spills > 0, "multi-tile layers spill across tiles");
    }

    #[test]
    fn cropped_3d_matches_golden() {
        let spec = &zoo::tiny_3d().layers[1]; // 4ch 4^3 -> 2ch
        let q = LayerData::synth(spec, 22).quantize();
        let (input, weights) = match &q {
            LayerDataQ::D3 { input, weights } => (input, weights),
            _ => unreachable!(),
        };
        let cfg = AccelConfig::tiny(2, 2, 2, 2, 2);
        let run = run_layer_3d(&cfg, spec, input, weights);
        let golden = crop_3d_q(
            &deconv3d_iom_q(input, weights, spec.s),
            spec.out_d(),
            spec.out_h(),
            spec.out_w(),
        );
        assert_eq!(run.output.data(), golden.data());
    }

    #[test]
    fn uniform_architecture_2d_on_3d_config() {
        // §IV-C: the same (3D) operating point runs 2D nets, folding
        // T_z into channel parallelism.
        let spec = &zoo::tiny_2d().layers[0];
        let q = LayerData::synth(spec, 23).quantize();
        let (input, weights) = match &q {
            LayerDataQ::D2 { input, weights } => (input, weights),
            _ => unreachable!(),
        };
        let cfg3 = AccelConfig::tiny(2, 2, 2, 2, 2); // tz = 2, "3D" shape
        let run = run_layer_2d(&cfg3, spec, input, weights);
        let golden = crop_2d_q(
            &deconv2d_iom_q(input, weights, spec.s),
            spec.out_h(),
            spec.out_w(),
        );
        assert_eq!(run.output.data(), golden.data());
        assert_eq!(run.stats.fifo_d_pushes, 0, "FIFO-D stays disabled");
    }
}
