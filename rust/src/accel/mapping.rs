//! The uniform 2D/3D mapping (§IV-C).
//!
//! The same physical mesh (`T_m` groups × `T_n × T_z` arrays of
//! `T_r × T_c` PEs) serves both dimensionalities:
//!
//! * **3D**: `T_z` arrays cover `T_z` consecutive input depth planes of
//!   one input channel; `T_n` channels in parallel; FIFO-D carries the
//!   depth-direction overlaps between adjacent arrays.
//! * **2D**: there is no depth, so the `T_z` arrays are re-purposed as
//!   additional *channel* parallelism — `T_n · T_z` input feature maps
//!   in flight, FIFO-D disabled. "The dataflow in the PE arrays are
//!   identical when mapping 2D and 3D DCNNs" — only this fold changes.

use crate::dcnn::{Dims, LayerSpec};

use super::config::AccelConfig;

/// How a layer's loop nest is folded onto the physical mesh.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Mapping {
    /// Parallel input channels (`T_n` physical, × `T_z` folded for 2D).
    pub chan_par: usize,
    /// Parallel depth planes (`T_z` for 3D, 1 for 2D).
    pub depth_par: usize,
    /// Parallel output channels (`T_m`).
    pub out_par: usize,
    /// FIFO-D active? (3D only.)
    pub fifo_d_enabled: bool,
    /// MAC cycles one PE spends per activation (`K^d`).
    pub macs_per_activation: usize,
    /// Extra stall cycles per activation for depth-overlap exchange
    /// (3D only, `K²·(K−S)` products crossing FIFO-D per activation,
    /// one per cycle through the single FIFO-D port — see
    /// `AccelConfig::depth_overlap_stall`).
    pub stall_per_activation: usize,
}

impl Mapping {
    /// Fold `layer` onto `cfg`'s mesh.
    pub fn for_layer(cfg: &AccelConfig, layer: &LayerSpec) -> Mapping {
        let k = layer.k;
        match layer.dims {
            Dims::D2 => Mapping {
                chan_par: cfg.tn * cfg.tz,
                depth_par: 1,
                out_par: cfg.tm,
                fifo_d_enabled: false,
                macs_per_activation: k * k,
                stall_per_activation: 0,
            },
            Dims::D3 => {
                let stall = if cfg.depth_overlap_stall && layer.k > layer.s {
                    k * k * (k - layer.s)
                } else {
                    0
                };
                Mapping {
                    chan_par: cfg.tn,
                    depth_par: cfg.tz,
                    out_par: cfg.tm,
                    fifo_d_enabled: true,
                    macs_per_activation: k * k * k,
                    stall_per_activation: stall,
                }
            }
        }
    }

    /// Cycles one PE needs to fully process one resident activation.
    pub fn cycles_per_activation(&self) -> usize {
        self.macs_per_activation + self.stall_per_activation
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dcnn::zoo;

    #[test]
    fn mapping_2d_folds_tz_into_channels() {
        let cfg = AccelConfig::paper_2d();
        let layer = &zoo::dcgan().layers[0];
        let m = Mapping::for_layer(&cfg, layer);
        assert_eq!(m.chan_par, 64); // tn=64 · tz=1
        assert_eq!(m.depth_par, 1);
        assert!(!m.fifo_d_enabled);
        assert_eq!(m.macs_per_activation, 9);
        assert_eq!(m.stall_per_activation, 0);

        // Running a 2D net on the 3D operating point still folds T_z.
        let cfg3 = AccelConfig::paper_3d();
        let m = Mapping::for_layer(&cfg3, layer);
        assert_eq!(m.chan_par, 64); // 16 · 4 — same parallelism, §IV-C
    }

    #[test]
    fn mapping_3d_uses_depth() {
        let cfg = AccelConfig::paper_3d();
        let layer = &zoo::gan3d().layers[0];
        let m = Mapping::for_layer(&cfg, layer);
        assert_eq!(m.chan_par, 16);
        assert_eq!(m.depth_par, 4);
        assert!(m.fifo_d_enabled);
        assert_eq!(m.macs_per_activation, 27);
        assert_eq!(m.stall_per_activation, 0, "concurrent FIFO-D port by default");
        assert_eq!(m.cycles_per_activation(), 27);
    }

    #[test]
    fn stall_ablation_serializes_fifo_d() {
        let mut cfg = AccelConfig::paper_3d();
        cfg.depth_overlap_stall = true;
        let layer = &zoo::gan3d().layers[0];
        let m = Mapping::for_layer(&cfg, layer);
        assert_eq!(m.stall_per_activation, 9); // K²(K−S) = 9·1
        assert_eq!(m.cycles_per_activation(), 36);
    }
}
