//! The analytic timing tier.
//!
//! Consumes the [`Schedule`] enumeration, the [`Residency`] plan and
//! the [`DdrModel`], produces [`LayerMetrics`]. Compute and memory
//! streams are double-buffered (§IV-B "Writing back ... overlapped"),
//! so end-to-end time is `max(compute, memory)` plus the
//! un-overlappable first-tile load and last-tile store.
//!
//! The functional tier ([`super::functional`]) reproduces these cycle
//! counts event-by-event on small layers;
//! `rust/tests/integration_func_vs_sim.rs` pins the two tiers to each
//! other and `benches/fig6_*` consume this tier for the paper figures.

use crate::dcnn::{Dims, LayerSpec};

use super::buffers::Residency;
use super::config::AccelConfig;
use super::memory::DdrModel;
use super::metrics::{dense_equivalent_macs, BoundBy, LayerMetrics};
use super::schedule::Schedule;

/// Simulate one layer (batch folded in from `cfg.batch`).
pub fn simulate(cfg: &AccelConfig, layer: &LayerSpec) -> LayerMetrics {
    cfg.validate().expect("invalid accelerator config");
    let sched = Schedule::new(cfg, layer);
    simulate_with_schedule(cfg, layer, &sched)
}

/// Simulate one temporal tile of a layer: the depth slab of
/// `slab_frames` input frames (arriving chunk plus retained halo) a
/// streamed chunk runs this layer over (see [`crate::stream`]). The
/// slab is a sub-layer with `in_d = slab_frames` and otherwise
/// identical geometry, so blocking, residency and the DDR model all
/// apply unchanged; the streaming session sums these per-layer tile
/// metrics into its per-chunk cycle estimate. 2D layers are depth-1
/// already (one tile *is* the layer), and a slab covering the whole
/// depth is whole-volume execution.
pub fn simulate_chunk(cfg: &AccelConfig, layer: &LayerSpec, slab_frames: usize) -> LayerMetrics {
    if layer.dims == Dims::D2 || slab_frames >= layer.in_d {
        return simulate(cfg, layer);
    }
    let mut slab = layer.clone();
    slab.in_d = slab_frames.max(1);
    simulate(cfg, &slab)
}

/// Simulate with an explicit schedule (the DSE calls this directly).
pub fn simulate_with_schedule(
    cfg: &AccelConfig,
    layer: &LayerSpec,
    sched: &Schedule,
) -> LayerMetrics {
    let res = Residency::plan(cfg, layer, sched);
    let ddr = DdrModel::from_config(cfg);

    let compute_cycles = sched.compute_cycles(cfg);
    let memory_cycles = ddr.transfer_cycles(res.dram_bytes, cfg.freq_mhz);

    // Un-overlappable edges: the first input tile + first weight block
    // must land before compute starts; the last output slice drains
    // after compute ends.
    let eb = cfg.elem_bytes() as u64;
    let first_w = (sched.mapping.out_par * sched.mapping.chan_par * layer.kernel_volume())
        as u64
        * eb;
    let first_in =
        (sched.mapping.chan_par * sched.mapping.depth_par * cfg.tr * cfg.tc) as u64 * eb;
    let last_out = (sched.mapping.out_par * layer.out_spatial()) as u64 * eb;
    let edge_cycles = ddr.transfer_cycles(first_w + first_in, cfg.freq_mhz)
        + ddr.transfer_cycles(last_out, cfg.freq_mhz);

    let steady = compute_cycles.max(memory_cycles);
    let total_cycles = steady + edge_cycles;

    let bound_by = if memory_cycles > compute_cycles {
        BoundBy::Memory
    } else {
        BoundBy::Compute
    };

    LayerMetrics {
        layer_name: layer.name.clone(),
        compute_cycles,
        memory_cycles,
        total_cycles,
        ideal_mac_cycles: sched.ideal_mac_cycles(layer),
        total_pes: cfg.total_pes(),
        batch: cfg.batch,
        dense_macs: dense_equivalent_macs(layer),
        useful_macs: layer.op_counts().useful_macs,
        dram_bytes: res.dram_bytes,
        bound_by,
        freq_mhz: cfg.freq_mhz,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dcnn::zoo;

    #[test]
    fn dcgan_l1_is_compute_bound_and_saturated() {
        let cfg = AccelConfig::paper_2d();
        let m = simulate(&cfg, &zoo::dcgan().layers[0]);
        assert_eq!(m.bound_by, BoundBy::Compute);
        assert!(
            m.pe_utilization() > 0.9,
            "paper Fig. 6(a): util {:.3}",
            m.pe_utilization()
        );
    }

    #[test]
    fn dcgan_l4_is_memory_bound() {
        // "the fourth layers of DCGAN and GP-GAN are bottlenecked by
        // the memory access"
        let cfg = AccelConfig::paper_2d();
        let m = simulate(&cfg, &zoo::dcgan().layers[3]);
        assert_eq!(m.bound_by, BoundBy::Memory, "{m:?}");
        assert!(m.pe_utilization() < 0.9);
    }

    #[test]
    fn all_2d_layers_land_in_paper_band() {
        let cfg = AccelConfig::paper_2d();
        for net in [zoo::dcgan(), zoo::gp_gan()] {
            for layer in &net.layers {
                let m = simulate(&cfg, layer);
                let tops = m.effective_tops(&cfg);
                assert!(
                    (1.2..=3.6).contains(&tops),
                    "{}: {tops:.2} TOPS outside the (relaxed) paper band",
                    layer.name
                );
            }
        }
    }

    #[test]
    fn utilization_above_90_except_memory_bound() {
        for net in zoo::all_benchmarks() {
            let cfg = AccelConfig::paper_for(net.dims);
            for layer in &net.layers {
                let m = simulate(&cfg, layer);
                if m.bound_by == BoundBy::Compute && layer.out_c >= cfg.tm {
                    assert!(
                        m.pe_utilization() > 0.9,
                        "{}: util {:.3}",
                        layer.name,
                        m.pe_utilization()
                    );
                }
            }
        }
    }

    #[test]
    fn useful_tops_never_exceeds_peak() {
        for net in zoo::all_benchmarks() {
            let cfg = AccelConfig::paper_for(net.dims);
            for layer in &net.layers {
                let m = simulate(&cfg, layer);
                assert!(
                    m.useful_tops() <= cfg.peak_tops() + 1e-9,
                    "{}: useful {:.3} > peak {:.3}",
                    layer.name,
                    m.useful_tops(),
                    cfg.peak_tops()
                );
            }
        }
    }

    #[test]
    fn effective_exceeds_useful_by_sparsity_factor() {
        let cfg = AccelConfig::paper_2d();
        let m = simulate(&cfg, &zoo::dcgan().layers[2]);
        let ratio = m.effective_tops(&cfg) / m.useful_tops();
        assert!((ratio - 4.0).abs() < 1e-6, "2D dense/useful = S² = 4, got {ratio}");
    }

    #[test]
    fn gan3d_outperforms_2d_in_effective_tops() {
        // The paper: "the performance of 3D deconvolution on FPGA
        // outperforms that of 2D deconvolution."
        let cfg2 = AccelConfig::paper_2d();
        let cfg3 = AccelConfig::paper_3d();
        let t2 = simulate(&cfg2, &zoo::dcgan().layers[1]).effective_tops(&cfg2);
        let t3 = simulate(&cfg3, &zoo::gan3d().layers[1]).effective_tops(&cfg3);
        assert!(t3 > t2, "3D {t3:.2} vs 2D {t2:.2}");
    }

    #[test]
    fn batch_1_drops_utilization_on_weight_heavy_layers() {
        // Sanity for the DESIGN.md §5 claim: without batching, early
        // GAN layers are weight-bound and the paper's >90 % cannot hold.
        let mut cfg = AccelConfig::paper_2d();
        cfg.batch = 1;
        let m = simulate(&cfg, &zoo::dcgan().layers[0]);
        assert_eq!(m.bound_by, BoundBy::Memory);
        assert!(m.pe_utilization() < 0.5);
    }

    #[test]
    fn chunk_cycles_scale_with_slab_and_cap_at_whole() {
        let cfg = AccelConfig::paper_3d();
        let layer = &zoo::vnet().layers[0]; // in_d = 8
        let whole = simulate(&cfg, layer);
        let half = simulate_chunk(&cfg, layer, 4);
        let tiny = simulate_chunk(&cfg, layer, 1);
        assert!(tiny.total_cycles < half.total_cycles);
        assert!(half.total_cycles < whole.total_cycles);
        // a slab covering (or exceeding) the declared depth is the
        // whole layer; 2D layers are always one tile
        assert_eq!(simulate_chunk(&cfg, layer, 8).total_cycles, whole.total_cycles);
        assert_eq!(simulate_chunk(&cfg, layer, 99).total_cycles, whole.total_cycles);
        let cfg2 = AccelConfig::paper_2d();
        let l2 = &zoo::dcgan().layers[0];
        assert_eq!(
            simulate_chunk(&cfg2, l2, 1).total_cycles,
            simulate(&cfg2, l2).total_cycles
        );
    }

    #[test]
    fn total_cycles_ge_parts() {
        let cfg = AccelConfig::paper_3d();
        for layer in &zoo::vnet().layers {
            let m = simulate(&cfg, layer);
            assert!(m.total_cycles >= m.compute_cycles);
            assert!(m.total_cycles >= m.memory_cycles);
        }
    }
}
