//! The three on-chip buffers (§IV-A) and residency decisions.
//!
//! "We adopt three separate on-chip buffers to store input, output and
//! weight blocks." Buffer capacities determine how often each operand
//! class must be re-fetched from DDR; [`Residency::plan`] makes those
//! decisions for the timing tier and reports them in the metrics.

use crate::dcnn::LayerSpec;

use super::config::AccelConfig;
use super::schedule::Schedule;

/// Where an operand class lives for the duration of a layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OperandPlace {
    /// Fits entirely on-chip: fetched once per batch item (inputs) or
    /// once per layer (weights).
    Resident,
    /// Streamed block-by-block; may be re-fetched.
    Streamed,
}

/// The residency plan for one layer: drives DDR traffic accounting.
#[derive(Clone, Debug, PartialEq)]
pub struct Residency {
    /// Where the weight blocks live.
    pub weights: OperandPlace,
    /// Where the input blocks live.
    pub inputs: OperandPlace,
    /// Where the output blocks live.
    pub outputs: OperandPlace,
    /// Total DDR traffic in bytes for the whole layer (batch included).
    pub dram_bytes: u64,
    /// Breakdown for the report.
    pub weight_bytes: u64,
    /// Input bytes moved over DDR.
    pub input_bytes: u64,
    /// Output bytes moved over DDR.
    pub output_bytes: u64,
}

impl Residency {
    /// Plan operand residency and compute total DDR traffic.
    ///
    /// The scheduler picks between two loop orders per layer:
    ///
    /// * **Weight-resident** (all `N_o·N_c·K^d` weights fit the weight
    ///   buffer — typical for the activation-heavy late layers): the
    ///   spatial walk is outermost, every operand streams exactly once.
    /// * **Weight-streamed** (early GAN layers, where weights dominate):
    ///   the weight barrier is outermost; weights still transfer exactly
    ///   once (each `(oc, ic)` block serves the whole batch while
    ///   resident), and then:
    ///   - **Inputs**: fetched once per batch item if the whole input
    ///     fits the input buffer, else re-streamed per `oc` block;
    ///   - **Outputs**: accumulate on-chip per `oc` block; if the slice
    ///     fits, each output element is written once, else the
    ///     accumulation spills to DDR with a read-modify-write per
    ///     extra input-channel block.
    pub fn plan(cfg: &AccelConfig, layer: &LayerSpec, sched: &Schedule) -> Residency {
        let eb = cfg.elem_bytes() as u64;
        let w_total = layer.weight_elems() as u64 * eb;
        let in_total = layer.input_elems() as u64 * eb;
        // Output slice written per oc block (full Eq.1 extent is held
        // during accumulation; the crop happens on write-back).
        let out_slice = (sched.mapping.out_par * layer.out_full_spatial()) as u64 * eb;
        let out_total = layer.output_elems() as u64 * eb;

        let w_resident = w_total <= cfg.weight_buf_kib as u64 * 1024;
        if w_resident {
            // spatial-outer order: everything moves exactly once
            return Residency {
                weights: OperandPlace::Resident,
                inputs: OperandPlace::Streamed,
                outputs: OperandPlace::Streamed,
                dram_bytes: w_total + cfg.batch as u64 * (in_total + out_total),
                weight_bytes: w_total,
                input_bytes: cfg.batch as u64 * in_total,
                output_bytes: cfg.batch as u64 * out_total,
            };
        }

        let in_fits = in_total <= cfg.input_buf_kib as u64 * 1024;
        let out_fits = out_slice <= cfg.output_buf_kib as u64 * 1024;

        let input_traffic = if in_fits {
            cfg.batch as u64 * in_total
        } else {
            cfg.batch as u64 * in_total * sched.oc_blocks as u64
        };
        let output_traffic = if out_fits {
            cfg.batch as u64 * out_total
        } else {
            // spill: every extra ic block re-reads and re-writes the slice
            let rmw = (2 * (sched.ic_blocks as u64 - 1)).max(0) + 1;
            cfg.batch as u64 * out_total * rmw
        };

        Residency {
            weights: OperandPlace::Streamed,
            inputs: if in_fits {
                OperandPlace::Resident
            } else {
                OperandPlace::Streamed
            },
            outputs: if out_fits {
                OperandPlace::Resident
            } else {
                OperandPlace::Streamed
            },
            dram_bytes: w_total + input_traffic + output_traffic,
            weight_bytes: w_total,
            input_bytes: input_traffic,
            output_bytes: output_traffic,
        }
    }

    /// Kernel-aware residency: [`Residency::plan`] for the scatter
    /// kernel, or the gather variant for
    /// [`super::kernel::KernelChoice::Gather`].
    ///
    /// Gather is output-stationary: each *cropped* output element is
    /// produced by walking its contributor window and is written to
    /// DDR exactly once — there is no Eq.-(1) full-extent slice held
    /// during accumulation and no read-modify-write spill when the
    /// slice exceeds the output buffer. Weight and input traffic are
    /// unchanged (the same blocks stream through the same buffers).
    pub fn plan_kernel(
        cfg: &AccelConfig,
        layer: &LayerSpec,
        sched: &Schedule,
        kernel: super::kernel::KernelChoice,
    ) -> Residency {
        let scatter = Residency::plan(cfg, layer, sched);
        match kernel {
            super::kernel::KernelChoice::Scatter => scatter,
            super::kernel::KernelChoice::Gather => {
                let eb = cfg.elem_bytes() as u64;
                let out_once = cfg.batch as u64 * layer.output_elems() as u64 * eb;
                // The cropped per-oc-block slice a gather pass holds
                // on chip: out_par channels × cropped spatial extent.
                let out_slice =
                    (sched.mapping.out_par * layer.out_spatial()) as u64 * eb;
                let out_fits = out_slice <= cfg.output_buf_kib as u64 * 1024;
                Residency {
                    outputs: if out_fits {
                        OperandPlace::Resident
                    } else {
                        OperandPlace::Streamed
                    },
                    dram_bytes: scatter.weight_bytes + scatter.input_bytes + out_once,
                    output_bytes: out_once,
                    ..scatter
                }
            }
        }
    }
}

/// Check that the *working set* of one schedule step fits in the
/// buffers at all (hard constraint for the DSE).
pub fn working_set_fits(cfg: &AccelConfig, layer: &LayerSpec, sched: &Schedule) -> bool {
    let eb = cfg.elem_bytes();
    // weight double-buffer: 2 blocks
    let w_block = 2 * sched.mapping.out_par * sched.mapping.chan_par * layer.kernel_volume() * eb;
    // input tile double-buffer: chan_par × depth_par × (T_r·T_c) activations
    let in_tile =
        2 * sched.mapping.chan_par * sched.mapping.depth_par * cfg.tr * cfg.tc * eb;
    // output: one PE-array tile's result block per group
    let k = layer.k;
    let out_tile = sched.mapping.out_par
        * sched.mapping.depth_par
        * (cfg.tr * layer.s + k - layer.s)
        * (cfg.tc * layer.s + k - layer.s)
        * 4; // Acc48 stored as 4-byte banks per element pair, conservative
    w_block <= cfg.weight_buf_kib * 1024
        && in_tile <= cfg.input_buf_kib * 1024
        && out_tile <= cfg.output_buf_kib * 1024
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dcnn::zoo;

    #[test]
    fn dcgan_l1_weight_heavy() {
        let cfg = AccelConfig::paper_2d();
        let layer = &zoo::dcgan().layers[0];
        let sched = Schedule::new(&cfg, layer);
        let r = Residency::plan(&cfg, layer, &sched);
        // 1024·512·9·2B ≈ 9.4 MB of weights dominate
        assert_eq!(r.weight_bytes, 1024 * 512 * 9 * 2);
        assert!(r.weight_bytes > r.input_bytes);
        assert_eq!(r.inputs, OperandPlace::Resident, "4x4x1024 inputs fit");
    }

    #[test]
    fn dcgan_l4_activation_heavy() {
        let cfg = AccelConfig::paper_2d();
        let layer = &zoo::dcgan().layers[3];
        let sched = Schedule::new(&cfg, layer);
        let r = Residency::plan(&cfg, layer, &sched);
        assert!(
            r.input_bytes > r.weight_bytes,
            "layer 4 moves maps, not weights"
        );
    }

    #[test]
    fn weights_always_once() {
        for net in zoo::all_benchmarks() {
            let cfg = AccelConfig::paper_for(net.dims);
            for layer in &net.layers {
                let sched = Schedule::new(&cfg, layer);
                let r = Residency::plan(&cfg, layer, &sched);
                assert_eq!(
                    r.weight_bytes,
                    layer.weight_elems() as u64 * 2,
                    "{}",
                    layer.name
                );
            }
        }
    }

    #[test]
    fn working_sets_fit_paper_configs() {
        for net in zoo::all_benchmarks() {
            let cfg = AccelConfig::paper_for(net.dims);
            for layer in &net.layers {
                let sched = Schedule::new(&cfg, layer);
                assert!(
                    working_set_fits(&cfg, layer, &sched),
                    "{} working set must fit Table-II buffers",
                    layer.name
                );
            }
        }
    }

    #[test]
    fn gather_residency_never_spills_outputs() {
        use super::super::kernel::KernelChoice;
        for net in zoo::all_benchmarks() {
            let cfg = AccelConfig::paper_for(net.dims);
            for layer in &net.layers {
                let sched = Schedule::new(&cfg, layer);
                let s = Residency::plan_kernel(&cfg, layer, &sched, KernelChoice::Scatter);
                assert_eq!(s, Residency::plan(&cfg, layer, &sched), "{}", layer.name);
                let g = Residency::plan_kernel(&cfg, layer, &sched, KernelChoice::Gather);
                // outputs move exactly once, whatever the buffers hold
                assert_eq!(
                    g.output_bytes,
                    cfg.batch as u64 * layer.output_elems() as u64 * cfg.elem_bytes() as u64,
                    "{}",
                    layer.name
                );
                assert!(g.dram_bytes <= s.dram_bytes, "{}", layer.name);
                assert_eq!(g.weight_bytes, s.weight_bytes, "{}", layer.name);
                assert_eq!(g.input_bytes, s.input_bytes, "{}", layer.name);
            }
        }
    }

    #[test]
    fn traffic_scales_with_batch() {
        let mut cfg = AccelConfig::paper_2d();
        let layer = &zoo::dcgan().layers[3];
        let sched = Schedule::new(&cfg, layer);
        let r1 = Residency::plan(&cfg, layer, &sched);
        cfg.batch = 16;
        let sched = Schedule::new(&cfg, layer);
        let r2 = Residency::plan(&cfg, layer, &sched);
        assert_eq!(r2.input_bytes, 2 * r1.input_bytes);
        assert_eq!(r2.weight_bytes, r1.weight_bytes, "weights amortize");
    }
}
