//! Per-layer kernel choice: the paper's **scatter** pass pipeline vs
//! the zero-skip **gather** (output-stationary) evaluation of the same
//! IOM sum.
//!
//! Both kernels compute identical bits (the accumulation-order
//! contract in [`crate::func::uniform`]), so the choice is purely a
//! performance decision and the compiler makes it per layer shape:
//!
//! * **Scatter** (Fig. 5): each input activation is scattered against
//!   the whole kernel. Overlaps between neighbouring depth passes ride
//!   the FIFO-D and cost the `K²·(K−S)` stall
//!   ([`crate::accel::mapping`]), the full Eq.-(1) extent is
//!   accumulated before cropping, and when the output slice exceeds
//!   the output buffer the partial sums spill to DDR with a
//!   read-modify-write per extra input-channel block.
//! * **Gather** (the TDC formulation of arXiv:1705.02583): each
//!   *cropped* output element reads its contributor window
//!   `[⌈(z−K+1)/S⌉, ⌊z/S⌋]` per axis. Output-stationary accumulation
//!   has no depth-overlap hazard (no stall term), executes only
//!   [`LayerSpec::gather_macs`] MACs (the cropped border's taps are
//!   never computed — strictly fewer than `useful_macs` when
//!   `K > S`), and writes each output element exactly once (no
//!   read-modify-write spill, ever).
//!
//! [`choose`] scores both kernels under the full per-layer step model
//! (compute vs DDR transfer, the same terms
//! [`crate::graph::simulate_plan`] charges) and picks the cheaper,
//! ties going to scatter — deterministic by construction, which is
//! what lets the autotuner record the choice as machine-readable
//! justification and `tests/prop_dse.rs` pin that forcing the
//! non-chosen kernel never simulates faster.

use std::fmt;

use crate::dcnn::LayerSpec;

use super::buffers::Residency;
use super::config::AccelConfig;
use super::memory::DdrModel;
use super::schedule::Schedule;

/// Which kernel formulation a layer runs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum KernelChoice {
    /// The paper's input-oriented scatter pass pipeline (Fig. 5).
    #[default]
    Scatter,
    /// Zero-skip output-stationary gather over contributor windows.
    Gather,
}

impl KernelChoice {
    /// Both choices, in scoring order.
    pub const ALL: [KernelChoice; 2] = [KernelChoice::Scatter, KernelChoice::Gather];
}

impl fmt::Display for KernelChoice {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            KernelChoice::Scatter => write!(f, "scatter"),
            KernelChoice::Gather => write!(f, "gather"),
        }
    }
}

/// Compute cycles of `layer` under `kernel` on `cfg`'s mesh.
///
/// Scatter is [`Schedule::compute_cycles`] unchanged (pass pipeline
/// incl. the depth-overlap stall + fill + drain). Gather reuses the
/// same blocking walk but (a) drops the stall — output-stationary
/// accumulation has no FIFO-D hazard — and (b) scales the stall-free
/// pass cycles by `gather_macs / useful_macs`, rounding up, because
/// the cropped border's taps are never issued. The rounding keeps
/// `cycles · PEs ≥ batch · gather_macs`, so the roofline compute
/// bound over `min(useful, gather)` MACs stays a true lower bound for
/// both kernels ([`crate::accel::dse::roofline`]'s pruning-soundness
/// requirement).
pub fn compute_cycles(
    cfg: &AccelConfig,
    layer: &LayerSpec,
    sched: &Schedule,
    kernel: KernelChoice,
) -> u64 {
    match kernel {
        KernelChoice::Scatter => sched.compute_cycles(cfg),
        KernelChoice::Gather => {
            let no_stall =
                sched.total_passes() * sched.mapping.macs_per_activation as u64;
            let useful = layer.op_counts().useful_macs;
            let pass = (no_stall * layer.gather_macs()).div_ceil(useful);
            pass + sched.fill_cycles(cfg) + sched.drain_cycles(cfg)
        }
    }
}

/// Isolated step cycles of `layer` under `kernel`: compute overlapped
/// against the kernel-aware DDR traffic (gather never spills partial
/// sums), the same `max(compute, memory)` the plan simulator charges
/// per step. This is the score [`choose`] minimizes.
pub fn step_cycles(
    cfg: &AccelConfig,
    layer: &LayerSpec,
    sched: &Schedule,
    kernel: KernelChoice,
) -> u64 {
    let r = Residency::plan_kernel(cfg, layer, sched, kernel);
    let ddr = DdrModel::from_config(cfg);
    compute_cycles(cfg, layer, sched, kernel).max(ddr.transfer_cycles(r.dram_bytes, cfg.freq_mhz))
}

/// The scored per-layer kernel decision: the chosen kernel plus both
/// kernels' modeled step cycles — the machine-readable justification
/// the autotuner and the compiled plan carry.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct KernelSelection {
    /// The winning kernel (ties go to [`KernelChoice::Scatter`]).
    pub choice: KernelChoice,
    /// Modeled isolated step cycles under scatter.
    pub scatter_cycles: u64,
    /// Modeled isolated step cycles under gather.
    pub gather_cycles: u64,
}

impl KernelSelection {
    /// Modeled step cycles of one kernel.
    pub fn cycles(&self, kernel: KernelChoice) -> u64 {
        match kernel {
            KernelChoice::Scatter => self.scatter_cycles,
            KernelChoice::Gather => self.gather_cycles,
        }
    }

    /// Modeled step cycles of the chosen kernel.
    pub fn chosen_cycles(&self) -> u64 {
        self.cycles(self.choice)
    }

    /// Human-readable justification of the decision (the structured
    /// form is the two cycle fields themselves).
    pub fn reason(&self) -> String {
        match self.choice {
            KernelChoice::Gather => format!(
                "gather {} < scatter {} cycles: no depth-overlap stall, \
                 cropped-border taps skipped, outputs written once (no spill)",
                self.gather_cycles, self.scatter_cycles
            ),
            KernelChoice::Scatter => format!(
                "scatter {} <= gather {} cycles (ties keep the paper's pass pipeline)",
                self.scatter_cycles, self.gather_cycles
            ),
        }
    }
}

/// Score both kernels for `layer` on `cfg` and pick the cheaper one.
/// Pure arithmetic over the schedule — same inputs, same choice,
/// every time.
pub fn choose(cfg: &AccelConfig, layer: &LayerSpec, sched: &Schedule) -> KernelSelection {
    let scatter_cycles = step_cycles(cfg, layer, sched, KernelChoice::Scatter);
    let gather_cycles = step_cycles(cfg, layer, sched, KernelChoice::Gather);
    KernelSelection {
        choice: if gather_cycles < scatter_cycles {
            KernelChoice::Gather
        } else {
            KernelChoice::Scatter
        },
        scatter_cycles,
        gather_cycles,
    }
}

/// [`choose`] from the layer alone, deriving the schedule — the entry
/// point for host paths (the coordinator's golden forward, stream
/// sessions) that have a config but no compiled plan.
pub fn choose_for_layer(cfg: &AccelConfig, layer: &LayerSpec) -> KernelSelection {
    choose(cfg, layer, &Schedule::new(cfg, layer))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dcnn::zoo;

    #[test]
    fn gather_compute_never_exceeds_scatter_compute() {
        // same blocking walk, minus the stall, minus border taps
        for net in zoo::all_benchmarks() {
            let cfg = AccelConfig::paper_for(net.dims);
            for layer in &net.layers {
                let sched = Schedule::new(&cfg, layer);
                let g = compute_cycles(&cfg, layer, &sched, KernelChoice::Gather);
                let s = compute_cycles(&cfg, layer, &sched, KernelChoice::Scatter);
                assert!(g <= s, "{}: gather {g} > scatter {s}", layer.name);
            }
        }
    }

    #[test]
    fn gather_compute_dominates_its_mac_floor() {
        // the invariant roofline pruning rests on:
        // cycles * PEs >= batch * gather_macs
        for net in zoo::all_benchmarks() {
            let cfg = AccelConfig::paper_for(net.dims);
            for layer in &net.layers {
                let sched = Schedule::new(&cfg, layer);
                let g = compute_cycles(&cfg, layer, &sched, KernelChoice::Gather);
                let floor = (cfg.batch as u64 * layer.gather_macs())
                    .div_ceil(cfg.total_pes() as u64);
                assert!(g >= floor, "{}: {g} < floor {floor}", layer.name);
            }
        }
    }

    #[test]
    fn choice_is_deterministic_and_chosen_is_min() {
        for net in zoo::all_benchmarks() {
            let cfg = AccelConfig::paper_for(net.dims);
            for layer in &net.layers {
                let sched = Schedule::new(&cfg, layer);
                let a = choose(&cfg, layer, &sched);
                let b = choose(&cfg, layer, &sched);
                assert_eq!(a, b, "{}", layer.name);
                for k in KernelChoice::ALL {
                    assert!(
                        a.chosen_cycles() <= a.cycles(k),
                        "{}: chose {} but {} is cheaper",
                        layer.name,
                        a.choice,
                        k
                    );
                }
                assert!(!a.reason().is_empty());
            }
        }
    }

    #[test]
    fn stride2_3d_layers_prefer_gather() {
        // K=3 > S=2 in 3D: scatter pays the K^2(K-S)=9-cycle overlap
        // stall per activation; gather pays none. The model must see
        // it on every 3D zoo layer.
        let net = zoo::gan3d();
        let cfg = AccelConfig::paper_for(net.dims);
        for layer in &net.layers {
            let sel = choose_for_layer(&cfg, layer);
            assert_eq!(sel.choice, KernelChoice::Gather, "{}", layer.name);
            assert!(sel.gather_cycles < sel.scatter_cycles, "{}", layer.name);
        }
    }

    #[test]
    fn display_and_default() {
        assert_eq!(KernelChoice::Scatter.to_string(), "scatter");
        assert_eq!(KernelChoice::Gather.to_string(), "gather");
        assert_eq!(KernelChoice::default(), KernelChoice::Scatter);
    }
}
