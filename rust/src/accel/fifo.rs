//! Bounded FIFOs — the Overlap FIFOs (FIFO-V / FIFO-H / FIFO-D) and
//! Result FIFOs of the PE microarchitecture (Fig. 2, right).
//!
//! The functional simulator uses these to carry overlap products
//! between adjacent PEs; occupancy high-water marks size the hardware
//! FIFOs in the resource model.

use std::collections::VecDeque;

/// Which overlap direction a FIFO serves (Fig. 2: vertical, horizontal,
/// depth). `Depth` is disabled in 2D mode (§IV-C).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OverlapDir {
    /// Along a column's rows (FIFO-V).
    Vertical,
    /// Along a row (FIFO-H).
    Horizontal,
    /// Across depth planes (FIFO-D).
    Depth,
}

/// A bounded FIFO with occupancy statistics.
#[derive(Clone, Debug)]
pub struct Fifo<T> {
    q: VecDeque<T>,
    capacity: usize,
    /// High-water mark of occupancy over the FIFO's lifetime.
    pub max_occupancy: usize,
    /// Total number of pushes (traffic counter).
    pub total_pushes: u64,
}

/// Error returned when pushing into a full FIFO — the functional
/// simulator treats this as a hardware design error (FIFOs must be
/// sized so overlap traffic never backs up; see `sizing` tests).
#[derive(Debug, PartialEq, Eq)]
pub struct FifoFull;

impl<T> Fifo<T> {
    /// An empty FIFO with the given capacity (must be positive).
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "zero-capacity FIFO");
        Fifo {
            q: VecDeque::with_capacity(capacity),
            capacity,
            max_occupancy: 0,
            total_pushes: 0,
        }
    }

    /// Enqueue, failing with [`FifoFull`] at capacity.
    pub fn push(&mut self, v: T) -> Result<(), FifoFull> {
        if self.q.len() >= self.capacity {
            return Err(FifoFull);
        }
        self.q.push_back(v);
        self.total_pushes += 1;
        if self.q.len() > self.max_occupancy {
            self.max_occupancy = self.q.len();
        }
        Ok(())
    }

    /// Dequeue the oldest element, if any.
    pub fn pop(&mut self) -> Option<T> {
        self.q.pop_front()
    }

    /// The oldest element without dequeuing.
    pub fn peek(&self) -> Option<&T> {
        self.q.front()
    }

    /// Current occupancy.
    pub fn len(&self) -> usize {
        self.q.len()
    }

    /// Whether the FIFO is empty.
    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    /// Whether the FIFO is at capacity.
    pub fn is_full(&self) -> bool {
        self.q.len() >= self.capacity
    }

    /// Configured capacity.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Drain everything (end-of-pass flush).
    pub fn drain_all(&mut self) -> Vec<T> {
        self.q.drain(..).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_and_occupancy() {
        let mut f: Fifo<u32> = Fifo::new(4);
        f.push(1).unwrap();
        f.push(2).unwrap();
        f.push(3).unwrap();
        assert_eq!(f.max_occupancy, 3);
        assert_eq!(f.pop(), Some(1));
        assert_eq!(f.pop(), Some(2));
        f.push(4).unwrap();
        assert_eq!(f.pop(), Some(3));
        assert_eq!(f.pop(), Some(4));
        assert_eq!(f.pop(), None);
        assert_eq!(f.total_pushes, 4);
        assert_eq!(f.max_occupancy, 3, "high-water mark persists");
    }

    #[test]
    fn fifo_full_rejects() {
        let mut f: Fifo<u8> = Fifo::new(2);
        f.push(1).unwrap();
        f.push(2).unwrap();
        assert!(f.is_full());
        assert_eq!(f.push(3), Err(FifoFull));
        assert_eq!(f.len(), 2);
        assert_eq!(f.total_pushes, 2, "rejected push not counted");
    }

    #[test]
    fn drain_all_empties() {
        let mut f: Fifo<u8> = Fifo::new(8);
        for i in 0..5 {
            f.push(i).unwrap();
        }
        let all = f.drain_all();
        assert_eq!(all, vec![0, 1, 2, 3, 4]);
        assert!(f.is_empty());
    }

    #[test]
    #[should_panic(expected = "zero-capacity")]
    fn zero_capacity_panics() {
        let _: Fifo<u8> = Fifo::new(0);
    }
}
