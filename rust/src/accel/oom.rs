//! OOM (output-oriented mapping) timing model — the baseline the
//! paper's related work (refs. \[11\], \[12\]) uses and that IOM beats.
//!
//! Under OOM each PE computes one *output* pixel: it convolves a
//! `K^d` window of the zero-inserted input, multiplying every tap —
//! including the inserted zeros. Same mesh, same buffers, same DDR;
//! only the mapping discipline changes, which isolates the paper's
//! contribution in the `ablation_iom_vs_oom` bench.

use crate::dcnn::{Dims, LayerSpec};
use crate::util::ceil_div;

use super::buffers::Residency;
use super::config::AccelConfig;
use super::memory::DdrModel;
use super::metrics::{dense_equivalent_macs, BoundBy, LayerMetrics};
use super::schedule::Schedule;

/// Simulate a layer under OOM.
pub fn simulate_oom(cfg: &AccelConfig, layer: &LayerSpec) -> LayerMetrics {
    // Output-pixel tiling over the cropped output extent.
    let (chan_par, depth_par) = match layer.dims {
        Dims::D2 => (cfg.tn * cfg.tz, 1),
        Dims::D3 => (cfg.tn, cfg.tz),
    };
    let oc_blocks = ceil_div(layer.out_c, cfg.tm) as u64;
    let ic_blocks = ceil_div(layer.in_c, chan_par) as u64;
    let d_blocks = ceil_div(layer.out_d(), depth_par) as u64;
    let h_tiles = ceil_div(layer.out_h(), cfg.tr) as u64;
    let w_tiles = ceil_div(layer.out_w(), cfg.tc) as u64;
    let passes = cfg.batch as u64 * oc_blocks * ic_blocks * d_blocks * h_tiles * w_tiles;
    // every pass: K^d taps per output pixel, zeros included
    let cpa = layer.kernel_volume() as u64;
    let fill = oc_blocks * cfg.tc as u64;
    let drain =
        cfg.batch as u64 * oc_blocks * d_blocks * crate::util::ceil_log2(cfg.tn) as u64;
    let compute_cycles = passes * cpa + fill + drain;

    // identical traffic plan (same operands move)
    let sched = Schedule::new(cfg, layer);
    let res = Residency::plan(cfg, layer, &sched);
    let ddr = DdrModel::from_config(cfg);
    let memory_cycles = ddr.transfer_cycles(res.dram_bytes, cfg.freq_mhz);
    let total_cycles = compute_cycles.max(memory_cycles);

    LayerMetrics {
        layer_name: format!("{} (OOM)", layer.name),
        compute_cycles,
        memory_cycles,
        total_cycles,
        ideal_mac_cycles: cfg.batch as u64 * layer.op_counts().useful_macs,
        total_pes: cfg.total_pes(),
        batch: cfg.batch,
        dense_macs: dense_equivalent_macs(layer),
        useful_macs: layer.op_counts().useful_macs,
        dram_bytes: res.dram_bytes,
        bound_by: if memory_cycles > compute_cycles {
            BoundBy::Memory
        } else {
            BoundBy::Compute
        },
        freq_mhz: cfg.freq_mhz,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::timing;
    use crate::dcnn::zoo;

    #[test]
    fn iom_beats_oom_by_about_s_pow_d() {
        // The paper's core claim: IOM eliminates the invalid
        // multiplications, a ~S^d speedup on compute-bound layers.
        let cfg = AccelConfig::paper_2d();
        let layer = &zoo::dcgan().layers[2];
        let iom = timing::simulate(&cfg, layer);
        let oom = simulate_oom(&cfg, layer);
        let speedup = oom.total_cycles as f64 / iom.total_cycles as f64;
        assert!(
            (3.0..5.5).contains(&speedup),
            "2D IOM speedup ≈ S² = 4, got {speedup:.2}"
        );
    }

    #[test]
    fn iom_beats_oom_more_in_3d() {
        let cfg = AccelConfig::paper_3d();
        let layer = &zoo::gan3d().layers[2];
        let iom = timing::simulate(&cfg, layer);
        let oom = simulate_oom(&cfg, layer);
        let speedup = oom.total_cycles as f64 / iom.total_cycles as f64;
        assert!(
            speedup > 5.0,
            "3D IOM speedup approaches S³ = 8, got {speedup:.2}"
        );
    }

    #[test]
    fn oom_utilization_is_the_sparsity_complement() {
        // OOM PE utilization ≈ 1 − sparsity (Fig. 1 ↔ §II motivation).
        let cfg = AccelConfig::paper_2d();
        let layer = &zoo::dcgan().layers[2];
        let oom = simulate_oom(&cfg, layer);
        let util = oom.pe_utilization();
        let expected = 1.0 - layer.inserted_sparsity();
        assert!(
            (util - expected).abs() < 0.1,
            "OOM util {util:.3} vs 1−sparsity {expected:.3}"
        );
    }
}
