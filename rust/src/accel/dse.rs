//! Design-space exploration: why Table II's operating points win.
//!
//! Sweeps `(T_m, T_n, T_z, T_r, T_c)` under the VC709 resource budget
//! (DSP count caps total PEs; BRAM caps buffers — see
//! [`crate::resource`]) and ranks configurations by aggregate runtime
//! over a set of benchmark networks. The `table2_configs` bench prints
//! the resulting frontier next to the paper's chosen points.

use crate::dcnn::Network;

use super::config::AccelConfig;
use super::timing;

/// One evaluated design point.
#[derive(Clone, Debug)]
pub struct DsePoint {
    /// The configuration evaluated.
    pub cfg: AccelConfig,
    /// Total cycles across all layers of all supplied networks.
    pub total_cycles: u64,
    /// Time-weighted PE utilization.
    pub avg_utilization: f64,
    /// Whether the point fits the resource budget.
    pub fits: bool,
}

/// Constraints for the sweep.
#[derive(Clone, Copy, Debug)]
pub struct DseBudget {
    /// Max PEs (≈ DSP budget; VC709: 3600 DSP48E → the paper uses 2048
    /// PEs + adder-tree DSPs).
    pub max_pes: usize,
    /// Require `T_n` to be a power of two (adder tree).
    pub pow2_tn: bool,
}

impl Default for DseBudget {
    fn default() -> Self {
        DseBudget {
            max_pes: 2048,
            pow2_tn: true,
        }
    }
}

/// Enumerate candidate configurations.
pub fn candidates(budget: &DseBudget) -> Vec<AccelConfig> {
    let mut out = Vec::new();
    for tm in [1usize, 2, 4] {
        for tn_log in 2..=7 {
            let tn = 1usize << tn_log;
            for tz in [1usize, 2, 4, 8] {
                for tr in [2usize, 4, 8] {
                    for tc in [2usize, 4, 8] {
                        let cfg = AccelConfig {
                            tm,
                            tn,
                            tz,
                            tr,
                            tc,
                            ..AccelConfig::platform_defaults()
                        };
                        if cfg.total_pes() > budget.max_pes {
                            continue;
                        }
                        if budget.pow2_tn && !tn.is_power_of_two() {
                            continue;
                        }
                        if cfg.validate().is_ok() {
                            out.push(cfg);
                        }
                    }
                }
            }
        }
    }
    out
}

/// Evaluate one configuration over a benchmark set.
pub fn evaluate(cfg: &AccelConfig, nets: &[Network], budget: &DseBudget) -> DsePoint {
    let mut total_cycles = 0u64;
    let mut util_weighted = 0.0;
    for net in nets {
        for layer in &net.layers {
            let m = timing::simulate(cfg, layer);
            total_cycles += m.total_cycles;
            util_weighted += m.pe_utilization() * m.total_cycles as f64;
        }
    }
    DsePoint {
        cfg: cfg.clone(),
        total_cycles,
        avg_utilization: if total_cycles > 0 {
            util_weighted / total_cycles as f64
        } else {
            0.0
        },
        fits: cfg.total_pes() <= budget.max_pes,
    }
}

/// Full sweep: evaluate all candidates, best (fewest cycles) first.
pub fn sweep(nets: &[Network], budget: &DseBudget) -> Vec<DsePoint> {
    let mut points: Vec<DsePoint> = candidates(budget)
        .iter()
        .map(|c| evaluate(c, nets, budget))
        .collect();
    points.sort_by_key(|p| p.total_cycles);
    points
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dcnn::zoo;

    #[test]
    fn candidates_respect_budget() {
        let budget = DseBudget::default();
        for c in candidates(&budget) {
            assert!(c.total_pes() <= budget.max_pes);
            assert!(c.tn.is_power_of_two());
        }
    }

    #[test]
    fn paper_3d_point_is_near_optimal_for_3d_nets() {
        // Rank the paper's 3D point against the sweep on 3D benchmarks.
        let nets = [zoo::gan3d()];
        let budget = DseBudget::default();
        let points = sweep(&nets, &budget);
        let paper = evaluate(&AccelConfig::paper_3d(), &nets, &budget);
        let better = points
            .iter()
            .filter(|p| p.total_cycles < paper.total_cycles)
            .count();
        // The paper's point should sit in the top quartile of the space.
        assert!(
            better <= points.len() / 4,
            "paper 3D point beaten by {better}/{} candidates",
            points.len()
        );
    }

    #[test]
    fn full_pe_budget_beats_half() {
        let nets = [zoo::dcgan()];
        let budget = DseBudget::default();
        let full = evaluate(&AccelConfig::paper_2d(), &nets, &budget);
        let mut half_cfg = AccelConfig::paper_2d();
        half_cfg.tn = 32; // 1024 PEs
        let half = evaluate(&half_cfg, &nets, &budget);
        assert!(full.total_cycles < half.total_cycles);
    }
}
