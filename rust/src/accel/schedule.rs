//! Blocking schedule enumeration (§IV-A "we use blocking to resolve
//! this issue"; §IV-B dataflow steps).
//!
//! One **pass** = one batch of `T_r × T_c` input activations resident
//! in every active PE array, each PE performing `K^d` MACs. The
//! schedule walks:
//!
//! ```text
//! for oc_blk in ceil(N_o / out_par):          # weight barrier
//!   for ic_blk in ceil(N_c / chan_par):
//!     load W[oc_blk, ic_blk]                   # double-buffered
//!     for b in batch:
//!       for d_blk in ceil(I_D / depth_par):
//!         for (h_tile, w_tile) in spatial tiles:
//!           pass                               # K^d (+stall) cycles
//! ```
//!
//! Both simulator tiers consume this enumeration, which is what makes
//! the cross-check between them meaningful.

use crate::dcnn::LayerSpec;
use crate::util::{ceil_div, ceil_log2};

use super::config::AccelConfig;
use super::mapping::Mapping;

/// The static schedule for one layer on one configuration.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Schedule {
    /// The dimension mapping driving the walk.
    pub mapping: Mapping,
    /// `ceil(N_o / T_m)` output-channel blocks.
    pub oc_blocks: usize,
    /// `ceil(N_c / chan_par)` input-channel blocks.
    pub ic_blocks: usize,
    /// `ceil(I_D / depth_par)` depth blocks (1 for 2D).
    pub d_blocks: usize,
    /// `ceil(I_H / T_r)` × `ceil(I_W / T_c)` spatial tiles.
    pub h_tiles: usize,
    /// `ceil(I_W / T_c)` spatial tiles along the width.
    pub w_tiles: usize,
    /// Batch size folded into the walk.
    pub batch: usize,
}

impl Schedule {
    /// Enumerate the schedule of `layer` on `cfg`.
    pub fn new(cfg: &AccelConfig, layer: &LayerSpec) -> Schedule {
        let mapping = Mapping::for_layer(cfg, layer);
        Schedule {
            mapping,
            oc_blocks: ceil_div(layer.out_c, mapping.out_par),
            ic_blocks: ceil_div(layer.in_c, mapping.chan_par),
            d_blocks: ceil_div(layer.in_d, mapping.depth_par),
            h_tiles: ceil_div(layer.in_h, cfg.tr),
            w_tiles: ceil_div(layer.in_w, cfg.tc),
            batch: cfg.batch,
        }
    }

    /// Spatial tiles per (oc, ic, d) walk.
    pub fn spatial_tiles(&self) -> u64 {
        self.h_tiles as u64 * self.w_tiles as u64
    }

    /// Total passes over the whole layer (batch included).
    pub fn total_passes(&self) -> u64 {
        self.batch as u64
            * self.oc_blocks as u64
            * self.ic_blocks as u64
            * self.d_blocks as u64
            * self.spatial_tiles()
    }

    /// Compute cycles of the pass pipeline itself.
    pub fn pass_cycles(&self) -> u64 {
        self.total_passes() * self.mapping.cycles_per_activation() as u64
    }

    /// Pipeline-fill cycles: the `T_c`-column loading wavefront
    /// (Fig. 4) must refill whenever the weight set changes (an
    /// `oc_blk` boundary); within a block, double-buffered Ra/Rw hide
    /// activation loading behind compute.
    pub fn fill_cycles(&self, cfg: &AccelConfig) -> u64 {
        self.oc_blocks as u64 * cfg.tc as u64
    }

    /// Adder-tree drain: `log₂(T_n)` pipeline stages flush once per
    /// accumulation group (per oc_blk, per depth block, per batch item).
    pub fn drain_cycles(&self, cfg: &AccelConfig) -> u64 {
        let stages = ceil_log2(cfg.tn) as u64;
        self.batch as u64 * self.oc_blocks as u64 * self.d_blocks as u64 * stages
    }

    /// Total compute cycles (excluding memory waits).
    pub fn compute_cycles(&self, cfg: &AccelConfig) -> u64 {
        self.pass_cycles() + self.fill_cycles(cfg) + self.drain_cycles(cfg)
    }

    /// MAC slots actually used per pass-cycle accounting: the share of
    /// the mesh doing useful work. (Edge blocks leave PEs idle; the
    /// metric falls out of `useful_macs / (total_pes · cycles)`.)
    pub fn ideal_mac_cycles(&self, layer: &LayerSpec) -> u64 {
        self.batch as u64 * layer.op_counts().useful_macs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dcnn::zoo;

    #[test]
    fn dcgan_l1_schedule() {
        let cfg = AccelConfig::paper_2d();
        let layer = &zoo::dcgan().layers[0]; // 1024ch 4x4 -> 512
        let s = Schedule::new(&cfg, layer);
        assert_eq!(s.oc_blocks, 256);
        assert_eq!(s.ic_blocks, 16);
        assert_eq!(s.d_blocks, 1);
        assert_eq!((s.h_tiles, s.w_tiles), (1, 1));
        assert_eq!(s.total_passes(), 8 * 256 * 16);
        assert_eq!(s.pass_cycles(), 8 * 256 * 16 * 9);
    }

    #[test]
    fn gan3d_l1_schedule() {
        let cfg = AccelConfig::paper_3d();
        let layer = &zoo::gan3d().layers[0]; // 512ch 4^3 -> 256
        let s = Schedule::new(&cfg, layer);
        assert_eq!(s.oc_blocks, 128);
        assert_eq!(s.ic_blocks, 32);
        assert_eq!(s.d_blocks, 1);
        assert_eq!(s.spatial_tiles(), 1);
        assert_eq!(s.mapping.macs_per_activation, 27);
    }

    #[test]
    fn edge_blocks_round_up() {
        let cfg = AccelConfig::paper_2d();
        let layer = &zoo::dcgan().layers[3]; // out_c = 3, T_m = 2
        let s = Schedule::new(&cfg, layer);
        assert_eq!(s.oc_blocks, 2, "ceil(3/2)");
        assert_eq!(s.h_tiles, 8);
        assert_eq!(s.w_tiles, 8);
    }

    #[test]
    fn utilization_upper_bound_holds() {
        // ideal mac-cycles can never exceed pes * pass cycles
        let cfg = AccelConfig::paper_2d();
        for layer in &zoo::dcgan().layers {
            let s = Schedule::new(&cfg, layer);
            let ideal = s.ideal_mac_cycles(layer);
            let capacity = cfg.total_pes() as u64 * s.pass_cycles();
            assert!(ideal <= capacity, "{}", layer.name);
        }
    }

    #[test]
    fn perfectly_divisible_layer_saturates() {
        // DCGAN layer 1: all dims divide the blocking exactly, so
        // ideal == capacity over the pass cycles.
        let cfg = AccelConfig::paper_2d();
        let layer = &zoo::dcgan().layers[0];
        let s = Schedule::new(&cfg, layer);
        assert_eq!(
            s.ideal_mac_cycles(layer),
            cfg.total_pes() as u64 * s.pass_cycles()
        );
    }
}
