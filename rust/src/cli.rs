//! CLI argument handling (hand-rolled; the offline build has no clap).
//!
//! Kept in the library so the parser and name-resolution logic are
//! unit-testable; `rust/src/main.rs` is a thin shell over this.

use std::collections::BTreeMap;

use anyhow::{Error, Result};

use crate::dcnn::{zoo, Network};

/// Parsed options: `--key value` pairs and bare `--flag`s (stored as
/// `"true"`).
pub type Opts = BTreeMap<String, String>;

/// Parse `--key value` / `--flag` style options after the subcommand.
pub fn parse_opts(args: &[String]) -> Opts {
    let mut opts = BTreeMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(key) = a.strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                opts.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                opts.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    opts
}

/// Fetch a typed option with a default.
pub fn opt_parse<T: std::str::FromStr>(opts: &Opts, key: &str, default: T) -> Result<T>
where
    T::Err: std::fmt::Display,
{
    match opts.get(key) {
        None => Ok(default),
        Some(v) => v
            .parse()
            .map_err(|e| anyhow::anyhow!("invalid --{key} '{v}': {e}")),
    }
}

/// First bare (non-option) argument — subcommands like
/// `udcnn compile <net>` take the network positionally. `value_keys`
/// names the options that consume a value, so a boolean flag placed
/// before the positional (`compile --json dcgan`) does not swallow it.
pub fn first_positional<'a>(args: &'a [String], value_keys: &[&str]) -> Option<&'a String> {
    positionals(args, value_keys).into_iter().next()
}

/// All bare (non-option) arguments in order — subcommands like
/// `udcnn serve <net> <net>...` take several networks positionally.
/// `value_keys` names the options that consume a value (same contract
/// as [`first_positional`]).
pub fn positionals<'a>(args: &'a [String], value_keys: &[&str]) -> Vec<&'a String> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < args.len() {
        match args[i].strip_prefix("--") {
            Some(key) => {
                i += 1;
                if value_keys.contains(&key) && i < args.len() && !args[i].starts_with("--") {
                    i += 1; // skip the option's value
                }
            }
            None => {
                out.push(&args[i]);
                i += 1;
            }
        }
    }
    out
}

/// Resolve a benchmark network by (aliased) name. Thin adapter over
/// the shared [`zoo::by_name`] lookup (whose error lists the valid
/// names) so the `compile` and `serve` subcommands — and every other
/// front end — agree on the accepted spellings.
pub fn network_by_name(name: &str) -> Result<Network> {
    zoo::by_name(name).map_err(Error::msg)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_key_value_and_flags() {
        let o = parse_opts(&args(&["--net", "dcgan", "--all", "--batch", "4"]));
        assert_eq!(o["net"], "dcgan");
        assert_eq!(o["all"], "true");
        assert_eq!(o["batch"], "4");
    }

    #[test]
    fn flag_before_key_value() {
        let o = parse_opts(&args(&["--fast", "--net", "vnet"]));
        assert_eq!(o["fast"], "true");
        assert_eq!(o["net"], "vnet");
    }

    #[test]
    fn ignores_positional_noise() {
        let o = parse_opts(&args(&["positional", "--x", "1", "junk"]));
        assert_eq!(o.len(), 1);
        assert_eq!(o["x"], "1");
    }

    #[test]
    fn opt_parse_typed() {
        let o = parse_opts(&args(&["--batch", "16"]));
        let b: usize = opt_parse(&o, "batch", 8).unwrap();
        assert_eq!(b, 16);
        let d: usize = opt_parse(&o, "missing", 8).unwrap();
        assert_eq!(d, 8);
        let bad = parse_opts(&args(&["--batch", "xyz"]));
        assert!(opt_parse::<usize>(&bad, "batch", 8).is_err());
    }

    #[test]
    fn first_positional_skips_options() {
        let keys = &["batch", "net"];
        assert_eq!(
            first_positional(&args(&["--batch", "4", "dcgan", "--json"]), keys),
            Some(&"dcgan".to_string())
        );
        assert_eq!(
            first_positional(&args(&["dcgan", "--batch", "4"]), keys),
            Some(&"dcgan".to_string())
        );
        // boolean flag before the positional must not swallow it
        assert_eq!(
            first_positional(&args(&["--json", "dcgan"]), keys),
            Some(&"dcgan".to_string())
        );
        assert_eq!(first_positional(&args(&["--json", "--batch", "4"]), keys), None);
        assert_eq!(first_positional(&args(&[]), keys), None);
    }

    #[test]
    fn positionals_collects_all() {
        let keys = &["batch", "instances", "rps"];
        assert_eq!(
            positionals(&args(&["dcgan", "3d-gan", "--instances", "4"]), keys),
            vec!["dcgan", "3d-gan"]
        );
        assert_eq!(
            positionals(&args(&["--json", "dcgan", "--rps", "100", "vnet"]), keys),
            vec!["dcgan", "vnet"]
        );
        assert!(positionals(&args(&["--instances", "4"]), keys).is_empty());
    }

    #[test]
    fn network_aliases() {
        assert_eq!(network_by_name("vnet").unwrap().name, "v-net");
        assert_eq!(network_by_name("gan3d").unwrap().name, "3d-gan");
        assert_eq!(network_by_name("gpgan").unwrap().name, "gp-gan");
        assert!(network_by_name("bogus").is_err());
    }
}
