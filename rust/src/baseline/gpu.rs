//! Analytic GTX 1080 model.
//!
//! We have no CUDA device, so the GPU column of Fig. 7 comes from an
//! explicit roofline model with published constants. cuDNN executes
//! `conv_transpose` as the dense backward-data convolution over the
//! zero-inserted map (it has no zero-skipping path — exactly the
//! inefficiency the paper's related work attacks), so its *useful*
//! throughput on deconvolution is the dense rate divided by the
//! insertion ratio.

use crate::dcnn::{Dims, LayerSpec};

/// GPU platform + efficiency model.
#[derive(Clone, Copy, Debug)]
pub struct GpuModel {
    /// Peak fp32 throughput, TFLOPS (GTX 1080: 8.873).
    pub peak_tflops: f64,
    /// Memory bandwidth, GB/s (GTX 1080: 320).
    pub mem_gbps: f64,
    /// Board power, watts.
    pub watts: f64,
    /// Fraction of peak cuDNN sustains on dense 2D convolution
    /// (implicit-GEMM, K=3: ~0.45 measured in the DeepBench era).
    pub eff_2d: f64,
    /// Fraction of peak for dense 3D convolution (worse tiling: ~0.35).
    pub eff_3d: f64,
    /// Kernel-launch and framework overhead per layer, seconds.
    pub launch_overhead_s: f64,
}

impl Default for GpuModel {
    fn default() -> Self {
        GpuModel {
            peak_tflops: 8.873,
            mem_gbps: 320.0,
            watts: 180.0,
            eff_2d: 0.45,
            eff_3d: 0.35,
            launch_overhead_s: 20e-6,
        }
    }
}

impl GpuModel {
    /// Seconds for one inference (batch 1) of `layer`.
    ///
    /// Dense FLOPs over the full Eq. (1) extent at the sustained dense
    /// rate, floored by the memory roofline (inputs + weights +
    /// outputs at fp32), plus launch overhead.
    pub fn layer_seconds(&self, layer: &LayerSpec) -> f64 {
        let dense_flops = 2.0 * layer.op_counts().dense_macs as f64;
        let eff = match layer.dims {
            Dims::D2 => self.eff_2d,
            Dims::D3 => self.eff_3d,
        };
        let t_compute = dense_flops / (self.peak_tflops * 1e12 * eff);
        let bytes =
            (layer.input_elems() + layer.weight_elems() + layer.output_elems()) as f64 * 4.0;
        let t_mem = bytes / (self.mem_gbps * 1e9);
        t_compute.max(t_mem) + self.launch_overhead_s
    }

    /// Seconds for a whole network, batch `b` (weights amortized is
    /// already implicit: compute scales with b, launch overhead does
    /// not re-occur per item for batched cuDNN calls).
    pub fn network_seconds(&self, net: &crate::dcnn::Network, b: usize) -> f64 {
        net.layers
            .iter()
            .map(|l| {
                let per_item = self.layer_seconds(l) - self.launch_overhead_s;
                per_item * b as f64 + self.launch_overhead_s
            })
            .sum()
    }

    /// Dense-equivalent GOPS achieved on a network at batch `b`
    /// (same accounting as the FPGA's effective TOPS).
    pub fn network_dense_gops(&self, net: &crate::dcnn::Network, b: usize) -> f64 {
        let dense: u64 = net
            .layers
            .iter()
            .map(crate::accel::metrics::dense_equivalent_macs)
            .sum();
        2.0 * dense as f64 * b as f64 / self.network_seconds(net, b) / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dcnn::zoo;

    #[test]
    fn gpu_sustained_rate_below_peak() {
        let gpu = GpuModel::default();
        let net = zoo::dcgan();
        let gops = gpu.network_dense_gops(&net, 8);
        assert!(gops > 0.0);
        assert!(
            gops < gpu.peak_tflops * 1e3,
            "sustained {gops:.0} GOPS must stay below peak"
        );
    }

    #[test]
    fn compute_bound_layer_time_matches_roofline() {
        let gpu = GpuModel::default();
        let layer = &zoo::dcgan().layers[1];
        let t = gpu.layer_seconds(layer);
        let dense_flops = 2.0 * layer.op_counts().dense_macs as f64;
        let expect = dense_flops / (8.873e12 * 0.45) + 20e-6;
        assert!((t - expect).abs() / expect < 0.05);
    }

    #[test]
    fn memory_roofline_engages_on_thin_layers() {
        let gpu = GpuModel::default();
        // 1-channel huge map: almost no FLOPs, lots of bytes
        let thin = LayerSpec::new_2d("thin", 1, 512, 512, 1, 3, 2);
        let t = gpu.layer_seconds(&thin);
        let bytes = (thin.input_elems() + thin.weight_elems() + thin.output_elems()) as f64 * 4.0;
        assert!(t >= bytes / (320e9) , "memory floor applies");
    }

    #[test]
    fn batch_scales_compute_not_overhead() {
        let gpu = GpuModel::default();
        let net = zoo::dcgan();
        let t1 = gpu.network_seconds(&net, 1);
        let t8 = gpu.network_seconds(&net, 8);
        assert!(t8 < 8.0 * t1, "overhead amortizes");
        assert!(t8 > 6.0 * (t1 - 4.0 * gpu.launch_overhead_s));
    }

    #[test]
    fn gpu_3d_slower_than_2d_per_flop() {
        let gpu = GpuModel::default();
        let l2 = &zoo::dcgan().layers[1];
        let l3 = &zoo::gan3d().layers[1];
        let r2 = 2.0 * l2.op_counts().dense_macs as f64 / gpu.layer_seconds(l2);
        let r3 = 2.0 * l3.op_counts().dense_macs as f64 / gpu.layer_seconds(l3);
        assert!(r3 < r2, "3D efficiency factor is lower");
    }
}
