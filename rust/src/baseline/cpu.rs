//! The CPU baseline: dense OOM deconvolution (zero-insert + blocked
//! convolution), multithreaded with std::thread — the computation a
//! framework CPU backend performs for `conv_transpose`.
//!
//! Big benchmark layers (V-Net's 128³ outputs) would take minutes to
//! run repeatedly in benches, so the baseline (a) measures real
//! layers directly when their dense work is under a threshold, and
//! (b) otherwise extrapolates from the machine's measured effective
//! GFLOPS, calibrated once on a representative mid-size layer. Both
//! paths are exercised by tests; EXPERIMENTS.md states which layers
//! were measured vs extrapolated.

use std::sync::OnceLock;
use std::time::Instant;

use crate::dcnn::{LayerData, LayerSpec};
use crate::func::uniform;
use crate::tensor::{FeatureMap, Volume, WeightsOIDHW, WeightsOIHW};

/// Measured CPU execution of one layer.
#[derive(Clone, Copy, Debug)]
pub struct CpuResult {
    /// Seconds per single inference (batch 1).
    pub seconds_per_item: f64,
    /// Dense-equivalent GFLOPS achieved.
    pub dense_gflops: f64,
    /// True if directly measured (vs extrapolated).
    pub measured: bool,
}

/// The CPU baseline runner.
#[derive(Clone, Debug)]
pub struct CpuBaseline {
    /// Worker threads for the blocked convolution.
    pub threads: usize,
    /// Layers whose dense MAC count exceeds this are extrapolated.
    pub direct_limit_macs: u64,
}

impl Default for CpuBaseline {
    fn default() -> Self {
        CpuBaseline {
            threads: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4),
            direct_limit_macs: 600_000_000,
        }
    }
}

static CALIBRATED_GFLOPS: OnceLock<f64> = OnceLock::new();

impl CpuBaseline {
    /// Time one layer (batch 1). Direct measurement when affordable,
    /// else extrapolation at the calibrated effective GFLOPS.
    pub fn run_layer(&self, layer: &LayerSpec) -> CpuResult {
        let dense = 2 * crate::accel::metrics::dense_equivalent_macs(layer);
        if layer.op_counts().dense_macs <= self.direct_limit_macs {
            let secs = self.measure_layer(layer);
            CpuResult {
                seconds_per_item: secs,
                dense_gflops: dense as f64 / secs / 1e9,
                measured: true,
            }
        } else {
            let gflops = self.calibrated_gflops();
            CpuResult {
                seconds_per_item: dense as f64 / (gflops * 1e9),
                dense_gflops: gflops,
                measured: false,
            }
        }
    }

    /// Effective dense GFLOPS of this machine, measured once on a
    /// mid-size 2D layer and cached.
    pub fn calibrated_gflops(&self) -> f64 {
        *CALIBRATED_GFLOPS.get_or_init(|| {
            let probe = LayerSpec::new_2d("cpu.calib", 64, 16, 16, 64, 3, 2);
            let secs = self.measure_layer(&probe);
            let dense = 2 * crate::accel::metrics::dense_equivalent_macs(&probe);
            dense as f64 / secs / 1e9
        })
    }

    /// Direct wall-clock measurement of one inference — one
    /// dimension-uniform code path (2D runs as the depth-1 fold).
    pub fn measure_layer(&self, layer: &LayerSpec) -> f64 {
        let data = LayerData::synth(layer, 0xC0FFEE);
        let input = data.uniform_input();
        let weights = data.uniform_weights();
        let t0 = Instant::now();
        let out = uniform::deconv_oom_threaded(&input, &weights, layer.s, self.threads);
        std::hint::black_box(out.data()[0]);
        t0.elapsed().as_secs_f64()
    }

    /// Multithreaded 2D OOM deconvolution: the depth-1 fold of
    /// [`uniform::deconv_oom_threaded`] (output channels sharded across
    /// scoped threads over a single shared zero-inserted map).
    pub fn deconv2d_threaded(
        &self,
        input: &FeatureMap<f32>,
        w: &WeightsOIHW<f32>,
        s: usize,
    ) -> FeatureMap<f32> {
        uniform::deconv_oom_threaded(&input.to_volume(), &w.to_oidhw(), s, self.threads)
            .into_feature_map()
    }

    /// Multithreaded 3D OOM deconvolution (filter-sharded).
    pub fn deconv3d_threaded(
        &self,
        input: &Volume<f32>,
        w: &WeightsOIDHW<f32>,
        s: usize,
    ) -> Volume<f32> {
        uniform::deconv_oom_threaded(input, w, s, self.threads)
    }

    /// Normalize a measured time to the paper's CPU: scale by the
    /// peak-FLOPS ratio between this host and a ten-core E5 v2 at
    /// 2.8 GHz (10 cores × 2.8 GHz × 16 f32 FLOP/cycle = 448 GFLOPS).
    pub fn normalize_to_e5(&self, seconds: f64, host_peak_gflops: f64) -> f64 {
        seconds * host_peak_gflops / E5_PEAK_GFLOPS
    }
}

/// Peak f32 throughput of the paper's CPU (ten-core E5 v2, 2.8 GHz,
/// AVX: 16 FLOP/cycle/core).
pub const E5_PEAK_GFLOPS: f64 = 448.0;

/// Effective dense-convolution throughput we credit the paper's CPU
/// baseline with: ~1/3 of peak, typical for MKL/OpenMP direct
/// convolution of these shapes. Used to present Fig. 7 ratios on the
/// paper's own hardware scale next to the host-measured ratios.
pub const E5_EFFECTIVE_GFLOPS: f64 = 150.0;

/// Modelled seconds for the paper's CPU to execute `dense_flops`.
pub fn e5_seconds(dense_flops: f64) -> f64 {
    dense_flops / (E5_EFFECTIVE_GFLOPS * 1e9)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dcnn::zoo;
    use crate::func::{deconv2d_oom, deconv3d_oom};
    use crate::util::Prng;

    #[test]
    fn threaded_matches_single_2d() {
        let mut rng = Prng::new(3);
        let mut input = FeatureMap::zeros(3, 5, 4);
        rng.fill_f32(input.data_mut(), -1.0, 1.0);
        let mut w = WeightsOIHW::zeros(5, 3, 3, 3);
        rng.fill_f32(w.data_mut(), -1.0, 1.0);
        let base = CpuBaseline {
            threads: 4,
            ..Default::default()
        };
        let a = base.deconv2d_threaded(&input, &w, 2);
        let b = deconv2d_oom(&input, &w, 2);
        assert_eq!(a.data().len(), b.data().len());
        for (x, y) in a.data().iter().zip(b.data()) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn threaded_matches_single_3d() {
        let mut rng = Prng::new(5);
        let mut input = Volume::zeros(2, 3, 3, 3);
        rng.fill_f32(input.data_mut(), -1.0, 1.0);
        let mut w = WeightsOIDHW::zeros(3, 2, 3, 3, 3);
        rng.fill_f32(w.data_mut(), -1.0, 1.0);
        let base = CpuBaseline {
            threads: 3,
            ..Default::default()
        };
        let a = base.deconv3d_threaded(&input, &w, 2);
        let b = deconv3d_oom(&input, &w, 2);
        for (x, y) in a.data().iter().zip(b.data()) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn small_layers_measured_directly() {
        let base = CpuBaseline::default();
        let r = base.run_layer(&zoo::tiny_2d().layers[0]);
        assert!(r.measured);
        assert!(r.seconds_per_item > 0.0);
        assert!(r.dense_gflops > 0.0);
    }

    #[test]
    fn huge_layers_extrapolate() {
        let base = CpuBaseline::default();
        let big = &zoo::vnet().layers[3]; // 3.6 G useful MACs
        let r = base.run_layer(big);
        assert!(!r.measured);
        assert!(r.seconds_per_item > 0.0);
    }

    #[test]
    fn normalization_direction() {
        let base = CpuBaseline::default();
        // a slower host (lower peak) maps to a SHORTER normalized time
        let n = base.normalize_to_e5(1.0, 224.0);
        assert!((n - 0.5).abs() < 1e-12);
    }
}
