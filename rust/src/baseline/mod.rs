//! Comparison platforms for Fig. 7.
//!
//! * [`cpu`] — a real, multithreaded CPU implementation of the
//!   benchmark layers, *measured* on the host. The paper used a
//!   ten-core E5 at 2.8 GHz; ratios depend on the CPU generation, so
//!   EXPERIMENTS.md reports both raw-measured and peak-normalized
//!   ratios (see `cpu::CpuBaseline::normalize_to_e5`).
//! * [`gpu`] — an analytic GTX 1080 model (we have no CUDA device):
//!   published peak numbers × cuDNN efficiency factors. All model
//!   parameters are in one struct so the claim is auditable.

pub mod cpu;
pub mod gpu;

pub use cpu::CpuBaseline;
pub use gpu::GpuModel;
